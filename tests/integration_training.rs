//! Integration: end-to-end training across backends and noise modes on the
//! `small` (784-128-128-10) config with real synthetic digits.

use std::sync::Arc;

use photonic_dfa::dfa::config::{Algorithm, TrainConfig};
use photonic_dfa::dfa::noise_model::NoiseMode;
use photonic_dfa::dfa::trainer::Trainer;
use photonic_dfa::photonics::BpdMode;
use photonic_dfa::runtime::Engine;

fn engine() -> Option<Arc<Engine>> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json")
        .exists()
        .then(|| Arc::new(Engine::new(dir).unwrap()))
}

fn base_cfg() -> TrainConfig {
    TrainConfig {
        config: "small".into(),
        epochs: 2,
        n_train: 1024,
        n_test: 512,
        seed: 7,
        max_steps_per_epoch: Some(12),
        ..TrainConfig::default()
    }
}

#[test]
fn dfa_clean_learns_digits() {
    let Some(engine) = engine() else { return };
    let mut t = Trainer::new(engine, base_cfg()).unwrap();
    let (train, test) = t.load_data().unwrap();
    let res = t.train(train, test, |_| {}).unwrap();
    assert!(
        res.history.last().unwrap().train_loss < res.history[0].train_loss,
        "{:?}",
        res.history.iter().map(|h| h.train_loss).collect::<Vec<_>>()
    );
    assert!(res.test_acc > 0.25, "better than chance: {}", res.test_acc);
}

#[test]
fn noise_modes_all_train() {
    let Some(engine) = engine() else { return };
    for noise in [
        NoiseMode::offchip(),
        NoiseMode::onchip(),
        NoiseMode::Resolution { bits: 4.0 },
        NoiseMode::Quantized { bits: 6.0 },
    ] {
        let cfg = TrainConfig { noise, ..base_cfg() };
        let mut t = Trainer::new(engine.clone(), cfg).unwrap();
        let (train, test) = t.load_data().unwrap();
        let res = t.train(train, test, |_| {}).unwrap();
        assert!(
            res.history.last().unwrap().train_loss.is_finite(),
            "{noise:?} diverged"
        );
    }
}

#[test]
fn backprop_beats_chance_too() {
    let Some(engine) = engine() else { return };
    let cfg = TrainConfig { algorithm: Algorithm::Backprop, ..base_cfg() };
    let mut t = Trainer::new(engine, cfg).unwrap();
    let (train, test) = t.load_data().unwrap();
    let res = t.train(train, test, |_| {}).unwrap();
    assert!(res.test_acc > 0.25, "{}", res.test_acc);
}

#[test]
fn device_mode_end_to_end() {
    // the full stack: fwd artifact -> photonic bank gradient -> apply_grads
    let Some(engine) = engine() else { return };
    let cfg = TrainConfig {
        noise: NoiseMode::Device { bpd: BpdMode::OffChip },
        epochs: 1,
        max_steps_per_epoch: Some(4),
        n_train: 512,
        n_test: 256,
        ..base_cfg()
    };
    let mut t = Trainer::new(engine, cfg).unwrap();
    let (train, test) = t.load_data().unwrap();
    let res = t.train(train, test, |_| {}).unwrap();
    assert_eq!(res.history.len(), 1);
    assert!(res.history[0].train_loss.is_finite());
}

#[test]
fn device_mode_rejects_backprop() {
    let Some(engine) = engine() else { return };
    let cfg = TrainConfig {
        algorithm: Algorithm::Backprop,
        noise: NoiseMode::Device { bpd: BpdMode::Ideal },
        ..base_cfg()
    };
    assert!(Trainer::new(engine, cfg).is_err());
}

#[test]
fn training_is_reproducible_per_seed() {
    let Some(engine) = engine() else { return };
    let run = |seed: u64| {
        let cfg = TrainConfig {
            seed,
            epochs: 1,
            noise: NoiseMode::offchip(),
            ..base_cfg()
        };
        let mut t = Trainer::new(engine.clone(), cfg).unwrap();
        let (train, test) = t.load_data().unwrap();
        t.train(train, test, |_| {}).unwrap().test_acc
    };
    assert_eq!(run(3), run(3));
    assert_ne!(run(3), run(4));
}
