//! Integration: end-to-end training across backends and noise modes on the
//! `small` (784-128-128-10) config with real synthetic digits.
//!
//! Runs on whichever backend `Backend::Auto` resolves — the pure-Rust
//! native engine on a clean machine, PJRT when built with
//! `--features pjrt` over compiled artifacts — so tier-1 always drives
//! real training steps.

use std::sync::Arc;

use photonic_dfa::dfa::config::{Algorithm, TrainConfig};
use photonic_dfa::dfa::noise_model::NoiseMode;
use photonic_dfa::dfa::trainer::Trainer;
use photonic_dfa::photonics::BpdMode;
use photonic_dfa::runtime::{self, Backend, StepEngine};

fn engine() -> Arc<dyn StepEngine> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    runtime::open(dir, Backend::Auto).unwrap()
}

fn base_cfg() -> TrainConfig {
    TrainConfig {
        config: "small".into(),
        epochs: 2,
        n_train: 1024,
        n_test: 512,
        seed: 7,
        max_steps_per_epoch: Some(12),
        ..TrainConfig::default()
    }
}

#[test]
fn dfa_clean_learns_digits() {
    let mut t = Trainer::new(engine(), base_cfg()).unwrap();
    let (train, test) = t.load_data().unwrap();
    let res = t.train(train, test, |_| {}).unwrap();
    assert!(
        res.history.last().unwrap().train_loss < res.history[0].train_loss,
        "{:?}",
        res.history.iter().map(|h| h.train_loss).collect::<Vec<_>>()
    );
    assert!(res.test_acc > 0.25, "better than chance: {}", res.test_acc);
}

#[test]
fn dfa_full_epoch_on_default_backend() {
    // the acceptance path: a whole epoch (no step cap) of the small config
    // on synthetic digits, through whichever engine the default build has
    let cfg = TrainConfig {
        epochs: 1,
        n_train: 512,
        n_test: 256,
        max_steps_per_epoch: None,
        ..base_cfg()
    };
    let mut t = Trainer::new(engine(), cfg).unwrap();
    let (train, test) = t.load_data().unwrap();
    let res = t.train(train, test, |_| {}).unwrap();
    assert_eq!(res.history.len(), 1);
    assert_eq!(res.history[0].steps, 512 / t.dims().batch);
    assert!(res.history[0].train_loss.is_finite());
    assert!(res.photonic_macs > 0);
}

#[test]
fn noise_modes_all_train() {
    let engine = engine();
    for noise in [
        NoiseMode::offchip(),
        NoiseMode::onchip(),
        NoiseMode::Resolution { bits: 4.0 },
        NoiseMode::Quantized { bits: 6.0 },
    ] {
        let cfg = TrainConfig { noise, ..base_cfg() };
        let mut t = Trainer::new(engine.clone(), cfg).unwrap();
        let (train, test) = t.load_data().unwrap();
        let res = t.train(train, test, |_| {}).unwrap();
        assert!(
            res.history.last().unwrap().train_loss.is_finite(),
            "{noise:?} diverged"
        );
    }
}

#[test]
fn backprop_beats_chance_too() {
    let cfg = TrainConfig { algorithm: Algorithm::Backprop, ..base_cfg() };
    let mut t = Trainer::new(engine(), cfg).unwrap();
    let (train, test) = t.load_data().unwrap();
    let res = t.train(train, test, |_| {}).unwrap();
    assert!(res.test_acc > 0.25, "{}", res.test_acc);
}

#[test]
fn device_mode_end_to_end() {
    // the full stack: fwd artifact -> photonic bank gradient -> apply_grads
    let cfg = TrainConfig {
        noise: NoiseMode::Device { bpd: BpdMode::OffChip },
        epochs: 1,
        max_steps_per_epoch: Some(4),
        n_train: 512,
        n_test: 256,
        ..base_cfg()
    };
    let mut t = Trainer::new(engine(), cfg).unwrap();
    let (train, test) = t.load_data().unwrap();
    let res = t.train(train, test, |_| {}).unwrap();
    assert_eq!(res.history.len(), 1);
    assert!(res.history[0].train_loss.is_finite());
}

#[test]
fn device_mode_rejects_backprop() {
    let cfg = TrainConfig {
        algorithm: Algorithm::Backprop,
        noise: NoiseMode::Device { bpd: BpdMode::Ideal },
        ..base_cfg()
    };
    assert!(Trainer::new(engine(), cfg).is_err());
}

#[test]
fn training_is_reproducible_per_seed() {
    let engine = engine();
    let run = |seed: u64| {
        let cfg = TrainConfig {
            seed,
            epochs: 1,
            noise: NoiseMode::offchip(),
            ..base_cfg()
        };
        let mut t = Trainer::new(engine.clone(), cfg).unwrap();
        let (train, test) = t.load_data().unwrap();
        t.train(train, test, |_| {}).unwrap().test_acc
    };
    assert_eq!(run(3), run(3));
    assert_ne!(run(3), run(4));
}

#[test]
fn checkpoint_roundtrip_and_resume_reproduce_the_trajectory() {
    use photonic_dfa::dfa::checkpoint::Checkpoint;

    // A: uninterrupted 4-epoch run, recording the loss trajectory
    let engine = engine();
    let four_epochs = TrainConfig { epochs: 4, ..base_cfg() };
    let mut full = Trainer::new(engine.clone(), four_epochs.clone()).unwrap();
    let (train, test) = full.load_data().unwrap();
    let full_res = full.train(train.clone(), test.clone(), |_| {}).unwrap();
    assert_eq!(full_res.history.len(), 4);

    // B: same run stopped after 2 epochs, checkpointed through disk
    let dir = std::env::temp_dir().join("pdfa_resume_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("epoch2.ckpt");
    let mut head =
        Trainer::new(engine.clone(), TrainConfig { epochs: 2, ..base_cfg() }).unwrap();
    head.train(train.clone(), test.clone(), |_| {}).unwrap();
    head.save_checkpoint(&path).unwrap();

    // save -> load -> save is byte-identical
    let bytes = std::fs::read(&path).unwrap();
    let loaded = Checkpoint::load(&path).unwrap();
    assert_eq!(loaded.to_bytes(), bytes);
    assert_eq!(loaded.epoch, 2);
    assert_eq!(loaded.state.to_bytes(), head.state.to_bytes());

    // C: resume B from disk and finish epochs 3..4
    let mut tail = Trainer::new(engine, four_epochs).unwrap();
    tail.restore(&loaded).unwrap();
    let tail_res = tail.train(train, test, |_| {}).unwrap();
    assert_eq!(tail_res.history.len(), 2);
    for (resumed, original) in tail_res.history.iter().zip(&full_res.history[2..]) {
        assert_eq!(resumed.epoch, original.epoch);
        assert_eq!(
            resumed.train_loss, original.train_loss,
            "epoch {} loss diverged after resume",
            resumed.epoch
        );
        assert_eq!(resumed.train_acc, original.train_acc);
    }
    // and the final parameter state is bit-identical to the straight run
    assert_eq!(tail.state.to_bytes(), full.state.to_bytes());
    assert_eq!(tail_res.test_acc, full_res.test_acc);
}

#[test]
fn native_trainer_is_bit_identical_to_a_pure_reference_loop() {
    // The strongest end-to-end pin: drive the full Trainer (coordinator
    // pipeline, native engine, state plumbing) and independently re-run
    // the identical protocol with nothing but `dfa::reference` math and
    // the documented RNG discipline (seed -> init -> feedback -> one
    // fork per epoch). The final parameter state must agree bit-for-bit;
    // any divergence between NativeEngine and the reference, or any
    // silent reordering in the batch pipeline, trips this.
    use photonic_dfa::data::Batcher;
    use photonic_dfa::dfa::params::NetState;
    use photonic_dfa::dfa::reference;
    use photonic_dfa::tensor::Tensor;
    use photonic_dfa::util::rng::Pcg64;

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let native = runtime::open(&dir, Backend::Native).unwrap();
    let cfg = base_cfg(); // NoiseMode::Clean: no noise draws on either side
    let mut t = Trainer::new(native, cfg.clone()).unwrap();
    let (train, test) = t.load_data().unwrap();
    t.train(train.clone(), test, |_| {}).unwrap();

    let dims = t.dims().clone();
    let mut rng = Pcg64::seed(cfg.seed);
    let mut state = NetState::init(&dims, &mut rng);
    let (b1, b2) = NetState::init_feedback(&dims, &mut rng);
    let zeros1 = Tensor::zeros(&[dims.d_h1, dims.batch]);
    let zeros2 = Tensor::zeros(&[dims.d_h2, dims.batch]);
    for epoch in 1..=cfg.epochs {
        let mut erng = rng.fork(epoch as u64);
        for (step, idx) in Batcher::new(train.len(), dims.batch, &mut erng).enumerate() {
            if step >= cfg.max_steps_per_epoch.unwrap() {
                break;
            }
            let (x, y) = train.batch(&idx);
            reference::dfa_step(
                &mut state.tensors, &b1, &b2, &x, &y, &zeros1, &zeros2,
                0.0, 0.0, cfg.lr, cfg.momentum,
            );
        }
    }
    assert_eq!(t.state.to_bytes(), state.to_bytes());
}
