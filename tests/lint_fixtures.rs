//! Fixture tests for `pdfa lint` (`photonic_dfa::analysis`).
//!
//! Every rule gets at least one positive fixture (the violation is
//! flagged, by name) and one negative fixture (compliant or suppressed
//! code stays quiet), plus lexer edge cases — multi-line strings, raw
//! strings and block comments that *contain* banned spellings must not
//! trip the rules. The call-graph sections pin the resolution contract:
//! shadowed names bind by module, dot calls bind methods (never free
//! fns), closures attribute to their enclosing fn, recursion
//! terminates, `boundary`/call-site `allow` pragmas stop transitive
//! descent, and lock-order cycles are caught across call edges. The
//! final test self-hosts: the crate's own tree (sources plus the
//! relaxed `benches/`/`tests/` walk) must lint clean, which is exactly
//! what CI enforces via `pdfa lint --json LINT.json --baseline LINT.json`.

use photonic_dfa::analysis::rules::{
    ATOMIC_ORDERING, DETERMINISM_TAINT, HOT_PATH_ALLOC, KEYED_RNG_ONLY,
    LOCK_ORDER, NO_RAW_THREAD_CAP, NO_WALLCLOCK, PANIC_FREE_SERVE,
};
use photonic_dfa::analysis::{lint_repo, lint_source, lint_sources, Diag, RULES};

/// Lint `src` under a neutral path (no allowlisted suffixes).
fn lint(src: &str) -> Vec<Diag> {
    lint_source("src/fixture.rs", src)
}

fn rule_names(diags: &[Diag]) -> Vec<&'static str> {
    diags.iter().map(|d| d.rule).collect()
}

// ---------------------------------------------------------------- hot-path-alloc

#[test]
fn hot_path_alloc_flags_every_banned_form() {
    let src = r#"
// lint: hot-path
fn hot(xs: &[f32], n: usize) -> Vec<f32> {
    let a = xs.to_vec();
    let b = a.clone();
    let c: Vec<f32> = b.iter().copied().collect();
    let d = Vec::with_capacity(n);
    let e: Vec<f32> = Vec::new();
    let f = Box::new(0.0f32);
    let g = String::from("x");
    let h = format!("{n}");
    let i = vec![0.0f32; n];
    e
}
"#;
    let diags = lint(src);
    let rules = rule_names(&diags);
    assert_eq!(rules.len(), 9, "{diags:?}");
    assert!(rules.iter().all(|r| *r == HOT_PATH_ALLOC), "{diags:?}");
    // findings carry the offending spelling and the fn name
    assert!(diags.iter().any(|d| d.msg.contains("`vec!`")), "{diags:?}");
    assert!(diags.iter().all(|d| d.msg.contains("`hot`")), "{diags:?}");
}

#[test]
fn hot_path_alloc_ignores_unmarked_fns_and_lookalike_idents() {
    // same body, no `// lint: hot-path` pragma → out of scope
    let unmarked = r#"
fn cold(xs: &[f32]) -> Vec<f32> { xs.to_vec() }
"#;
    assert!(lint(unmarked).is_empty());

    // `clone`/`new`/`from` only count as the banned call forms:
    // `try_clone`, `Pcg64::new`, `f32::from` and a bare `new` field are
    // different tokens or path heads
    let lookalike = r#"
// lint: hot-path
fn hot(s: &Sock, x: u16) -> f32 {
    let _dup = s.try_clone();
    let _rng = Pcg64::new(1, 2);
    let _v = f32::from(x);
    let _s = String::new();
    0.0
}
"#;
    assert!(lint(lookalike).is_empty(), "{:?}", lint(lookalike));
}

// ---------------------------------------------------------------- no-raw-thread-cap

#[test]
fn raw_thread_cap_call_is_flagged_anywhere() {
    let src = r#"
fn sneaky(n: usize) {
    crate::tensor::ops::set_thread_cap(Some(n));
}
"#;
    let diags = lint(src);
    assert_eq!(rule_names(&diags), [NO_RAW_THREAD_CAP], "{diags:?}");
    assert_eq!(diags[0].line, 3);
}

#[test]
fn thread_cap_declaration_import_and_home_module_are_exempt() {
    // the declaration and a `use` import carry no call parens
    let decl = r#"
use crate::tensor::ops::set_thread_cap;
pub fn set_thread_cap(cap: Option<usize>) { CAP.store(pack(cap)); }
"#;
    assert!(lint(decl).is_empty(), "{:?}", lint(decl));

    // the defining module may call it (ThreadCapGuard lives there)
    let home = r#"
fn guard_drop() { set_thread_cap(self.prev); }
"#;
    assert!(lint_source("rust/src/tensor/ops.rs", home).is_empty());
}

// ---------------------------------------------------------------- keyed-rng-only

#[test]
fn seeded_rng_in_rng_region_is_flagged() {
    let src = r#"
// lint: rng-region
fn shard(row: usize, seed: u64) -> f32 {
    let mut a = Pcg64::seed(seed + row as u64);
    let mut b = Pcg64::new(seed, row as u64);
    let mut c = Pcg64::fork(7);
    a.uniform()
}
"#;
    let diags = lint(src);
    assert_eq!(
        rule_names(&diags),
        [KEYED_RNG_ONLY, KEYED_RNG_ONLY, KEYED_RNG_ONLY],
        "{diags:?}"
    );
    assert!(diags[0].msg.contains("Pcg64::seed"), "{diags:?}");
}

#[test]
fn keyed_rng_and_out_of_region_seeding_stay_quiet() {
    // `Pcg64::keyed` is the sanctioned constructor inside a region
    let keyed = r#"
// lint: rng-region
fn shard(row: usize, seed: u64) -> f32 {
    let mut rng = Pcg64::keyed(seed, 0, row as u64);
    rng.uniform()
}
"#;
    assert!(lint(keyed).is_empty(), "{:?}", lint(keyed));

    // sequential seeding outside any rng-region fn is fine (e.g. the
    // trainer's top-level init)
    let outside = r#"
fn init(seed: u64) -> Pcg64 { Pcg64::seed(seed) }
"#;
    assert!(lint(outside).is_empty());
}

// ---------------------------------------------------------------- panic-free-serve

#[test]
fn thread_body_panics_and_unguarded_indexing_are_flagged() {
    let src = r#"
// lint: thread-body
fn conn_loop(q: &Queue, xs: &[f32], i: usize) {
    let job = q.pop().unwrap();
    let slot = q.slot().expect("slot");
    if xs.is_empty() { panic!("empty"); }
    let x = xs[i];
    match job { _ => unreachable!() }
}
"#;
    let diags = lint(src);
    let rules = rule_names(&diags);
    assert_eq!(rules.len(), 5, "{diags:?}");
    assert!(rules.iter().all(|r| *r == PANIC_FREE_SERVE), "{diags:?}");
    assert!(diags.iter().any(|d| d.msg.contains("index expression")), "{diags:?}");
}

#[test]
fn guarded_indexing_and_non_index_brackets_stay_quiet() {
    let src = r#"
// lint: thread-body
fn conn_loop(xs: &[f32], i: usize) -> f32 {
    // array literals, slice patterns and `for … in [..]` are not
    // index expressions
    let ys = [0.0f32; 4];
    for _v in [1, 2, 3] { }
    // lint: guarded: loop condition pins i < xs.len()
    let x = xs[i];
    x + ys.iter().sum::<f32>()
}
"#;
    assert!(lint(src).is_empty(), "{:?}", lint(src));

    // unwrap outside any thread-body fn is out of scope
    let outside = "fn main_path(v: Option<u32>) -> u32 { v.unwrap() }\n";
    assert!(lint(outside).is_empty());
}

// ---------------------------------------------------------------- no-wallclock-in-determinism

#[test]
fn wallclock_reads_are_flagged_without_a_timing_pragma() {
    let src = r#"
fn step() -> f64 {
    let t0 = std::time::Instant::now();
    let _wall = std::time::SystemTime::now();
    t0.elapsed().as_secs_f64()
}
"#;
    let diags = lint(src);
    assert_eq!(rule_names(&diags), [NO_WALLCLOCK, NO_WALLCLOCK], "{diags:?}");
    assert!(diags[0].msg.contains("Instant::now"), "{diags:?}");
    assert!(diags[1].msg.contains("SystemTime::now"), "{diags:?}");
}

#[test]
fn timing_pragma_type_positions_and_benchx_are_exempt() {
    let pragma = r#"
fn step() -> f64 {
    // lint: timing: epoch wall-clock for the report line
    let t0 = Instant::now();
    t0.elapsed().as_secs_f64()
}
"#;
    assert!(lint(pragma).is_empty(), "{:?}", lint(pragma));

    // `Instant` in type position (no `::now` after it) is not a read
    let typed = "fn wait_until(deadline: Instant) -> bool { later(deadline) }\n";
    assert!(lint(typed).is_empty());

    // the bench harness and coordinator own wallclock wholesale
    let raw = "fn t() -> Instant { Instant::now() }\n";
    assert!(lint_source("rust/src/util/benchx.rs", raw).is_empty());
    assert!(lint_source("rust/src/coordinator/loops.rs", raw).is_empty());
    // …but the same code elsewhere is flagged
    assert_eq!(lint_source("rust/src/dfa/x.rs", raw).len(), 1);
}

// ---------------------------------------------------------------- atomic-ordering-audit

#[test]
fn strict_orderings_need_a_written_justification() {
    let src = r#"
fn publish(flag: &AtomicBool) {
    flag.store(true, Ordering::SeqCst);
    let _seen = flag.load(Ordering::Acquire);
}
"#;
    let diags = lint(src);
    assert_eq!(
        rule_names(&diags),
        [ATOMIC_ORDERING, ATOMIC_ORDERING],
        "{diags:?}"
    );
    assert!(diags[0].msg.contains("SeqCst"), "{diags:?}");
}

#[test]
fn justified_and_relaxed_orderings_stay_quiet() {
    let src = r#"
fn publish(flag: &AtomicBool, n: &AtomicU64) {
    // lint: ordering: release-publishes the queue write; pairs with
    // the Acquire load in the consumer
    flag.store(true, Ordering::Release);
    n.fetch_add(1, Ordering::Relaxed);
}
"#;
    assert!(lint(src).is_empty(), "{:?}", lint(src));

    // a bare `// lint: ordering` with no written reason does NOT count
    let bare = r#"
fn publish(flag: &AtomicBool) {
    // lint: ordering
    flag.store(true, Ordering::Release);
}
"#;
    assert_eq!(rule_names(&lint(bare)), [ATOMIC_ORDERING]);
}

// ---------------------------------------------------------------- suppression mechanics

#[test]
fn fn_level_allow_suppresses_only_the_named_rule() {
    let src = r#"
// lint: hot-path
// lint: thread-body
// lint: allow(hot-path-alloc) — fixture: exercises selective fn allow
fn mixed(xs: &[f32]) -> Vec<f32> {
    let v = xs.to_vec();
    v.first().copied().unwrap();
    v
}
"#;
    // the alloc is allowed; the unwrap is still a panic-free-serve hit
    assert_eq!(rule_names(&lint(src)), [PANIC_FREE_SERVE]);
}

#[test]
fn bare_fn_allow_without_a_written_contract_is_inert() {
    let src = r#"
// lint: hot-path
// lint: allow(hot-path-alloc)
fn hot(xs: &[f32]) -> Vec<f32> { xs.to_vec() }
"#;
    assert_eq!(rule_names(&lint(src)), [HOT_PATH_ALLOC]);
}

#[test]
fn line_level_allow_covers_its_line_and_the_next_code_line() {
    // pragma on the comment line directly above (with a free-text reason)
    let above = r#"
// lint: hot-path
fn hot(xs: &[f32]) -> Vec<f32> {
    // lint: allow(hot-path-alloc) — cold path, runs once at startup
    let v = xs.to_vec();
    v
}
"#;
    assert!(lint(above).is_empty(), "{:?}", lint(above));

    // trailing pragma on the flagged line itself
    let trailing = r#"
// lint: hot-path
fn hot(xs: &[f32]) -> Vec<f32> {
    xs.to_vec() // lint: allow(hot-path-alloc) — cold path
}
"#;
    assert!(lint(trailing).is_empty(), "{:?}", lint(trailing));

    // a line allow does NOT leak past the next code line
    let leak = r#"
// lint: hot-path
fn hot(xs: &[f32]) -> Vec<f32> {
    // lint: allow(hot-path-alloc) — covers only the next line
    let a = xs.to_vec();
    let b = xs.to_vec();
    b
}
"#;
    let diags = lint(leak);
    assert_eq!(rule_names(&diags), [HOT_PATH_ALLOC], "{diags:?}");
    assert_eq!(diags[0].line, 6);

    // allow(<other-rule>) does not suppress this rule
    let wrong = r#"
// lint: hot-path
fn hot(xs: &[f32]) -> Vec<f32> {
    // lint: allow(keyed-rng-only) — wrong rule name
    xs.to_vec()
}
"#;
    assert_eq!(rule_names(&lint(wrong)), [HOT_PATH_ALLOC]);
}

#[test]
fn cfg_test_modules_are_exempt_from_every_rule() {
    let src = r#"
fn live() {}

#[cfg(test)]
mod tests {
    // lint: hot-path
    // lint: thread-body
    fn helper(xs: &[f32], i: usize) -> f32 {
        let t0 = Instant::now();
        crate::tensor::ops::set_thread_cap(Some(1));
        let v = xs.to_vec();
        v.first().unwrap();
        xs[i]
    }
}
"#;
    assert!(lint(src).is_empty(), "{:?}", lint(src));
}

// ---------------------------------------------------------------- lexer edge cases

#[test]
fn banned_spellings_inside_strings_are_not_code() {
    let src = r##"
// lint: hot-path
// lint: thread-body
fn hot() -> &'static str {
    let _multi = "line one
        Instant::now() panic!(oops) xs.to_vec()
        line three";
    let _raw = r#"format!("{}") Ordering::SeqCst set_thread_cap(4)"#;
    let _esc = "escaped \" quote then unwrap() and vec![0; 4]";
    "ok"
}
"##;
    assert!(lint(src).is_empty(), "{:?}", lint(src));
}

#[test]
fn banned_spellings_inside_block_comments_are_not_code() {
    let src = r#"
// lint: hot-path
fn hot() -> f32 {
    /* a block comment spanning lines:
       xs.to_vec(); Vec::new(); panic!("no");
       /* nested: Instant::now() still a comment */
       Ordering::SeqCst
    */
    0.0
}
"#;
    assert!(lint(src).is_empty(), "{:?}", lint(src));
}

#[test]
fn multiline_strings_do_not_desync_line_numbers() {
    // the violation sits *after* a 3-line string; its reported line
    // must account for the newlines inside the literal
    let src = r#"
// lint: hot-path
fn hot(xs: &[f32]) -> Vec<f32> {
    let _banner = "one
two
three";
    xs.to_vec()
}
"#;
    let diags = lint(src);
    assert_eq!(rule_names(&diags), [HOT_PATH_ALLOC], "{diags:?}");
    assert_eq!(diags[0].line, 7, "{diags:?}");
}

// ---------------------------------------------------------------- call-graph resolution

#[test]
fn transitive_hot_path_findings_name_the_root() {
    let src = r#"
// lint: hot-path
fn root(xs: &[f32]) -> f32 { helper(xs) }
fn helper(xs: &[f32]) -> f32 { xs.to_vec(); 0.0 }
"#;
    let diags = lint(src);
    assert_eq!(rule_names(&diags), [HOT_PATH_ALLOC], "{diags:?}");
    assert!(
        diags[0].msg.contains("reachable from `src::fixture::root`"),
        "{}",
        diags[0].msg
    );
}

#[test]
fn shadowed_fn_names_bind_by_module_path() {
    // `crate::b::helper()` must bind b's clean helper, not a's
    // allocating one of the same name
    let same_module = [
        ("a.rs", "pub fn helper() { let v = vec![1]; }\n"),
        (
            "b.rs",
            "pub fn helper() {}\n\
             // lint: hot-path\n\
             pub fn root() { crate::b::helper(); }\n",
        ),
    ];
    assert!(lint_sources(&same_module).is_empty(), "{:?}", lint_sources(&same_module));

    // …and a qualified call INTO the allocating module is flagged
    let cross_module = [
        ("a.rs", "pub fn helper() { let v = vec![1]; }\n"),
        (
            "b.rs",
            "// lint: hot-path\n\
             pub fn root() { crate::a::helper(); }\n",
        ),
    ];
    assert_eq!(rule_names(&lint_sources(&cross_module)), [HOT_PATH_ALLOC]);
}

#[test]
fn dot_calls_bind_methods_and_bare_calls_bind_free_fns() {
    // `w.helper()` reaches the impl method (which allocates), never the
    // clean free fn of the same name
    let dotted = r#"
struct W;
impl W { fn helper(&self) { let v = vec![1]; } }
fn helper() {}
// lint: hot-path
fn root(w: &W) { w.helper(); }
"#;
    assert_eq!(rule_names(&lint(dotted)), [HOT_PATH_ALLOC]);

    // the bare call binds the free fn only — the method is unreachable
    let bare = r#"
struct W;
impl W { fn helper(&self) { let v = vec![1]; } }
fn helper() {}
// lint: hot-path
fn root() { helper(); }
"#;
    assert!(lint(bare).is_empty(), "{:?}", lint(bare));
}

#[test]
fn calls_inside_closures_attribute_to_the_enclosing_fn() {
    let src = r#"
// lint: hot-path
fn root() { let f = || helper(); f(); }
fn helper() { let v = vec![1]; }
"#;
    assert_eq!(rule_names(&lint(src)), [HOT_PATH_ALLOC]);
}

#[test]
fn mutual_recursion_terminates_and_flags_once() {
    let src = r#"
// lint: hot-path
fn ping(n: u32) { if n > 0 { pong(n - 1); } let v = vec![n]; }
fn pong(n: u32) { ping(n); }
"#;
    assert_eq!(rule_names(&lint(src)), [HOT_PATH_ALLOC]);
}

// ---------------------------------------------------------------- transitive closures & suppression

#[test]
fn panic_free_serve_descends_into_callees() {
    let src = r#"
// lint: thread-body
fn worker(q: &Q) { helper(q); }
fn helper(q: &Q) { q.pop().unwrap(); }
"#;
    let diags = lint(src);
    assert_eq!(rule_names(&diags), [PANIC_FREE_SERVE], "{diags:?}");
    assert!(diags[0].msg.contains("`unwrap()` can panic"), "{}", diags[0].msg);
}

#[test]
fn boundary_pragma_stops_transitive_descent() {
    let contracted = r#"
// lint: thread-body
fn worker(q: &Q) { helper(q); }
// lint: boundary(panic-free-serve) — helper validated by its own suite
fn helper(q: &Q) { q.pop().unwrap(); }
"#;
    assert!(lint(contracted).is_empty(), "{:?}", lint(contracted));

    // a boundary with no written contract does NOT stop the walk
    let bare = r#"
// lint: thread-body
fn worker(q: &Q) { helper(q); }
// lint: boundary(panic-free-serve)
fn helper(q: &Q) { q.pop().unwrap(); }
"#;
    assert_eq!(rule_names(&lint(bare)), [PANIC_FREE_SERVE]);
}

#[test]
fn call_site_allow_prunes_the_edge() {
    let src = r#"
// lint: thread-body
fn worker(q: &Q) {
    // lint: allow(panic-free-serve) — verified cold path, edge pruned
    helper(q);
}
fn helper(q: &Q) { q.pop().unwrap(); }
"#;
    assert!(lint(src).is_empty(), "{:?}", lint(src));
}

// ---------------------------------------------------------------- determinism taint

#[test]
fn wallclock_reachable_from_a_dispatch_root_is_taint() {
    let src = r#"
fn bank_linear(x: &[f32]) -> f32 { noise() }
fn noise() -> f32 { let t = std::time::Instant::now(); 0.0 }
"#;
    let diags = lint(src);
    assert_eq!(
        rule_names(&diags),
        [DETERMINISM_TAINT, NO_WALLCLOCK],
        "{diags:?}"
    );
    assert!(
        diags.iter().any(|d| d.msg.contains("taints the photonic dispatch")),
        "{diags:?}"
    );
}

#[test]
fn keyed_rng_below_a_dispatch_root_is_clean() {
    let src = r#"
fn bank_dfa_gradient(seed: u64, row: u64) -> f32 { sample(seed, row) }
fn sample(seed: u64, row: u64) -> f32 { let r = Pcg64::keyed(seed, 1, row); 0.0 }
"#;
    assert!(lint(src).is_empty(), "{:?}", lint(src));
}

#[test]
fn non_keyed_rng_ctor_below_a_dispatch_root_is_taint() {
    let src = r#"
fn eval_into(seed: u64) -> f32 { sample(seed) }
fn sample(seed: u64) -> f32 { let r = Pcg64::seed(seed); 0.0 }
"#;
    assert_eq!(rule_names(&lint(src)), [DETERMINISM_TAINT]);
}

// ---------------------------------------------------------------- lock order

#[test]
fn inconsistent_lock_acquisition_order_is_a_cycle() {
    let src = r#"
struct S;
impl S {
    fn ab(&self) { let a = self.m1.lock(); let b = self.m2.lock(); }
    fn ba(&self) { let b = self.m2.lock(); let a = self.m1.lock(); }
}
"#;
    let diags = lint(src);
    assert_eq!(rule_names(&diags), [LOCK_ORDER], "{diags:?}");
    assert!(
        diags[0].msg.contains("inconsistent lock acquisition order"),
        "{}",
        diags[0].msg
    );
}

#[test]
fn consistent_lock_order_stays_quiet() {
    let src = r#"
struct S;
impl S {
    fn ab(&self) { let a = self.m1.lock(); let b = self.m2.lock(); }
    fn ab2(&self) { let a = self.m1.lock(); let b = self.m2.lock(); }
}
"#;
    assert!(lint(src).is_empty(), "{:?}", lint(src));
}

#[test]
fn lock_order_cycles_are_caught_across_call_edges() {
    // `ab` holds m1 and calls `inner`, which takes m2 → order m1<m2;
    // `ba` takes m2 then m1 → cycle, even though no single fn inverts
    let src = r#"
struct S;
impl S {
    fn inner(&self) { self.m2.lock(); }
    fn ab(&self) { let a = self.m1.lock(); self.inner(); }
    fn ba(&self) { let b = self.m2.lock(); let a = self.m1.lock(); }
}
"#;
    assert_eq!(rule_names(&lint(src)), [LOCK_ORDER]);
}

// ---------------------------------------------------------------- self-hosting

#[test]
fn the_crates_own_tree_lints_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src");
    let report = lint_repo(&root).unwrap();
    // the repo walk covers rust/src plus the relaxed benches/ + tests/
    assert!(report.files > 40, "walked only {} files", report.files);
    assert_eq!(RULES.len(), 8);
    // a real crate produces a non-trivial graph, and the transitive
    // rules carry standing suppression debt (each with a written
    // contract) — CI caps that debt against the committed LINT.json
    assert!(report.graph.nodes > 300, "only {} graph nodes", report.graph.nodes);
    assert!(report.graph.edges > 500, "only {} call edges", report.graph.edges);
    assert!(
        report.debt.get(HOT_PATH_ALLOC).copied().unwrap_or(0) > 0,
        "hot-path closure should carry contracted allows: {:?}",
        report.debt
    );
    assert!(
        report.clean(),
        "`pdfa lint` findings on the crate's own sources:\n{}",
        report.render()
    );
}
