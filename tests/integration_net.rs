//! Integration: the NDJSON-over-TCP serving front-end on real loopback
//! sockets. The acceptance invariants: many concurrent pipelined clients
//! sustain traffic through the micro-batcher with every reply bit-exact
//! vs `dfa::reference::forward`; malformed lines get in-order error
//! replies without dropping the connection; and a request budget drains
//! gracefully — every accepted request is answered before the socket
//! closes.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use photonic_dfa::dfa::params::NetState;
use photonic_dfa::dfa::reference;
use photonic_dfa::runtime::manifest::NetDims;
use photonic_dfa::runtime::{NativeEngine, StepEngine};
use photonic_dfa::serve::net::{self, NetConfig, NetServer, TrafficConfig};
use photonic_dfa::serve::{BatchPolicy, ServeConfig, Server};
use photonic_dfa::tensor::Tensor;
use photonic_dfa::util::json_stream::{self, Lexer};
use photonic_dfa::util::rng::Pcg64;

fn tiny_server(seed: u64, max_batch: usize) -> (Arc<Server>, NetState, NetDims) {
    let engine: Arc<dyn StepEngine> = Arc::new(NativeEngine::new());
    let dims = NetDims { d_in: 16, d_h1: 32, d_h2: 32, d_out: 4, batch: 8 };
    let state = NetState::init(&dims, &mut Pcg64::seed(seed));
    let server = Server::start(
        &engine,
        "tiny",
        state.params(),
        ServeConfig {
            workers: 2,
            policy: BatchPolicy {
                max_batch,
                max_wait: Duration::from_millis(1),
                queue_cap: 128,
            },
        },
    )
    .unwrap();
    (Arc::new(server), state, dims)
}

fn bind_loopback() -> TcpListener {
    TcpListener::bind("127.0.0.1:0").unwrap()
}

fn shutdown_server(server: Arc<Server>) -> photonic_dfa::serve::ServeStats {
    Arc::try_unwrap(server)
        .unwrap_or_else(|_| panic!("connections joined, server uniquely owned"))
        .shutdown()
}

/// The headline acceptance run: 8 concurrent pipelined TCP clients, every
/// reply verified bit-exact against the reference forward.
#[test]
fn eight_concurrent_clients_get_bit_identical_logits() {
    let (server, state, dims) = tiny_server(101, 8);
    let netsrv =
        NetServer::start(server.clone(), bind_loopback(), NetConfig::default())
            .unwrap();
    let cfg = TrafficConfig {
        clients: 8,
        requests_per_client: 32,
        depth: 8,
        d_in: dims.d_in,
        seed: 2026,
    };
    let report =
        net::drive(netsrv.local_addr(), &cfg, Some(state.params())).unwrap();
    assert_eq!(report.sent, 256);
    assert_eq!(report.ok, 256, "every request answered: {report:?}");
    assert_eq!(report.errors, 0);
    assert_eq!(report.verified, 256, "every reply checked bit-exact");
    assert_eq!(report.latency.samples_ns.len(), 256);
    assert!(report.req_per_s() > 0.0);
    let text = report.report();
    assert!(text.contains("req/s") && text.contains("bit-exact"), "{text}");

    let net_stats = netsrv.shutdown();
    assert_eq!(net_stats.accepted, 256);
    assert_eq!(net_stats.rejected, 0);
    assert_eq!(net_stats.connections, 8);
    let stats = shutdown_server(server);
    assert_eq!(stats.completed, 256);
    assert_eq!(stats.failed, 0);
}

/// Malformed lines must produce in-order `{"error":...}` replies and
/// leave the connection serving; a wrong-width request echoes its id.
#[test]
fn malformed_lines_get_in_order_error_replies() {
    let (server, state, dims) = tiny_server(103, 4);
    let netsrv =
        NetServer::start(server.clone(), bind_loopback(), NetConfig::default())
            .unwrap();
    let stream = TcpStream::connect(netsrv.local_addr()).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;

    let x: Vec<f32> = (0..dims.d_in).map(|j| j as f32 * 0.03).collect();
    let mut good = String::new();
    json_stream::write_request(&mut good, Some(7), &x);
    // garbage, then a good request, then a wrong-width request — the
    // replies must come back in exactly that order
    w.write_all(b"this is not json\n").unwrap();
    w.write_all(good.as_bytes()).unwrap();
    w.write_all(b"{\"id\":8,\"x\":[1,2,3]}\n").unwrap();
    w.flush().unwrap();

    let mut lexer = Lexer::new();
    let mut line = String::new();
    let mut logits = Vec::new();
    let mut errbuf = String::new();
    let mut read_reply = |line: &mut String,
                          logits: &mut Vec<f32>,
                          errbuf: &mut String| {
        line.clear();
        assert!(reader.read_line(line).unwrap() > 0, "connection closed early");
        json_stream::parse_reply(&mut lexer, line.trim_end(), logits, errbuf)
            .unwrap()
    };

    let head = read_reply(&mut line, &mut logits, &mut errbuf);
    assert!(head.is_error, "garbage line must error: {line}");
    assert_eq!(head.id, None, "a line that failed to parse has no id");

    let head = read_reply(&mut line, &mut logits, &mut errbuf);
    assert!(!head.is_error, "good request must succeed: {line}");
    assert_eq!(head.id, Some(7));
    let xt = Tensor::new(&[1, dims.d_in], x).unwrap();
    let want = reference::forward(state.params(), &xt);
    assert_eq!(logits, want.logits.row(0), "logits drifted over the wire");

    let head = read_reply(&mut line, &mut logits, &mut errbuf);
    assert!(head.is_error, "wrong-width request must error: {line}");
    assert_eq!(head.id, Some(8), "submit-side errors echo the request id");
    assert!(errbuf.contains("features"), "{errbuf}");

    drop(w);
    drop(reader);
    let net_stats = netsrv.shutdown();
    assert_eq!(net_stats.accepted, 1);
    assert_eq!(net_stats.rejected, 2);
    let stats = shutdown_server(server);
    assert_eq!(stats.completed, 1);
}

/// A `max_requests` budget drains gracefully: a client that pipelines
/// past the budget still receives a reply for every accepted request (in
/// order) before the server half-closes.
#[test]
fn request_budget_drains_gracefully() {
    let (server, _state, dims) = tiny_server(107, 4);
    let netsrv = NetServer::start(
        server.clone(),
        bind_loopback(),
        NetConfig { max_inflight: 32, max_requests: 16 },
    )
    .unwrap();
    let stream = TcpStream::connect(netsrv.local_addr()).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;

    // fire 24 pipelined requests at a 16-request budget
    let mut out = String::new();
    for id in 0..24u64 {
        let x: Vec<f32> = (0..dims.d_in).map(|j| (id + j as u64) as f32 * 0.01).collect();
        json_stream::write_request(&mut out, Some(id), &x);
        w.write_all(out.as_bytes()).unwrap();
    }
    w.flush().unwrap();

    let mut lexer = Lexer::new();
    let mut line = String::new();
    let mut logits = Vec::new();
    let mut errbuf = String::new();
    let mut replies = Vec::new();
    loop {
        line.clear();
        if reader.read_line(&mut line).unwrap() == 0 {
            break; // server half-closed after the drain
        }
        let head = json_stream::parse_reply(
            &mut lexer,
            line.trim_end(),
            &mut logits,
            &mut errbuf,
        )
        .unwrap();
        assert!(!head.is_error, "budgeted requests must succeed: {line}");
        replies.push(head.id.unwrap());
    }
    assert_eq!(
        replies,
        (0..16).collect::<Vec<u64>>(),
        "exactly the accepted budget, replied in order"
    );

    drop(w);
    drop(reader);
    let net_stats = netsrv.join_all(); // budget exhaustion stops the front-end
    assert_eq!(net_stats.accepted, 16);
    let stats = shutdown_server(server);
    assert_eq!(stats.completed, 16);
    assert_eq!(stats.failed, 0);
}

/// Stop latency is bounded by the accept loop's poll interval: an idle
/// front-end must notice `stop()` within one [`net::POLL_INTERVAL`]
/// sleep (plus scheduling slack), not hang until the next connection.
/// Regression guard for the interval staying a shared named constant —
/// if the sleep and the check ever drift apart, this test times out.
#[test]
fn stop_latency_is_bounded_by_one_poll_interval() {
    let (server, _state, _dims) = tiny_server(109, 4);
    let netsrv =
        NetServer::start(server.clone(), bind_loopback(), NetConfig::default())
            .unwrap();
    // let the accept loop settle into its idle poll sleep
    std::thread::sleep(net::POLL_INTERVAL / 2);

    // lint: timing: asserts shutdown latency, not a compute input
    let t0 = std::time::Instant::now();
    netsrv.stop();
    let stats = netsrv.join_all();
    let elapsed = t0.elapsed();

    // one full poll sleep + generous scheduling slack for loaded CI
    let budget = net::POLL_INTERVAL + Duration::from_millis(200);
    assert!(
        elapsed < budget,
        "idle front-end took {elapsed:?} to stop (budget {budget:?}, \
         poll interval {:?})",
        net::POLL_INTERVAL
    );
    assert_eq!(stats.accepted, 0);
    let server_stats = shutdown_server(server);
    assert_eq!(server_stats.completed, 0);
}

/// Oversized driver shapes are rejected cleanly, not served garbage.
#[test]
fn traffic_driver_validates_its_config() {
    let cfg = TrafficConfig {
        clients: 0,
        requests_per_client: 8,
        depth: 1,
        d_in: 16,
        seed: 1,
    };
    let addr = "127.0.0.1:9".parse().unwrap(); // never dialed
    let err = net::drive(addr, &cfg, None).unwrap_err().to_string();
    assert!(err.contains("clients"), "{err}");
}
