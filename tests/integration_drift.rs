//! Integration: device-lifetime robustness under injected faults.
//!
//! The fault-injection harness of the drift subsystem: scripts thermal
//! faults (temperature steps, drift ramps, dead rings) into a photonic
//! engine's [`DriftModel`] and trains through them, pinning the
//! recalibration scheduler's contract —
//!
//! * with the scheduler armed, a faulted run recovers to the clean
//!   trajectory (bit-exactly, for faults the §4 recalibration protocol
//!   can null) and the recovery cost lands in the telemetry;
//! * with the scheduler disarmed, the same fault degrades accuracy;
//! * a dead ring degrades gracefully — finite numbers, no NaNs, and no
//!   endless recalibration storm chasing an unfixable error;
//! * drifting trajectories stay bit-identical across `--threads`, and a
//!   drifting run resumes bit-exactly from its checkpoint (the device
//!   blob carries the drift state across the restart).

use std::sync::Arc;

use photonic_dfa::dfa::checkpoint::Checkpoint;
use photonic_dfa::dfa::config::TrainConfig;
use photonic_dfa::dfa::trainer::{TrainResult, Trainer};
use photonic_dfa::photonics::drift::{FaultEvent, FaultKind};
use photonic_dfa::runtime::photonic::{
    PhotonicEngine, DRIFT_RATE_DEFAULT, RECAL_THRESHOLD_DEFAULT,
};
use photonic_dfa::runtime::{PhysicsConfig, StepEngine};

/// Recalibration threshold that disarms the scheduler (finite, so
/// `PhysicsConfig::validate` accepts it, but never reachable).
const RECAL_OFF: f64 = 1e30;

/// The noise-free lifetime testbed: ideal converters on a multi-tile
/// bank, so any trajectory difference is attributable to the injected
/// fault alone.
fn quiet_physics() -> PhysicsConfig {
    PhysicsConfig {
        bank_rows: 16,
        bank_cols: 12,
        recal_threshold: RECAL_THRESHOLD_DEFAULT,
        ..PhysicsConfig::ideal()
    }
}

fn tiny_cfg(physics: PhysicsConfig) -> TrainConfig {
    TrainConfig {
        config: "tiny".into(),
        epochs: 3,
        lr: 0.05,
        n_train: 256,
        n_test: 64,
        seed: 3,
        physics: Some(physics),
        ..TrainConfig::default()
    }
}

/// Train tiny end to end on a fresh engine under `physics`, with `faults`
/// scripted into the device before the first dispatch. Returns the run
/// result and the final network state bytes (the bit-exactness witness).
fn train_with_faults(
    physics: PhysicsConfig,
    faults: &[FaultEvent],
) -> (TrainResult, Vec<u8>) {
    let engine = PhotonicEngine::open("artifacts", physics).unwrap();
    engine.inject_faults(faults).unwrap();
    let engine: Arc<dyn StepEngine> = Arc::new(engine);
    let mut t = Trainer::new(engine, tiny_cfg(physics)).unwrap();
    let (train, test) = t.load_data().unwrap();
    let res = t.train(train, test, |_| {}).unwrap();
    (res, t.state.to_bytes())
}

#[test]
fn step_drift_fault_recovers_with_recal_and_degrades_without() {
    // a package temperature step knocks every ring 0.05 rad off its
    // locking point — ~6 weight units on the high-finesse flank, far
    // over the 0.05 recalibration threshold
    let step = [FaultEvent {
        at_tick: 1,
        kind: FaultKind::StepDrift { phase: 0.05 },
    }];
    let (clean, clean_state) = train_with_faults(quiet_physics(), &[]);
    assert!(clean.test_acc > 0.6, "clean sanity: {}", clean.test_acc);
    assert_eq!(clean.telemetry.recal_events, 0);

    // scheduler armed: the recalibration fires at the very tick the step
    // lands, so no dispatch ever sees the fault — the trajectory is
    // bit-identical to the clean run, and the recovery cost is charged
    let (on, on_state) = train_with_faults(quiet_physics(), &step);
    assert!(on.telemetry.recal_events >= 1, "{:?}", on.telemetry);
    assert!(on.telemetry.recal_cycles > 0);
    assert_eq!(on_state, clean_state, "recovered trajectory diverged");
    assert_eq!(on.test_acc.to_bits(), clean.test_acc.to_bits());
    for (a, b) in on.history.iter().zip(&clean.history) {
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
    }
    assert!(
        on.telemetry.energy_j > clean.telemetry.energy_j,
        "recalibration must cost modeled energy: {} vs {}",
        on.telemetry.energy_j,
        clean.telemetry.energy_j
    );
    assert_eq!(on.telemetry.drift_err, 0.0, "recal must null the estimate");

    // scheduler disarmed: the step goes uncompensated and wrecks both
    // the forward pass and the photonic gradient readout
    let off_physics =
        PhysicsConfig { recal_threshold: RECAL_OFF, ..quiet_physics() };
    let (off, off_state) = train_with_faults(off_physics, &step);
    assert_eq!(off.telemetry.recal_events, 0);
    assert!(off.telemetry.drift_err > 1.0, "{}", off.telemetry.drift_err);
    assert!(off.test_acc.is_finite());
    for h in &off.history {
        assert!(h.train_loss.is_finite(), "epoch {}: NaN loss", h.epoch);
    }
    assert!(
        off.test_acc <= clean.test_acc - 0.2,
        "uncompensated step must degrade accuracy: {} vs clean {}",
        off.test_acc,
        clean.test_acc
    );
    assert_ne!(off_state, clean_state);
}

#[test]
fn ramp_drift_is_continuously_recalibrated() {
    // ambient drift accelerates mid-run: from tick 2 the walk amplitude
    // jumps to 0.02 rad/√tick (~2.4 weight units per tick), so every
    // later tick crosses the threshold and the scheduler must keep
    // firing — pinning the run to the clean trajectory throughout
    let ramp = [FaultEvent {
        at_tick: 2,
        kind: FaultKind::RampDrift { rate: 0.02 },
    }];
    let (clean, clean_state) = train_with_faults(quiet_physics(), &[]);
    let (on, on_state) = train_with_faults(quiet_physics(), &ramp);
    assert!(
        on.telemetry.recal_events >= 2,
        "ramp must recalibrate repeatedly: {:?}",
        on.telemetry
    );
    assert_eq!(on_state, clean_state, "ramp-compensated trajectory diverged");
    assert_eq!(on.test_acc.to_bits(), clean.test_acc.to_bits());
    assert!(on.telemetry.recal_cycles > on.telemetry.recal_events); // >1 cycle each
}

#[test]
fn dead_ring_degrades_gracefully_without_recal_storm() {
    // ring 7 dies with its weight stuck at 0.25: recalibration cannot
    // recover it, so the scheduler must exclude it from the error
    // estimate (no endless recal loop) and the run must stay finite
    let dead = [FaultEvent {
        at_tick: 1,
        kind: FaultKind::DeadRing { ring: 7, weight: 0.25 },
    }];
    let (clean, _) = train_with_faults(quiet_physics(), &[]);
    let (res, _) = train_with_faults(quiet_physics(), &dead);
    assert_eq!(
        res.telemetry.recal_events, 0,
        "a dead ring must not trigger a recalibration storm"
    );
    assert_eq!(res.telemetry.drift_err, 0.0, "stuck rings are excluded");
    assert!(res.test_acc.is_finite());
    assert!(res.telemetry.energy_j.is_finite());
    for h in &res.history {
        assert!(h.train_loss.is_finite(), "epoch {}: NaN loss", h.epoch);
        assert!(h.train_acc.is_finite());
    }
    // one stuck ring out of 192 dents but does not destroy the run
    assert!(
        res.test_acc >= clean.test_acc - 0.3,
        "dead ring: {} vs clean {}",
        res.test_acc,
        clean.test_acc
    );
}

#[test]
fn default_lifetime_physics_meets_static_accuracy_with_recal() {
    // the acceptance arm: the paper operating point on an aging device.
    // The thermal walk is the drifty default; aging is scaled up (1e-4
    // vs the 2e-6/tick default) so the short tiny run spans the same
    // device lifetime an MNIST run covers at default rates. The armed
    // scheduler must hold accuracy at the static preset's level while
    // the disarmed device visibly ages.
    let budget = |mut cfg: TrainConfig| {
        cfg.epochs = 2;
        cfg.max_steps_per_epoch = Some(8);
        cfg.n_train = 64;
        cfg
    };
    let run = |physics: PhysicsConfig| {
        let engine: Arc<dyn StepEngine> =
            Arc::new(PhotonicEngine::open("artifacts", physics).unwrap());
        let mut t = Trainer::new(engine, budget(tiny_cfg(physics))).unwrap();
        let (train, test) = t.load_data().unwrap();
        t.train(train, test, |_| {}).unwrap()
    };
    // multi-tile bank, otherwise the full paper/static operating point
    let static_physics = PhysicsConfig {
        bank_rows: 16,
        bank_cols: 12,
        ..PhysicsConfig::paper()
    };
    let aging_physics = |threshold: f64| PhysicsConfig {
        drift_rate: DRIFT_RATE_DEFAULT,
        drift_aging: 1e-4,
        recal_threshold: threshold,
        ..static_physics
    };

    let fresh = run(static_physics);
    assert!(fresh.test_acc > 0.3, "static sanity: {}", fresh.test_acc);

    let on = run(aging_physics(RECAL_THRESHOLD_DEFAULT));
    assert!(on.telemetry.recal_events >= 1, "{:?}", on.telemetry);
    // the scheduler bounds the telemetry-estimated weight error by its
    // threshold: every dispatch past it was preceded by a recalibration
    assert!(
        on.telemetry.drift_err <= RECAL_THRESHOLD_DEFAULT,
        "{}",
        on.telemetry.drift_err
    );
    assert!(
        on.test_acc >= fresh.test_acc - 0.08,
        "recal-on aging device fell behind the static preset: {} vs {}",
        on.test_acc,
        fresh.test_acc
    );

    let off = run(aging_physics(RECAL_OFF));
    assert_eq!(off.telemetry.recal_events, 0);
    assert!(
        off.telemetry.drift_err > RECAL_THRESHOLD_DEFAULT,
        "uncompensated aging must grow past the threshold: {}",
        off.telemetry.drift_err
    );
    assert!(
        off.test_acc <= on.test_acc,
        "aging without recalibration must not beat the scheduler: {} vs {}",
        off.test_acc,
        on.test_acc
    );
}

/// A drifting, noisy operating point that exercises the whole stochastic
/// stack at once: live read noise, real converters, thermal walk hot
/// enough to recalibrate every tick.
fn drifting_noisy_physics() -> PhysicsConfig {
    PhysicsConfig {
        bank_rows: 16,
        bank_cols: 12,
        dac_bits: 6,
        adc_bits: 6,
        sigma: 0.1,
        drift_rate: 1e-3,
        drift_aging: 1e-5,
        recal_threshold: RECAL_THRESHOLD_DEFAULT,
        ..PhysicsConfig::ideal()
    }
}

#[test]
fn drifting_training_is_bit_identical_across_thread_counts() {
    // drift ticks derive from the engine's cycle counter, never from
    // wall-clock, so the drift/recalibration schedule — and with it the
    // whole trajectory — must be a pure function of the dispatch sequence
    let physics = drifting_noisy_physics();
    let ckpt_at = |threads: usize| {
        let engine: Arc<dyn StepEngine> = Arc::new(
            PhotonicEngine::open_threaded("artifacts", physics, threads).unwrap(),
        );
        let mut cfg = tiny_cfg(physics);
        cfg.epochs = 1;
        cfg.max_steps_per_epoch = Some(6);
        cfg.n_train = 64;
        cfg.threads = threads;
        let mut t = Trainer::new(engine, cfg).unwrap();
        let (train, test) = t.load_data().unwrap();
        let res = t.train(train, test, |_| {}).unwrap();
        assert!(res.test_acc.is_finite());
        assert!(res.telemetry.recal_events >= 1, "drift never engaged");
        let path = std::env::temp_dir()
            .join(format!("pdfa_drift_thread_inv_{threads}.ckpt"));
        t.save_checkpoint(&path).unwrap();
        std::fs::read(&path).unwrap()
    };
    let a = ckpt_at(1);
    let b = ckpt_at(4);
    assert_eq!(a, b, "drifting checkpoints diverged across thread counts");
}

#[test]
fn drifting_run_resumes_bit_exactly_from_checkpoint() {
    // the device blob in the v2 checkpoint carries the op sequence,
    // counters and drift state, so a resumed drifting run must replay
    // the uninterrupted trajectory byte for byte — including the
    // mid-lifetime thermal phases and the recalibration schedule
    let physics = drifting_noisy_physics();
    let dir = std::env::temp_dir().join("pdfa_drift_resume");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg_to = |epochs: usize, path: &std::path::Path| {
        let mut cfg = tiny_cfg(physics);
        cfg.epochs = epochs;
        cfg.max_steps_per_epoch = Some(6);
        cfg.n_train = 64;
        cfg.save_path = Some(path.to_str().unwrap().into());
        cfg.save_every = 1; // in-loop saves: both arms snapshot at the
                            // same point of the dispatch sequence
        cfg
    };
    let trainer = |cfg: TrainConfig| {
        let engine: Arc<dyn StepEngine> =
            Arc::new(PhotonicEngine::open("artifacts", physics).unwrap());
        Trainer::new(engine, cfg).unwrap()
    };

    // uninterrupted: two epochs straight through
    let full_path = dir.join("full.ckpt");
    let mut full = trainer(cfg_to(2, &full_path));
    let (train, test) = full.load_data().unwrap();
    full.train(train.clone(), test.clone(), |_| {}).unwrap();
    let want = std::fs::read(&full_path).unwrap();

    // interrupted: one epoch, checkpoint, fresh engine, resume, epoch two
    let donor_path = dir.join("donor.ckpt");
    let mut donor = trainer(cfg_to(1, &donor_path));
    donor.train(train.clone(), test.clone(), |_| {}).unwrap();
    let ckpt = Checkpoint::load(&donor_path).unwrap();
    assert!(
        ckpt.device.is_some(),
        "photonic checkpoints must carry the device blob"
    );

    let resumed_path = dir.join("resumed.ckpt");
    let mut resumed = trainer(cfg_to(2, &resumed_path));
    resumed.restore(&ckpt).unwrap();
    assert_eq!(resumed.epochs_done(), 1);
    resumed.train(train, test, |_| {}).unwrap();
    let got = std::fs::read(&resumed_path).unwrap();
    assert_eq!(got, want, "resumed drifting run diverged from uninterrupted");
}
