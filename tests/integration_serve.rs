//! Integration: the batched inference serving subsystem under concurrent
//! load. The core invariant: no request is lost, duplicated, or answered
//! with another request's logits — each producer embeds a unique payload
//! and checks its reply against an independently computed
//! `dfa::reference::forward`, under both dynamic-batcher flush paths.

use std::sync::Arc;
use std::time::Duration;

use photonic_dfa::dfa::params::NetState;
use photonic_dfa::dfa::reference;
use photonic_dfa::runtime::manifest::NetDims;
use photonic_dfa::runtime::{NativeEngine, StepEngine};
use photonic_dfa::serve::{BatchPolicy, ServeConfig, Server};
use photonic_dfa::tensor::Tensor;
use photonic_dfa::util::rng::Pcg64;

const PRODUCERS: usize = 4;
const REQUESTS_PER_PRODUCER: usize = 64;

fn engine() -> Arc<dyn StepEngine> {
    Arc::new(NativeEngine::new())
}

fn tiny_state(seed: u64) -> (NetDims, NetState) {
    let dims = NetDims { d_in: 16, d_h1: 32, d_h2: 32, d_out: 4, batch: 8 };
    let mut rng = Pcg64::seed(seed);
    (dims.clone(), NetState::init(&dims, &mut rng))
}

/// A payload unique to (producer, sequence): distinguishable logits for
/// every request, so cross-wired responses cannot go unnoticed.
fn payload(d_in: usize, producer: usize, seq: usize) -> Vec<f32> {
    (0..d_in)
        .map(|j| {
            let tag = (producer * REQUESTS_PER_PRODUCER + seq) as f32;
            ((j as f32 + 1.0) * 0.013 + tag * 0.001) % 1.0
        })
        .collect()
}

/// M producers x K burst-submitted requests each; every reply must equal
/// the reference forward of that producer's own payload.
fn stress(policy: BatchPolicy, workers: usize) -> photonic_dfa::serve::ServeStats {
    let engine = engine();
    let (dims, state) = tiny_state(33);
    let server = Arc::new(
        Server::start(&engine, "tiny", state.params(), ServeConfig { workers, policy })
            .unwrap(),
    );
    let params = Arc::new(state.params().to_vec());

    std::thread::scope(|scope| {
        for p in 0..PRODUCERS {
            let server = server.clone();
            let params = params.clone();
            let d_in = dims.d_in;
            scope.spawn(move || {
                // burst-submit the whole load, then verify every reply
                let xs: Vec<Vec<f32>> =
                    (0..REQUESTS_PER_PRODUCER).map(|s| payload(d_in, p, s)).collect();
                let tickets: Vec<_> = xs
                    .iter()
                    .map(|x| server.submit(x.clone()).unwrap())
                    .collect();
                for (x, ticket) in xs.iter().zip(tickets) {
                    let got = ticket.wait().unwrap();
                    let xt = Tensor::new(&[1, d_in], x.clone()).unwrap();
                    let want = reference::forward(&params, &xt);
                    assert_eq!(
                        got,
                        want.logits.row(0),
                        "producer {p} got someone else's logits"
                    );
                }
            });
        }
    });

    let stats = Arc::try_unwrap(server)
        .unwrap_or_else(|_| panic!("producers done, server uniquely owned"))
        .shutdown();
    assert_eq!(
        stats.completed,
        (PRODUCERS * REQUESTS_PER_PRODUCER) as u64,
        "every request answered exactly once"
    );
    assert_eq!(stats.failed, 0);
    stats
}

#[test]
fn stress_max_batch_flush_path() {
    // long max_wait: the only way requests move is the max_batch trigger
    // (plus the shutdown drain, which producers' waits already preclude)
    let stats = stress(
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_secs(30),
            queue_cap: 512,
        },
        3,
    );
    assert_eq!(stats.flush_timeout, 0, "nothing should age out: {stats:?}");
    assert_eq!(stats.batches, (PRODUCERS * REQUESTS_PER_PRODUCER / 8) as u64);
}

#[test]
fn stress_max_wait_flush_path() {
    // max_batch above the total load: every flush is an age-out (or the
    // final drain); the full trigger must never fire
    let stats = stress(
        BatchPolicy {
            max_batch: 4096,
            max_wait: Duration::from_millis(3),
            queue_cap: 512,
        },
        3,
    );
    assert_eq!(stats.flush_full, 0, "batcher must flush on age: {stats:?}");
    assert!(stats.batches >= 1);
}

#[test]
fn stress_tiny_batches_many_workers() {
    // max_batch 1 degenerates to per-request dispatch across 4 workers —
    // maximal interleaving, same correctness invariant
    let stats = stress(
        BatchPolicy {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            queue_cap: 64,
        },
        4,
    );
    assert_eq!(stats.batches, (PRODUCERS * REQUESTS_PER_PRODUCER) as u64);
}

#[test]
fn serve_results_match_training_evaluate() {
    // end-to-end: train a few epochs, serve the trained checkpoint, and
    // check served argmax predictions agree with the evaluation path
    use photonic_dfa::dfa::config::TrainConfig;
    use photonic_dfa::dfa::trainer::Trainer;
    use photonic_dfa::data::Dataset;

    let engine = engine();
    let cfg = TrainConfig {
        config: "tiny".into(),
        epochs: 2,
        lr: 0.05,
        n_train: 256,
        n_test: 64,
        seed: 5,
        ..TrainConfig::default()
    };
    let mut t = Trainer::new(engine.clone(), cfg).unwrap();
    let train = Arc::new(Dataset::synthetic_features(256, 16, 4, 50));
    let test = Arc::new(Dataset::synthetic_features(64, 16, 4, 51));
    t.train(train, test.clone(), |_| {}).unwrap();
    let ckpt = t.checkpoint();

    let server = Server::from_checkpoint(
        &engine,
        &ckpt,
        ServeConfig {
            workers: 2,
            policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                queue_cap: 64,
            },
        },
    )
    .unwrap();
    let mut correct = 0usize;
    for i in 0..test.len() {
        let logits = server.infer(test.x.row(i).to_vec()).unwrap();
        let pred = logits
            .iter()
            .enumerate()
            .fold(0, |best, (j, &v)| if v > logits[best] { j } else { best });
        if pred == test.y[i] as usize {
            correct += 1;
        }
    }
    let served_acc = correct as f64 / test.len() as f64;
    let eval_acc = t.evaluate(&test).unwrap();
    assert_eq!(served_acc, eval_acc, "serving and evaluate disagree");
    server.shutdown();
}
