//! Integration: the photonic step engine against its digital twins.
//!
//! Pins the `ideal` physics preset to the native engine (the acceptance
//! contract: same artifact vocabulary, logits within the documented
//! tolerance, same end-to-end training outcome), exercises the realistic
//! paper preset end to end, and checks that checkpoints refuse to resume
//! across different device physics.

use std::sync::Arc;

use photonic_dfa::dfa::config::TrainConfig;
use photonic_dfa::dfa::noise_model::NoiseMode;
use photonic_dfa::dfa::reference;
use photonic_dfa::dfa::trainer::Trainer;
use photonic_dfa::photonics::BpdMode;
use photonic_dfa::runtime::photonic::IDEAL_LOGIT_TOL;
use photonic_dfa::runtime::{self, Backend, PhysicsConfig, StepEngine};
use photonic_dfa::tensor::Tensor;
use photonic_dfa::util::check::assert_close;
use photonic_dfa::util::rng::Pcg64;

fn photonic(physics: PhysicsConfig) -> Arc<dyn StepEngine> {
    runtime::open("artifacts", Backend::Photonic(physics)).unwrap()
}

fn native() -> Arc<dyn StepEngine> {
    runtime::open("artifacts", Backend::Native).unwrap()
}

fn tiny_cfg(physics: Option<PhysicsConfig>) -> TrainConfig {
    TrainConfig {
        config: "tiny".into(),
        epochs: 3,
        lr: 0.05,
        n_train: 256,
        n_test: 64,
        seed: 3,
        physics,
        ..TrainConfig::default()
    }
}

#[test]
fn ideal_preset_pins_to_reference_forward() {
    // tolerance pin of the whole tiled analog path against
    // dfa::reference::forward on every output of the fwd artifact
    let engine = photonic(PhysicsConfig::ideal());
    let fwd = engine.load("fwd_tiny").unwrap();
    let dims = engine.net_dims("tiny").unwrap();
    let mut rng = Pcg64::seed(11);
    let params: Vec<Tensor> = fwd.spec().inputs[..6]
        .iter()
        .map(|s| Tensor::randn(&s.shape, 0.3, &mut rng))
        .collect();
    let x = Tensor::randn(&[dims.batch, dims.d_in], 0.8, &mut rng);
    let want = reference::forward(&params, &x);
    let mut inputs = params.clone();
    inputs.push(x);
    let got = fwd.execute(&inputs).unwrap();
    for (g, w, name) in [
        (&got[0], &want.logits, "logits"),
        (&got[1], &want.a1, "a1"),
        (&got[2], &want.a2, "a2"),
        (&got[3], &want.h1, "h1"),
        (&got[4], &want.h2, "h2"),
    ] {
        assert_eq!(g.shape(), w.shape());
        assert_close(g.data(), w.data(), IDEAL_LOGIT_TOL)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
    }
}

#[test]
fn ideal_preset_reproduces_native_training_end_to_end() {
    // the acceptance pin: a full tiny training run through the bank with
    // ideal physics must land on the native backend's accuracy
    let mut nat = Trainer::new(native(), tiny_cfg(None)).unwrap();
    let (train, test) = nat.load_data().unwrap();
    let nat_res = nat.train(train.clone(), test.clone(), |_| {}).unwrap();

    let physics = PhysicsConfig::ideal();
    let mut pho = Trainer::new(photonic(physics), tiny_cfg(Some(physics))).unwrap();
    // identical dataset recipe: config + seed + sizes match
    let (ptrain, ptest) = pho.load_data().unwrap();
    assert_eq!(ptrain.x.data(), train.x.data());
    let pho_res = pho.train(ptrain, ptest, |_| {}).unwrap();

    assert!(nat_res.test_acc > 0.6, "native sanity: {}", nat_res.test_acc);
    assert!(
        (pho_res.test_acc - nat_res.test_acc).abs() <= 0.05,
        "ideal photonic {} vs native {}",
        pho_res.test_acc,
        nat_res.test_acc
    );
    // the first-epoch losses track before rounding noise can compound
    let (p, n) = (&pho_res.history[0], &nat_res.history[0]);
    assert!(
        (p.train_loss - n.train_loss).abs() < 0.05,
        "epoch 1: {} vs {}",
        p.train_loss,
        n.train_loss
    );
}

#[test]
fn paper_preset_trains_under_full_physics() {
    // the realistic operating point: 12/6-bit converters, sigma 0.098,
    // crosstalk, feedback-locked inscription — one capped epoch must
    // execute cleanly and produce finite, learning-shaped numbers
    let physics = PhysicsConfig::paper();
    let mut cfg = tiny_cfg(Some(physics));
    cfg.epochs = 1;
    cfg.max_steps_per_epoch = Some(4);
    cfg.n_train = 64;
    let mut t = Trainer::new(photonic(physics), cfg).unwrap();
    let (train, test) = t.load_data().unwrap();
    let res = t.train(train, test, |_| {}).unwrap();
    assert_eq!(res.history.len(), 1);
    assert!(res.history[0].train_loss.is_finite());
    assert!(res.test_acc.is_finite());
    assert!(res.total_steps == 4, "{}", res.total_steps);
}

/// A small noisy operating point that exercises the whole stochastic
/// path: live read noise, real converters, multi-tile layers.
fn noisy_physics() -> PhysicsConfig {
    PhysicsConfig {
        bank_rows: 16,
        bank_cols: 12,
        dac_bits: 6,
        adc_bits: 6,
        sigma: 0.1,
        ..PhysicsConfig::ideal()
    }
}

#[test]
fn photonic_training_is_bit_identical_across_thread_counts() {
    // the tentpole acceptance: train under live read noise at --threads 1
    // and --threads 4 and compare the checkpoints byte for byte — the
    // per-row counter-keyed noise streams make the trajectory a pure
    // function of the inputs, never of scheduling
    let physics = noisy_physics();
    let ckpt_at = |threads: usize| {
        let engine = runtime::open_threaded(
            "artifacts",
            Backend::Photonic(physics),
            threads,
        )
        .unwrap();
        let mut cfg = tiny_cfg(Some(physics));
        cfg.epochs = 1;
        cfg.max_steps_per_epoch = Some(3);
        cfg.n_train = 64;
        cfg.threads = threads;
        let mut t = Trainer::new(engine, cfg).unwrap();
        let (train, test) = t.load_data().unwrap();
        let res = t.train(train, test, |_| {}).unwrap();
        assert!(res.test_acc.is_finite());
        let path =
            std::env::temp_dir().join(format!("pdfa_thread_inv_{threads}.ckpt"));
        t.save_checkpoint(&path).unwrap();
        std::fs::read(&path).unwrap()
    };
    let a = ckpt_at(1);
    let b = ckpt_at(4);
    assert_eq!(a, b, "checkpoints diverged across thread counts");
}

#[test]
fn physics_sweep_table_is_thread_count_invariant() {
    // `pdfa sweep-physics` output must not depend on --threads: compare
    // the rendered tables minus the wall-clock column
    use photonic_dfa::experiments::{physics_sweep, render_table, SweepSettings};
    let settings = |threads: usize| SweepSettings {
        artifacts_dir: "artifacts".into(),
        config: "tiny".into(),
        base: noisy_physics(),
        epochs: 1,
        seed: 5,
        n_train: 64,
        n_test: 32,
        max_steps_per_epoch: Some(2),
        threads,
    };
    // the wall column is the only non-deterministic one; it renders as
    // two whitespace tokens ("<num> <unit>", util::benchx::fmt_ns)
    let strip_wall = |table: String| -> Vec<String> {
        table
            .lines()
            .map(|l| {
                let toks: Vec<&str> = l.split_whitespace().collect();
                toks[..toks.len().saturating_sub(2)].join(" ")
            })
            .collect()
    };
    let seq = strip_wall(render_table(
        &physics_sweep(&settings(1), &[0, 4], &[0.0, 0.1]).unwrap(),
    ));
    let par = strip_wall(render_table(
        &physics_sweep(&settings(4), &[0, 4], &[0.0, 0.1]).unwrap(),
    ));
    assert_eq!(seq.len(), 5); // header + 4 grid cells
    assert_eq!(seq, par, "sweep table diverged across thread counts");
}

#[test]
fn checkpoint_refuses_resume_under_different_physics() {
    let physics = PhysicsConfig::ideal();
    let mut cfg = tiny_cfg(Some(physics));
    cfg.epochs = 1;
    let mut donor = Trainer::new(photonic(physics), cfg).unwrap();
    let (train, test) = donor.load_data().unwrap();
    donor.train(train, test, |_| {}).unwrap();
    let ckpt = donor.checkpoint();

    // same physics resumes fine
    let mut same = Trainer::new(photonic(physics), tiny_cfg(Some(physics))).unwrap();
    same.restore(&ckpt).unwrap();
    assert_eq!(same.epochs_done(), 1);

    // a different DAC resolution is a different trajectory: rejected
    let other = PhysicsConfig { dac_bits: 4, ..PhysicsConfig::ideal() };
    let mut mismatched = Trainer::new(photonic(other), tiny_cfg(Some(other))).unwrap();
    let err = mismatched.restore(&ckpt).unwrap_err().to_string();
    assert!(err.contains("protocol"), "{err}");

    // and a native run cannot adopt a photonic checkpoint at all
    let mut nat = Trainer::new(native(), tiny_cfg(None)).unwrap();
    assert!(nat.restore(&ckpt).is_err());
}

#[test]
fn device_noise_mode_is_rejected_on_photonic_backend() {
    // the legacy device-mode gradient path and the photonic backend are
    // two different physics models — combining them must be a hard error,
    // not a silent hybrid
    let physics = PhysicsConfig::ideal();
    let mut cfg = tiny_cfg(Some(physics));
    cfg.noise = NoiseMode::Device { bpd: BpdMode::Ideal };
    let err = Trainer::new(photonic(physics), cfg).unwrap_err().to_string();
    assert!(err.contains("--physics"), "{err}");
}

#[test]
fn photonic_backend_is_a_hard_parse_error_for_typos() {
    let err = Backend::parse("photonics").unwrap_err().to_string();
    assert!(err.contains("photonic") && err.contains("native"), "{err}");
}
