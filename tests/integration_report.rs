//! Integration: telemetry in run records and the `pdfa report` command.
//!
//! The acceptance pins of the telemetry subsystem:
//! * a photonic tiny-config run's `pdfa report` prints MACs, MAC/s and
//!   modeled pJ/MAC next to the §5 targets (1.0 pJ nominal / 0.28 pJ
//!   trimmed), and the printed counters match the run json;
//! * the `telemetry` counter objects in `result.json` and `history.json`
//!   are byte-identical at `--threads 1` vs `--threads 4` (the PR 4
//!   determinism contract extended to the new counters).

use std::path::{Path, PathBuf};
use std::process::Command;

use photonic_dfa::util::json::Value;

fn pdfa() -> Command {
    Command::new(env!("CARGO_BIN_EXE_pdfa"))
}

/// Train a small photonic run (noisy physics, so cycles and noise paths
/// are genuinely exercised) and return its run directory.
fn train_photonic(out_dir: &Path, run: &str, threads: &str) -> PathBuf {
    let out = pdfa()
        .args([
            "train",
            "--config", "tiny",
            "--backend", "photonic",
            "--physics", "ideal,bank=16x12,dac=6,adc=6,sigma=0.1",
            "--threads", threads,
            "--epochs", "2",
            "--max-steps", "3",
            "--n-train", "64",
            "--n-test", "32",
            "--seed", "9",
            "--out", out_dir.to_str().unwrap(),
            "--run-name", run,
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("MAC/s"), "train summary lacks MAC/s: {text}");
    assert!(text.contains("pJ/MAC"), "photonic train lacks pJ/MAC: {text}");
    out_dir.join(run)
}

fn read_json(path: &Path) -> Value {
    Value::parse(&std::fs::read_to_string(path).unwrap()).unwrap()
}

/// The value printed right after `label` on its report line.
fn report_value(text: &str, label: &str) -> String {
    let line = text
        .lines()
        .find(|l| l.starts_with(label))
        .unwrap_or_else(|| panic!("no '{label}' line in:\n{text}"));
    line[label.len()..]
        .split_whitespace()
        .next()
        .unwrap_or_else(|| panic!("no value on '{label}' line: {line}"))
        .to_string()
}

#[test]
fn telemetry_counters_byte_identical_across_threads() {
    let out_dir = std::env::temp_dir().join("pdfa_report_threads");
    let _ = std::fs::remove_dir_all(&out_dir);
    let t1 = train_photonic(&out_dir, "t1", "1");
    let t4 = train_photonic(&out_dir, "t4", "4");

    // run totals: the telemetry counter object serialises identically
    let tel = |dir: &Path| {
        read_json(&dir.join("result.json"))
            .get("telemetry")
            .to_string_compact()
    };
    let (a, b) = (tel(&t1), tel(&t4));
    assert!(a.contains("\"cycles\""), "telemetry block missing: {a}");
    assert_eq!(a, b, "run telemetry diverged across --threads");

    // per-epoch records too (wall_s/mac_per_s may differ; counters not)
    let hist = |dir: &Path| read_json(&dir.join("history.json"));
    let (h1, h4) = (hist(&t1), hist(&t4));
    let (e1, e4) = (h1.as_array().unwrap(), h4.as_array().unwrap());
    assert_eq!(e1.len(), 2);
    assert_eq!(e1.len(), e4.len());
    for (a, b) in e1.iter().zip(e4) {
        assert_eq!(
            a.get("telemetry").to_string_compact(),
            b.get("telemetry").to_string_compact(),
            "epoch telemetry diverged across --threads"
        );
        assert!(a.get("mac_per_s").as_f64().unwrap() > 0.0);
    }
}

#[test]
fn report_on_photonic_run_matches_run_json() {
    let out_dir = std::env::temp_dir().join("pdfa_report_run");
    let _ = std::fs::remove_dir_all(&out_dir);
    let run = train_photonic(&out_dir, "photonic", "2");

    let out = pdfa().args(["report", run.to_str().unwrap()]).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);

    // the acceptance needles: measured rows + the §5 targets
    for needle in [
        "MACs dispatched",
        "on-bank MACs",
        "MAC/s (wall-clock)",
        "optical cycles",
        "bank utilisation",
        "pJ/MAC heater-locked",
        "pJ/MAC trimmed",
        "1.0 pJ nominal",
        "0.28 pJ trimmed",
        "20 TOPS peak",
        "backend photonic",
    ] {
        assert!(text.contains(needle), "missing '{needle}' in:\n{text}");
    }

    // printed counters == run json counters, exactly
    let result = read_json(&run.join("result.json"));
    let tel = result.get("telemetry");
    let macs = tel.get("macs").as_f64().unwrap() as u64;
    let bank = tel.get("photonic_macs").as_f64().unwrap() as u64;
    let cycles = tel.get("cycles").as_f64().unwrap() as u64;
    assert!(macs > 0 && bank > 0 && cycles > 0, "empty telemetry: {tel:?}");
    assert_eq!(report_value(&text, "MACs dispatched"), macs.to_string());
    assert_eq!(report_value(&text, "on-bank MACs"), bank.to_string());
    assert_eq!(report_value(&text, "optical cycles"), cycles.to_string());

    // the measured pJ/MAC row is a parseable number
    let pj: f64 = report_value(&text, "pJ/MAC heater-locked").parse().unwrap();
    assert!(pj > 0.0, "{pj}");
}

#[test]
fn report_handles_checkpoints_and_native_runs() {
    let out_dir = std::env::temp_dir().join("pdfa_report_misc");
    let _ = std::fs::remove_dir_all(&out_dir);
    // a native run: telemetry exists, energy rows fall back to targets
    let out = pdfa()
        .args([
            "train",
            "--config", "tiny",
            "--epochs", "1",
            "--max-steps", "2",
            "--n-train", "64",
            "--n-test", "32",
            "--out", out_dir.to_str().unwrap(),
            "--run-name", "native",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let run = out_dir.join("native");

    let out = pdfa().args(["report", run.to_str().unwrap()]).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("backend native"), "{text}");
    assert!(text.contains("n/a (no on-bank work recorded)"), "{text}");
    assert!(text.contains("1.0 pJ nominal"), "{text}");

    // checkpoint form: analytic cost report (positional and --path both)
    let ckpt = run.join("final.ckpt");
    let out = pdfa().args(["report", ckpt.to_str().unwrap()]).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("(checkpoint)"), "{text}");
    assert!(text.contains("MACs/step"), "{text}");
    let out = pdfa()
        .args(["report", "--path", ckpt.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // a bogus path is a clean error
    let out = pdfa().args(["report", "definitely/not/there"]).output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("error:"), "{err}");
}
