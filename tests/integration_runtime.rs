//! Integration: every artifact in the manifest loads, compiles and executes.

use photonic_dfa::runtime::Engine;
use photonic_dfa::tensor::Tensor;
use photonic_dfa::util::rng::Pcg64;

fn engine() -> Option<Engine> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then(|| Engine::new(dir).unwrap())
}

#[test]
fn every_artifact_compiles_and_executes() {
    let Some(engine) = engine() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let names: Vec<String> = engine.manifest().artifacts.keys().cloned().collect();
    assert!(names.len() >= 13, "expected full artifact set, got {names:?}");
    let mut rng = Pcg64::seed(0);
    for name in names {
        let art = engine.load(&name).unwrap();
        let inputs: Vec<Tensor> = art
            .spec
            .inputs
            .iter()
            .map(|s| match s.name.as_str() {
                // keep runtime scalars in sane ranges
                "sigma" | "bits" => Tensor::scalar(0.0),
                "lr" => Tensor::scalar(0.01),
                "momentum" => Tensor::scalar(0.9),
                "r" => Tensor::scalar(0.95),
                "a" => Tensor::scalar(0.999),
                _ => Tensor::randn(&s.shape, 0.1, &mut rng),
            })
            .collect();
        let outputs = art.execute(&inputs).unwrap();
        assert_eq!(outputs.len(), art.spec.outputs.len(), "artifact {name}");
        for (out, spec) in outputs.iter().zip(&art.spec.outputs) {
            assert_eq!(out.shape(), spec.shape.as_slice(), "artifact {name}");
            assert!(
                out.data().iter().all(|v| v.is_finite()),
                "artifact {name} produced non-finite values"
            );
        }
    }
}

#[test]
fn photonic_matvec_artifact_matches_rust_device_physics() {
    // The L1 Pallas MRR kernel and the L3 photonics::mrr module implement
    // the same Lorentzian physics; pin them against each other.
    let Some(engine) = engine() else { return };
    let art = engine.load("photonic_matvec").unwrap();
    let mut rng = Pcg64::seed(5);
    let k = art.spec.inputs[0].shape[0];
    let m = art.spec.inputs[1].shape[0];
    let x = Tensor::rand_uniform(&[k], 0.0, 1.0, &mut rng);
    let phi = Tensor::rand_uniform(&[m, k], -0.5, 0.5, &mut rng);
    let (r, a) = (0.95f32, 0.999f32);
    let out = art
        .execute(&[x.clone(), phi.clone(), Tensor::scalar(r), Tensor::scalar(a)])
        .unwrap();

    use photonic_dfa::photonics::mrr::MrrDesign;
    let design = MrrDesign { self_coupling: r as f64, loss_a: a as f64 };
    for row in 0..m {
        let want: f64 = (0..k)
            .map(|c| x.data()[c] as f64 * design.weight(phi.at(row, c) as f64))
            .sum();
        let got = out[0].data()[row] as f64;
        assert!(
            (got - want).abs() < 1e-4 * k as f64,
            "row {row}: rust {want} vs artifact {got}"
        );
    }
}

#[test]
fn fwd_artifact_deterministic_across_executions() {
    let Some(engine) = engine() else { return };
    let fwd = engine.load("fwd_small").unwrap();
    let mut rng = Pcg64::seed(9);
    let inputs: Vec<Tensor> = fwd
        .spec
        .inputs
        .iter()
        .map(|s| Tensor::randn(&s.shape, 0.2, &mut rng))
        .collect();
    let a = fwd.execute(&inputs).unwrap();
    let b = fwd.execute(&inputs).unwrap();
    assert_eq!(a, b);
}
