//! Integration: every artifact the active step engine serves loads and
//! executes.
//!
//! Backend selection is `Backend::Auto`: the PJRT engine over
//! `artifacts/` when built with `--features pjrt` and `make artifacts`
//! has run, the pure-Rust [`NativeEngine`] otherwise — so this suite
//! always executes real artifacts instead of silently skipping.

use std::sync::Arc;

use photonic_dfa::runtime::{self, Backend, StepEngine};
use photonic_dfa::tensor::Tensor;
use photonic_dfa::util::rng::Pcg64;

fn engine() -> Arc<dyn StepEngine> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    runtime::open(dir, Backend::Auto).unwrap()
}

#[test]
fn every_artifact_loads_and_executes() {
    let engine = engine();
    let specs = engine.artifact_specs();
    assert!(specs.len() >= 13, "expected full artifact set, got {specs:?}");
    let mut rng = Pcg64::seed(0);
    for spec in specs {
        let art = engine.load(&spec.name).unwrap();
        let inputs: Vec<Tensor> = art
            .spec()
            .inputs
            .iter()
            .map(|s| match s.name.as_str() {
                // keep runtime scalars in sane ranges
                "sigma" | "bits" => Tensor::scalar(0.0),
                "lr" => Tensor::scalar(0.01),
                "momentum" => Tensor::scalar(0.9),
                "r" => Tensor::scalar(0.95),
                "a" => Tensor::scalar(0.999),
                _ => Tensor::randn(&s.shape, 0.1, &mut rng),
            })
            .collect();
        let outputs = art.execute(&inputs).unwrap();
        assert_eq!(outputs.len(), art.spec().outputs.len(), "artifact {}", spec.name);
        for (out, ospec) in outputs.iter().zip(&art.spec().outputs) {
            assert_eq!(out.shape(), ospec.shape.as_slice(), "artifact {}", spec.name);
            assert!(
                out.data().iter().all(|v| v.is_finite()),
                "artifact {} produced non-finite values",
                spec.name
            );
        }
    }
}

#[test]
fn photonic_matvec_artifact_matches_rust_device_physics() {
    // The weight-bank matvec artifact and the L3 photonics::mrr module
    // implement the same Lorentzian physics; pin them against each other
    // (under PJRT this cross-checks the L1 Pallas kernel's HLO).
    let engine = engine();
    let art = engine.load("photonic_matvec").unwrap();
    let mut rng = Pcg64::seed(5);
    let k = art.spec().inputs[0].shape[0];
    let m = art.spec().inputs[1].shape[0];
    let x = Tensor::rand_uniform(&[k], 0.0, 1.0, &mut rng);
    let phi = Tensor::rand_uniform(&[m, k], -0.5, 0.5, &mut rng);
    let (r, a) = (0.95f32, 0.999f32);
    let out = art
        .execute(&[x.clone(), phi.clone(), Tensor::scalar(r), Tensor::scalar(a)])
        .unwrap();

    use photonic_dfa::photonics::mrr::MrrDesign;
    let design = MrrDesign { self_coupling: r as f64, loss_a: a as f64 };
    for row in 0..m {
        let want: f64 = (0..k)
            .map(|c| x.data()[c] as f64 * design.weight(phi.at(row, c) as f64))
            .sum();
        let got = out[0].data()[row] as f64;
        assert!(
            (got - want).abs() < 1e-4 * k as f64,
            "row {row}: rust {want} vs artifact {got}"
        );
    }
}

#[test]
fn fwd_artifact_deterministic_across_executions() {
    let engine = engine();
    let fwd = engine.load("fwd_small").unwrap();
    let mut rng = Pcg64::seed(9);
    let inputs: Vec<Tensor> = fwd
        .spec()
        .inputs
        .iter()
        .map(|s| Tensor::randn(&s.shape, 0.2, &mut rng))
        .collect();
    let a = fwd.execute(&inputs).unwrap();
    let b = fwd.execute(&inputs).unwrap();
    assert_eq!(a, b);
}

#[test]
fn backend_selection_is_explicit() {
    // native always opens, even with no artifact directory at all
    let nowhere = std::env::temp_dir().join("pdfa_missing_artifacts");
    let native = runtime::open(&nowhere, Backend::Native).unwrap();
    assert_eq!(native.platform_name(), "native");
    // pjrt demands both the feature and a manifest
    if !cfg!(feature = "pjrt") {
        assert!(runtime::open(&nowhere, Backend::Pjrt).is_err());
    }
}
