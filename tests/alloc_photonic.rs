//! Proof that the photonic per-dispatch path is allocation-free at
//! steady state: a counting global allocator wraps `System`, a
//! `BankDispatcher`'s pools are warmed up, and then repeated
//! `linear_into` / `dfa_gradient_into` dispatches must not allocate
//! once. Run at `threads = 1` — the only configuration where
//! "allocation-free" is even definable (spawning worker threads
//! allocates stacks by nature); the multi-threaded path shares every
//! per-row kernel with this one.
//!
//! This file deliberately holds a SINGLE test: the allocator counter is
//! process-global, and libtest runs tests in parallel threads, so any
//! sibling test in this binary could pollute the count.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use photonic_dfa::runtime::{BankDispatcher, PhysicsConfig};
use photonic_dfa::tensor::Tensor;
use photonic_dfa::util::rng::Pcg64;

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn photonic_dispatch_is_allocation_free_at_steady_state() {
    // realistic degraded physics: quantised converters, read noise,
    // crosstalk — every conditional branch of the signal chain is live.
    // `lock` is exercised both ways: the feedback-locked inscription is
    // the expensive path and must be just as heap-free as the exact one.
    for lock in [false, true] {
        let phys = PhysicsConfig {
            bank_rows: 7,
            bank_cols: 5,
            dac_bits: 6,
            adc_bits: 6,
            sigma: 0.1,
            crosstalk: true,
            lock,
            ..PhysicsConfig::ideal()
        };
        let mut disp = BankDispatcher::new(phys, 1).unwrap();
        assert_eq!(disp.threads(), 1);

        let mut rng = Pcg64::seed(11);
        let (batch, k, m) = (4usize, 11usize, 9usize); // ragged multi-tile
        let x = Tensor::rand_uniform(&[batch, k], 0.0, 1.0, &mut rng);
        let w = Tensor::rand_uniform(&[k, m], -0.9, 0.9, &mut rng);
        let b = Tensor::rand_uniform(&[m], -0.2, 0.2, &mut rng);
        let bmat = Tensor::rand_uniform(&[m, k], -0.9, 0.9, &mut rng);
        let e = Tensor::randn(&[batch, k], 0.5, &mut rng);
        let a = Tensor::randn(&[batch, m], 1.0, &mut rng);
        let mut y = Tensor::zeros(&[batch, m]);
        let mut g = Tensor::zeros(&[m, batch]);

        // the drift-tick refresh rides the same steady-state contract:
        // the phase buffer is the caller's, the stuck list reuses its
        // capacity after the warm-up pass below
        let drift_phases = vec![1e-4f64; 7 * 5];
        let stuck = [(3usize, 0.25f64)];

        // warm-up: plan the tilings, grow the snapshot pool and every
        // scratch buffer to steady-state capacity
        let mut op = 0u64;
        for _ in 0..3 {
            disp.set_drift(&drift_phases, &stuck).unwrap();
            disp.linear_into(op, &x, &w, Some(&b), &mut y).unwrap();
            op += 1;
            disp.dfa_gradient_into(op, &bmat, &e, &a, &mut g).unwrap();
            op += 1;
        }
        let before = ALLOC_CALLS.load(Ordering::Relaxed);
        for i in 0..50u64 {
            // refreshing the drift state every dispatch (the recal
            // scheduler's cadence upper bound) must stay heap-free too
            disp.set_drift(&drift_phases, &stuck).unwrap();
            disp.linear_into(op, &x, &w, Some(&b), &mut y).unwrap();
            disp.dfa_gradient_into(op + 1, &bmat, &e, &a, &mut g).unwrap();
            assert!(
                y.data().iter().all(|v| v.is_finite()),
                "lock={lock}: dispatch {i} produced non-finite output"
            );
        }
        let after = ALLOC_CALLS.load(Ordering::Relaxed);
        assert_eq!(
            after - before,
            0,
            "lock={lock}: photonic dispatch allocated {} times over 100 \
             steady-state dispatches",
            after - before
        );

        // the pooled path stayed numerically honest: the same op key
        // redraws the same counter-keyed noise, so outputs are
        // bit-identical after 100 buffer reuses. Since the lifetime
        // refactor this holds on BOTH inscription paths — the locked
        // path keys its lock-readout noise by (seed, op, tile) instead
        // of a bank-owned stream, making it a pure function of the
        // dispatch coordinates (the property checkpoint resume and
        // replica determinism are built on).
        disp.linear_into(op, &x, &w, Some(&b), &mut y).unwrap();
        disp.dfa_gradient_into(op + 1, &bmat, &e, &a, &mut g).unwrap();
        let mut y2 = Tensor::zeros(&[batch, m]);
        let mut g2 = Tensor::zeros(&[m, batch]);
        disp.linear_into(op, &x, &w, Some(&b), &mut y2).unwrap();
        disp.dfa_gradient_into(op + 1, &bmat, &e, &a, &mut g2).unwrap();
        assert_eq!(y, y2, "lock={lock}: same op key must redraw identically");
        assert_eq!(g, g2, "lock={lock}: same op key must redraw identically");
    }
}
