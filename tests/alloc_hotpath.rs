//! Proof that the NDJSON serving hot path is allocation-free at steady
//! state: a counting global allocator wraps `System`, the codec buffers
//! are warmed up, and then a thousand parse/serialize round trips must
//! not allocate once.
//!
//! This file deliberately holds a SINGLE test: the allocator counter is
//! process-global, and libtest runs tests in parallel threads, so any
//! sibling test in this binary could pollute the count.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use photonic_dfa::util::json_stream::{self, Lexer};

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn ndjson_round_trip_is_allocation_free_at_steady_state() {
    let mut lexer = Lexer::new();
    let mut line = String::new();
    let mut x: Vec<f32> = Vec::new();
    let mut logits: Vec<f32> = Vec::new();
    let mut errbuf = String::new();
    // a realistic request: wide enough that a per-feature allocation
    // would light the counter up hundreds of times per iteration
    let feats: Vec<f32> = (0..64).map(|j| j as f32 * 0.015_625 - 0.5).collect();

    // warm-up: grow every reusable buffer to its steady-state capacity
    for _ in 0..4 {
        json_stream::write_request(&mut line, Some(41), &feats);
        let id = json_stream::parse_request(&mut lexer, line.trim_end(), &mut x).unwrap();
        assert_eq!(id, Some(41));
        json_stream::write_reply(&mut line, id, 3, &x);
        let head = json_stream::parse_reply(
            &mut lexer,
            line.trim_end(),
            &mut logits,
            &mut errbuf,
        )
        .unwrap();
        assert_eq!(head.pred, Some(3));
        json_stream::write_error(&mut line, Some(9), "serve: queue is shut down");
        let head = json_stream::parse_reply(
            &mut lexer,
            line.trim_end(),
            &mut logits,
            &mut errbuf,
        )
        .unwrap();
        assert!(head.is_error);
    }

    let before = ALLOC_CALLS.load(Ordering::Relaxed);
    for i in 0..1000u64 {
        // client serializes a request, server parses it...
        json_stream::write_request(&mut line, Some(i), &feats);
        let id = json_stream::parse_request(&mut lexer, line.trim_end(), &mut x).unwrap();
        // ...server serializes the reply, client parses it back
        json_stream::write_reply(&mut line, id, (i % 10) as usize, &x);
        let head = json_stream::parse_reply(
            &mut lexer,
            line.trim_end(),
            &mut logits,
            &mut errbuf,
        )
        .unwrap();
        assert!(!head.is_error);
        assert!(logits == feats, "round trip drifted at iteration {i}");
    }
    let after = ALLOC_CALLS.load(Ordering::Relaxed);
    assert_eq!(
        after - before,
        0,
        "per-request hot path allocated {} times over 1000 round trips",
        after - before
    );
}
