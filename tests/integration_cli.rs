//! Integration: exercise the `pdfa` binary end-to-end.

use std::process::Command;

fn pdfa() -> Command {
    Command::new(env!("CARGO_BIN_EXE_pdfa"))
}

#[test]
fn help_lists_commands() {
    let out = pdfa().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in ["train", "energy", "characterize", "inner-product", "gen-data"] {
        assert!(text.contains(cmd), "missing {cmd} in help");
    }
}

#[test]
fn unknown_command_fails_cleanly() {
    let out = pdfa().arg("bogus").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn energy_reports_paper_numbers() {
    let out = pdfa().args(["energy", "--fig6-points", "6"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("TOPS/mm^2"));
    assert!(text.contains("Fig. 6"));
    // headline throughput row
    assert!(text.contains("20.000"), "{text}");
}

#[test]
fn characterize_runs_small_sample() {
    let out = pdfa()
        .args(["characterize", "--n", "200", "--seed", "3"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("single-MRR multiply"));
    assert!(text.contains("bits"));
}

#[test]
fn gen_data_writes_idx_files() {
    let dir = std::env::temp_dir().join("pdfa_cli_gendata");
    let _ = std::fs::remove_dir_all(&dir);
    let out = pdfa()
        .args([
            "gen-data",
            "--out",
            dir.to_str().unwrap(),
            "--n-train",
            "64",
            "--n-test",
            "32",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    for f in [
        "train-images-idx3-ubyte.gz",
        "train-labels-idx1-ubyte.gz",
        "t10k-images-idx3-ubyte.gz",
        "t10k-labels-idx1-ubyte.gz",
    ] {
        assert!(dir.join(f).exists(), "missing {f}");
    }
    // and the files round-trip through the loader
    let ds = photonic_dfa::data::Dataset::load_split(&dir, true).unwrap();
    assert_eq!(ds.len(), 64);
}

#[test]
fn train_small_run_produces_artifacts() {
    // runs on the native backend when no AOT artifacts are present
    let out_dir = std::env::temp_dir().join("pdfa_cli_train");
    let _ = std::fs::remove_dir_all(&out_dir);
    let out = pdfa()
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .args([
            "train",
            "--config", "small",
            "--noise", "offchip",
            "--epochs", "1",
            "--n-train", "256",
            "--n-test", "128",
            "--max-steps", "4",
            "--out", out_dir.to_str().unwrap(),
            "--run-name", "cli_test",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let run = out_dir.join("cli_test");
    for f in ["config.json", "history.json", "final.ckpt", "result.json"] {
        assert!(run.join(f).exists(), "missing {f}");
    }
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("test accuracy"));
}

#[test]
fn bad_flags_rejected() {
    let out = pdfa().args(["train", "--nonsense", "1"]).output().unwrap();
    assert!(!out.status.success());
    let out = pdfa().args(["train", "--noise", "bogus:xyz"]).output().unwrap();
    assert!(!out.status.success());
    let out = pdfa().args(["train", "--backend", "bogus"]).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn info_lists_native_artifacts_without_manifest() {
    let out = pdfa()
        .args(["info", "--backend", "native"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("backend: native"), "{text}");
    for needle in ["small: 784-128-128-10 batch 64", "dfa_step_mnist", "photonic_matvec"] {
        assert!(text.contains(needle), "missing {needle} in:\n{text}");
    }
}
