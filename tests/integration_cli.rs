//! Integration: exercise the `pdfa` binary end-to-end.

use std::process::Command;

fn pdfa() -> Command {
    Command::new(env!("CARGO_BIN_EXE_pdfa"))
}

#[test]
fn help_lists_commands() {
    let out = pdfa().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in ["train", "energy", "characterize", "inner-product", "gen-data", "report"] {
        assert!(text.contains(cmd), "missing {cmd} in help");
    }
}

#[test]
fn unknown_command_fails_cleanly() {
    let out = pdfa().arg("bogus").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn energy_reports_paper_numbers() {
    let out = pdfa().args(["energy", "--fig6-points", "6"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("TOPS/mm^2"));
    assert!(text.contains("Fig. 6"));
    // headline throughput row
    assert!(text.contains("20.000"), "{text}");
}

#[test]
fn characterize_runs_small_sample() {
    let out = pdfa()
        .args(["characterize", "--n", "200", "--seed", "3"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("single-MRR multiply"));
    assert!(text.contains("bits"));
}

#[test]
fn gen_data_writes_idx_files() {
    let dir = std::env::temp_dir().join("pdfa_cli_gendata");
    let _ = std::fs::remove_dir_all(&dir);
    let out = pdfa()
        .args([
            "gen-data",
            "--out",
            dir.to_str().unwrap(),
            "--n-train",
            "64",
            "--n-test",
            "32",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    for f in [
        "train-images-idx3-ubyte.gz",
        "train-labels-idx1-ubyte.gz",
        "t10k-images-idx3-ubyte.gz",
        "t10k-labels-idx1-ubyte.gz",
    ] {
        assert!(dir.join(f).exists(), "missing {f}");
    }
    // and the files round-trip through the loader
    let ds = photonic_dfa::data::Dataset::load_split(&dir, true).unwrap();
    assert_eq!(ds.len(), 64);
}

#[test]
fn train_small_run_produces_artifacts() {
    // runs on the native backend when no AOT artifacts are present
    let out_dir = std::env::temp_dir().join("pdfa_cli_train");
    let _ = std::fs::remove_dir_all(&out_dir);
    let out = pdfa()
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .args([
            "train",
            "--config", "small",
            "--noise", "offchip",
            "--epochs", "1",
            "--n-train", "256",
            "--n-test", "128",
            "--max-steps", "4",
            "--out", out_dir.to_str().unwrap(),
            "--run-name", "cli_test",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let run = out_dir.join("cli_test");
    for f in ["config.json", "history.json", "final.ckpt", "result.json"] {
        assert!(run.join(f).exists(), "missing {f}");
    }
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("test accuracy"));
}

#[test]
fn train_save_every_then_infer_is_bit_identical_to_reference() {
    use photonic_dfa::dfa::checkpoint::Checkpoint;
    use photonic_dfa::dfa::reference;
    use photonic_dfa::tensor::Tensor;
    use photonic_dfa::util::rng::Pcg64;

    let out_dir = std::env::temp_dir().join("pdfa_cli_ckpt_infer");
    let _ = std::fs::remove_dir_all(&out_dir);
    let out = pdfa()
        .args([
            "train",
            "--config", "tiny",
            "--epochs", "2",
            "--lr", "0.05",
            "--n-train", "128",
            "--n-test", "64",
            "--save-every", "1",
            "--out", out_dir.to_str().unwrap(),
            "--run-name", "ckpt_test",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let ckpt_path = out_dir.join("ckpt_test").join("ckpt.gz");
    assert!(ckpt_path.exists(), "default --save path not written");

    let logits_path = out_dir.join("logits.f32");
    let out = pdfa()
        .args([
            "infer",
            "--checkpoint", ckpt_path.to_str().unwrap(),
            "--n", "6",
            "--seed", "21",
            "--dump-logits", logits_path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("sample"), "{text}");
    assert!(text.contains("serve:"), "missing stats report: {text}");

    // the acceptance pin: served logits == reference::forward on the
    // loaded checkpoint params, bit for bit
    let ckpt = Checkpoint::load(&ckpt_path).unwrap();
    let mut rng = Pcg64::seed(21); // mirrors `pdfa infer --seed 21`
    let d_in = ckpt.dims.d_in;
    let mut want = Vec::new();
    for _ in 0..6 {
        let x: Vec<f32> = (0..d_in).map(|_| rng.uniform() as f32).collect();
        let xt = Tensor::new(&[1, d_in], x).unwrap();
        let fwd = reference::forward(ckpt.state.params(), &xt);
        for &v in fwd.logits.row(0) {
            want.extend_from_slice(&v.to_le_bytes());
        }
    }
    let got = std::fs::read(&logits_path).unwrap();
    assert_eq!(got, want, "CLI logits differ from reference::forward");
}

#[test]
fn serve_synthetic_smoke_run() {
    let out_dir = std::env::temp_dir().join("pdfa_cli_serve");
    let _ = std::fs::remove_dir_all(&out_dir);
    let out = pdfa()
        .args([
            "train",
            "--config", "tiny",
            "--epochs", "1",
            "--max-steps", "2",
            "--n-train", "64",
            "--n-test", "32",
            "--out", out_dir.to_str().unwrap(),
            "--run-name", "serve_test",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let ckpt = out_dir.join("serve_test").join("final.ckpt");

    let out = pdfa()
        .args([
            "serve",
            "--checkpoint", ckpt.to_str().unwrap(),
            "--source", "synthetic",
            "--max-requests", "16",
            "--workers", "2",
            "--max-batch", "4",
            "--max-wait-ms", "1",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("served 16 synthetic requests"), "{text}");
    assert!(text.contains("serve: 16 ok / 0 failed"), "{text}");
}

/// Train a quick tiny checkpoint for the serve-path tests.
fn train_tiny_ckpt(tag: &str) -> std::path::PathBuf {
    let out_dir = std::env::temp_dir().join(format!("pdfa_cli_{tag}"));
    let _ = std::fs::remove_dir_all(&out_dir);
    let out = pdfa()
        .args([
            "train",
            "--config", "tiny",
            "--epochs", "1",
            "--max-steps", "2",
            "--n-train", "64",
            "--n-test", "32",
            "--out", out_dir.to_str().unwrap(),
            "--run-name", tag,
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    out_dir.join(tag).join("final.ckpt")
}

#[test]
fn serve_stdin_budget_counts_only_accepted_requests() {
    use std::io::Write;
    use std::process::Stdio;

    let ckpt = train_tiny_ckpt("serve_stdin_budget");
    let mut child = pdfa()
        .args([
            "serve",
            "--checkpoint", ckpt.to_str().unwrap(),
            "--max-requests", "2",
            "--max-wait-ms", "1",
        ])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .unwrap();
    // a wrong-width line first, then three good ones: the rejected
    // submit must NOT consume the 2-request budget (it used to, so the
    // run stopped one accepted request short)
    let good: String =
        (0..16).map(|j| format!("{} ", 0.1 + j as f64 * 0.01)).collect();
    let mut input = String::from("0.5 0.5\n");
    for _ in 0..3 {
        input.push_str(good.trim_end());
        input.push('\n');
    }
    child.stdin.take().unwrap().write_all(input.as_bytes()).unwrap();
    let out = child.wait_with_output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(
        text.lines().any(|l| l.starts_with("error:") && l.contains("features")),
        "wrong-width line must error: {text}"
    );
    let preds = text.lines().filter(|l| l.starts_with("pred ")).count();
    assert_eq!(preds, 2, "budget is 2 ACCEPTED requests: {text}");
    assert!(text.contains("serve: 2 ok / 0 failed"), "{text}");
}

#[test]
fn serve_listen_tcp_round_trip_bit_exact() {
    use photonic_dfa::dfa::checkpoint::Checkpoint;
    use photonic_dfa::dfa::reference;
    use photonic_dfa::tensor::Tensor;
    use photonic_dfa::util::json_stream::{self, Lexer};
    use std::io::{BufRead, BufReader, Read, Write};
    use std::process::Stdio;

    let ckpt_path = train_tiny_ckpt("serve_listen_tcp");
    let mut child = pdfa()
        .args([
            "serve",
            "--checkpoint", ckpt_path.to_str().unwrap(),
            "--source", "listen",
            "--listen", "127.0.0.1:0",
            "--max-requests", "2",
            "--max-wait-ms", "1",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .unwrap();
    let mut child_out = BufReader::new(child.stdout.take().unwrap());
    let mut line = String::new();
    let addr = loop {
        line.clear();
        assert!(
            child_out.read_line(&mut line).unwrap() > 0,
            "server exited before announcing its port"
        );
        if let Some(rest) = line.trim().strip_prefix("listening on ") {
            break rest.to_string();
        }
    };

    let ckpt = Checkpoint::load(&ckpt_path).unwrap();
    let d_in = ckpt.dims.d_in;
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    stream.set_read_timeout(Some(std::time::Duration::from_secs(30))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    let mut lexer = Lexer::new();
    let mut out = String::new();
    let mut logits = Vec::new();
    let mut errbuf = String::new();
    for id in 0..2u64 {
        let x: Vec<f32> =
            (0..d_in).map(|j| (j as f32 + id as f32 * 3.0) * 0.02).collect();
        json_stream::write_request(&mut out, Some(id), &x);
        w.write_all(out.as_bytes()).unwrap();
        w.flush().unwrap();
        line.clear();
        assert!(reader.read_line(&mut line).unwrap() > 0, "no reply for {id}");
        let head = json_stream::parse_reply(
            &mut lexer,
            line.trim_end(),
            &mut logits,
            &mut errbuf,
        )
        .unwrap();
        assert!(!head.is_error, "{line}");
        assert_eq!(head.id, Some(id));
        // the acceptance pin: logits over TCP == reference::forward on
        // the checkpoint params, bit for bit
        let xt = Tensor::new(&[1, d_in], x).unwrap();
        let want = reference::forward(ckpt.state.params(), &xt);
        assert_eq!(logits, want.logits.row(0), "TCP logits drifted");
    }
    drop(w);
    drop(reader);

    // budget reached: the server drains and exits on its own
    let mut rest = String::new();
    child_out.read_to_string(&mut rest).unwrap();
    assert!(child.wait().unwrap().success(), "{rest}");
    assert!(rest.contains("2 accepted"), "{rest}");
    assert!(rest.contains("serve: 2 ok / 0 failed"), "{rest}");
}

#[test]
fn serve_tcp_driver_writes_bench_record() {
    use photonic_dfa::util::json::Value;

    let ckpt = train_tiny_ckpt("serve_tcp_bench");
    let bench_path = std::env::temp_dir().join("pdfa_cli_tcp_bench.json");
    let _ = std::fs::remove_file(&bench_path);
    let out = pdfa()
        .args([
            "serve",
            "--checkpoint", ckpt.to_str().unwrap(),
            "--source", "tcp",
            "--max-requests", "64",
            "--clients", "8",
            "--pipeline", "4",
            "--max-wait-ms", "1",
            "--verify",
            "--bench-out", bench_path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("tcp: 64 ok / 0 errors"), "{text}");
    assert!(text.contains("verified: 64 replies bit-exact"), "{text}");
    assert!(text.contains("serve: 64 ok"), "{text}");

    let record = std::fs::read_to_string(&bench_path).unwrap();
    let v = Value::parse(&record).unwrap();
    let get = |k: &str| match &v {
        Value::Object(map) => map.get(k).cloned().unwrap(),
        other => panic!("bench record is not an object: {other:?}"),
    };
    assert_eq!(get("bench"), Value::String("serve_tcp".into()));
    assert_eq!(get("ok"), Value::Number(64.0));
    assert_eq!(get("verified"), Value::Number(64.0));
    assert_eq!(get("clients"), Value::Number(8.0));
    assert!(matches!(get("latency_ns"), Value::Object(_)));
}

#[test]
fn malformed_checkpoints_rejected_cleanly() {
    let dir = std::env::temp_dir().join("pdfa_cli_badckpt");
    std::fs::create_dir_all(&dir).unwrap();

    // garbage bytes: Error::Format, not a panic
    let garbage = dir.join("garbage.ckpt");
    std::fs::write(&garbage, b"these are not the bytes you are looking for").unwrap();
    let out = pdfa()
        .args(["infer", "--checkpoint", garbage.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("format:"), "want a clean format error, got: {err}");
    assert!(!err.contains("panicked"), "{err}");

    // a truncated but genuine checkpoint: also Error::Format
    let real = {
        use photonic_dfa::dfa::config::TrainConfig;
        use photonic_dfa::dfa::trainer::Trainer;
        use photonic_dfa::runtime::NativeEngine;
        use std::sync::Arc;
        let engine: Arc<dyn photonic_dfa::runtime::StepEngine> =
            Arc::new(NativeEngine::new());
        let cfg = TrainConfig {
            config: "tiny".into(),
            n_train: 64,
            n_test: 32,
            ..TrainConfig::default()
        };
        Trainer::new(engine, cfg).unwrap().checkpoint().to_bytes()
    };
    let truncated = dir.join("truncated.ckpt");
    std::fs::write(&truncated, &real[..real.len() / 3]).unwrap();
    let out = pdfa()
        .args(["serve", "--checkpoint", truncated.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("format:"), "{err}");

    // a missing file: Error::Io
    let out = pdfa()
        .args(["infer", "--checkpoint", dir.join("nope.ckpt").to_str().unwrap()])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("io:"), "{err}");
}

#[test]
fn train_resume_matches_uninterrupted_run() {
    let out_dir = std::env::temp_dir().join("pdfa_cli_resume");
    let _ = std::fs::remove_dir_all(&out_dir);
    let base = |extra: &[&str], run: &str| {
        let mut args = vec![
            "train",
            "--config", "tiny",
            "--lr", "0.05",
            "--n-train", "128",
            "--n-test", "64",
            "--seed", "9",
            "--out", out_dir.to_str().unwrap(),
            "--run-name", run,
        ];
        args.extend_from_slice(extra);
        let out = pdfa().args(&args).output().unwrap();
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        String::from_utf8_lossy(&out.stdout).into_owned()
    };
    // straight 2-epoch run vs 1 epoch + resume for the second
    let straight = base(&["--epochs", "2"], "straight");
    base(&["--epochs", "1"], "head");
    let head_ckpt = out_dir.join("head").join("final.ckpt");
    let resumed = base(
        &["--epochs", "2", "--resume", head_ckpt.to_str().unwrap()],
        "tail",
    );
    let acc = |s: &str| {
        s.lines()
            .find(|l| l.starts_with("test accuracy:"))
            .map(|l| l.split_whitespace().nth(2).unwrap().to_string())
            .unwrap()
    };
    assert_eq!(acc(&straight), acc(&resumed), "\n{straight}\nvs\n{resumed}");
}

#[test]
fn bad_flags_rejected() {
    let out = pdfa().args(["train", "--nonsense", "1"]).output().unwrap();
    assert!(!out.status.success());
    let out = pdfa().args(["train", "--noise", "bogus:xyz"]).output().unwrap();
    assert!(!out.status.success());
    let out = pdfa().args(["train", "--backend", "bogus"]).output().unwrap();
    assert!(!out.status.success());
    // photonic physics values are validated, not coerced
    let out = pdfa()
        .args(["train", "--backend", "photonic", "--physics", "ideal,dac=-3"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let out = pdfa().args(["sweep-physics", "--bits", "-2"]).output().unwrap();
    assert!(!out.status.success());
    let out = pdfa().args(["sweep-physics", "--bits", "2.5"]).output().unwrap();
    assert!(!out.status.success());
    let out = pdfa().args(["sweep-physics", "--sigmas", "-0.1"]).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn info_lists_native_artifacts_without_manifest() {
    let out = pdfa()
        .args(["info", "--backend", "native"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("backend: native"), "{text}");
    for needle in ["small: 784-128-128-10 batch 64", "dfa_step_mnist", "photonic_matvec"] {
        assert!(text.contains(needle), "missing {needle} in:\n{text}");
    }
}

#[test]
fn bad_backend_error_enumerates_valid_values() {
    let out = pdfa().args(["info", "--backend", "bogus"]).output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    for valid in ["auto", "native", "photonic", "pjrt"] {
        assert!(err.contains(valid), "stderr should list '{valid}': {err}");
    }
}

#[test]
fn train_photonic_backend_completes_an_epoch() {
    // the acceptance smoke: `pdfa train --config tiny --backend photonic`
    // trains through the device-level bank end to end
    let out_dir = std::env::temp_dir().join("pdfa_cli_photonic");
    let _ = std::fs::remove_dir_all(&out_dir);
    let out = pdfa()
        .args([
            "train",
            "--config", "tiny",
            "--backend", "photonic",
            "--physics", "ideal",
            "--epochs", "1",
            "--n-train", "64",
            "--n-test", "32",
            "--max-steps", "2",
            "--out", out_dir.to_str().unwrap(),
            "--run-name", "photonic_smoke",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("test accuracy"), "{text}");
    // the run record carries the physics protocol
    let cfg = std::fs::read_to_string(out_dir.join("photonic_smoke/config.json")).unwrap();
    assert!(cfg.contains("bank=50x20"), "{cfg}");
    // a Gaussian-noise mode on the photonic backend is a clean error
    let out = pdfa()
        .args([
            "train",
            "--config", "tiny",
            "--backend", "photonic",
            "--noise", "offchip",
            "--epochs", "1",
            "--n-train", "64",
            "--n-test", "32",
            "--max-steps", "1",
            "--out", out_dir.to_str().unwrap(),
            "--run-name", "photonic_noise_clash",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("--physics"),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn sweep_physics_emits_accuracy_table() {
    let out = pdfa()
        .args([
            "sweep-physics",
            "--config", "tiny",
            "--physics", "ideal",
            "--bits", "0,4",
            "--sigmas", "0,0.1",
            "--epochs", "1",
            "--n-train", "64",
            "--n-test", "32",
            "--max-steps", "2",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("dac/adc bits"), "{text}");
    assert!(text.contains("test_acc"), "{text}");
    // 2 bits x 2 sigmas = 4 table rows + header (+ the banner line)
    let rows = text
        .lines()
        .filter(|l| l.contains("ideal") || l.trim_start().starts_with('4'))
        .count();
    assert!(rows >= 4, "expected 4 grid rows:\n{text}");
}

#[test]
fn info_photonic_reports_physics() {
    let out = pdfa()
        .args(["info", "--backend", "photonic", "--physics", "ideal,dac=6"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("backend: photonic"), "{text}");
    assert!(text.contains("dac=6"), "{text}");
    // bp_step is native-only: it must not appear in the photonic vocabulary
    assert!(!text.contains("bp_step"), "{text}");
}
