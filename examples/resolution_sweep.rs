//! Fig. 5(c): test accuracy vs effective resolution of the gradient
//! mat-vec, on the `small` (784-128-128-10) configuration.
//!
//! ```bash
//! cargo run --release --example resolution_sweep
//! # heavier, paper-network version:
//! PDFA_CONFIG=mnist PDFA_EPOCHS=5 cargo run --release --example resolution_sweep
//! ```
//!
//! Each sweep point trains a fresh network with gradient noise
//! σ = 2 / 2^bits, the paper's effective-resolution equivalence.

use photonic_dfa::experiments::fig5c_sweep;
use photonic_dfa::runtime::{self, Backend};

fn main() -> photonic_dfa::Result<()> {
    photonic_dfa::util::logging::init();
    let config = std::env::var("PDFA_CONFIG").unwrap_or_else(|_| "small".into());
    let epochs: usize = std::env::var("PDFA_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    let n_train: usize = std::env::var("PDFA_NTRAIN")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(16_384);

    let engine = runtime::open("artifacts", Backend::Auto)?;
    let bits = [1.0, 2.0, 3.0, 3.31, 4.0, 4.35, 5.0, 6.0, 8.0];
    let pts = fig5c_sweep(engine, &config, &bits, epochs, 1, n_train, 4096, None)?;

    println!("\nFig. 5(c) — test accuracy vs gradient effective resolution ({config}):\n");
    println!("bits    sigma      test_acc");
    for p in &pts {
        let marker = if (p.bits - 4.35).abs() < 0.01 {
            "   <- off-chip BPD operating point"
        } else if (p.bits - 3.31).abs() < 0.01 {
            "   <- on-chip BPD operating point"
        } else {
            ""
        };
        println!("{:>4.2}  {:.5}    {:.4}{marker}", p.bits, p.sigma, p.test_acc);
    }
    println!(
        "\npaper shape: accuracy saturates above ~4 bits; the off-chip (4.35 b) and \
         on-chip (3.31 b) operating points sit at ~97.4% and ~96.3% on MNIST"
    );
    Ok(())
}
