//! Device-mode training: the backward pass runs through the *device-level*
//! photonic simulator (MRR physics, calibration, BPD noise, crosstalk)
//! instead of the lumped Gaussian-noise model — the strongest validation
//! that the architecture of Fig. 4(b) trains networks end to end.
//!
//! ```bash
//! cargo run --release --example device_mode
//! ```
//!
//! The fixed feedback matrices B(k) are compiled onto the 50×20 bank once
//! (analog weight memory, §5); each training step then consumes only
//! optical cycles. Negative error values use differential encoding
//! (B·e = B·e⁺ − B·e⁻). The run also rolls the consumed bank cycles into
//! the paper's Eq. (2)/(4) energy model.

use photonic_dfa::dfa::config::TrainConfig;
use photonic_dfa::dfa::noise_model::NoiseMode;
use photonic_dfa::dfa::trainer::Trainer;
use photonic_dfa::energy::components::MrrTuning;
use photonic_dfa::energy::model::ArchitectureModel;
use photonic_dfa::photonics::BpdMode;
use photonic_dfa::runtime::{self, Backend};

fn main() -> photonic_dfa::Result<()> {
    photonic_dfa::util::logging::init();
    let engine = runtime::open("artifacts", Backend::Auto)?;

    let steps = std::env::var("PDFA_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(40);

    let mut results = Vec::new();
    for (label, noise) in [
        ("device (off-chip BPD)", NoiseMode::Device { bpd: BpdMode::OffChip }),
        ("gaussian (sigma 0.098)", NoiseMode::offchip()),
    ] {
        println!("\n=== {label} ===");
        let cfg = TrainConfig {
            config: "small".into(),
            noise,
            epochs: 2,
            n_train: 4096,
            n_test: 1024,
            seed: 11,
            max_steps_per_epoch: Some(steps),
            ..TrainConfig::default()
        };
        let mut trainer = Trainer::new(engine.clone(), cfg)?;
        let (train, test) = trainer.load_data()?;
        let result = trainer.train(train, test, |s| {
            println!(
                "  epoch {}: loss {:.4} val acc {:.4} ({:.1}s)",
                s.epoch,
                s.train_loss,
                s.val_acc.unwrap_or(f64::NAN),
                s.wall_s
            );
        })?;
        println!("  test accuracy: {:.4}", result.test_acc);
        results.push((label, result.test_acc));
    }

    // Energy roll-up for the device run, at the §5 operating point.
    let model = ArchitectureModel::paper(MrrTuning::Trimmed);
    let macs_per_cycle = 50 * 20;
    let total_steps = 2 * steps;
    // per step: 2 layers x batch 64 x 3 tiles x <=2 differential cycles
    let cycles_per_step = 2 * 64 * 3 * 2;
    let cycles = total_steps * cycles_per_step;
    let energy_j =
        cycles as f64 * macs_per_cycle as f64 * 2.0 * model.energy_per_op();
    let time_s = cycles as f64 / 10e9;
    println!(
        "\nprojected on-chip cost of the device-mode gradient pass \
         ({} bank cycles): {:.2} µJ, {:.2} µs at 10 GHz (Eq. 2/4, trimmed MRRs)",
        cycles,
        energy_j * 1e6,
        time_s * 1e6
    );

    println!("\nsummary:");
    for (label, acc) in &results {
        println!("  {label:<24} test acc {:.4}", acc);
    }
    println!(
        "\nthe device-level path should land within a few points of the lumped \
         Gaussian model — the paper's core robustness claim"
    );
    Ok(())
}
