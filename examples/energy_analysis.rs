//! Reproduce the paper's §5 energy/speed analysis: the headline numbers
//! (Eq. 2-4) and the Fig. 6 optimal-E_op sweep.
//!
//! ```bash
//! cargo run --release --example energy_analysis
//! ```

use photonic_dfa::energy::components::MrrTuning;
use photonic_dfa::energy::model::ArchitectureModel;
use photonic_dfa::experiments::energy_tables;

fn main() {
    println!("=== §5 headline summary (model vs paper) ===\n");
    print!("{}", energy_tables::render_headline());

    println!("\n=== Eq. (4) wall-plug power breakdown, 50x20 bank ===\n");
    for (name, tuning) in [
        ("heater-locked", MrrTuning::HeaterLocked),
        ("trimmed", MrrTuning::Trimmed),
    ] {
        let m = ArchitectureModel::paper(tuning);
        let b = m.power_breakdown();
        println!(
            "{name:>14}: laser {:>7.3} W | MRR {:>7.3} W | DAC {:>6.3} W | \
             TIA {:>6.3} W | ADC {:>6.3} W | total {:>7.3} W",
            b.laser_w, b.mrr_w, b.dac_w, b.tia_w, b.adc_w,
            b.total_w()
        );
    }

    println!("\n=== Fig. 6 — optimal E_op vs number of MAC cells ===\n");
    println!("cells     E_op heater (pJ)   E_op trimmed (pJ)");
    for (cells, h, t) in photonic_dfa::experiments::fig6_rows(25, 100_000, 16) {
        println!("{cells:>7}   {:>12.3}      {:>12.3}", h * 1e12, t * 1e12);
    }

    println!(
        "\npaper anchor: 50x20 bank @ 10 GHz => 20 TOPS, 1.0 pJ/op (heaters), \
         0.28 pJ/op (trimming), 5.78 TOPS/mm²"
    );
}
