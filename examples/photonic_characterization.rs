//! Reproduce the device-characterisation experiments of §2/§4:
//!
//! * Fig. 3(b): add-drop MRR transmission profile (r = 0.95, lossless)
//! * Fig. 3(c): 3900 single-MRR multiplications — error σ and bits
//! * Fig. 5(a): 5000 photonic 1×4 inner products per BPD circuit
//!
//! ```bash
//! cargo run --release --example photonic_characterization
//! ```

use photonic_dfa::experiments::{fig3b_curve, fig3c_multiply, fig5a_inner_products};
use photonic_dfa::photonics::BpdMode;

fn main() -> photonic_dfa::Result<()> {
    println!("=== Fig. 3(b): add-drop transmission profile (ASCII) ===\n");
    // render T_drop and the weight as a terminal plot
    let rows = fig3b_curve(61);
    for (phi, tp, td, w) in &rows {
        if (phi * 10.0).round() % 2.0 != 0.0 {
            continue;
        }
        let bar = |v: f64| {
            let n = ((v + 1.0) / 2.0 * 40.0).round() as usize;
            format!("{}*", " ".repeat(n))
        };
        println!(
            "phi {phi:>6.2}  Tp {tp:>6.3}  Td {td:>6.3}  w {w:>6.3} |{}",
            bar(*w)
        );
    }

    println!("\n=== Fig. 3(c): single-MRR multiplication (n = 3900) ===\n");
    let m = fig3c_multiply(3900, 7)?;
    println!(
        "measured: sigma = {:.4}, mean = {:+.4}, effective resolution = {:.2} bits",
        m.sigma, m.mean, m.effective_bits
    );
    println!("paper:    sigma = 0.0190, mean = -0.0010, effective resolution = 6.72 bits");

    println!("\n=== Fig. 5(a): 1x4 photonic inner products (n = 5000 each) ===\n");
    for (label, mode, psig, pbits) in [
        ("off-chip BPD", BpdMode::OffChip, 0.098, 4.35),
        ("on-chip BPD", BpdMode::OnChip, 0.202, 3.31),
    ] {
        let m = fig5a_inner_products(mode, 5000, 7)?;
        println!(
            "{label:<13} measured sigma {:.4} ({:.2} bits)   paper {psig} ({pbits} bits)",
            m.sigma, m.effective_bits
        );
    }
    Ok(())
}
