//! Quickstart: train a small MLP through the photonic DFA path.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Resolves the `dfa_step_small` artifact (784-128-128-10) on the default
//! backend (native reference math; PJRT over the AOT artifacts when built
//! with `--features pjrt` after vendoring the `xla` crate — see
//! `Cargo.toml` — and running `make artifacts`), synthesises a small
//! digit dataset, and trains for two epochs with the off-chip-BPD noise
//! level of the paper's Fig. 5 — all from Rust, with Python nowhere on
//! the path.

use photonic_dfa::dfa::config::TrainConfig;
use photonic_dfa::dfa::noise_model::NoiseMode;
use photonic_dfa::dfa::trainer::Trainer;
use photonic_dfa::runtime::{self, Backend};

fn main() -> photonic_dfa::Result<()> {
    photonic_dfa::util::logging::init();

    // 1. a step engine (native by default; PJRT with --features pjrt)
    let engine = runtime::open("artifacts", Backend::Auto)?;

    // 2. a Fig. 5(b)-style configuration, shrunk to run in seconds
    let cfg = TrainConfig {
        config: "small".into(),
        noise: NoiseMode::offchip(), // the measured sigma = 0.098 circuit
        epochs: 2,
        n_train: 4096,
        n_test: 1024,
        seed: 42,
        ..TrainConfig::default()
    };

    // 3. train
    let mut trainer = Trainer::new(engine, cfg)?;
    let (train, test) = trainer.load_data()?;
    let result = trainer.train(train, test, |stats| {
        println!(
            "epoch {}: loss {:.4}, val acc {:.4}",
            stats.epoch,
            stats.train_loss,
            stats.val_acc.unwrap_or(f64::NAN)
        );
    })?;

    println!("\nfinal test accuracy: {:.4}", result.test_acc);
    println!(
        "{} steps in {:.1}s ({:.1} steps/s); {} gradient MACs on the photonic path",
        result.total_steps,
        result.wall_s,
        result.total_steps as f64 / result.wall_s,
        result.photonic_macs
    );
    Ok(())
}
