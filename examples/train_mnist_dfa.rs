//! END-TO-END DRIVER (Fig. 5(b)): train the paper's 784×800×800×10 network
//! (~1.28 M parameters) with DFA under the three measured noise conditions
//! and log the loss/accuracy curves.
//!
//! ```bash
//! cargo run --release --example train_mnist_dfa                # full run
//! PDFA_EPOCHS=3 PDFA_NTRAIN=12000 cargo run --release --example train_mnist_dfa
//! PDFA_DATA_DIR=/path/to/mnist cargo run --release --example train_mnist_dfa
//! ```
//!
//! This exercises every layer of the stack on a real workload: the Rust
//! coordinator streams mini-batches and samples read noise (L3), and each
//! step is one dispatch of the fused train-step artifact — native
//! reference math by default, or the AOT-compiled L2/L1 HLO through PJRT
//! with `--features pjrt` after `make artifacts`. Results land in
//! runs/fig5b_*.

use photonic_dfa::coordinator::run::RunRecorder;
use photonic_dfa::dfa::config::TrainConfig;
use photonic_dfa::dfa::noise_model::NoiseMode;
use photonic_dfa::dfa::trainer::Trainer;
use photonic_dfa::runtime::{self, Backend};
use photonic_dfa::util::json::Value;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> photonic_dfa::Result<()> {
    photonic_dfa::util::logging::init();
    let epochs = env_usize("PDFA_EPOCHS", 10);
    let n_train = env_usize("PDFA_NTRAIN", 60_000);
    let n_test = env_usize("PDFA_NTEST", 10_000);
    let data_dir = std::env::var("PDFA_DATA_DIR").ok();

    let engine = runtime::open("artifacts", Backend::Auto)?;
    let conditions: [(&str, NoiseMode); 3] = [
        ("clean", NoiseMode::Clean),
        ("offchip", NoiseMode::offchip()),
        ("onchip", NoiseMode::onchip()),
    ];

    let mut finals: Vec<(String, f64, f64)> = Vec::new();
    for (label, noise) in conditions {
        println!("\n=== Fig. 5(b) condition: {label} ({}) ===", noise.describe());
        let cfg = TrainConfig {
            config: "mnist".into(),
            noise,
            epochs,
            n_train,
            n_test,
            seed: 1,
            data_dir: data_dir.clone(),
            ..TrainConfig::default()
        };
        let mut recorder = RunRecorder::create("runs", &format!("fig5b_{label}"))?;
        recorder.write_config(&cfg.to_json())?;
        let mut trainer = Trainer::new(engine.clone(), cfg)?;
        let (train, test) = trainer.load_data()?;
        let result = {
            let rec = std::cell::RefCell::new(&mut recorder);
            trainer.train(train, test, |stats| {
                println!(
                    "  epoch {:2}: loss {:.4}  val acc {:.4}  ({:.1}s)",
                    stats.epoch,
                    stats.train_loss,
                    stats.val_acc.unwrap_or(f64::NAN),
                    stats.wall_s
                );
                let _ = rec.borrow_mut().record_epoch(stats.to_json());
            })?
        };
        recorder.write_report(
            "result.json",
            &Value::object(vec![
                ("test_acc", Value::Number(result.test_acc)),
                ("wall_s", Value::Number(result.wall_s)),
                ("steps", Value::Number(result.total_steps as f64)),
                ("photonic_macs", Value::Number(result.photonic_macs as f64)),
            ]),
        )?;
        println!(
            "  -> {label}: test accuracy {:.4} ({} steps, {:.1}s, {:.1} steps/s)",
            result.test_acc,
            result.total_steps,
            result.wall_s,
            result.total_steps as f64 / result.wall_s
        );
        finals.push((label.to_string(), result.test_acc, result.wall_s));
    }

    println!("\n=== summary (paper MNIST values in brackets) ===");
    let paper = [("clean", 98.10), ("offchip", 97.41), ("onchip", 96.33)];
    for ((label, acc, _), (_, pacc)) in finals.iter().zip(paper) {
        println!("{label:>8}: {:.2}%  [{pacc}%]", acc * 100.0);
    }
    if finals.len() == 3 {
        let (c, off, on) = (finals[0].1, finals[1].1, finals[2].1);
        println!(
            "degradation clean->offchip: {:.2}pp [paper 0.69pp], \
             clean->onchip: {:.2}pp [paper 1.77pp]",
            (c - off) * 100.0,
            (c - on) * 100.0
        );
        assert!(c >= off && off >= on, "noise ordering should hold");
    }
    Ok(())
}
