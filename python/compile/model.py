"""L2: the neural network trained by the photonic DFA architecture.

The paper's experiment (§4): a feed-forward MLP (784 x 800 x 800 x 10 for
MNIST), ReLU hidden activations, softmax output, cross-entropy loss, trained
with SGD + momentum (lr 0.01, momentum 0.9, batch 64). The backward pass is
Direct Feedback Alignment (Eq. 1): per hidden layer k,

    delta(k) = B(k) e  ⊙  g'(a(k))

with the B(k) e mat-vec executed *in the analog photonic domain* — here the
weight-bank Pallas kernel (kernels.weight_bank) with additive Gaussian read
noise and optional ADC quantisation, both runtime scalars so one artifact
serves the noise-free, off-chip-BPD (sigma=0.098), on-chip-BPD (sigma=0.202)
and resolution-sweep configurations of Figs. 5(b,c).

Everything here is traced ONCE by aot.py into HLO text; Python never runs on
the training path. Argument lists are flat and positional — the artifact
manifest records their order for the Rust runtime.

Functions:
  forward          inference pass, returns logits + pre/post activations
  dfa_step         one full DFA training step (fwd + analog bwd + update)
  bp_step          backpropagation baseline step (noise-free, digital)
  apply_grads      device-mode weight update from externally computed deltas
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from .kernels import dfa_gradient


class NetConfig(NamedTuple):
    """Static network/shape configuration baked into each artifact."""

    name: str
    d_in: int
    d_h1: int
    d_h2: int
    d_out: int
    batch: int

    @property
    def param_shapes(self):
        return [
            ("w1", (self.d_in, self.d_h1)),
            ("b1", (self.d_h1,)),
            ("w2", (self.d_h1, self.d_h2)),
            ("b2", (self.d_h2,)),
            ("w3", (self.d_h2, self.d_out)),
            ("b3", (self.d_out,)),
        ]


# The three artifact configurations (DESIGN.md §4).
CONFIGS = {
    "tiny": NetConfig("tiny", 16, 32, 32, 4, 8),
    "small": NetConfig("small", 784, 128, 128, 10, 64),
    "mnist": NetConfig("mnist", 784, 800, 800, 10, 64),
}

N_PARAMS = 6  # w1 b1 w2 b2 w3 b3


def forward(w1, b1, w2, b2, w3, b3, x):
    """Inference. x: (batch, d_in). Returns (logits, a1, a2, h1, h2)."""
    a1 = x @ w1 + b1
    h1 = jnp.maximum(a1, 0.0)
    a2 = h1 @ w2 + b2
    h2 = jnp.maximum(a2, 0.0)
    logits = h2 @ w3 + b3
    return logits, a1, a2, h1, h2


def _loss_and_error(logits, y):
    """Softmax cross-entropy. y: (batch, C) one-hot.

    Returns (mean loss, per-sample error e = dL/dlogits * batch, #correct).
    The paper's e is the per-example gradient of the loss: softmax(z) - y.
    """
    zmax = jnp.max(logits, axis=1, keepdims=True)
    z = logits - zmax
    logsumexp = jnp.log(jnp.sum(jnp.exp(z), axis=1, keepdims=True))
    logp = z - logsumexp
    loss = -jnp.mean(jnp.sum(y * logp, axis=1))
    e = jnp.exp(logp) - y  # (batch, C)
    ncorrect = jnp.sum(
        (jnp.argmax(logits, axis=1) == jnp.argmax(y, axis=1)).astype(jnp.float32)
    )
    return loss, e, ncorrect


def _sgd_momentum(params, vels, grads, lr, momentum):
    new_v = [momentum * v + g for v, g in zip(vels, grads)]
    new_p = [p - lr * v for p, v in zip(params, new_v)]
    return new_p, new_v


def _grads_from_deltas(x, h1, h2, e, d1t, d2t, batch):
    """Weight/bias gradients given hidden-layer deltas.

    d1t, d2t: (H, batch) — note the transposed (analog-output) layout.
    """
    gw3 = h2.T @ e / batch
    gb3 = jnp.sum(e, axis=0) / batch
    gw2 = h1.T @ d2t.T / batch
    gb2 = jnp.sum(d2t, axis=1) / batch
    gw1 = x.T @ d1t.T / batch
    gb1 = jnp.sum(d1t, axis=1) / batch
    return [gw1, gb1, gw2, gb2, gw3, gb3]


def dfa_step(
    w1, b1, w2, b2, w3, b3,
    vw1, vb1, vw2, vb2, vw3, vb3,
    bmat1,   # (H1, C) fixed random feedback for hidden layer 1
    bmat2,   # (H2, C) fixed random feedback for hidden layer 2
    x,       # (batch, d_in)
    y,       # (batch, C) one-hot targets
    noise1,  # (H1, batch) standard-normal draws (Rust-sampled)
    noise2,  # (H2, batch)
    sigma,   # () analog read-noise std (normalised domain); 0 = noise-free
    bits,    # () ADC resolution; <= 0 = off
    lr,      # ()
    momentum,  # ()
):
    """One DFA training step. Returns 12 updated state arrays + loss + #correct.

    The two B(k) e mat-vecs — the only backward-pass operations the photonic
    circuit performs — run through the weight-bank Pallas kernel; everything
    else (inference, error, update) is full-precision digital, exactly as in
    the paper's experimental protocol (§4).
    """
    params = [w1, b1, w2, b2, w3, b3]
    vels = [vw1, vb1, vw2, vb2, vw3, vb3]
    batch = x.shape[0]

    logits, a1, a2, h1, h2 = forward(*params, x)
    loss, e, ncorrect = _loss_and_error(logits, y)

    # Analog backward pass: both hidden layers in parallel, same error.
    gp1 = (a1 > 0.0).astype(jnp.float32).T  # (H1, batch) TIA gains
    gp2 = (a2 > 0.0).astype(jnp.float32).T
    et = e.T  # (C, batch): error amplitude-encoded on C WDM channels
    d1t = dfa_gradient(bmat1, et, noise1, gp1, sigma, bits)
    d2t = dfa_gradient(bmat2, et, noise2, gp2, sigma, bits)

    grads = _grads_from_deltas(x, h1, h2, e, d1t, d2t, batch)
    new_p, new_v = _sgd_momentum(params, vels, grads, lr, momentum)
    return (*new_p, *new_v, loss, ncorrect)


def bp_step(
    w1, b1, w2, b2, w3, b3,
    vw1, vb1, vw2, vb2, vw3, vb3,
    x, y, lr, momentum,
):
    """Backpropagation baseline (digital, noise-free). Same returns as dfa_step."""
    params = [w1, b1, w2, b2, w3, b3]
    vels = [vw1, vb1, vw2, vb2, vw3, vb3]
    batch = x.shape[0]

    logits, a1, a2, h1, h2 = forward(*params, x)
    loss, e, ncorrect = _loss_and_error(logits, y)

    d2 = (e @ w3.T) * (a2 > 0.0).astype(jnp.float32)  # (batch, H2)
    d1 = (d2 @ w2.T) * (a1 > 0.0).astype(jnp.float32)

    grads = _grads_from_deltas(x, h1, h2, e, d1.T, d2.T, batch)
    new_p, new_v = _sgd_momentum(params, vels, grads, lr, momentum)
    return (*new_p, *new_v, loss, ncorrect)


def apply_grads(
    w1, b1, w2, b2, w3, b3,
    vw1, vb1, vw2, vb2, vw3, vb3,
    x, h1, h2,
    e,       # (batch, C)
    d1t,     # (H1, batch) delta from the device-level photonic simulator
    d2t,     # (H2, batch)
    lr, momentum,
):
    """Device-mode update: deltas were computed by the Rust photonic
    simulator (photonics::weight_bank); this artifact applies the digital
    outer-product weight update (§3: performed by the control system)."""
    params = [w1, b1, w2, b2, w3, b3]
    vels = [vw1, vb1, vw2, vb2, vw3, vb3]
    batch = x.shape[0]
    grads = _grads_from_deltas(x, h1, h2, e, d1t, d2t, batch)
    new_p, new_v = _sgd_momentum(params, vels, grads, lr, momentum)
    return (*new_p, *new_v)
