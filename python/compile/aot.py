"""AOT compile path: lower every L2 function to HLO *text* + a manifest.

Run once by `make artifacts`:

    cd python && python -m compile.aot --out ../artifacts

Interchange is HLO **text**, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the Rust side's XLA
(xla_extension 0.5.1, via the `xla` crate) rejects (`proto.id() <= INT_MAX`).
The text parser reassigns ids and round-trips cleanly — see
/opt/xla-example/README.md.

Alongside the .hlo.txt files we emit `manifest.json`: for every artifact the
ordered input/output names, shapes and dtypes, plus the network configs.
The Rust runtime (runtime::manifest) is entirely manifest-driven — no shape
is hard-coded on the Rust side.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import BANK_COLS, BANK_ROWS, mrr_bank_matvec

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, F32)


def _io(names_shapes):
    return [
        {"name": n, "shape": list(s), "dtype": "f32"} for n, s in names_shapes
    ]


def _state_io(cfg: model.NetConfig, prefix=""):
    out = []
    for name, shape in cfg.param_shapes:
        out.append((prefix + name, shape))
    for name, shape in cfg.param_shapes:
        out.append((prefix + "v" + name, shape))
    return out


def build_artifacts(cfg: model.NetConfig):
    """Returns {artifact_name: (lowered, inputs, outputs)} for one config."""
    p_specs = [_spec(s) for _, s in cfg.param_shapes]
    state_specs = p_specs + p_specs  # params + momentum
    x_spec = _spec((cfg.batch, cfg.d_in))
    y_spec = _spec((cfg.batch, cfg.d_out))
    b1_spec = _spec((cfg.d_h1, cfg.d_out))
    b2_spec = _spec((cfg.d_h2, cfg.d_out))
    n1_spec = _spec((cfg.d_h1, cfg.batch))
    n2_spec = _spec((cfg.d_h2, cfg.batch))
    scalar = _spec(())

    arts = {}

    fwd_lowered = jax.jit(model.forward).lower(*p_specs, x_spec)
    arts[f"fwd_{cfg.name}"] = (
        fwd_lowered,
        _io([(n, s) for n, s in cfg.param_shapes] + [("x", x_spec.shape)]),
        _io([
            ("logits", (cfg.batch, cfg.d_out)),
            ("a1", (cfg.batch, cfg.d_h1)),
            ("a2", (cfg.batch, cfg.d_h2)),
            ("h1", (cfg.batch, cfg.d_h1)),
            ("h2", (cfg.batch, cfg.d_h2)),
        ]),
    )

    dfa_lowered = jax.jit(model.dfa_step).lower(
        *state_specs, b1_spec, b2_spec, x_spec, y_spec, n1_spec, n2_spec,
        scalar, scalar, scalar, scalar,
    )
    dfa_inputs = _state_io(cfg) + [
        ("bmat1", b1_spec.shape), ("bmat2", b2_spec.shape),
        ("x", x_spec.shape), ("y", y_spec.shape),
        ("noise1", n1_spec.shape), ("noise2", n2_spec.shape),
        ("sigma", ()), ("bits", ()), ("lr", ()), ("momentum", ()),
    ]
    step_outputs = _state_io(cfg) + [("loss", ()), ("ncorrect", ())]
    arts[f"dfa_step_{cfg.name}"] = (dfa_lowered, _io(dfa_inputs), _io(step_outputs))

    bp_lowered = jax.jit(model.bp_step).lower(
        *state_specs, x_spec, y_spec, scalar, scalar,
    )
    bp_inputs = _state_io(cfg) + [
        ("x", x_spec.shape), ("y", y_spec.shape),
        ("lr", ()), ("momentum", ()),
    ]
    arts[f"bp_step_{cfg.name}"] = (bp_lowered, _io(bp_inputs), _io(step_outputs))

    apply_lowered = jax.jit(model.apply_grads).lower(
        *state_specs, x_spec,
        _spec((cfg.batch, cfg.d_h1)), _spec((cfg.batch, cfg.d_h2)),
        y_spec, n1_spec, n2_spec, scalar, scalar,
    )
    apply_inputs = _state_io(cfg) + [
        ("x", x_spec.shape),
        ("h1", (cfg.batch, cfg.d_h1)), ("h2", (cfg.batch, cfg.d_h2)),
        ("e", y_spec.shape),
        ("d1t", n1_spec.shape), ("d2t", n2_spec.shape),
        ("lr", ()), ("momentum", ()),
    ]
    arts[f"apply_grads_{cfg.name}"] = (
        apply_lowered, _io(apply_inputs), _io(_state_io(cfg)),
    )
    return arts


def build_photonic_matvec():
    """Device-physics artifact at the paper's bank size (50 x 20)."""
    m, k = BANK_ROWS, BANK_COLS
    lowered = jax.jit(mrr_bank_matvec).lower(
        _spec((k,)), _spec((m, k)), _spec(()), _spec(())
    )
    inputs = _io([("x", (k,)), ("phi", (m, k)), ("r", ()), ("a", ())])
    outputs = _io([("out", (m,))])
    return {"photonic_matvec": (lowered, inputs, outputs)}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--configs", default="tiny,small,mnist",
        help="comma-separated subset of configs to build",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"format": 1, "configs": {}, "artifacts": {}}
    for name in args.configs.split(","):
        cfg = model.CONFIGS[name]
        manifest["configs"][name] = {
            "d_in": cfg.d_in, "d_h1": cfg.d_h1, "d_h2": cfg.d_h2,
            "d_out": cfg.d_out, "batch": cfg.batch,
        }
        for art_name, (lowered, inputs, outputs) in build_artifacts(cfg).items():
            path = f"{art_name}.hlo.txt"
            text = to_hlo_text(lowered)
            with open(os.path.join(args.out, path), "w") as f:
                f.write(text)
            manifest["artifacts"][art_name] = {
                "file": path, "config": name,
                "inputs": inputs, "outputs": outputs,
            }
            print(f"  {art_name}: {len(text)} chars, "
                  f"{len(inputs)} inputs, {len(outputs)} outputs")

    for art_name, (lowered, inputs, outputs) in build_photonic_matvec().items():
        path = f"{art_name}.hlo.txt"
        text = to_hlo_text(lowered)
        with open(os.path.join(args.out, path), "w") as f:
            f.write(text)
        manifest["artifacts"][art_name] = {
            "file": path, "config": "bank",
            "inputs": inputs, "outputs": outputs,
        }
        manifest["configs"]["bank"] = {"rows": BANK_ROWS, "cols": BANK_COLS}
        print(f"  {art_name}: {len(text)} chars")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts "
          f"to {args.out}/manifest.json")


if __name__ == "__main__":
    main()
