"""L1 Pallas kernel: device-level MRR weight-bank transfer (Fig. 3).

Computes the balanced-photodetector output of an M x K add-drop MRR array
from first principles: each MRR's through/drop transmissions are Lorentzian
functions of its round-trip phase detuning phi (Bogaerts 2012), the weight
is w = T_d - T_p, and each row's BPD sums the weighted channel powers.

This is the physics half of the "device mode" validation path: the Rust
photonic simulator computes detunings (via its calibration LUT) and either
evaluates this artifact or its native implementation (photonics::mrr) —
both must agree with ref.mrr_bank_matvec_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .weight_bank import BANK_ROWS, _pad_axis


def _mrr_bank_kernel(x_ref, phi_ref, r_ref, a_ref, o_ref):
    phi = phi_ref[...]                    # (BM, K)
    r = r_ref[0, 0]
    a = a_ref[0, 0]
    r2a = r * r * a
    denom = 1.0 - 2.0 * r2a * jnp.cos(phi) + r2a * r2a
    t_drop = (1.0 - r * r) ** 2 * a / denom
    t_thru = ((r * a) ** 2 - 2.0 * r2a * jnp.cos(phi) + r * r) / denom
    w = t_drop - t_thru                   # (BM, K)
    # BPD: photocurrent difference summed over the K WDM channels.
    o_ref[...] = jnp.sum(w * x_ref[...], axis=1, keepdims=True)


def mrr_bank_matvec(
    x: jnp.ndarray,     # (K,) channel amplitudes
    phi: jnp.ndarray,   # (M, K) round-trip phase detunings
    r: jnp.ndarray,     # () self-coupling coefficient
    a: jnp.ndarray,     # () single-pass amplitude transmission
) -> jnp.ndarray:
    """Returns (M,) per-row BPD outputs for the physical bank."""
    m, k = phi.shape
    bm = BANK_ROWS if m > BANK_ROWS else m
    phi_p = _pad_axis(phi, 0, bm)
    mp = phi_p.shape[0]
    ni = mp // bm

    x2d = jnp.reshape(x.astype(jnp.float32), (1, k))
    r2d = jnp.reshape(r.astype(jnp.float32), (1, 1))
    a2d = jnp.reshape(a.astype(jnp.float32), (1, 1))

    out = pl.pallas_call(
        _mrr_bank_kernel,
        grid=(ni,),
        in_specs=[
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, 1), jnp.float32),
        interpret=True,
    )(x2d, phi_p, r2d, a2d)
    return out[:m, 0]
