"""L1 Pallas kernel: the photonic weight bank datapath (Fig. 4(b)).

The physical system computes an M x N block of MACs per operational cycle
(the paper's headline bank is 50 x 20); a GeMM compiler tiles larger
matrix-vector products over bank-sized blocks. The kernel grid mirrors that
schedule exactly: grid step (i, j) is one bank cycle computing the partial
inner products of row-block i against channel-block j, and the final j step
applies the analog post-processing chain — normalisation to the BPD range,
additive Gaussian read noise, ADC quantisation, rescale, and (for the fused
DFA variant) the Hadamard product with g'(a) implemented by the TIA gains.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): on a real TPU the
(BM, BK) B-tile and (BK, B) e-tile live in VMEM and the MAC block maps onto
the MXU; BlockSpec expresses the HBM<->VMEM schedule that the PIC implements
with SRAM -> DAC -> MRR loads. Here the kernel is lowered with
``interpret=True`` (CPU PJRT cannot execute Mosaic custom-calls).

All kernels must match their oracles in ref.py (python/tests/test_kernels.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Paper's headline photonic weight bank: M=50 rows x N=20 WDM channels.
BANK_ROWS = 50
BANK_COLS = 20

_EPS = 1e-12


def _dfa_gradient_kernel(
    b_ref,       # (BM, BK)  weight-bank tile of B(k)
    e_ref,       # (BK, B)   normalised error tile (shared across row blocks)
    noise_ref,   # (BM, B)   standard-normal read noise
    gp_ref,      # (BM, B)   g'(a) tile (TIA gains)
    s_ref,       # (1, B)    per-sample normalisation scale max|e|
    rng_ref,     # (1, 1)    receiver full-scale range max_r sum_c |B|
    sig_ref,     # (1, 1)    noise std in the normalised domain
    bits_ref,    # (1, 1)    ADC bits (<= 0: off)
    o_ref,       # (BM, B)   output tile, revisited across j (accumulator)
    *,
    nj: int,
    fuse_gprime: bool,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # One bank operational cycle: the MAC block for this (row, channel) tile.
    o_ref[...] += jnp.dot(
        b_ref[...], e_ref[...], preferred_element_type=jnp.float32
    )

    # After the last channel block the BPD has integrated the full inner
    # product; apply the analog output chain.
    @pl.when(j == nj - 1)
    def _finish():
        full_scale = rng_ref[0, 0]
        y_n = o_ref[...] / full_scale                 # normalised BPD output
        y_n = y_n + sig_ref[0, 0] * noise_ref[...]    # analog read noise
        b = bits_ref[0, 0]
        levels = jnp.exp2(b - 1.0)
        q = jnp.clip(jnp.round(y_n * levels) / levels, -1.0, 1.0)
        y_n = jnp.where(b > 0.0, q, y_n)              # ADC quantisation
        y = y_n * (full_scale * s_ref[...])           # rescale to digital
        if fuse_gprime:
            y = y * gp_ref[...]                       # TIA Hadamard product
        o_ref[...] = y


def _pad_axis(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _block_sizes(m: int, k: int) -> tuple[int, int]:
    bm = BANK_ROWS if m > BANK_ROWS else m
    bk = BANK_COLS if k > BANK_COLS else k
    return bm, bk


def dfa_gradient(
    bmat: jnp.ndarray,    # (M, K)
    e: jnp.ndarray,       # (K, B)
    noise: jnp.ndarray,   # (M, B)
    gprime: jnp.ndarray,  # (M, B)
    sigma: jnp.ndarray,   # ()
    bits: jnp.ndarray,    # ()
) -> jnp.ndarray:
    """Fused Eq. (1) gradient: (B @ e in analog) ⊙ g'(a). Returns (M, B)."""
    return _run_bank(bmat, e, noise, sigma, bits, gprime=gprime)


def analog_matvec(
    bmat: jnp.ndarray,
    e: jnp.ndarray,
    noise: jnp.ndarray,
    sigma: jnp.ndarray,
    bits: jnp.ndarray,
) -> jnp.ndarray:
    """Weight-bank mat-vec with analog noise, no Hadamard. Returns (M, B)."""
    return _run_bank(bmat, e, noise, sigma, bits, gprime=None)


def _run_bank(bmat, e, noise, sigma, bits, *, gprime):
    m, k = bmat.shape
    batch = e.shape[1]
    fuse = gprime is not None
    if gprime is None:
        gprime = jnp.ones((m, batch), dtype=jnp.float32)

    # Per-sample amplitude-encoding scale (done digitally by the control
    # system before driving the input-modulator DACs).
    s = jnp.maximum(jnp.max(jnp.abs(e), axis=0, keepdims=True), _EPS)  # (1,B)
    e_n = e / s
    # Receiver full-scale range: the bank's maximum possible output swing
    # for the inscribed weights (sets TIA gain / ADC range; static per B).
    rng = jnp.maximum(jnp.max(jnp.sum(jnp.abs(bmat), axis=1)), _EPS)

    bm, bk = _block_sizes(m, k)
    bmat_p = _pad_axis(_pad_axis(bmat, 0, bm), 1, bk)
    e_p = _pad_axis(e_n, 0, bk)
    noise_p = _pad_axis(noise, 0, bm)
    gp_p = _pad_axis(gprime, 0, bm)
    mp, kp = bmat_p.shape
    ni, nj = mp // bm, kp // bk

    rng2d = jnp.reshape(rng.astype(jnp.float32), (1, 1))
    sig2d = jnp.reshape(sigma.astype(jnp.float32), (1, 1))
    bits2d = jnp.reshape(bits.astype(jnp.float32), (1, 1))

    out = pl.pallas_call(
        functools.partial(_dfa_gradient_kernel, nj=nj, fuse_gprime=fuse),
        grid=(ni, nj),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),   # B tile
            pl.BlockSpec((bk, batch), lambda i, j: (j, 0)),  # e tile
            pl.BlockSpec((bm, batch), lambda i, j: (i, 0)),  # noise
            pl.BlockSpec((bm, batch), lambda i, j: (i, 0)),  # g'
            pl.BlockSpec((1, batch), lambda i, j: (0, 0)),   # scale
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),       # range
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),       # sigma
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),       # bits
        ],
        out_specs=pl.BlockSpec((bm, batch), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, batch), jnp.float32),
        interpret=True,
    )(bmat_p, e_p, noise_p, gp_p, s, rng2d, sig2d, bits2d)
    return out[:m, :]


def bank_cycles(m: int, k: int) -> int:
    """Number of weight-bank operational cycles the grid performs — the
    quantity the GeMM schedule (rust gemm::schedule) must agree with."""
    bm, bk = _block_sizes(m, k)
    mp = m + ((-m) % bm)
    kp = k + ((-k) % bk)
    return (mp // bm) * (kp // bk)
