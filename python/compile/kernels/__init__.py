"""L1: Pallas kernels for the photonic weight-bank datapath.

Every kernel has a pure-jnp oracle in ref.py; pytest enforces agreement.
"""

from . import ref  # noqa: F401
from .mrr import mrr_bank_matvec  # noqa: F401
from .quantize import quantize  # noqa: F401
from .weight_bank import (  # noqa: F401
    BANK_COLS,
    BANK_ROWS,
    analog_matvec,
    bank_cycles,
    dfa_gradient,
)
