"""L1 Pallas kernel: fixed-point ADC quantiser (Fig. 5(c) resolution sweep).

Standalone elementwise quantiser with a *runtime* bit-depth scalar, so a
single AOT artifact serves every point of the paper's resolution sweep.
bits <= 0 is the "off" sentinel (identity), matching ref.quantize_ref.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quantize_kernel(x_ref, bits_ref, o_ref):
    x = x_ref[...]
    b = bits_ref[0, 0]
    levels = jnp.exp2(b - 1.0)
    q = jnp.clip(jnp.round(x * levels) / levels, -1.0, 1.0)
    o_ref[...] = jnp.where(b > 0.0, q, x)


def quantize(x: jnp.ndarray, bits: jnp.ndarray) -> jnp.ndarray:
    """Quantise ``x`` (any 2-D f32 array, values nominally in [-1,1])."""
    m, n = x.shape
    bits2d = jnp.reshape(bits.astype(jnp.float32), (1, 1))
    return pl.pallas_call(
        _quantize_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((m, n), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), bits2d)
