"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signal: every Pallas kernel in this package
must match its `*_ref` counterpart to float32 tolerance under pytest
(see python/tests/test_kernels.py). They also document the analog semantics
of the photonic datapath in plain numpy-style code.

Analog encoding convention (paper §2, §4):
  * weight-bank entries are inscribed in [-1, 1] (add-drop MRR, w = T_d - T_p)
  * the input vector (the DFA error e) is amplitude-encoded, normalised
    per-sample to [-1, 1] by its max-abs
  * the receiver chain (TIA gain + ADC range) is set to the bank's actual
    full-scale output swing, range = max_rows sum_cols |B| — the maximum
    possible BPD output for the inscribed weights. Dividing by it gives the
    normalised analog output in [-1, 1] on which the measured noise sigma
    and the effective ADC resolution are defined (exactly the Fig. 5(a)
    protocol, where measured outputs are scaled to the observed range)
  * Gaussian read noise N(0, sigma) and optional N_b-bit quantisation are
    applied in the normalised domain, then the result is rescaled back.
"""

from __future__ import annotations

import jax.numpy as jnp

_EPS = 1e-12


def quantize_ref(x: jnp.ndarray, bits: jnp.ndarray) -> jnp.ndarray:
    """Mid-rise fixed-point quantiser on [-1, 1].

    ``bits`` is a runtime scalar; ``bits <= 0`` is the sentinel for
    "quantisation off" (identity). Matches the paper's effective-resolution
    definition: an N_b-bit converter has 2^N_b levels across the range 2.
    """
    b = jnp.asarray(bits, dtype=jnp.float32)
    levels = jnp.exp2(b - 1.0)  # half-range level count
    q = jnp.clip(jnp.round(x * levels) / levels, -1.0, 1.0)
    return jnp.where(b > 0.0, q, x)


def analog_matvec_ref(
    bmat: jnp.ndarray,
    e: jnp.ndarray,
    noise: jnp.ndarray,
    sigma: jnp.ndarray,
    bits: jnp.ndarray,
) -> jnp.ndarray:
    """Photonic weight-bank matrix-vector product with analog read noise.

    bmat:  (M, K) inscribed weights in [-1, 1]
    e:     (K, B) input vectors (one column per batch sample)
    noise: (M, B) standard-normal draws (sampled by the Rust coordinator)
    sigma: ()     noise std in the normalised output domain
    bits:  ()     ADC resolution (<= 0 disables quantisation)

    Returns (M, B): bmat @ e computed "in the analog domain".
    """
    s = jnp.maximum(jnp.max(jnp.abs(e), axis=0, keepdims=True), _EPS)  # (1,B)
    e_n = e / s
    # full-scale output swing of the inscribed bank (receiver range)
    rng = jnp.maximum(jnp.max(jnp.sum(jnp.abs(bmat), axis=1)), _EPS)
    y_n = bmat @ e_n / rng                     # normalised BPD output
    y_n = y_n + sigma * noise                  # measured inner-product error
    y_n = quantize_ref(y_n, bits)              # ADC
    return y_n * (rng * s)                     # back to digital scale


def dfa_gradient_ref(
    bmat: jnp.ndarray,
    e: jnp.ndarray,
    noise: jnp.ndarray,
    gprime: jnp.ndarray,
    sigma: jnp.ndarray,
    bits: jnp.ndarray,
) -> jnp.ndarray:
    """Eq. (1): delta(k) = B(k) e  (in analog)  ⊙ g'(a(k))  (TIA gains).

    gprime: (M, B), the activation derivative encoded as TIA gain.
    """
    return analog_matvec_ref(bmat, e, noise, sigma, bits) * gprime


def mrr_through_ref(phi: jnp.ndarray, r: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """Add-drop MRR through-port power transmission vs round-trip phase.

    Symmetric coupling r1 = r2 = r, single-pass amplitude transmission a
    (Bogaerts et al., Laser Photon. Rev. 6, 47 (2012), add-drop form).
    """
    denom = 1.0 - 2.0 * r * r * a * jnp.cos(phi) + (r * r * a) ** 2
    num = (r * a) ** 2 - 2.0 * r * r * a * jnp.cos(phi) + r * r
    return num / denom


def mrr_drop_ref(phi: jnp.ndarray, r: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """Add-drop MRR drop-port power transmission vs round-trip phase."""
    denom = 1.0 - 2.0 * r * r * a * jnp.cos(phi) + (r * r * a) ** 2
    return (1.0 - r * r) ** 2 * a / denom


def mrr_weight_ref(phi: jnp.ndarray, r: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """Inscribed weight w = T_d - T_p in [-1, 1] (Fig. 3(b))."""
    return mrr_drop_ref(phi, r, a) - mrr_through_ref(phi, r, a)


def mrr_bank_matvec_ref(
    x: jnp.ndarray, phi: jnp.ndarray, r: jnp.ndarray, a: jnp.ndarray
) -> jnp.ndarray:
    """Device-level weight-bank transfer: out_m = sum_n x_n (T_d - T_p)(phi_mn).

    x:   (K,) non-negative channel amplitudes (optical power, a.u.)
    phi: (M, K) per-MRR round-trip phase detuning
    Returns (M,): per-row balanced-photodetector output.
    """
    w = mrr_weight_ref(phi, r, a)  # (M, K)
    return w @ x
