"""AOT path tests: manifest structure and HLO-text emission."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot, model


def test_manifest_io_counts():
    cfg = model.CONFIGS["tiny"]
    arts = aot.build_artifacts(cfg)
    assert set(arts) == {
        "fwd_tiny", "dfa_step_tiny", "bp_step_tiny", "apply_grads_tiny"
    }
    _, inputs, outputs = arts["dfa_step_tiny"]
    assert len(inputs) == 12 + 2 + 2 + 2 + 4   # state + B + data + noise + scalars
    assert len(outputs) == 14
    names = [i["name"] for i in inputs]
    assert names[:6] == ["w1", "b1", "w2", "b2", "w3", "b3"]
    assert names[-4:] == ["sigma", "bits", "lr", "momentum"]


def test_hlo_text_emitted(tmp_path):
    cfg = model.CONFIGS["tiny"]
    arts = aot.build_artifacts(cfg)
    lowered, inputs, _ = arts["fwd_tiny"]
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "HloModule" in text
    # one HLO parameter per manifest input, in order
    for i, inp in enumerate(inputs):
        assert f"parameter({i})" in text


def test_shapes_recorded_match_lowered():
    cfg = model.CONFIGS["tiny"]
    arts = aot.build_artifacts(cfg)
    _, inputs, _ = arts["dfa_step_tiny"]
    by_name = {i["name"]: tuple(i["shape"]) for i in inputs}
    assert by_name["w1"] == (cfg.d_in, cfg.d_h1)
    assert by_name["bmat1"] == (cfg.d_h1, cfg.d_out)
    assert by_name["noise2"] == (cfg.d_h2, cfg.batch)
    assert by_name["sigma"] == ()


def test_cli_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out),
         "--configs", "tiny"],
        check=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env,
        timeout=600,
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["format"] == 1
    assert "dfa_step_tiny" in manifest["artifacts"]
    assert "photonic_matvec" in manifest["artifacts"]
    for art in manifest["artifacts"].values():
        assert (out / art["file"]).exists()
    assert manifest["configs"]["tiny"]["d_in"] == 16
    assert manifest["configs"]["bank"] == {"rows": 50, "cols": 20}
