"""L2 model tests: shapes, learning behaviour, and DFA/BP cross-checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

CFG = model.CONFIGS["tiny"]


def _init_state(seed=0, scale=0.1):
    rng = np.random.default_rng(seed)
    params = [
        jnp.array(rng.normal(0, scale, s).astype(np.float32))
        for _, s in CFG.param_shapes
    ]
    vels = [jnp.zeros(s, jnp.float32) for _, s in CFG.param_shapes]
    return params, vels, rng


def _toy_batch(rng):
    x = jnp.array(rng.normal(0, 1, (CFG.batch, CFG.d_in)).astype(np.float32))
    yi = rng.integers(0, CFG.d_out, CFG.batch)
    y = jnp.array(np.eye(CFG.d_out, dtype=np.float32)[yi])
    return x, y


def _feedback(rng):
    b1 = jnp.array(rng.uniform(-1, 1, (CFG.d_h1, CFG.d_out)).astype(np.float32))
    b2 = jnp.array(rng.uniform(-1, 1, (CFG.d_h2, CFG.d_out)).astype(np.float32))
    return b1, b2


SC = jnp.float32


def test_forward_shapes():
    params, _, rng = _init_state()
    x, _ = _toy_batch(rng)
    logits, a1, a2, h1, h2 = model.forward(*params, x)
    assert logits.shape == (CFG.batch, CFG.d_out)
    assert a1.shape == h1.shape == (CFG.batch, CFG.d_h1)
    assert a2.shape == h2.shape == (CFG.batch, CFG.d_h2)
    np.testing.assert_array_equal(np.asarray(h1), np.maximum(np.asarray(a1), 0))


def test_loss_and_error_against_numpy():
    params, _, rng = _init_state()
    x, y = _toy_batch(rng)
    logits, *_ = model.forward(*params, x)
    loss, e, ncorrect = model._loss_and_error(logits, y)
    z = np.asarray(logits, dtype=np.float64)
    p = np.exp(z - z.max(1, keepdims=True))
    p /= p.sum(1, keepdims=True)
    want_loss = -np.mean(np.log(p[np.arange(len(p)), np.argmax(np.asarray(y), 1)]))
    assert abs(float(loss) - want_loss) < 1e-5
    np.testing.assert_allclose(np.asarray(e), p - np.asarray(y), atol=1e-5)
    want_correct = np.sum(np.argmax(z, 1) == np.argmax(np.asarray(y), 1))
    assert float(ncorrect) == want_correct


def _run_steps(step_fn, state, args, n):
    losses = []
    for _ in range(n):
        out = step_fn(*state, *args)
        state = list(out[:12])
        losses.append(float(out[12]))
    return state, losses


def test_dfa_learns_noise_free():
    params, vels, rng = _init_state()
    x, y = _toy_batch(rng)
    b1, b2 = _feedback(rng)
    n1 = jnp.zeros((CFG.d_h1, CFG.batch), jnp.float32)
    n2 = jnp.zeros((CFG.d_h2, CFG.batch), jnp.float32)
    args = (b1, b2, x, y, n1, n2, SC(0.0), SC(0.0), SC(0.05), SC(0.9))
    _, losses = _run_steps(jax.jit(model.dfa_step), params + vels, args, 25)
    assert losses[-1] < 0.5 * losses[0]


def test_dfa_learns_with_offchip_noise():
    """Paper §4: training remains effective at sigma = 0.098 (off-chip BPD)."""
    params, vels, rng = _init_state(seed=2)
    x, y = _toy_batch(rng)
    b1, b2 = _feedback(rng)
    step = jax.jit(model.dfa_step)
    state = params + vels
    losses = []
    for _ in range(30):
        n1 = jnp.array(rng.normal(0, 1, (CFG.d_h1, CFG.batch)).astype(np.float32))
        n2 = jnp.array(rng.normal(0, 1, (CFG.d_h2, CFG.batch)).astype(np.float32))
        out = step(*state, b1, b2, x, y, n1, n2,
                   SC(0.098), SC(0.0), SC(0.05), SC(0.9))
        state = list(out[:12])
        losses.append(float(out[12]))
    assert losses[-1] < 0.5 * losses[0]


def test_bp_learns():
    params, vels, rng = _init_state(seed=3)
    x, y = _toy_batch(rng)
    _, losses = _run_steps(
        jax.jit(model.bp_step), params + vels, (x, y, SC(0.05), SC(0.9)), 25
    )
    assert losses[-1] < 0.5 * losses[0]


def test_bp_matches_autodiff():
    """bp_step's hand-written backward pass == jax.grad of the same loss."""
    params, vels, rng = _init_state(seed=4)
    x, y = _toy_batch(rng)

    def loss_fn(ps):
        logits, *_ = model.forward(*ps, x)
        loss, _, _ = model._loss_and_error(logits, y)
        return loss

    grads = jax.grad(loss_fn)(params)
    out = model.bp_step(*params, *vels, x, y, SC(1.0), SC(0.0))
    # with momentum 0 and lr 1: new_p = p - g  =>  g = p - new_p
    for p, new_p, g in zip(params, out[:6], grads):
        np.testing.assert_allclose(
            np.asarray(p - new_p), np.asarray(g), atol=1e-5
        )


def test_dfa_step_matches_manual_composition():
    """dfa_step == forward + ref.dfa_gradient_ref + manual SGD update."""
    params, vels, rng = _init_state(seed=5)
    x, y = _toy_batch(rng)
    b1, b2 = _feedback(rng)
    n1 = jnp.array(rng.normal(0, 1, (CFG.d_h1, CFG.batch)).astype(np.float32))
    n2 = jnp.array(rng.normal(0, 1, (CFG.d_h2, CFG.batch)).astype(np.float32))
    sigma, bits, lr, mom = SC(0.05), SC(6.0), SC(0.01), SC(0.9)

    out = model.dfa_step(*params, *vels, b1, b2, x, y, n1, n2,
                         sigma, bits, lr, mom)

    logits, a1, a2, h1, h2 = model.forward(*params, x)
    _, e, _ = model._loss_and_error(logits, y)
    gp1 = (a1 > 0).astype(jnp.float32).T
    gp2 = (a2 > 0).astype(jnp.float32).T
    d1t = ref.dfa_gradient_ref(b1, e.T, n1, gp1, sigma, bits)
    d2t = ref.dfa_gradient_ref(b2, e.T, n2, gp2, sigma, bits)
    grads = model._grads_from_deltas(x, h1, h2, e, d1t, d2t, CFG.batch)
    for i, (p, v, g) in enumerate(zip(params, vels, grads)):
        v_new = 0.9 * v + g
        p_new = p - 0.01 * v_new
        np.testing.assert_allclose(
            np.asarray(out[i]), np.asarray(p_new), atol=1e-5,
            err_msg=f"param {i}",
        )
        np.testing.assert_allclose(
            np.asarray(out[6 + i]), np.asarray(v_new), atol=1e-5
        )


def test_apply_grads_consistent_with_dfa_step():
    """Device mode must reproduce simulation mode: feeding apply_grads the
    deltas that dfa_step computes internally yields identical new params."""
    params, vels, rng = _init_state(seed=6)
    x, y = _toy_batch(rng)
    b1, b2 = _feedback(rng)
    n1 = jnp.array(rng.normal(0, 1, (CFG.d_h1, CFG.batch)).astype(np.float32))
    n2 = jnp.array(rng.normal(0, 1, (CFG.d_h2, CFG.batch)).astype(np.float32))
    sigma, bits, lr, mom = SC(0.098), SC(0.0), SC(0.01), SC(0.9)

    out = model.dfa_step(*params, *vels, b1, b2, x, y, n1, n2,
                         sigma, bits, lr, mom)

    logits, a1, a2, h1, h2 = model.forward(*params, x)
    _, e, _ = model._loss_and_error(logits, y)
    gp1 = (a1 > 0).astype(jnp.float32).T
    gp2 = (a2 > 0).astype(jnp.float32).T
    d1t = ref.dfa_gradient_ref(b1, e.T, n1, gp1, sigma, bits)
    d2t = ref.dfa_gradient_ref(b2, e.T, n2, gp2, sigma, bits)
    out2 = model.apply_grads(*params, *vels, x, h1, h2, e, d1t, d2t, lr, mom)
    for i in range(12):
        np.testing.assert_allclose(
            np.asarray(out[i]), np.asarray(out2[i]), atol=1e-5
        )


def test_dfa_noise_perturbs_but_preserves_signal():
    """With moderate sigma the delta stays correlated with the clean delta —
    the alignment property DFA training relies on (paper §4, ref 29)."""
    params, vels, rng = _init_state(seed=7)
    x, y = _toy_batch(rng)
    b1, b2 = _feedback(rng)
    logits, a1, a2, h1, h2 = model.forward(*params, x)
    _, e, _ = model._loss_and_error(logits, y)
    gp1 = (a1 > 0).astype(jnp.float32).T
    n1 = jnp.array(rng.normal(0, 1, (CFG.d_h1, CFG.batch)).astype(np.float32))
    clean = ref.dfa_gradient_ref(b1, e.T, jnp.zeros_like(n1), gp1,
                                 SC(0.0), SC(0.0))
    noisy = ref.dfa_gradient_ref(b1, e.T, n1, gp1, SC(0.098), SC(0.0))
    c = np.corrcoef(np.asarray(clean).ravel(), np.asarray(noisy).ravel())[0, 1]
    assert c > 0.5
