"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes (including non-multiples of the 50x20 bank tile so
the padding/tiling path is exercised), noise levels and ADC depths; every
kernel output must match its ref.py oracle to f32 tolerance.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    BANK_COLS,
    BANK_ROWS,
    analog_matvec,
    bank_cycles,
    dfa_gradient,
    mrr_bank_matvec,
    quantize,
    ref,
)

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def _allclose(a, b, atol=2e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol, rtol=1e-4)


dims = st.tuples(
    st.integers(1, 3 * BANK_ROWS + 7),   # M: crosses several row tiles
    st.integers(1, 2 * BANK_COLS + 3),   # K: crosses channel tiles
    st.integers(1, 9),                   # batch
)


@given(dims=dims, sigma=st.sampled_from([0.0, 0.019, 0.098, 0.202]),
       bits=st.sampled_from([0.0, 3.0, 6.0, 8.0]), seed=st.integers(0, 2**31))
def test_analog_matvec_matches_ref(dims, sigma, bits, seed):
    m, k, b = dims
    rng = np.random.default_rng(seed)
    bmat = jnp.array(rng.uniform(-1, 1, (m, k)).astype(np.float32))
    e = jnp.array(rng.normal(0, 0.5, (k, b)).astype(np.float32))
    noise = jnp.array(rng.normal(0, 1, (m, b)).astype(np.float32))
    s, q = jnp.float32(sigma), jnp.float32(bits)
    _allclose(
        analog_matvec(bmat, e, noise, s, q),
        ref.analog_matvec_ref(bmat, e, noise, s, q),
        atol=1e-4 * max(1.0, k),
    )


@given(dims=dims, sigma=st.sampled_from([0.0, 0.098]),
       bits=st.sampled_from([0.0, 6.0]), seed=st.integers(0, 2**31))
def test_dfa_gradient_matches_ref(dims, sigma, bits, seed):
    m, k, b = dims
    rng = np.random.default_rng(seed)
    bmat = jnp.array(rng.uniform(-1, 1, (m, k)).astype(np.float32))
    e = jnp.array(rng.normal(0, 0.5, (k, b)).astype(np.float32))
    noise = jnp.array(rng.normal(0, 1, (m, b)).astype(np.float32))
    gp = jnp.array((rng.random((m, b)) > 0.5).astype(np.float32))
    s, q = jnp.float32(sigma), jnp.float32(bits)
    _allclose(
        dfa_gradient(bmat, e, noise, gp, s, q),
        ref.dfa_gradient_ref(bmat, e, noise, gp, s, q),
        atol=1e-4 * max(1.0, k),
    )


def test_relu_mask_zeroes_rows():
    """g' = 0 rows must be exactly zero (the TIA gain gates them off)."""
    m, k, b = 60, 10, 4
    rng = np.random.default_rng(7)
    bmat = jnp.array(rng.uniform(-1, 1, (m, k)).astype(np.float32))
    e = jnp.array(rng.normal(0, 1, (k, b)).astype(np.float32))
    noise = jnp.array(rng.normal(0, 1, (m, b)).astype(np.float32))
    gp = jnp.zeros((m, b), jnp.float32)
    out = dfa_gradient(bmat, e, noise, gp, jnp.float32(0.2), jnp.float32(0.0))
    assert float(jnp.max(jnp.abs(out))) == 0.0


def test_noise_free_is_exact_matvec():
    m, k, b = 123, 10, 8
    rng = np.random.default_rng(3)
    bmat = jnp.array(rng.uniform(-1, 1, (m, k)).astype(np.float32))
    e = jnp.array(rng.normal(0, 1, (k, b)).astype(np.float32))
    zero = jnp.zeros((m, b), jnp.float32)
    out = analog_matvec(bmat, e, zero, jnp.float32(0.0), jnp.float32(0.0))
    _allclose(out, bmat @ e, atol=1e-4)


def test_noise_statistics_match_sigma():
    """Injected read noise must have std sigma in the normalised domain."""
    m, k, b = 800, 10, 64
    rng = np.random.default_rng(11)
    bmat = jnp.array(rng.uniform(-1, 1, (m, k)).astype(np.float32))
    e = jnp.array(rng.normal(0, 1, (k, b)).astype(np.float32))
    noise = jnp.array(rng.normal(0, 1, (m, b)).astype(np.float32))
    sigma = 0.098
    noisy = analog_matvec(bmat, e, noise, jnp.float32(sigma), jnp.float32(0.0))
    clean = analog_matvec(bmat, e, jnp.zeros_like(noise), jnp.float32(0.0),
                          jnp.float32(0.0))
    s = np.maximum(np.max(np.abs(np.asarray(e)), axis=0, keepdims=True), 1e-12)
    rng_fs = np.max(np.sum(np.abs(np.asarray(bmat)), axis=1))
    resid_norm = (np.asarray(noisy) - np.asarray(clean)) / (rng_fs * s)
    assert abs(float(resid_norm.std()) - sigma) < 0.01


@given(bits=st.integers(1, 10), seed=st.integers(0, 2**31))
def test_quantize_properties(bits, seed):
    rng = np.random.default_rng(seed)
    x = jnp.array(rng.uniform(-1.2, 1.2, (17, 9)).astype(np.float32))
    b = jnp.float32(bits)
    q = quantize(x, b)
    _allclose(q, ref.quantize_ref(x, b), atol=1e-6)
    # idempotent
    _allclose(quantize(q, b), q, atol=1e-6)
    # bounded
    assert float(jnp.max(jnp.abs(q))) <= 1.0 + 1e-6
    # max error is half a step for in-range values
    xc = jnp.clip(x, -1.0, 1.0)
    step = 2.0 ** (1 - bits)
    assert float(jnp.max(jnp.abs(quantize(xc, b) - xc))) <= step / 2 + 1e-6


def test_quantize_off_sentinel():
    x = jnp.array(np.linspace(-2, 2, 40, dtype=np.float32).reshape(8, 5))
    _allclose(quantize(x, jnp.float32(0.0)), x, atol=0)
    _allclose(quantize(x, jnp.float32(-3.0)), x, atol=0)


@given(
    m=st.integers(1, 2 * BANK_ROWS + 5),
    k=st.integers(1, BANK_COLS),
    seed=st.integers(0, 2**31),
)
def test_mrr_bank_matches_ref(m, k, seed):
    rng = np.random.default_rng(seed)
    x = jnp.array(rng.random(k).astype(np.float32))
    phi = jnp.array(rng.normal(0, 0.5, (m, k)).astype(np.float32))
    r, a = jnp.float32(0.95), jnp.float32(0.999)
    _allclose(
        mrr_bank_matvec(x, phi, r, a),
        ref.mrr_bank_matvec_ref(x, phi, r, a),
        atol=1e-5 * max(1, k),
    )


def test_mrr_weight_physics():
    """Fig. 3(b): on resonance w -> +1, far detuned w -> ~ -1, lossless."""
    r, a = jnp.float32(0.95), jnp.float32(1.0)
    w_res = ref.mrr_weight_ref(jnp.float32(0.0), r, a)
    w_off = ref.mrr_weight_ref(jnp.float32(np.pi), r, a)
    assert abs(float(w_res) - 1.0) < 1e-5  # f32 round-off at resonance
    assert float(w_off) < -0.98
    # energy conservation: Tp + Td = 1 for a = 1
    phi = jnp.array(np.linspace(-np.pi, np.pi, 101, dtype=np.float32))
    tot = ref.mrr_through_ref(phi, r, a) + ref.mrr_drop_ref(phi, r, a)
    _allclose(tot, np.ones(101), atol=1e-5)


def test_mrr_weight_is_monotone_in_detuning():
    """Weight sweeps monotonically from +1 at resonance toward the floor —
    the property the calibration LUT (rust photonics::calibration) relies on."""
    r, a = jnp.float32(0.95), jnp.float32(0.9995)
    phi = jnp.array(np.linspace(0, np.pi, 400, dtype=np.float32))
    w = np.asarray(ref.mrr_weight_ref(phi, r, a))
    assert np.all(np.diff(w) < 1e-7)


@given(m=st.integers(1, 500), k=st.integers(1, 80))
def test_bank_cycles_consistent_with_tiling(m, k):
    """Grid step count == ceil(M/BM) * ceil(K/BK) with bank-clamped tiles —
    must equal the Rust GeMM scheduler's cycle count for the same dims."""
    bm = min(m, BANK_ROWS)
    bk = min(k, BANK_COLS)
    want = -(-m // bm) * (-(-k // bk))
    assert bank_cycles(m, k) == want
