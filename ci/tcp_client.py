#!/usr/bin/env python3
"""NDJSON-over-TCP smoke client for `pdfa serve --source listen`.

Reads the first N test images from an IDX dataset directory, normalizes
exactly like the Rust loader (`b as f32 / 255.0` — validated free of
double-rounding for every byte value), streams them as one
`{"id":i,"x":[...]}` request line each, and compares every reply's
logits — bit for bit — against the raw little-endian f32 dump written by
`pdfa infer --dump-logits` over the same samples.

Usage: tcp_client.py HOST:PORT DATA_DIR WANT_LOGITS.bin N
"""
import gzip
import json
import socket
import struct
import sys


def as_f32(x):
    """Round to the nearest f32, returned as the exact f64 holding it."""
    return struct.unpack("<f", struct.pack("<f", x))[0]


def load_images(path, n):
    with gzip.open(path, "rb") as f:
        magic, count, rows, cols = struct.unpack(">IIII", f.read(16))
        assert magic == 2051, f"bad IDX magic {magic}"
        assert n <= count, f"asked for {n} of {count} images"
        dim = rows * cols
        return [[as_f32(b / 255.0) for b in f.read(dim)] for _ in range(n)]


def main():
    addr, data_dir, want_path, n = (
        sys.argv[1],
        sys.argv[2],
        sys.argv[3],
        int(sys.argv[4]),
    )
    host, port = addr.rsplit(":", 1)
    xs = load_images(f"{data_dir}/t10k-images-idx3-ubyte.gz", n)
    with open(want_path, "rb") as f:
        want = f.read()

    sock = socket.create_connection((host, int(port)), timeout=30)
    rfile = sock.makefile("rb")
    got = b""
    for i, x in enumerate(xs):
        # repr() of an exact-f32 f64 is within a half-ulp of the f32, so
        # Rust's correctly-rounded parse recovers the same bits
        line = '{"id":%d,"x":[%s]}\n' % (i, ",".join(repr(v) for v in x))
        sock.sendall(line.encode())
        reply = json.loads(rfile.readline())
        assert "error" not in reply, f"server errored: {reply}"
        assert reply["id"] == i, f"reply out of order: {reply}"
        for v in reply["logits"]:
            got += struct.pack("<f", float("nan") if v is None else v)
    sock.close()

    assert got == want, "TCP logits differ from `pdfa infer --dump-logits`"
    print(f"{n} TCP replies bit-identical to pdfa infer")


if __name__ == "__main__":
    main()
