//! Noise modes of the gradient mat-vec — the experimental axes of
//! Figs. 5(b) and 5(c).

use crate::photonics::BpdMode;
use crate::util::stats::sigma_for_bits;

/// How the analog B(k)·e products are degraded.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum NoiseMode {
    /// No noise (the paper's 98.10% reference curve).
    Clean,
    /// Additive Gaussian read noise of std `sigma` in the normalised
    /// domain — Fig. 5(b) with the measured σ of each circuit.
    Gaussian { sigma: f64 },
    /// Effective-resolution sweep (Fig. 5(c)): noise equivalent to `bits`
    /// of resolution over the [-1, 1] range, σ = 2 / 2^bits.
    Resolution { bits: f64 },
    /// True fixed-point quantisation of the mat-vec output to `bits`
    /// (ablation: quantisation-limited rather than noise-limited).
    Quantized { bits: f64 },
    /// Full device-level simulation through the photonic weight bank.
    Device { bpd: BpdMode },
}

impl NoiseMode {
    /// The paper's three Fig. 5 measurement conditions.
    pub fn offchip() -> NoiseMode {
        NoiseMode::Gaussian { sigma: crate::photonics::constants::SIGMA_OFFCHIP_BPD }
    }

    pub fn onchip() -> NoiseMode {
        NoiseMode::Gaussian { sigma: crate::photonics::constants::SIGMA_ONCHIP_BPD }
    }

    /// (sigma, bits) scalar inputs for the dfa_step artifact. Device mode
    /// has no scalar encoding (the trainer routes through the device
    /// backend instead).
    pub fn artifact_inputs(&self) -> Option<(f32, f32)> {
        match *self {
            NoiseMode::Clean => Some((0.0, 0.0)),
            NoiseMode::Gaussian { sigma } => Some((sigma as f32, 0.0)),
            NoiseMode::Resolution { bits } => {
                Some((sigma_for_bits(2.0, bits) as f32, 0.0))
            }
            NoiseMode::Quantized { bits } => Some((0.0, bits as f32)),
            NoiseMode::Device { .. } => None,
        }
    }

    /// Whether the trainer must sample Gaussian noise tensors.
    pub fn needs_noise_draws(&self) -> bool {
        matches!(
            self,
            NoiseMode::Gaussian { .. } | NoiseMode::Resolution { .. }
        )
    }

    pub fn describe(&self) -> String {
        match self {
            NoiseMode::Clean => "clean".into(),
            NoiseMode::Gaussian { sigma } => format!("gaussian(sigma={sigma})"),
            NoiseMode::Resolution { bits } => format!("resolution({bits} bits)"),
            NoiseMode::Quantized { bits } => format!("quantized({bits} bits)"),
            NoiseMode::Device { bpd } => format!("device({bpd:?})"),
        }
    }

    /// Parse "clean" | "offchip" | "onchip" | "gaussian:0.1" |
    /// "resolution:4" | "quantized:6" | "device:offchip" etc.
    pub fn parse(s: &str) -> Option<NoiseMode> {
        let (head, arg) = match s.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (s, None),
        };
        match (head, arg) {
            ("clean", None) => Some(NoiseMode::Clean),
            ("offchip", None) => Some(Self::offchip()),
            ("onchip", None) => Some(Self::onchip()),
            ("gaussian", Some(a)) => {
                a.parse().ok().map(|sigma| NoiseMode::Gaussian { sigma })
            }
            ("resolution", Some(a)) => {
                a.parse().ok().map(|bits| NoiseMode::Resolution { bits })
            }
            ("quantized", Some(a)) => {
                a.parse().ok().map(|bits| NoiseMode::Quantized { bits })
            }
            ("device", Some(a)) => {
                let bpd = match a {
                    "ideal" => BpdMode::Ideal,
                    "offchip" => BpdMode::OffChip,
                    "onchip" => BpdMode::OnChip,
                    _ => return None,
                };
                Some(NoiseMode::Device { bpd })
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_inputs_per_mode() {
        assert_eq!(NoiseMode::Clean.artifact_inputs(), Some((0.0, 0.0)));
        assert_eq!(
            NoiseMode::offchip().artifact_inputs(),
            Some((0.098, 0.0))
        );
        let (s, b) = NoiseMode::Resolution { bits: 4.35 }.artifact_inputs().unwrap();
        assert!((s - 0.098).abs() < 0.002, "{s}"); // 4.35 bits ≡ σ 0.098
        assert_eq!(b, 0.0);
        assert_eq!(
            NoiseMode::Quantized { bits: 6.0 }.artifact_inputs(),
            Some((0.0, 6.0))
        );
        assert!(NoiseMode::Device { bpd: BpdMode::OffChip }
            .artifact_inputs()
            .is_none());
    }

    #[test]
    fn parse_all_forms() {
        assert_eq!(NoiseMode::parse("clean"), Some(NoiseMode::Clean));
        assert_eq!(NoiseMode::parse("offchip"), Some(NoiseMode::offchip()));
        assert_eq!(NoiseMode::parse("onchip"), Some(NoiseMode::onchip()));
        assert_eq!(
            NoiseMode::parse("gaussian:0.25"),
            Some(NoiseMode::Gaussian { sigma: 0.25 })
        );
        assert_eq!(
            NoiseMode::parse("resolution:3"),
            Some(NoiseMode::Resolution { bits: 3.0 })
        );
        assert_eq!(
            NoiseMode::parse("device:onchip"),
            Some(NoiseMode::Device { bpd: BpdMode::OnChip })
        );
        assert_eq!(NoiseMode::parse("bogus"), None);
        assert_eq!(NoiseMode::parse("gaussian:abc"), None);
    }

    #[test]
    fn needs_draws() {
        assert!(!NoiseMode::Clean.needs_noise_draws());
        assert!(NoiseMode::offchip().needs_noise_draws());
        assert!(NoiseMode::Resolution { bits: 4.0 }.needs_noise_draws());
        assert!(!NoiseMode::Quantized { bits: 4.0 }.needs_noise_draws());
    }
}
