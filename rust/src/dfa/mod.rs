//! DFA training orchestration — the system the paper's architecture serves.
//!
//! The coordinator drives the AOT train-step artifacts (L2+L1, via PJRT)
//! with the photonic noise/quantisation parameters of the experiment being
//! reproduced, or — in *device mode* — computes the backward-pass gradient
//! mat-vecs through the device-level photonic simulator and applies the
//! update with the `apply_grads` artifact.
//!
//! * [`config`]        — training configuration (paper §4 defaults)
//! * [`checkpoint`]    — versioned on-disk snapshots (params + resume
//!   metadata) feeding `--resume` and the `serve`/`infer` inference plane
//! * [`params`]        — parameter/momentum state management + init
//! * [`noise_model`]   — the Fig. 5(b)/(c) noise modes
//! * [`reference`]     — pure-Rust forward/backward oracle (cross-checks
//!   the artifacts end-to-end; mirrors kernels/ref.py)
//! * [`trainer`]       — the training loop (simulation + device modes)
//! * [`device_backend`]— photonic-bank gradient computation (device mode)

pub mod checkpoint;
pub mod config;
pub mod device_backend;
pub mod noise_model;
pub mod params;
pub mod reference;
pub mod trainer;

pub use checkpoint::Checkpoint;
pub use config::TrainConfig;
pub use noise_model::NoiseMode;
pub use trainer::{EpochStats, TrainResult, Trainer};
