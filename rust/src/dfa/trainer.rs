//! The training loop — the end-to-end system driver.
//!
//! Simulation mode (the paper's §4 protocol): one backend dispatch per
//! step executes the fused `dfa_step` artifact (forward + analog backward
//! through the weight-bank math + SGD update), with the coordinator
//! sampling read-noise draws and streaming mini-batches through the
//! [`crate::coordinator::pipeline`]. The trainer is backend-agnostic: it
//! drives any [`StepEngine`] — the pure-Rust [`crate::runtime::NativeEngine`]
//! by default, or the PJRT engine over the AOT artifacts with
//! `--features pjrt`. Python is never on this path.
//!
//! Device mode: the gradient mat-vecs route through the device-level
//! photonic simulator ([`super::device_backend`]); forward and update use
//! the `fwd` / `apply_grads` artifacts.

use std::sync::Arc;
use std::time::Instant;

use super::checkpoint::Checkpoint;
use super::config::{Algorithm, TrainConfig};
use super::device_backend::{CompiledFeedback, DeviceBackend};
use super::noise_model::NoiseMode;
use super::params::NetState;
use super::reference;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::pipeline::BatchFeeder;
use crate::data::Dataset;
use crate::runtime::manifest::NetDims;
use crate::runtime::{Artifact, StepEngine};
use crate::telemetry::Telemetry;
use crate::tensor::Tensor;
use crate::util::benchx::fmt_si;
use crate::util::json::Value;
use crate::util::rng::Pcg64;
use crate::{Error, Result};

/// Per-epoch statistics.
#[derive(Debug, Clone)]
pub struct EpochStats {
    pub epoch: usize,
    pub train_loss: f64,
    pub train_acc: f64,
    /// Validation accuracy (None on non-eval epochs).
    pub val_acc: Option<f64>,
    pub wall_s: f64,
    pub steps: usize,
    /// Hardware counters accrued during this epoch (training steps plus
    /// the epoch's evaluation passes). The counter values are
    /// bit-identical at any `--threads` count; only rates derived from
    /// `wall_s` vary.
    pub telemetry: Telemetry,
}

impl EpochStats {
    pub fn to_json(&self) -> Value {
        Value::object(vec![
            ("epoch", Value::Number(self.epoch as f64)),
            ("train_loss", Value::Number(self.train_loss)),
            ("train_acc", Value::Number(self.train_acc)),
            (
                "val_acc",
                self.val_acc.map_or(Value::Null, Value::Number),
            ),
            ("wall_s", Value::Number(self.wall_s)),
            ("steps", Value::Number(self.steps as f64)),
            // deterministic counters in their own object; the wall-clock
            // dependent rate outside it (see telemetry module docs)
            ("telemetry", self.telemetry.to_json()),
            (
                "mac_per_s",
                Value::Number(self.telemetry.macs_per_second(self.wall_s)),
            ),
        ])
    }
}

/// Final outcome of a run.
#[derive(Debug, Clone)]
pub struct TrainResult {
    pub history: Vec<EpochStats>,
    pub test_acc: f64,
    /// Optimizer steps across the whole run — after a `--resume`, this
    /// includes the pre-resume epochs, matching the checkpoint's count.
    pub total_steps: usize,
    pub wall_s: f64,
    /// Gradient-matvec MACs performed on the (simulated) photonic path
    /// (the pre-telemetry analytic counter, kept for continuity of the
    /// run-record schema; `telemetry.macs` is the full accounting).
    pub photonic_macs: u64,
    /// Hardware counters accrued over the whole run (every training
    /// step and evaluation pass since this trainer was constructed or
    /// restored): MACs, optical cycles, modeled §5 energy.
    pub telemetry: Telemetry,
}

/// The coordinator-owned trainer.
pub struct Trainer {
    pub cfg: TrainConfig,
    dims: NetDims,
    engine: Arc<dyn StepEngine>,
    step_art: Arc<dyn Artifact>,
    fwd_art: Arc<dyn Artifact>,
    apply_art: Arc<dyn Artifact>,
    pub state: NetState,
    bmat1: Tensor,
    bmat2: Tensor,
    rng: Pcg64,
    device: Option<(DeviceBackend, CompiledFeedback, CompiledFeedback)>,
    pub metrics: Metrics,
    /// Epochs fully completed (nonzero after a `restore`).
    epochs_done: usize,
    /// Optimizer steps across the whole run, including pre-resume epochs.
    steps_done: u64,
    /// Engine telemetry at construction: the run's counters are reported
    /// as a delta from here, so a shared engine (sweep cells, servers)
    /// never leaks another run's work into this one.
    tel_base: Telemetry,
    /// Engine telemetry at the end of the last completed epoch.
    tel_last: Telemetry,
}

impl Trainer {
    pub fn new(engine: Arc<dyn StepEngine>, cfg: TrainConfig) -> Result<Trainer> {
        cfg.validate()?;
        let dims = engine.net_dims(&cfg.config)?;

        // the photonic backend supplies its own noise physics: neither the
        // Gaussian noise model nor the legacy device-mode path can compose
        // with it, and both should fail here — before any bank is built
        // (artifact loads below calibrate the device) — rather than at the
        // first dfa_step dispatch
        if engine.platform_name() == "photonic" {
            match cfg.noise {
                NoiseMode::Clean => {}
                NoiseMode::Device { .. } => {
                    return Err(Error::Config(
                        "--noise device:* is the legacy device-mode path; the \
                         photonic backend already computes gradients on the \
                         bank — configure it with --physics instead"
                            .into(),
                    ));
                }
                _ => {
                    return Err(Error::Config(format!(
                        "--noise {} cannot run on the photonic backend: noise \
                         is modeled at device level — train with --noise clean \
                         and configure --physics instead",
                        cfg.noise.describe()
                    )));
                }
            }
        }

        let mut rng = Pcg64::seed(cfg.seed);
        let state = NetState::init(&dims, &mut rng);
        let (bmat1, bmat2) = NetState::init_feedback(&dims, &mut rng);

        let step_name = match cfg.algorithm {
            Algorithm::Dfa => format!("dfa_step_{}", cfg.config),
            Algorithm::Backprop => format!("bp_step_{}", cfg.config),
        };
        let step_art = engine.load(&step_name)?;
        let fwd_art = engine.load(&format!("fwd_{}", cfg.config))?;
        let apply_art = engine.load(&format!("apply_grads_{}", cfg.config))?;

        let device = match cfg.noise {
            NoiseMode::Device { bpd } => {
                if cfg.algorithm != Algorithm::Dfa {
                    return Err(Error::Config(
                        "device mode requires the DFA algorithm".into(),
                    ));
                }
                crate::log_info!("building photonic device backend ({bpd:?})...");
                let mut be = DeviceBackend::new(bpd, cfg.seed ^ 0xdeu64)?;
                let fb1 = be.compile_feedback(&bmat1)?;
                let fb2 = be.compile_feedback(&bmat2)?;
                Some((be, fb1, fb2))
            }
            _ => None,
        };

        let tel_base = engine.telemetry();
        Ok(Trainer {
            cfg,
            dims,
            engine,
            step_art,
            fwd_art,
            apply_art,
            state,
            bmat1,
            bmat2,
            rng,
            device,
            metrics: Metrics::new(),
            epochs_done: 0,
            steps_done: 0,
            tel_base,
            tel_last: tel_base,
        })
    }

    pub fn dims(&self) -> &NetDims {
        &self.dims
    }

    pub fn engine(&self) -> &Arc<dyn StepEngine> {
        &self.engine
    }

    /// Epochs fully completed so far (nonzero after [`Self::restore`]).
    pub fn epochs_done(&self) -> usize {
        self.epochs_done
    }

    /// The protocol string recorded in checkpoints: the backend identity
    /// plus every trajectory-determining hyperparameter. Backends round
    /// floats differently (XLA vs the native kernels), so a cross-backend
    /// resume is a trajectory change and gets rejected like any other
    /// protocol mismatch. The worker-thread count is deliberately absent:
    /// every parallel path is bit-deterministic, so a `--threads 4` run
    /// may resume a `--threads 1` checkpoint (and vice versa) without
    /// changing the trajectory.
    fn run_protocol(&self) -> String {
        format!(
            "backend={};{}",
            self.engine.platform_name(),
            self.cfg.protocol_string()
        )
    }

    /// Snapshot the run for [`Checkpoint::save`]. Taken between epochs the
    /// snapshot is exact: restoring reproduces the uninterrupted loss
    /// trajectory bit-for-bit. The run RNG covers the coordinator's
    /// stochastic state; backends with device-side state (the photonic
    /// engine's op sequence, counters, and drift model) contribute an
    /// opaque [`StepEngine::device_state`] blob so a drifting run resumes
    /// mid-lifetime rather than on a freshly calibrated chip. (Legacy
    /// device mode still re-seeds its photonic bank instead.)
    pub fn checkpoint(&self) -> Checkpoint {
        Checkpoint {
            config: self.cfg.config.clone(),
            dims: self.dims.clone(),
            epoch: self.epochs_done as u64,
            total_steps: self.steps_done,
            seed: self.cfg.seed,
            protocol: self.run_protocol(),
            rng: self.rng.clone(),
            state: self.state.clone(),
            device: self.engine.device_state(),
        }
    }

    /// Write [`Self::checkpoint`] to `path`.
    pub fn save_checkpoint(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        self.checkpoint().save(path)
    }

    /// Resume from a checkpoint taken by a run with the same config, dims
    /// and seed (the seed re-derives the fixed DFA feedback matrices, so a
    /// mismatch would silently change the trajectory — it is rejected
    /// instead). The next [`Self::train`] call continues at epoch
    /// `ckpt.epoch + 1`.
    pub fn restore(&mut self, ckpt: &Checkpoint) -> Result<()> {
        if ckpt.config != self.cfg.config {
            return Err(Error::Config(format!(
                "checkpoint is for config '{}', trainer runs '{}'",
                ckpt.config, self.cfg.config
            )));
        }
        if ckpt.dims != self.dims {
            return Err(Error::Config(format!(
                "checkpoint dims {:?} != engine dims {:?}",
                ckpt.dims, self.dims
            )));
        }
        if ckpt.seed != self.cfg.seed {
            return Err(Error::Config(format!(
                "checkpoint seed {} != configured seed {} (feedback matrices \
                 would differ)",
                ckpt.seed, self.cfg.seed
            )));
        }
        let protocol = self.run_protocol();
        if ckpt.protocol != protocol {
            return Err(Error::Config(format!(
                "checkpoint protocol mismatch: saved run used\n  {}\nthis run \
                 is configured as\n  {protocol}\n(resuming would silently \
                 change the trajectory)",
                ckpt.protocol
            )));
        }
        match &ckpt.device {
            // the engine rewinds its op sequence, counters, and drift model
            // to the snapshot, so the resumed trajectory is bit-exact even
            // with device physics (noise, drift, recalibration) in the loop
            Some(blob) => self.engine.restore_device_state(blob)?,
            None if self.device.is_some()
                || self.engine.platform_name() == "photonic" =>
            {
                crate::log_warn!(
                    "checkpoint carries no device state (pre-lifetime format): \
                     photonic noise streams restart from their seed, so the \
                     resumed trajectory is statistical, not bit-exact"
                );
            }
            None => {}
        }
        self.state = ckpt.state.clone();
        self.rng = ckpt.rng.clone();
        self.epochs_done = ckpt.epoch as usize;
        self.steps_done = ckpt.total_steps;
        Ok(())
    }

    /// Load (or synthesise) the train/test datasets per the config.
    pub fn load_data(&self) -> Result<(Arc<Dataset>, Arc<Dataset>)> {
        let (train, test) = match &self.cfg.data_dir {
            Some(dir) => {
                let tr = Dataset::load_split(dir, true)?;
                let te = Dataset::load_split(dir, false)?;
                (tr, te)
            }
            None if self.dims.d_in == 784 => (
                Dataset::synthetic_threaded(
                    self.cfg.n_train,
                    self.cfg.seed ^ 0x7a11,
                    self.cfg.threads,
                ),
                Dataset::synthetic_threaded(
                    self.cfg.n_test,
                    self.cfg.seed ^ 0x7e57,
                    self.cfg.threads,
                ),
            ),
            // non-MNIST-shaped configs (e.g. `tiny`) get the generic
            // separable generator at the network's own input width
            None if self.dims.d_out > self.dims.d_in => {
                return Err(Error::Data(format!(
                    "cannot synthesise separable data for config '{}' \
                     (d_out {} > d_in {}); provide --data-dir",
                    self.cfg.config, self.dims.d_out, self.dims.d_in
                )))
            }
            None => (
                Dataset::synthetic_features(
                    self.cfg.n_train,
                    self.dims.d_in,
                    self.dims.d_out,
                    self.cfg.seed ^ 0x7a11,
                ),
                Dataset::synthetic_features(
                    self.cfg.n_test,
                    self.dims.d_in,
                    self.dims.d_out,
                    self.cfg.seed ^ 0x7e57,
                ),
            ),
        };
        if train.dim() != self.dims.d_in {
            return Err(Error::Data(format!(
                "dataset dim {} != network d_in {}",
                train.dim(),
                self.dims.d_in
            )));
        }
        Ok((Arc::new(train), Arc::new(test)))
    }

    /// One training step in simulation mode (fused artifact).
    fn step_artifact(
        &mut self,
        x: &Tensor,
        y: &Tensor,
        noise1: Tensor,
        noise2: Tensor,
        sigma: f32,
        bits: f32,
    ) -> Result<(f32, usize)> {
        let mut inputs: Vec<Tensor> = Vec::with_capacity(22);
        inputs.extend(self.state.tensors.iter().cloned());
        match self.cfg.algorithm {
            Algorithm::Dfa => {
                inputs.push(self.bmat1.clone());
                inputs.push(self.bmat2.clone());
                inputs.push(x.clone());
                inputs.push(y.clone());
                inputs.push(noise1);
                inputs.push(noise2);
                inputs.push(Tensor::scalar(sigma));
                inputs.push(Tensor::scalar(bits));
            }
            Algorithm::Backprop => {
                inputs.push(x.clone());
                inputs.push(y.clone());
            }
        }
        inputs.push(Tensor::scalar(self.cfg.lr));
        inputs.push(Tensor::scalar(self.cfg.momentum));

        let mut outputs = self.step_art.execute(&inputs)?;
        let ncorrect = outputs.pop().expect("ncorrect").item() as usize;
        let loss = outputs.pop().expect("loss").item();
        self.state.update_from(&mut outputs)?;
        Ok((loss, ncorrect))
    }

    /// One training step in device mode (photonic gradient).
    fn step_device(&mut self, x: &Tensor, y: &Tensor) -> Result<(f32, usize)> {
        // forward through the artifact
        let mut inputs: Vec<Tensor> = self.state.tensors[..6].to_vec();
        inputs.push(x.clone());
        let fwd = self.fwd_art.execute(&inputs)?;
        let (logits, a1, a2, h1, h2) = (&fwd[0], &fwd[1], &fwd[2], &fwd[3], &fwd[4]);
        let (loss, e, correct) = reference::loss_and_error(logits, y);

        // photonic backward
        let (be, fb1, fb2) = self.device.as_mut().expect("device mode");
        let d1t = be.dfa_gradient(fb1, &e, a1)?;
        let d2t = be.dfa_gradient(fb2, &e, a2)?;

        // digital update through the apply_grads artifact
        let mut inputs: Vec<Tensor> = Vec::with_capacity(20);
        inputs.extend(self.state.tensors.iter().cloned());
        inputs.push(x.clone());
        inputs.push(h1.clone());
        inputs.push(h2.clone());
        inputs.push(e);
        inputs.push(d1t);
        inputs.push(d2t);
        inputs.push(Tensor::scalar(self.cfg.lr));
        inputs.push(Tensor::scalar(self.cfg.momentum));
        let mut outputs = self.apply_art.execute(&inputs)?;
        self.state.update_from(&mut outputs)?;
        Ok((loss, correct))
    }

    /// Evaluate accuracy on a dataset through the `fwd` artifact (batched;
    /// the ragged tail is dropped, as in the fixed-shape §4 protocol).
    pub fn evaluate(&mut self, data: &Dataset) -> Result<f64> {
        let batch = self.dims.batch;
        let n_batches = data.len() / batch;
        if n_batches == 0 {
            return Err(Error::Data("dataset smaller than one batch".into()));
        }
        let mut correct = 0usize;
        let mut seen = 0usize;
        for b in 0..n_batches {
            let idx: Vec<usize> = (b * batch..(b + 1) * batch).collect();
            let (x, _) = data.batch(&idx);
            let mut inputs: Vec<Tensor> = self.state.tensors[..6].to_vec();
            inputs.push(x);
            let out = self.fwd_art.execute(&inputs)?;
            let preds = out[0].argmax_rows();
            for (p, &i) in preds.iter().zip(&idx) {
                if *p == data.y[i] as usize {
                    correct += 1;
                }
            }
            seen += batch;
        }
        Ok(correct as f64 / seen as f64)
    }

    /// Run the configured training job.
    pub fn train(
        &mut self,
        train: Arc<Dataset>,
        test: Arc<Dataset>,
        mut on_epoch: impl FnMut(&EpochStats),
    ) -> Result<TrainResult> {
        // lint: timing: run wall-clock for the epoch report
        let t0 = Instant::now();
        let (sigma, bits) = self.cfg.noise.artifact_inputs().unwrap_or((0.0, 0.0));
        let noise_dims = if self.cfg.noise.needs_noise_draws() {
            Some((self.dims.d_h1, self.dims.d_h2))
        } else {
            None
        };
        let batch = self.dims.batch;
        let gradient_macs_per_step =
            (self.dims.d_h1 + self.dims.d_h2) * self.dims.d_out * batch;

        let save_every = self.cfg.save_every;
        let save_path = self.cfg.save_path.clone();
        let mut last_saved_epoch: Option<usize> = None;
        let mut history = Vec::new();
        let first_epoch = self.epochs_done + 1;
        for epoch in first_epoch..=self.cfg.epochs {
            // lint: timing: per-epoch wall-clock for the epoch report
            let e0 = Instant::now();
            let feeder = BatchFeeder::start(
                train.clone(),
                batch,
                noise_dims,
                self.rng.fork(epoch as u64),
                self.cfg.max_steps_per_epoch,
                4,
            );
            let mut loss_sum = 0.0f64;
            let mut correct = 0usize;
            let mut steps = 0usize;
            for input in feeder {
                let (loss, ncorrect) = if self.device.is_some() {
                    self.step_device(&input.x, &input.y)?
                } else {
                    let zeros1 = || Tensor::zeros(&[self.dims.d_h1, batch]);
                    let zeros2 = || Tensor::zeros(&[self.dims.d_h2, batch]);
                    self.step_artifact(
                        &input.x,
                        &input.y,
                        input.noise1.unwrap_or_else(zeros1),
                        input.noise2.unwrap_or_else(zeros2),
                        sigma,
                        bits,
                    )?
                };
                loss_sum += loss as f64;
                correct += ncorrect;
                steps += 1;
            }
            self.epochs_done = epoch;
            self.steps_done += steps as u64;
            self.metrics.add("steps", steps as u64);
            self.metrics
                .add("photonic_macs", (steps * gradient_macs_per_step) as u64);

            let val_acc = if epoch % self.cfg.eval_every == 0 || epoch == self.cfg.epochs
            {
                // lint: timing: eval-time metric
                let te = Instant::now();
                let acc = self.evaluate(&test)?;
                self.metrics.add_time("eval_s", te.elapsed());
                Some(acc)
            } else {
                None
            };
            let tel_now = self.engine.telemetry();
            let epoch_tel = tel_now.delta(&self.tel_last);
            self.tel_last = tel_now;
            self.metrics.add_telemetry(&epoch_tel);
            let stats = EpochStats {
                epoch,
                train_loss: loss_sum / steps.max(1) as f64,
                train_acc: correct as f64 / (steps.max(1) * batch) as f64,
                val_acc,
                wall_s: e0.elapsed().as_secs_f64(),
                steps,
                telemetry: epoch_tel,
            };
            crate::log_info!(
                "epoch {epoch:3}: loss {:.4} train_acc {:.4} val_acc {} ({:.1}s, {} steps, {} MAC/s{})",
                stats.train_loss,
                stats.train_acc,
                stats
                    .val_acc
                    .map_or("-".to_string(), |a| format!("{a:.4}")),
                stats.wall_s,
                steps,
                fmt_si(epoch_tel.macs_per_second(stats.wall_s)),
                epoch_tel
                    .pj_per_mac()
                    .map_or(String::new(), |pj| format!(", {pj:.2} pJ/MAC modeled")),
            );
            on_epoch(&stats);
            history.push(stats);
            if let Some(path) = &save_path {
                if save_every > 0 && epoch % save_every == 0 {
                    self.save_checkpoint(path)?;
                    last_saved_epoch = Some(epoch);
                    crate::log_info!("checkpoint saved to {path} (epoch {epoch})");
                }
            }
        }
        if let Some(path) = &save_path {
            // final snapshot, unless the last in-loop save already wrote it
            if last_saved_epoch != Some(self.epochs_done) {
                self.save_checkpoint(path)?;
            }
        }

        let test_acc = self.evaluate(&test)?;
        // run totals: everything this trainer dispatched (training steps,
        // per-epoch evals, and this final test eval) since construction
        let final_tel = self.engine.telemetry();
        let run_tel = final_tel.delta(&self.tel_base);
        self.tel_last = final_tel;
        Ok(TrainResult {
            history,
            test_acc,
            total_steps: self.steps_done as usize,
            wall_s: t0.elapsed().as_secs_f64(),
            photonic_macs: self.metrics.count("photonic_macs"),
            telemetry: run_tel,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeEngine;

    // The trainer is backend-agnostic; the native engine makes every test
    // below hermetic (no `make artifacts` required).
    fn engine() -> Arc<dyn StepEngine> {
        Arc::new(NativeEngine::new())
    }

    fn tiny_cfg() -> TrainConfig {
        TrainConfig {
            config: "tiny".into(),
            epochs: 3,
            lr: 0.05,
            n_train: 128,
            n_test: 64,
            seed: 3,
            ..TrainConfig::default()
        }
    }

    // The tiny config has d_in = 16, so synthetic 784-dim digits don't fit;
    // build a random separable 16-dim problem instead.
    fn tiny_data(n: usize, seed: u64) -> Dataset {
        use crate::data::idx::IdxArray;
        let mut rng = Pcg64::seed(seed);
        let mut pixels = Vec::with_capacity(n * 16);
        let mut labels = Vec::with_capacity(n);
        // 4 classes: bright block at one of 4 positions + noise
        for _ in 0..n {
            let c = rng.below(4) as usize;
            for j in 0..16 {
                let base = if j / 4 == c { 200.0 } else { 30.0 };
                let v = (base + rng.normal(0.0, 25.0)).clamp(0.0, 255.0);
                pixels.push(v as u8);
            }
            labels.push(c as u8);
        }
        Dataset::from_idx(
            &IdxArray::new(vec![n, 16], pixels).unwrap(),
            &IdxArray::new(vec![n], labels).unwrap(),
            4,
        )
        .unwrap()
    }

    #[test]
    fn dfa_trains_tiny_network_via_artifacts() {
        let engine = engine();
        let mut t = Trainer::new(engine, tiny_cfg()).unwrap();
        let train = Arc::new(tiny_data(256, 1));
        let test = Arc::new(tiny_data(64, 2));
        let res = t.train(train, test, |_| {}).unwrap();
        assert_eq!(res.history.len(), 3);
        assert!(
            res.history.last().unwrap().train_loss
                < 0.7 * res.history[0].train_loss,
            "loss should fall: {:?}",
            res.history.iter().map(|h| h.train_loss).collect::<Vec<_>>()
        );
        assert!(res.test_acc > 0.5, "test acc {}", res.test_acc);
        assert!(res.photonic_macs > 0);
    }

    #[test]
    fn epoch_telemetry_sums_into_run_total() {
        let mut t = Trainer::new(engine(), tiny_cfg()).unwrap();
        let train = Arc::new(tiny_data(256, 1));
        let test = Arc::new(tiny_data(64, 2));
        let mut epoch_macs = 0u64;
        let res = t
            .train(train, test, |s| {
                // every epoch dispatches work and records it
                assert!(s.telemetry.macs > 0, "epoch {} counted nothing", s.epoch);
                assert_eq!(s.telemetry.cycles, 0, "native backend fires no optics");
                epoch_macs += s.telemetry.macs;
            })
            .unwrap();
        // per-epoch: 32 steps × 28672 (dfa_step) + 8 eval fwd × 13312
        assert_eq!(epoch_macs, 3 * (32 * 28_672 + 8 * 13_312));
        // the run total additionally counts the final test evaluation
        assert_eq!(res.telemetry.macs - epoch_macs, 8 * 13_312);
        assert_eq!(res.telemetry.energy_j, 0.0);
        // metrics folded the same counters
        assert_eq!(t.metrics.count("macs"), epoch_macs);
    }

    #[test]
    fn backprop_baseline_trains() {
        let engine = engine();
        let mut cfg = tiny_cfg();
        cfg.algorithm = Algorithm::Backprop;
        let mut t = Trainer::new(engine, cfg).unwrap();
        let train = Arc::new(tiny_data(256, 1));
        let test = Arc::new(tiny_data(64, 2));
        let res = t.train(train, test, |_| {}).unwrap();
        assert!(res.test_acc > 0.5, "test acc {}", res.test_acc);
    }

    #[test]
    fn noisy_training_still_learns() {
        let engine = engine();
        let mut cfg = tiny_cfg();
        cfg.noise = NoiseMode::offchip();
        let mut t = Trainer::new(engine, cfg).unwrap();
        let train = Arc::new(tiny_data(256, 1));
        let test = Arc::new(tiny_data(64, 2));
        let res = t.train(train, test, |_| {}).unwrap();
        assert!(res.test_acc > 0.4, "test acc {}", res.test_acc);
    }

    #[test]
    fn artifact_step_matches_pure_rust_reference() {
        // the end-to-end L1/L2-vs-L3 numerics cross-check
        let engine = engine();
        let mut cfg = tiny_cfg();
        cfg.noise = NoiseMode::Gaussian { sigma: 0.1 };
        let mut t = Trainer::new(engine, cfg).unwrap();
        let data = tiny_data(64, 9);
        let idx: Vec<usize> = (0..8).collect();
        let (x, y) = data.batch(&idx);
        let mut rng = Pcg64::seed(42);
        let mut n1 = Tensor::zeros(&[32, 8]);
        rng.fill_gaussian_f32(n1.data_mut());
        let mut n2 = Tensor::zeros(&[32, 8]);
        rng.fill_gaussian_f32(n2.data_mut());

        // pure-rust twin
        let mut ref_state = t.state.tensors.clone();
        let (ref_loss, ref_correct) = reference::dfa_step(
            &mut ref_state, &t.bmat1, &t.bmat2, &x, &y, &n1, &n2, 0.1, 0.0,
            t.cfg.lr, t.cfg.momentum,
        );

        let (loss, correct) =
            t.step_artifact(&x, &y, n1, n2, 0.1, 0.0).unwrap();
        assert!((loss - ref_loss).abs() < 1e-4, "{loss} vs {ref_loss}");
        assert_eq!(correct, ref_correct);
        for (i, (a, b)) in t.state.tensors.iter().zip(&ref_state).enumerate() {
            crate::util::check::assert_close(a.data(), b.data(), 2e-4)
                .unwrap_or_else(|e| panic!("state tensor {i}: {e}"));
        }
    }

    #[test]
    fn restore_rejects_mismatched_runs() {
        let engine = engine();
        let mut t = Trainer::new(engine.clone(), tiny_cfg()).unwrap();
        let train = Arc::new(tiny_data(64, 1));
        let test = Arc::new(tiny_data(64, 2));
        let mut cfg = tiny_cfg();
        cfg.epochs = 1;
        let mut donor = Trainer::new(engine.clone(), cfg).unwrap();
        donor.train(train, test, |_| {}).unwrap();
        let mut ckpt = donor.checkpoint();
        assert_eq!(ckpt.epoch, 1);
        assert!(ckpt.total_steps > 0);

        ckpt.seed = 999;
        assert!(t.restore(&ckpt).is_err());
        ckpt.seed = tiny_cfg().seed;
        ckpt.config = "small".into();
        assert!(t.restore(&ckpt).is_err());
        ckpt.config = "tiny".into();
        // a changed hyperparameter (lr) is a protocol mismatch
        let hot = TrainConfig { lr: 0.5, ..tiny_cfg() };
        let mut other = Trainer::new(engine.clone(), hot).unwrap();
        assert!(other.restore(&ckpt).is_err());
        // a changed thread count is NOT: trajectories are thread-invariant
        let wide = TrainConfig { threads: 4, ..tiny_cfg() };
        let mut wide = Trainer::new(engine.clone(), wide).unwrap();
        wide.restore(&ckpt).unwrap();
        assert_eq!(wide.epochs_done(), 1);
        t.restore(&ckpt).unwrap();
        assert_eq!(t.epochs_done(), 1);
        assert_eq!(t.state.to_bytes(), donor.state.to_bytes());
    }

    #[test]
    fn save_every_writes_checkpoints_during_training() {
        let dir = std::env::temp_dir().join("pdfa_trainer_save");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.ckpt");
        let _ = std::fs::remove_file(&path);
        let mut cfg = tiny_cfg();
        cfg.epochs = 2;
        cfg.save_path = Some(path.to_str().unwrap().into());
        cfg.save_every = 1;
        let mut t = Trainer::new(engine(), cfg).unwrap();
        let train = Arc::new(tiny_data(64, 1));
        let test = Arc::new(tiny_data(64, 2));
        t.train(train, test, |_| {}).unwrap();
        let ckpt = Checkpoint::load(&path).unwrap();
        assert_eq!(ckpt.epoch, 2);
        assert_eq!(ckpt.state.to_bytes(), t.state.to_bytes());
    }

    #[test]
    fn eval_is_deterministic() {
        let engine = engine();
        let mut t = Trainer::new(engine, tiny_cfg()).unwrap();
        let test = tiny_data(64, 2);
        let a = t.evaluate(&test).unwrap();
        let b = t.evaluate(&test).unwrap();
        assert_eq!(a, b);
    }
}
