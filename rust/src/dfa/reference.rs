//! Pure-Rust reference implementation of the forward/backward passes.
//!
//! Mirrors `python/compile/model.py` + `kernels/ref.py` operation-for-
//! operation, so the AOT artifacts can be cross-validated end-to-end from
//! Rust (tests/artifact_vs_reference.rs): same normalisation, same noise
//! injection point, same update rule. Also used by the device backend for
//! everything outside the photonic mat-vec.

use crate::tensor::{ops, Tensor};

const EPS: f32 = 1e-12;

/// Forward activations of one batch.
#[derive(Debug, Clone)]
pub struct Forward {
    pub a1: Tensor,
    pub h1: Tensor,
    pub a2: Tensor,
    pub h2: Tensor,
    pub logits: Tensor,
}

/// x: (batch, d_in); params: [w1, b1, w2, b2, w3, b3].
pub fn forward(params: &[Tensor], x: &Tensor) -> Forward {
    let linear = |inp: &Tensor, w: &Tensor, b: &Tensor| -> Tensor {
        let mut out = inp.matmul(w).expect("shape-checked upstream");
        let cols = out.cols();
        for r in 0..out.rows() {
            for (v, bv) in out.row_mut(r).iter_mut().zip(&b.data()[..cols]) {
                *v += bv;
            }
        }
        out
    };
    let a1 = linear(x, &params[0], &params[1]);
    let h1 = a1.map(|v| v.max(0.0));
    let a2 = linear(&h1, &params[2], &params[3]);
    let h2 = a2.map(|v| v.max(0.0));
    let logits = linear(&h2, &params[4], &params[5]);
    Forward { a1, h1, a2, h2, logits }
}

/// Softmax cross-entropy: returns (mean loss, error e = softmax - y, #correct).
pub fn loss_and_error(logits: &Tensor, y: &Tensor) -> (f32, Tensor, usize) {
    let (n, c) = (logits.rows(), logits.cols());
    let mut e = Tensor::zeros(&[n, c]);
    let mut loss = 0.0f64;
    let mut correct = 0usize;
    for r in 0..n {
        let row = logits.row(r);
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let exps: Vec<f32> = row.iter().map(|&v| (v - max).exp()).collect();
        let sum: f32 = exps.iter().sum();
        let yrow = y.row(r);
        let mut y_idx = 0;
        let mut z_idx = 0;
        for j in 0..c {
            let p = exps[j] / sum;
            e.set(r, j, p - yrow[j]);
            if yrow[j] > yrow[y_idx] {
                y_idx = j;
            }
            if row[j] > row[z_idx] {
                z_idx = j;
            }
        }
        loss -= ((exps[y_idx] / sum).max(1e-30) as f64).ln();
        if y_idx == z_idx {
            correct += 1;
        }
    }
    ((loss / n as f64) as f32, e, correct)
}

/// The analog mat-vec of kernels/ref.py: B (m,k) @ e (k,batch) with
/// per-sample normalisation, additive noise sigma, optional quantisation.
pub fn analog_matvec(
    bmat: &Tensor,
    e_t: &Tensor,     // (k, batch)
    noise: &Tensor,   // (m, batch)
    sigma: f32,
    bits: f32,
) -> Tensor {
    let batch = e_t.cols();
    // per-sample scale
    let mut s = vec![EPS; batch];
    for r in 0..e_t.rows() {
        for (c, sv) in s.iter_mut().enumerate() {
            *sv = sv.max(e_t.at(r, c).abs());
        }
    }
    let mut e_n = e_t.clone();
    for r in 0..e_n.rows() {
        for c in 0..batch {
            let v = e_n.at(r, c) / s[c];
            e_n.set(r, c, v);
        }
    }
    // receiver full-scale range: max possible bank output swing for B
    let mut range = EPS;
    for r in 0..bmat.rows() {
        let swing: f32 = bmat.row(r).iter().map(|v| v.abs()).sum();
        range = range.max(swing);
    }
    let mut y = bmat.matmul(&e_n).expect("dims ok");
    let levels = (2f32).powf(bits - 1.0);
    for r in 0..y.rows() {
        for c in 0..batch {
            let mut v = y.at(r, c) / range; // normalised BPD output
            v += sigma * noise.at(r, c);
            if bits > 0.0 {
                v = (v * levels).round() / levels;
                v = v.clamp(-1.0, 1.0);
            }
            y.set(r, c, v * range * s[c]);
        }
    }
    y
}

/// Eq. (1): delta(k) = (B e in analog) ⊙ g'(a), transposed layout (m, batch).
pub fn dfa_gradient(
    bmat: &Tensor,
    e: &Tensor,      // (batch, k) — row-major error
    noise: &Tensor,  // (m, batch)
    a: &Tensor,      // (batch, m) pre-activations
    sigma: f32,
    bits: f32,
) -> Tensor {
    let y = analog_matvec(bmat, &e.t(), noise, sigma, bits);
    let mut out = y;
    for r in 0..out.rows() {
        for c in 0..out.cols() {
            if a.at(c, r) <= 0.0 {
                out.set(r, c, 0.0);
            }
        }
    }
    out
}

/// Gradients from deltas (transposed layout), matching model.py.
pub struct Grads {
    pub gw1: Tensor,
    pub gb1: Tensor,
    pub gw2: Tensor,
    pub gb2: Tensor,
    pub gw3: Tensor,
    pub gb3: Tensor,
}

pub fn grads_from_deltas(
    x: &Tensor,
    h1: &Tensor,
    h2: &Tensor,
    e: &Tensor,
    d1t: &Tensor, // (h1, batch)
    d2t: &Tensor, // (h2, batch)
) -> Grads {
    let batch = x.rows() as f32;
    let gw3 = ops::matmul_at(h2, e).unwrap().scale(1.0 / batch);
    let gb3 = ops::col_mean(e);
    let gw2 = ops::matmul_at(h1, &d2t.t()).unwrap().scale(1.0 / batch);
    let gb2 = ops::row_mean(d2t);
    let gw1 = ops::matmul_at(x, &d1t.t()).unwrap().scale(1.0 / batch);
    let gb1 = ops::row_mean(d1t);
    Grads { gw1, gb1, gw2, gb2, gw3, gb3 }
}

/// SGD + momentum in place over [params..., momentum...] (12 tensors).
pub fn sgd_momentum(state: &mut [Tensor], grads: &Grads, lr: f32, momentum: f32) {
    let gs = [
        &grads.gw1, &grads.gb1, &grads.gw2, &grads.gb2, &grads.gw3, &grads.gb3,
    ];
    for (i, g) in gs.iter().enumerate() {
        let (ps, vs) = state.split_at_mut(6);
        let v = &mut vs[i];
        for (vv, gv) in v.data_mut().iter_mut().zip(g.data()) {
            *vv = momentum * *vv + gv;
        }
        let p = &mut ps[i];
        for (pv, vv) in p.data_mut().iter_mut().zip(v.data()) {
            *pv -= lr * vv;
        }
    }
}

/// One full DFA step (the reference twin of the dfa_step artifact).
/// Returns (loss, #correct).
#[allow(clippy::too_many_arguments)]
pub fn dfa_step(
    state: &mut [Tensor],
    bmat1: &Tensor,
    bmat2: &Tensor,
    x: &Tensor,
    y: &Tensor,
    noise1: &Tensor,
    noise2: &Tensor,
    sigma: f32,
    bits: f32,
    lr: f32,
    momentum: f32,
) -> (f32, usize) {
    let fwd = forward(&state[..6], x);
    let (loss, e, correct) = loss_and_error(&fwd.logits, y);
    let d1t = dfa_gradient(bmat1, &e, noise1, &fwd.a1, sigma, bits);
    let d2t = dfa_gradient(bmat2, &e, noise2, &fwd.a2, sigma, bits);
    let grads = grads_from_deltas(x, &fwd.h1, &fwd.h2, &e, &d1t, &d2t);
    sgd_momentum(state, &grads, lr, momentum);
    (loss, correct)
}

/// One backprop step (baseline twin of the bp_step artifact).
pub fn bp_step(
    state: &mut [Tensor],
    x: &Tensor,
    y: &Tensor,
    lr: f32,
    momentum: f32,
) -> (f32, usize) {
    let fwd = forward(&state[..6], x);
    let (loss, e, correct) = loss_and_error(&fwd.logits, y);
    // d2 = (e @ w3^T) ⊙ relu'(a2); d1 = (d2 @ w2^T) ⊙ relu'(a1)
    let mut d2 = ops::matmul_bt(&e, &state[4]).unwrap();
    for r in 0..d2.rows() {
        for c in 0..d2.cols() {
            if fwd.a2.at(r, c) <= 0.0 {
                d2.set(r, c, 0.0);
            }
        }
    }
    let mut d1 = ops::matmul_bt(&d2, &state[2]).unwrap();
    for r in 0..d1.rows() {
        for c in 0..d1.cols() {
            if fwd.a1.at(r, c) <= 0.0 {
                d1.set(r, c, 0.0);
            }
        }
    }
    let grads = grads_from_deltas(x, &fwd.h1, &fwd.h2, &e, &d1.t(), &d2.t());
    sgd_momentum(state, &grads, lr, momentum);
    (loss, correct)
}

/// Accuracy of `params` on (x, y) evaluated in `batch`-row chunks.
pub fn accuracy(params: &[Tensor], x: &Tensor, labels: &[u8]) -> f64 {
    let fwd = forward(params, x);
    let pred = fwd.logits.argmax_rows();
    let correct = pred
        .iter()
        .zip(labels)
        .filter(|(&p, &l)| p == l as usize)
        .count();
    correct as f64 / labels.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::NetDims;
    use crate::dfa::params::NetState;
    use crate::util::rng::Pcg64;

    fn dims() -> NetDims {
        NetDims { d_in: 16, d_h1: 32, d_h2: 32, d_out: 4, batch: 8 }
    }

    fn toy_batch(rng: &mut Pcg64) -> (Tensor, Tensor, Vec<u8>) {
        let d = dims();
        let x = Tensor::randn(&[d.batch, d.d_in], 1.0, rng);
        let mut y = Tensor::zeros(&[d.batch, d.d_out]);
        let mut labels = Vec::new();
        for r in 0..d.batch {
            let c = rng.below(d.d_out as u64) as usize;
            y.set(r, c, 1.0);
            labels.push(c as u8);
        }
        (x, y, labels)
    }

    #[test]
    fn forward_shapes_and_relu() {
        let mut rng = Pcg64::seed(0);
        let s = NetState::init(&dims(), &mut rng);
        let (x, _, _) = toy_batch(&mut rng);
        let f = forward(s.params(), &x);
        assert_eq!(f.logits.shape(), &[8, 4]);
        assert!(f.h1.data().iter().all(|&v| v >= 0.0));
        for (h, a) in f.h1.data().iter().zip(f.a1.data()) {
            assert_eq!(*h, a.max(0.0));
        }
    }

    #[test]
    fn loss_is_lnc_at_uniform() {
        // zero logits -> loss = ln(4)
        let logits = Tensor::zeros(&[5, 4]);
        let mut y = Tensor::zeros(&[5, 4]);
        for r in 0..5 {
            y.set(r, r % 4, 1.0);
        }
        let (loss, e, _) = loss_and_error(&logits, &y);
        assert!((loss - (4.0f32).ln()).abs() < 1e-6);
        // error rows sum to 0 (softmax sums to 1, one-hot sums to 1)
        for r in 0..5 {
            assert!(e.row(r).iter().sum::<f32>().abs() < 1e-6);
        }
    }

    #[test]
    fn dfa_learns_toy_problem() {
        let mut rng = Pcg64::seed(1);
        let d = dims();
        let mut s = NetState::init(&d, &mut rng);
        let (b1, b2) = NetState::init_feedback(&d, &mut rng);
        let (x, y, _) = toy_batch(&mut rng);
        let zero1 = Tensor::zeros(&[d.d_h1, d.batch]);
        let zero2 = Tensor::zeros(&[d.d_h2, d.batch]);
        let (first, _) = dfa_step(
            &mut s.tensors, &b1, &b2, &x, &y, &zero1, &zero2, 0.0, 0.0, 0.05, 0.9,
        );
        let mut last = first;
        for _ in 0..25 {
            let (l, _) = dfa_step(
                &mut s.tensors, &b1, &b2, &x, &y, &zero1, &zero2, 0.0, 0.0, 0.05, 0.9,
            );
            last = l;
        }
        assert!(last < 0.5 * first, "{first} -> {last}");
    }

    #[test]
    fn bp_learns_toy_problem() {
        let mut rng = Pcg64::seed(2);
        let d = dims();
        let mut s = NetState::init(&d, &mut rng);
        let (x, y, _) = toy_batch(&mut rng);
        let (first, _) = bp_step(&mut s.tensors, &x, &y, 0.05, 0.9);
        let mut last = first;
        for _ in 0..25 {
            last = bp_step(&mut s.tensors, &x, &y, 0.05, 0.9).0;
        }
        assert!(last < 0.5 * first, "{first} -> {last}");
    }

    #[test]
    fn noise_free_matvec_is_exact() {
        let mut rng = Pcg64::seed(3);
        let bmat = Tensor::rand_uniform(&[30, 4], -1.0, 1.0, &mut rng);
        let e_t = Tensor::randn(&[4, 8], 0.5, &mut rng);
        let zero = Tensor::zeros(&[30, 8]);
        let got = analog_matvec(&bmat, &e_t, &zero, 0.0, 0.0);
        let want = bmat.matmul(&e_t).unwrap();
        crate::util::check::assert_close(got.data(), want.data(), 1e-4).unwrap();
    }

    #[test]
    fn accuracy_counts() {
        let params = vec![
            Tensor::new(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap(),
            Tensor::zeros(&[2]),
            Tensor::new(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap(),
            Tensor::zeros(&[2]),
            Tensor::new(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap(),
            Tensor::zeros(&[2]),
        ];
        let x = Tensor::new(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        assert_eq!(accuracy(&params, &x, &[0, 1]), 1.0);
        assert_eq!(accuracy(&params, &x, &[1, 0]), 0.0);
    }
}
