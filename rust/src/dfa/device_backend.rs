//! Device mode: the backward-pass gradient computed through the
//! device-level photonic weight bank instead of the Gaussian-noise model.
//!
//! Per hidden layer the fixed feedback matrix B(k) is tiled onto the bank
//! by the GeMM compiler; every tile's inscription is snapshotted once (the
//! paper's analog weight memory, §5) and restored per cycle — so training
//! steps never pay the feedback-lock cost again. Negative error values use
//! differential encoding: B·e = B·e⁺ − B·e⁻ with non-negative channel
//! amplitudes (two optical cycles), avoiding per-sample re-inscription.
//!
//! Everything outside the mat-vec (error, Hadamard via TIA gains, update)
//! matches the reference implementation.

use crate::gemm::tiler::Tiling;
use crate::photonics::weight_bank::Inscription;
use crate::photonics::{BankConfig, BpdMode, WeightBank};
use crate::tensor::Tensor;
use crate::{Error, Result};

/// A feedback matrix pre-compiled onto the photonic bank.
pub struct CompiledFeedback {
    tiling: Tiling,
    /// Snapshot per tile, in tiling order.
    inscriptions: Vec<Inscription>,
    /// Digital gain undoing the full-range inscription amplification.
    amp: f32,
    /// Signed weights kept for reference/debug.
    pub bmat: Tensor,
}

/// The photonic gradient engine of device mode.
pub struct DeviceBackend {
    pub bank: WeightBank,
}

impl DeviceBackend {
    /// Build a bank in the requested BPD mode at the paper's 50 × 20
    /// geometry.
    pub fn new(bpd: BpdMode, seed: u64) -> Result<DeviceBackend> {
        let bank = WeightBank::new(BankConfig { seed, ..BankConfig::paper(bpd) })?;
        Ok(DeviceBackend { bank })
    }

    /// Tile + inscribe a feedback matrix; snapshots every tile inscription.
    ///
    /// The weights are amplified to fill the bank's inscribable range
    /// (max |B| -> ~weight_max) and the inverse gain is applied digitally
    /// after readout — standard analog practice: small inscribed weights
    /// would waste receiver dynamic range and drown in BPD noise.
    pub fn compile_feedback(&mut self, bmat: &Tensor) -> Result<CompiledFeedback> {
        let (m, k) = (bmat.rows(), bmat.cols());
        let tiling = Tiling::new(m, k, self.bank.rows(), self.bank.cols())?;
        let w_max = self.bank.weight_range().1.min(0.95) as f32;
        let amp = (bmat.max_abs() / w_max).max(1e-12);
        let mut inscriptions = Vec::with_capacity(tiling.tiles.len());
        let (br, bc) = (self.bank.rows(), self.bank.cols());
        let mut tile_w = Tensor::zeros(&[br, bc]);
        for tile in &tiling.tiles {
            tile_w.data_mut().fill(0.0);
            for r in 0..tile.rows() {
                for c in 0..tile.cols() {
                    tile_w.set(r, c, bmat.at(tile.row0 + r, tile.col0 + c) / amp);
                }
            }
            self.bank.inscribe(&tile_w)?;
            inscriptions.push(self.bank.snapshot());
        }
        Ok(CompiledFeedback { tiling, inscriptions, amp, bmat: bmat.clone() })
    }

    /// y = B @ e for one sample through the photonic bank, with the TIA
    /// gains implementing the per-row Hadamard mask `gprime` (or all-ones).
    ///
    /// e is signed; differential encoding splits it into e⁺/e⁻ cycles.
    pub fn matvec(
        &mut self,
        fb: &CompiledFeedback,
        e: &[f32],
        gprime: Option<&[f32]>,
    ) -> Result<Vec<f32>> {
        let t = &fb.tiling;
        if e.len() != t.k {
            return Err(Error::Shape(format!(
                "device matvec: e length {} != {}",
                e.len(),
                t.k
            )));
        }
        if let Some(g) = gprime {
            if g.len() != t.m {
                return Err(Error::Shape("gprime length != output rows".into()));
            }
        }
        let s = e.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-12);
        let bc = self.bank.cols();
        let mut y = vec![0.0f32; t.m];
        let mut x_pos = vec![0.0f32; bc];
        let mut x_neg = vec![0.0f32; bc];
        for (tile, ins) in t.tiles.iter().zip(&fb.inscriptions) {
            self.bank.restore(ins)?;
            // TIA gains for this tile's rows
            let mut gains = vec![0.0f32; self.bank.rows()];
            for r in 0..tile.rows() {
                gains[r] = gprime.map_or(1.0, |g| g[tile.row0 + r]);
            }
            for g in gains.iter_mut().skip(tile.rows()) {
                *g = 0.0; // padding rows gated off
            }
            self.bank.set_tia_gains(&gains)?;

            x_pos.fill(0.0);
            x_neg.fill(0.0);
            let mut any_neg = false;
            for c in 0..tile.cols() {
                let v = e[tile.col0 + c] / s;
                if v >= 0.0 {
                    x_pos[c] = v.min(1.0);
                } else {
                    x_neg[c] = (-v).min(1.0);
                    any_neg = true;
                }
            }
            let gain = bc as f32 * s * fb.amp; // undo bank norm + amplification
            let out_pos = self.bank.matvec(&x_pos)?;
            for r in 0..tile.rows() {
                y[tile.row0 + r] += out_pos[r] * gain;
            }
            if any_neg {
                let out_neg = self.bank.matvec(&x_neg)?;
                for r in 0..tile.rows() {
                    y[tile.row0 + r] -= out_neg[r] * gain;
                }
            }
        }
        Ok(y)
    }

    /// Batched gradient: delta(k)^T (m, batch) for error rows `e` (batch, k)
    /// and pre-activations `a` (batch, m) — Eq. (1) end-to-end on-device.
    pub fn dfa_gradient(
        &mut self,
        fb: &CompiledFeedback,
        e: &Tensor,
        a: &Tensor,
    ) -> Result<Tensor> {
        let batch = e.rows();
        let m = fb.tiling.m;
        let mut out = Tensor::zeros(&[m, batch]);
        let mut gprime = vec![0.0f32; m];
        for smp in 0..batch {
            for (j, g) in gprime.iter_mut().enumerate() {
                *g = if a.at(smp, j) > 0.0 { 1.0 } else { 0.0 };
            }
            let y = self.matvec(fb, e.row(smp), Some(&gprime))?;
            for (j, v) in y.into_iter().enumerate() {
                out.set(j, smp, v);
            }
        }
        Ok(out)
    }

    /// Total bank cycles consumed so far (energy/throughput accounting).
    pub fn cycles(&self) -> u64 {
        self.bank.cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::assert_close;
    use crate::util::rng::Pcg64;

    fn ideal_backend() -> DeviceBackend {
        DeviceBackend::new(BpdMode::Ideal, 11).unwrap()
    }

    #[test]
    fn device_matvec_matches_dense() {
        let mut be = ideal_backend();
        let mut rng = Pcg64::seed(4);
        // 80 x 10: ragged over the 50 x 20 bank (2 row tiles, half-full cols)
        let bmat = Tensor::rand_uniform(&[80, 10], -0.9, 0.9, &mut rng);
        let fb = be.compile_feedback(&bmat).unwrap();
        let e: Vec<f32> = (0..10).map(|_| rng.normal(0.0, 0.5) as f32).collect();
        let y = be.matvec(&fb, &e, None).unwrap();
        let want: Vec<f32> = (0..80)
            .map(|r| bmat.row(r).iter().zip(&e).map(|(&w, &x)| w * x).sum())
            .collect();
        // ideal device: small systematic error from lock tolerance/crosstalk
        let scale = e.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        assert_close(&y, &want, 0.15 * scale * 10.0).unwrap();
        // correlation should be essentially 1
        let c = crate::util::stats::correlation(
            &y.iter().map(|&v| v as f64).collect::<Vec<_>>(),
            &want.iter().map(|&v| v as f64).collect::<Vec<_>>(),
        );
        assert!(c > 0.98, "correlation {c}");
    }

    #[test]
    fn gprime_gates_rows_on_device() {
        let mut be = ideal_backend();
        let mut rng = Pcg64::seed(5);
        let bmat = Tensor::rand_uniform(&[20, 4], -0.9, 0.9, &mut rng);
        let fb = be.compile_feedback(&bmat).unwrap();
        let e = [0.5f32, -0.3, 0.2, 0.1];
        let mut gp = vec![1.0f32; 20];
        for g in gp.iter_mut().take(10) {
            *g = 0.0;
        }
        let y = be.matvec(&fb, &e, Some(&gp)).unwrap();
        for (r, &v) in y.iter().enumerate().take(10) {
            assert_eq!(v, 0.0, "row {r} should be gated");
        }
        assert!(y[10..].iter().any(|&v| v.abs() > 0.01));
    }

    #[test]
    fn batched_gradient_shape_and_masking() {
        let mut be = ideal_backend();
        let mut rng = Pcg64::seed(6);
        let bmat = Tensor::rand_uniform(&[30, 4], -0.9, 0.9, &mut rng);
        let fb = be.compile_feedback(&bmat).unwrap();
        let e = Tensor::randn(&[3, 4], 0.5, &mut rng);
        let mut a = Tensor::randn(&[3, 30], 1.0, &mut rng);
        // force one sample fully inactive
        for j in 0..30 {
            a.set(1, j, -1.0);
        }
        let d = be.dfa_gradient(&fb, &e, &a).unwrap();
        assert_eq!(d.shape(), &[30, 3]);
        for j in 0..30 {
            assert_eq!(d.at(j, 1), 0.0);
        }
    }

    #[test]
    fn cycle_accounting_grows() {
        let mut be = ideal_backend();
        let mut rng = Pcg64::seed(7);
        let bmat = Tensor::rand_uniform(&[50, 20], -0.9, 0.9, &mut rng);
        let fb = be.compile_feedback(&bmat).unwrap();
        let before = be.cycles();
        let e: Vec<f32> = (0..20).map(|_| rng.uniform() as f32).collect(); // all >= 0
        be.matvec(&fb, &e, None).unwrap();
        assert_eq!(be.cycles() - before, 1); // single tile, no negatives: 1 cycle
        let e_signed: Vec<f32> = (0..20).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let before = be.cycles();
        be.matvec(&fb, &e_signed, None).unwrap();
        assert_eq!(be.cycles() - before, 2); // differential: 2 cycles
    }

    #[test]
    fn shape_errors() {
        let mut be = ideal_backend();
        let bmat = Tensor::zeros(&[10, 4]);
        let fb = be.compile_feedback(&bmat).unwrap();
        assert!(be.matvec(&fb, &[0.0; 3], None).is_err());
        assert!(be.matvec(&fb, &[0.0; 4], Some(&[1.0; 3])).is_err());
    }
}
