//! Versioned on-disk checkpoints: trained parameters + resume metadata.
//!
//! A checkpoint makes a trained network outlive its process — `pdfa infer`
//! and `pdfa serve` load one to run the forward-only inference plane, and
//! `pdfa train --resume` continues a long run bit-exactly where it
//! stopped. The container is a gzip stream (the crate's own
//! [`crate::util::gzip`] writer) holding a little-endian payload:
//!
//! | field        | bytes | contents                                        |
//! |--------------|-------|-------------------------------------------------|
//! | magic        | 8     | `PDFACKPT`                                      |
//! | version      | 4     | u32, currently [`VERSION`]                      |
//! | config       | 4 + n | u32 length + UTF-8 config name ("tiny", ...)    |
//! | dims         | 20    | 5 × u32: d_in, d_h1, d_h2, d_out, batch         |
//! | epoch        | 8     | u64 epochs fully completed                      |
//! | total_steps  | 8     | u64 optimizer steps taken                       |
//! | seed         | 8     | u64 master seed of the run                      |
//! | protocol     | 4 + n | u32 length + the run's trajectory-determining   |
//! |              |       | hyperparameters ([`protocol_string`])           |
//! | rng          | 41    | [`Pcg64`] snapshot (state, inc, Gaussian spare) |
//! | state        | 8 + n | u64 byte length + [`NetState::to_bytes`] layout |
//! | device       | 1 (+ 8 + n) | presence flag; if 1: u64 byte length + the  |
//! |              |       | engine's opaque device blob (v2)                |
//!
//! The `device` field (new in version 2) carries
//! [`crate::runtime::StepEngine::device_state`] — for the photonic
//! backend, the drift model, telemetry tallies and bank-op sequence that
//! make a resumed run on an aging device bit-identical to an
//! uninterrupted one. Digital backends write no device blob (flag 0).
//!
//! The state layout is the artifact-manifest order
//! `[w1, b1, w2, b2, w3, b3, vw1, vb1, vw2, vb2, vw3, vb3]`, each tensor a
//! flat little-endian f32 blob. The protocol string
//! ([`crate::dfa::config::TrainConfig::protocol_string`]) pins every
//! hyperparameter that shapes the trajectory (lr, momentum, algorithm,
//! noise mode, dataset recipe, step cap); `--resume` rejects a mismatch
//! instead of silently diverging. Versioning rule: any layout change bumps
//! [`VERSION`]; readers reject unknown versions with [`Error::Format`]
//! rather than guessing. Serialisation is deterministic, so
//! save → load → save is byte-identical (pinned by tests).

use std::path::Path;

use super::params::NetState;
use crate::runtime::manifest::NetDims;
use crate::util::gzip;
use crate::util::rng::{self, Pcg64};
use crate::{Error, Result};

/// File magic (first 8 bytes of the decompressed payload).
pub const MAGIC: [u8; 8] = *b"PDFACKPT";
/// Current payload version. Version 2 added the `device` field; version
/// 1 checkpoints are rejected like any other unknown version (they
/// predate the device-lifetime machinery, and resuming one as if the
/// device were factory-fresh would silently change the experiment).
pub const VERSION: u32 = 2;

/// Everything needed to serve a trained network or resume its run.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Network config name ("tiny" | "small" | "mnist" | manifest extras).
    pub config: String,
    pub dims: NetDims,
    /// Epochs fully completed when the snapshot was taken.
    pub epoch: u64,
    /// Optimizer steps taken across the whole run (including pre-resume).
    pub total_steps: u64,
    /// Master seed of the run (re-derives the DFA feedback matrices).
    pub seed: u64,
    /// [`crate::dfa::config::TrainConfig::protocol_string`] of the run:
    /// the trajectory-determining hyperparameters, validated on resume.
    pub protocol: String,
    /// Run RNG, snapshotted mid-stream for exact-trajectory resumption.
    pub rng: Pcg64,
    /// Parameter + momentum state in manifest order.
    pub state: NetState,
    /// Opaque engine device state
    /// ([`crate::runtime::StepEngine::device_state`]): `Some` when the
    /// backend carries resumable device physics (the photonic drift
    /// model + telemetry tallies), `None` on digital backends.
    pub device: Option<Vec<u8>>,
}

fn bad(msg: impl Into<String>) -> Error {
    Error::Format(format!("checkpoint: {}", msg.into()))
}

/// Bounds-checked little-endian reader over the decompressed payload.
struct Cursor<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.data.len() - self.pos < n {
            return Err(bad(format!(
                "truncated: wanted {n} bytes for {what}, {} left",
                self.data.len() - self.pos
            )));
        }
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }
}

impl Checkpoint {
    /// Serialise to the gzip container (deterministic).
    pub fn to_bytes(&self) -> Vec<u8> {
        let state = self.state.to_bytes();
        let mut p = Vec::with_capacity(state.len() + 128);
        p.extend_from_slice(&MAGIC);
        p.extend_from_slice(&VERSION.to_le_bytes());
        p.extend_from_slice(&(self.config.len() as u32).to_le_bytes());
        p.extend_from_slice(self.config.as_bytes());
        for d in [
            self.dims.d_in,
            self.dims.d_h1,
            self.dims.d_h2,
            self.dims.d_out,
            self.dims.batch,
        ] {
            p.extend_from_slice(&(d as u32).to_le_bytes());
        }
        p.extend_from_slice(&self.epoch.to_le_bytes());
        p.extend_from_slice(&self.total_steps.to_le_bytes());
        p.extend_from_slice(&self.seed.to_le_bytes());
        p.extend_from_slice(&(self.protocol.len() as u32).to_le_bytes());
        p.extend_from_slice(self.protocol.as_bytes());
        p.extend_from_slice(&self.rng.to_state_bytes());
        p.extend_from_slice(&(state.len() as u64).to_le_bytes());
        p.extend_from_slice(&state);
        match &self.device {
            Some(d) => {
                p.push(1);
                p.extend_from_slice(&(d.len() as u64).to_le_bytes());
                p.extend_from_slice(d);
            }
            None => p.push(0),
        }
        gzip::compress(&p)
    }

    /// Parse a serialised checkpoint; every malformation (bad container,
    /// magic, version, truncation, dim/state mismatch, trailing bytes)
    /// is a clean [`Error::Format`].
    pub fn from_bytes(raw: &[u8]) -> Result<Checkpoint> {
        let payload =
            gzip::decompress(raw).map_err(|e| bad(format!("bad container ({e})")))?;
        let mut c = Cursor { data: &payload, pos: 0 };
        if c.take(8, "magic")? != MAGIC {
            return Err(bad("bad magic (not a pdfa checkpoint)"));
        }
        let version = c.u32("version")?;
        if version != VERSION {
            return Err(bad(format!(
                "unsupported version {version} (this build reads {VERSION})"
            )));
        }
        let name_len = c.u32("config length")? as usize;
        if name_len > 256 {
            return Err(bad(format!("implausible config name length {name_len}")));
        }
        let config = std::str::from_utf8(c.take(name_len, "config name")?)
            .map_err(|_| bad("config name is not UTF-8"))?
            .to_string();
        let mut dim = |what| -> Result<usize> {
            let v = c.u32(what)? as usize;
            if v == 0 {
                return Err(bad(format!("{what} is zero")));
            }
            Ok(v)
        };
        let dims = NetDims {
            d_in: dim("d_in")?,
            d_h1: dim("d_h1")?,
            d_h2: dim("d_h2")?,
            d_out: dim("d_out")?,
            batch: dim("batch")?,
        };
        let epoch = c.u64("epoch")?;
        let total_steps = c.u64("total_steps")?;
        let seed = c.u64("seed")?;
        let proto_len = c.u32("protocol length")? as usize;
        if proto_len > 4096 {
            return Err(bad(format!("implausible protocol length {proto_len}")));
        }
        let protocol = std::str::from_utf8(c.take(proto_len, "protocol")?)
            .map_err(|_| bad("protocol string is not UTF-8"))?
            .to_string();
        let rng_bytes: [u8; rng::STATE_BYTES] =
            c.take(rng::STATE_BYTES, "rng state")?.try_into().unwrap();
        let rng = Pcg64::from_state_bytes(&rng_bytes)
            .ok_or_else(|| bad("invalid rng snapshot"))?;
        let state_len = c.u64("state length")? as usize;
        let state_bytes = c.take(state_len, "parameter state")?;
        let state = NetState::from_bytes(&dims, state_bytes)
            .map_err(|e| bad(format!("state does not match dims ({e})")))?;
        let device = match c.take(1, "device flag")?[0] {
            0 => None,
            1 => {
                let n = c.u64("device length")? as usize;
                Some(c.take(n, "device state")?.to_vec())
            }
            other => return Err(bad(format!("invalid device flag {other}"))),
        };
        if c.pos != payload.len() {
            return Err(bad(format!(
                "{} trailing bytes after state",
                payload.len() - c.pos
            )));
        }
        Ok(Checkpoint {
            config,
            dims,
            epoch,
            total_steps,
            seed,
            protocol,
            rng,
            state,
            device,
        })
    }

    /// Write to `path` atomically: the bytes land in a sibling `.tmp`
    /// file first and are renamed over the target, so a crash mid-save
    /// can never destroy the previous good checkpoint (fs errors surface
    /// as [`Error::Io`]).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let mut tmp_name = path.as_os_str().to_owned();
        tmp_name.push(".tmp");
        let tmp = std::path::PathBuf::from(tmp_name);
        std::fs::write(&tmp, self.to_bytes())?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Read from `path`: [`Error::Io`] for fs failures, [`Error::Format`]
    /// for anything malformed past that.
    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        Self::from_bytes(&std::fs::read(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> NetDims {
        NetDims { d_in: 16, d_h1: 32, d_h2: 32, d_out: 4, batch: 8 }
    }

    fn sample() -> Checkpoint {
        let mut rng = Pcg64::seed(7);
        let state = NetState::init(&dims(), &mut rng);
        Checkpoint {
            config: "tiny".into(),
            dims: dims(),
            epoch: 3,
            total_steps: 96,
            seed: 7,
            protocol: "lr=0.05;momentum=0.9".into(),
            rng,
            state,
            device: None,
        }
    }

    #[test]
    fn roundtrip_is_byte_identical() {
        let ckpt = sample();
        let bytes = ckpt.to_bytes();
        let back = Checkpoint::from_bytes(&bytes).unwrap();
        assert_eq!(back.config, "tiny");
        assert_eq!(back.dims, dims());
        assert_eq!(back.epoch, 3);
        assert_eq!(back.total_steps, 96);
        assert_eq!(back.seed, 7);
        assert_eq!(back.protocol, "lr=0.05;momentum=0.9");
        assert_eq!(back.state.to_bytes(), ckpt.state.to_bytes());
        // save -> load -> save pins determinism end to end
        assert_eq!(back.to_bytes(), bytes);
        // and the restored rng continues the same stream
        let mut a = ckpt.rng.clone();
        let mut b = back.rng.clone();
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn file_roundtrip_and_io_error() {
        let dir = std::env::temp_dir().join("pdfa_ckpt_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.ckpt");
        let ckpt = sample();
        ckpt.save(&path).unwrap();
        // atomic write: the staging file never lingers
        assert!(!dir.join("a.ckpt.tmp").exists());
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.to_bytes(), ckpt.to_bytes());
        match Checkpoint::load(dir.join("missing.ckpt")) {
            Err(Error::Io(_)) => {}
            other => panic!("expected Io error, got {other:?}"),
        }
    }

    fn expect_format(r: Result<Checkpoint>) {
        match r {
            Err(Error::Format(_)) => {}
            Err(e) => panic!("expected Format error, got {e:?}"),
            Ok(_) => panic!("malformed checkpoint accepted"),
        }
    }

    #[test]
    fn malformations_are_clean_format_errors() {
        let good = sample().to_bytes();
        // not gzip at all
        expect_format(Checkpoint::from_bytes(b"definitely not gzip"));
        // truncated container
        expect_format(Checkpoint::from_bytes(&good[..good.len() / 2]));
        // valid gzip, wrong magic
        expect_format(Checkpoint::from_bytes(&gzip::compress(b"XXXXXXXXrest")));
        // valid gzip, truncated payload
        let payload = gzip::decompress(&good).unwrap();
        expect_format(Checkpoint::from_bytes(&gzip::compress(&payload[..40])));
        // future version
        let mut v3 = payload.clone();
        v3[8] = 3;
        expect_format(Checkpoint::from_bytes(&gzip::compress(&v3)));
        // the retired pre-device version is rejected too, not guessed at
        let mut v1 = payload.clone();
        v1[8] = 1;
        expect_format(Checkpoint::from_bytes(&gzip::compress(&v1)));
        // trailing garbage
        let mut long = payload.clone();
        long.extend_from_slice(&[0u8; 4]);
        expect_format(Checkpoint::from_bytes(&gzip::compress(&long)));
        // invalid device presence flag
        let mut flag = payload.clone();
        let at = flag.len() - 1;
        flag[at] = 9;
        expect_format(Checkpoint::from_bytes(&gzip::compress(&flag)));
        // state shorter than dims demand
        let mut short = payload;
        let cut = short.len() - 9; // device flag byte + 8 state bytes
        short.truncate(cut);
        expect_format(Checkpoint::from_bytes(&gzip::compress(&short)));
    }

    #[test]
    fn device_blob_round_trips_and_truncation_is_rejected() {
        let mut ckpt = sample();
        ckpt.device = Some(vec![0xAB; 37]);
        let bytes = ckpt.to_bytes();
        let back = Checkpoint::from_bytes(&bytes).unwrap();
        assert_eq!(back.device.as_deref(), Some(&[0xAB; 37][..]));
        // determinism holds with the device field present
        assert_eq!(back.to_bytes(), bytes);
        // a truncated device blob is a clean format error
        let payload = gzip::decompress(&bytes).unwrap();
        let cut = payload.len() - 5;
        expect_format(Checkpoint::from_bytes(&gzip::compress(&payload[..cut])));
    }
}
