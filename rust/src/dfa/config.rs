//! Training configuration.
//!
//! Defaults are the paper's §4 protocol: SGD with momentum 0.9, learning
//! rate 0.01, mini-batch 64, cross-entropy loss, ReLU hidden layers.

use super::noise_model::NoiseMode;
use crate::runtime::photonic::PhysicsConfig;
use crate::util::json::Value;
use crate::{Error, Result};

/// Which backward-pass algorithm trains the network.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    /// Direct feedback alignment through the photonic path (the paper).
    Dfa,
    /// Backpropagation baseline (digital, noise-free).
    Backprop,
}

/// Full run configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Artifact config name: "tiny", "small" or "mnist".
    pub config: String,
    pub algorithm: Algorithm,
    pub noise: NoiseMode,
    pub epochs: usize,
    pub lr: f32,
    pub momentum: f32,
    /// Master seed: init, shuffling, noise draws, dataset synthesis.
    pub seed: u64,
    /// Dataset sizes (synthetic generation or subset of loaded files).
    pub n_train: usize,
    pub n_test: usize,
    /// Optional directory of IDX files (real MNIST drop-in); None = synth.
    pub data_dir: Option<String>,
    /// Evaluate on the validation set every `eval_every` epochs.
    pub eval_every: usize,
    /// Optional cap on steps per epoch (quick smoke runs).
    pub max_steps_per_epoch: Option<usize>,
    /// Checkpoint path; when set the trainer writes a checkpoint there
    /// every [`Self::save_every`] epochs and at the end of the run.
    pub save_path: Option<String>,
    /// Checkpoint cadence in epochs (0 = only the final checkpoint).
    pub save_every: usize,
    /// Device physics of the photonic backend (`--backend photonic`):
    /// bank geometry, DAC/ADC bits, read-noise sigma, crosstalk/lock
    /// fidelity. `None` for the digital backends. Part of the protocol
    /// string — a resume under different physics is a trajectory change.
    pub physics: Option<PhysicsConfig>,
    /// Worker threads for the engines' parallel paths (0 = all cores,
    /// the `--threads` CLI convention). Deliberately NOT part of the
    /// protocol string: per-row counter-keyed noise streams make every
    /// trajectory bit-identical at any thread count, so this knob only
    /// changes wall-clock time.
    pub threads: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            config: "mnist".into(),
            algorithm: Algorithm::Dfa,
            noise: NoiseMode::Clean,
            epochs: 10,
            lr: 0.01,
            momentum: 0.9,
            seed: 1,
            n_train: 60_000,
            n_test: 10_000,
            data_dir: None,
            eval_every: 1,
            max_steps_per_epoch: None,
            save_path: None,
            save_every: 0,
            physics: None,
            threads: 0,
        }
    }
}

impl TrainConfig {
    /// Serialise for the run record.
    pub fn to_json(&self) -> Value {
        Value::object(vec![
            ("config", Value::str(&self.config)),
            (
                "algorithm",
                Value::str(match self.algorithm {
                    Algorithm::Dfa => "dfa",
                    Algorithm::Backprop => "backprop",
                }),
            ),
            ("noise", Value::str(self.noise.describe())),
            ("epochs", Value::Number(self.epochs as f64)),
            ("lr", Value::Number(self.lr as f64)),
            ("momentum", Value::Number(self.momentum as f64)),
            ("seed", Value::Number(self.seed as f64)),
            ("n_train", Value::Number(self.n_train as f64)),
            ("n_test", Value::Number(self.n_test as f64)),
            (
                "physics",
                self.physics
                    .map_or(Value::Null, |p| Value::str(&p.describe())),
            ),
            // recorded for the run report only; not trajectory-determining
            ("threads", Value::Number(self.threads as f64)),
        ])
    }

    /// Canonical string of every trajectory-determining hyperparameter
    /// (everything except epoch count and checkpoint cadence). Stored in
    /// checkpoints and compared on `--resume`, so a resumed run cannot
    /// silently diverge from the uninterrupted one through a changed lr,
    /// algorithm, noise mode or dataset recipe. f32s print in Rust's
    /// shortest round-trip form, so string equality is value equality.
    pub fn protocol_string(&self) -> String {
        format!(
            "lr={};momentum={};algorithm={:?};noise={};n_train={};n_test={};\
             max_steps={:?};data_dir={};physics={}",
            self.lr,
            self.momentum,
            self.algorithm,
            self.noise.describe(),
            self.n_train,
            self.n_test,
            self.max_steps_per_epoch,
            self.data_dir.as_deref().unwrap_or(""),
            self.physics
                .map_or_else(|| "none".to_string(), |p| p.describe()),
        )
    }

    pub fn validate(&self) -> Result<()> {
        if self.epochs == 0 {
            return Err(Error::Config("epochs must be >= 1".into()));
        }
        if !(self.lr > 0.0) {
            return Err(Error::Config("lr must be positive".into()));
        }
        if !(0.0..1.0).contains(&self.momentum) {
            return Err(Error::Config("momentum must be in [0, 1)".into()));
        }
        if self.n_train == 0 || self.n_test == 0 {
            return Err(Error::Config("dataset sizes must be positive".into()));
        }
        if let Some(physics) = &self.physics {
            physics.validate()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_protocol() {
        let c = TrainConfig::default();
        assert_eq!(c.lr, 0.01);
        assert_eq!(c.momentum, 0.9);
        assert_eq!(c.config, "mnist");
        assert_eq!(c.n_train, 60_000);
        c.validate().unwrap();
    }

    #[test]
    fn validation_rejects_nonsense() {
        let mut c = TrainConfig::default();
        c.epochs = 0;
        assert!(c.validate().is_err());
        let mut c = TrainConfig::default();
        c.lr = -0.1;
        assert!(c.validate().is_err());
        let mut c = TrainConfig::default();
        c.momentum = 1.5;
        assert!(c.validate().is_err());
        let mut c = TrainConfig::default();
        c.physics = Some(PhysicsConfig { bank_rows: 0, ..PhysicsConfig::ideal() });
        assert!(c.validate().is_err());
    }

    #[test]
    fn protocol_string_tracks_trajectory_knobs() {
        let base = TrainConfig::default();
        assert_eq!(base.protocol_string(), TrainConfig::default().protocol_string());
        // epochs and checkpoint cadence are NOT part of the protocol
        let c = TrainConfig { epochs: 99, save_every: 3, ..TrainConfig::default() };
        assert_eq!(c.protocol_string(), base.protocol_string());
        // neither is the thread count: results are bit-identical at any
        // value, so a --threads 4 run may resume a --threads 1 checkpoint
        let c = TrainConfig { threads: 4, ..TrainConfig::default() };
        assert_eq!(c.protocol_string(), base.protocol_string());
        // every trajectory-determining knob changes it
        for mutate in [
            (|c: &mut TrainConfig| c.lr = 0.1) as fn(&mut TrainConfig),
            |c| c.momentum = 0.5,
            |c| c.algorithm = Algorithm::Backprop,
            |c| c.noise = NoiseMode::Gaussian { sigma: 0.2 },
            |c| c.n_train = 7,
            |c| c.max_steps_per_epoch = Some(3),
            |c| c.data_dir = Some("elsewhere".into()),
            |c| c.physics = Some(PhysicsConfig::ideal()),
        ] {
            let mut c = TrainConfig::default();
            mutate(&mut c);
            assert_ne!(c.protocol_string(), base.protocol_string());
        }
    }

    #[test]
    fn physics_hyperparameters_are_protocol_determining() {
        // every physics knob must flip the protocol string, so --resume
        // rejects a checkpoint trained under different device physics
        // instead of silently diverging
        let base = TrainConfig { physics: Some(PhysicsConfig::ideal()), ..TrainConfig::default() };
        assert_eq!(base.protocol_string(), base.clone().protocol_string());
        for mutate in [
            (|p: &mut PhysicsConfig| p.bank_rows = 25) as fn(&mut PhysicsConfig),
            |p| p.bank_cols = 10,
            |p| p.dac_bits = 8,
            |p| p.adc_bits = 4,
            |p| p.sigma = 0.2,
            |p| p.crosstalk = true,
            |p| p.lock = true,
            |p| p.seed = 99,
            |p| p.drift_rate = 1e-3,
            |p| p.drift_aging = 1e-5,
            |p| p.recal_threshold = 0.1,
        ] {
            let mut physics = PhysicsConfig::ideal();
            mutate(&mut physics);
            let c = TrainConfig { physics: Some(physics), ..TrainConfig::default() };
            assert_ne!(
                c.protocol_string(),
                base.protocol_string(),
                "physics change must change the protocol: {}",
                physics.describe()
            );
        }
        // and turning the physics off entirely is a protocol change too
        let off = TrainConfig::default();
        assert_ne!(off.protocol_string(), base.protocol_string());
    }

    #[test]
    fn json_round_trips_keys() {
        let c = TrainConfig::default();
        let j = c.to_json();
        assert_eq!(j.get("lr").as_f64(), Some(0.01f32 as f64));
        assert_eq!(j.get("algorithm").as_str(), Some("dfa"));
    }
}
