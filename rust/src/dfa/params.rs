//! Network parameter state: initialisation and (de)serialisation.
//!
//! State layout matches the artifact manifest exactly:
//! `[w1, b1, w2, b2, w3, b3, vw1, vb1, vw2, vb2, vw3, vb3]`.

use crate::runtime::manifest::NetDims;
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;
use crate::{Error, Result};

pub const N_STATE: usize = 12;

/// Parameter + momentum state of the 3-layer MLP.
#[derive(Debug, Clone)]
pub struct NetState {
    /// 12 tensors in manifest order.
    pub tensors: Vec<Tensor>,
}

impl NetState {
    /// He-style initialisation (ReLU layers): W ~ N(0, sqrt(2/fan_in)),
    /// biases zero, momentum zero. Matches the Python tests' protocol.
    pub fn init(dims: &NetDims, rng: &mut Pcg64) -> NetState {
        let he = |fan_in: usize| (2.0 / fan_in as f32).sqrt();
        let shapes = Self::param_shapes(dims);
        let mut tensors = Vec::with_capacity(N_STATE);
        for (i, shape) in shapes.iter().enumerate() {
            if shape.len() == 2 {
                tensors.push(Tensor::randn(shape, he(shape[0]), rng));
            } else {
                tensors.push(Tensor::zeros(shape));
            }
            let _ = i;
        }
        for shape in &shapes {
            tensors.push(Tensor::zeros(shape)); // momentum
        }
        NetState { tensors }
    }

    /// The 6 parameter shapes (weights interleaved with biases).
    pub fn param_shapes(dims: &NetDims) -> Vec<Vec<usize>> {
        vec![
            vec![dims.d_in, dims.d_h1],
            vec![dims.d_h1],
            vec![dims.d_h1, dims.d_h2],
            vec![dims.d_h2],
            vec![dims.d_h2, dims.d_out],
            vec![dims.d_out],
        ]
    }

    /// Fixed random feedback matrices B(k) ~ U(-a, a) with a = 1/sqrt(C):
    /// inside the photonic weight bank's inscribable [-1, 1] range (§3),
    /// scaled so the DFA delta magnitudes match the true-gradient scale
    /// (Nøkland-style feedback init; keeps the paper's lr = 0.01 stable).
    pub fn init_feedback(dims: &NetDims, rng: &mut Pcg64) -> (Tensor, Tensor) {
        let a = 1.0 / (dims.d_out as f32).sqrt();
        (
            Tensor::rand_uniform(&[dims.d_h1, dims.d_out], -a, a, rng),
            Tensor::rand_uniform(&[dims.d_h2, dims.d_out], -a, a, rng),
        )
    }

    pub fn params(&self) -> &[Tensor] {
        &self.tensors[..6]
    }

    /// Replace state from an artifact's first 12 outputs.
    pub fn update_from(&mut self, outputs: &mut Vec<Tensor>) -> Result<()> {
        if outputs.len() < N_STATE {
            return Err(Error::Shape(format!(
                "expected >= {N_STATE} outputs, got {}",
                outputs.len()
            )));
        }
        for (i, t) in outputs.drain(..N_STATE).enumerate() {
            if t.shape() != self.tensors[i].shape() {
                return Err(Error::Shape(format!(
                    "state tensor {i} shape changed: {:?} -> {:?}",
                    self.tensors[i].shape(),
                    t.shape()
                )));
            }
            self.tensors[i] = t;
        }
        Ok(())
    }

    /// Serialise to a flat little-endian f32 blob (checkpointing).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        for t in &self.tensors {
            for &v in t.data() {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    /// Restore from [`Self::to_bytes`] given the dims.
    pub fn from_bytes(dims: &NetDims, bytes: &[u8]) -> Result<NetState> {
        let shapes: Vec<Vec<usize>> = Self::param_shapes(dims)
            .into_iter()
            .cycle()
            .take(N_STATE)
            .collect();
        let total: usize = shapes.iter().map(|s| s.iter().product::<usize>()).sum();
        if bytes.len() != total * 4 {
            return Err(Error::Data(format!(
                "checkpoint size {} != expected {}",
                bytes.len(),
                total * 4
            )));
        }
        let mut tensors = Vec::with_capacity(N_STATE);
        let mut off = 0;
        for shape in &shapes {
            let n: usize = shape.iter().product();
            let data: Vec<f32> = (0..n)
                .map(|i| {
                    let o = off + i * 4;
                    f32::from_le_bytes([bytes[o], bytes[o + 1], bytes[o + 2], bytes[o + 3]])
                })
                .collect();
            off += n * 4;
            tensors.push(Tensor::new(shape, data)?);
        }
        Ok(NetState { tensors })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> NetDims {
        NetDims { d_in: 16, d_h1: 32, d_h2: 32, d_out: 4, batch: 8 }
    }

    #[test]
    fn init_shapes_and_scales() {
        let mut rng = Pcg64::seed(0);
        let s = NetState::init(&dims(), &mut rng);
        assert_eq!(s.tensors.len(), 12);
        assert_eq!(s.tensors[0].shape(), &[16, 32]);
        assert_eq!(s.tensors[1].shape(), &[32]);
        assert_eq!(s.tensors[4].shape(), &[32, 4]);
        // biases and momentum start at zero
        assert_eq!(s.tensors[1].sum(), 0.0);
        for t in &s.tensors[6..] {
            assert_eq!(t.sum(), 0.0);
        }
        // He std
        let w1 = &s.tensors[0];
        let std = (w1.data().iter().map(|v| v * v).sum::<f32>() / w1.len() as f32).sqrt();
        assert!((std - (2.0f32 / 16.0).sqrt()).abs() < 0.03, "{std}");
    }

    #[test]
    fn feedback_in_inscribable_range() {
        let mut rng = Pcg64::seed(1);
        let (b1, b2) = NetState::init_feedback(&dims(), &mut rng);
        assert_eq!(b1.shape(), &[32, 4]);
        assert_eq!(b2.shape(), &[32, 4]);
        assert!(b1.data().iter().all(|v| (-1.0..1.0).contains(v)));
    }

    #[test]
    fn update_from_drains_and_validates() {
        let mut rng = Pcg64::seed(2);
        let mut s = NetState::init(&dims(), &mut rng);
        let replacement: Vec<Tensor> = s
            .tensors
            .iter()
            .map(|t| Tensor::full(t.shape(), 7.0))
            .chain([Tensor::scalar(0.5), Tensor::scalar(3.0)])
            .collect();
        let mut outs = replacement;
        s.update_from(&mut outs).unwrap();
        assert_eq!(outs.len(), 2); // loss and ncorrect left behind
        assert_eq!(s.tensors[0].data()[0], 7.0);
        // wrong shapes rejected
        let mut bad = vec![Tensor::zeros(&[1]); 12];
        assert!(s.update_from(&mut bad).is_err());
    }

    #[test]
    fn checkpoint_roundtrip() {
        let mut rng = Pcg64::seed(3);
        let s = NetState::init(&dims(), &mut rng);
        let bytes = s.to_bytes();
        let back = NetState::from_bytes(&dims(), &bytes).unwrap();
        for (a, b) in s.tensors.iter().zip(&back.tensors) {
            assert_eq!(a, b);
        }
        assert!(NetState::from_bytes(&dims(), &bytes[..10]).is_err());
    }
}
