//! Input pipeline: overlap batch assembly with step execution.
//!
//! A single producer thread gathers the next mini-batch, one-hot encodes
//! the labels and samples the analog read-noise tensors while the consumer
//! (the trainer) executes the current step on whichever
//! [`crate::runtime::StepEngine`] backend is active — the role the SRAM +
//! DMA engine plays in the paper's control system. A bounded channel
//! provides backpressure. Single-threaded production keeps runs
//! bit-deterministic across backends.

use std::sync::mpsc;
use std::sync::Arc;

use crate::data::{Batcher, Dataset};
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

/// Everything one training step consumes.
pub struct StepInput {
    pub x: Tensor,
    pub y: Tensor,
    /// Standard-normal draws for the two hidden layers, or None when the
    /// noise mode doesn't need them (zeros are passed to the artifact).
    pub noise1: Option<Tensor>,
    pub noise2: Option<Tensor>,
    pub step_in_epoch: usize,
}

/// Producer handle; iterate to consume the epoch.
pub struct BatchFeeder {
    rx: mpsc::Receiver<StepInput>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl BatchFeeder {
    /// Start producing one epoch of batches.
    ///
    /// `noise_dims = Some((h1, h2))` enables per-step noise tensor draws of
    /// shapes (h1, batch) and (h2, batch). `rng` seeds both shuffling and
    /// noise; pass a fork of the run RNG so epochs differ.
    pub fn start(
        dataset: Arc<Dataset>,
        batch: usize,
        noise_dims: Option<(usize, usize)>,
        mut rng: Pcg64,
        max_steps: Option<usize>,
        depth: usize,
    ) -> BatchFeeder {
        let (tx, rx) = mpsc::sync_channel(depth.max(1));
        let handle = std::thread::spawn(move || {
            let batcher = Batcher::new(dataset.len(), batch, &mut rng);
            for (step, idx) in batcher.enumerate() {
                if let Some(cap) = max_steps {
                    if step >= cap {
                        break;
                    }
                }
                let (x, y) = dataset.batch(&idx);
                let (noise1, noise2) = match noise_dims {
                    Some((h1, h2)) => {
                        let mut n1 = Tensor::zeros(&[h1, batch]);
                        rng.fill_gaussian_f32(n1.data_mut());
                        let mut n2 = Tensor::zeros(&[h2, batch]);
                        rng.fill_gaussian_f32(n2.data_mut());
                        (Some(n1), Some(n2))
                    }
                    None => (None, None),
                };
                if tx
                    .send(StepInput { x, y, noise1, noise2, step_in_epoch: step })
                    .is_err()
                {
                    break; // consumer hung up early
                }
            }
        });
        BatchFeeder { rx, handle: Some(handle) }
    }
}

impl Iterator for BatchFeeder {
    type Item = StepInput;

    fn next(&mut self) -> Option<StepInput> {
        self.rx.recv().ok()
    }
}

impl Drop for BatchFeeder {
    fn drop(&mut self) {
        // Disconnect the channel so a blocked producer unblocks, then join.
        let (_tx, dummy) = mpsc::sync_channel(1);
        drop(std::mem::replace(&mut self.rx, dummy));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dataset() -> Arc<Dataset> {
        Arc::new(Dataset::synthetic(96, 5))
    }

    #[test]
    fn yields_full_epoch_in_order() {
        let f = BatchFeeder::start(dataset(), 32, None, Pcg64::seed(1), None, 2);
        let steps: Vec<StepInput> = f.collect();
        assert_eq!(steps.len(), 3);
        for (i, s) in steps.iter().enumerate() {
            assert_eq!(s.step_in_epoch, i);
            assert_eq!(s.x.shape(), &[32, 784]);
            assert_eq!(s.y.shape(), &[32, 10]);
            assert!(s.noise1.is_none());
        }
    }

    #[test]
    fn noise_tensors_when_requested() {
        let f = BatchFeeder::start(
            dataset(),
            32,
            Some((64, 48)),
            Pcg64::seed(2),
            None,
            2,
        );
        let first = f.into_iter().next().unwrap();
        let n1 = first.noise1.unwrap();
        assert_eq!(n1.shape(), &[64, 32]);
        assert_eq!(first.noise2.unwrap().shape(), &[48, 32]);
        // standard-normal-ish
        let mean = n1.sum() / n1.len() as f32;
        assert!(mean.abs() < 0.2);
    }

    #[test]
    fn max_steps_caps_epoch() {
        let f = BatchFeeder::start(dataset(), 32, None, Pcg64::seed(3), Some(2), 2);
        assert_eq!(f.count(), 2);
    }

    #[test]
    fn deterministic_given_seed() {
        let collect = |seed: u64| -> Vec<f32> {
            BatchFeeder::start(dataset(), 32, Some((8, 8)), Pcg64::seed(seed), None, 2)
                .flat_map(|s| {
                    let mut v = s.x.data()[..8].to_vec();
                    v.extend_from_slice(&s.noise1.unwrap().data()[..8]);
                    v
                })
                .collect()
        };
        assert_eq!(collect(7), collect(7));
        assert_ne!(collect(7), collect(8));
    }

    #[test]
    fn early_drop_does_not_hang() {
        let f = BatchFeeder::start(dataset(), 32, None, Pcg64::seed(4), None, 1);
        drop(f); // producer must unblock and join
    }
}
