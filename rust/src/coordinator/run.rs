//! Run recording: config and per-epoch history on disk.
//!
//! Layout: `<out_dir>/<run_name>/{config.json, history.json, result.json}`;
//! the trainer writes `final.ckpt` (and the `--save-every` checkpoint)
//! into the same directory through [`crate::dfa::checkpoint`]. History is
//! plain JSON so result tables can be regenerated from recorded runs
//! without re-training.
//!
//! Telemetry contract: each history record carries the epoch's hardware
//! counters (`telemetry`: MACs, optical cycles, bank ops, modeled
//! energy — see [`crate::telemetry`]) plus the wall-clock `mac_per_s`
//! rate, and `result.json` carries the run totals. The counter objects
//! are byte-identical at any `--threads` value; only the rate and
//! `wall_s` fields vary. `pdfa report <run-dir>` renders them against
//! the paper's §5 targets via [`crate::telemetry::report`].

use std::path::{Path, PathBuf};

use crate::util::json::Value;
use crate::Result;

pub struct RunRecorder {
    pub dir: PathBuf,
    history: Vec<Value>,
}

impl RunRecorder {
    pub fn create(out_dir: impl AsRef<Path>, run_name: &str) -> Result<RunRecorder> {
        let dir = out_dir.as_ref().join(run_name);
        std::fs::create_dir_all(&dir)?;
        Ok(RunRecorder { dir, history: Vec::new() })
    }

    pub fn write_config(&self, config: &Value) -> Result<()> {
        std::fs::write(self.dir.join("config.json"), config.to_string_pretty())?;
        Ok(())
    }

    /// Write `config.json` for an engine-driven run: the training config
    /// plus the backend identity that executed it. A recorded run is not
    /// reproducible without the engine — the same `TrainConfig` lands on
    /// different trajectories on `native` vs `photonic` (device physics)
    /// — so the backend is part of the run record, mirroring its role in
    /// the checkpoint protocol string.
    pub fn write_engine_config(&self, backend: &str, config: &Value) -> Result<()> {
        let doc = Value::object(vec![
            ("backend", Value::str(backend)),
            ("train", config.clone()),
        ]);
        self.write_config(&doc)
    }

    /// Append one epoch record and rewrite history.json (crash-safe-ish:
    /// the file is always a complete valid document).
    pub fn record_epoch(&mut self, record: Value) -> Result<()> {
        self.history.push(record);
        let doc = Value::Array(self.history.clone());
        let tmp = self.dir.join("history.json.tmp");
        std::fs::write(&tmp, doc.to_string_pretty())?;
        std::fs::rename(&tmp, self.dir.join("history.json"))?;
        Ok(())
    }

    pub fn write_report(&self, name: &str, report: &Value) -> Result<()> {
        std::fs::write(self.dir.join(name), report.to_string_pretty())?;
        Ok(())
    }

    pub fn history(&self) -> &[Value] {
        &self.history
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_persists() {
        let base = std::env::temp_dir().join("pdfa_run_test");
        let mut rec = RunRecorder::create(&base, "unit").unwrap();
        rec.write_config(&Value::object(vec![("lr", Value::Number(0.01))]))
            .unwrap();
        rec.record_epoch(Value::object(vec![
            ("epoch", Value::Number(1.0)),
            ("val_acc", Value::Number(0.91)),
        ]))
        .unwrap();
        rec.record_epoch(Value::object(vec![
            ("epoch", Value::Number(2.0)),
            ("val_acc", Value::Number(0.93)),
        ]))
        .unwrap();
        rec.write_report("result.json", &Value::object(vec![("ok", Value::Bool(true))]))
            .unwrap();

        let hist =
            Value::parse(&std::fs::read_to_string(rec.dir.join("history.json")).unwrap())
                .unwrap();
        assert_eq!(hist.as_array().unwrap().len(), 2);
        assert_eq!(
            hist.as_array().unwrap()[1].get("val_acc").as_f64(),
            Some(0.93)
        );
        assert!(rec.dir.join("result.json").exists());
    }

    #[test]
    fn engine_config_records_backend_identity() {
        let base = std::env::temp_dir().join("pdfa_run_test_engine");
        let rec = RunRecorder::create(&base, "unit").unwrap();
        rec.write_engine_config(
            "photonic",
            &Value::object(vec![("lr", Value::Number(0.01))]),
        )
        .unwrap();
        let doc =
            Value::parse(&std::fs::read_to_string(rec.dir.join("config.json")).unwrap())
                .unwrap();
        assert_eq!(doc.get("backend").as_str(), Some("photonic"));
        assert_eq!(doc.get("train").get("lr").as_f64(), Some(0.01));
    }
}
