//! The digital control system around the training loop.
//!
//! In the paper's architecture (§3, Fig. 4(b)) a digital controller fetches
//! the error vector from SRAM, drives the DACs, collects ADC results and
//! updates the network parameters. Here the equivalent roles are:
//!
//! * [`pipeline`] — a producer thread that assembles the next step's
//!   inputs (mini-batch gather + one-hot + analog-noise draws) while PJRT
//!   executes the current step — the SRAM-fetch/compute overlap
//! * [`metrics`]  — counters and timers (steps, MACs, wall time, per-phase
//!   latency) feeding the throughput numbers in the run reports
//! * [`run`]      — run directory management: config + history JSON,
//!   parameter checkpoints

pub mod metrics;
pub mod pipeline;
pub mod run;

pub use metrics::Metrics;
pub use pipeline::{BatchFeeder, StepInput};
pub use run::RunRecorder;
