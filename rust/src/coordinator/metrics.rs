//! Counters and timers for the training coordinator.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::util::json::Value;

/// Accumulated run metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    counters: BTreeMap<&'static str, u64>,
    timers: BTreeMap<&'static str, Duration>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn add(&mut self, key: &'static str, n: u64) {
        *self.counters.entry(key).or_default() += n;
    }

    pub fn count(&self, key: &str) -> u64 {
        self.counters.get(key).copied().unwrap_or(0)
    }

    /// Time a closure under `key`.
    pub fn time<T>(&mut self, key: &'static str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        *self.timers.entry(key).or_default() += t0.elapsed();
        out
    }

    pub fn add_time(&mut self, key: &'static str, d: Duration) {
        *self.timers.entry(key).or_default() += d;
    }

    /// Fold a telemetry delta into the counter set under stable keys
    /// (`macs`, `bank_macs`, `optical_cycles`, `bank_ops`). Energy is a
    /// float and stays in the run record's dedicated `telemetry` block
    /// rather than in these integer counters.
    pub fn add_telemetry(&mut self, t: &crate::telemetry::Telemetry) {
        self.add("macs", t.macs);
        self.add("bank_macs", t.photonic_macs);
        self.add("optical_cycles", t.cycles);
        self.add("bank_ops", t.bank_ops);
    }

    pub fn seconds(&self, key: &str) -> f64 {
        self.timers.get(key).map(|d| d.as_secs_f64()).unwrap_or(0.0)
    }

    /// Steps-per-second style rate for a counter over a timer.
    pub fn rate(&self, counter: &str, timer: &str) -> f64 {
        let s = self.seconds(timer);
        if s > 0.0 {
            self.count(counter) as f64 / s
        } else {
            0.0
        }
    }

    pub fn to_json(&self) -> Value {
        let mut pairs: Vec<(&str, Value)> = Vec::new();
        for (k, v) in &self.counters {
            pairs.push((k, Value::Number(*v as f64)));
        }
        for (k, v) in &self.timers {
            // timer keys suffixed to avoid clashing with counters
            pairs.push((k, Value::Number(v.as_secs_f64())));
        }
        Value::object(pairs)
    }

    pub fn summary_line(&self) -> String {
        let mut parts: Vec<String> = self
            .counters
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect();
        parts.extend(
            self.timers
                .iter()
                .map(|(k, v)| format!("{k}={:.2}s", v.as_secs_f64())),
        );
        parts.join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counting_and_timing() {
        let mut m = Metrics::new();
        m.add("steps", 3);
        m.add("steps", 2);
        assert_eq!(m.count("steps"), 5);
        assert_eq!(m.count("missing"), 0);
        let out = m.time("work", || {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(out, 42);
        assert!(m.seconds("work") >= 0.004);
        assert!(m.rate("steps", "work") > 0.0);
    }

    #[test]
    fn telemetry_folds_into_counters() {
        use crate::telemetry::Telemetry;
        let mut m = Metrics::new();
        let t = Telemetry {
            macs: 100,
            photonic_macs: 60,
            cycles: 7,
            bank_ops: 2,
            energy_j: 1e-9,
            ..Telemetry::default()
        };
        m.add_telemetry(&t);
        m.add_telemetry(&t);
        assert_eq!(m.count("macs"), 200);
        assert_eq!(m.count("bank_macs"), 120);
        assert_eq!(m.count("optical_cycles"), 14);
        assert_eq!(m.count("bank_ops"), 4);
    }

    #[test]
    fn json_and_summary() {
        let mut m = Metrics::new();
        m.add("macs", 1000);
        m.add_time("exec_s", Duration::from_millis(100));
        let j = m.to_json();
        assert_eq!(j.get("macs").as_f64(), Some(1000.0));
        assert!(j.get("exec_s").as_f64().unwrap() > 0.09);
        assert!(m.summary_line().contains("macs=1000"));
    }
}
