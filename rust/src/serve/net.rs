//! TCP front-end for the batched inference server, NDJSON wire format.
//!
//! Promotes [`crate::serve::Server`] from a stdin loop to a real
//! concurrent network service, hermetically on `std::net`:
//!
//! * **accept loop** — one listener thread hands each connection to a
//!   dedicated reader thread; a stop flag (budget exhausted or
//!   [`NetServer::stop`]) drains everything gracefully.
//! * **reader thread (per connection)** — reads newline-delimited JSON
//!   requests `{"x":[...]}` (optional `"id":N`), parses them with the
//!   zero-allocation [`json_stream`] codec into pooled buffers, and
//!   submits to the shared micro-batching queue. A bounded channel to
//!   the writer caps the connection's in-flight requests, so one greedy
//!   client saturates its own pipeline — not the server queue (whose
//!   `queue_cap` backpressure still bounds the sum over connections).
//! * **writer thread (per connection)** — pops tickets in submission
//!   order and writes replies `{"id":N,"pred":P,"logits":[...]}` (or
//!   `{"error":"..."}`), so replies are always in request order even
//!   though micro-batches complete out of order across workers. Reply
//!   buffers are recycled back to the reader, closing the
//!   allocation-free loop.
//!
//! A malformed line gets an in-order `{"error":...}` reply and the
//! connection stays up; an oversized line (> [`MAX_LINE_BYTES`]) or
//! non-UTF-8 input closes the connection after an error reply. Lines are
//! buffered until their newline arrives, so the cap is enforced after
//! the fact — this is a lab serving stack, not a hardened edge.
//!
//! The module also ships the client side: [`drive`] opens N real
//! sockets, pipelines deterministic requests over each, optionally
//! verifies every reply bit-exact against
//! [`crate::dfa::reference::forward`], and reports sustained req/s plus
//! latency percentiles — the loopback load generator behind
//! `pdfa serve --source tcp` and the `BENCH_SERVE.json` perf record.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, BufWriter, ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::server::{Server, Ticket};
use crate::dfa::reference;
use crate::tensor::Tensor;
use crate::util::benchx::{fmt_ns, fmt_si, BenchResult};
use crate::util::json_stream::{self, Lexer};
use crate::util::rng::Pcg64;
use crate::{Error, Result};

/// Reject request lines longer than this (16 MiB): a runaway client
/// can't grow a reader's line buffer without bound. Generous — an
/// MNIST-sized request is ~20 KiB.
pub const MAX_LINE_BYTES: usize = 16 << 20;

/// Stop-flag poll granularity in milliseconds — single source for
/// [`POLL_INTERVAL`] and the [`DRAIN_WINDOW`] derived from it.
const POLL_MILLIS: u64 = 50;

/// How long blocking reads wait before re-checking the stop flag; also
/// the accept loop's poll interval. Bounds shutdown latency for idle
/// connections: an idle front-end notices `stop()` within one interval
/// (regression-tested in `tests/integration_net.rs`).
pub const POLL_INTERVAL: Duration = Duration::from_millis(POLL_MILLIS);

/// Lingering-close drain window (20 poll intervals): after the final
/// reply the connection keeps discarding unread pipelined input for at
/// most this long before closing, so the peer's receive queue is never
/// RST away. Shares [`POLL_MILLIS`] with the read-timeout poll that
/// paces the drain loop.
pub const DRAIN_WINDOW: Duration = Duration::from_millis(20 * POLL_MILLIS);

/// Front-end sizing knobs (the queue policy lives in the [`Server`]).
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Per-connection in-flight request cap: the reader blocks once this
    /// many submissions await their reply on this connection.
    pub max_inflight: usize,
    /// Stop accepting after this many requests were accepted across all
    /// connections (0 = serve until [`NetServer::stop`]). Accepted means
    /// submitted to the queue: malformed and rejected lines don't count.
    pub max_requests: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig { max_inflight: 32, max_requests: 0 }
    }
}

/// Front-end counters, returned by [`NetServer::join`]/`shutdown`.
#[derive(Debug, Clone, Default)]
pub struct NetStats {
    /// Requests accepted into the queue (every one of these got a reply).
    pub accepted: u64,
    /// Lines answered with an error reply instead (parse/shape/submit).
    pub rejected: u64,
    /// Connections accepted over the front-end's lifetime.
    pub connections: u64,
}

/// The TCP front-end: accept loop + per-connection reader/writer pairs.
pub struct NetServer {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accepted: Arc<AtomicU64>,
    rejected: Arc<AtomicU64>,
    connections: Arc<AtomicU64>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl NetServer {
    /// Start serving `server` on `listener`. The listener is switched to
    /// non-blocking so the accept loop can notice the stop flag; accepted
    /// connections run blocking with a short read timeout for the same
    /// reason.
    pub fn start(
        server: Arc<Server>,
        listener: TcpListener,
        cfg: NetConfig,
    ) -> Result<NetServer> {
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let accepted = Arc::new(AtomicU64::new(0));
        let rejected = Arc::new(AtomicU64::new(0));
        let connections = Arc::new(AtomicU64::new(0));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let (server, cfg) = (server.clone(), cfg.clone());
            let (stop, accepted, rejected) =
                (stop.clone(), accepted.clone(), rejected.clone());
            let (connections, conns) = (connections.clone(), conns.clone());
            std::thread::Builder::new()
                .name("serve-accept".into())
                .spawn(move || {
                    accept_loop(
                        listener, server, cfg, stop, accepted, rejected, connections,
                        conns,
                    )
                })
                .map_err(Error::Io)?
        };
        Ok(NetServer {
            local_addr,
            stop,
            accepted,
            rejected,
            connections,
            accept: Some(accept),
            conns,
        })
    }

    /// The bound address (resolves port 0 to the actual port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Ask the front-end to stop: no new connections or requests are
    /// accepted; in-flight requests still get their replies.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }

    fn stats(&self) -> NetStats {
        NetStats {
            accepted: self.accepted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            connections: self.connections.load(Ordering::Relaxed),
        }
    }

    /// Block until the front-end stops — the request budget is reached
    /// or [`Self::stop`] is called — then join every connection thread.
    /// When this returns, every accepted request's reply has been
    /// written (graceful drain).
    pub fn join_all(mut self) -> NetStats {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        loop {
            let hs: Vec<_> = {
                let mut g = lock_conns(&self.conns);
                g.drain(..).collect()
            };
            if hs.is_empty() {
                break;
            }
            for h in hs {
                let _ = h.join();
            }
        }
        self.stats()
    }

    /// [`Self::stop`] + [`Self::join_all`].
    pub fn shutdown(self) -> NetStats {
        self.stop();
        self.join_all()
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in lock_conns(&self.conns).drain(..) {
            let _ = h.join();
        }
    }
}

/// Connection-registry lock, poison-proof: a panicking holder must not
/// wedge shutdown — the handle list (plain data) stays usable, so
/// `join`/`Drop` can still drain every connection (same recovery idiom
/// as `tensor::ops::CAP_SCOPE`).
fn lock_conns(
    conns: &Mutex<Vec<JoinHandle<()>>>,
) -> std::sync::MutexGuard<'_, Vec<JoinHandle<()>>> {
    conns.lock().unwrap_or_else(|p| p.into_inner())
}

// lint: thread-body
#[allow(clippy::too_many_arguments)]
fn accept_loop(
    listener: TcpListener,
    server: Arc<Server>,
    cfg: NetConfig,
    stop: Arc<AtomicBool>,
    accepted: Arc<AtomicU64>,
    rejected: Arc<AtomicU64>,
    connections: Arc<AtomicU64>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
) {
    let mut conn_id = 0u64;
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                conn_id += 1;
                connections.fetch_add(1, Ordering::Relaxed);
                let _ = stream.set_nodelay(true);
                // accepted sockets may inherit the listener's
                // non-blocking mode on some platforms; force blocking +
                // a read timeout so readers can see the stop flag
                let _ = stream.set_nonblocking(false);
                let ctx = ConnCtx {
                    server: server.clone(),
                    cfg: cfg.clone(),
                    stop: stop.clone(),
                    accepted: accepted.clone(),
                    rejected: rejected.clone(),
                };
                let spawned = std::thread::Builder::new()
                    .name(format!("serve-conn-{conn_id}"))
                    .spawn(move || ctx.run(stream));
                let mut g = lock_conns(&conns);
                if let Ok(h) = spawned {
                    g.push(h);
                }
                // reap finished connections so a long-lived server's
                // handle list stays proportional to live connections
                let mut i = 0;
                while i < g.len() {
                    // lint: guarded: loop condition pins i < g.len()
                    if g[i].is_finished() {
                        let _ = g.swap_remove(i).join();
                    } else {
                        i += 1;
                    }
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(POLL_INTERVAL);
            }
            Err(_) => break,
        }
    }
}

/// Work handed from a connection's reader to its writer, in request
/// order. The bounded channel carrying these IS the per-connection
/// in-flight cap.
enum ConnItem {
    /// A submitted request awaiting its reply.
    Pending(Ticket, Option<u64>),
    /// A line answered locally with an error (parse/shape/submit).
    Failed(String, Option<u64>),
}

struct ConnCtx {
    server: Arc<Server>,
    cfg: NetConfig,
    stop: Arc<AtomicBool>,
    accepted: Arc<AtomicU64>,
    rejected: Arc<AtomicU64>,
}

impl ConnCtx {
    /// Claim one unit of the global request budget; `false` once
    /// exhausted. Lock-free so concurrent readers can't overshoot
    /// `max_requests`.
    fn try_claim(&self) -> bool {
        if self.cfg.max_requests == 0 {
            self.accepted.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        let mut cur = self.accepted.load(Ordering::Relaxed);
        loop {
            if cur >= self.cfg.max_requests {
                return false;
            }
            match self.accepted.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(now) => cur = now,
            }
        }
    }

    /// Reader loop: owns the read half; the writer owns the write half
    /// and is joined before the connection closes, so every in-flight
    /// reply drains even when the reader stops first.
    // lint: thread-body
    // lint: hot-path
    fn run(self, stream: TcpStream) {
        let _ = stream.set_read_timeout(Some(POLL_INTERVAL));
        let write_half = match stream.try_clone() {
            Ok(s) => s,
            Err(_) => return,
        };
        let mut reader = BufReader::new(stream);
        let depth = self.cfg.max_inflight.max(1);
        let (work_tx, work_rx) = mpsc::sync_channel::<ConnItem>(depth);
        let (recycle_tx, recycle_rx) = mpsc::channel::<(Vec<f32>, Vec<f32>)>();
        let writer = std::thread::Builder::new()
            .name("serve-conn-writer".into())
            .spawn(move || writer_loop(write_half, work_rx, recycle_tx));
        let writer = match writer {
            Ok(h) => h,
            Err(_) => return,
        };

        let mut lexer = Lexer::new();
        let mut line = String::new();
        'conn: while !self.stop.load(Ordering::Relaxed) {
            line.clear();
            // a timeout leaves any partial line appended to `line`;
            // retrying without clearing completes it
            let n = loop {
                match reader.read_line(&mut line) {
                    Ok(n) => break n,
                    Err(e)
                        if matches!(
                            e.kind(),
                            ErrorKind::WouldBlock | ErrorKind::TimedOut
                        ) =>
                    {
                        if self.stop.load(Ordering::Relaxed) {
                            break 'conn;
                        }
                    }
                    Err(_) => break 'conn, // includes non-UTF-8 input
                }
            };
            if n == 0 {
                break; // clean EOF
            }
            if line.len() > MAX_LINE_BYTES {
                self.rejected.fetch_add(1, Ordering::Relaxed);
                let _ = work_tx.send(ConnItem::Failed(
                    // lint: allow(hot-path-alloc) — reject path closes the conn
                    format!("request line exceeds {MAX_LINE_BYTES} bytes"),
                    None,
                ));
                break;
            }
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            // pooled buffers: fresh allocations only until the pool
            // warms up to the pipeline depth
            let (mut x, out) = recycle_rx.try_recv().unwrap_or_default();
            match json_stream::parse_request(&mut lexer, trimmed, &mut x) {
                Ok(id) => {
                    if !self.try_claim() {
                        self.stop.store(true, Ordering::Relaxed);
                        break;
                    }
                    match self.server.submit_with(x, out) {
                        Ok(ticket) => {
                            if work_tx.send(ConnItem::Pending(ticket, id)).is_err() {
                                break;
                            }
                        }
                        Err(e) => {
                            // refund: the request never reached the queue
                            self.accepted.fetch_sub(1, Ordering::Relaxed);
                            self.rejected.fetch_add(1, Ordering::Relaxed);
                            if work_tx
                                .send(ConnItem::Failed(e.to_string(), id))
                                .is_err()
                            {
                                break;
                            }
                        }
                    }
                }
                Err(e) => {
                    self.rejected.fetch_add(1, Ordering::Relaxed);
                    if work_tx.send(ConnItem::Failed(e.to_string(), None)).is_err() {
                        break;
                    }
                }
            }
            if self.cfg.max_requests > 0
                && self.accepted.load(Ordering::Relaxed) >= self.cfg.max_requests
            {
                // budget reached: stop the whole front-end, drain below
                self.stop.store(true, Ordering::Relaxed);
                break;
            }
        }
        // dropping the work channel lets the writer drain remaining
        // replies and exit; joining it guarantees the drain finished
        drop(work_tx);
        let _ = writer.join();
        // lingering close: half-close the write side (FIN after the last
        // reply), then discard whatever the peer still has in flight
        // until it closes. Closing with unread pipelined input would RST
        // and could destroy replies still in the peer's receive queue.
        let _ = reader.get_ref().shutdown(std::net::Shutdown::Write);
        let mut scrap = [0u8; 4096];
        // lint: timing: bounds the lingering close, not a determinism path
        let deadline = Instant::now() + DRAIN_WINDOW;
        loop {
            use std::io::Read;
            match reader.get_mut().read(&mut scrap) {
                Ok(0) => break,
                Ok(_) => {}
                Err(e)
                    if matches!(
                        e.kind(),
                        ErrorKind::WouldBlock | ErrorKind::TimedOut
                    ) =>
                {
                    // lint: timing: drain-window check, see deadline above
                    if Instant::now() >= deadline {
                        break;
                    }
                }
                Err(_) => break,
            }
        }
    }
}

/// Writer loop: replies strictly in request order; flushes only when the
/// queue runs dry so pipelined bursts coalesce into one syscall.
// lint: thread-body
// lint: hot-path
fn writer_loop(
    stream: TcpStream,
    rx: mpsc::Receiver<ConnItem>,
    recycle: mpsc::Sender<(Vec<f32>, Vec<f32>)>,
) {
    let mut w = BufWriter::new(stream);
    let mut out = String::new();
    let mut next = rx.recv();
    while let Ok(item) = next {
        match item {
            ConnItem::Pending(ticket, id) => match ticket.wait_reply() {
                Ok(reply) => {
                    match &reply.result {
                        Ok(()) => {
                            let pred = argmax(&reply.logits);
                            json_stream::write_reply(&mut out, id, pred, &reply.logits);
                        }
                        Err(msg) => json_stream::write_error(&mut out, id, msg),
                    }
                    if w.write_all(out.as_bytes()).is_err() {
                        break;
                    }
                    let _ = recycle.send((reply.x, reply.logits));
                }
                Err(e) => {
                    json_stream::write_error(&mut out, id, &e.to_string());
                    if w.write_all(out.as_bytes()).is_err() {
                        break;
                    }
                }
            },
            ConnItem::Failed(msg, id) => {
                json_stream::write_error(&mut out, id, &msg);
                if w.write_all(out.as_bytes()).is_err() {
                    break;
                }
            }
        }
        match rx.try_recv() {
            Ok(item) => next = Ok(item),
            Err(mpsc::TryRecvError::Empty) => {
                if w.flush().is_err() {
                    break;
                }
                next = rx.recv();
            }
            Err(mpsc::TryRecvError::Disconnected) => {
                let _ = w.flush();
                break;
            }
        }
    }
}

// lint: thread-body
// lint: hot-path
fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        // lint: guarded: best is always a previously yielded index
        if v > xs[best] {
            best = i;
        }
    }
    best
}

// ---------------- loopback traffic driver (client side) ----------------

/// Load shape for [`drive`].
#[derive(Debug, Clone)]
pub struct TrafficConfig {
    /// Concurrent client connections (each its own OS thread + socket).
    pub clients: usize,
    /// Requests sent per connection.
    pub requests_per_client: usize,
    /// Pipeline depth per connection: requests in flight before the
    /// client blocks on the oldest reply.
    pub depth: usize,
    /// Feature width of generated requests (must match the checkpoint).
    pub d_in: usize,
    /// Master seed; each client derives its own deterministic stream.
    pub seed: u64,
}

/// Per-client tallies, merged into the [`TrafficReport`].
#[derive(Debug, Default)]
struct ClientStats {
    ok: u64,
    errors: u64,
    verified: u64,
    latencies_ns: Vec<f64>,
}

/// Aggregate result of one [`drive`] run.
#[derive(Debug)]
pub struct TrafficReport {
    /// Requests sent (clients × requests_per_client).
    pub sent: u64,
    /// Success replies.
    pub ok: u64,
    /// Error replies.
    pub errors: u64,
    /// Replies checked bit-exact against the reference forward.
    pub verified: u64,
    /// Wall time of the whole run (connect to last reply).
    pub wall_s: f64,
    /// Per-request latency (write to reply parsed), all clients merged.
    pub latency: BenchResult,
}

impl TrafficReport {
    /// Sustained request rate over the run.
    pub fn req_per_s(&self) -> f64 {
        self.ok as f64 / self.wall_s.max(1e-9)
    }

    /// Two-line human/machine-readable summary (mirrors
    /// [`super::ServeStats::report`]).
    pub fn report(&self) -> String {
        let mut line = format!(
            "tcp: {} ok / {} errors over {} in {:.3}s ({} req/s)",
            self.ok,
            self.errors,
            self.sent,
            self.wall_s,
            fmt_si(self.req_per_s()),
        );
        if !self.latency.samples_ns.is_empty() {
            line.push_str(&format!(
                "\nlatency: mean={} p50={} p95={} min={}",
                fmt_ns(self.latency.mean_ns()),
                fmt_ns(self.latency.p50_ns()),
                fmt_ns(self.latency.p95_ns()),
                fmt_ns(self.latency.min_ns()),
            ));
        }
        if self.verified > 0 {
            line.push_str(&format!(
                "\nverified: {} replies bit-exact vs the reference forward",
                self.verified
            ));
        }
        line
    }
}

/// Drive `cfg.clients` concurrent connections of deterministic traffic
/// against `addr`. With `verify`, every success reply is checked
/// bit-exact against [`reference::forward`] on the given parameters —
/// the end-to-end proof that JSON transport, micro-batching and
/// chunk padding never perturb a client's logits.
pub fn drive(
    addr: SocketAddr,
    cfg: &TrafficConfig,
    verify: Option<&[Tensor]>,
) -> Result<TrafficReport> {
    if cfg.clients == 0 || cfg.requests_per_client == 0 {
        return Err(Error::Config("traffic: clients and requests must be >= 1".into()));
    }
    // lint: timing: wall-clock throughput measurement (req/s)
    let start = Instant::now();
    let results: Vec<Result<ClientStats>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..cfg.clients)
            .map(|c| scope.spawn(move || client_run(addr, cfg, c, verify)))
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err(Error::msg("traffic: client thread panicked")))
            })
            .collect()
    });
    let wall_s = start.elapsed().as_secs_f64();
    let mut merged = ClientStats::default();
    for r in results {
        let s = r?;
        merged.ok += s.ok;
        merged.errors += s.errors;
        merged.verified += s.verified;
        merged.latencies_ns.extend(s.latencies_ns);
    }
    Ok(TrafficReport {
        sent: (cfg.clients * cfg.requests_per_client) as u64,
        ok: merged.ok,
        errors: merged.errors,
        verified: merged.verified,
        wall_s,
        latency: BenchResult {
            name: "tcp_request".into(),
            samples_ns: merged.latencies_ns,
            units_per_iter: None,
        },
    })
}

fn client_run(
    addr: SocketAddr,
    cfg: &TrafficConfig,
    client: usize,
    verify: Option<&[Tensor]>,
) -> Result<ClientStats> {
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut w = BufWriter::new(stream);
    // per-client deterministic stream: disjoint from every other client
    let mut rng = Pcg64::seed(
        cfg.seed ^ 0x9e37_79b9_7f4a_7c15u64.wrapping_mul(client as u64 + 1),
    );
    let total = cfg.requests_per_client as u64;
    let depth = cfg.depth.max(1);
    let mut pending: VecDeque<(u64, Instant, Vec<f32>)> = VecDeque::new();
    let mut line_out = String::new();
    let mut line_in = String::new();
    let mut logits = Vec::new();
    let mut errbuf = String::new();
    let mut lexer = Lexer::new();
    let mut stats = ClientStats::default();
    let mut next_id = 0u64;

    while next_id < total || !pending.is_empty() {
        while next_id < total && pending.len() < depth {
            let x: Vec<f32> =
                (0..cfg.d_in).map(|_| rng.uniform() as f32).collect();
            json_stream::write_request(&mut line_out, Some(next_id), &x);
            // lint: timing: per-request latency sample
            let t0 = Instant::now();
            w.write_all(line_out.as_bytes())?;
            pending.push_back((next_id, t0, x));
            next_id += 1;
        }
        w.flush()?;
        line_in.clear();
        if reader.read_line(&mut line_in)? == 0 {
            return Err(Error::msg(format!(
                "traffic client {client}: server closed with {} replies pending",
                pending.len()
            )));
        }
        let head =
            json_stream::parse_reply(&mut lexer, line_in.trim_end(), &mut logits, &mut errbuf)?;
        let (id, t0, x) = pending
            .pop_front()
            .ok_or_else(|| Error::msg("traffic: reply with nothing pending"))?;
        stats.latencies_ns.push(t0.elapsed().as_nanos() as f64);
        if head.is_error {
            stats.errors += 1;
            continue;
        }
        stats.ok += 1;
        // in-order replies are part of the wire contract: the echoed id
        // must be the oldest in-flight request's
        if head.id != Some(id) {
            return Err(Error::msg(format!(
                "traffic client {client}: reply id {:?}, expected {id} (ordering broken)",
                head.id
            )));
        }
        if let Some(params) = verify {
            let xt = Tensor::new(&[1, cfg.d_in], x)?;
            let want = reference::forward(params, &xt);
            if logits != want.logits.row(0) {
                return Err(Error::msg(format!(
                    "traffic client {client}: request {id} logits drifted from the \
                     reference forward"
                )));
            }
            stats.verified += 1;
        }
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_unbounded_and_pipelined() {
        let cfg = NetConfig::default();
        assert_eq!(cfg.max_requests, 0);
        assert!(cfg.max_inflight >= 1);
    }

    #[test]
    fn budget_claims_never_overshoot() {
        let ctx = ConnCtx {
            server: panic_free_server_stub(),
            cfg: NetConfig { max_inflight: 1, max_requests: 5 },
            stop: Arc::new(AtomicBool::new(false)),
            accepted: Arc::new(AtomicU64::new(0)),
            rejected: Arc::new(AtomicU64::new(0)),
        };
        let mut granted = 0;
        for _ in 0..20 {
            if ctx.try_claim() {
                granted += 1;
            }
        }
        assert_eq!(granted, 5);
        assert_eq!(ctx.accepted.load(Ordering::Relaxed), 5);
    }

    /// try_claim never touches the server, so a minimal real instance
    /// backs the stub.
    fn panic_free_server_stub() -> Arc<Server> {
        use crate::dfa::params::NetState;
        use crate::runtime::{NativeEngine, StepEngine};
        use crate::serve::ServeConfig;
        let engine: Arc<dyn StepEngine> = Arc::new(NativeEngine::new());
        let dims = engine.net_dims("tiny").unwrap();
        let state = NetState::init(&dims, &mut Pcg64::seed(1));
        Arc::new(
            Server::start(&engine, "tiny", state.params(), ServeConfig::default())
                .unwrap(),
        )
    }
}
