//! Batched inference serving — the photonic deployment's inference plane.
//!
//! The paper's headline case for photonics is massively parallel
//! *inference*: once trained, the MRR weight bank computes matrix-vector
//! products at line rate, so the economical way to serve traffic is to
//! coalesce many concurrent single-sample requests into the fixed-shape
//! batches the `fwd_<cfg>` artifact was traced for. This module is that
//! front end, digital-twin style:
//!
//! * [`batcher`] — a bounded request queue with dynamic micro-batching:
//!   flush on `max_batch` queued requests or when the oldest request has
//!   waited `max_wait` (the classic dynamic-batching policy), with
//!   backpressure on the submit side.
//! * [`server`]  — a worker pool; each worker owns a forward artifact
//!   loaded from the shared [`crate::runtime::StepEngine`] and executes
//!   micro-batches in `dims.batch`-sized chunks (zero-padded tail — row
//!   results are independent, so padding never changes a client's
//!   logits), then routes each row back to its requester and records
//!   per-request latency for the [`server::ServeStats`] report.
//! * [`net`]     — the concurrent TCP front-end: an accept loop plus a
//!   reader/writer thread pair per connection speaking newline-delimited
//!   JSON over real sockets, with per-connection in-flight caps feeding
//!   the queue's backpressure and in-order replies. The request hot path
//!   uses the [`crate::util::json_stream`] codec and recycles buffers
//!   through [`server::Ticket::wait_reply`], so steady-state serving
//!   performs no per-request heap allocation. Also home to the
//!   many-connection loopback traffic driver ([`net::drive`]) behind
//!   `pdfa serve --source tcp` and `BENCH_SERVE.json`.
//!
//! The [`server::ServeStats`] report pairs per-request latency with the
//! engine's hardware telemetry over the serving window (dispatch MACs
//! per request, and on the photonic backend the modeled §5 energy and
//! pJ/MAC — see [`crate::telemetry`]).
//!
//! The CLI front ends are `pdfa serve` (stdin / synthetic / TCP request
//! loops) and `pdfa infer` (batch inference over a checkpoint);
//! `benches/serve_throughput.rs` measures the stack end to end.

pub mod batcher;
pub mod net;
pub mod server;

pub use batcher::{BatchPolicy, FlushCause};
pub use net::{NetConfig, NetServer, NetStats, TrafficConfig, TrafficReport};
pub use server::{ServeConfig, ServeStats, Server, Ticket};
