//! Bounded request queue with dynamic micro-batching.
//!
//! Producers push single-sample requests; consumers (the
//! [`crate::serve::server`] workers) block on [`Queue::next_batch`], which
//! hands out micro-batches under the two classic flush triggers:
//!
//! * **full** — `max_batch` requests are queued, or
//! * **timeout** — the oldest queued request has waited `max_wait`.
//!
//! The queue is bounded at `queue_cap`: `push` blocks until space frees
//! up (backpressure), so a burst of clients cannot grow memory without
//! limit. Shutdown drains: workers keep receiving batches until the queue
//! is empty, so no accepted request is ever dropped.

use std::collections::VecDeque;
use std::sync::{mpsc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::{Error, Result};

/// Flush policy of the dynamic batcher.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Flush as soon as this many requests are queued. A flush goes to
    /// ONE worker, which executes it in artifact-batch-sized chunks; for
    /// burst traffic, keeping this at (or near) the network's traced
    /// batch dim lets multiple workers absorb a burst in parallel, while
    /// larger values trade pool parallelism for fewer flushes.
    pub max_batch: usize,
    /// Flush a partial batch once its oldest request has waited this long.
    pub max_wait: Duration,
    /// Bounded queue depth; [`Queue::push`] blocks when full.
    pub queue_cap: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            queue_cap: 256,
        }
    }
}

impl BatchPolicy {
    /// Reject degenerate policies before a queue is built around them.
    ///
    /// * `max_batch == 0` would make the full-flush trigger
    ///   (`q.len() >= max_batch`) always true, so `next_batch` would hand
    ///   out empty batches in a hot loop — every worker spinning at 100%
    ///   CPU while no request is ever served.
    /// * `max_wait == 0` degenerates the timeout trigger into a busy
    ///   poll: consumers flush one request at a time the instant it
    ///   arrives, so micro-batching never engages.
    /// * `queue_cap == 0` would deadlock every `push` on backpressure.
    pub fn validate(&self) -> Result<()> {
        if self.max_batch == 0 {
            return Err(Error::Config(
                "serve: max_batch must be >= 1 (0 would flush empty \
                 micro-batches in a hot loop)"
                    .into(),
            ));
        }
        if self.max_wait == Duration::ZERO {
            return Err(Error::Config(
                "serve: max_wait must be > 0 (a zero wait degenerates into \
                 a busy poll; use e.g. --max-wait-ms 1)"
                    .into(),
            ));
        }
        if self.queue_cap == 0 {
            return Err(Error::Config(
                "serve: queue_cap must be >= 1 (0 would block every push)".into(),
            ));
        }
        Ok(())
    }
}

/// Why a micro-batch was flushed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlushCause {
    /// `max_batch` requests were queued.
    Full,
    /// The oldest request aged past `max_wait`.
    Timeout,
    /// Shutdown drain of the remaining queue.
    Drain,
}

/// Response payload routed back to the submitting client.
///
/// Both buffers travel back with the reply so a recycling front-end
/// (the TCP reader/writer pair in [`crate::serve::net`]) can return
/// them to its pool — the zero-allocation hot path depends on `x` and
/// `logits` round-tripping instead of being dropped in the worker.
pub struct Reply {
    /// `Ok` when `logits` holds the forward result; `Err` carries a
    /// stringified server-side execution error.
    pub result: std::result::Result<(), String>,
    /// The request's input buffer, returned for reuse.
    pub x: Vec<f32>,
    /// Logits row (`d_out` values) on success; the untouched reply
    /// buffer on failure.
    pub logits: Vec<f32>,
}

/// One queued inference request.
pub struct Request {
    /// Input features, length `d_in`.
    pub x: Vec<f32>,
    /// Reply buffer: the worker clears and refills it with the logits
    /// row, so a client that recycles buffers pays no per-request
    /// allocation (first use grows it to `d_out` capacity, then it's
    /// warm).
    pub out: Vec<f32>,
    /// Oneshot reply channel back to the submitting client.
    pub tx: mpsc::Sender<Reply>,
    /// Enqueue time (latency accounting + the `max_wait` trigger).
    pub enqueued: Instant,
}

/// Flush counters, split by cause.
#[derive(Debug, Clone, Default)]
pub struct QueueStats {
    pub flush_full: u64,
    pub flush_timeout: u64,
    pub flush_drain: u64,
}

struct Inner {
    q: VecDeque<Request>,
    shutdown: bool,
    stats: QueueStats,
}

/// Recover the guard from a poisoned lock/condvar result. Every mutation
/// under [`Queue::inner`] is a single non-panicking statement (`push_back`,
/// `drain`, flag/counter writes), so a poisoning panic elsewhere in a
/// holder's frame cannot leave `Inner` half-updated — recovering is sound,
/// and it keeps one crashed connection thread from cascading panics into
/// every other producer and consumer of the queue.
fn recover<T>(r: std::result::Result<T, std::sync::PoisonError<T>>) -> T {
    r.unwrap_or_else(|p| p.into_inner())
}

/// The shared queue (one per [`crate::serve::Server`]).
pub struct Queue {
    policy: BatchPolicy,
    inner: Mutex<Inner>,
    /// Signals consumers: work arrived or shutdown.
    work: Condvar,
    /// Signals producers: space freed up or shutdown.
    space: Condvar,
}

impl Queue {
    /// Build a queue under `policy`. A degenerate policy (zero
    /// `max_batch`, `max_wait` or `queue_cap`) is a clean
    /// [`Error::Config`] instead of the panic (or, worse, the silent
    /// empty-batch hot spin) it used to be — see [`BatchPolicy::validate`].
    pub fn new(policy: BatchPolicy) -> Result<Queue> {
        policy.validate()?;
        Ok(Queue {
            policy,
            inner: Mutex::new(Inner {
                q: VecDeque::new(),
                shutdown: false,
                stats: QueueStats::default(),
            }),
            work: Condvar::new(),
            space: Condvar::new(),
        })
    }

    pub fn policy(&self) -> &BatchPolicy {
        &self.policy
    }

    /// Enqueue a request, blocking while the queue is at capacity.
    /// Errors once the queue has been shut down.
    pub fn push(&self, req: Request) -> Result<()> {
        let mut g = recover(self.inner.lock());
        while !g.shutdown && g.q.len() >= self.policy.queue_cap {
            g = recover(self.space.wait(g));
        }
        if g.shutdown {
            return Err(Error::msg("serve: queue is shut down"));
        }
        g.q.push_back(req);
        self.work.notify_one();
        Ok(())
    }

    /// Block until a micro-batch is ready under the flush policy. Returns
    /// `None` only after [`Self::shutdown`] once the queue is drained.
    pub fn next_batch(&self) -> Option<(Vec<Request>, FlushCause)> {
        let mut g = recover(self.inner.lock());
        loop {
            if g.q.len() >= self.policy.max_batch {
                g.stats.flush_full += 1;
                return Some((self.drain(&mut g), FlushCause::Full));
            }
            if g.shutdown {
                if g.q.is_empty() {
                    return None;
                }
                g.stats.flush_drain += 1;
                return Some((self.drain(&mut g), FlushCause::Drain));
            }
            match g.q.front() {
                Some(front) => {
                    let age = front.enqueued.elapsed();
                    if age >= self.policy.max_wait {
                        g.stats.flush_timeout += 1;
                        return Some((self.drain(&mut g), FlushCause::Timeout));
                    }
                    let (g2, _) = recover(
                        self.work.wait_timeout(g, self.policy.max_wait - age),
                    );
                    g = g2;
                }
                None => g = recover(self.work.wait(g)),
            }
        }
    }

    fn drain(&self, g: &mut Inner) -> Vec<Request> {
        let take = g.q.len().min(self.policy.max_batch);
        let out: Vec<Request> = g.q.drain(..take).collect();
        self.space.notify_all();
        out
    }

    /// Stop accepting requests and wake everyone; queued requests still
    /// drain through [`Self::next_batch`].
    pub fn shutdown(&self) {
        let mut g = recover(self.inner.lock());
        g.shutdown = true;
        self.work.notify_all();
        self.space.notify_all();
    }

    pub fn stats(&self) -> QueueStats {
        recover(self.inner.lock()).stats.clone()
    }

    pub fn len(&self) -> usize {
        recover(self.inner.lock()).q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(v: f32) -> (Request, mpsc::Receiver<Reply>) {
        let (tx, rx) = mpsc::channel();
        (Request { x: vec![v], out: Vec::new(), tx, enqueued: Instant::now() }, rx)
    }

    fn policy(max_batch: usize, max_wait_ms: u64, cap: usize) -> BatchPolicy {
        BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(max_wait_ms),
            queue_cap: cap,
        }
    }

    #[test]
    fn degenerate_policies_are_clean_config_errors() {
        // regression: max_batch 0 used to satisfy `q.len() >= max_batch`
        // unconditionally, flushing empty batches in a hot spin (and the
        // assert-based guard panicked instead of returning an error)
        for (max_batch, max_wait_ms, cap) in [(0, 5, 16), (4, 0, 16), (4, 5, 0)] {
            let p = BatchPolicy {
                max_batch,
                max_wait: Duration::from_millis(max_wait_ms),
                queue_cap: cap,
            };
            let err = Queue::new(p).map(|_| ()).unwrap_err().to_string();
            assert!(err.starts_with("config:"), "{err}");
        }
        BatchPolicy::default().validate().unwrap();
    }

    #[test]
    fn full_flush_takes_exactly_max_batch() {
        let q = Queue::new(policy(3, 10_000, 16)).unwrap();
        let mut rxs = Vec::new();
        for i in 0..5 {
            let (r, rx) = req(i as f32);
            q.push(r).unwrap();
            rxs.push(rx);
        }
        let (batch, cause) = q.next_batch().unwrap();
        assert_eq!(cause, FlushCause::Full);
        assert_eq!(batch.len(), 3);
        assert_eq!(batch[0].x, vec![0.0]); // FIFO order
        assert_eq!(q.len(), 2);
        q.shutdown();
        let (rest, cause) = q.next_batch().unwrap();
        assert_eq!(cause, FlushCause::Drain);
        assert_eq!(rest.len(), 2);
        assert!(q.next_batch().is_none());
    }

    #[test]
    fn timeout_flushes_partial_batch() {
        let q = Queue::new(policy(64, 5, 16)).unwrap();
        let (r, _rx) = req(1.0);
        let enqueued = r.enqueued;
        q.push(r).unwrap();
        let (batch, cause) = q.next_batch().unwrap();
        assert_eq!(cause, FlushCause::Timeout);
        assert_eq!(batch.len(), 1);
        // measured from the request's own enqueue stamp, so scheduler
        // delays between req() and push() can't fake an early flush
        assert!(
            enqueued.elapsed() >= Duration::from_millis(5),
            "{:?}",
            enqueued.elapsed()
        );
        assert_eq!(q.stats().flush_timeout, 1);
    }

    #[test]
    fn push_blocks_on_full_queue_until_drained() {
        let q = Arc::new(Queue::new(policy(2, 10_000, 2)).unwrap());
        for i in 0..2 {
            let (r, _rx) = req(i as f32);
            q.push(r).unwrap();
        }
        let q2 = q.clone();
        let pusher = std::thread::spawn(move || {
            let (r, _rx) = req(9.0);
            q2.push(r).unwrap(); // must block until a batch is taken
        });
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(q.len(), 2, "third push should still be blocked");
        let (batch, _) = q.next_batch().unwrap();
        assert_eq!(batch.len(), 2);
        pusher.join().unwrap();
        assert_eq!(q.len(), 1);
        q.shutdown();
    }

    #[test]
    fn push_after_shutdown_errors() {
        let q = Queue::new(policy(2, 1, 4)).unwrap();
        q.shutdown();
        let (r, _rx) = req(1.0);
        assert!(q.push(r).is_err());
        assert!(q.next_batch().is_none());
    }
}
