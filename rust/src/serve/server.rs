//! Worker pool executing micro-batches over forward artifacts.
//!
//! [`Server::start`] loads one `fwd_<cfg>` artifact per worker from the
//! shared [`StepEngine`] and pins the trained parameters into each
//! worker's reusable input slots, so a dispatch only writes the batch of
//! request rows and executes — no per-call parameter cloning. Micro-
//! batches larger than the artifact's traced batch dimension are split
//! into `dims.batch`-sized chunks; the ragged tail is zero-padded. Row
//! results of the forward pass are independent (GEMM + bias + ReLU act
//! row-wise), so padding and batch composition never change a client's
//! logits — `pdfa infer` output is bit-identical to
//! [`crate::dfa::reference::forward`] on the same parameters.

use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use super::batcher::{BatchPolicy, Queue, Reply, Request};
use crate::dfa::checkpoint::Checkpoint;
use crate::dfa::params::NetState;
use crate::runtime::{Artifact, StepEngine};
use crate::telemetry::Telemetry;
use crate::tensor::Tensor;
use crate::util::benchx::{fmt_ns, fmt_si, BenchResult};
use crate::{Error, Result};

/// Server sizing: worker count + the batcher's flush policy.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Forward-artifact replicas executing micro-batches concurrently.
    pub workers: usize,
    pub policy: BatchPolicy,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { workers: 2, policy: BatchPolicy::default() }
    }
}

/// Latency samples kept for the percentile report. Beyond this the
/// recorder switches to reservoir sampling (Algorithm R), so a
/// long-lived server's memory stays bounded while percentiles remain
/// an unbiased estimate over the whole run.
const LATENCY_RESERVOIR: usize = 65_536;

#[derive(Default)]
struct StatsInner {
    latencies_ns: Vec<f64>,
    /// Total latency observations (>= latencies_ns.len() once sampling).
    lat_seen: u64,
    /// LCG state driving the reservoir's replacement draws.
    lat_lcg: u64,
    completed: u64,
    failed: u64,
    batches: u64,
    fill_sum: u64,
    executes: u64,
}

impl StatsInner {
    fn record_latency(&mut self, ns: f64) {
        self.lat_seen += 1;
        if self.latencies_ns.len() < LATENCY_RESERVOIR {
            self.latencies_ns.push(ns);
            return;
        }
        // Algorithm R: keep with probability reservoir/seen
        self.lat_lcg = self
            .lat_lcg
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        let slot = (self.lat_lcg >> 33) % self.lat_seen;
        if (slot as usize) < LATENCY_RESERVOIR {
            self.latencies_ns[slot as usize] = ns;
        }
    }
}

/// Aggregate serving statistics (see [`ServeStats::report`]).
#[derive(Debug, Clone)]
pub struct ServeStats {
    /// Requests answered with logits.
    pub completed: u64,
    /// Requests answered with an execution error.
    pub failed: u64,
    /// Micro-batches flushed from the queue.
    pub batches: u64,
    /// Forward-artifact executions (>= batches: chunking).
    pub executes: u64,
    /// Mean requests per micro-batch.
    pub mean_fill: f64,
    pub flush_full: u64,
    pub flush_timeout: u64,
    pub flush_drain: u64,
    /// Seconds since the server started.
    pub wall_s: f64,
    /// Per-request latency samples (enqueue -> logits), benchx summary.
    /// Bounded at [`LATENCY_RESERVOIR`] samples via reservoir sampling,
    /// so long-lived servers report unbiased percentiles at fixed memory.
    pub latency: BenchResult,
    /// Hardware counters accrued by the engine since the server started:
    /// dispatch MACs (chunking pads the ragged tail, so padded rows are
    /// included — this is the hardware cost, not the useful work),
    /// optical cycles and modeled energy on the photonic backend.
    pub telemetry: Telemetry,
}

impl ServeStats {
    /// Two-line human/machine-readable summary.
    pub fn report(&self) -> String {
        let mut line = format!(
            "serve: {} ok / {} failed in {:.3}s ({} req/s) | {} micro-batches \
             (mean fill {:.2}), {} executes | flushes full/timeout/drain \
             {}/{}/{}",
            self.completed,
            self.failed,
            self.wall_s,
            fmt_si(self.completed as f64 / self.wall_s.max(1e-9)),
            self.batches,
            self.mean_fill,
            self.executes,
            self.flush_full,
            self.flush_timeout,
            self.flush_drain,
        );
        if !self.latency.samples_ns.is_empty() {
            line.push_str(&format!(
                "\nlatency: mean={} p50={} p95={} min={}",
                fmt_ns(self.latency.mean_ns()),
                fmt_ns(self.latency.p50_ns()),
                fmt_ns(self.latency.p95_ns()),
                fmt_ns(self.latency.min_ns()),
            ));
        }
        if self.completed > 0 && !self.telemetry.is_empty() {
            let t = &self.telemetry;
            line.push_str(&format!(
                "\nwork: {} MACs ({} MACs/req)",
                fmt_si(t.macs as f64),
                fmt_si(t.macs as f64 / self.completed as f64),
            ));
            if let Some(pj) = t.pj_per_mac() {
                use crate::telemetry::report::fmt_joules;
                line.push_str(&format!(
                    " | energy {} modeled ({}/req, {pj:.2} pJ/MAC)",
                    fmt_joules(t.energy_j),
                    fmt_joules(t.energy_j / self.completed as f64),
                ));
            }
            if t.recal_events > 0 {
                line.push_str(&format!(
                    " | recal {}x ({} cycles)",
                    t.recal_events, t.recal_cycles,
                ));
            }
        }
        line
    }
}

/// A submitted request's reply handle.
pub struct Ticket {
    rx: mpsc::Receiver<Reply>,
}

impl Ticket {
    /// Block until the request's logits (or the server's error) arrive.
    pub fn wait(self) -> Result<Vec<f32>> {
        match self.wait_reply()? {
            Reply { result: Ok(()), logits, .. } => Ok(logits),
            Reply { result: Err(msg), .. } => Err(Error::msg(format!("serve: {msg}"))),
        }
    }

    /// Block for the full [`Reply`], input buffer included — the
    /// buffer-recycling variant used by the TCP front-end
    /// ([`crate::serve::net`]) to keep the hot path allocation-free.
    pub fn wait_reply(self) -> Result<Reply> {
        self.rx
            .recv()
            .map_err(|_| Error::msg("serve: worker dropped the request"))
    }

    /// Non-blocking probe: `Some` once the reply has arrived (pipelined
    /// clients drain ready tickets between submissions). The reply is
    /// delivered exactly once — a `Some` here consumes it, and a later
    /// [`Self::wait`] would report the request as dropped.
    pub fn poll(&self) -> Option<Result<Vec<f32>>> {
        match self.rx.try_recv() {
            Ok(Reply { result: Ok(()), logits, .. }) => Some(Ok(logits)),
            Ok(Reply { result: Err(msg), .. }) => {
                Some(Err(Error::msg(format!("serve: {msg}"))))
            }
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(Err(Error::msg("serve: worker dropped the request")))
            }
        }
    }
}

/// The batched inference server.
pub struct Server {
    queue: Arc<Queue>,
    stats: Arc<Mutex<StatsInner>>,
    workers: Vec<JoinHandle<()>>,
    d_in: usize,
    d_out: usize,
    started: Instant,
    /// The engine whose telemetry window this server reports.
    engine: Arc<dyn StepEngine>,
    /// Engine telemetry when the server started; [`Self::stats`] reports
    /// the delta, so a shared engine never leaks earlier work in.
    tel_base: Telemetry,
}

impl Server {
    /// Start a worker pool serving `params` (the 6 leading tensors
    /// `[w1, b1, w2, b2, w3, b3]`; momentum slots are ignored if present)
    /// through `engine`'s `fwd_<config>` artifact.
    ///
    /// ```
    /// use std::sync::Arc;
    /// use photonic_dfa::dfa::params::NetState;
    /// use photonic_dfa::runtime::{NativeEngine, StepEngine};
    /// use photonic_dfa::serve::{ServeConfig, Server};
    /// use photonic_dfa::util::rng::Pcg64;
    ///
    /// let engine: Arc<dyn StepEngine> = Arc::new(NativeEngine::new());
    /// let dims = engine.net_dims("tiny").unwrap();
    /// let state = NetState::init(&dims, &mut Pcg64::seed(1));
    /// let server =
    ///     Server::start(&engine, "tiny", state.params(), ServeConfig::default()).unwrap();
    /// let logits = server.infer(vec![0.5; dims.d_in]).unwrap();
    /// assert_eq!(logits.len(), dims.d_out);
    /// let stats = server.shutdown();
    /// assert_eq!(stats.completed, 1);
    /// assert!(stats.telemetry.macs > 0); // the dispatch was counted
    /// ```
    pub fn start(
        engine: &Arc<dyn StepEngine>,
        config: &str,
        params: &[Tensor],
        cfg: ServeConfig,
    ) -> Result<Server> {
        let dims = engine.net_dims(config)?;
        let shapes = NetState::param_shapes(&dims);
        if params.len() < shapes.len() {
            return Err(Error::Shape(format!(
                "serve: need {} parameter tensors, got {}",
                shapes.len(),
                params.len()
            )));
        }
        for (i, (t, s)) in params.iter().zip(&shapes).enumerate() {
            if t.shape() != s.as_slice() {
                return Err(Error::Shape(format!(
                    "serve: parameter {i} has shape {:?}, config '{config}' \
                     wants {s:?}",
                    t.shape()
                )));
            }
        }
        // load every artifact replica before spawning anything, so a load
        // failure can't strand already-running workers
        let replicas: Result<Vec<_>> = (0..cfg.workers.max(1))
            .map(|_| engine.load(&format!("fwd_{config}")))
            .collect();
        let replicas = replicas?;
        let queue = Arc::new(Queue::new(cfg.policy.clone())?);
        let stats = Arc::new(Mutex::new(StatsInner::default()));
        let mut workers = Vec::new();
        for (w, fwd) in replicas.into_iter().enumerate() {
            let worker = WorkerCtx {
                fwd,
                params: params[..shapes.len()].to_vec(),
                batch: dims.batch,
                d_in: dims.d_in,
                queue: queue.clone(),
                stats: stats.clone(),
            };
            let spawned = std::thread::Builder::new()
                .name(format!("serve-worker-{w}"))
                .spawn(move || worker.run());
            match spawned {
                Ok(h) => workers.push(h),
                Err(e) => {
                    // unblock and reap the workers that did start
                    queue.shutdown();
                    for h in workers {
                        let _ = h.join();
                    }
                    return Err(Error::Io(e));
                }
            }
        }
        Ok(Server {
            queue,
            stats,
            workers,
            d_in: dims.d_in,
            d_out: dims.d_out,
            // lint: timing: anchors the wall_s throughput stat
            started: Instant::now(),
            engine: engine.clone(),
            tel_base: engine.telemetry(),
        })
    }

    /// [`Self::start`] from a loaded checkpoint, cross-checking that the
    /// engine's view of the config matches the checkpoint's dims.
    pub fn from_checkpoint(
        engine: &Arc<dyn StepEngine>,
        ckpt: &Checkpoint,
        cfg: ServeConfig,
    ) -> Result<Server> {
        let dims = engine.net_dims(&ckpt.config)?;
        if dims != ckpt.dims {
            return Err(Error::Config(format!(
                "checkpoint dims {:?} != engine's '{}' dims {dims:?}",
                ckpt.dims, ckpt.config
            )));
        }
        Self::start(engine, &ckpt.config, ckpt.state.params(), cfg)
    }

    pub fn d_in(&self) -> usize {
        self.d_in
    }

    pub fn d_out(&self) -> usize {
        self.d_out
    }

    /// Enqueue one sample (length `d_in`); blocks only on queue
    /// backpressure. The [`Ticket`] resolves to this sample's logits.
    pub fn submit(&self, x: Vec<f32>) -> Result<Ticket> {
        self.submit_with(x, Vec::new())
    }

    /// [`Self::submit`] with a recycled reply buffer: the worker clears
    /// and refills `out` with the logits row, and both buffers ride the
    /// [`Reply`] back through [`Ticket::wait_reply`] — after one warm-up
    /// round-trip per buffer pair, submitting costs no heap allocation
    /// beyond the oneshot reply channel.
    pub fn submit_with(&self, x: Vec<f32>, out: Vec<f32>) -> Result<Ticket> {
        if x.len() != self.d_in {
            // lint: allow(hot-path-alloc) — cold path, shape error
            return Err(Error::Shape(format!(
                "serve: request has {} features, network wants {}",
                x.len(),
                self.d_in
            )));
        }
        let (tx, rx) = mpsc::channel();
        // lint: timing: per-request latency sample, not a compute path
        self.queue.push(Request { x, out, tx, enqueued: Instant::now() })?;
        Ok(Ticket { rx })
    }

    /// Submit and wait: one-call inference for sequential clients.
    pub fn infer(&self, x: Vec<f32>) -> Result<Vec<f32>> {
        self.submit(x)?.wait()
    }

    /// Snapshot the serving statistics so far.
    pub fn stats(&self) -> ServeStats {
        let s = lock_stats(&self.stats);
        let q = self.queue.stats();
        ServeStats {
            completed: s.completed,
            failed: s.failed,
            batches: s.batches,
            executes: s.executes,
            mean_fill: if s.batches > 0 {
                s.fill_sum as f64 / s.batches as f64
            } else {
                0.0
            },
            flush_full: q.flush_full,
            flush_timeout: q.flush_timeout,
            flush_drain: q.flush_drain,
            wall_s: self.started.elapsed().as_secs_f64(),
            latency: BenchResult {
                name: "serve_latency".into(),
                samples_ns: s.latencies_ns.clone(),
                units_per_iter: None,
            },
            telemetry: self.engine.telemetry().delta(&self.tel_base),
        }
    }

    /// Drain the queue, stop the workers and return the final stats.
    /// Every request accepted before shutdown still gets its reply.
    pub fn shutdown(mut self) -> ServeStats {
        self.queue.shutdown();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        self.stats()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if !self.workers.is_empty() {
            self.queue.shutdown();
            for h in self.workers.drain(..) {
                let _ = h.join();
            }
        }
    }
}

/// Stats lock, poison-proof: the counters are plain data, so one
/// panicking holder must not wedge every other worker's bookkeeping or
/// the final [`Server::stats`] snapshot (same recovery idiom as
/// `tensor::ops::CAP_SCOPE`).
fn lock_stats(stats: &Mutex<StatsInner>) -> std::sync::MutexGuard<'_, StatsInner> {
    stats.lock().unwrap_or_else(|p| p.into_inner())
}

/// Per-worker state: one artifact replica + reusable input slots.
struct WorkerCtx {
    fwd: Arc<dyn Artifact>,
    params: Vec<Tensor>,
    batch: usize,
    d_in: usize,
    queue: Arc<Queue>,
    stats: Arc<Mutex<StatsInner>>,
}

impl WorkerCtx {
    // lint: thread-body
    fn run(self) {
        // input layout of fwd_<cfg>: [w1, b1, w2, b2, w3, b3, x]; the x
        // slot is rewritten per chunk, parameters stay in place.
        let mut inputs = self.params.clone();
        inputs.push(Tensor::zeros(&[self.batch, self.d_in]));
        let xi = inputs.len() - 1;
        while let Some((mut reqs, _cause)) = self.queue.next_batch() {
            let total = reqs.len() as u64;
            let mut executes = 0u64;
            // process (and drain) the micro-batch front-chunk by
            // front-chunk: requests are moved out so their buffers can
            // ride the Reply back to the client for recycling
            while !reqs.is_empty() {
                let n = reqs.len().min(self.batch);
                // lint: guarded: xi indexes the x slot pushed above
                let x = &mut inputs[xi];
                for (i, r) in reqs.iter().take(n).enumerate() {
                    x.row_mut(i).copy_from_slice(&r.x);
                }
                // zero only the ragged tail: full chunks overwrite every
                // row, and row results are independent anyway
                for i in n..self.batch {
                    x.row_mut(i).fill(0.0);
                }
                match self.fwd.execute(&inputs) {
                    Ok(out) => {
                        executes += 1;
                        // lint: timing: completion stamp for latency stats
                        let done = Instant::now();
                        // lint: guarded: artifact contract — >= 1 output
                        let logits = &out[0];
                        let mut s = lock_stats(&self.stats);
                        for (i, r) in reqs.drain(..n).enumerate() {
                            let Request { x, mut out, tx, enqueued } = r;
                            out.clear();
                            out.extend_from_slice(logits.row(i));
                            let _ = tx.send(Reply { result: Ok(()), x, logits: out });
                            s.record_latency((done - enqueued).as_nanos() as f64);
                            s.completed += 1;
                        }
                    }
                    Err(e) => {
                        let msg = e.to_string();
                        let mut s = lock_stats(&self.stats);
                        for r in reqs.drain(..n) {
                            let Request { x, out, tx, .. } = r;
                            let _ = tx.send(Reply {
                                result: Err(msg.clone()),
                                x,
                                logits: out,
                            });
                            s.failed += 1;
                        }
                    }
                }
            }
            let mut s = lock_stats(&self.stats);
            s.batches += 1;
            s.fill_sum += total;
            s.executes += executes;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfa::reference;
    use crate::runtime::manifest::NetDims;
    use crate::runtime::NativeEngine;
    use crate::util::rng::Pcg64;
    use std::time::Duration;

    fn engine() -> Arc<dyn StepEngine> {
        Arc::new(NativeEngine::new())
    }

    fn tiny_params(seed: u64) -> (NetDims, NetState) {
        let dims = NetDims { d_in: 16, d_h1: 32, d_h2: 32, d_out: 4, batch: 8 };
        let mut rng = Pcg64::seed(seed);
        let state = NetState::init(&dims, &mut rng);
        (dims, state)
    }

    fn cfg(max_batch: usize, max_wait_ms: u64) -> ServeConfig {
        ServeConfig {
            workers: 2,
            policy: BatchPolicy {
                max_batch,
                max_wait: Duration::from_millis(max_wait_ms),
                queue_cap: 64,
            },
        }
    }

    #[test]
    fn single_request_matches_reference_forward() {
        let engine = engine();
        let (dims, state) = tiny_params(3);
        let server = Server::start(&engine, "tiny", state.params(), cfg(4, 1)).unwrap();
        let mut rng = Pcg64::seed(9);
        let x: Vec<f32> = (0..dims.d_in).map(|_| rng.uniform() as f32).collect();
        let got = server.infer(x.clone()).unwrap();

        let xt = Tensor::new(&[1, dims.d_in], x).unwrap();
        let want = reference::forward(state.params(), &xt);
        assert_eq!(got, want.logits.row(0));
        let stats = server.shutdown();
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.failed, 0);
        assert_eq!(stats.latency.samples_ns.len(), 1);
        // telemetry window: one fwd_tiny dispatch = 8·1664 = 13312 MACs
        // (the traced batch is the dispatch cost, padding included)
        assert_eq!(stats.telemetry.macs, 13_312);
        assert_eq!(stats.telemetry.cycles, 0); // digital backend
        assert!(stats.report().contains("MACs/req"), "{}", stats.report());
    }

    #[test]
    fn oversized_micro_batch_chunks_and_stays_exact() {
        let engine = engine();
        let (dims, state) = tiny_params(5);
        // max_batch 20 > dims.batch 8 forces 3 chunks (8 + 8 + 4)
        let server =
            Server::start(&engine, "tiny", state.params(), cfg(20, 10_000)).unwrap();
        let mut rng = Pcg64::seed(11);
        let xs: Vec<Vec<f32>> = (0..20)
            .map(|_| (0..dims.d_in).map(|_| rng.uniform() as f32).collect())
            .collect();
        let tickets: Vec<Ticket> =
            xs.iter().map(|x| server.submit(x.clone()).unwrap()).collect();
        for (x, t) in xs.iter().zip(tickets) {
            let got = t.wait().unwrap();
            let xt = Tensor::new(&[1, dims.d_in], x.clone()).unwrap();
            let want = reference::forward(state.params(), &xt);
            assert_eq!(got, want.logits.row(0));
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 20);
        assert!(stats.executes >= 3, "{}", stats.executes);
        assert!(stats.report().contains("serve:"));
        // every execute is one fwd_tiny dispatch: MACs track executes
        assert_eq!(stats.telemetry.macs, stats.executes * 13_312);
    }

    #[test]
    fn rejects_bad_requests_and_params() {
        let engine = engine();
        let (_, state) = tiny_params(7);
        // wrong parameter shapes
        assert!(Server::start(&engine, "small", state.params(), cfg(4, 1)).is_err());
        // too few tensors
        assert!(Server::start(&engine, "tiny", &state.tensors[..3], cfg(4, 1)).is_err());
        // unknown config
        assert!(Server::start(&engine, "nope", state.params(), cfg(4, 1)).is_err());

        let server = Server::start(&engine, "tiny", state.params(), cfg(4, 1)).unwrap();
        assert!(server.submit(vec![0.0; 3]).is_err()); // wrong width
        assert_eq!(server.d_in(), 16);
        assert_eq!(server.d_out(), 4);
        drop(server); // Drop shuts down cleanly with requests never sent
    }

    #[test]
    fn ticket_poll_consumes_the_reply_exactly_once() {
        let engine = engine();
        let (dims, state) = tiny_params(17);
        let server = Server::start(&engine, "tiny", state.params(), cfg(1, 1)).unwrap();
        let ticket = server.submit(vec![0.25; dims.d_in]).unwrap();
        let logits = loop {
            if let Some(r) = ticket.poll() {
                break r.unwrap();
            }
            std::thread::sleep(Duration::from_millis(1));
        };
        assert_eq!(logits.len(), dims.d_out);
        // pinned semantics: the oneshot delivers exactly once — after a
        // consuming poll, both poll and wait report the request dropped
        match ticket.poll() {
            Some(Err(e)) => assert!(e.to_string().contains("dropped"), "{e}"),
            other => panic!("poll after consume must report dropped, got {other:?}"),
        }
        assert!(ticket.wait().unwrap_err().to_string().contains("dropped"));
        server.shutdown();
    }

    #[test]
    fn submit_with_round_trips_both_buffers() {
        let engine = engine();
        let (dims, state) = tiny_params(19);
        let server = Server::start(&engine, "tiny", state.params(), cfg(1, 1)).unwrap();
        let x: Vec<f32> = (0..dims.d_in).map(|j| j as f32 * 0.01).collect();
        let want = {
            let xt = Tensor::new(&[1, dims.d_in], x.clone()).unwrap();
            reference::forward(state.params(), &xt).logits.row(0).to_vec()
        };
        // recycle the same pair of buffers through several requests: the
        // input comes back untouched, the reply buffer holds the logits,
        // and neither regrows once warm
        let mut xbuf = x.clone();
        let mut obuf = Vec::new();
        let mut caps = (0, 0);
        for round in 0..4 {
            let reply = server
                .submit_with(std::mem::take(&mut xbuf), std::mem::take(&mut obuf))
                .unwrap()
                .wait_reply()
                .unwrap();
            assert!(reply.result.is_ok());
            assert_eq!(reply.x, x, "input buffer must ride back unchanged");
            assert_eq!(reply.logits, want);
            xbuf = reply.x;
            obuf = reply.logits;
            if round == 1 {
                caps = (xbuf.capacity(), obuf.capacity());
            } else if round > 1 {
                assert_eq!((xbuf.capacity(), obuf.capacity()), caps);
            }
        }
        assert_eq!(server.shutdown().completed, 4);
    }

    #[test]
    fn drifting_photonic_serve_is_exact_within_a_calibration_epoch() {
        use crate::photonics::drift::DRIFT_TICK_CYCLES;
        use crate::runtime::photonic::{PhotonicEngine, PhysicsConfig};

        let dir = std::env::temp_dir().join("pdfa_no_artifacts_here");
        let (dims, state) = tiny_params(23);
        let x: Vec<f32> =
            (0..dims.d_in).map(|j| (j as f32 * 0.07).sin() * 0.5).collect();
        // drift of 0.01 rad/√tick is ~1.2 in weight units on the high-
        // finesse flank — far over the 0.05 threshold at every tick
        let serve = |threshold: f64| {
            let phys = PhysicsConfig {
                bank_rows: 16,
                bank_cols: 12,
                drift_rate: 0.01,
                recal_threshold: threshold,
                ..PhysicsConfig::ideal()
            };
            let engine: Arc<dyn StepEngine> =
                Arc::new(PhotonicEngine::open(&dir, phys).unwrap());
            let server = Server::start(
                &engine,
                "tiny",
                state.params(),
                ServeConfig { workers: 1, ..cfg(1, 1) },
            )
            .unwrap();
            (engine, server)
        };

        // scheduler OFF (threshold unreachably high): replies are bit-
        // exact only while the device stays inside one calibration epoch
        let (engine, server) = serve(1e9);
        let r0 = server.infer(x.clone()).unwrap();
        let per_exec = engine.telemetry().cycles;
        assert!(per_exec > 0, "photonic serve must fire optical cycles");
        let mut in_epoch = 0;
        while engine.telemetry().cycles + per_exec < DRIFT_TICK_CYCLES {
            assert_eq!(
                server.infer(x.clone()).unwrap(),
                r0,
                "replies inside the first drift tick must be bit-exact"
            );
            in_epoch += 1;
        }
        assert!(in_epoch > 0, "bank too slow: no request fit in one tick");
        let mut last = r0.clone();
        for _ in 0..200 {
            last = server.infer(x.clone()).unwrap();
            if engine.telemetry().cycles >= 2 * DRIFT_TICK_CYCLES {
                break;
            }
        }
        assert_ne!(last, r0, "uncompensated drift must move the logits");
        let stats = server.shutdown();
        assert_eq!(stats.telemetry.recal_events, 0);
        assert!(!stats.report().contains("recal"), "{}", stats.report());

        // scheduler ON: every tick crosses the threshold, so the device is
        // recalibrated before each dispatch and all replies match the
        // freshly calibrated logits — including across epochs
        let (engine, server) = serve(0.05);
        let first = server.infer(x.clone()).unwrap();
        assert_eq!(first, r0, "fresh calibration must match the other bank");
        for i in 0..200 {
            let r = server.infer(x.clone()).unwrap();
            assert_eq!(r, r0, "recalibrated reply {i} diverged");
            if engine.telemetry().cycles >= 3 * DRIFT_TICK_CYCLES {
                break;
            }
        }
        assert!(
            engine.telemetry().cycles >= 3 * DRIFT_TICK_CYCLES,
            "soak did not cross enough drift ticks"
        );
        let stats = server.shutdown();
        assert!(stats.failed == 0 && stats.telemetry.recal_events >= 2);
        assert!(stats.telemetry.recal_cycles > 0);
        assert!(stats.report().contains("recal"), "{}", stats.report());
    }

    #[test]
    fn from_checkpoint_round_trips_params() {
        let engine = engine();
        let (dims, state) = tiny_params(13);
        let ckpt = Checkpoint {
            config: "tiny".into(),
            dims: dims.clone(),
            epoch: 0,
            total_steps: 0,
            seed: 13,
            protocol: String::new(), // inference never checks the protocol
            rng: Pcg64::seed(13),
            state: state.clone(),
            device: None,
        };
        let server = Server::from_checkpoint(&engine, &ckpt, cfg(4, 1)).unwrap();
        let x = vec![0.5f32; dims.d_in];
        let got = server.infer(x.clone()).unwrap();
        let xt = Tensor::new(&[1, dims.d_in], x).unwrap();
        assert_eq!(got, reference::forward(state.params(), &xt).logits.row(0));

        // dims mismatch rejected
        let mut bad = ckpt;
        bad.dims.d_h1 = 64;
        assert!(Server::from_checkpoint(&engine, &bad, cfg(4, 1)).is_err());
    }
}
