//! The photonic step engine: in-situ training on the device-level MRR
//! weight bank.
//!
//! [`PhotonicEngine`] is the third [`crate::runtime::StepEngine`] backend
//! (`--backend photonic`). It serves the same artifact vocabulary as the
//! native and PJRT engines, but routes every matvec/GEMM of the training
//! step through the simulated silicon-photonic substrate, the way the
//! paper's architecture executes them in hardware:
//!
//! * [`crate::gemm::tiler::Tiling`] partitions each weight matrix onto
//!   bank-sized tiles;
//! * every tile is inscribed into a [`WeightBank`] once per dispatch and
//!   snapshotted, so the inscription cost is amortised across all batch
//!   rows (the §5 analog weight memory — [`WeightBank::snapshot`] /
//!   [`WeightBank::eval`]);
//! * channel amplitudes pass through the DAC quantiser; signed values use
//!   differential e⁺/e⁻ encoding (two optical cycles);
//! * row outputs return through the BPD + TIA chain and are digitised by
//!   the ADC quantiser before the digital rescale; the configured read
//!   noise σ additionally degrades the *gradient* readouts (see
//!   [`PhysicsConfig::sigma`] for why the forward pass is exempt).
//!
//! Artifact routing: `fwd_<cfg>` runs all three layer GEMMs on the bank;
//! `dfa_step_<cfg>` additionally computes the feedback projections
//! `B(k) · e` on the bank with the per-sample g′(a) mask applied as TIA
//! gains (Eq. 1 end-to-end in analog), while loss and the SGD update stay
//! digital, exactly as in the paper. `apply_grads_<cfg>` (pure digital
//! update) and `photonic_matvec` (already the raw MRR kernel) delegate to
//! the native engine; `bp_step_<cfg>` is refused — the photonic
//! architecture trains with DFA.
//!
//! Sharing contract: each [`StepEngine::load`] call builds an artifact
//! with its *own* bank behind a `Mutex`, so worker-pool replicas (one
//! `load` per worker, as the serve pool does) never contend, and the
//! artifacts satisfy the same `Send + Sync` bound as the native ones.
//! Hardware-in-the-loop precedent: Launay et al., arXiv:2006.01475; Pai
//! et al., arXiv:2205.08501.
//!
//! Execution model (the wavelength-parallel hot path): every dispatch has
//! a short *sequential* phase — inscribe each bank-sized tile once and
//! snapshot it (the §5 analog weight memory) — followed by a *row-parallel*
//! phase in which the batch rows drive the snapshotted tiles through the
//! read-only [`WeightBank::eval_into`] chain, sharded across a
//! `std::thread::scope` worker pool ([`PhotonicEngine::open_threaded`],
//! CLI `--threads`). Results are **bit-identical at any thread count**:
//! each batch row draws its read noise from a counter-keyed stream
//! ([`Pcg64::keyed`] over `(device seed, bank-op counter, row)`), a pure
//! function of the row's index rather than of scheduling order, and a
//! row's outputs accumulate in a fixed tile order. The bank-op counter
//! and the optical-cycle tally live in atomics, so [`PhotonicArtifact::cycles`]
//! never takes the bank lock.
//!
//! Device lifetime: the engine owns one [`DriftModel`] (thermal phase
//! walk + calibration aging, advanced in *device time* — ticks of
//! [`DRIFT_TICK_CYCLES`] telemetry cycles, never wall-clock) shared by
//! all of its artifacts. Every dispatch advances it under the dispatcher
//! lock, loads the drifted phases into the bank, and lets the online
//! recalibration scheduler re-run the §4 calibration protocol when the
//! estimated weight error crosses `--physics drift:recal`; the
//! recalibration readout cycles are priced by the same §5 energy model,
//! so `pdfa report` shows the true lifetime cost.
//! [`StepEngine::device_state`] serializes drift state + telemetry
//! tallies + the bank-op sequence, which is what makes a resumed
//! drifting run bit-identical to an uninterrupted one
//! (`tests/integration_drift.rs`).
//!
//! All per-dispatch state — the tile staging tensor, the inscription
//! snapshot pool, the tiling plans, the row-worker buffers — lives in a
//! reusable [`BankDispatcher`], so a steady-state dispatch performs zero
//! heap allocations on the single-threaded path (`tests/alloc_photonic.rs`
//! enforces this under a counting global allocator). Its speed is a
//! tracked deliverable: `cargo bench --bench photonic_step -- --json
//! BENCH_STEP.json` records the per-dfa-step trajectory (with 1/2/4/all
//! thread-scaling rows) that CI commits on main pushes.

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::dfa::reference;
use crate::energy::{EnergyModel, MrrTuning};
use crate::gemm::tiler::Tiling;
use crate::photonics::converters::Quantizer;
use crate::photonics::drift::{DriftModel, FaultEvent, DRIFT_TICK_CYCLES};
use crate::photonics::mrr::MrrDesign;
use crate::photonics::weight_bank::{BankConfig, BpdMode, Inscription, WeightBank};
use crate::runtime::manifest::{ArtifactSpec, NetDims};
use crate::runtime::native::NativeEngine;
use crate::runtime::step_engine::{Artifact, StepEngine};
use crate::telemetry::{self, Counters, Telemetry};
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;
use crate::{Error, Result};

/// Physical configuration of the simulated photonic substrate.
///
/// Threaded from the CLI (`--physics`) through
/// [`crate::dfa::config::TrainConfig`] (where it joins the checkpoint
/// protocol string) into the engine. `Copy` on purpose: it rides inside
/// [`crate::runtime::Backend::Photonic`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PhysicsConfig {
    /// Weight-bank geometry (paper headline: 50 × 20).
    pub bank_rows: usize,
    pub bank_cols: usize,
    /// Input DAC resolution in bits; 0 = transparent (ideal source).
    pub dac_bits: u32,
    /// Readout ADC resolution in bits; 0 = analog readout.
    pub adc_bits: u32,
    /// Additive read noise std in the normalised output domain, applied
    /// per optical cycle on the *gradient* readouts `B(k) · e` — the
    /// lumped σ of Fig. 5, injected exactly where the Gaussian reference
    /// model injects it: at the balanced photodetector, before the TIA,
    /// so the g′(a) gain mask gates it (a dead-ReLU row reads exactly
    /// zero, as in `reference::dfa_gradient`) and before the ADC. Forward
    /// inference readouts carry the converter quantisation but not this
    /// σ: the paper's training experiments degrade Eq. (1)'s analog
    /// product, and DFA's robustness to that noise is the claim under
    /// test.
    pub sigma: f64,
    /// Model inter-channel WDM crosstalk (3.4-linewidth grid) or space the
    /// channels wide enough that leakage is negligible.
    pub crosstalk: bool,
    /// `true`: inscribe tiles through calibration LUT + feedback locking
    /// (residual lock error, phase-jitter sensitivity). `false`: the
    /// perfect-calibration limit ([`WeightBank::inscribe_exact`]).
    pub lock: bool,
    /// Thermal drift: per-ring phase random-walk amplitude in
    /// radians/√tick of device time (`drift:rate`). 0 = thermally
    /// stable bank (the pre-lifetime engine behaviour).
    pub drift_rate: f64,
    /// Calibration aging: deterministic per-tick phase creep along a
    /// per-calibration-epoch direction (`drift:aging`). 0 = the stored
    /// LUT inverses never decay.
    pub drift_aging: f64,
    /// Online recalibration threshold on the telemetry-estimated weight
    /// error (`drift:recal`). 0 disables the scheduler, so drift
    /// accumulates unchecked — the ablation arm of
    /// `tests/integration_drift.rs`.
    pub recal_threshold: f64,
    /// Device seed: fabrication offsets + intrinsic noise streams.
    pub seed: u64,
}

/// Default `drifty`-preset thermal walk amplitude (radians/√tick).
/// With the high-finesse ring design's flank slope (≈ 117 weight/rad,
/// [`crate::photonics::drift::weight_slope`]) the walk's rms weight error
/// is ≈ 0.0117·√ticks, crossing [`RECAL_THRESHOLD_DEFAULT`] after ~18
/// ticks (~18k optical cycles): a training run re-locks every few dozen
/// steps, the cadence of the continuously locked testbeds (refs 34–36).
pub const DRIFT_RATE_DEFAULT: f64 = 1e-4;

/// Default `drifty`-preset calibration-aging creep (radians/tick):
/// negligible between recalibrations, but ≈ 0.23 weight error after
/// 1000 unrecalibrated ticks — the slow decay that ruins the ablation
/// arm with the scheduler off.
pub const DRIFT_AGING_DEFAULT: f64 = 2e-6;

/// Default `drifty`-preset scheduler threshold on the estimated weight
/// error (≈ half the §4 lock tolerance budget over a 50-ring column).
pub const RECAL_THRESHOLD_DEFAULT: f64 = 0.05;

impl Default for PhysicsConfig {
    fn default() -> Self {
        Self::paper()
    }
}

impl PhysicsConfig {
    /// The ideal preset: perfectly calibrated bank, transparent
    /// converters, zero noise, no crosstalk. Must reproduce
    /// [`NativeEngine`] logits within [`IDEAL_LOGIT_TOL`] (the residual is
    /// pure f32⇄f64 accumulation-order rounding of the tiled analog path).
    pub fn ideal() -> PhysicsConfig {
        PhysicsConfig {
            bank_rows: crate::photonics::constants::BANK_ROWS,
            bank_cols: crate::photonics::constants::BANK_COLS,
            dac_bits: 0,
            adc_bits: 0,
            sigma: 0.0,
            crosstalk: false,
            lock: false,
            drift_rate: 0.0,
            drift_aging: 0.0,
            recal_threshold: 0.0,
            seed: 7,
        }
    }

    /// The paper's §4/§5 operating point: 50 × 20 bank, 12-bit DAC
    /// (Alphacore D12B10G), 6-bit ADC (A6B12G), the off-chip-BPD lumped
    /// read noise σ ≈ 0.098, dense 3.4-linewidth WDM grid, feedback-locked
    /// inscription.
    pub fn paper() -> PhysicsConfig {
        PhysicsConfig {
            bank_rows: crate::photonics::constants::BANK_ROWS,
            bank_cols: crate::photonics::constants::BANK_COLS,
            dac_bits: 12,
            adc_bits: 6,
            sigma: crate::photonics::constants::SIGMA_OFFCHIP_BPD,
            crosstalk: true,
            lock: true,
            drift_rate: 0.0,
            drift_aging: 0.0,
            recal_threshold: 0.0,
            seed: 7,
        }
    }

    /// The `drifty` preset: the paper operating point on a device that
    /// ages — default thermal walk, LUT decay, and an armed
    /// recalibration scheduler. The `static` preset is the explicit
    /// alias for [`Self::paper`], which models a freshly calibrated,
    /// thermally stable bank.
    pub fn drifty() -> PhysicsConfig {
        PhysicsConfig {
            drift_rate: DRIFT_RATE_DEFAULT,
            drift_aging: DRIFT_AGING_DEFAULT,
            recal_threshold: RECAL_THRESHOLD_DEFAULT,
            ..Self::paper()
        }
    }

    /// Canonical string form: stable, value-complete, used both for
    /// display and inside [`crate::dfa::config::TrainConfig::protocol_string`]
    /// (f64 prints in shortest round-trip form, so string equality is
    /// value equality).
    pub fn describe(&self) -> String {
        format!(
            "bank={}x{};dac={};adc={};sigma={};xtalk={};lock={};seed={};\
             drift={};aging={};recal={}",
            self.bank_rows,
            self.bank_cols,
            self.dac_bits,
            self.adc_bits,
            self.sigma,
            if self.crosstalk { "on" } else { "off" },
            if self.lock { "on" } else { "off" },
            self.seed,
            self.drift_rate,
            self.drift_aging,
            self.recal_threshold,
        )
    }

    /// Parse the `--physics` CLI value: a preset name (`ideal` | `paper`
    /// | `static` | `drifty`) optionally followed by comma-separated
    /// `key=value` overrides, e.g.
    /// `drifty,dac=6,sigma=0.05,drift:rate=2e-4,drift:recal=0.03`.
    pub fn parse(s: &str) -> Result<PhysicsConfig> {
        let mut parts = s.split(',');
        let head = parts.next().unwrap_or("").trim();
        let mut cfg = match head {
            "ideal" => Self::ideal(),
            "paper" | "static" | "" => Self::paper(),
            "drifty" => Self::drifty(),
            other => {
                return Err(Error::Cli(format!(
                    "unknown physics preset '{other}' (valid: ideal | paper | \
                     static | drifty, optionally followed by key=value \
                     overrides: bank=RxC, dac=N, adc=N, sigma=S, xtalk=on|off, \
                     lock=on|off, seed=N, drift:rate=R, drift:aging=A, \
                     drift:recal=T)"
                )))
            }
        };
        let on_off = |key: &str, v: &str| match v {
            "on" | "true" => Ok(true),
            "off" | "false" => Ok(false),
            _ => Err(Error::Cli(format!("physics {key}: expected on|off, got '{v}'"))),
        };
        for kv in parts {
            let kv = kv.trim();
            let (k, v) = kv.split_once('=').ok_or_else(|| {
                Error::Cli(format!("physics override '{kv}' is not key=value"))
            })?;
            let num = |what: &str| -> Result<f64> {
                v.parse::<f64>().map_err(|_| {
                    Error::Cli(format!("physics {k}: expected {what}, got '{v}'"))
                })
            };
            // strict parses — a silent `as u32` coercion would turn
            // dac=-3 into dac=0 (ideal converters), the opposite of what
            // was asked for, and a seed routed through f64 would round
            // above 2^53
            let bits = || -> Result<u32> {
                Self::check_bits(num("a bit depth")?)
                    .map_err(|e| Error::Cli(format!("physics {k}: {e}")))
            };
            match k {
                "bank" => {
                    let (r, c) = v.split_once('x').ok_or_else(|| {
                        Error::Cli(format!("physics bank: expected RxC, got '{v}'"))
                    })?;
                    cfg.bank_rows = r.parse().map_err(|_| {
                        Error::Cli(format!("physics bank rows: '{r}'"))
                    })?;
                    cfg.bank_cols = c.parse().map_err(|_| {
                        Error::Cli(format!("physics bank cols: '{c}'"))
                    })?;
                }
                "dac" => cfg.dac_bits = bits()?,
                "adc" => cfg.adc_bits = bits()?,
                "sigma" => cfg.sigma = num("a noise std")?,
                "xtalk" => cfg.crosstalk = on_off(k, v)?,
                "lock" => cfg.lock = on_off(k, v)?,
                "drift:rate" => cfg.drift_rate = num("a thermal walk rate")?,
                "drift:aging" => cfg.drift_aging = num("an aging rate")?,
                "drift:recal" => {
                    cfg.recal_threshold = num("a recalibration threshold")?
                }
                "seed" => {
                    cfg.seed = v.parse::<u64>().map_err(|_| {
                        Error::Cli(format!(
                            "physics {k}: expected an unsigned integer seed, got '{v}'"
                        ))
                    })?
                }
                other => {
                    return Err(Error::Cli(format!(
                        "unknown physics key '{other}' (valid: bank, dac, adc, \
                         sigma, xtalk, lock, seed, drift:rate, drift:aging, \
                         drift:recal)"
                    )))
                }
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// The one converter-bit-depth rule, shared by `--physics dac=/adc=`
    /// and `pdfa sweep-physics --bits`: whole, 0..=24, 0 = transparent.
    /// Plain-`String` error so callers can prefix their own context.
    pub fn check_bits(b: f64) -> std::result::Result<u32, String> {
        if (0.0..=24.0).contains(&b) && b.fract() == 0.0 {
            Ok(b as u32)
        } else {
            Err(format!(
                "expected a whole converter bit depth in 0..=24 (0 = ideal \
                 converters), got '{b}'"
            ))
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.bank_rows == 0 || self.bank_cols == 0 {
            return Err(Error::Config("physics: bank dims must be >= 1".into()));
        }
        if self.bank_cols > 108 {
            return Err(Error::Config(format!(
                "physics: {} WDM channels exceed the §3 ring design's FSR \
                 budget (max 108)",
                self.bank_cols
            )));
        }
        if !(self.sigma >= 0.0 && self.sigma.is_finite()) {
            return Err(Error::Config(format!(
                "physics: sigma must be finite and >= 0, got {}",
                self.sigma
            )));
        }
        for (k, v) in [
            ("drift:rate", self.drift_rate),
            ("drift:aging", self.drift_aging),
            ("drift:recal", self.recal_threshold),
        ] {
            if !(v >= 0.0 && v.is_finite()) {
                return Err(Error::Config(format!(
                    "physics: {k} must be finite and >= 0, got {v}"
                )));
            }
        }
        Ok(())
    }

    /// Whether this physics ever changes the device over time (the
    /// lifetime machinery engages; resume messaging keys off this too).
    pub fn drifting(&self) -> bool {
        self.drift_rate > 0.0 || self.drift_aging > 0.0
    }

    /// The §5 energy model sized to this bank: heater-locked MRRs (the
    /// paper's nominal operating point — `pdfa report` re-prices the
    /// same cycle tally under trimming for the 0.28 pJ/op comparison).
    /// Attached to the engine so every dispatch accrues modeled joules
    /// in its [`Telemetry`] snapshots.
    pub fn energy_model(&self) -> EnergyModel {
        EnergyModel::for_bank(self.bank_rows, self.bank_cols, MrrTuning::HeaterLocked)
    }

    /// The bank this physics describes. Read noise is injected at the
    /// engine level (per optical cycle, before the ADC), so the bank
    /// itself runs the ideal BPD chain; crosstalk off maps to a channel
    /// grid spaced wide enough that leakage is negligible.
    fn bank_config(&self) -> BankConfig {
        let design = MrrDesign::high_finesse();
        let spacing = if self.crosstalk {
            3.4
        } else {
            (design.finesse() / self.bank_cols as f64).min(12.0)
        };
        BankConfig {
            rows: self.bank_rows,
            cols: self.bank_cols,
            bpd_mode: BpdMode::Ideal,
            design,
            spacing_linewidths: spacing,
            adc_bits: 0,
            seed: self.seed,
        }
    }
}

/// Documented tolerance of the `ideal` preset against the native engine:
/// per-logit absolute deviation caused only by the tiled f64 analog
/// accumulation vs the dense f32 reference GEMM.
pub const IDEAL_LOGIT_TOL: f32 = 2e-3;

/// The device state of one loaded artifact: the bank and the converter
/// pair. Split from the old monolithic bank-state: everything stochastic
/// now lives in per-row counter-keyed streams (see [`NoiseKey`]), so the
/// device itself is mutated only during a dispatch's sequential
/// inscription phase — the row-parallel eval phase borrows it immutably
/// from every worker.
struct Device {
    bank: WeightBank,
    dac: Quantizer,
    adc: Quantizer,
}

/// Noise keying of one bank operation (one [`BankDispatcher::linear`] /
/// [`BankDispatcher::dfa_gradient`] call): batch row `r` draws its read
/// noise from
/// `Pcg64::keyed(seed, op, r)` — a fresh stream per (operation, row), so
/// a row's draws (including Box–Muller spare caching, which stays inside
/// the row's own stream) are a pure function of its index, never of which
/// worker thread ran it or how many rows came before it.
#[derive(Clone, Copy)]
struct NoiseKey {
    /// Device seed ([`PhysicsConfig::seed`]).
    seed: u64,
    /// The artifact's bank-operation counter at this operation.
    op: u64,
}

impl NoiseKey {
    fn row_rng(self, row: usize) -> Pcg64 {
        Pcg64::keyed(self.seed, self.op, row as u64)
    }
}

/// Shard the rows of a row-major buffer across up to `threads` scoped
/// workers and run `per_row(global_row_index, row_slice, scratch)` on
/// each row. `scratch0` is the caller's persistent scratch: the
/// single-threaded path runs entirely on it, so a dispatcher that
/// hoists its buffers dispatches without touching the heap. Worker
/// threads each build their own via `make_scratch` (once per worker,
/// not per row — thread spawning allocates anyway). Every row's work —
/// including its read-noise draws, which come from a counter-keyed
/// stream — is a pure function of the row index, so the result is
/// bit-identical at any thread count; only wall-clock time changes.
/// Returns the summed per-row optical-cycle counts.
// lint: rng-region
// lint: allow(hot-path-alloc) — scope setup: two O(threads) vecs per
// dispatch (chunk list + join handles), never O(rows·row_len); the
// per-row loop itself is allocation-free
fn shard_rows<S>(
    threads: usize,
    out: &mut [f32],
    row_len: usize,
    scratch0: &mut S,
    make_scratch: impl Fn() -> S + Sync,
    per_row: impl Fn(usize, &mut [f32], &mut S) -> Result<u64> + Sync,
) -> Result<u64> {
    if out.is_empty() || row_len == 0 {
        return Ok(0);
    }
    let rows = out.len() / row_len;
    let threads = threads.min(rows).max(1);
    if threads == 1 {
        let mut fired = 0u64;
        for (i, row) in out.chunks_mut(row_len).enumerate() {
            fired += per_row(i, row, scratch0)?;
        }
        return Ok(fired);
    }
    let rows_per = rows.div_ceil(threads);
    let chunks: Vec<&mut [f32]> = out.chunks_mut(rows_per * row_len).collect();
    let per_row = &per_row;
    let make_scratch = &make_scratch;
    std::thread::scope(|scope| {
        let handles: Vec<_> = chunks
            .into_iter()
            .enumerate()
            .map(|(t, chunk)| {
                scope.spawn(move || -> Result<u64> {
                    let mut fired = 0u64;
                    let mut scratch = make_scratch();
                    for (i, row) in chunk.chunks_mut(row_len).enumerate() {
                        fired += per_row(t * rows_per + i, row, &mut scratch)?;
                    }
                    Ok(fired)
                })
            })
            .collect();
        let mut fired = 0u64;
        for h in handles {
            // lint: allow(panic-free-serve) — re-raises a worker panic;
            // std::thread::scope would re-panic on scope exit anyway
            fired += h.join().expect("photonic row worker panicked")?;
        }
        Ok(fired)
    })
}

impl Device {
    fn new(physics: &PhysicsConfig) -> Result<Device> {
        Ok(Device {
            bank: WeightBank::new(physics.bank_config())?,
            dac: Quantizer::new(physics.dac_bits, 1.0),
            adc: Quantizer::new(physics.adc_bits, 1.0),
        })
    }

    /// Receiver path of one row readout: normalised chain output + read
    /// noise (gradient path only — callers pass `sigma = 0` for forward
    /// inference), then the ADC. `rng` is the batch row's keyed stream.
    fn readout(&self, sigma: f64, v: f32, rng: &mut Pcg64) -> f32 {
        let mut v = v as f64;
        if sigma > 0.0 {
            v += rng.normal(0.0, sigma);
        }
        self.adc.quantize(v) as f32
    }

    /// Inscribe one bank-sized tile per the configured fidelity. The
    /// locked path draws its lock-loop measurement noise from a stream
    /// keyed by `(device seed, bank op, tile)`, so an inscription is a
    /// pure function of those coordinates — what makes locked runs
    /// resumable and replica-identical. Tiles key the lane space above
    /// `2^32`, disjoint from the batch-row readout lanes of [`NoiseKey`].
    fn inscribe(
        &mut self,
        physics: &PhysicsConfig,
        tile_w: &Tensor,
        op: u64,
        tile: u64,
    ) -> Result<()> {
        if physics.lock {
            let mut rng = Pcg64::keyed(physics.seed, op, (1u64 << 32) | tile);
            self.bank.inscribe_keyed(tile_w, &mut rng)
        } else {
            self.bank.inscribe_exact(tile_w, physics.crosstalk)
        }
    }

    /// Fire one (or, with negative values, two differential) optical
    /// cycles driving the snapshotted tile `ins` with the signed channel
    /// values `vals`, and accumulate the digitally rescaled result into
    /// `out[..n_rows]`. `ebuf` is the worker's reusable readout buffer
    /// (length = bank rows); returns the cycles fired.
    // lint: hot-path
    #[allow(clippy::too_many_arguments)]
    fn drive_tile(
        &self,
        sigma: f64,
        ins: &Inscription,
        n_rows: usize,
        vals: &[f32],
        gains: Option<&[f32]>,
        amp: f32,
        out: &mut [f32],
        ebuf: &mut [f32],
        rng: &mut Pcg64,
    ) -> Result<u64> {
        let bc = self.bank.cols();
        // per-sample full scale: the DAC drives |v|/s onto the channels
        let mut s = 0.0f32;
        for &v in vals {
            if v.is_finite() {
                s = s.max(v.abs());
            }
        }
        if s <= 0.0 {
            return Ok(0); // all channels dark (zero or non-finite input)
        }
        // stack scratch: validate() caps the bank at 108 WDM channels, and
        // this runs per (tile × batch row) — the training hot loop
        let mut x_pos = [0.0f32; 128];
        let mut x_neg = [0.0f32; 128];
        let (x_pos, x_neg) = (&mut x_pos[..bc], &mut x_neg[..bc]);
        let mut any_neg = false;
        for (c, &v) in vals.iter().enumerate() {
            // NaN saturates to a dark channel inside the DAC quantiser
            let q = (self.dac.quantize((v / s).abs() as f64) as f32).min(1.0);
            if v >= 0.0 {
                x_pos[c] = q;
            } else {
                x_neg[c] = q;
                any_neg |= q > 0.0;
            }
        }
        // undo the bank's 1/cols normalisation, the per-sample full scale
        // and the inscription amplification
        let gain = bc as f32 * s * amp;
        // read noise enters at the BPD (pre-TIA): a row's gain mask scales
        // it, so a g'(a)=0 row reads exactly zero, like the reference model
        let row_sigma =
            |r: usize| gains.map_or(sigma, |g| sigma * (g[r] as f64).clamp(0.0, 1.0));
        let mut fired = 0u64;
        self.bank.eval_into(ins, x_pos, gains, rng, ebuf)?;
        fired += 1;
        for (r, (o, &p)) in out[..n_rows].iter_mut().zip(ebuf.iter()).enumerate() {
            *o += self.readout(row_sigma(r), p, rng) * gain;
        }
        if any_neg {
            self.bank.eval_into(ins, x_neg, gains, rng, ebuf)?;
            fired += 1;
            for (r, (o, &p)) in out[..n_rows].iter_mut().zip(ebuf.iter()).enumerate() {
                *o -= self.readout(row_sigma(r), p, rng) * gain;
            }
        }
        Ok(fired)
    }
}

/// Inscription amplification for a matrix: weights are scaled to fill the
/// bank's inscribable range and the inverse gain is applied digitally
/// after readout (small inscribed weights would drown in receiver noise).
fn inscription_amp(physics: &PhysicsConfig, bank: &WeightBank, w: &Tensor) -> f32 {
    let w_cap = if physics.lock {
        bank.weight_range().1.min(0.95) as f32
    } else {
        1.0 // the exact path inscribes the full [-1, 1] range
    };
    (w.max_abs() / w_cap).max(1e-12)
}

/// The reusable dispatch state of one loaded artifact: the device plus
/// every per-dispatch scratch buffer, hoisted so that a steady-state
/// dispatch makes **zero heap allocations** (enforced by
/// `tests/alloc_photonic.rs` under a counting global allocator, at
/// `threads = 1` — worker threads allocate on spawn by nature).
///
/// What is pooled and why:
/// * `tile_w` — the bank-shaped staging tensor each tile is written
///   into before inscription (was a fresh `Tensor::zeros` per dispatch);
/// * `snaps` — one [`Inscription`] pool slot per tile, refilled through
///   [`WeightBank::snapshot_into`] (was a fresh snapshot `Vec` per tile
///   per dispatch);
/// * `tilings` — the [`Tiling`] plans keyed by `(m, k)`: a model has a
///   handful of GEMM shapes, each planned once per dispatcher lifetime;
/// * `lin_scratch` / `grad_scratch` — the single-thread row-worker
///   buffers ((acc, ebuf) and (gains, acc, ebuf), each bank-rows long);
/// * `gbuf` — the gradient's `(batch, m)` row-major staging buffer,
///   transposed into the caller's `(m, batch)` output.
///
/// The `*_into` entry points write into caller-owned outputs; the
/// allocating [`Self::linear`] / [`Self::dfa_gradient`] wrappers are
/// what the artifact layer uses (its outputs leave the dispatch).
pub struct BankDispatcher {
    physics: PhysicsConfig,
    /// Batch-row worker count (resolved, >= 1).
    threads: usize,
    device: Device,
    tile_w: Tensor,
    snaps: Vec<Inscription>,
    tilings: Vec<((usize, usize), Tiling)>,
    lin_scratch: (Vec<f32>, Vec<f32>),
    grad_scratch: (Vec<f32>, Vec<f32>, Vec<f32>),
    gbuf: Vec<f32>,
}

impl BankDispatcher {
    /// Build the device for `physics` and size the per-dispatch scratch
    /// to its bank geometry. `threads` follows the CLI convention
    /// (0 = all cores).
    pub fn new(physics: PhysicsConfig, threads: usize) -> Result<BankDispatcher> {
        physics.validate()?;
        let device = Device::new(&physics)?;
        let br = device.bank.rows();
        let bc = device.bank.cols();
        Ok(BankDispatcher {
            physics,
            threads: crate::util::threads::resolve(threads),
            tile_w: Tensor::zeros(&[br, bc]),
            snaps: Vec::new(),
            tilings: Vec::new(),
            lin_scratch: (vec![0.0; br], vec![0.0; br]),
            grad_scratch: (vec![0.0; br], vec![0.0; br], vec![0.0; br]),
            gbuf: Vec::new(),
            device,
        })
    }

    /// The resolved batch-row worker count (>= 1).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Load the device-lifetime state into the bank: subsequent
    /// inscriptions land on the drifted flanks, and dead rings hold
    /// their stuck weights. Allocation-free in steady state (the bank
    /// reuses its drift buffers).
    pub fn set_drift(&mut self, phases: &[f64], stuck: &[(usize, f64)]) -> Result<()> {
        self.device.bank.set_drift(phases, stuck)
    }

    /// Re-run the §4 calibration protocol on every ring (LUT sweep plus
    /// a verification lock); returns the charged readout cycles and the
    /// probe residual. See [`WeightBank::recalibrate`].
    pub fn recalibrate(&mut self, rng: &mut Pcg64) -> Result<(u64, f64)> {
        self.device.bank.recalibrate(rng)
    }

    /// The tiling plan for an `(m, k)` weight matrix on this bank,
    /// planned once and cached (returned by index to keep `self`
    /// borrowable afterwards).
    fn tiling_index(&mut self, m: usize, k: usize) -> Result<usize> {
        if let Some(i) = self
            .tilings
            .iter()
            .position(|&((tm, tk), _)| tm == m && tk == k)
        {
            return Ok(i);
        }
        let t = Tiling::new(m, k, self.device.bank.rows(), self.device.bank.cols())?;
        self.tilings.push(((m, k), t));
        Ok(self.tilings.len() - 1)
    }

    /// `y = x @ w [+ b]` with every MAC on the bank: `wᵀ` is tiled onto
    /// the array, inscribed once per tile (sequential phase), and each
    /// batch row is driven through the optical chain (Fig. 4(b)
    /// operation) by the row-parallel worker pool. Per output element
    /// the tile contributions accumulate in the fixed tiling order, so
    /// the result — including the returned optical-cycle count, which
    /// the telemetry layer prices in joules — is bit-identical at any
    /// `threads`. `op` keys the per-row noise streams (see [`NoiseKey`]).
    pub fn linear(
        &mut self,
        op: u64,
        x: &Tensor,
        w: &Tensor,
        b: Option<&Tensor>,
    ) -> Result<(Tensor, u64)> {
        let mut y = Tensor::zeros(&[x.rows(), w.cols()]);
        let fired = self.linear_into(op, x, w, b, &mut y)?;
        Ok((y, fired))
    }

    /// [`Self::linear`] into a caller-owned `(batch, m)` output tensor —
    /// the allocation-free form.
    // lint: hot-path
    // lint: rng-region
    pub fn linear_into(
        &mut self,
        op: u64,
        x: &Tensor,
        w: &Tensor,
        b: Option<&Tensor>,
        y: &mut Tensor,
    ) -> Result<u64> {
        let (batch, k) = (x.rows(), x.cols());
        let m = w.cols();
        if w.rows() != k {
            // lint: allow(hot-path-alloc) — cold path, shape error
            return Err(Error::Shape(format!(
                "bank linear: x is (_, {k}) but w is ({}, {m})",
                w.rows()
            )));
        }
        if y.shape() != [batch, m] {
            // lint: allow(hot-path-alloc) — cold path, shape error
            return Err(Error::Shape(format!(
                "bank linear: output must be ({batch}, {m}), got {:?}",
                y.shape()
            )));
        }
        let ti = self.tiling_index(m, k)?;
        let BankDispatcher {
            physics,
            threads,
            device,
            tile_w,
            snaps,
            tilings,
            lin_scratch,
            ..
        } = self;
        let tiling = &tilings[ti].1;
        let amp = inscription_amp(physics, &device.bank, w);
        // sequential phase: inscribe every tile once and snapshot it
        // into its pool slot (§5 analog weight memory) — the only part
        // that needs the bank mutably
        while snaps.len() < tiling.tiles.len() {
            snaps.push(Inscription::empty());
        }
        for (t, (tile, snap)) in tiling.tiles.iter().zip(snaps.iter_mut()).enumerate() {
            tile_w.data_mut().fill(0.0);
            for r in 0..tile.rows() {
                for c in 0..tile.cols() {
                    // the bank computes wᵀ · x_row
                    tile_w.set(r, c, w.at(tile.col0 + c, tile.row0 + r) / amp);
                }
            }
            device.inscribe(physics, tile_w, op, t as u64)?;
            device.bank.snapshot_into(snap);
        }
        match b {
            Some(b) if m > 0 => {
                for row in y.data_mut().chunks_mut(m) {
                    row.copy_from_slice(&b.data()[..m]);
                }
            }
            _ => y.data_mut().fill(0.0),
        }
        // row-parallel phase: batch rows are independent on the device
        let key = NoiseKey { seed: physics.seed, op };
        let dev: &Device = device;
        let snaps: &[Inscription] = snaps;
        let br = dev.bank.rows();
        let fired = shard_rows(
            *threads,
            y.data_mut(),
            m,
            lin_scratch,
            // worker-local reusable buffers: (acc, ebuf)
            // lint: allow(hot-path-alloc) — once per worker, not per row
            || (vec![0.0f32; br], vec![0.0f32; br]),
            |smp, y_row, scratch| {
                let (acc, ebuf) = scratch;
                let mut rng = key.row_rng(smp);
                let mut fired = 0u64;
                for (tile, ins) in tiling.tiles.iter().zip(snaps) {
                    let vals = &x.row(smp)[tile.col0..tile.col1];
                    acc[..tile.rows()].fill(0.0);
                    // forward inference: converters yes, gradient read-noise no
                    fired += dev.drive_tile(
                        0.0,
                        ins,
                        tile.rows(),
                        vals,
                        None,
                        amp,
                        acc,
                        ebuf,
                        &mut rng,
                    )?;
                    for r in 0..tile.rows() {
                        y_row[tile.row0 + r] += acc[r];
                    }
                }
                Ok(fired)
            },
        )?;
        Ok(fired)
    }

    /// Eq. (1) on the bank: `delta(k)ᵀ (m, batch)` for feedback matrix
    /// `bmat (m, k)`, error rows `e (batch, k)` and pre-activations
    /// `a (batch, m)`. The g′(a) ReLU mask rides on the TIA gains, so
    /// the Hadamard product costs no extra optical cycle (§3).
    pub fn dfa_gradient(
        &mut self,
        op: u64,
        bmat: &Tensor,
        e: &Tensor,
        a: &Tensor,
    ) -> Result<(Tensor, u64)> {
        let mut out = Tensor::zeros(&[bmat.rows(), e.rows()]);
        let fired = self.dfa_gradient_into(op, bmat, e, a, &mut out)?;
        Ok((out, fired))
    }

    /// [`Self::dfa_gradient`] into a caller-owned `(m, batch)` output
    /// tensor — the allocation-free form.
    // lint: hot-path
    // lint: rng-region
    pub fn dfa_gradient_into(
        &mut self,
        op: u64,
        bmat: &Tensor,
        e: &Tensor,
        a: &Tensor,
        out: &mut Tensor,
    ) -> Result<u64> {
        let (batch, k) = (e.rows(), e.cols());
        let m = bmat.rows();
        if bmat.cols() != k || a.rows() != batch || a.cols() != m {
            // lint: allow(hot-path-alloc) — cold path, shape error
            return Err(Error::Shape(format!(
                "bank dfa_gradient: bmat {:?}, e {:?}, a {:?}",
                bmat.shape(),
                e.shape(),
                a.shape()
            )));
        }
        if out.shape() != [m, batch] {
            // lint: allow(hot-path-alloc) — cold path, shape error
            return Err(Error::Shape(format!(
                "bank dfa_gradient: output must be ({m}, {batch}), got {:?}",
                out.shape()
            )));
        }
        let ti = self.tiling_index(m, k)?;
        let BankDispatcher {
            physics,
            threads,
            device,
            tile_w,
            snaps,
            tilings,
            grad_scratch,
            gbuf,
            ..
        } = self;
        let tiling = &tilings[ti].1;
        let amp = inscription_amp(physics, &device.bank, bmat);
        // sequential inscription phase (see linear_into)
        while snaps.len() < tiling.tiles.len() {
            snaps.push(Inscription::empty());
        }
        for (t, (tile, snap)) in tiling.tiles.iter().zip(snaps.iter_mut()).enumerate() {
            tile_w.data_mut().fill(0.0);
            for r in 0..tile.rows() {
                for c in 0..tile.cols() {
                    tile_w.set(r, c, bmat.at(tile.row0 + r, tile.col0 + c) / amp);
                }
            }
            device.inscribe(physics, tile_w, op, t as u64)?;
            device.bank.snapshot_into(snap);
        }
        // row-parallel phase into the pooled (batch, m) staging buffer —
        // each worker owns contiguous per-sample rows — transposed
        // afterwards into the (m, batch) layout the digital update expects
        gbuf.resize(batch * m, 0.0);
        gbuf.fill(0.0);
        let key = NoiseKey { seed: physics.seed, op };
        let dev: &Device = device;
        let snaps: &[Inscription] = snaps;
        let sigma = physics.sigma;
        let br = dev.bank.rows();
        let fired = shard_rows(
            *threads,
            gbuf,
            m,
            grad_scratch,
            // worker-local reusable buffers: (gains, acc, ebuf)
            // lint: allow(hot-path-alloc) — once per worker, not per row
            || (vec![0.0f32; br], vec![0.0f32; br], vec![0.0f32; br]),
            |smp, d_row, scratch| {
                let (gains, acc, ebuf) = scratch;
                let mut rng = key.row_rng(smp);
                let mut fired = 0u64;
                for (tile, ins) in tiling.tiles.iter().zip(snaps) {
                    // TIA gains: g'(a) for live rows, padding rows gated off
                    gains.fill(0.0);
                    for r in 0..tile.rows() {
                        gains[r] = if a.at(smp, tile.row0 + r) > 0.0 { 1.0 } else { 0.0 };
                    }
                    let vals = &e.row(smp)[tile.col0..tile.col1];
                    acc[..tile.rows()].fill(0.0);
                    fired += dev.drive_tile(
                        sigma,
                        ins,
                        tile.rows(),
                        vals,
                        Some(&gains[..]),
                        amp,
                        acc,
                        ebuf,
                        &mut rng,
                    )?;
                    for r in 0..tile.rows() {
                        d_row[tile.row0 + r] += acc[r];
                    }
                }
                Ok(fired)
            },
        )?;
        let od = out.data_mut();
        for smp in 0..batch {
            for j in 0..m {
                od[j * batch + smp] = gbuf[smp * m + j];
            }
        }
        Ok(fired)
    }
}

/// Which physical routine an artifact name maps onto.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    Fwd,
    DfaStep,
}

/// One loaded photonic artifact: spec-identical to its native twin, but
/// every GEMM runs on the owned device state.
pub struct PhotonicArtifact {
    spec: ArtifactSpec,
    kind: Kind,
    /// The bank + converters + pooled dispatch scratch. The mutex
    /// serializes whole dispatches (the inscription phase mutates the
    /// bank, and the scratch pools are exclusive); within a dispatch the
    /// row-parallel phase runs under the guard with scoped workers
    /// borrowing the device immutably.
    ///
    /// Poisoned-lock recovery semantics: a panic inside a dispatch (e.g.
    /// in a row worker) can leave the bank with a partially-updated
    /// inscription, but never an *observable* one — every dispatch
    /// re-inscribes each tile it uses before snapshotting and driving it,
    /// so the next dispatch starts from freshly written ring state and
    /// `into_inner` recovery is sound. Noise determinism is unaffected
    /// too: the read-noise streams are counter-keyed (not carried in the
    /// device), and the engine's banks run the Ideal BPD chain, so the
    /// bank's internal stream has no value-bearing draws to lose. The
    /// scratch pools hold no cross-dispatch state either — every buffer
    /// is refilled before it is read.
    dispatcher: Mutex<BankDispatcher>,
    /// The engine's shared device-lifetime state (one physical chip per
    /// engine: every artifact advances the same clock). Always locked
    /// *inside* the dispatcher lock — `dispatcher → drift` is the
    /// registered lock order.
    drift: Arc<Mutex<DriftModel>>,
    /// Optical cycles fired; atomic so [`Self::cycles`] never takes the
    /// bank lock.
    cycles: AtomicU64,
    /// Engine-shared telemetry cells (cycles also accrue here, next to
    /// the analytic MAC counts, so [`StepEngine::telemetry`] aggregates
    /// across every loaded artifact).
    counters: Arc<Counters>,
    /// Analytic on-bank MACs of one successful `execute`.
    bank_macs: u64,
    /// Analytic digitally-executed MACs of one successful `execute`
    /// (the weight-gradient outer products of `dfa_step`).
    digital_macs: u64,
    /// Bank operations one `execute` dispatches (3 for `fwd`, 5 for
    /// `dfa_step`).
    bank_ops: u64,
}

impl PhotonicArtifact {
    /// Optical cycles fired through this artifact so far (differential
    /// encoding counts both the e⁺ and e⁻ passes, like the real chip).
    /// Lock-free: safe to poll while a dispatch is in flight.
    pub fn cycles(&self) -> u64 {
        self.cycles.load(Ordering::Relaxed)
    }

    /// Claim the next bank-operation id from the engine-shared sequence
    /// ([`Counters::next_op`] — checkpointed by
    /// [`StepEngine::device_state`], so a resumed run continues the very
    /// same noise streams). Sequential callers (the trainer executes
    /// steps one by one) observe a deterministic sequence, which makes
    /// every noise draw of a run reproducible; concurrent `execute`
    /// calls stay safe but interleave op ids.
    fn next_op(&self) -> u64 {
        self.counters.next_op()
    }

    /// Advance device time to the engine's cycle tally and run the
    /// online recalibration scheduler before the dispatch fires: when
    /// the drift model's weight-error estimate crosses the configured
    /// threshold, the §4 calibration protocol re-runs on the bank, its
    /// readout cycles are charged to the lifetime tally (priced by the
    /// §5 energy model, but *not* added to the device-time clock — see
    /// the drift module docs), and the compensable error is re-locked
    /// away. Called with the dispatcher lock held; inactive models
    /// return after one branch, keeping static configurations on the
    /// pre-lifetime fast path.
    fn advance_device_time(&self, disp: &mut BankDispatcher) -> Result<()> {
        let mut drift = self.drift.lock().unwrap_or_else(|p| p.into_inner());
        if !drift.is_active() {
            return Ok(());
        }
        drift.advance_to(self.counters.cycles() / DRIFT_TICK_CYCLES);
        if drift.should_recalibrate() {
            let mut rng = drift.recal_rng();
            let (cost, _residual) = disp.recalibrate(&mut rng)?;
            drift.complete_recalibration(cost);
            self.counters.add_recal(cost);
        }
        self.counters.set_drift_err(drift.estimated_weight_error());
        disp.set_drift(drift.phases(), drift.stuck())
    }

    /// One bank linear dispatch; tallies the fired cycles on the
    /// artifact counter and returns them for the engine-level accrual.
    fn linear(
        &self,
        disp: &mut BankDispatcher,
        x: &Tensor,
        w: &Tensor,
        b: Option<&Tensor>,
    ) -> Result<(Tensor, u64)> {
        let (y, fired) = disp.linear(self.next_op(), x, w, b)?;
        self.cycles.fetch_add(fired, Ordering::Relaxed);
        Ok((y, fired))
    }

    fn dfa_gradient(
        &self,
        disp: &mut BankDispatcher,
        bmat: &Tensor,
        e: &Tensor,
        a: &Tensor,
    ) -> Result<(Tensor, u64)> {
        let (d, fired) = disp.dfa_gradient(self.next_op(), bmat, e, a)?;
        self.cycles.fetch_add(fired, Ordering::Relaxed);
        Ok((d, fired))
    }

    fn forward(
        &self,
        disp: &mut BankDispatcher,
        params: &[Tensor],
        x: &Tensor,
    ) -> Result<(reference::Forward, u64)> {
        let (a1, f1) = self.linear(disp, x, &params[0], Some(&params[1]))?;
        let h1 = a1.map(|v| v.max(0.0));
        let (a2, f2) = self.linear(disp, &h1, &params[2], Some(&params[3]))?;
        let h2 = a2.map(|v| v.max(0.0));
        let (logits, f3) = self.linear(disp, &h2, &params[4], Some(&params[5]))?;
        Ok((reference::Forward { a1, h1, a2, h2, logits }, f1 + f2 + f3))
    }
}

impl Artifact for PhotonicArtifact {
    fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    // lint: boundary(panic-free-serve) — every input is spec-validated
    // on entry, and the reference kernels' shape expects/unwraps are
    // unreachable on validated shapes; a worker panic here is a bug in
    // the artifact contract, not a request-dependent path
    fn execute(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.spec.validate_inputs(inputs)?;
        // see the `dispatcher` field docs for the poisoned-lock recovery story
        let mut disp = self.dispatcher.lock().unwrap_or_else(|p| p.into_inner());
        self.advance_device_time(&mut disp)?;
        let (out, fired) = match self.kind {
            Kind::Fwd => {
                let (f, fired) = self.forward(&mut disp, &inputs[..6], &inputs[6])?;
                (vec![f.logits, f.a1, f.a2, f.h1, f.h2], fired)
            }
            Kind::DfaStep => {
                // contract twin of reference::dfa_step, with the Gaussian
                // noise model replaced by the device physics: the injected
                // noise/sigma/bits inputs must be silent
                let sigma = inputs[18].item();
                let bits = inputs[19].item();
                if sigma != 0.0 || bits != 0.0 {
                    return Err(Error::Config(format!(
                        "the photonic backend models noise at device level \
                         (--physics), so the Gaussian noise-model inputs must \
                         be zero; got sigma={sigma}, bits={bits} — train with \
                         --noise clean or switch to --backend native"
                    )));
                }
                let (lr, momentum) = (inputs[20].item(), inputs[21].item());
                let mut state: Vec<Tensor> = inputs[..12].to_vec();
                let (bmat1, bmat2) = (&inputs[12], &inputs[13]);
                let (x, y) = (&inputs[14], &inputs[15]);
                let (f, ff) = self.forward(&mut disp, &state[..6], x)?;
                let (loss, e, correct) = reference::loss_and_error(&f.logits, y);
                let (d1t, f1) = self.dfa_gradient(&mut disp, bmat1, &e, &f.a1)?;
                let (d2t, f2) = self.dfa_gradient(&mut disp, bmat2, &e, &f.a2)?;
                let grads = reference::grads_from_deltas(x, &f.h1, &f.h2, &e, &d1t, &d2t);
                reference::sgd_momentum(&mut state, &grads, lr, momentum);
                state.push(Tensor::scalar(loss));
                state.push(Tensor::scalar(correct as f32));
                (state, ff + f1 + f2)
            }
        };
        self.counters.add_bank(self.bank_macs, fired, self.bank_ops);
        self.counters.add_macs(self.digital_macs);
        Ok(out)
    }
}

/// The in-situ photonic step engine.
pub struct PhotonicEngine {
    native: NativeEngine,
    physics: PhysicsConfig,
    /// Resolved batch-row worker count every loaded artifact shards with.
    threads: usize,
    /// Telemetry cells shared with the inner native engine, so the
    /// digitally delegated artifacts (`apply_grads_*`, `photonic_matvec`)
    /// and the bank dispatches aggregate into one snapshot.
    counters: Arc<Counters>,
    /// §5 energy model sized to the configured bank; prices the cycle
    /// tally in every [`StepEngine::telemetry`] snapshot.
    energy: EnergyModel,
    /// The device-lifetime state: one physical chip per engine, shared
    /// by every loaded artifact (they advance one clock and trigger one
    /// scheduler between them).
    drift: Arc<Mutex<DriftModel>>,
}

/// Header of the engine's opaque [`StepEngine::device_state`] blob
/// (checkpointed as the `device` field of a v2 training checkpoint).
const DEVICE_STATE_MAGIC: [u8; 4] = *b"PDV1";

impl PhotonicEngine {
    /// Engine over `artifacts_dir` (same config resolution as the native
    /// engine: built-ins plus any manifest extras) with the given physics,
    /// sharding batch rows across all available cores.
    pub fn open(artifacts_dir: impl AsRef<Path>, physics: PhysicsConfig) -> Result<Self> {
        Self::open_threaded(artifacts_dir, physics, 0)
    }

    /// [`Self::open`] with an explicit batch-row worker count (0 = all
    /// cores, the CLI `--threads` convention). Thread count changes
    /// wall-clock time only: per-row counter-keyed noise streams keep
    /// every result bit-identical at any value.
    pub fn open_threaded(
        artifacts_dir: impl AsRef<Path>,
        physics: PhysicsConfig,
        threads: usize,
    ) -> Result<Self> {
        physics.validate()?;
        let native = NativeEngine::open(artifacts_dir)?;
        let counters = native.counters();
        let drift = Arc::new(Mutex::new(DriftModel::new(
            physics.bank_rows,
            physics.bank_cols,
            physics.drift_rate,
            physics.drift_aging,
            physics.recal_threshold,
            physics.seed,
            &physics.bank_config().design,
        )));
        Ok(PhotonicEngine {
            native,
            physics,
            threads: crate::util::threads::resolve(threads),
            counters,
            energy: physics.energy_model(),
            drift,
        })
    }

    pub fn physics(&self) -> &PhysicsConfig {
        &self.physics
    }

    /// The energy model pricing this engine's optical cycles.
    pub fn energy_model(&self) -> &EnergyModel {
        &self.energy
    }

    /// The resolved batch-row worker count (>= 1).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Schedule scripted device faults (the fault-injection harness of
    /// `tests/integration_drift.rs`): they apply when device time
    /// reaches their tick. See [`DriftModel::inject`].
    pub fn inject_faults(&self, events: &[FaultEvent]) -> Result<()> {
        self.drift.lock().unwrap_or_else(|p| p.into_inner()).inject(events)
    }
}

impl StepEngine for PhotonicEngine {
    fn platform_name(&self) -> String {
        "photonic".into()
    }

    fn net_dims(&self, config: &str) -> Result<NetDims> {
        self.native.net_dims(config)
    }

    fn configs(&self) -> Vec<(String, NetDims)> {
        self.native.configs()
    }

    fn artifact_specs(&self) -> Vec<ArtifactSpec> {
        // the digital backprop baseline does not exist on this substrate
        self.native
            .artifact_specs()
            .into_iter()
            .filter(|s| !s.name.starts_with("bp_step_"))
            .collect()
    }

    fn load(&self, name: &str) -> Result<Arc<dyn Artifact>> {
        if name.starts_with("bp_step_") {
            return Err(Error::Config(format!(
                "artifact '{name}': the photonic backend trains with DFA only \
                 (the paper's in-situ algorithm); run the digital backprop \
                 baseline with --backend native"
            )));
        }
        let kind = if name.starts_with("fwd_") {
            Kind::Fwd
        } else if name.starts_with("dfa_step_") {
            Kind::DfaStep
        } else {
            // apply_grads_* is the digital SGD update; photonic_matvec is
            // already the raw MRR kernel — both execute natively
            return self.native.load(name);
        };
        let spec = self.native.load(name)?.spec().clone();
        let dims = self.native.net_dims(&spec.config)?;
        // analytic MAC split of one execute: what runs on the bank vs
        // what stays digital (the weight-gradient outer products)
        let (bank_macs, digital_macs, bank_ops) = match kind {
            Kind::Fwd => (telemetry::macs_forward(&dims), 0, 3),
            Kind::DfaStep => (
                telemetry::macs_forward(&dims) + telemetry::macs_feedback(&dims),
                telemetry::macs_weight_grads(&dims),
                5,
            ),
        };
        Ok(Arc::new(PhotonicArtifact {
            spec,
            kind,
            dispatcher: Mutex::new(BankDispatcher::new(self.physics, self.threads)?),
            drift: self.drift.clone(),
            cycles: AtomicU64::new(0),
            counters: self.counters.clone(),
            bank_macs,
            digital_macs,
            bank_ops,
        }))
    }

    fn telemetry(&self) -> Telemetry {
        self.counters.snapshot(Some(&self.energy))
    }

    fn device_state(&self) -> Option<Vec<u8>> {
        let drift = self.drift.lock().unwrap_or_else(|p| p.into_inner());
        let t = self.counters.snapshot(None);
        let blob = drift.state_bytes();
        let mut out = Vec::with_capacity(4 + 8 * 8 + blob.len());
        out.extend_from_slice(&DEVICE_STATE_MAGIC);
        for v in [
            self.counters.op_seq(),
            t.macs,
            t.photonic_macs,
            t.cycles,
            t.bank_ops,
            t.recal_events,
            t.recal_cycles,
        ] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.extend_from_slice(&(blob.len() as u64).to_le_bytes());
        out.extend_from_slice(&blob);
        Some(out)
    }

    fn restore_device_state(&self, bytes: &[u8]) -> Result<()> {
        if bytes.len() < 4 + 8 * 8 || bytes[..4] != DEVICE_STATE_MAGIC {
            return Err(Error::Format(
                "photonic device state: bad magic or truncated header".into(),
            ));
        }
        let word = |i: usize| {
            u64::from_le_bytes(
                bytes[4 + 8 * i..4 + 8 * (i + 1)].try_into().expect("8 bytes"),
            )
        };
        if bytes.len() - (4 + 8 * 8) != word(7) as usize {
            return Err(Error::Format(format!(
                "photonic device state: drift blob length {} recorded, {} present",
                word(7),
                bytes.len() - (4 + 8 * 8)
            )));
        }
        // geometry and format are checked by the drift model before any
        // state is overwritten; the counters only change after it accepts
        self.drift
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .restore_state(&bytes[4 + 8 * 8..])?;
        self.counters.restore(
            &Telemetry {
                macs: word(1),
                photonic_macs: word(2),
                cycles: word(3),
                bank_ops: word(4),
                recal_events: word(5),
                recal_cycles: word(6),
                ..Telemetry::default()
            },
            word(0),
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfa::params::NetState;
    use crate::util::check::assert_close;

    fn small_physics() -> PhysicsConfig {
        PhysicsConfig { bank_rows: 7, bank_cols: 5, ..PhysicsConfig::ideal() }
    }

    fn disp_for(phys: &PhysicsConfig) -> BankDispatcher {
        BankDispatcher::new(*phys, 1).unwrap()
    }

    /// Single-threaded linear driver for the numerics tests.
    fn linear(
        disp: &mut BankDispatcher,
        _phys: &PhysicsConfig,
        op: u64,
        x: &Tensor,
        w: &Tensor,
        b: Option<&Tensor>,
    ) -> Result<Tensor> {
        disp.linear(op, x, w, b).map(|(y, _)| y)
    }

    /// Single-threaded dfa-gradient driver for the numerics tests.
    fn gradient(
        disp: &mut BankDispatcher,
        _phys: &PhysicsConfig,
        op: u64,
        bmat: &Tensor,
        e: &Tensor,
        a: &Tensor,
    ) -> Result<Tensor> {
        disp.dfa_gradient(op, bmat, e, a).map(|(d, _)| d)
    }

    #[test]
    fn physics_parse_presets_and_overrides() {
        assert_eq!(PhysicsConfig::parse("ideal").unwrap(), PhysicsConfig::ideal());
        assert_eq!(PhysicsConfig::parse("paper").unwrap(), PhysicsConfig::paper());
        let p = PhysicsConfig::parse(
            "ideal,bank=10x4,dac=6,adc=4,sigma=0.05,xtalk=on,lock=on,seed=9",
        )
        .unwrap();
        assert_eq!((p.bank_rows, p.bank_cols), (10, 4));
        assert_eq!((p.dac_bits, p.adc_bits), (6, 4));
        assert_eq!(p.sigma, 0.05);
        assert!(p.crosstalk && p.lock);
        assert_eq!(p.seed, 9);
        // seeds parse as u64 directly: no f64 rounding above 2^53
        let p = PhysicsConfig::parse("ideal,seed=9007199254740993").unwrap();
        assert_eq!(p.seed, 9_007_199_254_740_993);
        // lifetime presets: `static` is the explicit paper alias (zero
        // drift), `drifty` arms the full lifetime machinery
        assert_eq!(PhysicsConfig::parse("static").unwrap(), PhysicsConfig::paper());
        assert!(!PhysicsConfig::paper().drifting());
        let d = PhysicsConfig::parse("drifty").unwrap();
        assert_eq!(d, PhysicsConfig::drifty());
        assert!(d.drifting());
        assert_eq!(d.drift_rate, DRIFT_RATE_DEFAULT);
        assert_eq!(d.drift_aging, DRIFT_AGING_DEFAULT);
        assert_eq!(d.recal_threshold, RECAL_THRESHOLD_DEFAULT);
        let p = PhysicsConfig::parse(
            "ideal,drift:rate=2e-4,drift:aging=1e-6,drift:recal=0.03",
        )
        .unwrap();
        assert_eq!(
            (p.drift_rate, p.drift_aging, p.recal_threshold),
            (2e-4, 1e-6, 0.03)
        );
        for bad in [
            "bogus",
            "ideal,dac",
            "ideal,dac=x",
            "ideal,dac=-3",
            "ideal,dac=2.5",
            "ideal,adc=99",
            "ideal,seed=-1",
            "ideal,seed=1.5",
            "ideal,bank=10",
            "ideal,warp=9",
            "ideal,xtalk=maybe",
            "ideal,sigma=-1",
            "ideal,bank=0x4",
            "ideal,bank=10x200",
            "ideal,drift:rate=-1",
            "ideal,drift:aging=-2e-6",
            "ideal,drift:recal=x",
            "drifty,drift:rate=nan",
        ] {
            assert!(PhysicsConfig::parse(bad).is_err(), "{bad} should fail");
        }
    }

    #[test]
    fn describe_is_protocol_stable() {
        let a = PhysicsConfig::ideal().describe();
        assert_eq!(a, PhysicsConfig::ideal().describe());
        assert_ne!(a, PhysicsConfig::paper().describe());
        let mut p = PhysicsConfig::ideal();
        p.dac_bits = 5;
        assert_ne!(a, p.describe());
        let mut p = PhysicsConfig::ideal();
        p.sigma = 0.125;
        assert_ne!(a, p.describe());
        // the lifetime knobs join the checkpoint protocol string too: a
        // drifting device is a different experiment
        let mut p = PhysicsConfig::ideal();
        p.drift_rate = 1e-4;
        assert_ne!(a, p.describe());
        assert_ne!(
            PhysicsConfig::paper().describe(),
            PhysicsConfig::drifty().describe()
        );
    }

    #[test]
    fn tiled_bank_linear_matches_dense_for_ragged_shapes() {
        // the satellite property: Tiling-driven bank matvec == dense
        // matmul, for shapes that pad both tile axes
        let phys = small_physics(); // 7 x 5 bank
        let mut dev = disp_for(&phys);
        let mut rng = Pcg64::seed(21);
        for (op, (batch, k, m)) in [
            (3usize, 11usize, 9usize), // ragged both ways
            (1, 5, 7),                 // exact fit
            (2, 6, 8),                 // one extra row/col
            (4, 3, 2),                 // smaller than one tile
            (2, 16, 15),               // multi-block ragged
        ]
        .into_iter()
        .enumerate()
        {
            let x = Tensor::randn(&[batch, k], 0.8, &mut rng);
            let w = Tensor::rand_uniform(&[k, m], -0.9, 0.9, &mut rng);
            let b = Tensor::rand_uniform(&[m], -0.2, 0.2, &mut rng);
            let got = linear(&mut dev, &phys, op as u64, &x, &w, Some(&b)).unwrap();
            let mut want = x.matmul(&w).unwrap();
            for r in 0..batch {
                for (v, bv) in want.row_mut(r).iter_mut().zip(b.data()) {
                    *v += bv;
                }
            }
            assert_close(got.data(), want.data(), 1e-3)
                .unwrap_or_else(|e| panic!("({batch},{k},{m}): {e}"));
        }
    }

    #[test]
    fn locked_inscription_tracks_dense_within_device_budget() {
        let phys = PhysicsConfig {
            bank_rows: 10,
            bank_cols: 5,
            lock: true,
            ..PhysicsConfig::ideal()
        };
        let mut dev = disp_for(&phys);
        let mut rng = Pcg64::seed(4);
        let x = Tensor::rand_uniform(&[2, 7], 0.0, 1.0, &mut rng);
        let w = Tensor::rand_uniform(&[7, 12], -0.9, 0.9, &mut rng);
        let got = linear(&mut dev, &phys, 0, &x, &w, None).unwrap();
        let want = x.matmul(&w).unwrap();
        // lock residual ~2e-3/ring, amplified by the inscription gain and
        // summed over k terms: generous 5σ-style budget, plus correlation
        assert_close(got.data(), want.data(), 0.15 * 7.0).unwrap();
        let c = crate::util::stats::correlation(
            &got.data().iter().map(|&v| v as f64).collect::<Vec<_>>(),
            &want.data().iter().map(|&v| v as f64).collect::<Vec<_>>(),
        );
        assert!(c > 0.98, "correlation {c}");
    }

    #[test]
    fn converter_resolution_degrades_fidelity() {
        let mut rng = Pcg64::seed(8);
        let x = Tensor::rand_uniform(&[2, 9], 0.0, 1.0, &mut rng);
        let w = Tensor::rand_uniform(&[9, 6], -0.9, 0.9, &mut rng);
        let want = x.matmul(&w).unwrap();
        let err_at = |dac: u32, adc: u32| {
            let phys = PhysicsConfig { dac_bits: dac, adc_bits: adc, ..small_physics() };
            let mut dev = disp_for(&phys);
            let got = linear(&mut dev, &phys, 0, &x, &w, None).unwrap();
            got.data()
                .iter()
                .zip(want.data())
                .map(|(g, w)| (g - w).abs() as f64)
                .fold(0.0, f64::max)
        };
        let exact = err_at(0, 0);
        let coarse = err_at(2, 2);
        assert!(exact < 1e-4, "ideal converters should be transparent: {exact}");
        assert!(coarse > 10.0 * exact.max(1e-6), "2-bit converters: {coarse}");
    }

    #[test]
    fn read_noise_hits_gradient_readouts_only() {
        let phys = PhysicsConfig { sigma: 0.1, ..small_physics() };
        let clean = small_physics();
        let mut rng = Pcg64::seed(9);
        let x = Tensor::rand_uniform(&[1, 5], 0.0, 1.0, &mut rng);
        let w = Tensor::rand_uniform(&[5, 7], -0.9, 0.9, &mut rng);
        // forward inference is exempt from the lumped gradient-read σ
        let a = linear(&mut disp_for(&phys), &phys, 0, &x, &w, None).unwrap();
        let c = linear(&mut disp_for(&clean), &clean, 0, &x, &w, None).unwrap();
        assert_eq!(a, c, "sigma must not perturb the forward chain");
        // the B·e path picks it up, deterministically per (seed, op, row)
        let bmat = Tensor::rand_uniform(&[7, 5], -0.9, 0.9, &mut rng);
        let e = Tensor::randn(&[2, 5], 0.5, &mut rng);
        let act = Tensor::full(&[2, 7], 1.0);
        let g1 = gradient(&mut disp_for(&phys), &phys, 0, &bmat, &e, &act).unwrap();
        let g2 = gradient(&mut disp_for(&phys), &phys, 0, &bmat, &e, &act).unwrap();
        assert_eq!(g1, g2, "same device seed + op, same draw");
        let g3 = gradient(&mut disp_for(&clean), &clean, 0, &bmat, &e, &act).unwrap();
        assert_ne!(g1, g3, "sigma=0.1 must perturb the gradient readout");
        // a different bank-op counter is a different noise stream
        let g4 = gradient(&mut disp_for(&phys), &phys, 1, &bmat, &e, &act).unwrap();
        assert_ne!(g1, g4, "op counter must advance the noise stream");
    }

    #[test]
    fn nan_input_darks_its_channel_only() {
        // regression companion to the converter NaN fix: one NaN feature
        // must not poison the other channels of the matvec
        let phys = small_physics();
        let mut dev = disp_for(&phys);
        let mut x = Tensor::rand_uniform(&[1, 5], 0.1, 1.0, &mut Pcg64::seed(3));
        let w = Tensor::rand_uniform(&[5, 4], -0.9, 0.9, &mut Pcg64::seed(4));
        let clean = linear(&mut dev, &phys, 0, &x, &w, None).unwrap();
        assert!(clean.data().iter().all(|v| v.is_finite()));
        x.set(0, 2, f32::NAN);
        let poisoned = linear(&mut dev, &phys, 1, &x, &w, None).unwrap();
        assert!(
            poisoned.data().iter().all(|v| v.is_finite()),
            "NaN leaked through the analog path: {:?}",
            poisoned.data()
        );
        // the surviving channels still contribute
        assert!(poisoned.data().iter().any(|v| v.abs() > 1e-3));
    }

    #[test]
    fn dfa_gradient_masks_inactive_rows() {
        let phys = small_physics();
        let mut dev = disp_for(&phys);
        let mut rng = Pcg64::seed(6);
        let bmat = Tensor::rand_uniform(&[9, 4], -0.9, 0.9, &mut rng);
        let e = Tensor::randn(&[3, 4], 0.5, &mut rng);
        let mut a = Tensor::randn(&[3, 9], 1.0, &mut rng);
        for j in 0..9 {
            a.set(1, j, -1.0); // sample 1 fully inactive
        }
        let d = gradient(&mut dev, &phys, 0, &bmat, &e, &a).unwrap();
        assert_eq!(d.shape(), &[9, 3]);
        for j in 0..9 {
            assert_eq!(d.at(j, 1), 0.0, "row {j} of the dead sample");
        }
        // ideal physics: live entries match B·e ⊙ g'(a)
        let dense = bmat.matmul(&e.t()).unwrap();
        for j in 0..9 {
            for smp in [0usize, 2] {
                let want = if a.at(smp, j) > 0.0 { dense.at(j, smp) } else { 0.0 };
                assert!(
                    (d.at(j, smp) - want).abs() < 1e-3,
                    "({j},{smp}): {} vs {want}",
                    d.at(j, smp)
                );
            }
        }
        // and under read noise: dead rows stay exactly zero — the noise
        // enters pre-TIA, so the g'(a) mask gates it like the reference
        // model's mask x (B·e + noise)
        let noisy = PhysicsConfig { sigma: 0.2, ..small_physics() };
        let dn = gradient(&mut disp_for(&noisy), &noisy, 0, &bmat, &e, &a).unwrap();
        for j in 0..9 {
            assert_eq!(dn.at(j, 1), 0.0, "noisy dead row {j}");
        }
        assert_ne!(dn, d, "sigma=0.2 must perturb the live rows");
    }

    #[test]
    fn row_sharding_is_bit_identical_at_any_thread_count() {
        // the tentpole guarantee: every result — forward, gradient, cycle
        // tally — is a pure function of the inputs, not of the thread count
        let phys = PhysicsConfig {
            sigma: 0.15,
            dac_bits: 6,
            adc_bits: 6,
            ..small_physics()
        };
        let mut rng = Pcg64::seed(12);
        let x = Tensor::rand_uniform(&[5, 11], 0.0, 1.0, &mut rng);
        let w = Tensor::rand_uniform(&[11, 9], -0.9, 0.9, &mut rng);
        let bmat = Tensor::rand_uniform(&[9, 11], -0.9, 0.9, &mut rng);
        let e = Tensor::randn(&[5, 11], 0.5, &mut rng);
        let act = Tensor::full(&[5, 9], 1.0);
        let run = |threads: usize| {
            let mut disp = BankDispatcher::new(phys, threads).unwrap();
            let (y, fy) = disp.linear(0, &x, &w, None).unwrap();
            let (g, fg) = disp.dfa_gradient(1, &bmat, &e, &act).unwrap();
            (y, g, fy + fg)
        };
        let (y1, g1, c1) = run(1);
        assert!(c1 > 0);
        for threads in [2, 3, 8] {
            let (y, g, c) = run(threads);
            assert_eq!(y, y1, "{threads} threads: forward diverged");
            assert_eq!(g, g1, "{threads} threads: gradient diverged");
            assert_eq!(c, c1, "{threads} threads: cycle tally diverged");
        }
    }

    #[test]
    fn row_noise_streams_are_prefix_stable() {
        // growing the batch must not change earlier rows' noise draws:
        // each row's stream is keyed by its index, not carved from a
        // shared sequential stream (the pre-refactor failure mode, where
        // the second tile's draws shifted when a row was appended)
        let phys = PhysicsConfig { sigma: 0.2, ..small_physics() };
        let mut rng = Pcg64::seed(14);
        let bmat = Tensor::rand_uniform(&[9, 11], -0.9, 0.9, &mut rng); // multi-tile
        let e2 = Tensor::randn(&[2, 11], 0.5, &mut rng);
        let extra = Tensor::randn(&[1, 11], 0.5, &mut rng);
        let mut e3_data = e2.data().to_vec();
        e3_data.extend_from_slice(extra.data());
        let e3 = Tensor::new(&[3, 11], e3_data).unwrap();
        let act2 = Tensor::full(&[2, 9], 1.0);
        let act3 = Tensor::full(&[3, 9], 1.0);
        let g2 = gradient(&mut disp_for(&phys), &phys, 0, &bmat, &e2, &act2).unwrap();
        let g3 = gradient(&mut disp_for(&phys), &phys, 0, &bmat, &e3, &act3).unwrap();
        for j in 0..9 {
            for smp in 0..2 {
                assert_eq!(
                    g3.at(j, smp),
                    g2.at(j, smp),
                    "({j},{smp}): appending a row changed an earlier row's draws"
                );
            }
        }
    }

    #[test]
    fn artifact_execute_is_thread_count_invariant_and_counts_cycles() {
        // end-to-end dfa_step dispatch under live read noise: engines
        // opened at different --threads must produce identical outputs,
        // and cycles() reads lock-free
        let dir = std::env::temp_dir().join("pdfa_no_artifacts_here");
        let phys = PhysicsConfig {
            bank_rows: 16,
            bank_cols: 12,
            sigma: 0.1,
            ..PhysicsConfig::ideal()
        };
        let dims = PhotonicEngine::open(&dir, phys).unwrap().net_dims("tiny").unwrap();
        let mut rng = Pcg64::seed(5);
        let state = NetState::init(&dims, &mut rng);
        let (b1, b2) = NetState::init_feedback(&dims, &mut rng);
        let x = Tensor::randn(&[dims.batch, dims.d_in], 0.5, &mut rng);
        let mut y = Tensor::zeros(&[dims.batch, dims.d_out]);
        for r in 0..dims.batch {
            y.set(r, r % dims.d_out, 1.0);
        }
        let mut inputs = state.tensors.clone();
        inputs.extend([
            b1,
            b2,
            x,
            y,
            Tensor::zeros(&[dims.d_h1, dims.batch]),
            Tensor::zeros(&[dims.d_h2, dims.batch]),
            Tensor::scalar(0.0),
            Tensor::scalar(0.0),
            Tensor::scalar(0.05),
            Tensor::scalar(0.9),
        ]);
        let run = |threads: usize| {
            let engine = PhotonicEngine::open_threaded(&dir, phys, threads).unwrap();
            assert_eq!(engine.threads(), threads);
            let art = engine.load("dfa_step_tiny").unwrap();
            let out = art.execute(&inputs).unwrap();
            (out, engine.telemetry())
        };
        let (want, tel1) = run(1);
        let (got, tel4) = run(4);
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g, w, "output {i} diverged across thread counts");
        }
        // the tentpole extension of PR 4's determinism contract: the
        // telemetry snapshot (counters AND priced energy) is identical too
        assert_eq!(tel1, tel4, "telemetry diverged across thread counts");
        assert!(tel1.cycles > 0 && tel1.energy_j > 0.0, "{tel1:?}");
        assert_eq!(tel1.pj_per_mac(), tel4.pj_per_mac());
        // cycles() is lock-free and tallies the whole dispatch (the test
        // module can build the concrete artifact directly)
        let spec = NativeEngine::open(&dir)
            .unwrap()
            .load("dfa_step_tiny")
            .unwrap()
            .spec()
            .clone();
        let art = PhotonicArtifact {
            spec,
            kind: Kind::DfaStep,
            dispatcher: Mutex::new(BankDispatcher::new(phys, 2).unwrap()),
            drift: Arc::new(Mutex::new(DriftModel::new(
                16,
                12,
                0.0,
                0.0,
                0.0,
                phys.seed,
                &MrrDesign::high_finesse(),
            ))),
            cycles: AtomicU64::new(0),
            counters: Arc::new(Counters::default()),
            bank_macs: telemetry::macs_forward(&dims) + telemetry::macs_feedback(&dims),
            digital_macs: telemetry::macs_weight_grads(&dims),
            bank_ops: 5,
        };
        assert_eq!(art.cycles(), 0);
        Artifact::execute(&art, &inputs).unwrap();
        assert!(art.cycles() > 0, "dispatch must tally optical cycles");
        // the op sequence now lives in the engine-shared counters (it is
        // checkpointed with the device state)
        assert_eq!(art.counters.op_seq(), 5, "3 fwd + 2 gradient ops");
        // the engine-shared counters saw the same dispatch: identical
        // cycle tally, analytic MAC split, one energy-priced snapshot
        let t = art.counters.snapshot(Some(&phys.energy_model()));
        assert_eq!(t.cycles, art.cycles());
        assert_eq!(t.photonic_macs, art.bank_macs);
        assert_eq!(t.macs, art.bank_macs + art.digital_macs);
        assert_eq!(t.bank_ops, 5);
        assert_eq!(t.energy_j, phys.energy_model().joules(t.cycles));
        assert!(t.energy_j > 0.0);
    }

    #[test]
    fn engine_serves_photonic_vocabulary() {
        let dir = std::env::temp_dir().join("pdfa_no_artifacts_here");
        let e = PhotonicEngine::open(&dir, PhysicsConfig::ideal()).unwrap();
        assert_eq!(e.platform_name(), "photonic");
        let names: Vec<String> = e.artifact_specs().iter().map(|s| s.name.clone()).collect();
        assert_eq!(names.len(), 10); // 3 per config x 3 configs + photonic_matvec
        assert!(names.iter().all(|n| !n.starts_with("bp_step_")));
        assert!(e.load("fwd_tiny").is_ok());
        assert!(e.load("dfa_step_tiny").is_ok());
        assert!(e.load("apply_grads_tiny").is_ok());
        assert!(e.load("photonic_matvec").is_ok());
        let err = e.load("bp_step_tiny").unwrap_err().to_string();
        assert!(err.contains("backend native"), "{err}");
        assert!(e.load("nonexistent").is_err());
    }

    #[test]
    fn ideal_fwd_reproduces_native_logits() {
        let dir = std::env::temp_dir().join("pdfa_no_artifacts_here");
        let phys = PhysicsConfig { bank_rows: 16, bank_cols: 12, ..PhysicsConfig::ideal() };
        let photonic = PhotonicEngine::open(&dir, phys).unwrap();
        let native = NativeEngine::open(&dir).unwrap();
        let dims = native.net_dims("tiny").unwrap();
        let mut rng = Pcg64::seed(2);
        let state = NetState::init(&dims, &mut rng);
        let x = Tensor::randn(&[dims.batch, dims.d_in], 0.7, &mut rng);
        let mut inputs: Vec<Tensor> = state.tensors[..6].to_vec();
        inputs.push(x);
        let want = native.load("fwd_tiny").unwrap().execute(&inputs).unwrap();
        let got = photonic.load("fwd_tiny").unwrap().execute(&inputs).unwrap();
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_close(g.data(), w.data(), IDEAL_LOGIT_TOL)
                .unwrap_or_else(|e| panic!("{e}"));
        }
    }

    #[test]
    fn dfa_step_rejects_gaussian_noise_inputs() {
        let dir = std::env::temp_dir().join("pdfa_no_artifacts_here");
        let phys = PhysicsConfig { bank_rows: 16, bank_cols: 12, ..PhysicsConfig::ideal() };
        let e = PhotonicEngine::open(&dir, phys).unwrap();
        let art = e.load("dfa_step_tiny").unwrap();
        let dims = e.net_dims("tiny").unwrap();
        let mut rng = Pcg64::seed(3);
        let state = NetState::init(&dims, &mut rng);
        let (b1, b2) = NetState::init_feedback(&dims, &mut rng);
        let x = Tensor::randn(&[dims.batch, dims.d_in], 0.5, &mut rng);
        let mut y = Tensor::zeros(&[dims.batch, dims.d_out]);
        for r in 0..dims.batch {
            y.set(r, r % dims.d_out, 1.0);
        }
        let n1 = Tensor::zeros(&[dims.d_h1, dims.batch]);
        let n2 = Tensor::zeros(&[dims.d_h2, dims.batch]);
        let mut inputs = state.tensors.clone();
        inputs.extend([
            b1, b2, x, y, n1, n2,
            Tensor::scalar(0.1), // sigma: the Gaussian model, not ours
            Tensor::scalar(0.0),
            Tensor::scalar(0.05),
            Tensor::scalar(0.9),
        ]);
        let err = art.execute(&inputs).unwrap_err().to_string();
        assert!(err.contains("--physics"), "{err}");
        // zero sigma/bits executes the full in-situ step
        inputs[18] = Tensor::scalar(0.0);
        let out = art.execute(&inputs).unwrap();
        assert_eq!(out.len(), 14);
        assert!(out[12].item().is_finite());
    }

    /// Forward-artifact inputs for the lifetime tests: 6 params + x.
    fn fwd_inputs(dims: &crate::runtime::manifest::NetDims) -> Vec<Tensor> {
        let mut rng = Pcg64::seed(11);
        let state = NetState::init(dims, &mut rng);
        let mut inputs: Vec<Tensor> = state.tensors[..6].to_vec();
        inputs.push(Tensor::randn(&[dims.batch, dims.d_in], 0.7, &mut rng));
        inputs
    }

    #[test]
    fn drift_faults_fire_the_recalibration_scheduler() {
        use crate::photonics::drift::FaultKind;
        // a scripted package-temperature step at tick 1 knocks every ring
        // off its calibration; the armed scheduler must buy the device
        // back (and charge for it), the disarmed one must keep serving
        // degraded outputs
        let dir = std::env::temp_dir().join("pdfa_no_artifacts_here");
        let dims = PhotonicEngine::open(&dir, PhysicsConfig::ideal())
            .unwrap()
            .net_dims("tiny")
            .unwrap();
        let inputs = fwd_inputs(&dims);
        let phys_at = |threshold: f64| PhysicsConfig {
            bank_rows: 16,
            bank_cols: 12,
            recal_threshold: threshold,
            ..PhysicsConfig::ideal()
        };
        let run = |threshold: f64, threads: usize| {
            let engine =
                PhotonicEngine::open_threaded(&dir, phys_at(threshold), threads).unwrap();
            engine
                .inject_faults(&[FaultEvent {
                    at_tick: 1,
                    kind: FaultKind::StepDrift { phase: 0.05 },
                }])
                .unwrap();
            let art = engine.load("fwd_tiny").unwrap();
            // device time starts at tick 0: the first dispatch sees the
            // factory-calibrated bank
            let clean = art.execute(&inputs).unwrap();
            // the loop condition reads the (thread-invariant) cycle
            // tally, so every thread count executes the same schedule
            for _ in 0..200 {
                if engine.telemetry().cycles >= 2 * DRIFT_TICK_CYCLES {
                    break;
                }
                art.execute(&inputs).unwrap();
            }
            let tel = engine.telemetry();
            assert!(tel.cycles >= 2 * DRIFT_TICK_CYCLES, "loop cap too low: {tel:?}");
            // device time has certainly passed the fault tick by now
            let out = art.execute(&inputs).unwrap();
            (clean, out, engine.telemetry())
        };
        let (clean, recovered, tel_on) = run(0.01, 1);
        assert!(tel_on.recal_events >= 1, "{tel_on:?}");
        assert!(tel_on.recal_cycles > 0, "{tel_on:?}");
        assert_eq!(tel_on.drift_err, 0.0, "recal re-locked the error away");
        // the §5 model prices the recalibration readouts with the compute
        assert_eq!(
            tel_on.energy_j,
            phys_at(0.01).energy_model().joules(tel_on.cycles + tel_on.recal_cycles)
        );
        assert_eq!(
            clean.iter().zip(&recovered).filter(|(c, r)| c != r).count(),
            0,
            "recalibration must restore the clean outputs bit-exactly"
        );
        // threshold 0 disarms the scheduler: the fault persists
        let (clean_off, degraded, tel_off) = run(0.0, 1);
        assert_eq!(tel_off.recal_events, 0, "{tel_off:?}");
        assert!(tel_off.drift_err > 0.0, "{tel_off:?}");
        assert_eq!(clean_off.len(), clean.len());
        assert!(
            clean.iter().zip(&degraded).any(|(c, d)| c != d),
            "an unrecalibrated 0.05 rad step must show up in the outputs"
        );
        // the whole lifetime machinery is thread-count invariant
        let (_, recovered4, tel_on4) = run(0.01, 4);
        assert_eq!(recovered4, recovered, "outputs diverged across thread counts");
        assert_eq!(tel_on4, tel_on, "telemetry diverged across thread counts");
    }

    #[test]
    fn device_state_round_trips_for_bit_exact_resume() {
        // the checkpoint contract: device_state() after N steps, restored
        // into a fresh engine, continues bit-identically — locked
        // inscription noise, read noise, drift walk and telemetry alike
        let dir = std::env::temp_dir().join("pdfa_no_artifacts_here");
        let phys = PhysicsConfig {
            bank_rows: 16,
            bank_cols: 12,
            sigma: 0.1,
            dac_bits: 6,
            adc_bits: 6,
            lock: true,
            drift_rate: 1e-3,
            drift_aging: 1e-5,
            recal_threshold: 0.5,
            ..PhysicsConfig::ideal()
        };
        let engine = PhotonicEngine::open(&dir, phys).unwrap();
        let dims = engine.net_dims("tiny").unwrap();
        let inputs = fwd_inputs(&dims);
        let art = engine.load("fwd_tiny").unwrap();
        for _ in 0..25 {
            art.execute(&inputs).unwrap();
        }
        let blob = engine.device_state().expect("photonic engines checkpoint");
        let want_next = art.execute(&inputs).unwrap();
        let tel_a = engine.telemetry();
        assert!(tel_a.drift_err > 0.0, "the walk must have engaged: {tel_a:?}");

        let resumed = PhotonicEngine::open(&dir, phys).unwrap();
        resumed.restore_device_state(&blob).unwrap();
        let got_next = resumed.load("fwd_tiny").unwrap().execute(&inputs).unwrap();
        assert_eq!(got_next.len(), want_next.len());
        for (i, (g, w)) in got_next.iter().zip(&want_next).enumerate() {
            assert_eq!(g, w, "output {i}: resumed run diverged");
        }
        assert_eq!(resumed.telemetry(), tel_a, "telemetry diverged after resume");

        // malformed blobs are rejected before any state is overwritten
        assert!(resumed.restore_device_state(&blob[..10]).is_err());
        let mut bad = blob.clone();
        bad[0] = b'X';
        assert!(resumed.restore_device_state(&bad).is_err());
        let mut truncated = blob.clone();
        truncated.pop();
        assert!(resumed.restore_device_state(&truncated).is_err());
        // a different bank geometry is a different device
        let other = PhotonicEngine::open(
            &dir,
            PhysicsConfig { bank_rows: 8, bank_cols: 6, ..phys },
        )
        .unwrap();
        assert!(other.restore_device_state(&blob).is_err());
        // digital backends have no device state
        assert!(NativeEngine::open(&dir).unwrap().device_state().is_none());
        assert!(NativeEngine::open(&dir).unwrap().restore_device_state(&blob).is_err());
    }
}
