//! PJRT execution engine: compile-once, execute-many (`--features pjrt`).
//!
//! Owns the PJRT CPU client and a cache of compiled executables keyed by
//! artifact name. Marshals [`Tensor`]s to XLA `Literal`s (validated against
//! the manifest's shapes) and decomposes the tuple result back into
//! `Tensor`s. One `execute` call == one training step == one PJRT dispatch;
//! Python is never involved. Implements [`StepEngine`]/[`Artifact`] so the
//! trainer is oblivious to which backend runs the step.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::runtime::manifest::{ArtifactSpec, Manifest, NetDims};
use crate::runtime::step_engine::{Artifact, StepEngine};
use crate::telemetry::{self, Counters, Telemetry};
use crate::tensor::Tensor;
use crate::{Error, Result};

/// A compiled artifact ready for execution.
pub struct LoadedArtifact {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    /// Analytic MACs of one successful `execute` (from the manifest
    /// shapes — the PJRT runtime exposes no hardware counters).
    macs: u64,
    /// Engine-shared telemetry cells.
    counters: Arc<Counters>,
}

impl LoadedArtifact {
    /// Execute with positional inputs; returns outputs in manifest order.
    pub fn execute(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.spec.validate_inputs(inputs)?;
        // Upload inputs as PjRtBuffers we own and execute via execute_b:
        // the crate's literal-based `execute` leaks the input device
        // buffers it creates internally (xla_rs.cc releases without
        // deleting) — ~13 MB/step on the mnist config. Buffers created
        // here are freed on drop.
        let client = self.exe.client();
        let mut buffers = Vec::with_capacity(inputs.len());
        for t in inputs {
            buffers.push(client.buffer_from_host_buffer::<f32>(
                t.data(),
                t.shape(),
                None,
            )?);
        }

        let result = self.exe.execute_b(&buffers)?;
        let buffer = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| Error::msg("PJRT returned no output buffer"))?;
        let tuple = buffer.to_literal_sync()?;
        let elements = tuple.to_tuple()?;
        if elements.len() != self.spec.outputs.len() {
            return Err(Error::Shape(format!(
                "artifact {}: manifest promises {} outputs, runtime produced {}",
                self.spec.name,
                self.spec.outputs.len(),
                elements.len()
            )));
        }
        let out: Result<Vec<Tensor>> = elements
            .into_iter()
            .zip(&self.spec.outputs)
            .map(|(lit, spec)| literal_to_tensor(&lit, &spec.shape))
            .collect();
        if out.is_ok() {
            self.counters.add_macs(self.macs);
        }
        out
    }
}

impl Artifact for LoadedArtifact {
    fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    fn execute(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        LoadedArtifact::execute(self, inputs)
    }
}

/// Convert a row-major f32 [`Tensor`] into an XLA `Literal`.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    if t.rank() == 0 {
        return Ok(xla::Literal::scalar(t.item()));
    }
    let flat = xla::Literal::vec1(t.data());
    if t.rank() == 1 {
        return Ok(flat);
    }
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    Ok(flat.reshape(&dims)?)
}

/// Convert an XLA `Literal` back into a [`Tensor`] of the expected shape.
pub fn literal_to_tensor(lit: &xla::Literal, shape: &[usize]) -> Result<Tensor> {
    let data = lit.to_vec::<f32>()?;
    Tensor::new(shape, data)
}

/// Compile-once execute-many engine over an artifact directory.
pub struct Engine {
    manifest: Manifest,
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<LoadedArtifact>>>,
    /// Telemetry cells shared with every compiled artifact (analytic
    /// MAC counts from the manifest shapes).
    counters: Arc<Counters>,
}

// xla::PjRtClient wraps a thread-safe C++ client; executables are immutable
// after compilation. The Mutex guards only the cache map itself.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Create a CPU PJRT client over `artifacts_dir` (must hold manifest.json).
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        crate::log_info!(
            "PJRT client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Engine {
            manifest,
            client,
            cache: Mutex::new(HashMap::new()),
            counters: Arc::new(Counters::default()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load (compile) an artifact, or fetch it from the cache.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<LoadedArtifact>> {
        if let Some(hit) = self.cache.lock().unwrap().get(name) {
            return Ok(hit.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        // lint: timing: one-shot compile-latency log line
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            spec.path
                .to_str()
                .ok_or_else(|| Error::msg("non-utf8 artifact path"))?,
        )?;
        let computation = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&computation)?;
        crate::log_info!("compiled artifact '{name}' in {:.2?}", t0.elapsed());
        let macs = if name == "photonic_matvec" {
            spec.inputs[1].shape.iter().product::<usize>() as u64
        } else {
            self.manifest
                .net_dims(&spec.config)
                .map_or(0, |d| telemetry::macs_for_artifact(name, d))
        };
        let loaded = std::sync::Arc::new(LoadedArtifact {
            spec,
            exe,
            macs,
            counters: self.counters.clone(),
        });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), loaded.clone());
        Ok(loaded)
    }
}

impl StepEngine for Engine {
    fn platform_name(&self) -> String {
        Engine::platform_name(self)
    }

    fn net_dims(&self, config: &str) -> Result<NetDims> {
        self.manifest.net_dims(config).cloned()
    }

    fn configs(&self) -> Vec<(String, NetDims)> {
        self.manifest
            .configs
            .iter()
            .map(|(n, d)| (n.clone(), d.clone()))
            .collect()
    }

    fn artifact_specs(&self) -> Vec<ArtifactSpec> {
        self.manifest.artifacts.values().cloned().collect()
    }

    fn load(&self, name: &str) -> Result<std::sync::Arc<dyn Artifact>> {
        Ok(Engine::load(self, name)?)
    }

    fn telemetry(&self) -> Telemetry {
        self.counters.snapshot(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn engine() -> Option<Engine> {
        let dir = artifacts_dir();
        if dir.join("manifest.json").exists() {
            Some(Engine::new(dir).unwrap())
        } else {
            None // `make artifacts` not run; integration tests cover this
        }
    }

    #[test]
    fn literal_roundtrip() {
        let mut rng = Pcg64::seed(0);
        for shape in [vec![], vec![5], vec![3, 4], vec![2, 3, 4]] {
            let t = Tensor::randn(&shape, 1.0, &mut rng);
            let lit = tensor_to_literal(&t).unwrap();
            let back = literal_to_tensor(&lit, &shape).unwrap();
            assert_eq!(t, back);
        }
    }

    #[test]
    fn forward_artifact_runs_and_matches_cpu_reference() {
        let Some(engine) = engine() else { return };
        let fwd = engine.load("fwd_tiny").unwrap();
        let dims = engine.manifest().net_dims("tiny").unwrap().clone();
        let mut rng = Pcg64::seed(7);
        let inputs: Vec<Tensor> = fwd
            .spec
            .inputs
            .iter()
            .map(|s| Tensor::randn(&s.shape, 0.3, &mut rng))
            .collect();
        let out = fwd.execute(&inputs).unwrap();
        assert_eq!(out.len(), 5);
        assert_eq!(out[0].shape(), &[dims.batch, dims.d_out]);

        // independent check: a1 = x @ w1 + b1 computed with tensor::ops
        let (w1, b1, x) = (&inputs[0], &inputs[1], &inputs[6]);
        let a1 = x.matmul(w1).unwrap();
        let want_a1 = Tensor::from_fn(&[dims.batch, dims.d_h1], |i| {
            a1.data()[i] + b1.data()[i % dims.d_h1]
        });
        crate::util::check::assert_close(out[1].data(), want_a1.data(), 1e-4).unwrap();
        // h1 = relu(a1)
        let relu = want_a1.map(|v| v.max(0.0));
        crate::util::check::assert_close(out[3].data(), relu.data(), 1e-4).unwrap();
    }

    #[test]
    fn shape_validation_rejects_wrong_inputs() {
        let Some(engine) = engine() else { return };
        let fwd = engine.load("fwd_tiny").unwrap();
        let bad: Vec<Tensor> = fwd
            .spec
            .inputs
            .iter()
            .map(|_| Tensor::zeros(&[1, 1]))
            .collect();
        assert!(fwd.execute(&bad).is_err());
        let too_few = vec![Tensor::zeros(&[16, 32])];
        assert!(fwd.execute(&too_few).is_err());
    }

    #[test]
    fn cache_returns_same_executable() {
        let Some(engine) = engine() else { return };
        let a = engine.load("fwd_tiny").unwrap();
        let b = engine.load("fwd_tiny").unwrap();
        assert!(std::sync::Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn named_execution_resolves_order() {
        use crate::runtime::step_engine::Artifact as _;
        let Some(engine) = engine() else { return };
        let fwd = engine.load("fwd_tiny").unwrap();
        let mut rng = Pcg64::seed(9);
        let tensors: Vec<(String, Tensor)> = fwd
            .spec
            .inputs
            .iter()
            .map(|s| (s.name.clone(), Tensor::randn(&s.shape, 0.3, &mut rng)))
            .collect();
        // shuffled name order must give identical results to positional
        let positional: Vec<Tensor> = tensors.iter().map(|(_, t)| t.clone()).collect();
        let want = fwd.execute(&positional).unwrap();
        let mut named: Vec<(&str, &Tensor)> = tensors
            .iter()
            .map(|(n, t)| (n.as_str(), t))
            .collect();
        named.reverse();
        let got = fwd.execute_named(&named).unwrap();
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g, w);
        }
        // missing input
        assert!(fwd.execute_named(&named[1..]).is_err());
    }
}
