//! Backend abstraction for training-step execution.
//!
//! The trainer, experiments and CLI only ever talk to [`StepEngine`] /
//! [`Artifact`]; *which* substrate runs the math is a deployment choice:
//!
//! * [`crate::runtime::native::NativeEngine`] — pure Rust, always
//!   available, executes the manifest's training-step contract through
//!   [`crate::dfa::reference`] (the op-for-op twin of the JAX model).
//! * [`crate::runtime::engine::Engine`] (`--features pjrt`) — the
//!   compile-once/execute-many PJRT path over the AOT HLO artifacts.
//! * [`crate::runtime::photonic::PhotonicEngine`] — in-situ execution:
//!   every matvec of the training step routed through the device-level
//!   MRR weight-bank simulator under a [`PhysicsConfig`].
//!
//! Both backends speak the same artifact vocabulary (`fwd_<cfg>`,
//! `dfa_step_<cfg>`, `bp_step_<cfg>`, `apply_grads_<cfg>`,
//! `photonic_matvec`) with identical input/output names, shapes and
//! ordering, so every caller — and every test — is backend-agnostic.

use std::path::Path;
use std::sync::Arc;

use crate::runtime::manifest::{ArtifactSpec, NetDims};
use crate::runtime::photonic::PhysicsConfig;
use crate::telemetry::Telemetry;
use crate::tensor::Tensor;
use crate::{Error, Result};

/// An executable training-step artifact (one PJRT dispatch or one native
/// reference-math call per `execute`).
pub trait Artifact: Send + Sync {
    /// The manifest contract this artifact satisfies.
    fn spec(&self) -> &ArtifactSpec;

    /// Execute with positional inputs; returns outputs in manifest order.
    fn execute(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>>;

    /// Execute with named inputs (order-independent, spec resolves).
    fn execute_named(&self, named: &[(&str, &Tensor)]) -> Result<Vec<Tensor>> {
        let spec = self.spec();
        let mut slots: Vec<Option<&Tensor>> = vec![None; spec.inputs.len()];
        for (name, t) in named {
            let idx = spec.input_index(name)?;
            if slots[idx].replace(t).is_some() {
                return Err(Error::Shape(format!("duplicate input '{name}'")));
            }
        }
        let inputs: Result<Vec<Tensor>> = slots
            .iter()
            .enumerate()
            .map(|(i, s)| {
                s.cloned().ok_or_else(|| {
                    Error::Shape(format!(
                        "missing input '{}' for artifact {}",
                        spec.inputs[i].name, spec.name
                    ))
                })
            })
            .collect();
        self.execute(&inputs?)
    }
}

/// A backend that can resolve network configs and load artifacts.
pub trait StepEngine: Send + Sync {
    /// Human-readable backend identity ("native", "cpu" for PJRT, ...).
    fn platform_name(&self) -> String;

    /// Dimensions of a named network config.
    fn net_dims(&self, config: &str) -> Result<NetDims>;

    /// All known network configs, sorted by name.
    fn configs(&self) -> Vec<(String, NetDims)>;

    /// Specs of every artifact this backend can load (cheap; does not
    /// compile anything).
    fn artifact_specs(&self) -> Vec<ArtifactSpec>;

    /// Load (and for PJRT, compile) an artifact by name.
    fn load(&self, name: &str) -> Result<Arc<dyn Artifact>>;

    /// Lock-free snapshot of the engine's accumulated hardware telemetry:
    /// MACs dispatched (counted analytically from artifact shapes),
    /// optical cycles fired, and — on the photonic backend — the modeled
    /// energy of those cycles under the §5 component budget.
    ///
    /// Counters accrue across every artifact loaded from this engine.
    /// Taken between dispatches the snapshot is exact; taken mid-dispatch
    /// it is a valid lower bound. Counter values are bit-identical at any
    /// worker-thread count (see [`crate::telemetry`]), so snapshots may
    /// be diffed ([`Telemetry::delta`]) and recorded deterministically.
    fn telemetry(&self) -> Telemetry {
        Telemetry::default()
    }

    /// Opaque resumable device state, checkpointed as the `device` field
    /// of a v2 training checkpoint. The photonic engine serializes its
    /// drift model, telemetry tallies and bank-op sequence — everything
    /// a resumed run needs to continue bit-identically to an
    /// uninterrupted one. Stateless digital backends return `None`.
    fn device_state(&self) -> Option<Vec<u8>> {
        None
    }

    /// Restore a [`Self::device_state`] blob taken from an engine with
    /// the same physics. Backends without device state refuse: silently
    /// dropping a checkpointed device would resume a *different* device.
    fn restore_device_state(&self, _bytes: &[u8]) -> Result<()> {
        Err(Error::Config(format!(
            "backend '{}' has no device state to restore (the checkpoint \
             was taken on a photonic engine)",
            self.platform_name()
        )))
    }
}

/// Which backend [`open`] should construct.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Backend {
    /// PJRT when built with `--features pjrt` *and* the artifact directory
    /// holds a manifest; the native engine otherwise.
    Auto,
    /// Force the pure-Rust engine (never touches the artifact directory's
    /// HLO files; uses its manifest only for extra config dims).
    Native,
    /// Force PJRT; errors without `--features pjrt` or a manifest.
    Pjrt,
    /// The in-situ device backend: every training-step matvec routed
    /// through the simulated MRR weight bank under the carried
    /// [`PhysicsConfig`].
    Photonic(PhysicsConfig),
}

impl Backend {
    /// Parse "auto" | "native" | "photonic" | "pjrt" (the `--backend` CLI
    /// values). `photonic` carries [`PhysicsConfig::default`]; callers
    /// with a `--physics` argument substitute it before [`open`].
    ///
    /// Unknown names are a hard [`Error::Cli`] enumerating every valid
    /// value — a bad `--backend` string must never fall back silently.
    pub fn parse(s: &str) -> Result<Backend> {
        match s {
            "auto" => Ok(Backend::Auto),
            "native" => Ok(Backend::Native),
            "pjrt" => Ok(Backend::Pjrt),
            "photonic" => Ok(Backend::Photonic(PhysicsConfig::default())),
            other => Err(Error::Cli(format!(
                "unknown backend '{other}' (valid values: auto | native | \
                 photonic | pjrt)"
            ))),
        }
    }
}

/// Construct a [`StepEngine`] over `artifacts_dir` per the backend policy,
/// sharding parallel work across all available cores.
///
/// The directory may not exist at all for [`Backend::Native`] /
/// [`Backend::Auto`]: the native engine then serves its built-in configs.
///
/// ```
/// use photonic_dfa::runtime::{open, Backend};
///
/// let engine = open("artifacts", Backend::Native).unwrap();
/// assert_eq!(engine.platform_name(), "native");
/// assert!(engine.net_dims("mnist").is_ok());
/// assert!(engine.telemetry().is_empty()); // nothing dispatched yet
/// ```
pub fn open(artifacts_dir: impl AsRef<Path>, backend: Backend) -> Result<Arc<dyn StepEngine>> {
    open_inner(artifacts_dir, backend, 0)
}

/// [`open`] with an explicit worker-thread count (0 = all cores, the CLI
/// `--threads` convention). The photonic engine shards batch rows across
/// this many workers; the native (and PJRT-fallback) GEMM kernels are
/// capped process-wide via [`crate::tensor::ops::set_thread_cap`] — plain
/// [`open`] leaves that cap untouched. The GEMM cap is deliberately
/// process-global (matching the one-engine-per-process CLI): the last
/// `open_threaded` call wins for every engine in the process. Library
/// callers juggling several engines with different budgets should open
/// engines directly (e.g. [`crate::runtime::PhotonicEngine::open_threaded`],
/// which carries its row-shard width per engine) and drive
/// `set_thread_cap` themselves. Every parallel path is bit-deterministic,
/// so the count changes wall-clock time only, never results.
pub fn open_threaded(
    artifacts_dir: impl AsRef<Path>,
    backend: Backend,
    threads: usize,
) -> Result<Arc<dyn StepEngine>> {
    // lint: allow(no-raw-thread-cap) — the documented process-global
    // contract above: a persistent cap set at engine open, deliberately
    // NOT a scoped ThreadCapGuard override
    crate::tensor::ops::set_thread_cap(threads);
    open_inner(artifacts_dir, backend, threads)
}

fn open_inner(
    artifacts_dir: impl AsRef<Path>,
    backend: Backend,
    threads: usize,
) -> Result<Arc<dyn StepEngine>> {
    let dir = artifacts_dir.as_ref();
    let has_manifest = dir.join("manifest.json").exists();
    match backend {
        Backend::Native => Ok(Arc::new(super::native::NativeEngine::open(dir)?)),
        Backend::Photonic(physics) => Ok(Arc::new(
            super::photonic::PhotonicEngine::open_threaded(dir, physics, threads)?,
        )),
        Backend::Pjrt => open_pjrt(dir, has_manifest),
        Backend::Auto => {
            if cfg!(feature = "pjrt") && has_manifest {
                open_pjrt(dir, true)
            } else {
                Ok(Arc::new(super::native::NativeEngine::open(dir)?))
            }
        }
    }
}

#[cfg(feature = "pjrt")]
fn open_pjrt(dir: &Path, has_manifest: bool) -> Result<Arc<dyn StepEngine>> {
    if !has_manifest {
        return Err(Error::Manifest(format!(
            "backend pjrt needs {}/manifest.json (run `make artifacts`)",
            dir.display()
        )));
    }
    Ok(Arc::new(super::engine::Engine::new(dir)?))
}

#[cfg(not(feature = "pjrt"))]
fn open_pjrt(_dir: &Path, _has_manifest: bool) -> Result<Arc<dyn StepEngine>> {
    Err(Error::Config(
        "backend pjrt requires building with `--features pjrt`".into(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_parses_cli_values() {
        assert_eq!(Backend::parse("auto").unwrap(), Backend::Auto);
        assert_eq!(Backend::parse("native").unwrap(), Backend::Native);
        assert_eq!(Backend::parse("pjrt").unwrap(), Backend::Pjrt);
        assert_eq!(
            Backend::parse("photonic").unwrap(),
            Backend::Photonic(PhysicsConfig::default())
        );
        // unknown values are a hard CLI error enumerating the valid set
        let err = Backend::parse("xla").unwrap_err().to_string();
        for valid in ["auto", "native", "photonic", "pjrt"] {
            assert!(err.contains(valid), "{err} should list {valid}");
        }
    }

    #[test]
    fn open_threaded_reaches_every_backend() {
        // `open_threaded` writes the process-global GEMM cap raw (by
        // design — the last open wins for the CLI). Wrapping the test in
        // a `ThreadCapGuard` scope serializes those raw writes against
        // every other cap-scoped test in the process and restores the
        // prior cap on every exit path, including a failing assert.
        let _cap_scope = crate::tensor::ops::ThreadCapGuard::set(0);
        let dir = std::env::temp_dir().join("pdfa_no_artifacts_here");
        let physics = crate::runtime::photonic::PhysicsConfig::ideal();
        let engine = open_threaded(&dir, Backend::Photonic(physics), 3).unwrap();
        assert_eq!(engine.platform_name(), "photonic");
        let engine = open_threaded(&dir, Backend::Native, 1).unwrap();
        assert_eq!(engine.platform_name(), "native");
    }

    #[test]
    fn photonic_backend_opens_device_engine() {
        let dir = std::env::temp_dir().join("pdfa_no_artifacts_here");
        let engine = open(
            &dir,
            Backend::Photonic(crate::runtime::photonic::PhysicsConfig::ideal()),
        )
        .unwrap();
        assert_eq!(engine.platform_name(), "photonic");
        assert!(engine.net_dims("tiny").is_ok());
        // invalid physics surfaces as an open() error
        let mut bad = crate::runtime::photonic::PhysicsConfig::ideal();
        bad.bank_cols = 0;
        assert!(open(&dir, Backend::Photonic(bad)).is_err());
    }

    #[test]
    fn auto_without_manifest_is_native() {
        let dir = std::env::temp_dir().join("pdfa_no_artifacts_here");
        let engine = open(&dir, Backend::Auto).unwrap();
        assert_eq!(engine.platform_name(), "native");
        assert!(engine.net_dims("small").is_ok());
    }

    #[test]
    fn pjrt_backend_errors_without_feature_or_manifest() {
        let dir = std::env::temp_dir().join("pdfa_no_artifacts_here");
        assert!(open(&dir, Backend::Pjrt).is_err());
    }

    #[test]
    fn named_execution_resolves_order_on_native() {
        let engine = open("artifacts", Backend::Native).unwrap();
        let fwd = engine.load("fwd_tiny").unwrap();
        let mut rng = crate::util::rng::Pcg64::seed(9);
        let tensors: Vec<(String, Tensor)> = fwd
            .spec()
            .inputs
            .iter()
            .map(|s| (s.name.clone(), Tensor::randn(&s.shape, 0.3, &mut rng)))
            .collect();
        let positional: Vec<Tensor> = tensors.iter().map(|(_, t)| t.clone()).collect();
        let want = fwd.execute(&positional).unwrap();
        let mut named: Vec<(&str, &Tensor)> = tensors
            .iter()
            .map(|(n, t)| (n.as_str(), t))
            .collect();
        named.reverse();
        let got = fwd.execute_named(&named).unwrap();
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g, w);
        }
        // missing and duplicate inputs rejected
        assert!(fwd.execute_named(&named[1..]).is_err());
        let mut dup = named.clone();
        dup[0] = dup[1];
        assert!(fwd.execute_named(&dup).is_err());
    }
}
