//! Pure-Rust execution of the training-step artifact contract.
//!
//! [`NativeEngine`] serves the same artifact vocabulary the AOT manifest
//! describes — `fwd_<cfg>`, `dfa_step_<cfg>`, `bp_step_<cfg>`,
//! `apply_grads_<cfg>` and `photonic_matvec` — but executes each one with
//! [`crate::dfa::reference`] (the op-for-op twin of `python/compile/model.py`)
//! and the L3 MRR physics instead of PJRT. No XLA toolchain, no HLO files:
//! the default build trains end-to-end with this backend alone.
//!
//! Specs are synthesised from the same `NetDims` the AOT pipeline traces
//! (`python/compile/model.py::CONFIGS`), so input/output names, shapes and
//! ordering are bit-identical to the manifest's; when an artifact directory
//! with a `manifest.json` is present its configs are merged in, letting a
//! native build drive networks traced at non-default dimensions.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::dfa::reference;
use crate::photonics::constants::{BANK_COLS, BANK_ROWS};
use crate::photonics::mrr::MrrDesign;
use crate::runtime::manifest::{ArtifactSpec, IoSpec, Manifest, NetDims};
use crate::runtime::step_engine::{Artifact, StepEngine};
use crate::telemetry::{self, Counters, Telemetry};
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Which reference routine an artifact name maps onto.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    Fwd,
    DfaStep,
    BpStep,
    ApplyGrads,
    PhotonicMatvec,
}

/// The network configs the AOT pipeline traces (model.py::CONFIGS).
pub fn builtin_configs() -> BTreeMap<String, NetDims> {
    let mut m = BTreeMap::new();
    let mut put = |name: &str, d_in, d_h1, d_h2, d_out, batch| {
        m.insert(name.to_string(), NetDims { d_in, d_h1, d_h2, d_out, batch });
    };
    put("tiny", 16, 32, 32, 4, 8);
    put("small", 784, 128, 128, 10, 64);
    put("mnist", 784, 800, 800, 10, 64);
    m
}

fn io(name: &str, shape: &[usize]) -> IoSpec {
    IoSpec { name: name.to_string(), shape: shape.to_vec(), dtype: "f32".into() }
}

/// `[w1, b1, w2, b2, w3, b3, vw1, vb1, vw2, vb2, vw3, vb3]` — the state
/// layout of `aot.py::_state_io`.
fn state_io(d: &NetDims) -> Vec<IoSpec> {
    let params = [
        ("w1", vec![d.d_in, d.d_h1]),
        ("b1", vec![d.d_h1]),
        ("w2", vec![d.d_h1, d.d_h2]),
        ("b2", vec![d.d_h2]),
        ("w3", vec![d.d_h2, d.d_out]),
        ("b3", vec![d.d_out]),
    ];
    let mut out: Vec<IoSpec> = params.iter().map(|(n, s)| io(n, s)).collect();
    out.extend(params.iter().map(|(n, s)| io(&format!("v{n}"), s)));
    out
}

fn config_specs(config: &str, d: &NetDims, dir: &Path) -> Vec<(ArtifactSpec, Kind)> {
    let path = |name: &str| dir.join(format!("{name}.hlo.txt"));
    let x = io("x", &[d.batch, d.d_in]);
    let y = io("y", &[d.batch, d.d_out]);
    let step_outputs: Vec<IoSpec> = state_io(d)
        .into_iter()
        .chain([io("loss", &[]), io("ncorrect", &[])])
        .collect();

    let fwd_name = format!("fwd_{config}");
    let fwd = ArtifactSpec {
        name: fwd_name.clone(),
        path: path(&fwd_name),
        config: config.into(),
        inputs: state_io(d)[..6].iter().cloned().chain([x.clone()]).collect(),
        outputs: vec![
            io("logits", &[d.batch, d.d_out]),
            io("a1", &[d.batch, d.d_h1]),
            io("a2", &[d.batch, d.d_h2]),
            io("h1", &[d.batch, d.d_h1]),
            io("h2", &[d.batch, d.d_h2]),
        ],
    };

    let dfa_name = format!("dfa_step_{config}");
    let dfa = ArtifactSpec {
        name: dfa_name.clone(),
        path: path(&dfa_name),
        config: config.into(),
        inputs: state_io(d)
            .into_iter()
            .chain([
                io("bmat1", &[d.d_h1, d.d_out]),
                io("bmat2", &[d.d_h2, d.d_out]),
                x.clone(),
                y.clone(),
                io("noise1", &[d.d_h1, d.batch]),
                io("noise2", &[d.d_h2, d.batch]),
                io("sigma", &[]),
                io("bits", &[]),
                io("lr", &[]),
                io("momentum", &[]),
            ])
            .collect(),
        outputs: step_outputs.clone(),
    };

    let bp_name = format!("bp_step_{config}");
    let bp = ArtifactSpec {
        name: bp_name.clone(),
        path: path(&bp_name),
        config: config.into(),
        inputs: state_io(d)
            .into_iter()
            .chain([x.clone(), y.clone(), io("lr", &[]), io("momentum", &[])])
            .collect(),
        outputs: step_outputs,
    };

    let apply_name = format!("apply_grads_{config}");
    let apply = ArtifactSpec {
        name: apply_name.clone(),
        path: path(&apply_name),
        config: config.into(),
        inputs: state_io(d)
            .into_iter()
            .chain([
                x,
                io("h1", &[d.batch, d.d_h1]),
                io("h2", &[d.batch, d.d_h2]),
                io("e", &[d.batch, d.d_out]),
                io("d1t", &[d.d_h1, d.batch]),
                io("d2t", &[d.d_h2, d.batch]),
                io("lr", &[]),
                io("momentum", &[]),
            ])
            .collect(),
        outputs: state_io(d),
    };

    vec![
        (fwd, Kind::Fwd),
        (dfa, Kind::DfaStep),
        (bp, Kind::BpStep),
        (apply, Kind::ApplyGrads),
    ]
}

fn photonic_matvec_spec(dir: &Path) -> ArtifactSpec {
    ArtifactSpec {
        name: "photonic_matvec".into(),
        path: dir.join("photonic_matvec.hlo.txt"),
        config: "bank".into(),
        inputs: vec![
            io("x", &[BANK_COLS]),
            io("phi", &[BANK_ROWS, BANK_COLS]),
            io("r", &[]),
            io("a", &[]),
        ],
        outputs: vec![io("out", &[BANK_ROWS])],
    }
}

/// The pure-Rust step engine.
pub struct NativeEngine {
    configs: BTreeMap<String, NetDims>,
    artifacts: BTreeMap<String, (ArtifactSpec, Kind)>,
    /// Telemetry cells shared with every loaded artifact. MAC counts are
    /// analytic (from the dispatch shapes), so snapshots are exact and
    /// deterministic at any thread count.
    counters: Arc<Counters>,
}

impl NativeEngine {
    /// Engine over the built-in (model.py) configs only.
    pub fn new() -> NativeEngine {
        Self::with_configs(builtin_configs(), Path::new("artifacts"))
    }

    /// Engine over `artifacts_dir`: built-in configs, plus any extra
    /// configs a `manifest.json` there declares. The directory (and the
    /// manifest) may be absent — native execution needs neither.
    pub fn open(artifacts_dir: impl AsRef<Path>) -> Result<NativeEngine> {
        let dir = artifacts_dir.as_ref();
        let mut configs = builtin_configs();
        if dir.join("manifest.json").exists() {
            let manifest = Manifest::load(dir)?;
            for (name, dims) in manifest.configs {
                configs.insert(name, dims);
            }
        }
        Ok(Self::with_configs(configs, dir))
    }

    /// Engine over an explicit config table (tests, custom networks).
    pub fn with_configs(
        configs: BTreeMap<String, NetDims>,
        dir: impl AsRef<Path>,
    ) -> NativeEngine {
        let dir = dir.as_ref();
        let mut artifacts = BTreeMap::new();
        for (name, dims) in &configs {
            for (spec, kind) in config_specs(name, dims, dir) {
                artifacts.insert(spec.name.clone(), (spec, kind));
            }
        }
        let pm = photonic_matvec_spec(dir);
        artifacts.insert(pm.name.clone(), (pm, Kind::PhotonicMatvec));
        NativeEngine { configs, artifacts, counters: Arc::new(Counters::default()) }
    }

    /// The engine's telemetry cells — shared so a wrapping engine (the
    /// photonic one delegates its digital artifacts here) aggregates into
    /// a single snapshot.
    pub(crate) fn counters(&self) -> Arc<Counters> {
        self.counters.clone()
    }
}

impl Default for NativeEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl StepEngine for NativeEngine {
    fn platform_name(&self) -> String {
        "native".into()
    }

    fn net_dims(&self, config: &str) -> Result<NetDims> {
        self.configs
            .get(config)
            .cloned()
            .ok_or_else(|| Error::Manifest(format!("no config '{config}'")))
    }

    fn configs(&self) -> Vec<(String, NetDims)> {
        self.configs
            .iter()
            .map(|(n, d)| (n.clone(), d.clone()))
            .collect()
    }

    fn artifact_specs(&self) -> Vec<ArtifactSpec> {
        self.artifacts.values().map(|(s, _)| s.clone()).collect()
    }

    fn load(&self, name: &str) -> Result<Arc<dyn Artifact>> {
        let (spec, kind) = self
            .artifacts
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("no artifact '{name}'")))?;
        // analytic MACs of one execute: from the config dims for the
        // training vocabulary, from the phi shape for the raw bank kernel
        let macs = match kind {
            Kind::PhotonicMatvec => spec.inputs[1].shape.iter().product::<usize>() as u64,
            _ => self
                .configs
                .get(&spec.config)
                .map_or(0, |d| telemetry::macs_for_artifact(name, d)),
        };
        Ok(Arc::new(NativeArtifact {
            spec: spec.clone(),
            kind: *kind,
            macs,
            counters: self.counters.clone(),
        }))
    }

    fn telemetry(&self) -> Telemetry {
        self.counters.snapshot(None)
    }
}

/// One loaded native artifact.
pub struct NativeArtifact {
    spec: ArtifactSpec,
    kind: Kind,
    /// Analytic MACs of one successful `execute`.
    macs: u64,
    /// Engine-shared telemetry cells.
    counters: Arc<Counters>,
}

impl Artifact for NativeArtifact {
    fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    // lint: boundary(panic-free-serve) — every input is spec-validated
    // on entry, and the reference kernels' shape expects/unwraps are
    // unreachable on validated shapes; a worker panic here is a bug in
    // the artifact contract, not a request-dependent path
    fn execute(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.spec.validate_inputs(inputs)?;
        let out = match self.kind {
            Kind::Fwd => {
                let f = reference::forward(&inputs[..6], &inputs[6]);
                vec![f.logits, f.a1, f.a2, f.h1, f.h2]
            }
            Kind::DfaStep => {
                let mut state: Vec<Tensor> = inputs[..12].to_vec();
                let (loss, correct) = reference::dfa_step(
                    &mut state,
                    &inputs[12],
                    &inputs[13],
                    &inputs[14],
                    &inputs[15],
                    &inputs[16],
                    &inputs[17],
                    inputs[18].item(),
                    inputs[19].item(),
                    inputs[20].item(),
                    inputs[21].item(),
                );
                state.push(Tensor::scalar(loss));
                state.push(Tensor::scalar(correct as f32));
                state
            }
            Kind::BpStep => {
                let mut state: Vec<Tensor> = inputs[..12].to_vec();
                let (loss, correct) = reference::bp_step(
                    &mut state,
                    &inputs[12],
                    &inputs[13],
                    inputs[14].item(),
                    inputs[15].item(),
                );
                state.push(Tensor::scalar(loss));
                state.push(Tensor::scalar(correct as f32));
                state
            }
            Kind::ApplyGrads => {
                let mut state: Vec<Tensor> = inputs[..12].to_vec();
                let grads = reference::grads_from_deltas(
                    &inputs[12],
                    &inputs[13],
                    &inputs[14],
                    &inputs[15],
                    &inputs[16],
                    &inputs[17],
                );
                reference::sgd_momentum(
                    &mut state,
                    &grads,
                    inputs[18].item(),
                    inputs[19].item(),
                );
                state
            }
            Kind::PhotonicMatvec => {
                let (x, phi) = (&inputs[0], &inputs[1]);
                let design = MrrDesign {
                    self_coupling: inputs[2].item() as f64,
                    loss_a: inputs[3].item() as f64,
                };
                let (m, k) = (phi.rows(), phi.cols());
                let out: Vec<f32> = (0..m)
                    .map(|r| {
                        (0..k)
                            .map(|c| {
                                x.data()[c] as f64 * design.weight(phi.at(r, c) as f64)
                            })
                            .sum::<f64>() as f32
                    })
                    .collect();
                vec![Tensor::new(&[m], out)?]
            }
        };
        self.counters.add_macs(self.macs);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfa::params::NetState;
    use crate::util::rng::Pcg64;

    fn engine() -> NativeEngine {
        NativeEngine::new()
    }

    #[test]
    fn serves_full_artifact_vocabulary() {
        let e = engine();
        let names: Vec<String> =
            e.artifact_specs().iter().map(|s| s.name.clone()).collect();
        assert_eq!(names.len(), 13); // 4 per config x 3 configs + photonic_matvec
        for cfg in ["tiny", "small", "mnist"] {
            for prefix in ["fwd", "dfa_step", "bp_step", "apply_grads"] {
                assert!(names.iter().any(|n| n == &format!("{prefix}_{cfg}")));
            }
        }
        assert!(names.iter().any(|n| n == "photonic_matvec"));
        assert!(e.load("nonexistent").is_err());
        assert!(e.net_dims("nonexistent").is_err());
    }

    #[test]
    fn dfa_step_spec_matches_manifest_contract() {
        let e = engine();
        let art = e.load("dfa_step_tiny").unwrap();
        assert_eq!(art.spec().inputs.len(), 22);
        assert_eq!(art.spec().outputs.len(), 14);
        assert_eq!(art.spec().inputs.last().unwrap().name, "momentum");
        assert_eq!(art.spec().inputs[0].name, "w1");
        assert_eq!(art.spec().inputs[6].name, "vw1");
        assert_eq!(art.spec().input_index("x").unwrap(), 14);
    }

    #[test]
    fn dfa_step_executes_reference_math() {
        let e = engine();
        let dims = e.net_dims("tiny").unwrap();
        let art = e.load("dfa_step_tiny").unwrap();
        let mut rng = Pcg64::seed(3);
        let state = NetState::init(&dims, &mut rng);
        let (b1, b2) = NetState::init_feedback(&dims, &mut rng);
        let x = Tensor::randn(&[dims.batch, dims.d_in], 0.5, &mut rng);
        let mut y = Tensor::zeros(&[dims.batch, dims.d_out]);
        for r in 0..dims.batch {
            y.set(r, r % dims.d_out, 1.0);
        }
        let n1 = Tensor::zeros(&[dims.d_h1, dims.batch]);
        let n2 = Tensor::zeros(&[dims.d_h2, dims.batch]);

        let mut inputs = state.tensors.clone();
        inputs.extend([
            b1.clone(), b2.clone(), x.clone(), y.clone(), n1.clone(), n2.clone(),
            Tensor::scalar(0.0), Tensor::scalar(0.0),
            Tensor::scalar(0.05), Tensor::scalar(0.9),
        ]);
        let out = art.execute(&inputs).unwrap();
        assert_eq!(out.len(), 14);

        // twin through the reference directly
        let mut ref_state = state.tensors.clone();
        let (ref_loss, ref_correct) = reference::dfa_step(
            &mut ref_state, &b1, &b2, &x, &y, &n1, &n2, 0.0, 0.0, 0.05, 0.9,
        );
        assert_eq!(out[12].item(), ref_loss);
        assert_eq!(out[13].item(), ref_correct as f32);
        for (got, want) in out[..12].iter().zip(&ref_state) {
            assert_eq!(got, want);
        }
    }

    #[test]
    fn telemetry_pins_analytic_mac_counts() {
        // tiny (16-32-32-4, batch 8): fwd = 8·1664 = 13312 MACs,
        // dfa_step = fwd + feedback 2048 + weight grads 13312 = 28672
        let e = engine();
        let dims = e.net_dims("tiny").unwrap();
        assert!(e.telemetry().is_empty());

        let fwd = e.load("fwd_tiny").unwrap();
        let mut rng = Pcg64::seed(7);
        let state = NetState::init(&dims, &mut rng);
        let x = Tensor::randn(&[dims.batch, dims.d_in], 0.5, &mut rng);
        let mut inputs: Vec<Tensor> = state.tensors[..6].to_vec();
        inputs.push(x.clone());
        fwd.execute(&inputs).unwrap();
        assert_eq!(e.telemetry().macs, 13_312);
        fwd.execute(&inputs).unwrap();
        assert_eq!(e.telemetry().macs, 2 * 13_312);

        let step = e.load("dfa_step_tiny").unwrap();
        let (b1, b2) = NetState::init_feedback(&dims, &mut rng);
        let mut y = Tensor::zeros(&[dims.batch, dims.d_out]);
        for r in 0..dims.batch {
            y.set(r, r % dims.d_out, 1.0);
        }
        let mut si = state.tensors.clone();
        si.extend([
            b1,
            b2,
            x,
            y,
            Tensor::zeros(&[dims.d_h1, dims.batch]),
            Tensor::zeros(&[dims.d_h2, dims.batch]),
            Tensor::scalar(0.0),
            Tensor::scalar(0.0),
            Tensor::scalar(0.05),
            Tensor::scalar(0.9),
        ]);
        step.execute(&si).unwrap();
        let t = e.telemetry();
        assert_eq!(t.macs, 2 * 13_312 + 28_672);
        // a digital engine never fires optical cycles or accrues energy
        assert_eq!(t.photonic_macs, 0);
        assert_eq!(t.cycles, 0);
        assert_eq!(t.energy_j, 0.0);
        assert_eq!(t.pj_per_mac(), None);

        // a failed dispatch (bad shapes) counts nothing
        let before = e.telemetry();
        assert!(step.execute(&si[..3]).is_err());
        assert_eq!(e.telemetry(), before);

        // photonic_matvec counts its bank cells from the spec shape
        let pm = e.load("photonic_matvec").unwrap();
        let xb = Tensor::rand_uniform(&[BANK_COLS], 0.0, 1.0, &mut rng);
        let phi = Tensor::zeros(&[BANK_ROWS, BANK_COLS]);
        pm.execute(&[xb, phi, Tensor::scalar(0.95), Tensor::scalar(0.999)])
            .unwrap();
        let t2 = e.telemetry();
        assert_eq!(t2.macs, before.macs + (BANK_ROWS * BANK_COLS) as u64);
    }

    #[test]
    fn shape_validation_rejects_wrong_inputs() {
        let e = engine();
        let fwd = e.load("fwd_tiny").unwrap();
        let bad: Vec<Tensor> = fwd
            .spec()
            .inputs
            .iter()
            .map(|_| Tensor::zeros(&[1, 1]))
            .collect();
        assert!(fwd.execute(&bad).is_err());
        assert!(fwd.execute(&[Tensor::zeros(&[8, 16])]).is_err());
    }

    #[test]
    fn fwd_and_apply_grads_compose_into_a_step() {
        // fwd -> reference loss/error -> apply_grads must reduce the loss
        let e = engine();
        let dims = e.net_dims("tiny").unwrap();
        let fwd = e.load("fwd_tiny").unwrap();
        let apply = e.load("apply_grads_tiny").unwrap();
        let mut rng = Pcg64::seed(11);
        let mut state = NetState::init(&dims, &mut rng);
        let (b1, b2) = NetState::init_feedback(&dims, &mut rng);
        let x = Tensor::randn(&[dims.batch, dims.d_in], 0.5, &mut rng);
        let mut y = Tensor::zeros(&[dims.batch, dims.d_out]);
        for r in 0..dims.batch {
            y.set(r, r % dims.d_out, 1.0);
        }
        let zeros1 = Tensor::zeros(&[dims.d_h1, dims.batch]);
        let zeros2 = Tensor::zeros(&[dims.d_h2, dims.batch]);

        let mut first_loss = None;
        let mut last_loss = 0.0;
        for _ in 0..20 {
            let mut inputs = state.tensors[..6].to_vec();
            inputs.push(x.clone());
            let f = fwd.execute(&inputs).unwrap();
            let (loss, err, _) = reference::loss_and_error(&f[0], &y);
            let d1t = reference::dfa_gradient(&b1, &err, &zeros1, &f[1], 0.0, 0.0);
            let d2t = reference::dfa_gradient(&b2, &err, &zeros2, &f[2], 0.0, 0.0);
            let mut ai = state.tensors.clone();
            ai.extend([
                x.clone(), f[3].clone(), f[4].clone(), err, d1t, d2t,
                Tensor::scalar(0.05), Tensor::scalar(0.9),
            ]);
            let mut out = apply.execute(&ai).unwrap();
            state.update_from(&mut out).unwrap();
            first_loss.get_or_insert(loss);
            last_loss = loss;
        }
        let first = first_loss.unwrap();
        assert!(last_loss < 0.5 * first, "{first} -> {last_loss}");
    }

    #[test]
    fn photonic_matvec_matches_mrr_physics() {
        let e = engine();
        let art = e.load("photonic_matvec").unwrap();
        let mut rng = Pcg64::seed(5);
        let x = Tensor::rand_uniform(&[BANK_COLS], 0.0, 1.0, &mut rng);
        let phi = Tensor::rand_uniform(&[BANK_ROWS, BANK_COLS], -0.5, 0.5, &mut rng);
        let out = art
            .execute(&[x.clone(), phi.clone(), Tensor::scalar(0.95), Tensor::scalar(0.999)])
            .unwrap();
        assert_eq!(out[0].shape(), &[BANK_ROWS]);
        let design = MrrDesign { self_coupling: 0.95, loss_a: 0.999 };
        for row in 0..BANK_ROWS {
            let want: f64 = (0..BANK_COLS)
                .map(|c| x.data()[c] as f64 * design.weight(phi.at(row, c) as f64))
                .sum();
            assert!((out[0].data()[row] as f64 - want).abs() < 1e-4 * BANK_COLS as f64);
        }
    }
}
