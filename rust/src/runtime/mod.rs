//! PJRT runtime: load and execute the JAX/Pallas AOT artifacts.
//!
//! The compile path (`python/compile/aot.py`, run once by `make artifacts`)
//! lowers every L2 function to **HLO text** plus a JSON manifest describing
//! each artifact's ordered inputs/outputs. This module is the only place
//! that touches the `xla` crate:
//!
//! * [`manifest`] — parse `artifacts/manifest.json` into typed specs
//! * [`engine`]   — an [`engine::Engine`] owning the PJRT CPU client, a
//!   compiled-executable cache, and `Tensor` ⇄ `Literal` marshalling
//!
//! Interchange is HLO *text*, not a serialized `HloModuleProto`: jax ≥ 0.5
//! emits protos with 64-bit instruction ids which xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

pub mod engine;
pub mod manifest;

pub use engine::{Engine, LoadedArtifact};
pub use manifest::{ArtifactSpec, IoSpec, Manifest};
