//! Runtime: load and execute training-step artifacts, behind a backend
//! abstraction.
//!
//! * [`step_engine`] — the [`StepEngine`] / [`Artifact`] traits every
//!   caller programs against, plus the [`open`] factory and [`Backend`]
//!   selection policy. Every engine also reports hardware telemetry
//!   ([`StepEngine::telemetry`]): analytic MAC counts on the digital
//!   backends, measured optical cycles plus modeled §5 energy on the
//!   photonic one — see [`crate::telemetry`]
//! * [`native`]    — [`native::NativeEngine`]: pure-Rust execution of the
//!   artifact contract via `dfa::reference` (default build; hermetic)
//! * [`photonic`]  — [`photonic::PhotonicEngine`]: the same contract with
//!   every matvec routed through the device-level MRR weight bank
//!   (`--backend photonic`, noise-aware in-situ DFA)
//! * [`manifest`]  — parse `artifacts/manifest.json` into typed specs
//! * [`engine`]    — `--features pjrt` only: an [`engine::Engine`] owning
//!   the PJRT CPU client, a compiled-executable cache, and
//!   `Tensor` ⇄ `Literal` marshalling over the AOT HLO artifacts
//!
//! The compile path (`python/compile/aot.py`, run once by `make
//! artifacts`) lowers every L2 function to **HLO text** plus a JSON
//! manifest describing each artifact's ordered inputs/outputs.
//! Interchange is HLO *text*, not a serialized `HloModuleProto`: jax ≥ 0.5
//! emits protos with 64-bit instruction ids which xla_extension 0.5.1
//! rejects; the text parser reassigns ids.

#[cfg(feature = "pjrt")]
pub mod engine;
pub mod manifest;
pub mod native;
pub mod photonic;
pub mod step_engine;

#[cfg(feature = "pjrt")]
pub use engine::{Engine, LoadedArtifact};
pub use manifest::{ArtifactSpec, IoSpec, Manifest};
pub use native::NativeEngine;
pub use photonic::{BankDispatcher, PhotonicEngine, PhysicsConfig};
pub use step_engine::{open, open_threaded, Artifact, Backend, StepEngine};
