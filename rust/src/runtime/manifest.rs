//! Typed view of `artifacts/manifest.json`.
//!
//! The Rust side is entirely manifest-driven: no artifact shape is
//! hard-coded here. `aot.py` records, for every artifact, the ordered
//! input and output names/shapes/dtypes (HLO parameter order == manifest
//! order), plus the network configurations they were traced for.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Value;
use crate::{Error, Result};

/// One input or output of an artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl IoSpec {
    pub fn elem_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled HLO artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    /// Path to the `.hlo.txt`, resolved relative to the manifest location.
    pub path: PathBuf,
    pub config: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl ArtifactSpec {
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|i| i.name == name)
            .ok_or_else(|| {
                Error::Manifest(format!("artifact {} has no input '{name}'", self.name))
            })
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|o| o.name == name)
            .ok_or_else(|| {
                Error::Manifest(format!("artifact {} has no output '{name}'", self.name))
            })
    }

    /// Check positional inputs against the spec (count + shapes) — the
    /// shared front door of every backend's `execute`.
    pub fn validate_inputs(&self, inputs: &[crate::tensor::Tensor]) -> Result<()> {
        if inputs.len() != self.inputs.len() {
            return Err(Error::Shape(format!(
                "artifact {}: expected {} inputs, got {}",
                self.name,
                self.inputs.len(),
                inputs.len()
            )));
        }
        for (t, spec) in inputs.iter().zip(&self.inputs) {
            if t.shape() != spec.shape.as_slice() {
                return Err(Error::Shape(format!(
                    "artifact {}: input '{}' expects shape {:?}, got {:?}",
                    self.name,
                    spec.name,
                    spec.shape,
                    t.shape()
                )));
            }
        }
        Ok(())
    }
}

/// Network configuration an artifact set was traced for.
#[derive(Debug, Clone, PartialEq)]
pub struct NetDims {
    pub d_in: usize,
    pub d_h1: usize,
    pub d_h2: usize,
    pub d_out: usize,
    pub batch: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub configs: BTreeMap<String, NetDims>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Manifest(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let root = Value::parse(text)?;
        let format = root
            .get("format")
            .as_usize()
            .ok_or_else(|| Error::Manifest("missing 'format'".into()))?;
        if format != 1 {
            return Err(Error::Manifest(format!("unsupported format {format}")));
        }

        let mut artifacts = BTreeMap::new();
        for (name, art) in root
            .require("artifacts")?
            .as_object()
            .ok_or_else(|| Error::Manifest("'artifacts' not an object".into()))?
        {
            let file = art
                .require("file")?
                .as_str()
                .ok_or_else(|| Error::Manifest("artifact 'file' not a string".into()))?;
            let spec = ArtifactSpec {
                name: name.clone(),
                path: dir.join(file),
                config: art.get("config").as_str().unwrap_or("").to_string(),
                inputs: parse_io(art.require("inputs")?)?,
                outputs: parse_io(art.require("outputs")?)?,
            };
            artifacts.insert(name.clone(), spec);
        }

        let mut configs = BTreeMap::new();
        if let Some(cfgs) = root.get("configs").as_object() {
            for (name, c) in cfgs {
                // the special "bank" entry has different keys; skip non-net configs
                if c.get("d_in").as_usize().is_none() {
                    continue;
                }
                let dim = |k: &str| -> Result<usize> {
                    c.get(k)
                        .as_usize()
                        .ok_or_else(|| Error::Manifest(format!("config {name}: bad '{k}'")))
                };
                configs.insert(
                    name.clone(),
                    NetDims {
                        d_in: dim("d_in")?,
                        d_h1: dim("d_h1")?,
                        d_h2: dim("d_h2")?,
                        d_out: dim("d_out")?,
                        batch: dim("batch")?,
                    },
                );
            }
        }

        Ok(Manifest { dir, artifacts, configs })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("no artifact '{name}' in manifest")))
    }

    pub fn net_dims(&self, config: &str) -> Result<&NetDims> {
        self.configs
            .get(config)
            .ok_or_else(|| Error::Manifest(format!("no config '{config}' in manifest")))
    }
}

fn parse_io(v: &Value) -> Result<Vec<IoSpec>> {
    let arr = v
        .as_array()
        .ok_or_else(|| Error::Manifest("io list not an array".into()))?;
    arr.iter()
        .map(|item| {
            let name = item
                .require("name")?
                .as_str()
                .ok_or_else(|| Error::Manifest("io 'name' not a string".into()))?
                .to_string();
            let shape = item
                .require("shape")?
                .as_array()
                .ok_or_else(|| Error::Manifest("io 'shape' not an array".into()))?
                .iter()
                .map(|d| {
                    d.as_usize()
                        .ok_or_else(|| Error::Manifest("bad shape dim".into()))
                })
                .collect::<Result<Vec<_>>>()?;
            let dtype = item.get("dtype").as_str().unwrap_or("f32").to_string();
            if dtype != "f32" {
                return Err(Error::Manifest(format!(
                    "io '{name}': only f32 supported, got {dtype}"
                )));
            }
            Ok(IoSpec { name, shape, dtype })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "configs": {
        "tiny": {"d_in": 16, "d_h1": 32, "d_h2": 32, "d_out": 4, "batch": 8},
        "bank": {"rows": 50, "cols": 20}
      },
      "artifacts": {
        "fwd_tiny": {
          "file": "fwd_tiny.hlo.txt",
          "config": "tiny",
          "inputs": [
            {"name": "w1", "shape": [16, 32], "dtype": "f32"},
            {"name": "x", "shape": [8, 16], "dtype": "f32"}
          ],
          "outputs": [
            {"name": "logits", "shape": [8, 4], "dtype": "f32"}
          ]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let art = m.artifact("fwd_tiny").unwrap();
        assert_eq!(art.path, PathBuf::from("/tmp/a/fwd_tiny.hlo.txt"));
        assert_eq!(art.inputs.len(), 2);
        assert_eq!(art.inputs[0].shape, vec![16, 32]);
        assert_eq!(art.inputs[0].elem_count(), 512);
        assert_eq!(art.input_index("x").unwrap(), 1);
        assert_eq!(art.output_index("logits").unwrap(), 0);
        assert!(art.input_index("nope").is_err());
        let dims = m.net_dims("tiny").unwrap();
        assert_eq!(dims.batch, 8);
        // "bank" config is skipped (not a network config)
        assert!(m.net_dims("bank").is_err());
    }

    #[test]
    fn rejects_bad_manifests() {
        assert!(Manifest::parse("{}", PathBuf::new()).is_err());
        assert!(Manifest::parse(r#"{"format": 2, "artifacts": {}}"#, PathBuf::new()).is_err());
        let bad_dtype = r#"{"format": 1, "artifacts": {"a": {"file": "a",
            "inputs": [{"name": "x", "shape": [1], "dtype": "s8"}],
            "outputs": []}}}"#;
        assert!(Manifest::parse(bad_dtype, PathBuf::new()).is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        // integration hook: when `make artifacts` has run, validate for real
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.artifacts.contains_key("dfa_step_tiny"));
            let art = m.artifact("dfa_step_tiny").unwrap();
            assert_eq!(art.inputs.len(), 22);
            assert_eq!(art.outputs.len(), 14);
            assert_eq!(art.inputs.last().unwrap().name, "momentum");
            assert!(art.path.exists());
        }
    }
}
