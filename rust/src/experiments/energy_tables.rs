//! Energy/speed tables: Fig. 6 and the §5 headline numbers.

use crate::energy::components::MrrTuning;
use crate::energy::model::ArchitectureModel;
use crate::energy::sweep::{optimal_energy_curve, OptimalPoint};
use crate::energy::area::compute_density_tops_per_mm2;
use crate::photonics::constants as k;

/// One row of the headline summary.
#[derive(Debug, Clone)]
pub struct HeadlineRow {
    pub label: &'static str,
    pub value: f64,
    pub unit: &'static str,
    pub paper: f64,
}

/// The §5 headline table (measured-by-model vs paper).
pub fn headline_summary() -> Vec<HeadlineRow> {
    let heater = ArchitectureModel::paper(MrrTuning::HeaterLocked);
    let trimmed = ArchitectureModel::paper(MrrTuning::Trimmed);
    vec![
        HeadlineRow {
            label: "throughput (50x20 bank @ 10 GHz)",
            value: heater.ops_per_second() / 1e12,
            unit: "TOPS",
            paper: 20.0,
        },
        HeadlineRow {
            label: "E_op, heater-locked MRRs",
            value: heater.energy_per_op() * 1e12,
            unit: "pJ/op",
            paper: 1.0,
        },
        HeadlineRow {
            label: "E_op, trimmed MRRs",
            value: trimmed.energy_per_op() * 1e12,
            unit: "pJ/op",
            paper: 0.28,
        },
        HeadlineRow {
            label: "wall-plug power, heater-locked",
            value: heater.power_breakdown().total_w(),
            unit: "W",
            paper: 20.0,
        },
        HeadlineRow {
            label: "compute density",
            value: compute_density_tops_per_mm2(k::F_S_HZ),
            unit: "TOPS/mm^2",
            paper: 5.78,
        },
        HeadlineRow {
            label: "E_MAC, trimmed (headline: < 1 pJ/MAC)",
            value: trimmed.energy_per_mac() * 1e12,
            unit: "pJ/MAC",
            paper: 1.0,
        },
    ]
}

/// Fig. 6 rows for both tuning schemes: (cells, E_op heater, E_op trimmed),
/// each minimised over bank aspect ratio (M, N >= 5).
pub fn fig6_rows(lo: usize, hi: usize, points: usize) -> Vec<(usize, f64, f64)> {
    let heater = optimal_energy_curve(MrrTuning::HeaterLocked, lo, hi, points);
    let trimmed = optimal_energy_curve(MrrTuning::Trimmed, lo, hi, points);
    heater
        .iter()
        .zip(trimmed.iter())
        .map(|(h, t): (&OptimalPoint, &OptimalPoint)| (h.cells, h.e_op_j, t.e_op_j))
        .collect()
}

/// Render the headline table as aligned text (CLI + run reports).
pub fn render_headline() -> String {
    let mut out = String::from(
        "metric                                     model      paper     unit\n",
    );
    for row in headline_summary() {
        out.push_str(&format!(
            "{:<42} {:>8.3}  {:>8.3}   {}\n",
            row.label, row.value, row.paper, row.unit
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_within_bands() {
        for row in headline_summary() {
            if row.label.contains('<') {
                // the paper states a bound, not a point value
                assert!(
                    row.value < row.paper,
                    "{}: model {} should be < {}",
                    row.label,
                    row.value,
                    row.paper
                );
                continue;
            }
            let rel = (row.value - row.paper).abs() / row.paper;
            assert!(
                rel < 0.10,
                "{}: model {} vs paper {} ({}% off)",
                row.label,
                row.value,
                row.paper,
                (rel * 100.0) as u32
            );
        }
    }

    #[test]
    fn fig6_rows_ordered_and_decreasing() {
        let rows = fig6_rows(25, 50_000, 14);
        assert!(rows.len() >= 8);
        for w in rows.windows(2) {
            assert!(w[1].0 > w[0].0, "cells must increase");
        }
        // heater curve above trimmed at scale
        for (cells, h, t) in &rows {
            if *cells >= 500 {
                assert!(h > t, "heater {h} <= trimmed {t} at {cells}");
            }
        }
    }

    #[test]
    fn render_contains_key_rows() {
        let text = render_headline();
        assert!(text.contains("TOPS/mm^2"));
        assert!(text.contains("E_op, trimmed"));
    }
}
