//! Physics sweep: test accuracy of in-situ photonic DFA training as a
//! function of converter resolution × receiver read noise.
//!
//! The Fig. 5(c)-style experiment run on the *device* path instead of the
//! Gaussian noise model: every point opens a fresh
//! [`crate::runtime::PhotonicEngine`] whose DAC/ADC bits and
//! gradient-readout noise σ are overridden, trains a network end to end
//! on the bank, and records the final test accuracy. `pdfa sweep-physics`
//! renders the table via the [`crate::util::benchx`] formatting helpers.

use std::time::Instant;

use crate::dfa::config::{Algorithm, TrainConfig};
use crate::dfa::noise_model::NoiseMode;
use crate::dfa::trainer::Trainer;
use crate::runtime::{self, Backend, PhysicsConfig};
use crate::util::benchx::fmt_ns;
use crate::Result;

/// One grid point of the sweep.
#[derive(Debug, Clone, Copy)]
pub struct PhysicsPoint {
    pub dac_bits: u32,
    pub adc_bits: u32,
    pub sigma: f64,
    pub test_acc: f64,
    pub train_wall_s: f64,
}

/// Everything a sweep run needs besides the grid itself.
#[derive(Debug, Clone)]
pub struct SweepSettings {
    pub artifacts_dir: String,
    pub config: String,
    /// Base physics: the grid overrides `dac_bits`/`adc_bits`/`sigma` on
    /// top of this (so `lock`, `crosstalk`, bank geometry and seed come
    /// from here).
    pub base: PhysicsConfig,
    pub epochs: usize,
    pub seed: u64,
    pub n_train: usize,
    pub n_test: usize,
    pub max_steps_per_epoch: Option<usize>,
}

/// Train one network per (bits, sigma) grid point on the photonic backend
/// and report final test accuracy — the paper-style accuracy-vs-resolution
/// table, with the physics actually in the loop.
pub fn physics_sweep(
    settings: &SweepSettings,
    bits_list: &[u32],
    sigma_list: &[f64],
) -> Result<Vec<PhysicsPoint>> {
    let mut out = Vec::with_capacity(bits_list.len() * sigma_list.len());
    for &bits in bits_list {
        for &sigma in sigma_list {
            let mut physics = settings.base;
            physics.dac_bits = bits;
            physics.adc_bits = bits;
            physics.sigma = sigma;
            let engine = runtime::open(&settings.artifacts_dir, Backend::Photonic(physics))?;
            let cfg = TrainConfig {
                config: settings.config.clone(),
                algorithm: Algorithm::Dfa,
                noise: NoiseMode::Clean, // the device supplies the noise
                epochs: settings.epochs,
                seed: settings.seed,
                n_train: settings.n_train,
                n_test: settings.n_test,
                max_steps_per_epoch: settings.max_steps_per_epoch,
                physics: Some(physics),
                ..TrainConfig::default()
            };
            let mut trainer = Trainer::new(engine, cfg)?;
            let (train, test) = trainer.load_data()?;
            let t0 = Instant::now();
            let res = trainer.train(train, test, |_| {})?;
            let point = PhysicsPoint {
                dac_bits: bits,
                adc_bits: bits,
                sigma,
                test_acc: res.test_acc,
                train_wall_s: t0.elapsed().as_secs_f64(),
            };
            crate::log_info!(
                "physics point dac/adc={bits} sigma={sigma}: test acc {:.4}",
                res.test_acc
            );
            out.push(point);
        }
    }
    Ok(out)
}

/// Render the sweep as the paper-style fixed-width table (one row per
/// grid point, benchx time formatting).
pub fn render_table(points: &[PhysicsPoint]) -> String {
    let mut s = String::from("dac/adc bits   sigma     test_acc   train_wall\n");
    for p in points {
        let bits = if p.dac_bits == 0 {
            "ideal".to_string()
        } else {
            p.dac_bits.to_string()
        };
        s.push_str(&format!(
            "{bits:>12}   {:<7.4}   {:<8.4}   {}\n",
            p.sigma,
            p.test_acc,
            fmt_ns(p.train_wall_s * 1e9),
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn settings() -> SweepSettings {
        SweepSettings {
            artifacts_dir: "artifacts".into(),
            config: "tiny".into(),
            base: PhysicsConfig {
                bank_rows: 16,
                bank_cols: 12,
                ..PhysicsConfig::ideal()
            },
            epochs: 1,
            seed: 5,
            n_train: 64,
            n_test: 32,
            max_steps_per_epoch: Some(2),
        }
    }

    #[test]
    fn sweep_covers_the_grid_and_stays_finite() {
        let pts = physics_sweep(&settings(), &[0, 2], &[0.0]).unwrap();
        assert_eq!(pts.len(), 2);
        for p in &pts {
            assert!(p.test_acc.is_finite() && (0.0..=1.0).contains(&p.test_acc));
            assert!(p.train_wall_s >= 0.0);
        }
        assert_eq!(pts[0].dac_bits, 0);
        assert_eq!(pts[1].dac_bits, 2);
    }

    #[test]
    fn table_renders_one_row_per_point() {
        let pts = [
            PhysicsPoint {
                dac_bits: 0,
                adc_bits: 0,
                sigma: 0.0,
                test_acc: 0.98,
                train_wall_s: 1.5,
            },
            PhysicsPoint {
                dac_bits: 4,
                adc_bits: 4,
                sigma: 0.1,
                test_acc: 0.75,
                train_wall_s: 2.0,
            },
        ];
        let t = render_table(&pts);
        assert_eq!(t.lines().count(), 3, "{t}");
        assert!(t.contains("ideal"), "{t}");
        assert!(t.contains("0.7500"), "{t}");
        assert!(t.contains("test_acc"), "{t}");
    }
}
