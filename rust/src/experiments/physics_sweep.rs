//! Physics sweep: test accuracy of in-situ photonic DFA training as a
//! function of converter resolution × receiver read noise.
//!
//! The Fig. 5(c)-style experiment run on the *device* path instead of the
//! Gaussian noise model: every point opens a fresh
//! [`crate::runtime::PhotonicEngine`] whose DAC/ADC bits and
//! gradient-readout noise σ are overridden, trains a network end to end
//! on the bank, and records the final test accuracy. `pdfa sweep-physics`
//! renders the table via the [`crate::util::benchx`] formatting helpers.
//!
//! The lifetime axis ([`drift_sweep`], `pdfa sweep-physics --drift-rates`)
//! reuses the same cell machinery over thermal drift rate × recalibration
//! scheduler {on, off}: each cell trains under live drift and records
//! accuracy plus the scheduler's telemetry (recalibrations fired, cycles
//! spent), quantifying what the §4 protocol's accuracy costs on an aging
//! device.

use std::sync::Arc;
use std::time::Instant;

use crate::dfa::config::{Algorithm, TrainConfig};
use crate::dfa::noise_model::NoiseMode;
use crate::dfa::trainer::Trainer;
use crate::runtime::photonic::RECAL_THRESHOLD_DEFAULT;
use crate::runtime::{PhotonicEngine, PhysicsConfig, StepEngine};
use crate::util::benchx::fmt_ns;
use crate::Result;

/// One grid point of the sweep.
#[derive(Debug, Clone, Copy)]
pub struct PhysicsPoint {
    pub dac_bits: u32,
    pub adc_bits: u32,
    pub sigma: f64,
    pub test_acc: f64,
    pub train_wall_s: f64,
}

/// One grid point of the lifetime (drift) sweep.
#[derive(Debug, Clone, Copy)]
pub struct DriftPoint {
    /// Thermal walk rate (rad/√tick) this cell trained under.
    pub drift_rate: f64,
    /// Whether the online recalibration scheduler was armed.
    pub recal: bool,
    pub test_acc: f64,
    /// Recalibrations the scheduler fired during the run.
    pub recal_events: u64,
    /// Optical cycles spent inside those recalibrations.
    pub recal_cycles: u64,
    pub train_wall_s: f64,
}

/// Everything a sweep run needs besides the grid itself.
#[derive(Debug, Clone)]
pub struct SweepSettings {
    pub artifacts_dir: String,
    pub config: String,
    /// Base physics: the grid overrides `dac_bits`/`adc_bits`/`sigma` on
    /// top of this (so `lock`, `crosstalk`, bank geometry and seed come
    /// from here).
    pub base: PhysicsConfig,
    pub epochs: usize,
    pub seed: u64,
    pub n_train: usize,
    pub n_test: usize,
    pub max_steps_per_epoch: Option<usize>,
    /// Worker threads (0 = all cores). Grid cells are independent
    /// training runs, so the sweep shards *cells* across this many
    /// workers; with more than one cell worker, each cell's engine runs
    /// single-threaded (no oversubscription). Accuracy per cell is
    /// bit-identical at any value — only wall-clock time changes.
    pub threads: usize,
}

/// One cell's training outcome: final accuracy, the run's telemetry
/// delta, and wall-clock seconds.
struct CellRun {
    test_acc: f64,
    telemetry: crate::telemetry::Telemetry,
    wall_s: f64,
}

/// Open a fresh photonic engine under `physics` and train end to end —
/// the body shared by every sweep cell.
fn train_under(
    settings: &SweepSettings,
    physics: PhysicsConfig,
    engine_threads: usize,
) -> Result<CellRun> {
    // open the engine directly (not through runtime::open_threaded): the
    // sweep already set the process-wide GEMM cap to the per-cell plan,
    // and a cell worker must not override it mid-flight
    let engine: Arc<dyn StepEngine> = Arc::new(PhotonicEngine::open_threaded(
        &settings.artifacts_dir,
        physics,
        engine_threads,
    )?);
    let cfg = TrainConfig {
        config: settings.config.clone(),
        algorithm: Algorithm::Dfa,
        noise: NoiseMode::Clean, // the device supplies the noise
        epochs: settings.epochs,
        seed: settings.seed,
        n_train: settings.n_train,
        n_test: settings.n_test,
        max_steps_per_epoch: settings.max_steps_per_epoch,
        physics: Some(physics),
        threads: engine_threads,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(engine, cfg)?;
    let (train, test) = trainer.load_data()?;
    // lint: timing: per-point wall-clock for the sweep report
    let t0 = Instant::now();
    let res = trainer.train(train, test, |_| {})?;
    Ok(CellRun {
        test_acc: res.test_acc,
        telemetry: res.telemetry,
        wall_s: t0.elapsed().as_secs_f64(),
    })
}

/// Shard independent grid cells across [`SweepSettings::threads`] workers
/// in deterministic input order. With more than one cell worker, each
/// cell's engine runs single-threaded (no oversubscription); every cell's
/// result is bit-identical at any worker count, so only wall-clock time
/// changes. `ThreadCapGuard` serializes this scope against every other
/// cap-scoped user of the process-global GEMM cap and restores the exact
/// prior value on every exit path, including a panicking cell.
fn shard_cells<C: Copy + Send + Sync, P: Send>(
    cells: &[C],
    threads: usize,
    run: impl Fn(C, usize) -> Result<P> + Sync,
) -> Result<Vec<P>> {
    if cells.is_empty() {
        return Ok(Vec::new());
    }
    let workers = crate::util::threads::resolve(threads).min(cells.len()).max(1);
    // one worker: let the cell's engine use the full thread budget instead
    let engine_threads = if workers > 1 { 1 } else { threads };
    let _restore_cap = crate::tensor::ops::ThreadCapGuard::set(engine_threads);
    let mut results: Vec<Option<Result<P>>> =
        (0..cells.len()).map(|_| None).collect();
    if workers == 1 {
        for (slot, &cell) in results.iter_mut().zip(cells) {
            *slot = Some(run(cell, engine_threads));
        }
    } else {
        let per = cells.len().div_ceil(workers);
        let run = &run;
        std::thread::scope(|scope| {
            for (t, chunk) in results.chunks_mut(per).enumerate() {
                scope.spawn(move || {
                    for (i, slot) in chunk.iter_mut().enumerate() {
                        *slot = Some(run(cells[t * per + i], engine_threads));
                    }
                });
            }
        });
    }
    results
        .into_iter()
        .map(|r| r.expect("every grid cell ran"))
        .collect()
}

/// Train one network per (bits, sigma) grid point on the photonic backend
/// and report final test accuracy — the paper-style accuracy-vs-resolution
/// table, with the physics actually in the loop. Cells are independent
/// runs, sharded across [`SweepSettings::threads`] workers; the returned
/// points are always in deterministic grid order (bits-major, sigma-minor)
/// and each cell's accuracy is bit-identical at any thread count.
pub fn physics_sweep(
    settings: &SweepSettings,
    bits_list: &[u32],
    sigma_list: &[f64],
) -> Result<Vec<PhysicsPoint>> {
    let cells: Vec<(u32, f64)> = bits_list
        .iter()
        .flat_map(|&b| sigma_list.iter().map(move |&s| (b, s)))
        .collect();
    shard_cells(&cells, settings.threads, |(bits, sigma), engine_threads| {
        let mut physics = settings.base;
        physics.dac_bits = bits;
        physics.adc_bits = bits;
        physics.sigma = sigma;
        let run = train_under(settings, physics, engine_threads)?;
        crate::log_info!(
            "physics point dac/adc={bits} sigma={sigma}: test acc {:.4}",
            run.test_acc
        );
        Ok(PhysicsPoint {
            dac_bits: bits,
            adc_bits: bits,
            sigma,
            test_acc: run.test_acc,
            train_wall_s: run.wall_s,
        })
    })
}

/// Recalibration threshold that disarms the scheduler: finite (so
/// [`PhysicsConfig::validate`] accepts it) but beyond any reachable
/// telemetry-estimated weight error.
const RECAL_OFF: f64 = 1e30;

/// Train one network per drift rate × recalibration-scheduler {on, off}
/// and report final test accuracy plus the scheduler's telemetry — the
/// device-lifetime ablation. The recal-ON arm uses the base physics'
/// threshold (or [`RECAL_THRESHOLD_DEFAULT`] if the base never set one);
/// the OFF arm raises it out of reach so drift goes uncompensated.
/// Sharded and ordered like [`physics_sweep`] (rate-major, ON before OFF).
pub fn drift_sweep(
    settings: &SweepSettings,
    rate_list: &[f64],
) -> Result<Vec<DriftPoint>> {
    let cells: Vec<(f64, bool)> = rate_list
        .iter()
        .flat_map(|&r| [(r, true), (r, false)])
        .collect();
    shard_cells(&cells, settings.threads, |(rate, recal), engine_threads| {
        let mut physics = settings.base;
        physics.drift_rate = rate;
        physics.recal_threshold = if !recal {
            RECAL_OFF
        } else if settings.base.recal_threshold > 0.0 {
            settings.base.recal_threshold
        } else {
            RECAL_THRESHOLD_DEFAULT
        };
        let run = train_under(settings, physics, engine_threads)?;
        crate::log_info!(
            "drift point rate={rate} recal={}: test acc {:.4} ({} recals)",
            if recal { "on" } else { "off" },
            run.test_acc,
            run.telemetry.recal_events,
        );
        Ok(DriftPoint {
            drift_rate: rate,
            recal,
            test_acc: run.test_acc,
            recal_events: run.telemetry.recal_events,
            recal_cycles: run.telemetry.recal_cycles,
            train_wall_s: run.wall_s,
        })
    })
}

/// Render the sweep as the paper-style fixed-width table (one row per
/// grid point, benchx time formatting).
pub fn render_table(points: &[PhysicsPoint]) -> String {
    let mut s = String::from("dac/adc bits   sigma     test_acc   train_wall\n");
    for p in points {
        let bits = if p.dac_bits == 0 {
            "ideal".to_string()
        } else {
            p.dac_bits.to_string()
        };
        s.push_str(&format!(
            "{bits:>12}   {:<7.4}   {:<8.4}   {}\n",
            p.sigma,
            p.test_acc,
            fmt_ns(p.train_wall_s * 1e9),
        ));
    }
    s
}

/// Render the drift sweep as a fixed-width table (one row per grid
/// point): walk rate, scheduler arm, accuracy, recal count + cycle cost.
pub fn render_drift_table(points: &[DriftPoint]) -> String {
    let mut s = String::from(
        "drift_rate   recal   test_acc   recals   recal_cycles   train_wall\n",
    );
    for p in points {
        s.push_str(&format!(
            "{:<10}   {:<5}   {:<8.4}   {:>6}   {:>12}   {}\n",
            p.drift_rate,
            if p.recal { "on" } else { "off" },
            p.test_acc,
            p.recal_events,
            p.recal_cycles,
            fmt_ns(p.train_wall_s * 1e9),
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn settings() -> SweepSettings {
        SweepSettings {
            artifacts_dir: "artifacts".into(),
            config: "tiny".into(),
            base: PhysicsConfig {
                bank_rows: 16,
                bank_cols: 12,
                ..PhysicsConfig::ideal()
            },
            epochs: 1,
            seed: 5,
            n_train: 64,
            n_test: 32,
            max_steps_per_epoch: Some(2),
            threads: 1,
        }
    }

    #[test]
    fn sweep_covers_the_grid_and_stays_finite() {
        let pts = physics_sweep(&settings(), &[0, 2], &[0.0]).unwrap();
        assert_eq!(pts.len(), 2);
        for p in &pts {
            assert!(p.test_acc.is_finite() && (0.0..=1.0).contains(&p.test_acc));
            assert!(p.train_wall_s >= 0.0);
        }
        assert_eq!(pts[0].dac_bits, 0);
        assert_eq!(pts[1].dac_bits, 2);
    }

    #[test]
    fn sweep_grid_is_thread_count_invariant() {
        // cells shard across workers, but accuracy and order must be
        // bit-identical to the sequential sweep
        let grid = (&[0u32, 4u32][..], &[0.0, 0.1][..]);
        let sequential = physics_sweep(&settings(), grid.0, grid.1).unwrap();
        let parallel =
            physics_sweep(&SweepSettings { threads: 4, ..settings() }, grid.0, grid.1)
                .unwrap();
        assert_eq!(sequential.len(), 4);
        assert_eq!(parallel.len(), 4);
        for (s, p) in sequential.iter().zip(&parallel) {
            assert_eq!((s.dac_bits, s.adc_bits), (p.dac_bits, p.adc_bits));
            assert_eq!(s.sigma.to_bits(), p.sigma.to_bits());
            assert_eq!(
                s.test_acc.to_bits(),
                p.test_acc.to_bits(),
                "cell dac/adc={} sigma={}: {} vs {}",
                s.dac_bits,
                s.sigma,
                s.test_acc,
                p.test_acc
            );
        }
    }

    #[test]
    fn drift_sweep_ablates_the_recalibration_scheduler() {
        // enough dispatches to cross several drift ticks even on the
        // small per-cell budget
        let s = SweepSettings { epochs: 2, ..settings() };
        let pts = drift_sweep(&s, &[0.0, 0.05]).unwrap();
        assert_eq!(pts.len(), 4, "two rates x (recal on, off)");
        // deterministic order: rate-major, scheduler ON before OFF
        let arms: Vec<(f64, bool)> =
            pts.iter().map(|p| (p.drift_rate, p.recal)).collect();
        assert_eq!(
            arms,
            [(0.0, true), (0.0, false), (0.05, true), (0.05, false)]
        );
        for p in &pts {
            assert!(p.test_acc.is_finite() && (0.0..=1.0).contains(&p.test_acc));
        }
        // a drift-free device never recalibrates, and the scheduler arm
        // is inert: both cells run the identical trajectory
        assert_eq!(pts[0].recal_events, 0);
        assert_eq!(pts[1].recal_events, 0);
        assert_eq!(pts[0].test_acc.to_bits(), pts[1].test_acc.to_bits());
        // a drift of 0.05 rad/√tick is ~6 in weight units: the armed
        // scheduler must fire (and charge cycles), the disarmed one not
        assert!(pts[2].recal_events > 0, "scheduler never fired");
        assert!(pts[2].recal_cycles > 0);
        assert_eq!(pts[3].recal_events, 0);
        assert_eq!(pts[3].recal_cycles, 0);
    }

    #[test]
    fn drift_table_renders_one_row_per_point() {
        let pts = [
            DriftPoint {
                drift_rate: 0.0,
                recal: true,
                test_acc: 0.97,
                recal_events: 0,
                recal_cycles: 0,
                train_wall_s: 1.0,
            },
            DriftPoint {
                drift_rate: 1e-4,
                recal: false,
                test_acc: 0.42,
                recal_events: 0,
                recal_cycles: 0,
                train_wall_s: 1.0,
            },
        ];
        let t = render_drift_table(&pts);
        assert_eq!(t.lines().count(), 3, "{t}");
        assert!(t.contains("off"), "{t}");
        assert!(t.contains("0.4200"), "{t}");
        assert!(t.contains("recal_cycles"), "{t}");
    }

    #[test]
    fn table_renders_one_row_per_point() {
        let pts = [
            PhysicsPoint {
                dac_bits: 0,
                adc_bits: 0,
                sigma: 0.0,
                test_acc: 0.98,
                train_wall_s: 1.5,
            },
            PhysicsPoint {
                dac_bits: 4,
                adc_bits: 4,
                sigma: 0.1,
                test_acc: 0.75,
                train_wall_s: 2.0,
            },
        ];
        let t = render_table(&pts);
        assert_eq!(t.lines().count(), 3, "{t}");
        assert!(t.contains("ideal"), "{t}");
        assert!(t.contains("0.7500"), "{t}");
        assert!(t.contains("test_acc"), "{t}");
    }
}
