//! Training experiments: Fig. 5(b) curves and the Fig. 5(c) resolution sweep.

use std::sync::Arc;

use crate::dfa::config::{Algorithm, TrainConfig};
use crate::dfa::noise_model::NoiseMode;
use crate::dfa::trainer::{TrainResult, Trainer};
use crate::runtime::StepEngine;
use crate::Result;

/// One Fig. 5(b)-style run: returns the full result (validation curve in
/// `history`, final test accuracy).
pub fn fig5b_run(
    engine: Arc<dyn StepEngine>,
    config: &str,
    noise: NoiseMode,
    epochs: usize,
    seed: u64,
    n_train: usize,
    n_test: usize,
    max_steps_per_epoch: Option<usize>,
    mut on_epoch: impl FnMut(&crate::dfa::trainer::EpochStats),
) -> Result<TrainResult> {
    let cfg = TrainConfig {
        config: config.into(),
        algorithm: Algorithm::Dfa,
        noise,
        epochs,
        seed,
        n_train,
        n_test,
        max_steps_per_epoch,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(engine, cfg)?;
    let (train, test) = trainer.load_data()?;
    trainer.train(train, test, &mut on_epoch)
}

/// One point of the Fig. 5(c) sweep.
#[derive(Debug, Clone, Copy)]
pub struct SweepPoint {
    pub bits: f64,
    pub sigma: f64,
    pub test_acc: f64,
}

/// Fig. 5(c): test accuracy as a function of the effective resolution of
/// the gradient mat-vec. Each point trains a fresh network with noise
/// σ = 2 / 2^bits.
#[allow(clippy::too_many_arguments)]
pub fn fig5c_sweep(
    engine: Arc<dyn StepEngine>,
    config: &str,
    bits_list: &[f64],
    epochs: usize,
    seed: u64,
    n_train: usize,
    n_test: usize,
    max_steps_per_epoch: Option<usize>,
) -> Result<Vec<SweepPoint>> {
    let mut out = Vec::with_capacity(bits_list.len());
    for &bits in bits_list {
        let noise = NoiseMode::Resolution { bits };
        let (sigma, _) = noise.artifact_inputs().expect("resolution mode");
        let res = fig5b_run(
            engine.clone(),
            config,
            noise,
            epochs,
            seed,
            n_train,
            n_test,
            max_steps_per_epoch,
            |_| {},
        )?;
        crate::log_info!(
            "resolution {bits:.2} bits (sigma {sigma:.4}): test acc {:.4}",
            res.test_acc
        );
        out.push(SweepPoint { bits, sigma: sigma as f64, test_acc: res.test_acc });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Arc<dyn StepEngine> {
        Arc::new(crate::runtime::NativeEngine::new())
    }

    #[test]
    fn fig5b_smoke_on_small_config() {
        // "small" = 784-128-128-10 on real synthetic digits — a true
        // minified Fig. 5(b) run
        let engine = engine();
        let res = fig5b_run(
            engine,
            "small",
            NoiseMode::Clean,
            1,
            3,
            512,
            128,
            Some(8),
            |_| {},
        )
        .unwrap();
        assert_eq!(res.history.len(), 1);
        assert!(res.test_acc > 0.05); // better than random-ish after 8 steps
        assert!(res.history[0].train_loss.is_finite());
    }

    #[test]
    fn fig5c_sweep_orders_accuracy() {
        let engine = engine();
        // extreme comparison: 1 bit (sigma = 1) vs clean-ish (12 bits)
        let pts = fig5c_sweep(
            engine,
            "small",
            &[1.0, 12.0],
            2,
            5,
            1024,
            256,
            Some(16),
        )
        .unwrap();
        assert_eq!(pts.len(), 2);
        assert!(pts[0].sigma > pts[1].sigma);
        assert!(
            pts[1].test_acc >= pts[0].test_acc - 0.05,
            "more bits should not hurt: {pts:?}"
        );
    }
}
