//! Experiment drivers: one function per paper figure/table.
//!
//! Shared by the `pdfa` CLI subcommands, the `examples/` binaries and the
//! `benches/` harnesses so every surface regenerates identical numbers.
//! See README.md for the experiment index.

pub mod characterization;
pub mod energy_tables;
pub mod physics_sweep;
pub mod training;

pub use characterization::{fig3b_curve, fig3c_multiply, fig5a_inner_products, MeasuredError};
pub use energy_tables::{fig6_rows, headline_summary};
pub use physics_sweep::{
    drift_sweep, physics_sweep, render_drift_table, render_table, DriftPoint,
    PhysicsPoint, SweepSettings,
};
pub use training::{fig5b_run, fig5c_sweep, SweepPoint};
