//! Device characterisation experiments: Fig. 3(b), Fig. 3(c), Fig. 5(a).

use crate::photonics::mrr::MrrDesign;
use crate::photonics::{BankConfig, BpdMode, WeightBank};
use crate::util::rng::Pcg64;
use crate::util::stats::{effective_bits, Summary};
use crate::Result;

/// Error statistics of a measured analog operation, in the normalised
/// [-1, 1] output domain (the paper's reporting convention).
#[derive(Debug, Clone, Copy)]
pub struct MeasuredError {
    pub n: usize,
    pub sigma: f64,
    pub mean: f64,
    pub effective_bits: f64,
}

impl MeasuredError {
    fn from_summary(s: &Summary) -> MeasuredError {
        MeasuredError {
            n: s.count() as usize,
            sigma: s.std(),
            mean: s.mean(),
            effective_bits: effective_bits(2.0, s.std()),
        }
    }
}

/// Fig. 3(b): theoretical add-drop transmission profile, r = 0.95,
/// negligible attenuation. Returns (phase, T_through, T_drop, weight) rows.
pub fn fig3b_curve(points: usize) -> Vec<(f64, f64, f64, f64)> {
    let design = MrrDesign { self_coupling: 0.95, loss_a: 1.0 };
    (0..points)
        .map(|i| {
            let phi = -std::f64::consts::PI
                + 2.0 * std::f64::consts::PI * i as f64 / (points - 1) as f64;
            (phi, design.through(phi), design.drop(phi), design.weight(phi))
        })
        .collect()
}

/// Fig. 3(c): single-MRR multiplications across `n` random (x, w) pairs
/// (paper: 3900 combinations, σ = 0.019 ⇒ 6.72 bits, mean ≈ -0.001).
///
/// Each measurement is the average of three readouts, as in §2.
pub fn fig3c_multiply(n: usize, seed: u64) -> Result<MeasuredError> {
    let mut bank = WeightBank::new(BankConfig {
        rows: 1,
        cols: 1,
        ..BankConfig::testbed(BpdMode::SingleMrr)
    })?;
    let mut rng = Pcg64::new(seed, 0xf19_3c);
    let mut s = Summary::new();
    for _ in 0..n {
        let x = rng.uniform() as f32;
        let w = rng.uniform_in(-1.0, 1.0) as f32;
        let mut meas = 0.0f64;
        for _ in 0..3 {
            meas += bank.multiply(x, w)? as f64 / 3.0;
        }
        s.add(meas - (x * w) as f64);
    }
    Ok(MeasuredError::from_summary(&s))
}

/// Fig. 5(a): `n` photonic 1×4 inner products through the chosen BPD
/// circuit (paper: 5000 each; off-chip σ = 0.098 ⇒ 4.35 bits, on-chip
/// σ = 0.202 ⇒ 3.31 bits, means ≈ 0.003).
pub fn fig5a_inner_products(mode: BpdMode, n: usize, seed: u64) -> Result<MeasuredError> {
    let mut bank = WeightBank::new(BankConfig {
        seed,
        ..BankConfig::testbed(mode)
    })?;
    let mut rng = Pcg64::new(seed, 0xf19_5a);
    let mut s = Summary::new();
    let cols = bank.cols();
    for _ in 0..n {
        let w: Vec<f32> = (0..cols).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect();
        let x: Vec<f32> = (0..cols).map(|_| rng.uniform() as f32).collect();
        let got = bank.inner_product(&x, &w)? as f64;
        let want: f64 = w
            .iter()
            .zip(&x)
            .map(|(&wi, &xi)| (wi * xi) as f64)
            .sum::<f64>()
            / cols as f64;
        s.add(got - want);
    }
    Ok(MeasuredError::from_summary(&s))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3b_profile_shape() {
        let rows = fig3b_curve(201);
        assert_eq!(rows.len(), 201);
        let mid = rows[100]; // phi = 0 (resonance)
        assert!(mid.0.abs() < 1e-9);
        assert!(mid.1 < 1e-9, "through dips to 0 on resonance");
        assert!((mid.2 - 1.0).abs() < 1e-9, "drop peaks at 1");
        assert!((mid.3 - 1.0).abs() < 1e-9, "weight = +1");
        // energy conservation everywhere (lossless)
        for (_, tp, td, _) in &rows {
            assert!((tp + td - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn fig3c_matches_paper_band() {
        let m = fig3c_multiply(600, 7).unwrap();
        // paper: sigma = 0.019 (6.72 bits); accept the calibrated band
        assert!(m.sigma > 0.008 && m.sigma < 0.035, "sigma {}", m.sigma);
        assert!(m.mean.abs() < 0.01, "mean {}", m.mean);
        assert!(m.effective_bits > 5.5 && m.effective_bits < 8.0);
    }

    #[test]
    fn fig5a_offchip_vs_onchip() {
        let off = fig5a_inner_products(BpdMode::OffChip, 400, 7).unwrap();
        let on = fig5a_inner_products(BpdMode::OnChip, 400, 7).unwrap();
        // paper bands: 0.098 and 0.202
        assert!(off.sigma > 0.06 && off.sigma < 0.14, "off {}", off.sigma);
        assert!(on.sigma > 0.15 && on.sigma < 0.27, "on {}", on.sigma);
        assert!(on.effective_bits < off.effective_bits);
    }
}
