//! `pdfa` — the photonic-DFA coordinator CLI.
//!
//! Subcommands map one-to-one onto the paper's experiments:
//!
//! ```text
//! pdfa train            train a network (Fig. 5(b) conditions)
//! pdfa sweep-resolution test accuracy vs gradient resolution (Fig. 5(c))
//! pdfa characterize     MRR profile + single-MRR multiplies (Fig. 3(b,c))
//! pdfa inner-product    1x4 photonic inner products (Fig. 5(a))
//! pdfa energy           Eq. 2-4 headline numbers + Fig. 6 table
//! pdfa gen-data         write the synthetic digit dataset as IDX files
//! pdfa info             list artifacts and configs in the manifest
//! ```

use std::sync::Arc;

use photonic_dfa::coordinator::run::RunRecorder;
use photonic_dfa::data::synth;
use photonic_dfa::dfa::config::{Algorithm, TrainConfig};
use photonic_dfa::dfa::noise_model::NoiseMode;
use photonic_dfa::dfa::trainer::Trainer;
use photonic_dfa::experiments;
use photonic_dfa::photonics::BpdMode;
use photonic_dfa::runtime::{self, Backend, StepEngine};
use photonic_dfa::util::cli::{help_text, ArgSpec, Args};
use photonic_dfa::util::json::Value;
use photonic_dfa::util::logging;
use photonic_dfa::{Error, Result};

fn main() {
    logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let cmd = argv.first().map(String::as_str).unwrap_or("help");
    let rest = if argv.is_empty() { &[][..] } else { &argv[1..] };
    let wants_help = rest.iter().any(|a| a == "--help" || a == "-h");
    match cmd {
        "train" => run_or_help(cmd, "train a network through the photonic DFA path",
            &train_specs(), rest, wants_help, cmd_train),
        "sweep-resolution" => run_or_help(cmd,
            "Fig. 5(c): accuracy vs gradient effective resolution",
            &sweep_specs(), rest, wants_help, cmd_sweep),
        "characterize" => run_or_help(cmd,
            "Fig. 3(b,c): MRR transmission profile + single-MRR multiplies",
            &char_specs(), rest, wants_help, cmd_characterize),
        "inner-product" => run_or_help(cmd,
            "Fig. 5(a): photonic 1x4 inner-product error statistics",
            &ip_specs(), rest, wants_help, cmd_inner_product),
        "energy" => run_or_help(cmd,
            "Eqs. 2-4 headline numbers and the Fig. 6 sweep",
            &energy_specs(), rest, wants_help, cmd_energy),
        "gen-data" => run_or_help(cmd,
            "generate the synthetic digit dataset as IDX files",
            &gendata_specs(), rest, wants_help, cmd_gen_data),
        "info" => run_or_help(cmd, "list manifest artifacts and configs",
            &info_specs(), rest, wants_help, cmd_info),
        "help" | "--help" | "-h" => {
            print_global_help();
            Ok(())
        }
        other => Err(Error::Cli(format!(
            "unknown command '{other}' (try `pdfa help`)"
        ))),
    }
}

fn run_or_help(
    cmd: &str,
    about: &str,
    specs: &[ArgSpec],
    rest: &[String],
    wants_help: bool,
    f: impl Fn(&Args) -> Result<()>,
) -> Result<()> {
    if wants_help {
        print!("{}", help_text(cmd, about, specs));
        return Ok(());
    }
    let args = Args::parse(specs, rest)?;
    f(&args)
}

fn print_global_help() {
    println!(
        "pdfa — silicon-photonic DFA training coordinator\n\n\
         commands:\n\
         \u{20}  train              train a network (Fig. 5(b) conditions)\n\
         \u{20}  sweep-resolution   accuracy vs gradient resolution (Fig. 5(c))\n\
         \u{20}  characterize       MRR profile + multiplies (Fig. 3(b,c))\n\
         \u{20}  inner-product      1x4 inner-product stats (Fig. 5(a))\n\
         \u{20}  energy             Eq. 2-4 + Fig. 6 tables\n\
         \u{20}  gen-data           write synthetic IDX dataset\n\
         \u{20}  info               inspect the artifact manifest\n\n\
         run `pdfa <command> --help` for options"
    );
}

/// Shared `--backend`/`--artifacts` resolution for engine-driving commands.
fn open_engine(a: &Args) -> Result<Arc<dyn StepEngine>> {
    let backend = Backend::parse(a.str("backend"))
        .ok_or_else(|| Error::Cli(format!("bad --backend '{}'", a.str("backend"))))?;
    runtime::open(a.str("artifacts"), backend)
}

const BACKEND_SPEC: ArgSpec = ArgSpec::opt(
    "backend",
    "auto",
    "step engine: auto | native | pjrt (pjrt needs a build with --features pjrt and a vendored xla crate — see Cargo.toml — plus AOT artifacts)",
);

// ---------------- train ----------------

fn train_specs() -> Vec<ArgSpec> {
    vec![
        ArgSpec::opt("config", "mnist", "network config: tiny | small | mnist"),
        ArgSpec::opt("algorithm", "dfa", "dfa | backprop"),
        ArgSpec::opt(
            "noise",
            "clean",
            "clean | offchip | onchip | gaussian:<s> | resolution:<b> | quantized:<b> | device:<ideal|offchip|onchip>",
        ),
        ArgSpec::opt("epochs", "10", "training epochs"),
        ArgSpec::opt("lr", "0.01", "learning rate (paper: 0.01)"),
        ArgSpec::opt("momentum", "0.9", "SGD momentum (paper: 0.9)"),
        ArgSpec::opt("seed", "1", "master seed"),
        ArgSpec::opt("n-train", "60000", "training examples (synthetic)"),
        ArgSpec::opt("n-test", "10000", "test examples (synthetic)"),
        ArgSpec::opt("data-dir", "", "IDX dataset directory (empty = synthesise)"),
        ArgSpec::opt("max-steps", "0", "cap steps per epoch (0 = full epoch)"),
        ArgSpec::opt("artifacts", "artifacts", "AOT artifact directory"),
        BACKEND_SPEC,
        ArgSpec::opt("out", "runs", "run output directory"),
        ArgSpec::opt("run-name", "", "run name (default: derived)"),
    ]
}

fn cmd_train(a: &Args) -> Result<()> {
    let noise = NoiseMode::parse(a.str("noise"))
        .ok_or_else(|| Error::Cli(format!("bad --noise '{}'", a.str("noise"))))?;
    let algorithm = match a.str("algorithm") {
        "dfa" => Algorithm::Dfa,
        "backprop" => Algorithm::Backprop,
        other => return Err(Error::Cli(format!("bad --algorithm '{other}'"))),
    };
    let cfg = TrainConfig {
        config: a.str("config").into(),
        algorithm,
        noise,
        epochs: a.usize("epochs")?,
        lr: a.f64("lr")? as f32,
        momentum: a.f64("momentum")? as f32,
        seed: a.u64("seed")?,
        n_train: a.usize("n-train")?,
        n_test: a.usize("n-test")?,
        data_dir: (!a.str("data-dir").is_empty()).then(|| a.str("data-dir").into()),
        eval_every: 1,
        max_steps_per_epoch: match a.usize("max-steps")? {
            0 => None,
            n => Some(n),
        },
    };
    let run_name = if a.str("run-name").is_empty() {
        format!(
            "{}_{}_{}_seed{}",
            a.str("config"),
            a.str("algorithm"),
            a.str("noise").replace(':', "-"),
            cfg.seed
        )
    } else {
        a.str("run-name").into()
    };

    let engine = open_engine(a)?;
    let mut recorder = RunRecorder::create(a.str("out"), &run_name)?;
    recorder.write_config(&cfg.to_json())?;
    let mut trainer = Trainer::new(engine, cfg)?;
    photonic_dfa::log_info!(
        "run '{run_name}' starting ({}): {}",
        trainer.engine().platform_name(),
        trainer.cfg.noise.describe()
    );
    let (train, test) = trainer.load_data()?;

    let result = {
        let recorder_cell = std::cell::RefCell::new(&mut recorder);
        trainer.train(train, test, |stats| {
            let _ = recorder_cell.borrow_mut().record_epoch(stats.to_json());
        })?
    };

    recorder.write_checkpoint("final.ckpt", &trainer.state.to_bytes())?;
    recorder.write_report(
        "result.json",
        &Value::object(vec![
            ("test_acc", Value::Number(result.test_acc)),
            ("total_steps", Value::Number(result.total_steps as f64)),
            ("wall_s", Value::Number(result.wall_s)),
            ("photonic_macs", Value::Number(result.photonic_macs as f64)),
            ("metrics", trainer.metrics.to_json()),
        ]),
    )?;
    println!(
        "test accuracy: {:.4} ({} steps, {:.1}s, {} photonic MACs)",
        result.test_acc, result.total_steps, result.wall_s, result.photonic_macs
    );
    println!("run artifacts in {}", recorder.dir.display());
    Ok(())
}

// ---------------- sweep-resolution ----------------

fn sweep_specs() -> Vec<ArgSpec> {
    vec![
        ArgSpec::opt("config", "small", "network config"),
        ArgSpec::opt("bits", "1,2,3,4,5,6,8", "comma-separated bit depths"),
        ArgSpec::opt("epochs", "3", "epochs per point"),
        ArgSpec::opt("seed", "1", "master seed"),
        ArgSpec::opt("n-train", "8192", "training examples per point"),
        ArgSpec::opt("n-test", "2048", "test examples"),
        ArgSpec::opt("max-steps", "0", "cap steps per epoch (0 = full)"),
        ArgSpec::opt("artifacts", "artifacts", "AOT artifact directory"),
        BACKEND_SPEC,
    ]
}

fn cmd_sweep(a: &Args) -> Result<()> {
    let engine = open_engine(a)?;
    let bits = a.f64_list("bits")?;
    let pts = experiments::fig5c_sweep(
        engine,
        a.str("config"),
        &bits,
        a.usize("epochs")?,
        a.u64("seed")?,
        a.usize("n-train")?,
        a.usize("n-test")?,
        match a.usize("max-steps")? {
            0 => None,
            n => Some(n),
        },
    )?;
    println!("bits   sigma     test_acc   (Fig. 5(c))");
    for p in pts {
        println!("{:>4.1}  {:.5}   {:.4}", p.bits, p.sigma, p.test_acc);
    }
    Ok(())
}

// ---------------- characterize ----------------

fn char_specs() -> Vec<ArgSpec> {
    vec![
        ArgSpec::opt("n", "3900", "number of multiply measurements (paper: 3900)"),
        ArgSpec::opt("seed", "7", "device + measurement seed"),
        ArgSpec::opt("profile-points", "0", "also print the Fig. 3(b) profile rows"),
    ]
}

fn cmd_characterize(a: &Args) -> Result<()> {
    let pts = a.usize("profile-points")?;
    if pts > 0 {
        println!("phase      T_pass     T_drop     weight    (Fig. 3(b))");
        for (phi, tp, td, w) in experiments::fig3b_curve(pts) {
            println!("{phi:>8.4}  {tp:>8.5}  {td:>8.5}  {w:>8.5}");
        }
    }
    let m = experiments::fig3c_multiply(a.usize("n")?, a.u64("seed")?)?;
    println!(
        "single-MRR multiply (Fig. 3(c)): n={} sigma={:.4} mean={:+.4} -> {:.2} bits \
         [paper: sigma=0.019, mean=-0.001, 6.72 bits]",
        m.n, m.sigma, m.mean, m.effective_bits
    );
    Ok(())
}

// ---------------- inner-product ----------------

fn ip_specs() -> Vec<ArgSpec> {
    vec![
        ArgSpec::opt("n", "5000", "measurements per circuit (paper: 5000)"),
        ArgSpec::opt("seed", "7", "device + measurement seed"),
        ArgSpec::opt("mode", "both", "offchip | onchip | both"),
    ]
}

fn cmd_inner_product(a: &Args) -> Result<()> {
    let n = a.usize("n")?;
    let seed = a.u64("seed")?;
    let modes: Vec<(&str, BpdMode, f64, f64)> = match a.str("mode") {
        "offchip" => vec![("off-chip BPD", BpdMode::OffChip, 0.098, 4.35)],
        "onchip" => vec![("on-chip BPD", BpdMode::OnChip, 0.202, 3.31)],
        "both" => vec![
            ("off-chip BPD", BpdMode::OffChip, 0.098, 4.35),
            ("on-chip BPD", BpdMode::OnChip, 0.202, 3.31),
        ],
        other => return Err(Error::Cli(format!("bad --mode '{other}'"))),
    };
    println!("circuit        n      sigma    mean      bits   [paper sigma/bits]");
    for (label, mode, paper_sigma, paper_bits) in modes {
        let m = experiments::fig5a_inner_products(mode, n, seed)?;
        println!(
            "{label:<13} {:>5}  {:.4}  {:+.4}   {:.2}   [{paper_sigma} / {paper_bits}]",
            m.n, m.sigma, m.mean, m.effective_bits
        );
    }
    Ok(())
}

// ---------------- energy ----------------

fn energy_specs() -> Vec<ArgSpec> {
    vec![
        ArgSpec::opt("fig6-points", "14", "points on the Fig. 6 sweep"),
        ArgSpec::opt("fig6-max-cells", "100000", "largest MAC-cell count"),
    ]
}

fn cmd_energy(a: &Args) -> Result<()> {
    print!("{}", experiments::energy_tables::render_headline());
    println!("\nFig. 6 — optimal E_op vs MAC cells (both locking schemes):");
    println!("cells     E_op heater (pJ)   E_op trimmed (pJ)");
    for (cells, h, t) in
        experiments::fig6_rows(25, a.usize("fig6-max-cells")?, a.usize("fig6-points")?)
    {
        println!("{cells:>7}   {:>12.3}      {:>12.3}", h * 1e12, t * 1e12);
    }
    Ok(())
}

// ---------------- gen-data ----------------

fn gendata_specs() -> Vec<ArgSpec> {
    vec![
        ArgSpec::opt("out", "data", "output directory"),
        ArgSpec::opt("n-train", "60000", "training images"),
        ArgSpec::opt("n-test", "10000", "test images"),
        ArgSpec::opt("seed", "1", "generation seed"),
    ]
}

fn cmd_gen_data(a: &Args) -> Result<()> {
    let out = std::path::PathBuf::from(a.str("out"));
    std::fs::create_dir_all(&out)?;
    let threads = std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1);
    let seed = a.u64("seed")?;
    let (tr_img, tr_lab) =
        synth::generate_split_parallel(a.usize("n-train")?, seed ^ 0x7a11, threads);
    tr_img.save(out.join("train-images-idx3-ubyte.gz"))?;
    tr_lab.save(out.join("train-labels-idx1-ubyte.gz"))?;
    let (te_img, te_lab) =
        synth::generate_split_parallel(a.usize("n-test")?, seed ^ 0x7e57, threads);
    te_img.save(out.join("t10k-images-idx3-ubyte.gz"))?;
    te_lab.save(out.join("t10k-labels-idx1-ubyte.gz"))?;
    println!(
        "wrote {} train + {} test images to {}",
        tr_img.dims[0],
        te_img.dims[0],
        out.display()
    );
    Ok(())
}

// ---------------- info ----------------

fn info_specs() -> Vec<ArgSpec> {
    vec![
        ArgSpec::opt("artifacts", "artifacts", "AOT artifact directory"),
        BACKEND_SPEC,
    ]
}

fn cmd_info(a: &Args) -> Result<()> {
    let engine = open_engine(a)?;
    println!("backend: {}", engine.platform_name());
    println!("configs:");
    for (name, d) in engine.configs() {
        println!(
            "  {name}: {}-{}-{}-{} batch {}",
            d.d_in, d.d_h1, d.d_h2, d.d_out, d.batch
        );
    }
    println!("artifacts:");
    for art in engine.artifact_specs() {
        println!(
            "  {}: {} inputs, {} outputs ({})",
            art.name,
            art.inputs.len(),
            art.outputs.len(),
            art.path.file_name().unwrap_or_default().to_string_lossy()
        );
    }
    Ok(())
}
