//! `pdfa` — the photonic-DFA coordinator CLI.
//!
//! Subcommands map one-to-one onto the paper's experiments:
//!
//! ```text
//! pdfa train            train a network (Fig. 5(b) conditions)
//! pdfa infer            batched inference over a saved checkpoint
//! pdfa serve            dynamic-batching inference server (stdin/TCP/loopback)
//! pdfa sweep-resolution test accuracy vs gradient resolution (Fig. 5(c))
//! pdfa sweep-physics    in-situ accuracy vs DAC/ADC bits x read noise
//! pdfa characterize     MRR profile + single-MRR multiplies (Fig. 3(b,c))
//! pdfa inner-product    1x4 photonic inner products (Fig. 5(a))
//! pdfa energy           Eq. 2-4 headline numbers + Fig. 6 table
//! pdfa report           telemetry of a recorded run vs the §5 targets
//! pdfa gen-data         write the synthetic digit dataset as IDX files
//! pdfa info             list artifacts and configs in the manifest
//! pdfa lint             static-analysis pass over the repo's own sources
//! ```

use std::io::BufRead;
use std::sync::Arc;
use std::time::Duration;

use photonic_dfa::coordinator::run::RunRecorder;
use photonic_dfa::data::{synth, Dataset};
use photonic_dfa::dfa::checkpoint::Checkpoint;
use photonic_dfa::dfa::config::{Algorithm, TrainConfig};
use photonic_dfa::dfa::noise_model::NoiseMode;
use photonic_dfa::dfa::trainer::Trainer;
use photonic_dfa::experiments;
use photonic_dfa::photonics::BpdMode;
use photonic_dfa::runtime::{self, Backend, PhysicsConfig, StepEngine};
use photonic_dfa::serve::{
    net, BatchPolicy, NetConfig, NetServer, NetStats, ServeConfig, ServeStats, Server,
    Ticket, TrafficConfig, TrafficReport,
};
use photonic_dfa::telemetry::report as telemetry_report;
use photonic_dfa::util::cli::{help_text, ArgSpec, Args};
use photonic_dfa::util::json::Value;
use photonic_dfa::util::logging;
use photonic_dfa::util::rng::Pcg64;
use photonic_dfa::{Error, Result};

fn main() {
    logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let cmd = argv.first().map(String::as_str).unwrap_or("help");
    let rest = if argv.is_empty() { &[][..] } else { &argv[1..] };
    let wants_help = rest.iter().any(|a| a == "--help" || a == "-h");
    match cmd {
        "train" => run_or_help(cmd, "train a network through the photonic DFA path",
            &train_specs(), rest, wants_help, cmd_train),
        "infer" => run_or_help(cmd,
            "batched inference over a checkpoint (bit-identical to the reference forward)",
            &infer_specs(), rest, wants_help, cmd_infer),
        "serve" => run_or_help(cmd,
            "dynamic-batching inference server over a checkpoint (stdin, \
             synthetic loopback, or a concurrent NDJSON-over-TCP front-end)",
            &serve_specs(), rest, wants_help, cmd_serve),
        "sweep-resolution" => run_or_help(cmd,
            "Fig. 5(c): accuracy vs gradient effective resolution",
            &sweep_specs(), rest, wants_help, cmd_sweep),
        "sweep-physics" => run_or_help(cmd,
            "in-situ photonic training accuracy vs DAC/ADC bits x read-noise \
             sigma, or vs thermal drift with --drift-rates",
            &sweep_physics_specs(), rest, wants_help, cmd_sweep_physics),
        "characterize" => run_or_help(cmd,
            "Fig. 3(b,c): MRR transmission profile + single-MRR multiplies",
            &char_specs(), rest, wants_help, cmd_characterize),
        "inner-product" => run_or_help(cmd,
            "Fig. 5(a): photonic 1x4 inner-product error statistics",
            &ip_specs(), rest, wants_help, cmd_inner_product),
        "energy" => run_or_help(cmd,
            "Eqs. 2-4 headline numbers and the Fig. 6 sweep",
            &energy_specs(), rest, wants_help, cmd_energy),
        "report" => {
            // `pdfa report <path>` reads naturally; rewrite the leading
            // positional into the declared --path flag
            let mut rest = rest.to_vec();
            if rest.first().is_some_and(|a| !a.starts_with("--")) {
                rest.insert(0, "--path".into());
            }
            run_or_help(cmd,
                "telemetry of a recorded run (or checkpoint) vs the paper's §5 targets",
                &report_specs(), &rest, wants_help, cmd_report)
        }
        "gen-data" => run_or_help(cmd,
            "generate the synthetic digit dataset as IDX files",
            &gendata_specs(), rest, wants_help, cmd_gen_data),
        "info" => run_or_help(cmd, "list manifest artifacts and configs",
            &info_specs(), rest, wants_help, cmd_info),
        "lint" => run_or_help(cmd,
            "enforce the repo's hot-path/determinism/panic-safety invariants \
             statically (see DESIGN.md, \"Static analysis\")",
            &lint_specs(), rest, wants_help, cmd_lint),
        "help" | "--help" | "-h" => {
            print_global_help();
            Ok(())
        }
        other => Err(Error::Cli(format!(
            "unknown command '{other}' (try `pdfa help`)"
        ))),
    }
}

fn run_or_help(
    cmd: &str,
    about: &str,
    specs: &[ArgSpec],
    rest: &[String],
    wants_help: bool,
    f: impl Fn(&Args) -> Result<()>,
) -> Result<()> {
    if wants_help {
        print!("{}", help_text(cmd, about, specs));
        return Ok(());
    }
    let args = Args::parse(specs, rest)?;
    f(&args)
}

fn print_global_help() {
    println!(
        "pdfa — silicon-photonic DFA training coordinator\n\n\
         commands:\n\
         \u{20}  train              train a network (Fig. 5(b) conditions)\n\
         \u{20}  infer              batched inference over a saved checkpoint\n\
         \u{20}  serve              dynamic-batching inference server\n\
         \u{20}  sweep-resolution   accuracy vs gradient resolution (Fig. 5(c))\n\
         \u{20}  sweep-physics      in-situ accuracy vs DAC/ADC bits x noise sigma\n\
         \u{20}  characterize       MRR profile + multiplies (Fig. 3(b,c))\n\
         \u{20}  inner-product      1x4 inner-product stats (Fig. 5(a))\n\
         \u{20}  energy             Eq. 2-4 + Fig. 6 tables\n\
         \u{20}  report             run telemetry vs the §5 targets (MAC/s, pJ/MAC)\n\
         \u{20}  gen-data           write synthetic IDX dataset\n\
         \u{20}  info               inspect the artifact manifest\n\
         \u{20}  lint               static-analysis pass over the repo's own sources\n\n\
         run `pdfa <command> --help` for options"
    );
}

/// Shared `--backend`/`--physics`/`--threads`/`--artifacts` resolution
/// for engine-driving commands. Returns the engine plus the physics
/// config when the photonic backend was selected (for the train
/// protocol). The thread knob reaches every engine: the photonic
/// batch-row shards directly, the native/PJRT GEMM kernels via the
/// process-wide cap — results are bit-identical at any value.
fn open_engine(a: &Args) -> Result<(Arc<dyn StepEngine>, Option<PhysicsConfig>)> {
    let backend = match Backend::parse(a.str("backend"))? {
        // the --physics argument replaces the default carried by parse()
        Backend::Photonic(_) => Backend::Photonic(PhysicsConfig::parse(a.str("physics"))?),
        other => other,
    };
    let physics = match backend {
        Backend::Photonic(p) => Some(p),
        _ => None,
    };
    let engine = runtime::open_threaded(a.str("artifacts"), backend, a.usize("threads")?)?;
    Ok((engine, physics))
}

const BACKEND_SPEC: ArgSpec = ArgSpec::opt(
    "backend",
    "auto",
    "step engine: auto | native | photonic | pjrt (photonic routes every training matvec through the device-level MRR weight bank — see --physics; pjrt needs a build with --features pjrt and a vendored xla crate — see Cargo.toml — plus AOT artifacts)",
);

const PHYSICS_SPEC: ArgSpec = ArgSpec::opt(
    "physics",
    "paper",
    "photonic-backend device physics: ideal | paper (alias: static) | drifty, with optional key=value overrides bank=RxC, dac=N, adc=N, sigma=S, xtalk=on|off, lock=on|off, seed=N, drift:rate=R (thermal walk, rad/\u{221a}tick), drift:aging=A (calibration aging, rad/tick), drift:recal=T (online recalibration threshold in weight units; drives the scheduler) (e.g. 'drifty,drift:rate=1e-3'); ignored by the other backends",
);

const THREADS_SPEC: ArgSpec = ArgSpec::opt(
    "threads",
    "0",
    "worker threads for the parallel paths: photonic batch-row shards, GEMM kernels, sweep grid cells, dataset synthesis (0 = all cores); per-row counter-keyed noise streams keep results bit-identical at any value",
);

// ---------------- train ----------------

fn train_specs() -> Vec<ArgSpec> {
    vec![
        ArgSpec::opt("config", "mnist", "network config: tiny | small | mnist"),
        ArgSpec::opt("algorithm", "dfa", "dfa | backprop"),
        ArgSpec::opt(
            "noise",
            "clean",
            "clean | offchip | onchip | gaussian:<s> | resolution:<b> | quantized:<b> | device:<ideal|offchip|onchip>",
        ),
        ArgSpec::opt("epochs", "10", "training epochs"),
        ArgSpec::opt("lr", "0.01", "learning rate (paper: 0.01)"),
        ArgSpec::opt("momentum", "0.9", "SGD momentum (paper: 0.9)"),
        ArgSpec::opt("seed", "1", "master seed"),
        ArgSpec::opt("n-train", "60000", "training examples (synthetic)"),
        ArgSpec::opt("n-test", "10000", "test examples (synthetic)"),
        ArgSpec::opt("data-dir", "", "IDX dataset directory (empty = synthesise)"),
        ArgSpec::opt("max-steps", "0", "cap steps per epoch (0 = full epoch)"),
        ArgSpec::opt("artifacts", "artifacts", "AOT artifact directory"),
        BACKEND_SPEC,
        PHYSICS_SPEC,
        THREADS_SPEC,
        ArgSpec::opt("out", "runs", "run output directory"),
        ArgSpec::opt("run-name", "", "run name (default: derived)"),
        ArgSpec::opt(
            "save",
            "",
            "checkpoint path (default <out>/<run>/ckpt.gz when --save-every is set)",
        ),
        ArgSpec::opt("save-every", "0", "checkpoint every N epochs (0 = final only)"),
        ArgSpec::opt("resume", "", "resume from a checkpoint of the same run"),
    ]
}

fn cmd_train(a: &Args) -> Result<()> {
    let noise = NoiseMode::parse(a.str("noise"))
        .ok_or_else(|| Error::Cli(format!("bad --noise '{}'", a.str("noise"))))?;
    let algorithm = match a.str("algorithm") {
        "dfa" => Algorithm::Dfa,
        "backprop" => Algorithm::Backprop,
        other => return Err(Error::Cli(format!("bad --algorithm '{other}'"))),
    };
    let mut cfg = TrainConfig {
        config: a.str("config").into(),
        algorithm,
        noise,
        epochs: a.usize("epochs")?,
        lr: a.f64("lr")? as f32,
        momentum: a.f64("momentum")? as f32,
        seed: a.u64("seed")?,
        n_train: a.usize("n-train")?,
        n_test: a.usize("n-test")?,
        data_dir: (!a.str("data-dir").is_empty()).then(|| a.str("data-dir").into()),
        eval_every: 1,
        max_steps_per_epoch: match a.usize("max-steps")? {
            0 => None,
            n => Some(n),
        },
        threads: a.usize("threads")?,
        ..TrainConfig::default()
    };
    let run_name = if a.str("run-name").is_empty() {
        format!(
            "{}_{}_{}_seed{}",
            a.str("config"),
            a.str("algorithm"),
            a.str("noise").replace(':', "-"),
            cfg.seed
        )
    } else {
        a.str("run-name").into()
    };

    let (engine, physics) = open_engine(a)?;
    cfg.physics = physics;
    let mut recorder = RunRecorder::create(a.str("out"), &run_name)?;
    cfg.save_every = a.usize("save-every")?;
    cfg.save_path = if !a.str("save").is_empty() {
        Some(a.str("save").to_string())
    } else if cfg.save_every > 0 {
        Some(recorder.dir.join("ckpt.gz").to_string_lossy().into_owned())
    } else {
        None
    };
    recorder.write_engine_config(&engine.platform_name(), &cfg.to_json())?;
    let mut trainer = Trainer::new(engine, cfg)?;
    if !a.str("resume").is_empty() {
        let ckpt = Checkpoint::load(a.str("resume"))?;
        trainer.restore(&ckpt)?;
        photonic_dfa::log_info!(
            "resumed from {} (epoch {}, {} steps)",
            a.str("resume"),
            ckpt.epoch,
            ckpt.total_steps
        );
    }
    photonic_dfa::log_info!(
        "run '{run_name}' starting ({}): {}",
        trainer.engine().platform_name(),
        trainer.cfg.noise.describe()
    );
    let (train, test) = trainer.load_data()?;

    let result = {
        let recorder_cell = std::cell::RefCell::new(&mut recorder);
        trainer.train(train, test, |stats| {
            let _ = recorder_cell.borrow_mut().record_epoch(stats.to_json());
        })?
    };

    // serialisation is deterministic and save() stages through tmp+rename,
    // so this is safe and byte-identical even when --save points here too
    trainer.save_checkpoint(recorder.dir.join("final.ckpt"))?;
    recorder.write_report(
        "result.json",
        &Value::object(vec![
            ("test_acc", Value::Number(result.test_acc)),
            ("total_steps", Value::Number(result.total_steps as f64)),
            ("wall_s", Value::Number(result.wall_s)),
            ("photonic_macs", Value::Number(result.photonic_macs as f64)),
            ("metrics", trainer.metrics.to_json()),
            // deterministic counters (byte-identical at any --threads);
            // the wall-clock rate rides outside the counter object
            ("telemetry", result.telemetry.to_json()),
            (
                "mac_per_s",
                Value::Number(result.telemetry.macs_per_second(result.wall_s)),
            ),
        ]),
    )?;
    println!(
        "test accuracy: {:.4} ({} steps, {:.1}s, {} photonic MACs)",
        result.test_acc, result.total_steps, result.wall_s, result.photonic_macs
    );
    println!(
        "telemetry: {} MACs, {} MAC/s{}",
        result.telemetry.macs,
        photonic_dfa::util::benchx::fmt_si(
            result.telemetry.macs_per_second(result.wall_s)
        ),
        result
            .telemetry
            .pj_per_mac()
            .map_or(String::new(), |pj| format!(", {pj:.2} pJ/MAC modeled")),
    );
    println!("run artifacts in {}", recorder.dir.display());
    println!("telemetry report: pdfa report {}", recorder.dir.display());
    if let Some(path) = &trainer.cfg.save_path {
        println!("checkpoint: {path}");
    }
    println!("checkpoint: {}", recorder.dir.join("final.ckpt").display());
    Ok(())
}

// ---------------- infer / serve ----------------

/// Shared `--workers`/`--max-batch`/`--max-wait-ms`/`--queue-cap` specs.
fn serving_knob_specs() -> Vec<ArgSpec> {
    vec![
        ArgSpec::req("checkpoint", "checkpoint file (written by `pdfa train`)"),
        ArgSpec::opt("workers", "2", "forward-artifact replicas in the worker pool"),
        ArgSpec::opt(
            "max-batch",
            "0",
            "flush a micro-batch at this many requests (0 = the network's batch dim)",
        ),
        ArgSpec::opt("max-wait-ms", "2", "flush a partial micro-batch after this wait"),
        ArgSpec::opt("queue-cap", "256", "bounded request-queue depth (backpressure)"),
        ArgSpec::opt("artifacts", "artifacts", "AOT artifact directory"),
        BACKEND_SPEC,
        PHYSICS_SPEC,
        THREADS_SPEC,
    ]
}

/// Open the engine, load the checkpoint and start the worker pool.
fn start_server(a: &Args) -> Result<(Server, Checkpoint)> {
    let (engine, _physics) = open_engine(a)?;
    let ckpt = Checkpoint::load(a.str("checkpoint"))?;
    let policy = BatchPolicy {
        max_batch: match a.usize("max-batch")? {
            0 => ckpt.dims.batch,
            n => n,
        },
        max_wait: Duration::from_millis(a.u64("max-wait-ms")?),
        queue_cap: a.usize("queue-cap")?.max(1),
    };
    let cfg = ServeConfig { workers: a.usize("workers")?.max(1), policy };
    photonic_dfa::log_info!(
        "serving '{}' ({}-{}-{}-{}) from {}: {} workers, max_batch {}, max_wait {:?}",
        ckpt.config,
        ckpt.dims.d_in,
        ckpt.dims.d_h1,
        ckpt.dims.d_h2,
        ckpt.dims.d_out,
        a.str("checkpoint"),
        cfg.workers,
        cfg.policy.max_batch,
        cfg.policy.max_wait
    );
    let server = Server::from_checkpoint(&engine, &ckpt, cfg)?;
    Ok((server, ckpt))
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

fn infer_specs() -> Vec<ArgSpec> {
    let mut specs = serving_knob_specs();
    specs.extend([
        ArgSpec::opt("n", "8", "number of samples to run"),
        ArgSpec::opt("data-dir", "", "IDX dataset directory (test split; empty = synthetic)"),
        ArgSpec::opt("seed", "1", "synthetic request seed"),
        ArgSpec::opt("dump-logits", "", "also write raw little-endian f32 logits here"),
    ]);
    specs
}

fn cmd_infer(a: &Args) -> Result<()> {
    let (server, ckpt) = start_server(a)?;
    let d_in = ckpt.dims.d_in;
    let inputs: Vec<Vec<f32>> = if !a.str("data-dir").is_empty() {
        let ds = Dataset::load_split(a.str("data-dir"), false)?;
        if ds.dim() != d_in {
            return Err(Error::Data(format!(
                "dataset dim {} != checkpoint d_in {d_in}",
                ds.dim()
            )));
        }
        let n = a.usize("n")?.min(ds.len());
        (0..n).map(|i| ds.x.row(i).to_vec()).collect()
    } else {
        let mut rng = Pcg64::seed(a.u64("seed")?);
        (0..a.usize("n")?)
            .map(|_| (0..d_in).map(|_| rng.uniform() as f32).collect())
            .collect()
    };

    // burst-submit everything (exercises dynamic batching), then collect
    // replies in submission order
    let tickets: Result<Vec<_>> =
        inputs.iter().map(|x| server.submit(x.clone())).collect();
    let mut raw = Vec::new();
    for (i, ticket) in tickets?.into_iter().enumerate() {
        let logits = ticket.wait()?;
        println!("sample {i:>4}: pred {}  logits {logits:?}", argmax(&logits));
        for v in &logits {
            raw.extend_from_slice(&v.to_le_bytes());
        }
    }
    if !a.str("dump-logits").is_empty() {
        std::fs::write(a.str("dump-logits"), &raw)?;
    }
    println!("{}", server.shutdown().report());
    Ok(())
}

fn serve_specs() -> Vec<ArgSpec> {
    let mut specs = serving_knob_specs();
    specs.extend([
        ArgSpec::opt(
            "source",
            "stdin",
            "stdin | synthetic (in-process loopback) | tcp (NDJSON server + \
             many-connection loopback traffic driver) | listen (NDJSON server \
             for external clients)",
        ),
        ArgSpec::opt(
            "max-requests",
            "0",
            "stop after N accepted requests (0 = until EOF / until stopped; \
             synthetic and tcp default to 64 and 512)",
        ),
        ArgSpec::opt("seed", "1", "synthetic/tcp request seed"),
        ArgSpec::opt(
            "pipeline",
            "1",
            "max in-flight requests per producer: the stdin loop's depth cap, \
             and each tcp driver connection's pipeline depth (1 = await every \
             reply before the next request; raise so micro-batching engages)",
        ),
        ArgSpec::opt("listen", "127.0.0.1:0", "bind address for tcp/listen (port 0 = ephemeral)"),
        ArgSpec::opt("clients", "8", "concurrent driver connections (tcp source)"),
        ArgSpec::opt(
            "inflight",
            "32",
            "per-connection in-flight request cap on the server side (tcp/listen)",
        ),
        ArgSpec::flag(
            "verify",
            "tcp source: check every reply bit-exact against the reference forward",
        ),
        ArgSpec::opt("bench-out", "", "tcp source: write a BENCH_SERVE.json perf record here"),
    ]);
    specs
}

fn cmd_serve(a: &Args) -> Result<()> {
    let (server, ckpt) = start_server(a)?;
    let d_in = ckpt.dims.d_in;
    let max_requests = a.usize("max-requests")?;
    match a.str("source") {
        "synthetic" => {
            let n = if max_requests == 0 { 64 } else { max_requests };
            let mut rng = Pcg64::seed(a.u64("seed")?);
            // keep per-request failures (submit or execution) from
            // aborting the run: tally them and still print the stats
            // report, so a partially failing load run stays diagnosable
            let tickets: Vec<Result<Ticket>> = (0..n)
                .map(|_| {
                    let x: Vec<f32> =
                        (0..d_in).map(|_| rng.uniform() as f32).collect();
                    server.submit(x)
                })
                .collect();
            let mut preds = vec![0usize; server.d_out()];
            let mut failed = 0usize;
            for ticket in tickets {
                match ticket.and_then(Ticket::wait) {
                    Ok(logits) => preds[argmax(&logits)] += 1,
                    Err(e) => {
                        failed += 1;
                        println!("error: {e}");
                    }
                }
            }
            println!(
                "served {n} synthetic requests ({failed} failed); \
                 predictions per class: {preds:?}"
            );
        }
        "tcp" => {
            let listener = std::net::TcpListener::bind(a.str("listen"))?;
            let clients = a.usize("clients")?.max(1);
            let total = if max_requests == 0 { 512 } else { max_requests };
            let tcfg = TrafficConfig {
                clients,
                requests_per_client: total.div_ceil(clients),
                depth: a.usize("pipeline")?.max(1),
                d_in,
                seed: a.u64("seed")?,
            };
            // the driver sends an exact request count and then the
            // front-end is shut down, so no server-side budget here
            let net_cfg = NetConfig {
                max_inflight: a.usize("inflight")?.max(1),
                max_requests: 0,
            };
            let server = Arc::new(server);
            let netsrv = NetServer::start(server.clone(), listener, net_cfg)?;
            let addr = netsrv.local_addr();
            println!("listening on {addr}");
            let verify_params =
                a.flag("verify").then(|| ckpt.state.params().to_vec());
            let report = net::drive(addr, &tcfg, verify_params.as_deref())?;
            let net_stats = netsrv.shutdown();
            let server = Arc::try_unwrap(server).map_err(|_| {
                Error::msg("serve: server still referenced after drain")
            })?;
            let stats = server.shutdown();
            println!("{}", report.report());
            println!("{}", stats.report());
            if !a.str("bench-out").is_empty() {
                write_bench_serve(a, &ckpt, &tcfg, &report, &net_stats, &stats)?;
            }
            return Ok(());
        }
        "listen" => {
            let listener = std::net::TcpListener::bind(a.str("listen"))?;
            let net_cfg = NetConfig {
                max_inflight: a.usize("inflight")?.max(1),
                max_requests: max_requests as u64,
            };
            let server = Arc::new(server);
            let netsrv = NetServer::start(server.clone(), listener, net_cfg)?;
            // external clients (and the CI smoke test) scrape this line
            // for the ephemeral port
            println!("listening on {}", netsrv.local_addr());
            let net_stats = netsrv.join_all();
            println!(
                "tcp front-end: {} accepted / {} rejected over {} connections",
                net_stats.accepted, net_stats.rejected, net_stats.connections
            );
            let server = Arc::try_unwrap(server).map_err(|_| {
                Error::msg("serve: server still referenced after drain")
            })?;
            println!("{}", server.shutdown().report());
            return Ok(());
        }
        "stdin" => {
            // in-order replies with up to --pipeline requests in flight:
            // depth 1 is the interactive reply-per-line loop, larger
            // depths let piped batch input actually fill micro-batches
            let depth = a.usize("pipeline")?.max(1);
            let mut pending: std::collections::VecDeque<photonic_dfa::serve::Ticket> =
                std::collections::VecDeque::new();
            let print_reply = |reply: Result<Vec<f32>>| match reply {
                Ok(logits) => println!("pred {}  logits {logits:?}", argmax(&logits)),
                Err(e) => println!("error: {e}"),
            };
            let stdin = std::io::stdin();
            let mut served = 0usize;
            for line in stdin.lock().lines() {
                let line = line?;
                let line = line.trim();
                if line.is_empty() {
                    continue;
                }
                let parsed: std::result::Result<Vec<f32>, _> = line
                    .split(|c: char| c == ',' || c.is_whitespace())
                    .filter(|s| !s.is_empty())
                    .map(str::parse::<f32>)
                    .collect();
                let x = match parsed {
                    // width errors surface through submit's Shape check
                    Ok(x) => x,
                    Err(e) => {
                        println!("error: bad request line ({e})");
                        continue;
                    }
                };
                match server.submit(x) {
                    Ok(ticket) => {
                        pending.push_back(ticket);
                        // only an accepted request consumes the
                        // --max-requests budget: a rejected submit used to
                        // count too, stopping the loop short of N
                        served += 1;
                    }
                    Err(e) => {
                        println!("error: {e}");
                        continue;
                    }
                }
                // drain replies that are already done (poll consumes the
                // reply, so print it directly), then enforce the depth cap
                while let Some(reply) = pending.front().and_then(|t| t.poll()) {
                    pending.pop_front();
                    print_reply(reply);
                }
                while pending.len() >= depth {
                    let ticket = pending.pop_front().expect("len checked");
                    print_reply(ticket.wait());
                }
                if max_requests > 0 && served >= max_requests {
                    break;
                }
            }
            for ticket in pending {
                print_reply(ticket.wait());
            }
        }
        other => return Err(Error::Cli(format!("bad --source '{other}'"))),
    }
    println!("{}", server.shutdown().report());
    Ok(())
}

/// Write the `--bench-out` perf record for a `--source tcp` run. Cold
/// path, so the DOM builder is the right tool (the per-request wire uses
/// the streaming codec instead).
fn write_bench_serve(
    a: &Args,
    ckpt: &Checkpoint,
    tcfg: &TrafficConfig,
    report: &TrafficReport,
    net_stats: &NetStats,
    stats: &ServeStats,
) -> Result<()> {
    let path = a.str("bench-out");
    let lat = &report.latency;
    let max_batch = match a.usize("max-batch")? {
        0 => ckpt.dims.batch,
        n => n,
    };
    let v = Value::object(vec![
        ("bench", Value::String("serve_tcp".into())),
        ("config", Value::String(ckpt.config.clone())),
        ("clients", Value::Number(tcfg.clients as f64)),
        ("requests", Value::Number(report.sent as f64)),
        ("pipeline_depth", Value::Number(tcfg.depth as f64)),
        ("workers", Value::Number(a.usize("workers")?.max(1) as f64)),
        ("max_batch", Value::Number(max_batch as f64)),
        ("inflight", Value::Number(a.usize("inflight")?.max(1) as f64)),
        ("ok", Value::Number(report.ok as f64)),
        ("errors", Value::Number(report.errors as f64)),
        ("verified", Value::Number(report.verified as f64)),
        ("wall_s", Value::Number(report.wall_s)),
        ("req_per_s", Value::Number(report.req_per_s())),
        (
            "latency_ns",
            Value::object(vec![
                ("mean", Value::Number(lat.mean_ns())),
                ("p50", Value::Number(lat.p50_ns())),
                ("p95", Value::Number(lat.p95_ns())),
                ("min", Value::Number(lat.min_ns())),
            ]),
        ),
        (
            "net",
            Value::object(vec![
                ("accepted", Value::Number(net_stats.accepted as f64)),
                ("rejected", Value::Number(net_stats.rejected as f64)),
                ("connections", Value::Number(net_stats.connections as f64)),
            ]),
        ),
        (
            "serve",
            Value::object(vec![
                ("completed", Value::Number(stats.completed as f64)),
                ("failed", Value::Number(stats.failed as f64)),
                ("batches", Value::Number(stats.batches as f64)),
                ("mean_fill", Value::Number(stats.mean_fill)),
                ("executes", Value::Number(stats.executes as f64)),
            ]),
        ),
        ("telemetry", stats.telemetry.to_json()),
    ]);
    std::fs::write(path, v.to_string_pretty() + "\n")?;
    println!("wrote {path}");
    Ok(())
}

// ---------------- sweep-resolution ----------------

fn sweep_specs() -> Vec<ArgSpec> {
    vec![
        ArgSpec::opt("config", "small", "network config"),
        ArgSpec::opt("bits", "1,2,3,4,5,6,8", "comma-separated bit depths"),
        ArgSpec::opt("epochs", "3", "epochs per point"),
        ArgSpec::opt("seed", "1", "master seed"),
        ArgSpec::opt("n-train", "8192", "training examples per point"),
        ArgSpec::opt("n-test", "2048", "test examples"),
        ArgSpec::opt("max-steps", "0", "cap steps per epoch (0 = full)"),
        ArgSpec::opt("artifacts", "artifacts", "AOT artifact directory"),
        BACKEND_SPEC,
        PHYSICS_SPEC,
        THREADS_SPEC,
    ]
}

fn cmd_sweep(a: &Args) -> Result<()> {
    let (engine, _physics) = open_engine(a)?;
    let bits = a.f64_list("bits")?;
    let pts = experiments::fig5c_sweep(
        engine,
        a.str("config"),
        &bits,
        a.usize("epochs")?,
        a.u64("seed")?,
        a.usize("n-train")?,
        a.usize("n-test")?,
        match a.usize("max-steps")? {
            0 => None,
            n => Some(n),
        },
    )?;
    println!("bits   sigma     test_acc   (Fig. 5(c))");
    for p in pts {
        println!("{:>4.1}  {:.5}   {:.4}", p.bits, p.sigma, p.test_acc);
    }
    Ok(())
}

// ---------------- sweep-physics ----------------

fn sweep_physics_specs() -> Vec<ArgSpec> {
    vec![
        ArgSpec::opt("config", "tiny", "network config: tiny | small | mnist"),
        ArgSpec::opt(
            "bits",
            "0,2,4,6,8",
            "comma-separated DAC/ADC bit depths (0 = ideal converters)",
        ),
        ArgSpec::opt(
            "sigmas",
            "0,0.05,0.1,0.2",
            "comma-separated read-noise sigmas (normalised domain)",
        ),
        ArgSpec::opt(
            "drift-rates",
            "",
            "comma-separated thermal drift rates (rad/\u{221a}tick): when set, \
             sweeps the device-lifetime axis instead — each rate trains with \
             the recalibration scheduler on AND off (bits/sigmas come from \
             --physics)",
        ),
        ArgSpec::opt("epochs", "2", "epochs per grid point"),
        ArgSpec::opt("seed", "1", "master seed"),
        ArgSpec::opt("n-train", "512", "training examples per point"),
        ArgSpec::opt("n-test", "128", "test examples"),
        ArgSpec::opt("max-steps", "0", "cap steps per epoch (0 = full)"),
        ArgSpec::opt("artifacts", "artifacts", "AOT artifact directory"),
        PHYSICS_SPEC,
        THREADS_SPEC,
    ]
}

fn cmd_sweep_physics(a: &Args) -> Result<()> {
    let base = PhysicsConfig::parse(a.str("physics"))?;
    let mut bits = Vec::new();
    for b in a.f64_list("bits")? {
        bits.push(
            PhysicsConfig::check_bits(b).map_err(|e| Error::Cli(format!("--bits: {e}")))?,
        );
    }
    let sigmas = a.f64_list("sigmas")?;
    for s in &sigmas {
        if !(*s >= 0.0 && s.is_finite()) {
            return Err(Error::Cli(format!(
                "--sigmas: expected finite non-negative noise stds, got '{s}'"
            )));
        }
    }
    let settings = experiments::SweepSettings {
        artifacts_dir: a.str("artifacts").into(),
        config: a.str("config").into(),
        base,
        epochs: a.usize("epochs")?,
        seed: a.u64("seed")?,
        n_train: a.usize("n-train")?,
        n_test: a.usize("n-test")?,
        max_steps_per_epoch: match a.usize("max-steps")? {
            0 => None,
            n => Some(n),
        },
        threads: a.usize("threads")?,
    };
    let drift_rates = a.f64_list("drift-rates")?;
    for r in &drift_rates {
        if !(*r >= 0.0 && r.is_finite()) {
            return Err(Error::Cli(format!(
                "--drift-rates: expected finite non-negative rates, got '{r}'"
            )));
        }
    }
    if !drift_rates.is_empty() {
        // lifetime axis: drift rate x recalibration scheduler {on, off}
        let pts = experiments::drift_sweep(&settings, &drift_rates)?;
        println!(
            "device-lifetime ablation on '{}' (base physics {}):",
            settings.config,
            base.describe()
        );
        print!("{}", experiments::render_drift_table(&pts));
        return Ok(());
    }
    let pts = experiments::physics_sweep(&settings, &bits, &sigmas)?;
    println!(
        "in-situ photonic DFA on '{}' (base physics {}):",
        settings.config,
        base.describe()
    );
    print!("{}", experiments::render_table(&pts));
    Ok(())
}

// ---------------- characterize ----------------

fn char_specs() -> Vec<ArgSpec> {
    vec![
        ArgSpec::opt("n", "3900", "number of multiply measurements (paper: 3900)"),
        ArgSpec::opt("seed", "7", "device + measurement seed"),
        ArgSpec::opt("profile-points", "0", "also print the Fig. 3(b) profile rows"),
    ]
}

fn cmd_characterize(a: &Args) -> Result<()> {
    let pts = a.usize("profile-points")?;
    if pts > 0 {
        println!("phase      T_pass     T_drop     weight    (Fig. 3(b))");
        for (phi, tp, td, w) in experiments::fig3b_curve(pts) {
            println!("{phi:>8.4}  {tp:>8.5}  {td:>8.5}  {w:>8.5}");
        }
    }
    let m = experiments::fig3c_multiply(a.usize("n")?, a.u64("seed")?)?;
    println!(
        "single-MRR multiply (Fig. 3(c)): n={} sigma={:.4} mean={:+.4} -> {:.2} bits \
         [paper: sigma=0.019, mean=-0.001, 6.72 bits]",
        m.n, m.sigma, m.mean, m.effective_bits
    );
    Ok(())
}

// ---------------- inner-product ----------------

fn ip_specs() -> Vec<ArgSpec> {
    vec![
        ArgSpec::opt("n", "5000", "measurements per circuit (paper: 5000)"),
        ArgSpec::opt("seed", "7", "device + measurement seed"),
        ArgSpec::opt("mode", "both", "offchip | onchip | both"),
    ]
}

fn cmd_inner_product(a: &Args) -> Result<()> {
    let n = a.usize("n")?;
    let seed = a.u64("seed")?;
    let modes: Vec<(&str, BpdMode, f64, f64)> = match a.str("mode") {
        "offchip" => vec![("off-chip BPD", BpdMode::OffChip, 0.098, 4.35)],
        "onchip" => vec![("on-chip BPD", BpdMode::OnChip, 0.202, 3.31)],
        "both" => vec![
            ("off-chip BPD", BpdMode::OffChip, 0.098, 4.35),
            ("on-chip BPD", BpdMode::OnChip, 0.202, 3.31),
        ],
        other => return Err(Error::Cli(format!("bad --mode '{other}'"))),
    };
    println!("circuit        n      sigma    mean      bits   [paper sigma/bits]");
    for (label, mode, paper_sigma, paper_bits) in modes {
        let m = experiments::fig5a_inner_products(mode, n, seed)?;
        println!(
            "{label:<13} {:>5}  {:.4}  {:+.4}   {:.2}   [{paper_sigma} / {paper_bits}]",
            m.n, m.sigma, m.mean, m.effective_bits
        );
    }
    Ok(())
}

// ---------------- energy ----------------

fn energy_specs() -> Vec<ArgSpec> {
    vec![
        ArgSpec::opt("fig6-points", "14", "points on the Fig. 6 sweep"),
        ArgSpec::opt("fig6-max-cells", "100000", "largest MAC-cell count"),
    ]
}

fn cmd_energy(a: &Args) -> Result<()> {
    print!("{}", experiments::energy_tables::render_headline());
    println!("\nFig. 6 — optimal E_op vs MAC cells (both locking schemes):");
    println!("cells     E_op heater (pJ)   E_op trimmed (pJ)");
    for (cells, h, t) in
        experiments::fig6_rows(25, a.usize("fig6-max-cells")?, a.usize("fig6-points")?)
    {
        println!("{cells:>7}   {:>12.3}      {:>12.3}", h * 1e12, t * 1e12);
    }
    Ok(())
}

// ---------------- report ----------------

fn report_specs() -> Vec<ArgSpec> {
    vec![ArgSpec::req(
        "path",
        "a `pdfa train` run directory (measured telemetry) or a checkpoint \
         file (analytic cost); the leading positional argument is accepted \
         too: `pdfa report runs/my_run`",
    )]
}

fn cmd_report(a: &Args) -> Result<()> {
    let path = std::path::Path::new(a.str("path"));
    if path.is_dir() {
        let run = telemetry_report::load_run(path)?;
        print!("{}", telemetry_report::render_run(&run));
    } else {
        let ckpt = Checkpoint::load(path)?;
        print!("{}", telemetry_report::render_checkpoint(path, &ckpt));
    }
    Ok(())
}

// ---------------- gen-data ----------------

fn gendata_specs() -> Vec<ArgSpec> {
    vec![
        ArgSpec::opt("out", "data", "output directory"),
        ArgSpec::opt("n-train", "60000", "training images"),
        ArgSpec::opt("n-test", "10000", "test images"),
        ArgSpec::opt("seed", "1", "generation seed"),
        THREADS_SPEC,
    ]
}

fn cmd_gen_data(a: &Args) -> Result<()> {
    let out = std::path::PathBuf::from(a.str("out"));
    std::fs::create_dir_all(&out)?;
    let threads = photonic_dfa::util::threads::resolve(a.usize("threads")?);
    let seed = a.u64("seed")?;
    let (tr_img, tr_lab) =
        synth::generate_split_parallel(a.usize("n-train")?, seed ^ 0x7a11, threads);
    tr_img.save(out.join("train-images-idx3-ubyte.gz"))?;
    tr_lab.save(out.join("train-labels-idx1-ubyte.gz"))?;
    let (te_img, te_lab) =
        synth::generate_split_parallel(a.usize("n-test")?, seed ^ 0x7e57, threads);
    te_img.save(out.join("t10k-images-idx3-ubyte.gz"))?;
    te_lab.save(out.join("t10k-labels-idx1-ubyte.gz"))?;
    println!(
        "wrote {} train + {} test images to {}",
        tr_img.dims[0],
        te_img.dims[0],
        out.display()
    );
    Ok(())
}

// ---------------- info ----------------

fn info_specs() -> Vec<ArgSpec> {
    vec![
        ArgSpec::opt("artifacts", "artifacts", "AOT artifact directory"),
        BACKEND_SPEC,
        PHYSICS_SPEC,
        THREADS_SPEC,
    ]
}

fn cmd_info(a: &Args) -> Result<()> {
    let (engine, physics) = open_engine(a)?;
    println!("backend: {}", engine.platform_name());
    if let Some(p) = physics {
        println!("physics: {}", p.describe());
    }
    println!("configs:");
    for (name, d) in engine.configs() {
        println!(
            "  {name}: {}-{}-{}-{} batch {}",
            d.d_in, d.d_h1, d.d_h2, d.d_out, d.batch
        );
    }
    println!("artifacts:");
    for art in engine.artifact_specs() {
        println!(
            "  {}: {} inputs, {} outputs ({})",
            art.name,
            art.inputs.len(),
            art.outputs.len(),
            art.path.file_name().unwrap_or_default().to_string_lossy()
        );
    }
    Ok(())
}

fn lint_specs() -> Vec<ArgSpec> {
    vec![
        ArgSpec::opt("root", "rust/src", "source tree to lint"),
        ArgSpec::opt("json", "", "also write the JSON report to this path"),
        ArgSpec::opt("graph", "", "write a DOT rendering of the hot-path closure"),
        ArgSpec::opt(
            "baseline",
            "",
            "LINT.json whose per-rule suppression counts cap this run",
        ),
    ]
}

fn cmd_lint(a: &Args) -> Result<()> {
    let root = std::path::Path::new(a.str("root"));
    // read the baseline before any writes: --json may overwrite it
    let baseline = match a.str("baseline") {
        "" => None,
        p => {
            let text = std::fs::read_to_string(p)
                .map_err(|e| Error::Cli(format!("lint: read baseline {p}: {e}")))?;
            Some(photonic_dfa::util::json::Value::parse(&text)?)
        }
    };
    let report = photonic_dfa::analysis::lint_repo(root)?;
    let json = a.str("json");
    if !json.is_empty() {
        let mut text = report.to_value().to_string_pretty();
        text.push('\n');
        std::fs::write(json, text)
            .map_err(|e| Error::Cli(format!("lint: write {json}: {e}")))?;
    }
    let dot = a.str("graph");
    if !dot.is_empty() {
        std::fs::write(dot, &report.hot_path_dot)
            .map_err(|e| Error::Cli(format!("lint: write {dot}: {e}")))?;
    }
    print!("{}", report.render());
    if let Some(base) = &baseline {
        photonic_dfa::analysis::check_baseline(&report, base)?;
    }
    if report.clean() {
        let spent: usize = report.debt.values().sum();
        println!(
            "pdfa lint: {} files clean under {} rules ({} nodes, {} edges, \
             {} written suppression(s))",
            report.files,
            photonic_dfa::analysis::RULES.len(),
            report.graph.nodes,
            report.graph.edges,
            spent,
        );
        Ok(())
    } else {
        Err(Error::Cli(format!(
            "pdfa lint: {} finding(s) across {} files",
            report.findings.len(),
            report.files
        )))
    }
}
