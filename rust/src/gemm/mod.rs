//! GeMM compiler: tile arbitrary matrix-vector products onto the finite
//! photonic weight bank.
//!
//! §3: "a customized general matrix multiplication (GeMM) compiler can be
//! used to subdivide the matrix B(k) such that the matrix-vector product is
//! determined over multiple operational cycles ... the dimensions of the
//! photonic weight bank do not restrict the size of the neural network."
//!
//! * [`tiler`]    — partition an (M, K) matrix into bank-sized tiles
//! * [`schedule`] — order tiles into operational cycles, roll up latency
//!   and per-cycle work (the numbers the energy model consumes)
//! * [`compiler`] — execute a plan against any [`compiler::BankExecutor`]
//!   (the device-level [`crate::photonics::WeightBank`], or a fast
//!   numerical executor for testing)
//!
//! The L1 Pallas kernel's grid (python/compile/kernels/weight_bank.py)
//! mirrors this exact tiling; `schedule::Schedule::cycles` must equal the
//! kernel's `bank_cycles` for the same dims — pinned by unit tests here and
//! hypothesis tests on the Python side.

pub mod compiler;
pub mod schedule;
pub mod tiler;

pub use compiler::{BankExecutor, GemmCompiler, NumericExecutor};
pub use schedule::{Schedule, ScheduleStats};
pub use tiler::{Tile, Tiling};
