//! Cycle scheduling and work accounting for a tiled mat-vec.
//!
//! The physical bank re-inscribes its MRRs between tiles that carry
//! different weights; for DFA the B(k) tiles cycle through a *fixed* set
//! each step (§5: stored in analog memory, switching cost negligible), so
//! the schedule distinguishes inscription cycles from compute cycles.

use super::tiler::Tiling;

/// Ordering policy for tile execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Order {
    /// All column-blocks of one row-block before moving on (output-local:
    /// each output element finishes in consecutive cycles — minimal
    /// accumulator state, matches the L1 kernel's grid order).
    RowMajor,
    /// All row-blocks of one column-block first (input-local: each input
    /// chunk is encoded once onto the modulators and fanned across
    /// row-blocks — minimal DAC re-encodes when M > bank rows).
    ColMajor,
}

/// Static work/latency statistics of a schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScheduleStats {
    pub cycles: usize,
    /// Useful MACs over all cycles.
    pub macs: usize,
    /// Input-vector (re-)encodes: how many times a column-block's channel
    /// amplitudes must be driven onto the modulators.
    pub input_encodes: usize,
    /// Bank re-inscriptions needed when the weight tile changes.
    pub inscriptions: usize,
    /// Wall-clock at operational rate f_s (s) for the compute cycles alone.
    pub compute_time_s: f64,
}

/// An ordered tile schedule.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub tiling: Tiling,
    pub order: Order,
    /// Tile indices in execution order.
    pub sequence: Vec<usize>,
}

impl Schedule {
    pub fn new(tiling: Tiling, order: Order) -> Schedule {
        let nr = tiling.n_row_blocks();
        let nc = tiling.n_col_blocks();
        let mut sequence = Vec::with_capacity(nr * nc);
        match order {
            Order::RowMajor => {
                for r in 0..nr {
                    for c in 0..nc {
                        sequence.push(r * nc + c);
                    }
                }
            }
            Order::ColMajor => {
                for c in 0..nc {
                    for r in 0..nr {
                        sequence.push(r * nc + c);
                    }
                }
            }
        }
        Schedule { tiling, order, sequence }
    }

    /// Work accounting at operational rate `f_s_hz`. `weights_resident`
    /// marks the DFA case where the tile set is pre-stored in analog memory
    /// and switching is free (§5) — otherwise each tile change costs an
    /// inscription.
    pub fn stats(&self, f_s_hz: f64, weights_resident: bool) -> ScheduleStats {
        let cycles = self.sequence.len();
        let macs: usize = self.tiling.tiles.iter().map(|t| t.macs()).sum();
        // input encodes: consecutive cycles sharing a column block reuse the
        // encoded channel amplitudes
        let mut input_encodes = 0;
        let mut last_col_block = usize::MAX;
        let nc = self.tiling.n_col_blocks();
        for &idx in &self.sequence {
            let col_block = idx % nc;
            if col_block != last_col_block {
                input_encodes += 1;
                last_col_block = col_block;
            }
        }
        let inscriptions = if weights_resident { 0 } else { cycles };
        ScheduleStats {
            cycles,
            macs,
            input_encodes,
            inscriptions,
            compute_time_s: cycles as f64 / f_s_hz,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::tiler::Tiling;

    fn tiling() -> Tiling {
        Tiling::new(120, 50, 50, 20).unwrap() // 3 x 3 blocks
    }

    #[test]
    fn row_major_sequence() {
        let s = Schedule::new(tiling(), Order::RowMajor);
        assert_eq!(s.sequence, vec![0, 1, 2, 3, 4, 5, 6, 7, 8]);
    }

    #[test]
    fn col_major_sequence() {
        let s = Schedule::new(tiling(), Order::ColMajor);
        assert_eq!(s.sequence, vec![0, 3, 6, 1, 4, 7, 2, 5, 8]);
    }

    #[test]
    fn stats_account_work() {
        let s = Schedule::new(tiling(), Order::RowMajor);
        let st = s.stats(10e9, true);
        assert_eq!(st.cycles, 9);
        assert_eq!(st.macs, 120 * 50);
        assert_eq!(st.inscriptions, 0);
        assert!((st.compute_time_s - 9.0 / 10e9).abs() < 1e-20);
        // row-major revisits each column block per row block
        assert_eq!(st.input_encodes, 9);
        let st2 = s.stats(10e9, false);
        assert_eq!(st2.inscriptions, 9);
    }

    #[test]
    fn col_major_minimises_encodes() {
        let s = Schedule::new(tiling(), Order::ColMajor);
        let st = s.stats(10e9, true);
        // one encode per column block: 3 instead of 9
        assert_eq!(st.input_encodes, 3);
    }

    #[test]
    fn paper_dfa_layer_schedule() {
        // 800 x 10 feedback matrix on the 50 x 20 bank: 16 cycles at 10 GHz
        // = 1.6 ns for the whole layer gradient (both layers in parallel).
        let t = Tiling::new(800, 10, 50, 20).unwrap();
        let s = Schedule::new(t, Order::ColMajor);
        let st = s.stats(10e9, true);
        assert_eq!(st.cycles, 16);
        assert_eq!(st.input_encodes, 1); // e fits one column block
        assert!((st.compute_time_s - 1.6e-9).abs() < 1e-15);
    }
}
