//! Matrix tiling onto the (bank_rows × bank_cols) physical array.

use crate::{Error, Result};

/// One tile of the partition: a rectangular sub-block of the matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tile {
    /// Row range [row0, row1) of the source matrix.
    pub row0: usize,
    pub row1: usize,
    /// Column range [col0, col1).
    pub col0: usize,
    pub col1: usize,
}

impl Tile {
    pub fn rows(&self) -> usize {
        self.row1 - self.row0
    }

    pub fn cols(&self) -> usize {
        self.col1 - self.col0
    }

    pub fn macs(&self) -> usize {
        self.rows() * self.cols()
    }
}

/// A complete partition of an (m × k) matrix into bank-sized tiles.
#[derive(Debug, Clone)]
pub struct Tiling {
    pub m: usize,
    pub k: usize,
    pub bank_rows: usize,
    pub bank_cols: usize,
    /// Row-major over (row-block, col-block).
    pub tiles: Vec<Tile>,
}

impl Tiling {
    /// Partition an (m × k) matrix for a bank of (bank_rows × bank_cols).
    pub fn new(m: usize, k: usize, bank_rows: usize, bank_cols: usize) -> Result<Tiling> {
        if m == 0 || k == 0 {
            return Err(Error::Gemm("cannot tile an empty matrix".into()));
        }
        if bank_rows == 0 || bank_cols == 0 {
            return Err(Error::Gemm("bank dims must be positive".into()));
        }
        // lint: allow(hot-path-alloc) — cold: tilings are computed once
        // per (m, k) shape and cached by the dispatcher
        let mut tiles = Vec::new();
        let mut row0 = 0;
        while row0 < m {
            let row1 = (row0 + bank_rows).min(m);
            let mut col0 = 0;
            while col0 < k {
                let col1 = (col0 + bank_cols).min(k);
                tiles.push(Tile { row0, row1, col0, col1 });
                col0 = col1;
            }
            row0 = row1;
        }
        Ok(Tiling { m, k, bank_rows, bank_cols, tiles })
    }

    pub fn n_row_blocks(&self) -> usize {
        self.m.div_ceil(self.bank_rows)
    }

    pub fn n_col_blocks(&self) -> usize {
        self.k.div_ceil(self.bank_cols)
    }

    /// Total operational cycles = number of tiles (one bank load per tile).
    pub fn n_cycles(&self) -> usize {
        self.tiles.len()
    }

    /// Fraction of bank MAC cells doing useful work, averaged over cycles —
    /// the utilisation figure ablation benches report (ragged edges waste
    /// cells, exactly as the paper's "redundant MRRs tuned to zero").
    pub fn utilisation(&self) -> f64 {
        let useful: usize = self.tiles.iter().map(Tile::macs).sum();
        useful as f64 / (self.tiles.len() * self.bank_rows * self.bank_cols) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;

    #[test]
    fn exact_fit() {
        let t = Tiling::new(100, 40, 50, 20).unwrap();
        assert_eq!(t.n_cycles(), 2 * 2);
        assert_eq!(t.utilisation(), 1.0);
        assert_eq!(t.n_row_blocks(), 2);
        assert_eq!(t.n_col_blocks(), 2);
    }

    #[test]
    fn ragged_edges() {
        let t = Tiling::new(60, 25, 50, 20).unwrap();
        assert_eq!(t.n_cycles(), 4); // 2 row blocks x 2 col blocks
        let last = t.tiles.last().unwrap();
        assert_eq!(last.rows(), 10);
        assert_eq!(last.cols(), 5);
        assert!(t.utilisation() < 1.0);
    }

    #[test]
    fn paper_mnist_case() {
        // B(k) is 800 x 10 on a 50 x 20 bank: 16 cycles, half the channels idle
        let t = Tiling::new(800, 10, 50, 20).unwrap();
        assert_eq!(t.n_cycles(), 16);
        assert!((t.utilisation() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn degenerate_inputs() {
        assert!(Tiling::new(0, 5, 50, 20).is_err());
        assert!(Tiling::new(5, 0, 50, 20).is_err());
        assert!(Tiling::new(5, 5, 0, 20).is_err());
    }

    #[test]
    fn ragged_tiling_roundtrips_matrix_through_padded_tiles() {
        // Scatter an (m x k) matrix into zero-padded bank-sized tiles (the
        // inscription path) and gather it back: every ragged shape must
        // reconstruct exactly, with padding confined to the ragged edges.
        use crate::tensor::Tensor;
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::seed(12);
        for (m, k, bm, bk) in [
            (60, 25, 50, 20),  // ragged both ways
            (50, 21, 50, 20),  // one extra column
            (51, 20, 50, 20),  // one extra row
            (7, 3, 50, 20),    // smaller than one tile
            (101, 41, 50, 20), // ragged multi-block
        ] {
            let src = Tensor::rand_uniform(&[m, k], -1.0, 1.0, &mut rng);
            let t = Tiling::new(m, k, bm, bk).unwrap();
            let mut back = Tensor::full(&[m, k], f32::NAN);
            let mut pad_cells = 0usize;
            for tile in &t.tiles {
                // inscribe: copy into a zero-padded (bm x bk) tile
                let mut buf = Tensor::zeros(&[bm, bk]);
                for r in 0..tile.rows() {
                    for c in 0..tile.cols() {
                        buf.set(r, c, src.at(tile.row0 + r, tile.col0 + c));
                    }
                }
                pad_cells += bm * bk - tile.macs();
                // gather: read the live region back out
                for r in 0..tile.rows() {
                    for c in 0..tile.cols() {
                        back.set(tile.row0 + r, tile.col0 + c, buf.at(r, c));
                    }
                }
            }
            assert_eq!(back, src, "({m},{k}) on ({bm},{bk})");
            // padding accounting must agree with the utilisation figure
            let total = t.n_cycles() * bm * bk;
            let util = (total - pad_cells) as f64 / total as f64;
            assert!((util - t.utilisation()).abs() < 1e-12);
        }
    }

    #[test]
    fn partition_properties() {
        // tiles exactly cover the matrix, no overlap, and agree with the
        // L1 kernel's grid arithmetic: cycles = ceil(m/bm) * ceil(k/bk)
        check("tiling-covers-matrix", 40, |rng| {
            let m = 1 + rng.below(300) as usize;
            let k = 1 + rng.below(80) as usize;
            let bm = 1 + rng.below(64) as usize;
            let bk = 1 + rng.below(32) as usize;
            let t = Tiling::new(m, k, bm, bk).unwrap();
            let want_cycles = m.div_ceil(bm) * k.div_ceil(bk);
            if t.n_cycles() != want_cycles {
                return Err(format!("cycles {} != {want_cycles}", t.n_cycles()));
            }
            let mut covered = vec![0u8; m * k];
            for tile in &t.tiles {
                if tile.rows() > bm || tile.cols() > bk {
                    return Err(format!("oversized tile {tile:?}"));
                }
                for r in tile.row0..tile.row1 {
                    for c in tile.col0..tile.col1 {
                        covered[r * k + c] += 1;
                    }
                }
            }
            if covered.iter().any(|&c| c != 1) {
                return Err("coverage not exactly 1".into());
            }
            Ok(())
        });
    }
}
