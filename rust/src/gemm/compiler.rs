//! Plan + execute: run a tiled mat-vec against a bank executor.
//!
//! [`GemmCompiler`] owns a [`Schedule`] and drives any [`BankExecutor`]:
//! the device-level photonic bank (validation/"device mode") or the fast
//! [`NumericExecutor`] (tests, planning). Inputs may be signed — negative
//! channel values are folded into the inscribed weights by flipping the
//! sign of the corresponding weight column (§3: "a negative value in the
//! error vector can be encoded by inverting the sign of the inscribed
//! weighting values of the corresponding column of MRRs").

use super::schedule::{Order, Schedule};
use super::tiler::{Tile, Tiling};
use crate::photonics::WeightBank;
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Anything that can execute one bank cycle: inscribe a (rows × cols) tile
/// and produce per-row outputs for non-negative channel amplitudes.
pub trait BankExecutor {
    fn bank_rows(&self) -> usize;
    fn bank_cols(&self) -> usize;

    /// Inscribe a full-bank weight tile (callers pad ragged tiles with 0).
    fn inscribe(&mut self, weights: &Tensor) -> Result<()>;

    /// One operational cycle; `x.len() == bank_cols`, entries in [0, 1].
    /// Returns `bank_rows` outputs in the normalised domain (inner product
    /// divided by `bank_cols`).
    fn cycle(&mut self, x: &[f32]) -> Result<Vec<f32>>;
}

impl BankExecutor for WeightBank {
    fn bank_rows(&self) -> usize {
        self.rows()
    }

    fn bank_cols(&self) -> usize {
        self.cols()
    }

    fn inscribe(&mut self, weights: &Tensor) -> Result<()> {
        WeightBank::inscribe(self, weights)
    }

    fn cycle(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        self.matvec(x)
    }
}

/// Ideal numerical bank (no noise): reference executor for tests and for
/// fast schedule exploration.
pub struct NumericExecutor {
    rows: usize,
    cols: usize,
    weights: Tensor,
}

impl NumericExecutor {
    pub fn new(rows: usize, cols: usize) -> NumericExecutor {
        NumericExecutor { rows, cols, weights: Tensor::zeros(&[rows, cols]) }
    }
}

impl BankExecutor for NumericExecutor {
    fn bank_rows(&self) -> usize {
        self.rows
    }

    fn bank_cols(&self) -> usize {
        self.cols
    }

    fn inscribe(&mut self, weights: &Tensor) -> Result<()> {
        if weights.shape() != [self.rows, self.cols] {
            return Err(Error::Shape("bad tile shape".into()));
        }
        self.weights = weights.clone();
        Ok(())
    }

    fn cycle(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        Ok((0..self.rows)
            .map(|r| {
                let row = self.weights.row(r);
                row.iter().zip(x).map(|(&w, &xi)| w * xi).sum::<f32>() / self.cols as f32
            })
            .collect())
    }
}

/// The compiler: plans a tiling for (m × k) and executes mat-vecs.
pub struct GemmCompiler {
    pub schedule: Schedule,
}

impl GemmCompiler {
    /// Plan for an (m × k) matrix on the executor's bank geometry.
    pub fn plan(m: usize, k: usize, exec: &dyn BankExecutor, order: Order) -> Result<GemmCompiler> {
        let tiling = Tiling::new(m, k, exec.bank_rows(), exec.bank_cols())?;
        Ok(GemmCompiler { schedule: Schedule::new(tiling, order) })
    }

    /// Compute y = B @ e on the bank.
    ///
    /// `bmat` is (m × k) with entries in [-1, 1]; `e` is length-k, signed.
    /// Per-sample normalisation (scale to [-1, 1], fold signs into weights)
    /// mirrors kernels/ref.py exactly; the returned y is in digital scale.
    pub fn matvec(&self, exec: &mut dyn BankExecutor, bmat: &Tensor, e: &[f32]) -> Result<Tensor> {
        let t = &self.schedule.tiling;
        if bmat.shape() != [t.m, t.k] {
            return Err(Error::Shape(format!(
                "matvec expects B of {:?}, got {:?}",
                [t.m, t.k],
                bmat.shape()
            )));
        }
        if e.len() != t.k {
            return Err(Error::Shape(format!(
                "matvec expects e of length {}, got {}",
                t.k,
                e.len()
            )));
        }
        let (br, bc) = (exec.bank_rows(), exec.bank_cols());
        if (br, bc) != (t.bank_rows, t.bank_cols) {
            return Err(Error::Gemm("executor geometry != planned geometry".into()));
        }

        // amplitude-encoding scale (per-call; one "sample")
        let s = e.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-12);

        let mut y = vec![0.0f32; t.m];
        let mut tile_w = Tensor::zeros(&[br, bc]);
        let mut x = vec![0.0f32; bc];
        for &idx in &self.schedule.sequence {
            let tile: &Tile = &t.tiles[idx];
            // fold input signs into the inscribed weights; pad ragged edges
            tile_w.data_mut().fill(0.0);
            for r in 0..tile.rows() {
                for c in 0..tile.cols() {
                    let sign = e[tile.col0 + c].signum();
                    let w = bmat.at(tile.row0 + r, tile.col0 + c);
                    tile_w.set(r, c, w * if sign == 0.0 { 1.0 } else { sign });
                }
            }
            x.fill(0.0);
            for c in 0..tile.cols() {
                x[c] = (e[tile.col0 + c].abs() / s).min(1.0);
            }
            exec.inscribe(&tile_w)?;
            let out = exec.cycle(&x)?;
            // bank output is normalised by bank_cols; undo and accumulate
            for r in 0..tile.rows() {
                y[tile.row0 + r] += out[r] * bc as f32 * s;
            }
        }
        Tensor::new(&[t.m], y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::schedule::Order;
    use crate::util::check::{assert_close, check};
    use crate::util::rng::Pcg64;

    #[test]
    fn numeric_executor_matches_dense_matmul() {
        check("gemm-matches-matmul", 25, |rng| {
            let m = 1 + rng.below(130) as usize;
            let k = 1 + rng.below(45) as usize;
            let bmat = Tensor::rand_uniform(&[m, k], -1.0, 1.0, rng);
            let e: Vec<f32> = (0..k).map(|_| rng.normal(0.0, 0.6) as f32).collect();
            let mut exec = NumericExecutor::new(50, 20);
            let plan = GemmCompiler::plan(m, k, &exec, Order::ColMajor).unwrap();
            let y = plan.matvec(&mut exec, &bmat, &e).unwrap();
            let want: Vec<f32> = (0..m)
                .map(|r| bmat.row(r).iter().zip(&e).map(|(&w, &x)| w * x).sum())
                .collect();
            assert_close(y.data(), &want, 1e-3 * k as f32)
        });
    }

    #[test]
    fn signed_inputs_match_plain_matvec_across_tile_orders() {
        // Property: for random signed e (mixed positive/negative/zero
        // entries) and random bank geometries, the sign-folding executor
        // path equals a plain f32 mat-vec in both tile orders.
        check("gemm-signed-fold-both-orders", 30, |rng| {
            let m = 1 + rng.below(120) as usize;
            let k = 1 + rng.below(40) as usize;
            let br = 1 + rng.below(60) as usize;
            let bc = 1 + rng.below(25) as usize;
            let bmat = Tensor::rand_uniform(&[m, k], -1.0, 1.0, rng);
            let e: Vec<f32> = (0..k)
                .map(|_| match rng.below(4) {
                    0 => 0.0, // exercise the signum()==0 fold branch
                    1 => -(rng.uniform() as f32),
                    _ => rng.normal(0.0, 0.8) as f32,
                })
                .collect();
            let want: Vec<f32> = (0..m)
                .map(|r| bmat.row(r).iter().zip(&e).map(|(&w, &x)| w * x).sum())
                .collect();
            for order in [Order::RowMajor, Order::ColMajor] {
                let mut exec = NumericExecutor::new(br, bc);
                let plan = GemmCompiler::plan(m, k, &exec, order).unwrap();
                let y = plan
                    .matvec(&mut exec, &bmat, &e)
                    .map_err(|err| format!("{order:?}: {err}"))?;
                assert_close(y.data(), &want, 2e-3 * k as f32)
                    .map_err(|err| format!("{order:?} ({m}x{k} on {br}x{bc}): {err}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn both_orders_agree() {
        let mut rng = Pcg64::seed(3);
        let bmat = Tensor::rand_uniform(&[73, 31], -1.0, 1.0, &mut rng);
        let e: Vec<f32> = (0..31).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let mut exec = NumericExecutor::new(50, 20);
        let row = GemmCompiler::plan(73, 31, &exec, Order::RowMajor)
            .unwrap()
            .matvec(&mut exec, &bmat, &e)
            .unwrap();
        let col = GemmCompiler::plan(73, 31, &exec, Order::ColMajor)
            .unwrap()
            .matvec(&mut exec, &bmat, &e)
            .unwrap();
        assert_close(row.data(), col.data(), 1e-5).unwrap();
    }

    #[test]
    fn negative_inputs_fold_into_weights() {
        let bmat = Tensor::new(&[2, 2], vec![0.5, -0.5, 0.25, 1.0]).unwrap();
        let e = [-0.8f32, 0.4];
        let mut exec = NumericExecutor::new(2, 2);
        let plan = GemmCompiler::plan(2, 2, &exec, Order::RowMajor).unwrap();
        let y = plan.matvec(&mut exec, &bmat, &e).unwrap();
        assert_close(y.data(), &[-0.6, 0.2], 1e-6).unwrap();
    }

    #[test]
    fn zero_vector_gives_zero() {
        let bmat = Tensor::full(&[5, 3], 0.7);
        let mut exec = NumericExecutor::new(5, 3);
        let plan = GemmCompiler::plan(5, 3, &exec, Order::RowMajor).unwrap();
        let y = plan.matvec(&mut exec, &bmat, &[0.0, 0.0, 0.0]).unwrap();
        assert_close(y.data(), &[0.0; 5], 1e-6).unwrap();
    }

    #[test]
    fn shape_errors() {
        let mut exec = NumericExecutor::new(4, 4);
        let plan = GemmCompiler::plan(8, 4, &exec, Order::RowMajor).unwrap();
        assert!(plan
            .matvec(&mut exec, &Tensor::zeros(&[4, 4]), &[0.0; 4])
            .is_err());
        assert!(plan
            .matvec(&mut exec, &Tensor::zeros(&[8, 4]), &[0.0; 3])
            .is_err());
        let mut wrong_geom = NumericExecutor::new(2, 2);
        assert!(plan
            .matvec(&mut wrong_geom, &Tensor::zeros(&[8, 4]), &[0.0; 4])
            .is_err());
    }

    #[test]
    fn cycles_match_python_kernel_grid() {
        // pinned against kernels/weight_bank.py::bank_cycles for the
        // paper's layer shapes (see python/tests/test_kernels.py)
        let exec = NumericExecutor::new(50, 20);
        for (m, k, want) in [(800, 10, 16), (128, 10, 3), (50, 20, 1), (51, 21, 4)] {
            let plan = GemmCompiler::plan(m, k, &exec, Order::RowMajor).unwrap();
            assert_eq!(plan.schedule.tiling.n_cycles(), want, "({m},{k})");
        }
    }
}
