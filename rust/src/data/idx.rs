//! IDX file format (the MNIST container): read/write, transparent gzip
//! (via the self-contained [`crate::util::gzip`] codec).
//!
//! Format: big-endian magic `[0, 0, dtype, ndims]`, then ndims u32 dims,
//! then row-major payload. Only dtype 0x08 (u8) is needed for MNIST.

use std::path::Path;

use crate::util::gzip;
use crate::{Error, Result};

const DTYPE_U8: u8 = 0x08;

/// An IDX tensor of u8 (images: [n, 28, 28]; labels: [n]).
#[derive(Debug, Clone, PartialEq)]
pub struct IdxArray {
    pub dims: Vec<usize>,
    pub data: Vec<u8>,
}

impl IdxArray {
    pub fn new(dims: Vec<usize>, data: Vec<u8>) -> Result<IdxArray> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            return Err(Error::Data(format!(
                "idx dims {dims:?} want {n} bytes, got {}",
                data.len()
            )));
        }
        Ok(IdxArray { dims, data })
    }

    /// Parse from raw IDX bytes.
    pub fn parse(bytes: &[u8]) -> Result<IdxArray> {
        if bytes.len() < 4 || bytes[0] != 0 || bytes[1] != 0 {
            return Err(Error::Data("bad idx magic".into()));
        }
        if bytes[2] != DTYPE_U8 {
            return Err(Error::Data(format!(
                "unsupported idx dtype 0x{:02x} (only u8)",
                bytes[2]
            )));
        }
        let ndims = bytes[3] as usize;
        let header = 4 + 4 * ndims;
        if bytes.len() < header {
            return Err(Error::Data("truncated idx header".into()));
        }
        let mut dims = Vec::with_capacity(ndims);
        for d in 0..ndims {
            let o = 4 + 4 * d;
            dims.push(u32::from_be_bytes([bytes[o], bytes[o + 1], bytes[o + 2], bytes[o + 3]])
                as usize);
        }
        let n: usize = dims.iter().product();
        if bytes.len() != header + n {
            return Err(Error::Data(format!(
                "idx payload size {} != expected {n}",
                bytes.len() - header
            )));
        }
        Ok(IdxArray { dims, data: bytes[header..].to_vec() })
    }

    /// Serialize to raw IDX bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(4 + 4 * self.dims.len() + self.data.len());
        out.extend_from_slice(&[0, 0, DTYPE_U8, self.dims.len() as u8]);
        for &d in &self.dims {
            out.extend_from_slice(&(d as u32).to_be_bytes());
        }
        out.extend_from_slice(&self.data);
        out
    }

    /// Load from a file; `.gz` suffix (or gzip magic) is decompressed.
    pub fn load(path: impl AsRef<Path>) -> Result<IdxArray> {
        let raw = std::fs::read(path.as_ref())?;
        let bytes = if raw.len() >= 2 && raw[0] == 0x1f && raw[1] == 0x8b {
            gzip::decompress(&raw)?
        } else {
            raw
        };
        Self::parse(&bytes)
    }

    /// Save, gzipped when the path ends in `.gz`.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let bytes = self.to_bytes();
        if path.extension().is_some_and(|e| e == "gz") {
            std::fs::write(path, gzip::compress(&bytes))?;
        } else {
            std::fs::write(path, bytes)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_bytes() {
        let a = IdxArray::new(vec![2, 3], (0u8..6).collect()).unwrap();
        let b = IdxArray::parse(&a.to_bytes()).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn roundtrip_files_plain_and_gz() {
        let dir = std::env::temp_dir().join("pdfa_idx_test");
        std::fs::create_dir_all(&dir).unwrap();
        let a = IdxArray::new(vec![4, 7], (0u8..28).collect()).unwrap();
        for name in ["t.idx", "t.idx.gz"] {
            let p = dir.join(name);
            a.save(&p).unwrap();
            assert_eq!(IdxArray::load(&p).unwrap(), a);
        }
    }

    #[test]
    fn mnist_shaped_header() {
        let imgs = IdxArray::new(vec![2, 28, 28], vec![7; 2 * 28 * 28]).unwrap();
        let bytes = imgs.to_bytes();
        assert_eq!(&bytes[..4], &[0, 0, 0x08, 3]);
        assert_eq!(&bytes[4..8], &2u32.to_be_bytes());
        assert_eq!(&bytes[8..12], &28u32.to_be_bytes());
    }

    #[test]
    fn rejects_malformed() {
        assert!(IdxArray::parse(&[]).is_err());
        assert!(IdxArray::parse(&[1, 0, 8, 1, 0, 0, 0, 0]).is_err()); // magic
        assert!(IdxArray::parse(&[0, 0, 0x0d, 1, 0, 0, 0, 0]).is_err()); // dtype
        assert!(IdxArray::parse(&[0, 0, 8, 1, 0, 0, 0, 5, 1, 2]).is_err()); // short
        assert!(IdxArray::new(vec![2, 2], vec![0; 3]).is_err());
    }

    #[test]
    fn rejects_malformed_headers() {
        // header promises 2 dims but only carries one
        assert!(IdxArray::parse(&[0, 0, 8, 2, 0, 0, 0, 1]).is_err());
        // header alone, zero payload for a 1-element dim
        assert!(IdxArray::parse(&[0, 0, 8, 1, 0, 0, 0, 1]).is_err());
        // payload longer than the dims promise
        assert!(IdxArray::parse(&[0, 0, 8, 1, 0, 0, 0, 1, 7, 7]).is_err());
        // magic half-right
        assert!(IdxArray::parse(&[0, 1, 8, 1, 0, 0, 0, 0]).is_err());
        // zero-dim scalars: ndims = 0 means a 1-element payload
        let scalar = IdxArray::parse(&[0, 0, 8, 0, 42]).unwrap();
        assert_eq!(scalar.dims, Vec::<usize>::new());
        assert_eq!(scalar.data, vec![42]);
    }

    #[test]
    fn corrupt_gzip_file_errors_cleanly() {
        let dir = std::env::temp_dir().join("pdfa_idx_badgz");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.idx.gz");
        // gzip magic followed by garbage must error, not panic
        std::fs::write(&p, [0x1f, 0x8b, 0x08, 0x00, 1, 2, 3, 4]).unwrap();
        assert!(IdxArray::load(&p).is_err());
    }
}
