//! Procedural digit dataset ("synth-MNIST").
//!
//! Deterministic stand-in for MNIST (no network access in this sandbox —
//! README.md data notes): each class has a handwritten-style stroke skeleton
//! (polylines + arcs on the unit square) rendered at 28×28 through a
//! random affine jitter (rotation, scale, shear, translation), random
//! stroke thickness, soft-edge rasterisation, and pixel noise. Same
//! geometry and value range as MNIST; an MLP plateaus in the high 90s,
//! leaving the paper's noise-degradation effects visible.

use super::idx::IdxArray;
use crate::util::rng::Pcg64;

pub const IMG_SIDE: usize = 28;
pub const N_CLASSES: usize = 10;

type Pt = (f32, f32);

/// Stroke skeleton of one digit: polylines in [0,1]² (y grows downward).
fn skeleton(class: usize) -> Vec<Vec<Pt>> {
    // helper: arc from a0 to a1 (radians) on ellipse centre (cx,cy) radii (rx,ry)
    let arc = |cx: f32, cy: f32, rx: f32, ry: f32, a0: f32, a1: f32, n: usize| -> Vec<Pt> {
        (0..=n)
            .map(|i| {
                let a = a0 + (a1 - a0) * i as f32 / n as f32;
                (cx + rx * a.cos(), cy + ry * a.sin())
            })
            .collect()
    };
    use std::f32::consts::PI;
    match class {
        0 => vec![arc(0.5, 0.5, 0.28, 0.38, 0.0, 2.0 * PI, 24)],
        1 => vec![
            vec![(0.38, 0.30), (0.55, 0.15), (0.55, 0.85)],
        ],
        2 => vec![
            arc(0.5, 0.32, 0.26, 0.20, -PI, 0.0, 12),
            vec![(0.76, 0.32), (0.30, 0.85)],
            vec![(0.30, 0.85), (0.78, 0.85)],
        ],
        3 => vec![
            arc(0.47, 0.32, 0.24, 0.18, -PI, 0.5 * PI, 14),
            arc(0.47, 0.68, 0.26, 0.20, -0.5 * PI, PI, 14),
        ],
        4 => vec![
            vec![(0.62, 0.15), (0.25, 0.62), (0.80, 0.62)],
            vec![(0.62, 0.15), (0.62, 0.88)],
        ],
        5 => vec![
            vec![(0.75, 0.15), (0.32, 0.15), (0.30, 0.48)],
            arc(0.50, 0.66, 0.26, 0.21, -0.6 * PI, 0.8 * PI, 16),
        ],
        6 => vec![
            arc(0.58, 0.30, 0.30, 0.45, 0.8 * PI, 1.45 * PI, 12),
            arc(0.50, 0.66, 0.24, 0.20, 0.0, 2.0 * PI, 18),
        ],
        7 => vec![
            vec![(0.25, 0.17), (0.78, 0.17), (0.42, 0.88)],
        ],
        8 => vec![
            arc(0.5, 0.32, 0.21, 0.17, 0.0, 2.0 * PI, 18),
            arc(0.5, 0.70, 0.25, 0.19, 0.0, 2.0 * PI, 18),
        ],
        9 => vec![
            arc(0.52, 0.34, 0.22, 0.19, 0.0, 2.0 * PI, 18),
            vec![(0.74, 0.34), (0.70, 0.88)],
        ],
        _ => panic!("class must be 0..10"),
    }
}

/// Distance from point p to segment (a, b).
fn seg_dist(p: Pt, a: Pt, b: Pt) -> f32 {
    let (px, py) = (p.0 - a.0, p.1 - a.1);
    let (vx, vy) = (b.0 - a.0, b.1 - a.1);
    let len2 = vx * vx + vy * vy;
    let t = if len2 > 0.0 { ((px * vx + py * vy) / len2).clamp(0.0, 1.0) } else { 0.0 };
    let (dx, dy) = (px - t * vx, py - t * vy);
    (dx * dx + dy * dy).sqrt()
}

/// Render one digit image (row-major, values 0..=255).
pub fn render_digit(class: usize, rng: &mut Pcg64) -> Vec<u8> {
    let strokes = skeleton(class);

    // random affine jitter around the image centre
    let rot = rng.normal(0.0, 0.10) as f32; // ~±17°at 3σ
    let scale = rng.uniform_in(0.85, 1.10) as f32;
    let shear = rng.normal(0.0, 0.08) as f32;
    let (dx, dy) = (
        rng.normal(0.0, 0.035) as f32,
        rng.normal(0.0, 0.035) as f32,
    );
    let (sin, cos) = (rot.sin(), rot.cos());
    let xform = |p: Pt| -> Pt {
        let (x, y) = (p.0 - 0.5, p.1 - 0.5);
        let (x, y) = (x + shear * y, y);
        let (x, y) = (scale * (cos * x - sin * y), scale * (sin * x + cos * y));
        (x + 0.5 + dx, y + 0.5 + dy)
    };

    // transformed segments
    let mut segs: Vec<(Pt, Pt)> = Vec::new();
    for stroke in &strokes {
        for w in stroke.windows(2) {
            segs.push((xform(w[0]), xform(w[1])));
        }
    }

    let thick = rng.uniform_in(0.035, 0.058) as f32; // stroke half-width
    let soft = 0.022f32; // antialias band
    let mut img = vec![0u8; IMG_SIDE * IMG_SIDE];
    for iy in 0..IMG_SIDE {
        for ix in 0..IMG_SIDE {
            let p = (
                (ix as f32 + 0.5) / IMG_SIDE as f32,
                (iy as f32 + 0.5) / IMG_SIDE as f32,
            );
            let mut d = f32::INFINITY;
            for &(a, b) in &segs {
                d = d.min(seg_dist(p, a, b));
                if d <= 0.0 {
                    break;
                }
            }
            let v = if d <= thick {
                1.0
            } else if d < thick + soft {
                1.0 - (d - thick) / soft
            } else {
                0.0
            };
            // ink-intensity jitter + sensor noise
            let noisy = (v * rng.uniform_in(0.82, 1.0) as f32
                + rng.normal(0.0, 0.02) as f32)
                .clamp(0.0, 1.0);
            img[iy * IMG_SIDE + ix] = (noisy * 255.0) as u8;
        }
    }
    img
}

/// Generate a full split: `n` images + labels, balanced classes, as IDX
/// arrays (identical container format to real MNIST).
pub fn generate_split(n: usize, seed: u64) -> (IdxArray, IdxArray) {
    let mut rng = Pcg64::new(seed, 0x5e17);
    let mut images = Vec::with_capacity(n * IMG_SIDE * IMG_SIDE);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let class = (rng.below(N_CLASSES as u64)) as usize;
        let _ = i;
        images.extend_from_slice(&render_digit(class, &mut rng));
        labels.push(class as u8);
    }
    (
        IdxArray::new(vec![n, IMG_SIDE, IMG_SIDE], images).unwrap(),
        IdxArray::new(vec![n], labels).unwrap(),
    )
}

/// Generate with multiple threads (rendering is embarrassingly parallel).
///
/// Output is independent of `threads`: work is split into fixed-size
/// chunks, each with its own RNG stream keyed by chunk index.
pub fn generate_split_parallel(n: usize, seed: u64, threads: usize) -> (IdxArray, IdxArray) {
    const CHUNK: usize = 1024;
    let n_chunks = n.div_ceil(CHUNK).max(1);
    let threads = threads.clamp(1, n_chunks);
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut parts: Vec<(usize, Vec<u8>, Vec<u8>)> = Vec::with_capacity(n_chunks);
    let parts_mx = std::sync::Mutex::new(&mut parts);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let c = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if c >= n_chunks {
                    break;
                }
                let count = CHUNK.min(n - c * CHUNK);
                let mut rng = Pcg64::new(seed, 0x517e_ad00 + c as u64);
                let mut images = Vec::with_capacity(count * IMG_SIDE * IMG_SIDE);
                let mut labels = Vec::with_capacity(count);
                for _ in 0..count {
                    let class = rng.below(N_CLASSES as u64) as usize;
                    images.extend_from_slice(&render_digit(class, &mut rng));
                    labels.push(class as u8);
                }
                parts_mx.lock().unwrap().push((c, images, labels));
            });
        }
    });
    parts.sort_by_key(|p| p.0);
    let mut images = Vec::with_capacity(n * IMG_SIDE * IMG_SIDE);
    let mut labels = Vec::with_capacity(n);
    for (_, im, la) in parts {
        images.extend(im);
        labels.extend(la);
    }
    (
        IdxArray::new(vec![n, IMG_SIDE, IMG_SIDE], images).unwrap(),
        IdxArray::new(vec![n], labels).unwrap(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_classes() {
        let mut rng = Pcg64::seed(0);
        for class in 0..N_CLASSES {
            let img = render_digit(class, &mut rng);
            assert_eq!(img.len(), 784);
            let ink: u32 = img.iter().map(|&v| v as u32).sum();
            // some ink, not a full page
            assert!(ink > 5_000, "class {class} too faint: {ink}");
            assert!(ink < 120_000, "class {class} too dense: {ink}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let (a_img, a_lab) = generate_split(20, 7);
        let (b_img, b_lab) = generate_split(20, 7);
        assert_eq!(a_img, b_img);
        assert_eq!(a_lab, b_lab);
        let (c_img, _) = generate_split(20, 8);
        assert_ne!(a_img, c_img);
    }

    #[test]
    fn split_shapes_and_label_range() {
        let (img, lab) = generate_split(50, 1);
        assert_eq!(img.dims, vec![50, 28, 28]);
        assert_eq!(lab.dims, vec![50]);
        assert!(lab.data.iter().all(|&l| l < 10));
        // roughly balanced classes
        let mut counts = [0u32; 10];
        for &l in &lab.data {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c > 0));
    }

    #[test]
    fn parallel_matches_shape_and_balance() {
        let (img, lab) = generate_split_parallel(64, 3, 4);
        assert_eq!(img.dims, vec![64, 28, 28]);
        assert_eq!(lab.data.len(), 64);
        assert!(lab.data.iter().all(|&l| l < 10));
    }

    #[test]
    fn parallel_is_thread_count_invariant() {
        let (a_img, a_lab) = generate_split_parallel(40, 9, 1);
        let (b_img, b_lab) = generate_split_parallel(40, 9, 4);
        assert_eq!(a_img, b_img);
        assert_eq!(a_lab, b_lab);
    }

    #[test]
    fn classes_are_visually_distinct() {
        // mean intra-class pixel distance must be far below inter-class —
        // the separability the MLP relies on
        let mut rng = Pcg64::seed(5);
        let n_per = 8;
        let mut means: Vec<Vec<f32>> = Vec::new();
        for class in 0..N_CLASSES {
            let mut mean = vec![0f32; 784];
            for _ in 0..n_per {
                for (m, &v) in mean.iter_mut().zip(&render_digit(class, &mut rng)) {
                    *m += v as f32 / 255.0 / n_per as f32;
                }
            }
            means.push(mean);
        }
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>()
        };
        for i in 0..N_CLASSES {
            for j in (i + 1)..N_CLASSES {
                assert!(
                    dist(&means[i], &means[j]) > 2.0,
                    "classes {i} and {j} overlap"
                );
            }
        }
    }
}
