//! Dataset substrate.
//!
//! The paper trains on MNIST; this sandbox has no network access, so the
//! drop-in substitute is a deterministic procedural digit generator
//! ([`synth`]) with the same geometry (28×28 grayscale, 10 classes,
//! 60k/10k split) and comparable MLP difficulty. Real MNIST IDX files
//! (optionally gzipped) load through [`idx`] with zero code changes —
//! point `--data-dir` at them. The substrate substitutes for real MNIST files.
//!
//! * [`idx`]     — IDX file format reader/writer (+ gzip)
//! * [`synth`]   — procedural stroke-based digit renderer
//! * [`dataset`] — in-memory dataset, normalisation, shuffled batching

pub mod dataset;
pub mod idx;
pub mod synth;

pub use dataset::{Batcher, Dataset};
