//! In-memory dataset with normalisation, one-hot labels and shuffled
//! mini-batching — the data path of the §4 training experiment
//! (mini-batch 64, pixels scaled to [0, 1]).

use std::path::Path;

use super::idx::IdxArray;
use super::synth;
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;
use crate::{Error, Result};

/// A split: flattened normalised images + integer labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// (n, d) pixels in [0, 1].
    pub x: Tensor,
    /// class indices
    pub y: Vec<u8>,
    pub n_classes: usize,
}

impl Dataset {
    pub fn from_idx(images: &IdxArray, labels: &IdxArray, n_classes: usize) -> Result<Dataset> {
        if images.dims.len() < 2 || images.dims[0] != labels.dims[0] {
            return Err(Error::Data(format!(
                "images {:?} / labels {:?} mismatch",
                images.dims, labels.dims
            )));
        }
        let n = images.dims[0];
        let d: usize = images.dims[1..].iter().product();
        let data: Vec<f32> = images.data.iter().map(|&b| b as f32 / 255.0).collect();
        if labels.data.iter().any(|&l| l as usize >= n_classes) {
            return Err(Error::Data("label out of range".into()));
        }
        Ok(Dataset {
            x: Tensor::new(&[n, d], data)?,
            y: labels.data.clone(),
            n_classes,
        })
    }

    /// Load a split from IDX files under `dir`, trying the canonical MNIST
    /// names with and without `.gz`.
    pub fn load_split(dir: impl AsRef<Path>, train: bool) -> Result<Dataset> {
        let dir = dir.as_ref();
        let (img_base, lab_base) = if train {
            ("train-images-idx3-ubyte", "train-labels-idx1-ubyte")
        } else {
            ("t10k-images-idx3-ubyte", "t10k-labels-idx1-ubyte")
        };
        let find = |base: &str| -> Result<IdxArray> {
            for name in [base.to_string(), format!("{base}.gz")] {
                let p = dir.join(&name);
                if p.exists() {
                    return IdxArray::load(&p);
                }
            }
            Err(Error::Data(format!(
                "no {base}[.gz] under {} (run `pdfa gen-data` or point --data-dir at MNIST)",
                dir.display()
            )))
        };
        Dataset::from_idx(&find(img_base)?, &find(lab_base)?, synth::N_CLASSES)
    }

    /// Generate the synthetic split in memory (no files) on all cores.
    pub fn synthetic(n: usize, seed: u64) -> Dataset {
        Self::synthetic_threaded(n, seed, 0)
    }

    /// [`Self::synthetic`] with an explicit worker count (0 = all cores,
    /// the `--threads` convention). Generation is sharded per chunk with
    /// chunk-keyed RNG streams, so the worker count never changes the
    /// data — only wall-clock time.
    pub fn synthetic_threaded(n: usize, seed: u64, threads: usize) -> Dataset {
        let threads = crate::util::threads::resolve(threads);
        let (img, lab) = synth::generate_split_parallel(n, seed, threads);
        Dataset::from_idx(&img, &lab, synth::N_CLASSES).expect("synth arrays are consistent")
    }

    /// Generate a separable random split at arbitrary feature dimension:
    /// each class lights up one contiguous block of features (plus noise).
    /// The 784-dim digit generator stays the default for MNIST-shaped
    /// configs; this covers every other `NetDims` (e.g. `tiny`, 16-dim).
    pub fn synthetic_features(n: usize, d: usize, n_classes: usize, seed: u64) -> Dataset {
        assert!(d > 0 && n_classes > 0);
        // more classes than features degenerates to block = 0 (pure-noise
        // rows); callers that need learnable data validate upstream
        // (`Trainer::load_data` rejects such configs with Error::Data)
        let block = d / n_classes;
        let mut rng = Pcg64::seed(seed);
        let mut data = Vec::with_capacity(n * d);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let c = rng.below(n_classes as u64) as usize;
            for j in 0..d {
                let base = if block > 0 && j / block == c { 0.8 } else { 0.12 };
                data.push((base + rng.normal(0.0, 0.1)).clamp(0.0, 1.0) as f32);
            }
            y.push(c as u8);
        }
        Dataset {
            x: Tensor::new(&[n, d], data).expect("consistent by construction"),
            y,
            n_classes,
        }
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.x.cols()
    }

    /// One-hot encode labels for rows `idx` -> (len, n_classes).
    pub fn one_hot(&self, idx: &[usize]) -> Tensor {
        let mut t = Tensor::zeros(&[idx.len(), self.n_classes]);
        for (r, &i) in idx.iter().enumerate() {
            t.set(r, self.y[i] as usize, 1.0);
        }
        t
    }

    /// Gather an (x, y_onehot) batch by indices.
    pub fn batch(&self, idx: &[usize]) -> (Tensor, Tensor) {
        (self.x.gather_rows(idx), self.one_hot(idx))
    }
}

/// Epoch iterator: shuffles indices and yields fixed-size batches
/// (dropping the ragged tail, as the fixed-shape AOT artifacts require).
pub struct Batcher {
    indices: Vec<usize>,
    batch: usize,
    pos: usize,
}

impl Batcher {
    pub fn new(n: usize, batch: usize, rng: &mut Pcg64) -> Batcher {
        assert!(batch > 0);
        let mut indices: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut indices);
        Batcher { indices, batch, pos: 0 }
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.indices.len() / self.batch
    }
}

impl Iterator for Batcher {
    type Item = Vec<usize>;

    fn next(&mut self) -> Option<Vec<usize>> {
        if self.pos + self.batch > self.indices.len() {
            return None;
        }
        let out = self.indices[self.pos..self.pos + self.batch].to_vec();
        self.pos += self.batch;
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_dataset() -> Dataset {
        Dataset::synthetic(64, 1)
    }

    #[test]
    fn synthetic_normalised_and_shaped() {
        let d = tiny_dataset();
        assert_eq!(d.len(), 64);
        assert_eq!(d.dim(), 784);
        assert!(d.x.data().iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn one_hot_rows_sum_to_one() {
        let d = tiny_dataset();
        let oh = d.one_hot(&[0, 5, 9]);
        assert_eq!(oh.shape(), &[3, 10]);
        for r in 0..3 {
            assert_eq!(oh.row(r).iter().sum::<f32>(), 1.0);
            assert_eq!(oh.at(r, d.y[[0, 5, 9][r]] as usize), 1.0);
        }
    }

    #[test]
    fn synthetic_features_shaped_and_separable() {
        let d = Dataset::synthetic_features(128, 16, 4, 9);
        assert_eq!(d.len(), 128);
        assert_eq!(d.dim(), 16);
        assert_eq!(d.n_classes, 4);
        assert!(d.x.data().iter().all(|&v| (0.0..=1.0).contains(&v)));
        // the class block is brighter than the rest of the row
        for i in 0..d.len() {
            let c = d.y[i] as usize;
            let row = d.x.row(i);
            let on: f32 = row[c * 4..(c + 1) * 4].iter().sum::<f32>() / 4.0;
            let off: f32 = (row.iter().sum::<f32>() - on * 4.0) / 12.0;
            assert!(on > off, "row {i}: on {on} off {off}");
        }
        // deterministic per seed
        let twin = Dataset::synthetic_features(128, 16, 4, 9);
        assert_eq!(d.x.data(), twin.x.data());
        assert_eq!(d.y, twin.y);
    }

    #[test]
    fn batcher_covers_without_repeats() {
        let mut rng = Pcg64::seed(0);
        let b = Batcher::new(100, 32, &mut rng);
        assert_eq!(b.batches_per_epoch(), 3);
        let mut seen = Vec::new();
        let mut count = 0;
        for batch in b {
            assert_eq!(batch.len(), 32);
            seen.extend(batch);
            count += 1;
        }
        assert_eq!(count, 3);
        seen.sort();
        seen.dedup();
        assert_eq!(seen.len(), 96); // no repeats; 4 dropped (ragged tail)
    }

    #[test]
    fn idx_roundtrip_through_files() {
        let dir = std::env::temp_dir().join("pdfa_ds_test");
        std::fs::create_dir_all(&dir).unwrap();
        let (img, lab) = synth::generate_split(32, 3);
        img.save(dir.join("train-images-idx3-ubyte.gz")).unwrap();
        lab.save(dir.join("train-labels-idx1-ubyte.gz")).unwrap();
        let d = Dataset::load_split(&dir, true).unwrap();
        assert_eq!(d.len(), 32);
        assert!(Dataset::load_split(&dir, false).is_err()); // no test split
    }

    #[test]
    fn from_idx_validates() {
        let img = IdxArray::new(vec![2, 2, 2], vec![0; 8]).unwrap();
        let lab_ok = IdxArray::new(vec![2], vec![0, 9]).unwrap();
        let lab_bad_len = IdxArray::new(vec![3], vec![0, 1, 2]).unwrap();
        let lab_bad_class = IdxArray::new(vec![2], vec![0, 10]).unwrap();
        assert!(Dataset::from_idx(&img, &lab_ok, 10).is_ok());
        assert!(Dataset::from_idx(&img, &lab_bad_len, 10).is_err());
        assert!(Dataset::from_idx(&img, &lab_bad_class, 10).is_err());
    }
}
