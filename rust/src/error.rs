//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (no `thiserror` in the offline
//! vendor set). The `Xla` variant exists only under the `pjrt` feature so
//! the default build carries no XLA dependency.

use std::fmt;

/// Unified error for every subsystem (runtime, photonics, data, CLI).
#[derive(Debug)]
pub enum Error {
    /// PJRT/XLA runtime failure (only with `--features pjrt`).
    #[cfg(feature = "pjrt")]
    Xla(xla::Error),
    Io(std::io::Error),
    /// Malformed serialised data (checkpoints, wire formats): bad magic,
    /// unsupported version, truncation, corrupted payload.
    Format(String),
    Json { offset: usize, msg: String },
    Manifest(String),
    Shape(String),
    Photonics(String),
    Calibration(String),
    Gemm(String),
    Data(String),
    Config(String),
    Cli(String),
    Msg(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            #[cfg(feature = "pjrt")]
            Error::Xla(e) => write!(f, "xla: {e}"),
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Format(m) => write!(f, "format: {m}"),
            Error::Json { offset, msg } => {
                write!(f, "json parse error at byte {offset}: {msg}")
            }
            Error::Manifest(m) => write!(f, "manifest: {m}"),
            Error::Shape(m) => write!(f, "shape mismatch: {m}"),
            Error::Photonics(m) => write!(f, "photonics: {m}"),
            Error::Calibration(m) => write!(f, "calibration: {m}"),
            Error::Gemm(m) => write!(f, "gemm: {m}"),
            Error::Data(m) => write!(f, "data: {m}"),
            Error::Config(m) => write!(f, "config: {m}"),
            Error::Cli(m) => write!(f, "cli: {m}"),
            Error::Msg(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            #[cfg(feature = "pjrt")]
            Error::Xla(e) => Some(e),
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Error {
        Error::Xla(e)
    }
}

impl Error {
    pub fn msg(s: impl Into<String>) -> Self {
        Error::Msg(s.into())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_keeps_subsystem_prefixes() {
        assert_eq!(Error::Shape("2x3 vs 3x2".into()).to_string(), "shape mismatch: 2x3 vs 3x2");
        assert_eq!(Error::Manifest("no artifact".into()).to_string(), "manifest: no artifact");
        assert_eq!(Error::msg("plain").to_string(), "plain");
        assert_eq!(
            Error::Format("bad magic".into()).to_string(),
            "format: bad magic"
        );
        let e = Error::Json { offset: 7, msg: "bad token".into() };
        assert_eq!(e.to_string(), "json parse error at byte 7: bad token");
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(e.to_string().starts_with("io:"));
        assert!(std::error::Error::source(&e).is_some());
        assert!(std::error::Error::source(&Error::msg("x")).is_none());
    }
}
