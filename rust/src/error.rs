//! Crate-wide error type.

/// Unified error for every subsystem (runtime, photonics, data, CLI).
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("xla: {0}")]
    Xla(#[from] xla::Error),

    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    #[error("json parse error at byte {offset}: {msg}")]
    Json { offset: usize, msg: String },

    #[error("manifest: {0}")]
    Manifest(String),

    #[error("shape mismatch: {0}")]
    Shape(String),

    #[error("photonics: {0}")]
    Photonics(String),

    #[error("calibration: {0}")]
    Calibration(String),

    #[error("gemm: {0}")]
    Gemm(String),

    #[error("data: {0}")]
    Data(String),

    #[error("config: {0}")]
    Config(String),

    #[error("cli: {0}")]
    Cli(String),

    #[error("{0}")]
    Msg(String),
}

impl Error {
    pub fn msg(s: impl Into<String>) -> Self {
        Error::Msg(s.into())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
