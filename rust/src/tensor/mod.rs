//! Dense row-major f32 tensors.
//!
//! A deliberately small linear-algebra substrate: everything the
//! coordinator, photonic simulator and reference trainer need — creation,
//! elementwise ops, matmul (cache-blocked, see [`ops`]), transposition,
//! row slicing — without pulling in an external BLAS. PJRT executes the
//! heavy training math; these tensors feed it and post-process results.

pub mod ops;

use crate::{Error, Result};
use crate::util::rng::Pcg64;

/// Dense row-major f32 tensor with up to 4 dimensions.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    // ---------- construction ----------

    pub fn new(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Shape(format!(
                "shape {shape:?} wants {n} elements, got {}",
                data.len()
            )));
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn from_fn(shape: &[usize], mut f: impl FnMut(usize) -> f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: (0..n).map(&mut f).collect() }
    }

    /// I.i.d. standard-normal entries scaled by `std`.
    pub fn randn(shape: &[usize], std: f32, rng: &mut Pcg64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_gaussian_f32(&mut t.data);
        if std != 1.0 {
            for x in &mut t.data {
                *x *= std;
            }
        }
        t
    }

    /// I.i.d. U[lo, hi) entries.
    pub fn rand_uniform(shape: &[usize], lo: f32, hi: f32, rng: &mut Pcg64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_uniform_f32(&mut t.data, lo, hi);
        t
    }

    // ---------- accessors ----------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn item(&self) -> f32 {
        assert_eq!(self.data.len(), 1, "item() on non-scalar tensor");
        self.data[0]
    }

    /// 2-D element access (row, col).
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[r * self.shape[1] + c]
    }

    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert_eq!(self.rank(), 2);
        let cols = self.shape[1];
        self.data[r * cols + c] = v;
    }

    pub fn rows(&self) -> usize {
        assert_eq!(self.rank(), 2);
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.rank(), 2);
        self.shape[1]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        let c = self.cols();
        &self.data[r * c..(r + 1) * c]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[r * c..(r + 1) * c]
    }

    // ---------- shape ops ----------

    pub fn reshape(&self, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            return Err(Error::Shape(format!(
                "cannot reshape {:?} ({} elems) to {shape:?}",
                self.shape,
                self.data.len()
            )));
        }
        Ok(Tensor { shape: shape.to_vec(), data: self.data.clone() })
    }

    /// 2-D transpose.
    pub fn t(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = Tensor::zeros(&[n, m]);
        for i in 0..m {
            for j in 0..n {
                out.data[j * m + i] = self.data[i * n + j];
            }
        }
        out
    }

    /// Copy rows [start, start+count) into a new (count, cols) tensor.
    pub fn slice_rows(&self, start: usize, count: usize) -> Tensor {
        let c = self.cols();
        let data = self.data[start * c..(start + count) * c].to_vec();
        Tensor { shape: vec![count, c], data }
    }

    /// Gather rows by index into a new tensor.
    pub fn gather_rows(&self, idx: &[usize]) -> Tensor {
        let c = self.cols();
        let mut data = Vec::with_capacity(idx.len() * c);
        for &i in idx {
            data.extend_from_slice(self.row(i));
        }
        Tensor { shape: vec![idx.len(), c], data }
    }

    // ---------- elementwise ----------

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for x in &mut self.data {
            *x = f(*x);
        }
    }

    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Result<Tensor> {
        if self.shape != other.shape {
            return Err(Error::Shape(format!(
                "zip shape mismatch: {:?} vs {:?}",
                self.shape, other.shape
            )));
        }
        Ok(Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        })
    }

    pub fn add(&self, other: &Tensor) -> Result<Tensor> {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Result<Tensor> {
        self.zip(other, |a, b| a - b)
    }

    pub fn hadamard(&self, other: &Tensor) -> Result<Tensor> {
        self.zip(other, |a, b| a * b)
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    /// axpy: self += alpha * other (in place, shape-checked).
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) -> Result<()> {
        if self.shape != other.shape {
            return Err(Error::Shape("axpy shape mismatch".into()));
        }
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
        Ok(())
    }

    // ---------- reductions ----------

    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Per-row argmax of a 2-D tensor.
    pub fn argmax_rows(&self) -> Vec<usize> {
        (0..self.rows())
            .map(|r| {
                let row = self.row(r);
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap()
            })
            .collect()
    }

    /// Matrix product — delegates to the blocked kernel in [`ops`].
    pub fn matmul(&self, other: &Tensor) -> Result<Tensor> {
        ops::matmul(self, other)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_shape_checks() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        assert_eq!(t.at(1, 2), 6.0);
        assert!(Tensor::new(&[2, 2], vec![1.0]).is_err());
        assert_eq!(Tensor::zeros(&[3, 3]).sum(), 0.0);
        assert_eq!(Tensor::scalar(5.0).item(), 5.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let tt = t.t();
        assert_eq!(tt.shape(), &[3, 2]);
        assert_eq!(tt.at(2, 1), 6.0);
        assert_eq!(tt.t(), t);
    }

    #[test]
    fn elementwise_and_axpy() {
        let a = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::full(&[2, 2], 2.0);
        assert_eq!(a.add(&b).unwrap().data(), &[3., 4., 5., 6.]);
        assert_eq!(a.hadamard(&b).unwrap().data(), &[2., 4., 6., 8.]);
        assert_eq!(a.sub(&b).unwrap().data(), &[-1., 0., 1., 2.]);
        let mut c = a.clone();
        c.axpy(0.5, &b).unwrap();
        assert_eq!(c.data(), &[2., 3., 4., 5.]);
        assert!(a.add(&Tensor::zeros(&[3])).is_err());
    }

    #[test]
    fn slicing_and_gather() {
        let t = Tensor::new(&[3, 2], vec![0., 1., 10., 11., 20., 21.]).unwrap();
        let s = t.slice_rows(1, 2);
        assert_eq!(s.shape(), &[2, 2]);
        assert_eq!(s.data(), &[10., 11., 20., 21.]);
        let g = t.gather_rows(&[2, 0]);
        assert_eq!(g.data(), &[20., 21., 0., 1.]);
    }

    #[test]
    fn argmax_rows() {
        let t = Tensor::new(&[2, 3], vec![0., 5., 1., 9., 2., 3.]).unwrap();
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn random_tensors_have_right_stats() {
        let mut rng = Pcg64::seed(0);
        let t = Tensor::randn(&[100, 100], 0.5, &mut rng);
        let mean = t.sum() / t.len() as f32;
        assert!(mean.abs() < 0.02);
        let var = t.data().iter().map(|x| (x - mean).powi(2)).sum::<f32>()
            / t.len() as f32;
        assert!((var.sqrt() - 0.5).abs() < 0.02);
        let u = Tensor::rand_uniform(&[1000], -1.0, 1.0, &mut rng);
        assert!(u.data().iter().all(|x| (-1.0..1.0).contains(x)));
    }

    #[test]
    fn reshape_checks_count() {
        let t = Tensor::zeros(&[4, 3]);
        assert_eq!(t.reshape(&[2, 6]).unwrap().shape(), &[2, 6]);
        assert!(t.reshape(&[5, 2]).is_err());
    }
}
