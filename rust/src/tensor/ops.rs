//! Matmul kernels and fused linear-algebra helpers.
//!
//! The coordinator's hot paths that do NOT go through PJRT are the
//! pure-Rust reference trainer (dfa::reference) and the device-level
//! photonic simulation (photonics::weight_bank). Both reduce to GEMM-like
//! loops, implemented here with the standard CPU tricks: ikj loop order
//! (stride-1 inner loop), cache blocking, a register-blocked column
//! micro-kernel shaped for autovectorization, and a multi-threaded row
//! split for large products. No unsafe, no external BLAS.
//!
//! Kernel speed is a tracked deliverable: `cargo bench --bench
//! gemm_kernels -- --json BENCH_GEMM.json` records the trajectory CI
//! commits on main pushes (see DESIGN.md, "Bench trajectory").

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard};

use crate::{Error, Result};

use super::Tensor;

/// Cache block edge (fits comfortably in L1 for three f32 blocks).
const BLOCK: usize = 64;
/// Register-block width of the micro-kernel: output columns processed
/// per strip, with the strip's partial sums held in registers across a
/// whole K-block (two 4-lane / one 8-lane SIMD register of f32).
const RBLOCK: usize = 8;
/// Below this many f32 multiply-adds a single thread is faster.
const PAR_THRESHOLD: usize = 1 << 20;

/// Process-wide cap on the kernels' worker threads (0 = all cores), set
/// once from the CLI's `--threads` flag. Row results are independent of
/// the chunking, so the cap changes wall-clock time only — outputs stay
/// bit-identical at any value.
static THREAD_CAP: AtomicUsize = AtomicUsize::new(0);

/// Cap the parallel kernels at `threads` workers (0 = all cores).
pub fn set_thread_cap(threads: usize) {
    THREAD_CAP.store(threads, Ordering::Relaxed);
}

/// The resolved worker count the parallel kernels will use.
pub fn thread_cap() -> usize {
    crate::util::threads::resolve(THREAD_CAP.load(Ordering::Relaxed))
}

/// The raw cap value as last set (0 = all cores), unresolved — for
/// callers that temporarily override the cap and must restore exactly
/// what they found.
pub fn thread_cap_raw() -> usize {
    THREAD_CAP.load(Ordering::Relaxed)
}

/// Serializes scoped overrides of the process-global cap: concurrent
/// [`ThreadCapGuard`]s (e.g. libtest threads racing on `set_thread_cap`,
/// or two sweeps in one process) queue on this instead of clobbering
/// each other's restore values.
static CAP_SCOPE: Mutex<()> = Mutex::new(());

/// A mutex-serialized, panic-safe scoped override of the GEMM thread
/// cap. `set` takes the scope lock, records [`thread_cap_raw`], and
/// applies the override; `Drop` restores the exact prior raw value —
/// on panic too, since drop glue runs during unwinding. This is the
/// only sanctioned way for tests and bounded library scopes (the
/// physics sweep's oversubscription guard) to touch the cap: raw
/// `set_thread_cap` calls from concurrently running tests race on the
/// process-global and leak their override into sibling tests.
#[must_use = "the override ends when the guard drops"]
pub struct ThreadCapGuard {
    prev: usize,
    _scope: MutexGuard<'static, ()>,
}

impl ThreadCapGuard {
    /// Override the cap to `threads` (0 = all cores) until the guard
    /// drops. Blocks while another guard is alive.
    pub fn set(threads: usize) -> ThreadCapGuard {
        // a poisoned scope lock only means some earlier guard's scope
        // panicked; its Drop already restored the cap, so proceeding is
        // sound
        let scope = CAP_SCOPE.lock().unwrap_or_else(|p| p.into_inner());
        let prev = thread_cap_raw();
        set_thread_cap(threads);
        ThreadCapGuard { prev, _scope: scope }
    }
}

impl Drop for ThreadCapGuard {
    fn drop(&mut self) {
        set_thread_cap(self.prev);
    }
}

/// C = A @ B for 2-D tensors.
pub fn matmul(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    if a.rank() != 2 || b.rank() != 2 {
        return Err(Error::Shape("matmul needs 2-D tensors".into()));
    }
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    if k != k2 {
        return Err(Error::Shape(format!(
            "matmul inner dims: ({m},{k}) @ ({k2},{n})"
        )));
    }
    let mut out = Tensor::zeros(&[m, n]);
    matmul_into(a.data(), b.data(), out.data_mut(), m, k, n);
    Ok(out)
}

/// Raw-slice GEMM: c (m x n) += a (m x k) @ b (k x n); c must be zeroed.
///
/// Zero-term semantics (the contract of *all four* kernels in this
/// module — `matmul`/`matmul_into`, the parallel row split, `matmul_bt`
/// and `matmul_at` on both their fused and transpose-then-GEMM routes):
/// a term whose **left-operand** factor is ±0.0 contributes exactly
/// nothing, even when the matching right-operand element is NaN or ±∞ —
/// i.e. `0 × x ≡ 0` for every `x`, not the IEEE `0 × NaN = NaN`. Zero
/// entries of A (ubiquitous post-ReLU activations) are skipped outright,
/// which is both the performance point and the poison-containment
/// property: a NaN/∞ in B only reaches output elements that a non-zero
/// A term actually connects it to, on every route and at every thread
/// count. Non-zero terms keep full IEEE semantics (NaN in A propagates).
pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if m * n * k >= PAR_THRESHOLD {
        matmul_parallel(a, b, c, m, k, n);
    } else {
        matmul_blocked(a, b, c, m, k, n);
    }
}

fn matmul_blocked(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    for i0 in (0..m).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(m);
        for k0 in (0..k).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(k);
            for i in i0..i1 {
                let a_row = &a[i * k..(i + 1) * k];
                let c_row = &mut c[i * n..(i + 1) * n];
                microkernel_row(a_row, b, c_row, k0, k1, n);
            }
        }
    }
}

/// Register-blocked micro-kernel of [`matmul_blocked`]: one output row
/// against one K-block, in [`RBLOCK`]-column strips whose partial sums
/// live in a fixed-size accumulator array — registers, after
/// autovectorization — across the whole K-block, so C is loaded and
/// stored once per block instead of once per `kk` step. Per output
/// element the accumulation order (ascending `kk` within the block) is
/// identical to the pre-register-blocked kernel, so results are
/// bit-for-bit unchanged; the zero-skip keeps the [`matmul_into`]
/// left-zero semantics.
// lint: hot-path
#[inline]
fn microkernel_row(a_row: &[f32], b: &[f32], c_row: &mut [f32], k0: usize, k1: usize, n: usize) {
    let mut j0 = 0;
    while j0 + RBLOCK <= n {
        let mut acc = [0.0f32; RBLOCK];
        acc.copy_from_slice(&c_row[j0..j0 + RBLOCK]);
        for kk in k0..k1 {
            let aik = a_row[kk];
            if aik == 0.0 {
                continue; // ReLU-sparse activations are common
            }
            let b_strip = &b[kk * n + j0..kk * n + j0 + RBLOCK];
            for (av, bv) in acc.iter_mut().zip(b_strip) {
                *av += aik * bv;
            }
        }
        c_row[j0..j0 + RBLOCK].copy_from_slice(&acc);
        j0 += RBLOCK;
    }
    if j0 < n {
        // ragged tail (n % RBLOCK columns): same ascending-kk order
        for kk in k0..k1 {
            let aik = a_row[kk];
            if aik == 0.0 {
                continue;
            }
            let b_tail = &b[kk * n + j0..kk * n + n];
            for (cv, bv) in c_row[j0..].iter_mut().zip(b_tail) {
                *cv += aik * bv;
            }
        }
    }
}

fn matmul_parallel(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    split_rows_parallel(a, c, m, k, n, |a_chunk, c_chunk| {
        matmul_blocked(a_chunk, b, c_chunk, c_chunk.len() / n, k, n)
    });
}

/// Rows per worker chunk when `m` rows split across up to `threads`
/// workers. Factored out so the chunk plan is unit-testable: for every
/// (m, threads) with `1 <= threads <= m`, `ceil(m / rows_per)` chunks
/// are produced, each with 1..=rows_per rows — never an empty chunk, and
/// never more chunks than `threads` (awkward pairs like m=5/threads=4
/// simply use fewer workers: rows_per=2 -> 3 chunks of 2+2+1).
fn rows_per_chunk(m: usize, threads: usize) -> usize {
    m.div_ceil(threads)
}

/// Shared thread scaffolding of the parallel kernels: split C (m x n,
/// with A's rows aligned to it) into disjoint per-thread row chunks and
/// run `kernel(a_chunk, c_chunk)` on each. Caller guarantees n > 0;
/// falls back to one inline kernel call on single-CPU machines. Zero-row
/// chunks are skipped defensively (no worker is ever spawned for one),
/// though [`rows_per_chunk`]'s plan cannot produce any.
fn split_rows_parallel(
    a: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    kernel: impl Fn(&[f32], &mut [f32]) + Copy + Send,
) {
    let threads = thread_cap().min(m).max(1);
    if threads <= 1 {
        return kernel(a, c);
    }
    let rows_per = rows_per_chunk(m, threads);
    debug_assert!(m.div_ceil(rows_per) <= threads);
    let chunks: Vec<&mut [f32]> = c.chunks_mut(rows_per * n).collect();
    std::thread::scope(|scope| {
        for (t, c_chunk) in chunks.into_iter().enumerate() {
            let rows = c_chunk.len() / n;
            if rows == 0 {
                continue; // never burn a spawn on an empty tail chunk
            }
            let i0 = t * rows_per;
            let a_chunk = &a[i0 * k..(i0 + rows) * k];
            scope.spawn(move || kernel(a_chunk, c_chunk));
        }
    });
}

/// out = a @ b^T without materializing the transpose (b given row-major
/// as (n x k)); the photonic reference path uses this for delta products.
/// Large products split the output rows across threads like
/// [`matmul_into`]; the per-row kernel is already stride-1 in both
/// operands, so no extra blocking is needed.
pub fn matmul_bt(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (n, k2) = (b.shape()[0], b.shape()[1]);
    if k != k2 {
        return Err(Error::Shape(format!(
            "matmul_bt inner dims: ({m},{k}) @ ({n},{k2})^T"
        )));
    }
    let mut out = Tensor::zeros(&[m, n]);
    let (ad, bd) = (a.data(), b.data());
    let od = out.data_mut();
    if n == 0 {
        return Ok(out); // nothing to compute; avoid chunks_mut(0) below
    }
    if m * n * k < PAR_THRESHOLD {
        matmul_bt_rows(ad, bd, od, k, n);
    } else {
        split_rows_parallel(ad, od, m, k, n, |a_chunk, o_chunk| {
            matmul_bt_rows(a_chunk, bd, o_chunk, k, n)
        });
    }
    Ok(out)
}

/// Row-dot-row kernel of [`matmul_bt`]: c (rows x n) = a (rows x k) @ b^T.
/// Skips a-zero terms, pinning the [`matmul_into`] left-zero semantics
/// on this route too (pre-fix it accumulated them, so `0 × NaN`
/// poisoned here while vanishing on the blocked kernels).
// lint: hot-path
fn matmul_bt_rows(a: &[f32], b: &[f32], c: &mut [f32], k: usize, n: usize) {
    for (a_row, c_row) in a.chunks(k.max(1)).zip(c.chunks_mut(n)) {
        for (j, cv) in c_row.iter_mut().enumerate() {
            let b_row = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (x, y) in a_row.iter().zip(b_row) {
                if *x != 0.0 {
                    acc += x * y;
                }
            }
            *cv = acc;
        }
    }
}

/// out = a^T @ b: a (k x m), b (k x n). Small products run a fused
/// single-pass kernel; large ones materialize aᵀ once and route through
/// [`matmul_into`] so they get its cache blocking and thread split (the
/// O(km) transpose buffer is noise next to the O(kmn) product).
pub fn matmul_at(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let (k, m) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    if k != k2 {
        return Err(Error::Shape(format!(
            "matmul_at inner dims: ({k},{m})^T @ ({k2},{n})"
        )));
    }
    let mut out = Tensor::zeros(&[m, n]);
    let (ad, bd) = (a.data(), b.data());
    let od = out.data_mut();
    if m * n * k >= PAR_THRESHOLD {
        // write-once transpose: push aᵀ in its final row-major order
        // instead of zero-filling k*m floats and then overwriting every
        // one of them through a strided store
        let mut at = Vec::with_capacity(k * m);
        for i in 0..m {
            for kk in 0..k {
                at.push(ad[kk * m + i]);
            }
        }
        matmul_into(&at, bd, od, m, k, n);
        return Ok(out);
    }
    for kk in 0..k {
        let a_row = &ad[kk * m..(kk + 1) * m];
        let b_row = &bd[kk * n..(kk + 1) * n];
        for i in 0..m {
            let aik = a_row[i];
            if aik == 0.0 {
                continue;
            }
            let o_row = &mut od[i * n..(i + 1) * n];
            for (ov, bv) in o_row.iter_mut().zip(b_row) {
                *ov += aik * bv;
            }
        }
    }
    Ok(out)
}

/// Column-wise mean of a 2-D tensor -> (cols,) vector. The mean over
/// zero rows is defined as zero (not NaN), so empty mini-batches and
/// zero-sized tensors stay poison-free.
pub fn col_mean(t: &Tensor) -> Tensor {
    let (m, n) = (t.rows(), t.cols());
    let mut out = Tensor::zeros(&[n]);
    if m == 0 {
        return out;
    }
    for i in 0..m {
        for (o, v) in out.data_mut().iter_mut().zip(t.row(i)) {
            *o += v;
        }
    }
    let inv = 1.0 / m as f32;
    for o in out.data_mut() {
        *o *= inv;
    }
    out
}

/// Row-wise mean of a 2-D tensor -> (rows,) vector; the mean over zero
/// columns is zero, mirroring [`col_mean`].
pub fn row_mean(t: &Tensor) -> Tensor {
    let (m, n) = (t.rows(), t.cols());
    if n == 0 {
        return Tensor::zeros(&[m]);
    }
    let inv = 1.0 / n as f32;
    Tensor::from_fn(&[m], |i| t.row(i).iter().sum::<f32>() * inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{assert_close, check};
    use crate::util::rng::Pcg64;

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.shape()[0], a.shape()[1]);
        let n = b.shape()[1];
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for kk in 0..k {
                    acc += a.at(i, kk) * b.at(kk, j);
                }
                out.set(i, j, acc);
            }
        }
        out
    }

    #[test]
    fn small_known_product() {
        let a = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::new(&[2, 2], vec![1., 1., 1., 1.]).unwrap();
        assert_eq!(matmul(&a, &b).unwrap().data(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn shape_errors() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4, 2]);
        assert!(matmul(&a, &b).is_err());
        assert!(matmul_bt(&a, &Tensor::zeros(&[2, 4])).is_err());
        assert!(matmul_at(&a, &Tensor::zeros(&[3, 2])).is_err());
    }

    #[test]
    fn blocked_matches_naive_property() {
        check("matmul-vs-naive", 20, |rng| {
            let m = 1 + rng.below(70) as usize;
            let k = 1 + rng.below(70) as usize;
            let n = 1 + rng.below(70) as usize;
            let a = Tensor::randn(&[m, k], 1.0, rng);
            let b = Tensor::randn(&[k, n], 1.0, rng);
            let got = matmul(&a, &b).unwrap();
            let want = naive(&a, &b);
            assert_close(got.data(), want.data(), 1e-3 * k as f32)
        });
    }

    #[test]
    fn parallel_path_matches_blocked() {
        let mut rng = Pcg64::seed(42);
        // big enough to cross PAR_THRESHOLD
        let a = Tensor::randn(&[256, 128], 1.0, &mut rng);
        let b = Tensor::randn(&[128, 200], 1.0, &mut rng);
        let got = matmul(&a, &b).unwrap();
        let mut want = Tensor::zeros(&[256, 200]);
        matmul_blocked(a.data(), b.data(), want.data_mut(), 256, 128, 200);
        assert_close(got.data(), want.data(), 1e-3).unwrap();
    }

    #[test]
    fn thread_cap_changes_chunking_not_results() {
        let mut rng = Pcg64::seed(43);
        let a = Tensor::randn(&[256, 128], 1.0, &mut rng);
        let b = Tensor::randn(&[128, 200], 1.0, &mut rng);
        let single;
        let multi;
        {
            let _cap = ThreadCapGuard::set(4);
            multi = matmul(&a, &b).unwrap();
        }
        {
            let _cap = ThreadCapGuard::set(1);
            single = matmul(&a, &b).unwrap();
        }
        // the guard restored the ambient cap on both drops
        assert!(thread_cap() >= 1);
        // row chunking never changes the per-row accumulation order
        assert_eq!(single.data(), multi.data());
    }

    #[test]
    fn guard_restores_cap_even_on_panic() {
        // sentinel no other test uses: the restore happens in the
        // guard's Drop *before* the scope lock releases, so if it works
        // no thread can ever observe this value after the catch
        const SENTINEL: usize = 6271;
        let caught = std::panic::catch_unwind(|| {
            let _cap = ThreadCapGuard::set(SENTINEL);
            assert_eq!(thread_cap_raw(), SENTINEL);
            panic!("boom");
        });
        assert!(caught.is_err());
        assert_ne!(thread_cap_raw(), SENTINEL);
    }

    #[test]
    fn microkernel_boundary_remainders_match_naive() {
        // every edge remainder 1..=8 against both the register-block
        // (RBLOCK=8) and the cache-block (BLOCK=64) boundary: the strip
        // loop, its ragged tail, and the K-block edges all get exercised
        let mut rng = Pcg64::seed(7);
        for r in 1..=RBLOCK {
            for (m, k, n) in [
                (r, BLOCK + r, RBLOCK + r),       // ragged strip tail
                (RBLOCK + r, r, BLOCK + r),       // K shorter than a block
                (BLOCK + r, RBLOCK + r, r),       // n below one full strip
                (BLOCK - r, BLOCK, 2 * RBLOCK + r), // row count under BLOCK
            ] {
                let a = Tensor::randn(&[m, k], 1.0, &mut rng);
                let b = Tensor::randn(&[k, n], 1.0, &mut rng);
                let got = matmul(&a, &b).unwrap();
                let want = naive(&a, &b);
                assert_close(got.data(), want.data(), 1e-3 * k as f32)
                    .unwrap_or_else(|e| panic!("shape ({m},{k},{n}): {e:?}"));
            }
        }
    }

    /// a with zeroed columns `poison`, b with NaN/+Inf rows at `poison`:
    /// under the left-zero contract every kernel must produce the finite
    /// product of the clean terms.
    fn poison_pair(m: usize, k: usize, n: usize, poison: &[usize]) -> (Tensor, Tensor, Tensor) {
        let mut rng = Pcg64::seed(91);
        let mut a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let mut b = Tensor::randn(&[k, n], 1.0, &mut rng);
        for i in 0..m {
            for &kk in poison {
                a.set(i, kk, 0.0);
            }
        }
        let mut b_clean = b.clone();
        for (idx, &kk) in poison.iter().enumerate() {
            for j in 0..n {
                b.set(kk, j, if idx % 2 == 0 { f32::NAN } else { f32::INFINITY });
                b_clean.set(kk, j, 0.0);
            }
        }
        let want = naive(&a, &b_clean);
        (a, b, want)
    }

    #[test]
    fn zero_times_poison_vanishes_on_every_kernel_below_threshold() {
        let (m, k, n) = (9, 17, 13);
        assert!(m * k * n < super::PAR_THRESHOLD);
        let (a, b, want) = poison_pair(m, k, n, &[0, 5, 16]);
        for (name, got) in [
            ("matmul", matmul(&a, &b).unwrap()),
            ("matmul_bt", matmul_bt(&a, &b.t()).unwrap()),
            ("matmul_at", matmul_at(&a.t(), &b).unwrap()),
        ] {
            assert!(
                got.data().iter().all(|v| v.is_finite()),
                "{name}: poison leaked through a zero left operand"
            );
            assert_close(got.data(), want.data(), 1e-3 * k as f32)
                .unwrap_or_else(|e| panic!("{name}: {e:?}"));
        }
    }

    #[test]
    fn zero_times_poison_vanishes_on_parallel_routes() {
        // 160*80*120 = 1.54M multiply-adds > PAR_THRESHOLD: covers the
        // row-split matmul, the bt row split, and the at
        // transpose-then-matmul_into route, at 1 and 4 workers
        let (m, k, n) = (160, 80, 120);
        assert!(m * k * n >= super::PAR_THRESHOLD);
        let (a, b, want) = poison_pair(m, k, n, &[3, 40, 79]);
        for cap in [1usize, 4] {
            let _cap = ThreadCapGuard::set(cap);
            for (name, got) in [
                ("matmul", matmul(&a, &b).unwrap()),
                ("matmul_bt", matmul_bt(&a, &b.t()).unwrap()),
                ("matmul_at", matmul_at(&a.t(), &b).unwrap()),
            ] {
                assert!(
                    got.data().iter().all(|v| v.is_finite()),
                    "{name} at cap {cap}: poison leaked through a zero left operand"
                );
                assert_close(got.data(), want.data(), 1e-3 * k as f32)
                    .unwrap_or_else(|e| panic!("{name} at cap {cap}: {e:?}"));
            }
        }
    }

    #[test]
    fn rows_per_chunk_plan_is_tight() {
        for m in 1..=64usize {
            for threads in 1..=8usize.min(m) {
                let rows_per = rows_per_chunk(m, threads);
                assert!(rows_per >= 1, "m={m} threads={threads}");
                let chunks = m.div_ceil(rows_per);
                assert!(
                    chunks <= threads,
                    "m={m} threads={threads}: {chunks} chunks oversubscribes"
                );
                // the tail chunk is never empty: (chunks-1) full chunks
                // leave at least one row for the last
                assert!(
                    (chunks - 1) * rows_per < m,
                    "m={m} threads={threads}: empty tail chunk"
                );
            }
        }
    }

    #[test]
    fn awkward_row_splits_match_single_thread() {
        // m values that divide badly across small worker counts, at a
        // size that crosses PAR_THRESHOLD (m*512*512 >= 1<<20 for m>=4)
        let mut rng = Pcg64::seed(29);
        for m in [5usize, 7, 13] {
            let a = Tensor::randn(&[m, 512], 1.0, &mut rng);
            let b = Tensor::randn(&[512, 512], 1.0, &mut rng);
            assert!(m * 512 * 512 >= super::PAR_THRESHOLD);
            let single = {
                let _cap = ThreadCapGuard::set(1);
                matmul(&a, &b).unwrap()
            };
            for threads in [2usize, 3, 4, 5] {
                let _cap = ThreadCapGuard::set(threads);
                let multi = matmul(&a, &b).unwrap();
                assert_eq!(
                    single.data(),
                    multi.data(),
                    "m={m} threads={threads} drifted"
                );
            }
        }
    }

    #[test]
    fn transposed_variants_match() {
        check("matmul-transposed-variants", 20, |rng| {
            let m = 1 + rng.below(40) as usize;
            let k = 1 + rng.below(40) as usize;
            let n = 1 + rng.below(40) as usize;
            let a = Tensor::randn(&[m, k], 1.0, rng);
            let b = Tensor::randn(&[k, n], 1.0, rng);
            let want = matmul(&a, &b).unwrap();
            let got_bt = matmul_bt(&a, &b.t()).unwrap();
            assert_close(got_bt.data(), want.data(), 1e-3 * k as f32)?;
            let got_at = matmul_at(&a.t(), &b).unwrap();
            assert_close(got_at.data(), want.data(), 1e-3 * k as f32)
        });
    }

    #[test]
    fn transposed_variants_cross_parallel_threshold() {
        // 160 * 120 * 80 = 1.54M multiply-adds > PAR_THRESHOLD, so the
        // bt row-split and the at transpose-then-matmul_into routes run.
        let (m, k, n) = (160, 80, 120);
        assert!(m * k * n >= super::PAR_THRESHOLD);
        let mut rng = Pcg64::seed(17);
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let want = naive(&a, &b);
        let got_bt = matmul_bt(&a, &b.t()).unwrap();
        assert_close(got_bt.data(), want.data(), 1e-3 * k as f32).unwrap();
        let got_at = matmul_at(&a.t(), &b).unwrap();
        assert_close(got_at.data(), want.data(), 1e-3 * k as f32).unwrap();
    }

    #[test]
    fn zero_dim_products_are_empty_not_poisoned() {
        // every degenerate (0-extent) shape must produce finite zeros,
        // not NaNs or panics, on all four kernels
        for (m, k, n) in [(0, 3, 4), (3, 0, 4), (3, 4, 0), (0, 0, 0)] {
            let a = Tensor::zeros(&[m, k]);
            let b = Tensor::zeros(&[k, n]);
            let c = matmul(&a, &b).unwrap();
            assert_eq!(c.shape(), &[m, n]);
            let c = matmul_bt(&a, &Tensor::zeros(&[n, k])).unwrap();
            assert_eq!(c.shape(), &[m, n]);
            assert!(c.data().iter().all(|v| v.is_finite()));
            let c = matmul_at(&Tensor::zeros(&[k, m]), &b).unwrap();
            assert_eq!(c.shape(), &[m, n]);
            assert!(c.data().iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn means() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 5., 6., 7.]).unwrap();
        assert_eq!(col_mean(&t).data(), &[3., 4., 5.]);
        assert_eq!(row_mean(&t).data(), &[2., 6.]);
    }

    #[test]
    fn means_of_zero_extent_tensors_are_zero() {
        // previously 0/0 -> NaN; the mean over an empty axis is pinned to 0
        let rows0 = Tensor::zeros(&[0, 5]);
        let cm = col_mean(&rows0);
        assert_eq!(cm.shape(), &[5]);
        assert!(cm.data().iter().all(|&v| v == 0.0));
        let cols0 = Tensor::zeros(&[4, 0]);
        let rm = row_mean(&cols0);
        assert_eq!(rm.shape(), &[4]);
        assert!(rm.data().iter().all(|&v| v == 0.0));
        assert!(col_mean(&cols0).data().is_empty());
        assert!(row_mean(&rows0).data().is_empty());
    }
}
