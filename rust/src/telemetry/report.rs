//! `pdfa report`: render a recorded run's telemetry against the §5
//! targets.
//!
//! Input is either a run directory written by `pdfa train`
//! (`config.json` + `result.json` + `history.json`) or a checkpoint
//! file. A run directory carries measured counters — the report shows
//! MACs, wall-clock MAC/s, optical cycles, bank utilisation and the
//! modeled energy/pJ-per-MAC next to the paper's numbers (E_op = 1.0 pJ
//! nominal with heater locking, 0.28 pJ with trimming; Eq. 2's 20 TOPS
//! peak). A checkpoint carries no counters, so its report is the
//! analytic training cost derived from the network dimensions and step
//! count.
//!
//! Counter rows are byte-identical across `--threads` values (see the
//! module docs of [`crate::telemetry`]); only the MAC/s row depends on
//! wall-clock time.

use std::path::{Path, PathBuf};

use super::{
    macs_feedback, macs_forward, macs_weight_grads, Telemetry, PAPER_PJ_PER_OP_NOMINAL,
    PAPER_PJ_PER_OP_TRIMMED, PAPER_TOPS,
};
use crate::dfa::checkpoint::Checkpoint;
use crate::energy::{EnergyModel, MrrTuning};
use crate::util::benchx::fmt_si;
use crate::util::json::Value;
use crate::{Error, Result};

/// Everything `pdfa report` needs from a recorded run directory.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub dir: PathBuf,
    /// Backend identity recorded by `RunRecorder::write_engine_config`.
    pub backend: String,
    /// Network config name ("tiny", "small", "mnist").
    pub config: String,
    /// Photonic physics string (None for digital backends).
    pub physics: Option<String>,
    /// Epochs recorded in history.json.
    pub epochs: usize,
    pub total_steps: u64,
    pub test_acc: Option<f64>,
    pub wall_s: f64,
    /// The run's accumulated counters (result.json `telemetry` block).
    pub telemetry: Telemetry,
}

/// Load `config.json`, `result.json` and `history.json` from a run
/// directory written by `pdfa train`.
pub fn load_run(dir: impl AsRef<Path>) -> Result<RunSummary> {
    let dir = dir.as_ref();
    let read = |name: &str| -> Result<Value> {
        let path = dir.join(name);
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Data(format!(
                "{}: {e} (expected a `pdfa train` run directory)",
                path.display()
            ))
        })?;
        Value::parse(&text)
    };
    let config = read("config.json")?;
    let result = read("result.json")?;
    let epochs = read("history.json")
        .ok()
        .and_then(|h| h.as_array().map(<[Value]>::len))
        .unwrap_or(0);
    let train = config.get("train").clone();
    Ok(RunSummary {
        dir: dir.to_path_buf(),
        backend: config.get("backend").as_str().unwrap_or("unknown").to_string(),
        config: train.get("config").as_str().unwrap_or("?").to_string(),
        physics: train.get("physics").as_str().map(str::to_string),
        epochs,
        total_steps: result.get("total_steps").as_f64().unwrap_or(0.0) as u64,
        test_acc: result.get("test_acc").as_f64(),
        wall_s: result.get("wall_s").as_f64().unwrap_or(0.0),
        telemetry: Telemetry::from_json(result.get("telemetry")).unwrap_or_default(),
    })
}

/// Parse the bank geometry out of a physics (or checkpoint protocol)
/// string: the `bank=RxC` key of [`crate::runtime::PhysicsConfig::describe`].
pub fn bank_dims(physics: &str) -> Option<(usize, usize)> {
    let spec = physics.split(';').find_map(|kv| {
        let kv = kv.trim();
        let kv = kv.strip_prefix("physics=").unwrap_or(kv);
        kv.strip_prefix("bank=")
    })?;
    let (r, c) = spec.split_once('x')?;
    Some((r.trim().parse().ok()?, c.trim().parse().ok()?))
}

/// Engineering-prefixed joules for the energy rows.
pub fn fmt_joules(j: f64) -> String {
    if j <= 0.0 {
        return "0 J".into();
    }
    let (v, unit) = if j >= 1.0 {
        (j, "J")
    } else if j >= 1e-3 {
        (j * 1e3, "mJ")
    } else if j >= 1e-6 {
        (j * 1e6, "µJ")
    } else if j >= 1e-9 {
        (j * 1e9, "nJ")
    } else {
        (j * 1e12, "pJ")
    };
    format!("{v:.2} {unit}")
}

fn row(out: &mut String, label: &str, measured: &str, target: &str) {
    out.push_str(&format!("{label:<30} {measured:<26} {target}\n"));
}

/// Render the paper-comparison table for a recorded run.
pub fn render_run(r: &RunSummary) -> String {
    let t = &r.telemetry;
    let mut out = format!("telemetry report — {}\n", r.dir.display());
    out.push_str(&format!("backend {} | config {}", r.backend, r.config));
    if let Some(p) = &r.physics {
        out.push_str(&format!(" | physics {p}"));
    }
    out.push('\n');
    out.push_str(&format!(
        "epochs {} | steps {} | test acc {} | wall {:.1}s\n\n",
        r.epochs,
        r.total_steps,
        r.test_acc.map_or("-".into(), |a| format!("{a:.4}")),
        r.wall_s,
    ));
    row(&mut out, "metric", "measured", "paper §5");
    row(&mut out, "MACs dispatched", &format!("{} ({})", t.macs, fmt_si(t.macs as f64)), "—");
    row(
        &mut out,
        "on-bank MACs",
        &format!("{} ({})", t.photonic_macs, fmt_si(t.photonic_macs as f64)),
        "—",
    );
    row(
        &mut out,
        "MAC/s (wall-clock)",
        &fmt_si(t.macs_per_second(r.wall_s)),
        &format!("{} (Eq. 2: {PAPER_TOPS} TOPS peak)", fmt_si(PAPER_TOPS / 2.0 * 1e12)),
    );
    row(&mut out, "optical cycles", &t.cycles.to_string(), "—");
    row(&mut out, "bank operations", &t.bank_ops.to_string(), "—");

    // device-lifetime rows: only for runs where the drift scheduler was
    // live (a static device records no recalibration work)
    if t.recal_events > 0 || t.recal_cycles > 0 {
        row(&mut out, "recalibrations", &t.recal_events.to_string(), "— (drift scheduler)");
        let fired = (t.cycles + t.recal_cycles) as f64;
        let pct = if fired > 0.0 { 100.0 * t.recal_cycles as f64 / fired } else { 0.0 };
        row(
            &mut out,
            "recal cycles",
            &format!("{} ({pct:.1} % of fired)", t.recal_cycles),
            "—",
        );
    }
    if t.drift_err > 0.0 {
        row(&mut out, "drift error (est.)", &format!("{:.4}", t.drift_err), "< drift:recal");
    }

    let dims = r.physics.as_deref().and_then(bank_dims);
    if let Some((rows, cols)) = dims {
        if t.cycles > 0 {
            let driven = t.cycles as f64 * (rows * cols) as f64;
            let util = 100.0 * t.photonic_macs as f64 / driven;
            row(&mut out, "bank utilisation", &format!("{util:.1} %"), "100 % (dense dispatch)");
        }
    }
    row(&mut out, "energy (modeled, heater)", &fmt_joules(t.energy_j), "—");

    // measured pJ/MAC under both tuning schemes; the trimmed figure
    // re-prices the same cycle tally with the heater budget removed
    let nominal_target = format!(
        "{:.2}  (2·E_op; §5 E_op {PAPER_PJ_PER_OP_NOMINAL:.1} pJ nominal)",
        2.0 * PAPER_PJ_PER_OP_NOMINAL
    );
    let trimmed_target = format!(
        "{:.2}  (2·E_op; §5 E_op {PAPER_PJ_PER_OP_TRIMMED:.2} pJ trimmed)",
        2.0 * PAPER_PJ_PER_OP_TRIMMED
    );
    match t.pj_per_mac() {
        Some(pj) => {
            row(&mut out, "pJ/MAC heater-locked", &format!("{pj:.2}"), &nominal_target);
            if let Some((rows, cols)) = dims {
                let trimmed = EnergyModel::for_bank(rows, cols, MrrTuning::Trimmed);
                let pj_t =
                    trimmed.joules(t.cycles + t.recal_cycles) * 1e12 / t.photonic_macs as f64;
                row(&mut out, "pJ/MAC trimmed", &format!("{pj_t:.2}"), &trimmed_target);
            }
        }
        None => {
            let na = "n/a (no on-bank work recorded)";
            row(&mut out, "pJ/MAC heater-locked", na, &nominal_target);
            row(&mut out, "pJ/MAC trimmed", na, &trimmed_target);
        }
    }
    out.push_str(
        "\n§5 targets: E_op = 1.0 pJ/op nominal (heater-locked) and 0.28 pJ/op\n\
         trimmed; a MAC is two ops, so the per-MAC targets are 2.0 / 0.56 pJ.\n\
         Measured pJ/MAC above them reflects utilisation overheads: tile\n\
         padding, differential e⁺/e⁻ cycles, and partial batches.\n",
    );
    out
}

/// Render the analytic-cost report for a bare checkpoint (checkpoints
/// record steps and dims, not counters — point `pdfa report` at the run
/// directory for measured telemetry).
pub fn render_checkpoint(path: &Path, ckpt: &Checkpoint) -> String {
    let d = &ckpt.dims;
    let backprop = ckpt.protocol.contains("algorithm=Backprop");
    let macs_per_step = if backprop {
        macs_forward(d) + super::macs_backprop_deltas(d) + macs_weight_grads(d)
    } else {
        macs_forward(d) + macs_feedback(d) + macs_weight_grads(d)
    };
    let total = macs_per_step * ckpt.total_steps;
    let ops = 2.0 * total as f64;
    let mut out = format!("telemetry report — {} (checkpoint)\n", path.display());
    out.push_str(&format!(
        "config {} ({}-{}-{}-{}, batch {}) | epoch {} | {} optimizer steps\n",
        ckpt.config, d.d_in, d.d_h1, d.d_h2, d.d_out, d.batch, ckpt.epoch, ckpt.total_steps,
    ));
    out.push_str(&format!("protocol: {}\n\n", ckpt.protocol));
    out.push_str(
        "analytic training cost (checkpoints carry no counters; run\n\
         `pdfa report <run-dir>` for measured telemetry):\n",
    );
    out.push_str(&format!(
        "  MACs/step ({})        {} ({})\n",
        if backprop { "backprop" } else { "dfa" },
        macs_per_step,
        fmt_si(macs_per_step as f64),
    ));
    out.push_str(&format!("  total MACs              {} ({})\n", total, fmt_si(total as f64)));
    out.push_str(&format!(
        "  energy at §5 E_op:      {} nominal (1.0 pJ/op) | {} trimmed (0.28 pJ/op)\n",
        fmt_joules(ops * PAPER_PJ_PER_OP_NOMINAL * 1e-12),
        fmt_joules(ops * PAPER_PJ_PER_OP_TRIMMED * 1e-12),
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfa::params::NetState;
    use crate::runtime::manifest::NetDims;
    use crate::util::rng::Pcg64;

    #[test]
    fn bank_dims_parses_physics_and_protocol_strings() {
        assert_eq!(bank_dims("bank=50x20;dac=12;adc=6"), Some((50, 20)));
        assert_eq!(bank_dims("dac=12;bank=16x12;adc=6"), Some((16, 12)));
        // checkpoint protocol form: the physics= key wraps the bank key
        assert_eq!(bank_dims("lr=0.05;physics=bank=8x4;dac=0"), Some((8, 4)));
        assert_eq!(bank_dims("lr=0.05;physics=none"), None);
        assert_eq!(bank_dims("bank=ax4"), None);
        assert_eq!(bank_dims(""), None);
    }

    #[test]
    fn joules_format_across_scales() {
        assert_eq!(fmt_joules(0.0), "0 J");
        assert_eq!(fmt_joules(2.5), "2.50 J");
        assert_eq!(fmt_joules(3.2e-3), "3.20 mJ");
        assert_eq!(fmt_joules(4.7e-6), "4.70 µJ");
        assert_eq!(fmt_joules(9.9e-9), "9.90 nJ");
        assert_eq!(fmt_joules(1.5e-12), "1.50 pJ");
    }

    fn summary(telemetry: Telemetry, physics: Option<&str>) -> RunSummary {
        RunSummary {
            dir: PathBuf::from("runs/unit"),
            backend: if physics.is_some() { "photonic" } else { "native" }.into(),
            config: "tiny".into(),
            physics: physics.map(str::to_string),
            epochs: 2,
            total_steps: 16,
            test_acc: Some(0.875),
            wall_s: 1.5,
            telemetry,
        }
    }

    #[test]
    fn run_report_shows_measured_and_targets() {
        let t = Telemetry {
            macs: 200_000,
            photonic_macs: 150_000,
            cycles: 1_000,
            bank_ops: 40,
            energy_j: EnergyModel::for_bank(16, 12, crate::energy::MrrTuning::HeaterLocked)
                .joules(1_000),
            ..Telemetry::default()
        };
        let text = render_run(&summary(t, Some("bank=16x12;dac=6;adc=6;sigma=0.1")));
        for needle in [
            "MACs dispatched",
            "200000",
            "MAC/s (wall-clock)",
            "optical cycles",
            "bank utilisation",
            "pJ/MAC heater-locked",
            "pJ/MAC trimmed",
            "1.0 pJ nominal",
            "0.28 pJ trimmed",
            "20 TOPS peak",
        ] {
            assert!(text.contains(needle), "missing '{needle}' in:\n{text}");
        }
        // utilisation: 150k MACs over 1000 cycles x 192 cells = 78.1 %
        assert!(text.contains("78.1 %"), "{text}");
    }

    #[test]
    fn drifty_run_report_shows_lifetime_rows() {
        let model = EnergyModel::for_bank(16, 12, crate::energy::MrrTuning::HeaterLocked);
        let t = Telemetry {
            macs: 200_000,
            photonic_macs: 150_000,
            cycles: 1_000,
            bank_ops: 40,
            recal_events: 3,
            recal_cycles: 1_000, // 50 % of all fired cycles
            drift_err: 0.0421,
            energy_j: model.joules(2_000),
        };
        let text = render_run(&summary(t, Some("bank=16x12;dac=6;adc=6;sigma=0.1")));
        for needle in
            ["recalibrations", "drift scheduler", "(50.0 % of fired)", "drift error (est.)", "0.0421"]
        {
            assert!(text.contains(needle), "missing '{needle}' in:\n{text}");
        }
        // a static device keeps the lifetime rows out entirely
        let quiet = Telemetry { recal_events: 0, recal_cycles: 0, drift_err: 0.0, ..t };
        let text = render_run(&summary(quiet, Some("bank=16x12;dac=6")));
        assert!(!text.contains("recalibrations"), "{text}");
        assert!(!text.contains("drift error"), "{text}");
    }

    #[test]
    fn digital_run_report_still_shows_targets() {
        let t = Telemetry { macs: 64_000, ..Telemetry::default() };
        let text = render_run(&summary(t, None));
        assert!(text.contains("n/a (no on-bank work recorded)"), "{text}");
        assert!(text.contains("1.0 pJ nominal"), "{text}");
        assert!(text.contains("0.28 pJ trimmed"), "{text}");
        assert!(!text.contains("bank utilisation"), "{text}");
    }

    #[test]
    fn checkpoint_report_uses_analytic_counts() {
        let dims = NetDims { d_in: 16, d_h1: 32, d_h2: 32, d_out: 4, batch: 8 };
        let mut rng = Pcg64::seed(3);
        let ckpt = Checkpoint {
            config: "tiny".into(),
            dims: dims.clone(),
            epoch: 2,
            total_steps: 10,
            seed: 3,
            protocol: "backend=native;lr=0.05;algorithm=Dfa".into(),
            rng: Pcg64::seed(3),
            state: NetState::init(&dims, &mut rng),
            device: None,
        };
        let text = render_checkpoint(Path::new("x.ckpt"), &ckpt);
        // dfa step on tiny: 13312 + 2048 + 13312 = 28672; x10 steps
        assert!(text.contains("28672"), "{text}");
        assert!(text.contains("286720"), "{text}");
        assert!(text.contains("1.0 pJ/op"), "{text}");
        assert!(text.contains("0.28 pJ/op"), "{text}");
    }

    #[test]
    fn load_run_round_trips_a_recorded_directory() {
        let dir = std::env::temp_dir().join("pdfa_report_unit");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let t = Telemetry {
            macs: 1_234,
            photonic_macs: 1_000,
            cycles: 77,
            bank_ops: 5,
            energy_j: 1.5e-7,
            ..Telemetry::default()
        };
        let config = Value::object(vec![
            ("backend", Value::str("photonic")),
            (
                "train",
                Value::object(vec![
                    ("config", Value::str("tiny")),
                    ("physics", Value::str("bank=16x12;dac=6")),
                ]),
            ),
        ]);
        let result = Value::object(vec![
            ("test_acc", Value::Number(0.5)),
            ("total_steps", Value::Number(8.0)),
            ("wall_s", Value::Number(2.0)),
            ("telemetry", t.to_json()),
        ]);
        let history = Value::Array(vec![Value::object(vec![]), Value::object(vec![])]);
        std::fs::write(dir.join("config.json"), config.to_string_pretty()).unwrap();
        std::fs::write(dir.join("result.json"), result.to_string_pretty()).unwrap();
        std::fs::write(dir.join("history.json"), history.to_string_pretty()).unwrap();
        let r = load_run(&dir).unwrap();
        assert_eq!(r.backend, "photonic");
        assert_eq!(r.config, "tiny");
        assert_eq!(r.physics.as_deref(), Some("bank=16x12;dac=6"));
        assert_eq!(r.epochs, 2);
        assert_eq!(r.total_steps, 8);
        assert_eq!(r.telemetry, t);
        // a missing directory is a clean data error
        let err = load_run(dir.join("nope")).unwrap_err().to_string();
        assert!(err.contains("run directory"), "{err}");
    }
}
