//! Hardware telemetry: MAC, optical-cycle and energy accounting for every
//! [`crate::runtime::StepEngine`].
//!
//! The paper's headline claims are *operational*: Eq. (2) promises
//! 2·f_s·M·N operations per second (20 TOPS for the 50 × 20 bank at
//! 10 GHz) and §5 budgets the wall-plug energy at 1.0 pJ per operation
//! with heater-locked MRRs, 0.28 pJ with post-fabrication trimming. This
//! module is how the reproduction states those numbers about its own
//! runs instead of only about the analytic model in [`crate::energy`]:
//!
//! * [`Counters`] — lock-free accrual cells an engine shares with every
//!   artifact it loads. Digital engines ([`crate::runtime::NativeEngine`],
//!   the PJRT engine) count MACs *analytically* from each dispatch's
//!   manifest shapes ([`macs_for_artifact`]); the photonic engine
//!   additionally tallies the optical cycles its weight bank actually
//!   fired (differential e⁺/e⁻ encoding counts both passes, exactly as
//!   the artifact's own cycle counter does).
//! * [`Telemetry`] — an immutable snapshot of those counters, plus the
//!   modeled energy ([`crate::energy::EnergyModel`]) for engines with a
//!   physical substrate. Snapshots subtract ([`Telemetry::delta`]) so the
//!   trainer can attribute work to epochs and the serve stack to request
//!   windows.
//! * [`report`] — the `pdfa report` renderer: measured MAC/s and modeled
//!   pJ/MAC of a recorded run against the §5 targets.
//!
//! Determinism contract (inherited from the PR 4 threading work): every
//! counter is a pure function of the executed dispatches — MAC counts are
//! analytic, cycle counts are bit-identical at any `--threads` value —
//! so the telemetry block of a run record is byte-identical across
//! thread counts. Only *rates* (MAC/s) depend on wall-clock time, and
//! they are kept out of the counter snapshot for exactly that reason.
//!
//! The device-lifetime work adds two recalibration tallies (`recal_events`,
//! `recal_cycles` — fired by the drift scheduler, priced by the same §5
//! model as compute cycles) and one gauge (`drift_err`, the drift model's
//! latest weight-error estimate). The gauge is excluded from the
//! determinism contract's *tally* semantics but is still a pure function
//! of executed dispatches, so it too is thread-count invariant.
//!
//! ```
//! use photonic_dfa::telemetry::Counters;
//!
//! let c = Counters::default();
//! c.add_macs(1_000); // a digital dispatch
//! c.add_bank(500, 4, 2); // a bank dispatch: 500 MACs over 4 cycles, 2 ops
//! c.add_recal(300); // one scheduler-fired recalibration, 300 readout cycles
//! let t = c.snapshot(None);
//! assert_eq!(t.macs, 1_500);
//! assert_eq!(t.photonic_macs, 500);
//! assert_eq!(t.cycles, 4);
//! assert_eq!(t.recal_events, 1);
//! assert_eq!(t.recal_cycles, 300);
//! assert_eq!(t.energy_j, 0.0); // no energy model attached
//! ```

pub mod report;

use std::sync::atomic::{AtomicU64, Ordering};

use crate::energy::EnergyModel;
use crate::runtime::manifest::NetDims;
use crate::util::json::Value;

/// §5 nominal energy target: 1.0 pJ per operation with heater-locked
/// MRRs (Eq. 4 at the 50 × 20 / 10 GHz operating point).
pub const PAPER_PJ_PER_OP_NOMINAL: f64 = 1.0;

/// §5 trimmed energy target: 0.28 pJ per operation once post-fabrication
/// trimming removes the heater budget.
pub const PAPER_PJ_PER_OP_TRIMMED: f64 = 0.28;

/// Eq. (2) headline throughput of the §5 bank: 20 TOPS (= 10 T MAC/s,
/// one MAC being a multiply + an add).
pub const PAPER_TOPS: f64 = 20.0;

/// One engine's accumulated hardware counters at a point in time.
///
/// `macs` counts *all* multiply-accumulates the engine dispatched, on any
/// substrate; `photonic_macs` is the subset executed on the MRR weight
/// bank (zero for the digital backends). `cycles`/`bank_ops` mirror the
/// photonic artifact's own counters: optical cycles fired and bank
/// operations (inscribe-and-evaluate dispatches). `energy_j` is the
/// modeled wall-plug energy of those cycles under the §5 component
/// budget — zero when no [`EnergyModel`] is attached.
///
/// Every field except `energy_j` is an exact integer; `energy_j` is
/// `cycles` × a configuration constant, so the whole snapshot is
/// bit-identical at any worker-thread count.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Telemetry {
    /// Multiply-accumulates dispatched (analytic, from dispatch shapes).
    pub macs: u64,
    /// MACs executed on the photonic weight bank (subset of `macs`).
    pub photonic_macs: u64,
    /// Optical cycles fired (0 on digital backends).
    pub cycles: u64,
    /// Bank operations: inscribe-and-evaluate dispatches (0 on digital).
    pub bank_ops: u64,
    /// Recalibration events fired by the drift scheduler (0 on digital
    /// backends and on a static device).
    pub recal_events: u64,
    /// Calibration-readout cycles those recalibrations consumed; priced
    /// into `energy_j` alongside the compute cycles.
    pub recal_cycles: u64,
    /// Latest drift-model weight-error estimate (a gauge, not a tally;
    /// 0 on digital backends and before the first drift tick).
    pub drift_err: f64,
    /// Modeled wall-plug energy in joules (0 without an energy model).
    pub energy_j: f64,
}

impl Telemetry {
    /// Counters accrued since `earlier` (which must be an older snapshot
    /// of the same engine; fields saturate at zero otherwise).
    pub fn delta(&self, earlier: &Telemetry) -> Telemetry {
        Telemetry {
            macs: self.macs.saturating_sub(earlier.macs),
            photonic_macs: self.photonic_macs.saturating_sub(earlier.photonic_macs),
            cycles: self.cycles.saturating_sub(earlier.cycles),
            bank_ops: self.bank_ops.saturating_sub(earlier.bank_ops),
            recal_events: self.recal_events.saturating_sub(earlier.recal_events),
            recal_cycles: self.recal_cycles.saturating_sub(earlier.recal_cycles),
            // a gauge: the window's value is the latest reading, not a sum
            drift_err: self.drift_err,
            energy_j: (self.energy_j - earlier.energy_j).max(0.0),
        }
    }

    /// True when nothing has been counted (e.g. an engine predating the
    /// telemetry contract, or no dispatch yet).
    pub fn is_empty(&self) -> bool {
        self.macs == 0 && self.cycles == 0
    }

    /// Wall-clock MAC rate over `wall_s` seconds (0 for a zero window).
    pub fn macs_per_second(&self, wall_s: f64) -> f64 {
        if wall_s > 0.0 {
            self.macs as f64 / wall_s
        } else {
            0.0
        }
    }

    /// Modeled pJ per on-bank MAC: `energy_j / photonic_macs`, the number
    /// `pdfa report` compares against the §5 targets. `None` when no
    /// bank work (or no energy model) was recorded.
    pub fn pj_per_mac(&self) -> Option<f64> {
        if self.photonic_macs > 0 && self.energy_j > 0.0 {
            Some(self.energy_j * 1e12 / self.photonic_macs as f64)
        } else {
            None
        }
    }

    /// Serialise for run records. Keys hold counters only (no rates), so
    /// the object is byte-identical at any `--threads` value.
    pub fn to_json(&self) -> Value {
        Value::object(vec![
            ("macs", Value::Number(self.macs as f64)),
            ("photonic_macs", Value::Number(self.photonic_macs as f64)),
            ("cycles", Value::Number(self.cycles as f64)),
            ("bank_ops", Value::Number(self.bank_ops as f64)),
            ("recal_events", Value::Number(self.recal_events as f64)),
            ("recal_cycles", Value::Number(self.recal_cycles as f64)),
            ("drift_err", Value::Number(self.drift_err)),
            ("energy_j", Value::Number(self.energy_j)),
        ])
    }

    /// Parse a [`Self::to_json`] object back (run-report loading).
    pub fn from_json(v: &Value) -> Option<Telemetry> {
        Some(Telemetry {
            macs: v.get("macs").as_f64()? as u64,
            photonic_macs: v.get("photonic_macs").as_f64()? as u64,
            cycles: v.get("cycles").as_f64()? as u64,
            bank_ops: v.get("bank_ops").as_f64()? as u64,
            // lifetime counters postdate the first run-record format:
            // absent keys read as a static device, keeping old records
            // loadable
            recal_events: v.get("recal_events").as_f64().unwrap_or(0.0) as u64,
            recal_cycles: v.get("recal_cycles").as_f64().unwrap_or(0.0) as u64,
            drift_err: v.get("drift_err").as_f64().unwrap_or(0.0),
            energy_j: v.get("energy_j").as_f64()?,
        })
    }
}

/// Lock-free accrual cells, shared (`Arc`) between an engine and every
/// artifact it loads. All adds are `Relaxed` fetch-adds: counters are
/// monotone tallies, never synchronisation points, so a snapshot taken
/// between dispatches is exact and a snapshot taken mid-dispatch is a
/// valid lower bound.
#[derive(Debug, Default)]
pub struct Counters {
    macs: AtomicU64,
    photonic_macs: AtomicU64,
    cycles: AtomicU64,
    bank_ops: AtomicU64,
    recal_events: AtomicU64,
    recal_cycles: AtomicU64,
    /// `f64::to_bits` of the latest drift-error estimate (a gauge).
    drift_err: AtomicU64,
    /// Engine-global operation sequence: one draw per bank dispatch, used
    /// to key the dispatch's noise streams. Engine-level (not per
    /// artifact) so a run's op numbering is a pure function of its
    /// dispatch order — and therefore checkpointable.
    op_seq: AtomicU64,
}

impl Counters {
    /// Record `n` digitally executed MACs.
    pub fn add_macs(&self, n: u64) {
        self.macs.fetch_add(n, Ordering::Relaxed);
    }

    /// Record a bank dispatch: `macs` on-bank MACs over `cycles` optical
    /// cycles across `ops` bank operations.
    pub fn add_bank(&self, macs: u64, cycles: u64, ops: u64) {
        self.macs.fetch_add(macs, Ordering::Relaxed);
        self.photonic_macs.fetch_add(macs, Ordering::Relaxed);
        self.cycles.fetch_add(cycles, Ordering::Relaxed);
        self.bank_ops.fetch_add(ops, Ordering::Relaxed);
    }

    /// Record one scheduler-fired recalibration of `cycles` readout
    /// cycles. Kept out of the main `cycles` tally so device time (which
    /// drives the drift model) never advances while the device is being
    /// recalibrated — re-drifting during recalibration would make the
    /// scheduler chase its own tail.
    pub fn add_recal(&self, cycles: u64) {
        self.recal_events.fetch_add(1, Ordering::Relaxed);
        self.recal_cycles.fetch_add(cycles, Ordering::Relaxed);
    }

    /// Publish the drift model's latest weight-error estimate.
    pub fn set_drift_err(&self, err: f64) {
        self.drift_err.store(err.to_bits(), Ordering::Relaxed);
    }

    /// Optical cycles fired so far — the device-time base the drift model
    /// advances against.
    pub fn cycles(&self) -> u64 {
        self.cycles.load(Ordering::Relaxed)
    }

    /// Draw the next operation number (post-increment).
    pub fn next_op(&self) -> u64 {
        self.op_seq.fetch_add(1, Ordering::Relaxed)
    }

    /// Current operation-sequence value (for checkpointing).
    pub fn op_seq(&self) -> u64 {
        self.op_seq.load(Ordering::Relaxed)
    }

    /// Overwrite the tallies from a checkpointed snapshot (bit-exact
    /// resume of a photonic device). `energy_j` and `drift_err` are
    /// derived values and are ignored.
    pub fn restore(&self, t: &Telemetry, op_seq: u64) {
        self.macs.store(t.macs, Ordering::Relaxed);
        self.photonic_macs.store(t.photonic_macs, Ordering::Relaxed);
        self.cycles.store(t.cycles, Ordering::Relaxed);
        self.bank_ops.store(t.bank_ops, Ordering::Relaxed);
        self.recal_events.store(t.recal_events, Ordering::Relaxed);
        self.recal_cycles.store(t.recal_cycles, Ordering::Relaxed);
        self.op_seq.store(op_seq, Ordering::Relaxed);
    }

    /// Snapshot the counters; `energy` converts the cycle tallies into
    /// modeled joules (the photonic engine passes its §5 model, the
    /// digital engines pass `None`). Recalibration readout cycles are
    /// priced exactly like compute cycles — the §5 budget does not care
    /// why the bank fired.
    pub fn snapshot(&self, energy: Option<&EnergyModel>) -> Telemetry {
        let cycles = self.cycles.load(Ordering::Relaxed);
        let recal_cycles = self.recal_cycles.load(Ordering::Relaxed);
        Telemetry {
            macs: self.macs.load(Ordering::Relaxed),
            photonic_macs: self.photonic_macs.load(Ordering::Relaxed),
            cycles,
            bank_ops: self.bank_ops.load(Ordering::Relaxed),
            recal_events: self.recal_events.load(Ordering::Relaxed),
            recal_cycles,
            drift_err: f64::from_bits(self.drift_err.load(Ordering::Relaxed)),
            energy_j: energy.map_or(0.0, |e| e.joules(cycles + recal_cycles)),
        }
    }
}

/// MACs of the three-layer forward pass: one per weight-matrix cell per
/// batch row (`B·(d_in·h1 + h1·h2 + h2·out)`).
pub fn macs_forward(d: &NetDims) -> u64 {
    d.batch as u64 * (d.d_in * d.d_h1 + d.d_h1 * d.d_h2 + d.d_h2 * d.d_out) as u64
}

/// MACs of the DFA feedback projections `B(1)·e, B(2)·e` (Eq. 1):
/// `B·(h1 + h2)·out`.
pub fn macs_feedback(d: &NetDims) -> u64 {
    d.batch as u64 * ((d.d_h1 + d.d_h2) * d.d_out) as u64
}

/// MACs of the weight-gradient outer products `xᵀ·δ` — one per weight
/// cell per batch row, the same count as the forward pass.
pub fn macs_weight_grads(d: &NetDims) -> u64 {
    macs_forward(d)
}

/// MACs of backprop's extra delta transposes `δ3·W3ᵀ, δ2·W2ᵀ`:
/// `B·(h2·out + h1·h2)`.
pub fn macs_backprop_deltas(d: &NetDims) -> u64 {
    d.batch as u64 * (d.d_h2 * d.d_out + d.d_h1 * d.d_h2) as u64
}

/// Analytic MAC count of one `execute` of a config-bound artifact, by
/// vocabulary prefix. Unknown names (and `photonic_matvec`, whose bank
/// geometry is not described by `NetDims` — engines derive its count
/// from the spec's `phi` shape instead) report 0.
pub fn macs_for_artifact(name: &str, d: &NetDims) -> u64 {
    if name.starts_with("fwd_") {
        macs_forward(d)
    } else if name.starts_with("dfa_step_") {
        macs_forward(d) + macs_feedback(d) + macs_weight_grads(d)
    } else if name.starts_with("bp_step_") {
        macs_forward(d) + macs_backprop_deltas(d) + macs_weight_grads(d)
    } else if name.starts_with("apply_grads_") {
        macs_weight_grads(d)
    } else {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> NetDims {
        NetDims { d_in: 16, d_h1: 32, d_h2: 32, d_out: 4, batch: 8 }
    }

    #[test]
    fn analytic_mac_counts_for_known_shapes() {
        // tiny: 8·(16·32 + 32·32 + 32·4) = 8·1664 = 13312
        let d = tiny();
        assert_eq!(macs_forward(&d), 13_312);
        // feedback: 8·(32+32)·4 = 2048
        assert_eq!(macs_feedback(&d), 2_048);
        assert_eq!(macs_weight_grads(&d), 13_312);
        // bp deltas: 8·(32·4 + 32·32) = 9216
        assert_eq!(macs_backprop_deltas(&d), 9_216);

        assert_eq!(macs_for_artifact("fwd_tiny", &d), 13_312);
        assert_eq!(macs_for_artifact("dfa_step_tiny", &d), 13_312 + 2_048 + 13_312);
        assert_eq!(macs_for_artifact("bp_step_tiny", &d), 13_312 + 9_216 + 13_312);
        assert_eq!(macs_for_artifact("apply_grads_tiny", &d), 13_312);
        assert_eq!(macs_for_artifact("photonic_matvec", &d), 0);
        assert_eq!(macs_for_artifact("unknown", &d), 0);

        // mnist: 64·(784·800 + 800·800 + 800·10) per fwd
        let mnist = NetDims { d_in: 784, d_h1: 800, d_h2: 800, d_out: 10, batch: 64 };
        assert_eq!(macs_forward(&mnist), 64 * (784 * 800 + 800 * 800 + 800 * 10) as u64);
    }

    #[test]
    fn counters_accrue_and_snapshot() {
        let c = Counters::default();
        assert!(c.snapshot(None).is_empty());
        c.add_macs(100);
        c.add_bank(50, 7, 2);
        c.add_bank(50, 3, 1);
        c.add_recal(1_000);
        c.add_recal(2_000);
        c.set_drift_err(0.125);
        let t = c.snapshot(None);
        assert_eq!(t.macs, 200);
        assert_eq!(t.photonic_macs, 100);
        assert_eq!(t.cycles, 10);
        assert_eq!(t.bank_ops, 3);
        assert_eq!(t.recal_events, 2);
        assert_eq!(t.recal_cycles, 3_000);
        assert_eq!(t.drift_err, 0.125);
        assert_eq!(t.energy_j, 0.0);
        assert!(!t.is_empty());
        // recalibration never advances device time
        assert_eq!(c.cycles(), 10);
        // op sequence: post-increment draws
        assert_eq!(c.next_op(), 0);
        assert_eq!(c.next_op(), 1);
        assert_eq!(c.op_seq(), 2);
        // restore overwrites tallies bit-exactly
        let fresh = Counters::default();
        fresh.restore(&t, 7);
        assert_eq!(fresh.snapshot(None).recal_cycles, 3_000);
        assert_eq!(fresh.op_seq(), 7);
    }

    #[test]
    fn snapshot_with_energy_model_prices_cycles() {
        use crate::energy::{EnergyModel, MrrTuning};
        let c = Counters::default();
        c.add_bank(1_000, 10, 1);
        let model = EnergyModel::for_bank(50, 20, MrrTuning::HeaterLocked);
        let t = c.snapshot(Some(&model));
        assert_eq!(t.energy_j, model.joules(10));
        assert!(t.energy_j > 0.0);
        // pJ/MAC = energy / on-bank MACs
        let pj = t.pj_per_mac().unwrap();
        assert!((pj - t.energy_j * 1e12 / 1_000.0).abs() < 1e-12);
        // recalibration readouts are priced like compute cycles
        c.add_recal(5);
        let t = c.snapshot(Some(&model));
        assert_eq!(t.energy_j, model.joules(15));
    }

    #[test]
    fn delta_subtracts_and_saturates() {
        let c = Counters::default();
        c.add_bank(100, 4, 1);
        let a = c.snapshot(None);
        c.add_bank(50, 2, 1);
        let b = c.snapshot(None);
        let d = b.delta(&a);
        assert_eq!(d.macs, 50);
        assert_eq!(d.cycles, 2);
        assert_eq!(d.bank_ops, 1);
        // reversed order saturates instead of wrapping
        let z = a.delta(&b);
        assert_eq!(z.macs, 0);
        assert_eq!(z.energy_j, 0.0);
    }

    #[test]
    fn rates_and_edge_cases() {
        let t = Telemetry { macs: 1_000, ..Telemetry::default() };
        assert_eq!(t.macs_per_second(2.0), 500.0);
        assert_eq!(t.macs_per_second(0.0), 0.0);
        assert_eq!(t.pj_per_mac(), None); // no bank work
        let t = Telemetry { photonic_macs: 10, energy_j: 0.0, ..t };
        assert_eq!(t.pj_per_mac(), None); // no energy model
    }

    #[test]
    fn json_round_trips_exactly() {
        let t = Telemetry {
            macs: 123_456,
            photonic_macs: 98_765,
            cycles: 4_321,
            bank_ops: 17,
            recal_events: 3,
            recal_cycles: 9_300,
            drift_err: 0.03125,
            energy_j: 1.25e-6,
        };
        let v = t.to_json();
        assert_eq!(Telemetry::from_json(&v), Some(t));
        // serialised form is stable (sorted keys, counters only)
        let text = v.to_string_compact();
        let reparsed = Value::parse(&text).unwrap();
        assert_eq!(Telemetry::from_json(&reparsed), Some(t));
        assert!(!text.contains("mac_per_s"), "rates must stay out: {text}");
        assert_eq!(Telemetry::from_json(&Value::Null), None);
        // pre-lifetime run records (no recal keys) still load, as a
        // static device
        let old = Value::parse(
            r#"{"macs":10,"photonic_macs":5,"cycles":2,"bank_ops":1,"energy_j":0.5}"#,
        )
        .unwrap();
        let t = Telemetry::from_json(&old).unwrap();
        assert_eq!((t.recal_events, t.recal_cycles, t.drift_err), (0, 0, 0.0));
    }
}
