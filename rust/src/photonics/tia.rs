//! Transimpedance amplifier (TIA) with tunable gain.
//!
//! Each weight-bank row's BPD feeds a TIA that converts photocurrent to
//! voltage. The paper's key trick (§3): the Hadamard product with g'(a) is
//! *free* — the control system sets each TIA's gain to the activation
//! derivative (0 or 1 for ReLU) before the optical cycle fires, so the
//! element-wise multiply happens in the electrical domain with no extra
//! cycle. Gain setting does not limit speed because a(k) is known from the
//! forward pass.

use crate::{Error, Result};

/// One tunable-gain TIA channel.
#[derive(Debug, Clone)]
pub struct Tia {
    /// Programmable gain (dimensionless here; physically Ω · responsivity).
    gain: f64,
    /// Gain control resolution in bits (DAC-set); 0 = continuous.
    pub gain_bits: u32,
    /// Output saturation (normalised units).
    pub v_sat: f64,
}

impl Default for Tia {
    fn default() -> Self {
        Tia { gain: 1.0, gain_bits: 0, v_sat: 4.0 }
    }
}

impl Tia {
    pub fn with_resolution(gain_bits: u32) -> Tia {
        Tia { gain_bits, ..Tia::default() }
    }

    /// Program the gain (the g'(a) element for this row). Gains are
    /// quantised to `gain_bits` if configured, mirroring the control DAC.
    pub fn set_gain(&mut self, g: f64) -> Result<()> {
        if !(0.0..=1.0).contains(&g) {
            return Err(Error::Photonics(format!(
                "TIA gain {g} outside [0, 1] (activation derivatives only)"
            )));
        }
        self.gain = if self.gain_bits > 0 {
            let levels = (1u64 << self.gain_bits) as f64 - 1.0;
            (g * levels).round() / levels
        } else {
            g
        };
        Ok(())
    }

    pub fn gain(&self) -> f64 {
        self.gain
    }

    /// Amplify one BPD readout, with output saturation.
    pub fn amplify(&self, i_in: f64) -> f64 {
        (self.gain * i_in).clamp(-self.v_sat, self.v_sat)
    }
}

/// A row of TIAs programmed from a g'(a) vector in one call.
#[derive(Debug, Clone)]
pub struct TiaArray {
    pub tias: Vec<Tia>,
}

impl TiaArray {
    pub fn new(rows: usize, gain_bits: u32) -> TiaArray {
        TiaArray { tias: vec![Tia::with_resolution(gain_bits); rows] }
    }

    /// Program all gains from the activation-derivative vector.
    pub fn program(&mut self, gprime: &[f32]) -> Result<()> {
        if gprime.len() != self.tias.len() {
            return Err(Error::Photonics(format!(
                "g' length {} != {} TIA rows",
                gprime.len(),
                self.tias.len()
            )));
        }
        for (tia, &g) in self.tias.iter_mut().zip(gprime) {
            tia.set_gain(g as f64)?;
        }
        Ok(())
    }

    pub fn amplify_row(&self, row: usize, i_in: f64) -> f64 {
        self.tias[row].amplify(i_in)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_gating() {
        let mut tia = Tia::default();
        tia.set_gain(0.0).unwrap();
        assert_eq!(tia.amplify(0.7), 0.0);
        tia.set_gain(1.0).unwrap();
        assert_eq!(tia.amplify(0.7), 0.7);
    }

    #[test]
    fn rejects_invalid_gains() {
        let mut tia = Tia::default();
        assert!(tia.set_gain(-0.1).is_err());
        assert!(tia.set_gain(1.5).is_err());
    }

    #[test]
    fn gain_quantisation() {
        let mut tia = Tia::with_resolution(2); // levels: 0, 1/3, 2/3, 1
        tia.set_gain(0.30).unwrap();
        assert!((tia.gain() - 1.0 / 3.0).abs() < 1e-12);
        tia.set_gain(0.95).unwrap();
        assert!((tia.gain() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn saturation_clamps() {
        let tia = Tia { gain: 1.0, gain_bits: 0, v_sat: 2.0 };
        assert_eq!(tia.amplify(10.0), 2.0);
        assert_eq!(tia.amplify(-10.0), -2.0);
    }

    #[test]
    fn array_programs_all_rows() {
        let mut arr = TiaArray::new(3, 0);
        arr.program(&[1.0, 0.0, 1.0]).unwrap();
        assert_eq!(arr.amplify_row(0, 0.5), 0.5);
        assert_eq!(arr.amplify_row(1, 0.5), 0.0);
        assert!(arr.program(&[1.0]).is_err());
    }
}
