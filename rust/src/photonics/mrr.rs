//! Add-drop micro-ring resonator (MRR) transmission physics.
//!
//! An MRR in add-drop configuration couples a ring of radius ~8 µm to two
//! bus waveguides (through + drop ports, Fig. 3(a)). The power transmissions
//! as a function of round-trip phase φ are Lorentzian-shaped (Bogaerts et
//! al. 2012, symmetric coupling r₁ = r₂ = r, single-pass amplitude a):
//!
//! ```text
//!   T_p(φ) = (r²a² − 2r²a·cosφ + r²) / (1 − 2r²a·cosφ + r⁴a²)
//!   T_d(φ) = (1 − r²)² a            / (1 − 2r²a·cosφ + r⁴a²)
//! ```
//!
//! With both ports fed to a balanced photodetector the inscribed weight is
//! `w = T_d − T_p ∈ (−1, 1]` (Fig. 3(b)). The device simulator inverts this
//! curve (weight → detuning) to "inscribe" weights, mirroring what the
//! calibration LUT does against bias current on the real chip.
//!
//! This implementation must agree with the L1 Pallas kernel's physics
//! (python/compile/kernels/mrr.py vs ref.py) — enforced by the
//! `photonic_matvec` artifact cross-check in tests/device_mode.rs.

use crate::{Error, Result};

/// Static design parameters of one add-drop MRR.
#[derive(Debug, Clone, Copy)]
pub struct MrrDesign {
    /// Self-coupling coefficient r of both couplers (paper Fig. 3(b): 0.95).
    pub self_coupling: f64,
    /// Single-pass amplitude transmission a (1.0 = lossless).
    pub loss_a: f64,
}

impl Default for MrrDesign {
    fn default() -> Self {
        // Fig. 3(b): r = 0.95, negligible attenuation. Finesse ≈ 30: fine
        // for the 4-channel testbed, not for dense WDM (see high_finesse).
        MrrDesign { self_coupling: 0.95, loss_a: 0.9995 }
    }
}

impl MrrDesign {
    /// The optimised design of §3 (ref 32): finesse ≈ 368, supporting up to
    /// 108 WDM channels on one bus. Required for the paper's dense
    /// 50 × 20 weight bank — low-finesse rings alias neighbouring channels
    /// onto adjacent resonance orders (the FSR wrap is modeled faithfully
    /// by the periodic transmission functions below).
    pub fn high_finesse() -> MrrDesign {
        MrrDesign { self_coupling: 0.996, loss_a: 0.9998 }
    }
}

impl MrrDesign {
    fn denom(&self, phi: f64) -> f64 {
        let (r, a) = (self.self_coupling, self.loss_a);
        let r2a = r * r * a;
        1.0 - 2.0 * r2a * phi.cos() + r2a * r2a
    }

    /// Through-port power transmission T_p(φ).
    pub fn through(&self, phi: f64) -> f64 {
        let (r, a) = (self.self_coupling, self.loss_a);
        ((r * a).powi(2) - 2.0 * r * r * a * phi.cos() + r * r) / self.denom(phi)
    }

    /// Drop-port power transmission T_d(φ).
    pub fn drop(&self, phi: f64) -> f64 {
        let (r, a) = (self.self_coupling, self.loss_a);
        (1.0 - r * r).powi(2) * a / self.denom(phi)
    }

    /// Inscribed weight w(φ) = T_d − T_p.
    pub fn weight(&self, phi: f64) -> f64 {
        self.drop(phi) - self.through(phi)
    }

    /// Maximum achievable weight (at resonance, φ = 0).
    pub fn weight_max(&self) -> f64 {
        self.weight(0.0)
    }

    /// Minimum achievable weight (fully detuned, φ = π).
    pub fn weight_min(&self) -> f64 {
        self.weight(std::f64::consts::PI)
    }

    /// Invert w(φ) on φ ∈ [0, π]: find the detuning that inscribes `w`.
    ///
    /// w(φ) is strictly decreasing on [0, π] (resonance → fully detuned),
    /// so a bisection converges unconditionally. Weights outside the
    /// achievable range are clamped (the real control system saturates the
    /// same way). Returns the detuning in radians.
    pub fn detuning_for_weight(&self, w: f64) -> f64 {
        let w = w.clamp(self.weight_min(), self.weight_max());
        let (mut lo, mut hi) = (0.0f64, std::f64::consts::PI);
        // 60 bisection steps: |hi-lo| < π·2⁻⁶⁰, far below any noise floor.
        for _ in 0..60 {
            let mid = 0.5 * (lo + hi);
            if self.weight(mid) > w {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi)
    }

    /// Full width at half maximum of the drop-port resonance (radians) —
    /// sets the finesse and hence the WDM channel limit (crosstalk.rs).
    pub fn fwhm_phase(&self) -> f64 {
        let peak = self.drop(0.0);
        let half = peak / 2.0;
        // bisection for drop(φ) = half on [0, π]
        let (mut lo, mut hi) = (0.0f64, std::f64::consts::PI);
        for _ in 0..60 {
            let mid = 0.5 * (lo + hi);
            if self.drop(mid) > half {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo + hi // half-width * 2
    }

    /// Finesse = free spectral range / FWHM = 2π / FWHM(φ).
    pub fn finesse(&self) -> f64 {
        2.0 * std::f64::consts::PI / self.fwhm_phase()
    }
}

/// A tunable MRR instance: design + fabrication-induced resonance offset.
///
/// Fabrication variation shifts each ring's natural resonance by a random
/// phase (§3: "can be greater than the tuning range allowed via carrier
/// depletion"); the actuator must supply `fab_offset + detuning` to inscribe
/// a weight, which is exactly what the calibration LUT learns.
#[derive(Debug, Clone)]
pub struct Mrr {
    pub design: MrrDesign,
    /// Static fabrication-induced phase offset (radians).
    pub fab_offset: f64,
}

impl Mrr {
    pub fn new(design: MrrDesign, fab_offset: f64) -> Mrr {
        Mrr { design, fab_offset }
    }

    /// Transmissions at an *applied* actuator phase, accounting for the
    /// fabrication offset: the physical round-trip phase is
    /// `applied - fab_offset` (the actuator must cancel the offset first).
    pub fn weight_at(&self, applied_phase: f64) -> f64 {
        self.design.weight(applied_phase - self.fab_offset)
    }

    pub fn through_at(&self, applied_phase: f64) -> f64 {
        self.design.through(applied_phase - self.fab_offset)
    }

    pub fn drop_at(&self, applied_phase: f64) -> f64 {
        self.design.drop(applied_phase - self.fab_offset)
    }

    /// Ideal applied phase to inscribe weight `w` (what feedback locking
    /// converges to; feed-forward calibration approximates it with a LUT).
    pub fn ideal_phase_for(&self, w: f64) -> f64 {
        self.fab_offset + self.design.detuning_for_weight(w)
    }
}

/// All-pass (single-bus) MRR used by the input modulator array (§3): only a
/// through port, transmission dips to ~0 on resonance. Used to amplitude-
/// encode the error vector e onto each WDM channel.
#[derive(Debug, Clone, Copy)]
pub struct AllPassMrr {
    pub self_coupling: f64,
    pub loss_a: f64,
}

impl Default for AllPassMrr {
    fn default() -> Self {
        // Critically coupled (r = a): full extinction on resonance, which
        // is what an amplitude modulator wants.
        AllPassMrr { self_coupling: 0.95, loss_a: 0.95 }
    }
}

impl AllPassMrr {
    /// Through-port power transmission of an all-pass ring.
    pub fn through(&self, phi: f64) -> f64 {
        let (r, a) = (self.self_coupling, self.loss_a);
        (a * a - 2.0 * r * a * phi.cos() + r * r)
            / (1.0 - 2.0 * r * a * phi.cos() + (r * a) * (r * a))
    }

    /// Detuning that transmits fraction `t` ∈ [t_min, ~1] of the carrier —
    /// the amplitude-encoding inverse used by the input modulators.
    pub fn detuning_for_transmission(&self, t: f64) -> f64 {
        let t_min = self.through(0.0);
        let t_max = self.through(std::f64::consts::PI);
        let t = t.clamp(t_min.min(t_max), t_max.max(t_min));
        let (mut lo, mut hi) = (0.0f64, std::f64::consts::PI);
        for _ in 0..60 {
            let mid = 0.5 * (lo + hi);
            if self.through(mid) < t {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi)
    }
}

/// Convenience: batch-invert weights to detunings for a whole matrix (used
/// when inscribing B(k) into the weight bank and by the photonic_matvec
/// artifact path).
pub fn detunings_for_weights(design: &MrrDesign, weights: &[f32]) -> Vec<f32> {
    weights
        .iter()
        .map(|&w| design.detuning_for_weight(w as f64) as f32)
        .collect()
}

/// Check a proposed weight is inside the inscribable range.
pub fn validate_weight(design: &MrrDesign, w: f64) -> Result<()> {
    if w > design.weight_max() + 1e-9 || w < design.weight_min() - 1e-9 {
        return Err(Error::Photonics(format!(
            "weight {w} outside inscribable range [{:.4}, {:.4}]",
            design.weight_min(),
            design.weight_max()
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;

    #[test]
    fn lossless_energy_conservation() {
        let d = MrrDesign { self_coupling: 0.95, loss_a: 1.0 };
        for i in 0..100 {
            let phi = -std::f64::consts::PI + i as f64 * 0.063;
            let tot = d.through(phi) + d.drop(phi);
            assert!((tot - 1.0).abs() < 1e-12, "phi={phi}: {tot}");
        }
    }

    #[test]
    fn fig3b_extremes() {
        // Fig. 3(b): w = +1 at resonance, ≈ -1 fully detuned (r = 0.95).
        let d = MrrDesign { self_coupling: 0.95, loss_a: 1.0 };
        assert!((d.weight_max() - 1.0).abs() < 1e-12);
        assert!(d.weight_min() < -0.99);
        // through dips to 0 on resonance for the lossless symmetric ring
        assert!(d.through(0.0).abs() < 1e-12);
        assert!((d.drop(0.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn weight_monotone_on_half_period() {
        let d = MrrDesign::default();
        let mut prev = f64::INFINITY;
        for i in 0..=1000 {
            let phi = std::f64::consts::PI * i as f64 / 1000.0;
            let w = d.weight(phi);
            assert!(w <= prev + 1e-12, "not monotone at {phi}");
            prev = w;
        }
    }

    #[test]
    fn detuning_inversion_roundtrip() {
        check("mrr-weight-inversion", 50, |rng| {
            let d = MrrDesign {
                self_coupling: rng.uniform_in(0.85, 0.99),
                loss_a: rng.uniform_in(0.99, 1.0),
            };
            let w = rng.uniform_in(d.weight_min(), d.weight_max());
            let phi = d.detuning_for_weight(w);
            let got = d.weight(phi);
            if (got - w).abs() > 1e-9 {
                return Err(format!("w={w} -> phi={phi} -> {got}"));
            }
            Ok(())
        });
    }

    #[test]
    fn out_of_range_weights_clamp() {
        let d = MrrDesign::default();
        assert_eq!(d.detuning_for_weight(2.0), d.detuning_for_weight(d.weight_max()));
        let w_lo = d.weight(d.detuning_for_weight(-5.0));
        assert!((w_lo - d.weight_min()).abs() < 1e-9);
        assert!(validate_weight(&d, 0.5).is_ok());
        assert!(validate_weight(&d, 1.5).is_err());
    }

    #[test]
    fn fab_offset_shifts_response() {
        let mrr = Mrr::new(MrrDesign::default(), 0.4);
        // applying exactly the offset puts the ring on resonance
        assert!((mrr.weight_at(0.4) - mrr.design.weight_max()).abs() < 1e-12);
        let phi = mrr.ideal_phase_for(0.25);
        assert!((mrr.weight_at(phi) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn finesse_scale_is_physical() {
        // r = 0.95 gives finesse ~ 60; sharper coupling gives higher finesse.
        let f95 = MrrDesign { self_coupling: 0.95, loss_a: 1.0 }.finesse();
        assert!(f95 > 25.0 && f95 < 100.0, "{f95}");
        let f99 = MrrDesign { self_coupling: 0.99, loss_a: 1.0 }.finesse();
        assert!(f99 > 2.0 * f95, "f99={f99} f95={f95}");
    }

    #[test]
    fn allpass_encoding_inverts() {
        let ap = AllPassMrr::default();
        for t in [0.1, 0.3, 0.5, 0.8, 0.95] {
            let phi = ap.detuning_for_transmission(t);
            assert!((ap.through(phi) - t).abs() < 1e-9, "t={t}");
        }
        // on resonance nearly all power drops out of the bus
        assert!(ap.through(0.0) < 0.05);
    }

    #[test]
    fn batch_inversion_matches_scalar() {
        let d = MrrDesign::default();
        let ws = [-0.8f32, -0.2, 0.0, 0.5, 0.9];
        let phis = detunings_for_weights(&d, &ws);
        for (&w, &phi) in ws.iter().zip(&phis) {
            assert!((d.weight(phi as f64) - w as f64).abs() < 1e-6);
        }
    }
}
