//! Balanced photodetector (BPD).
//!
//! Two germanium-doped PIN photodiodes subtract the drop- and through-port
//! photocurrents of a weight-bank row (Fig. 3(d)): i_out ∝ Σ_n P_n·(T_d−T_p).
//! The §4 testbed compared an on-chip BPD whose control circuit "only allows
//! sensing and sourcing at the same location" — an incorrect bias voltage
//! that inflates output noise (σ 0.202 vs 0.098) — against a
//! correctly-biased off-chip Thorlabs BDX1BA. [`BiasQuality`] models that
//! difference explicitly.

use super::noise::NoiseModel;
use crate::util::rng::Pcg64;

/// Bias configuration of the photodiode pair.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BiasQuality {
    /// Correct reverse bias (off-chip BPD, or a fixed control board).
    Proper,
    /// Sensing/sourcing constrained to one node (the §4 on-chip circuit):
    /// under-biased diodes → reduced responsivity linearity + extra noise.
    MisBiased,
}

/// A balanced photodetector with responsivity and physical noise.
#[derive(Debug, Clone)]
pub struct Bpd {
    /// Responsivity of each diode (A/W); matched pair assumed.
    pub responsivity: f64,
    pub bias: BiasQuality,
    pub noise: NoiseModel,
}

impl Bpd {
    pub fn offchip() -> Bpd {
        Bpd {
            responsivity: 0.95,
            bias: BiasQuality::Proper,
            noise: NoiseModel::offchip_bpd(),
        }
    }

    pub fn onchip() -> Bpd {
        Bpd {
            responsivity: 0.95,
            bias: BiasQuality::MisBiased,
            noise: NoiseModel::onchip_bpd(),
        }
    }

    pub fn ideal() -> Bpd {
        Bpd { responsivity: 1.0, bias: BiasQuality::Proper, noise: NoiseModel::ideal() }
    }

    /// Small compressive nonlinearity of the under-biased pair: the diode
    /// stops acting as a current source at high photocurrent.
    fn bias_transfer(&self, x: f64) -> f64 {
        match self.bias {
            BiasQuality::Proper => x,
            // tanh-style soft compression, ~2% at full scale
            BiasQuality::MisBiased => {
                let k = 0.25;
                (x * (1.0 - k) + k * (x / (1.0 + 0.3 * x.abs()))).clamp(-1.5, 1.5)
            }
        }
    }

    /// Read out one balanced sum. `drop_sum`/`through_sum` are normalised
    /// optical powers (full scale 1.0 per channel, `n_channels` channels).
    /// Returns the normalised differential output in ~[-1, 1].
    pub fn read(
        &self,
        drop_sum: f64,
        through_sum: f64,
        n_channels: usize,
        rng: &mut Pcg64,
    ) -> f64 {
        let diff = (drop_sum - through_sum) / n_channels as f64;
        let signal = self.bias_transfer(self.responsivity * diff) / self.responsivity;
        signal + self.noise.sample_readout(signal.abs(), rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Summary;

    #[test]
    fn ideal_bpd_is_exact_difference() {
        let bpd = Bpd::ideal();
        let mut rng = Pcg64::seed(0);
        let out = bpd.read(3.0, 1.0, 4, &mut rng);
        assert!((out - 0.5).abs() < 1e-12);
    }

    #[test]
    fn misbias_compresses_large_signals() {
        let on = Bpd { noise: NoiseModel::ideal(), ..Bpd::onchip() };
        let off = Bpd { noise: NoiseModel::ideal(), ..Bpd::offchip() };
        let mut rng = Pcg64::seed(1);
        let big_on = on.read(4.0, 0.0, 4, &mut rng);
        let big_off = off.read(4.0, 0.0, 4, &mut rng);
        assert!(big_on < big_off, "{big_on} vs {big_off}");
        // small signals nearly unaffected
        let small_on = on.read(0.04, 0.0, 4, &mut rng);
        assert!((small_on - 0.01).abs() < 0.002);
    }

    #[test]
    fn onchip_noise_dominates() {
        let mut rng = Pcg64::seed(2);
        let mut s_on = Summary::new();
        let mut s_off = Summary::new();
        for _ in 0..20_000 {
            s_on.add(Bpd::onchip().read(2.0, 2.0, 4, &mut rng));
            s_off.add(Bpd::offchip().read(2.0, 2.0, 4, &mut rng));
        }
        // zero differential signal: spread is pure readout noise
        assert!(s_on.std() > 2.0 * s_off.std(), "{} vs {}", s_on.std(), s_off.std());
    }
}
