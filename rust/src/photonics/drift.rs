//! Device-lifetime physics: thermal phase drift, calibration aging, fault
//! injection, and the online recalibration scheduler.
//!
//! The §4 testbed only stays usable because its MRR weight bank is
//! continuously re-locked against thermal drift and calibration decay
//! (refs 34–36; Launay et al., arXiv:2006.01475 keep a drifting analog
//! co-processor inside a production training loop the same way). The
//! static bank of the earlier engine revisions never exercised that
//! machinery: this module makes the device's physics a function of *device
//! time* and gives the runtime a scheduler that buys the calibration back.
//!
//! ## Device-time contract
//!
//! Drift advances in **ticks** of [`DRIFT_TICK_CYCLES`] optical cycles,
//! derived from the engine's telemetry cycle counter — never from
//! wall-clock time. Two consequences, both load-bearing:
//!
//! * runs are bit-reproducible: the same dispatch sequence produces the
//!   same tick sequence at any `--threads` value (per-dispatch cycle
//!   tallies are thread-invariant), and every per-tick increment is drawn
//!   from a counter-keyed stream ([`Pcg64::keyed`] over
//!   `(seed, tick, ring)`) — a pure function of the coordinates, not of
//!   how the run was scheduled or resumed;
//! * between ticks the device is frozen, so a serving process answers
//!   bit-identically within a calibration epoch, and an idle device does
//!   not age (only fired cycles advance its clock).
//!
//! Recalibration cycles are tallied separately ([`DriftModel::recal_cycles`])
//! and deliberately do **not** advance device time: charging them into the
//! drift clock would make each recalibration re-drift the bank it just
//! fixed, a runaway feedback with no physical counterpart (the lock loop
//! runs concurrently with compute on the real chip).
//!
//! ## Model
//!
//! Each ring accumulates an uncompensated phase error `δᵣ` relative to the
//! calibration it was last locked against:
//!
//! ```text
//!   δᵣ(t+1) = δᵣ(t) + rate · 𝒩(seed, t, r) + aging · dirᵣ
//! ```
//!
//! `rate` is a per-√tick random-walk amplitude (ambient thermal wander);
//! `aging` is a deterministic per-tick creep along a per-ring direction
//! `dirᵣ` redrawn each calibration epoch (LUT decay: the stored inverse
//! slowly walks away from the device). The weight-domain error estimate
//! the scheduler watches is `rms(δ) · slope`, with `slope` the
//! steep-flank weight-per-radian scale of the ring design
//! ([`weight_slope`]) — the same first-order sensitivity the §4 lock loop
//! observes on its monitor photodiode.
//!
//! When the estimate crosses the configured threshold the runtime re-runs
//! the §4 calibration protocol ([`super::calibration::CalibrationTable`]
//! sweep + a [`super::calibration::FeedbackController`] verification
//! lock), zeroes `δ`, and charges the protocol's readout cycles to the
//! recalibration tally so `pdfa report` prices the true lifetime cost.

use crate::util::rng::Pcg64;
use crate::{Error, Result};

use super::mrr::MrrDesign;

/// Optical cycles per device-time tick. Chosen so one training step on a
/// small bank advances device time by O(1) ticks: drift is slow against
/// the 10 GHz cycle clock (thermal τ ≈ 170 µs ≈ 1.7 M cycles), but a
/// coarser tick would quantise the fault schedules of the test harness.
pub const DRIFT_TICK_CYCLES: u64 = 1_000;

/// Domain separators: the thermal walk, the aging directions and the
/// recalibration protocol draw from disjoint keyed-stream families even
/// when `(tick, ring)` coordinates collide.
const DOMAIN_THERMAL: u64 = 0x7d1f_7e12_0d41_c3a7;
const DOMAIN_AGING: u64 = 0xa91e_55b6_21f0_9d04;
const DOMAIN_RECAL: u64 = 0x3ec4_1bb0_57ad_66e9;

/// Serialized drift-state header (versioned independently of the
/// checkpoint container so the engine blob can evolve on its own).
const STATE_MAGIC: [u8; 4] = *b"DRF1";

/// First-order weight-per-radian sensitivity of a ring design's locking
/// flank: the full weight swing (≈ 2) happens over about one FWHM of
/// detuning, so `2 / FWHM` is the scale that converts an uncompensated
/// phase error into the weight error the lock monitor would read.
pub fn weight_slope(design: &MrrDesign) -> f64 {
    2.0 / design.fwhm_phase()
}

/// One scripted fault of the injection harness (`tests/integration_drift.rs`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// A sudden uniform phase jump on every ring (e.g. a package
    /// temperature step): adds `phase` radians to all accumulated errors.
    StepDrift { phase: f64 },
    /// Ambient drift accelerates: adds `rate` to the per-√tick walk
    /// amplitude from the fault tick onward.
    RampDrift { rate: f64 },
    /// Ring `ring` dies with its weight stuck at `weight` — recalibration
    /// cannot recover it, so the scheduler excludes it from the error
    /// estimate (a dead ring must degrade accuracy, not trigger an
    /// endless recalibration loop).
    DeadRing { ring: usize, weight: f64 },
}

/// A fault scheduled at a device-time tick.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub at_tick: u64,
    pub kind: FaultKind,
}

/// Per-ring drift state + the online recalibration scheduler.
///
/// Owned by the photonic engine behind a mutex (one physical chip per
/// engine) and advanced by every artifact dispatch; see the module docs
/// for the device-time and determinism contracts. Fault schedules are
/// test-harness inputs and are *not* part of the serialized state — a
/// resumed run replays them from its own script, while the accumulated
/// consequences (phases, stuck rings, ramp rate) are restored exactly.
#[derive(Debug)]
pub struct DriftModel {
    rate: f64,
    aging: f64,
    threshold: f64,
    seed: u64,
    rings: usize,
    /// Weight-per-radian scale of the bank's ring design.
    slope: f64,
    /// Device time (ticks) the state below is valid at.
    tick: u64,
    /// Tick of the last (re)calibration: the epoch the aging directions
    /// are keyed by.
    cal_tick: u64,
    /// Accumulated uncompensated phase error per ring (radians).
    phases: Vec<f64>,
    /// Per-epoch aging direction per ring (refreshed on recalibration).
    aging_dir: Vec<f64>,
    /// Extra walk amplitude accumulated from `RampDrift` faults.
    extra_rate: f64,
    /// Dead rings: `(ring index, stuck weight)`.
    stuck: Vec<(usize, f64)>,
    /// Pending scripted faults, sorted by tick ascending.
    faults: Vec<FaultEvent>,
    /// Index of the next unapplied fault.
    next_fault: usize,
    /// Completed recalibrations.
    pub recal_events: u64,
    /// Readout cycles charged by those recalibrations (priced by the
    /// energy model next to the compute cycles, but kept out of the
    /// device-time clock — see the module docs).
    pub recal_cycles: u64,
}

impl DriftModel {
    /// Model for a `rows × cols` bank of `design`-shaped rings. `rate` is
    /// the thermal walk amplitude (radians/√tick), `aging` the epoch-keyed
    /// creep (radians/tick), `threshold` the weight-domain error estimate
    /// past which the scheduler fires (0 disables recalibration).
    pub fn new(
        rows: usize,
        cols: usize,
        rate: f64,
        aging: f64,
        threshold: f64,
        seed: u64,
        design: &MrrDesign,
    ) -> DriftModel {
        let rings = rows * cols;
        let mut m = DriftModel {
            rate,
            aging,
            threshold,
            seed,
            rings,
            slope: weight_slope(design),
            tick: 0,
            cal_tick: 0,
            phases: vec![0.0; rings],
            aging_dir: vec![0.0; rings],
            extra_rate: 0.0,
            stuck: Vec::new(),
            faults: Vec::new(),
            next_fault: 0,
            recal_events: 0,
            recal_cycles: 0,
        };
        m.refresh_aging_dirs();
        m
    }

    /// Whether any mechanism can change the device state over time. Used
    /// by the runtime to skip the per-tick work entirely for static
    /// configurations (the pre-lifetime engine behaviour, bit-exactly).
    pub fn is_active(&self) -> bool {
        self.rate > 0.0
            || self.aging > 0.0
            || self.extra_rate > 0.0
            || self.next_fault < self.faults.len()
            || self.phases.iter().any(|&p| p != 0.0)
            || !self.stuck.is_empty()
    }

    /// Device time in ticks.
    pub fn tick(&self) -> u64 {
        self.tick
    }

    /// Per-ring accumulated phase errors (radians), row-major.
    pub fn phases(&self) -> &[f64] {
        &self.phases
    }

    /// Dead rings as `(ring, stuck weight)` pairs.
    pub fn stuck(&self) -> &[(usize, f64)] {
        &self.stuck
    }

    /// Schedule scripted faults (test harness). Events may be passed in
    /// any order; events at or before the current tick apply on the next
    /// advance. `DeadRing` indices must address the bank.
    pub fn inject(&mut self, events: &[FaultEvent]) -> Result<()> {
        for ev in events {
            if let FaultKind::DeadRing { ring, .. } = ev.kind {
                if ring >= self.rings {
                    return Err(Error::Photonics(format!(
                        "fault injection: ring {ring} outside the {}-ring bank",
                        self.rings
                    )));
                }
            }
        }
        self.faults.truncate(self.next_fault);
        self.faults.extend_from_slice(events);
        self.faults[self.next_fault..].sort_by_key(|e| e.at_tick);
        Ok(())
    }

    fn refresh_aging_dirs(&mut self) {
        for (r, d) in self.aging_dir.iter_mut().enumerate() {
            *d = Pcg64::keyed(self.seed ^ DOMAIN_AGING, self.cal_tick, r as u64)
                .gaussian();
        }
    }

    fn apply_faults_through(&mut self, t: u64) {
        while self.next_fault < self.faults.len()
            && self.faults[self.next_fault].at_tick <= t
        {
            match self.faults[self.next_fault].kind {
                FaultKind::StepDrift { phase } => {
                    let p = if phase.is_finite() { phase } else { 0.0 };
                    for d in &mut self.phases {
                        *d += p;
                    }
                }
                FaultKind::RampDrift { rate } => {
                    if rate.is_finite() {
                        self.extra_rate += rate.max(0.0);
                    }
                }
                FaultKind::DeadRing { ring, weight } => {
                    let w = if weight.is_finite() { weight } else { 0.0 };
                    if let Some(s) = self.stuck.iter_mut().find(|s| s.0 == ring) {
                        s.1 = w;
                    } else {
                        self.stuck.push((ring, w));
                    }
                }
            }
            self.next_fault += 1;
        }
    }

    /// Advance device time to `tick` (monotone; earlier ticks are a
    /// no-op). Each elapsed tick applies its scheduled faults and one
    /// keyed walk/creep increment per ring. The result is a pure function
    /// of `(seed, fault schedule, cal_tick, tick)` — independent of how
    /// the interval was partitioned across calls, which is what makes
    /// resumed and differently-threaded runs bit-identical.
    pub fn advance_to(&mut self, tick: u64) {
        while self.tick < tick {
            let t = self.tick + 1;
            self.apply_faults_through(t);
            let walk = self.rate + self.extra_rate;
            if walk > 0.0 || self.aging > 0.0 {
                for (r, d) in self.phases.iter_mut().enumerate() {
                    if walk > 0.0 {
                        *d += walk
                            * Pcg64::keyed(self.seed ^ DOMAIN_THERMAL, t, r as u64)
                                .gaussian();
                    }
                    *d += self.aging * self.aging_dir[r];
                }
            }
            self.tick = t;
        }
    }

    /// Telemetry-facing weight-domain error estimate: `rms(δ) · slope`
    /// over the live (non-stuck) rings. Dead rings are excluded — no
    /// amount of recalibration recovers them, and counting them would
    /// latch the scheduler into a permanent recalibration loop.
    pub fn estimated_weight_error(&self) -> f64 {
        let mut sum = 0.0;
        let mut n = 0usize;
        for (r, &d) in self.phases.iter().enumerate() {
            if self.stuck.iter().any(|s| s.0 == r) {
                continue;
            }
            sum += d * d;
            n += 1;
        }
        if n == 0 {
            return 0.0;
        }
        (sum / n as f64).sqrt() * self.slope
    }

    /// Scheduler predicate: fire when a threshold is configured and the
    /// estimate has crossed it.
    pub fn should_recalibrate(&self) -> bool {
        self.threshold > 0.0 && self.estimated_weight_error() >= self.threshold
    }

    /// The keyed measurement stream for the next recalibration's §4
    /// protocol rerun: a pure function of `(seed, completed recals)`, so
    /// every bank replica and every resumption re-derives the same
    /// protocol trajectory.
    pub fn recal_rng(&self) -> Pcg64 {
        Pcg64::keyed(self.seed ^ DOMAIN_RECAL, self.recal_events, 0)
    }

    /// Book a completed recalibration: the accumulated compensable error
    /// is re-locked away, the aging directions re-key to the new epoch,
    /// and `cycles` readout cycles join the lifetime tally.
    pub fn complete_recalibration(&mut self, cycles: u64) {
        self.phases.fill(0.0);
        self.cal_tick = self.tick;
        self.recal_events += 1;
        self.recal_cycles = self.recal_cycles.saturating_add(cycles);
        self.refresh_aging_dirs();
    }

    /// Serialize the resumable state (everything except the scripted
    /// fault schedule — see the struct docs). Format: `DRF1`, then
    /// little-endian `tick, cal_tick, recal_events, recal_cycles: u64`,
    /// `extra_rate: f64`, `n_phases: u64` + phases, `n_stuck: u64` +
    /// `(ring: u64, weight: f64)` pairs.
    pub fn state_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(4 + 8 * (6 + self.phases.len()) + 16 * self.stuck.len());
        out.extend_from_slice(&STATE_MAGIC);
        for v in [self.tick, self.cal_tick, self.recal_events, self.recal_cycles] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.extend_from_slice(&self.extra_rate.to_le_bytes());
        out.extend_from_slice(&(self.phases.len() as u64).to_le_bytes());
        for p in &self.phases {
            out.extend_from_slice(&p.to_le_bytes());
        }
        out.extend_from_slice(&(self.stuck.len() as u64).to_le_bytes());
        for &(r, w) in &self.stuck {
            out.extend_from_slice(&(r as u64).to_le_bytes());
            out.extend_from_slice(&w.to_le_bytes());
        }
        out
    }

    /// Restore a [`Self::state_bytes`] blob into this model. The model
    /// must describe the same bank geometry the blob was taken from.
    pub fn restore_state(&mut self, bytes: &[u8]) -> Result<()> {
        let mut cur = StateCursor { bytes, pos: 0 };
        let magic = cur.take(4)?;
        if magic != STATE_MAGIC {
            return Err(Error::Format("drift state: bad magic".into()));
        }
        let tick = cur.u64()?;
        let cal_tick = cur.u64()?;
        let recal_events = cur.u64()?;
        let recal_cycles = cur.u64()?;
        let extra_rate = cur.f64()?;
        let n = cur.u64()? as usize;
        if n != self.phases.len() {
            return Err(Error::Format(format!(
                "drift state: {n} rings in blob, bank has {}",
                self.phases.len()
            )));
        }
        let mut phases = vec![0.0f64; n];
        for p in phases.iter_mut() {
            *p = cur.f64()?;
        }
        let n_stuck = cur.u64()? as usize;
        let mut stuck = Vec::with_capacity(n_stuck);
        for _ in 0..n_stuck {
            let r = cur.u64()? as usize;
            let w = cur.f64()?;
            if r >= self.rings {
                return Err(Error::Format(format!(
                    "drift state: stuck ring {r} outside the {}-ring bank",
                    self.rings
                )));
            }
            stuck.push((r, w));
        }
        if cur.pos != bytes.len() {
            return Err(Error::Format("drift state: trailing bytes".into()));
        }
        self.tick = tick;
        self.cal_tick = cal_tick;
        self.recal_events = recal_events;
        self.recal_cycles = recal_cycles;
        self.extra_rate = extra_rate;
        self.phases = phases;
        self.stuck = stuck;
        self.refresh_aging_dirs();
        Ok(())
    }
}

/// Bounds-checked little-endian reader for [`DriftModel::restore_state`].
struct StateCursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> StateCursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.bytes.len() {
            return Err(Error::Format("drift state: truncated".into()));
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().expect("8-byte slice")))
    }

    fn f64(&mut self) -> Result<f64> {
        let b = self.take(8)?;
        Ok(f64::from_le_bytes(b.try_into().expect("8-byte slice")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(rate: f64, aging: f64, threshold: f64) -> DriftModel {
        DriftModel::new(4, 3, rate, aging, threshold, 7, &MrrDesign::high_finesse())
    }

    #[test]
    fn advance_is_partition_invariant() {
        // one jump vs many small steps must land on identical state: the
        // increments are keyed by (tick, ring), not by call pattern
        let mut a = model(1e-3, 1e-5, 0.0);
        let mut b = model(1e-3, 1e-5, 0.0);
        a.advance_to(37);
        for t in 0..=37 {
            b.advance_to(t);
        }
        assert_eq!(a.phases(), b.phases());
        assert_eq!(a.tick(), b.tick());
        // earlier ticks are a no-op
        a.advance_to(10);
        assert_eq!(a.tick(), 37);
    }

    #[test]
    fn error_estimate_grows_and_recal_resets_it() {
        let mut m = model(1e-3, 0.0, 0.05);
        assert_eq!(m.estimated_weight_error(), 0.0);
        assert!(!m.should_recalibrate());
        m.advance_to(200);
        let e1 = m.estimated_weight_error();
        assert!(e1 > 0.0, "{e1}");
        m.advance_to(800);
        let e2 = m.estimated_weight_error();
        assert!(e2 > e1, "walk rms should grow: {e1} -> {e2}");
        assert!(m.should_recalibrate(), "estimate {e2} vs threshold 0.05");
        m.complete_recalibration(1234);
        assert_eq!(m.estimated_weight_error(), 0.0);
        assert_eq!(m.recal_events, 1);
        assert_eq!(m.recal_cycles, 1234);
        // the walk resumes from zero in a fresh epoch
        m.advance_to(900);
        assert!(m.estimated_weight_error() > 0.0);
        assert!(m.estimated_weight_error() < e2);
    }

    #[test]
    fn weight_slope_matches_flank_scale() {
        let d = MrrDesign::high_finesse();
        let s = weight_slope(&d);
        assert!((s - 2.0 / d.fwhm_phase()).abs() < 1e-12);
        // finesse ~368 -> FWHM ~0.017 rad -> slope in the ~100/rad decade
        assert!(s > 50.0 && s < 500.0, "{s}");
        // low-finesse rings are gentler
        assert!(weight_slope(&MrrDesign::default()) < s);
    }

    #[test]
    fn faults_apply_at_their_ticks() {
        let mut m = model(0.0, 0.0, 0.0);
        m.inject(&[
            FaultEvent { at_tick: 5, kind: FaultKind::StepDrift { phase: 0.01 } },
            FaultEvent { at_tick: 10, kind: FaultKind::DeadRing { ring: 2, weight: 0.4 } },
            FaultEvent { at_tick: 3, kind: FaultKind::RampDrift { rate: 1e-3 } },
        ])
        .unwrap();
        assert!(m.is_active(), "pending faults make the model active");
        m.advance_to(2);
        assert!(m.phases().iter().all(|&p| p == 0.0));
        m.advance_to(4); // ramp live at t=3, step not yet
        assert!(m.phases().iter().all(|&p| p.abs() < 0.009));
        m.advance_to(6);
        // every ring carries the 0.01 step plus the small ramp walk
        assert!(m.phases().iter().all(|&p| (p - 0.01).abs() < 0.01));
        assert!(m.stuck().is_empty());
        m.advance_to(10);
        assert_eq!(m.stuck(), &[(2, 0.4)]);
        // dead ring is excluded from the estimate
        let with_dead = m.estimated_weight_error();
        assert!(with_dead.is_finite());
        // out-of-range ring is rejected up front
        let err = m
            .inject(&[FaultEvent {
                at_tick: 99,
                kind: FaultKind::DeadRing { ring: 99, weight: 0.0 },
            }])
            .unwrap_err();
        assert!(err.to_string().contains("ring 99"), "{err}");
        // non-finite stuck weight sanitizes to a dark ring, not a NaN
        let mut m2 = model(0.0, 0.0, 0.0);
        m2.inject(&[FaultEvent {
            at_tick: 1,
            kind: FaultKind::DeadRing { ring: 0, weight: f64::NAN },
        }])
        .unwrap();
        m2.advance_to(1);
        assert_eq!(m2.stuck(), &[(0, 0.0)]);
    }

    #[test]
    fn inactive_model_is_free() {
        let mut m = model(0.0, 0.0, 0.05);
        assert!(!m.is_active());
        m.advance_to(1_000_000);
        assert_eq!(m.estimated_weight_error(), 0.0);
        assert!(!m.should_recalibrate());
    }

    #[test]
    fn state_round_trips_exactly() {
        let mut m = model(1e-3, 1e-5, 0.05);
        m.inject(&[
            FaultEvent { at_tick: 3, kind: FaultKind::RampDrift { rate: 5e-4 } },
            FaultEvent { at_tick: 8, kind: FaultKind::DeadRing { ring: 1, weight: -0.2 } },
        ])
        .unwrap();
        m.advance_to(50);
        m.complete_recalibration(777);
        m.advance_to(90);
        let blob = m.state_bytes();

        let mut fresh = model(1e-3, 1e-5, 0.05);
        fresh.restore_state(&blob).unwrap();
        assert_eq!(fresh.tick(), m.tick());
        assert_eq!(fresh.phases(), m.phases());
        assert_eq!(fresh.stuck(), m.stuck());
        assert_eq!(fresh.recal_events, m.recal_events);
        assert_eq!(fresh.recal_cycles, m.recal_cycles);
        // restored and original continue identically: same keyed streams
        let mut orig = m;
        orig.advance_to(120);
        fresh.advance_to(120);
        assert_eq!(fresh.phases(), orig.phases());

        // malformed blobs fail cleanly
        assert!(fresh.restore_state(&blob[..blob.len() - 1]).is_err());
        let mut trailing = blob.clone();
        trailing.push(0);
        assert!(fresh.restore_state(&trailing).is_err());
        let mut bad_magic = blob.clone();
        bad_magic[0] = b'X';
        assert!(fresh.restore_state(&bad_magic).is_err());
        // geometry mismatch is rejected
        let mut small =
            DriftModel::new(2, 2, 1e-3, 0.0, 0.0, 7, &MrrDesign::high_finesse());
        assert!(small.restore_state(&blob).is_err());
    }

    #[test]
    fn recal_rng_is_epoch_keyed() {
        let mut m = model(1e-3, 0.0, 0.01);
        let a1 = m.recal_rng().gaussian();
        let a2 = m.recal_rng().gaussian();
        assert_eq!(a1, a2, "same epoch, same protocol stream");
        m.complete_recalibration(1);
        let b = m.recal_rng().gaussian();
        assert_ne!(a1, b, "next epoch re-keys the protocol stream");
    }
}
