//! WDM laser source array and the optical power budget.
//!
//! N continuous-wave lasers (or comb lines) at distinct wavelengths are
//! multiplexed onto one waveguide bus (§3). Eq. (3) of the paper sets the
//! minimum per-laser power so each of the M fan-out copies still delivers
//! enough photons per symbol to beat both the shot-noise limit for N_b bits
//! and the photodetector's CV_d/e charging requirement:
//!
//! ```text
//!   P_laser ≥ M · (ħω/η) · f_s · max(2^(2·N_b + 1), C·V_d/e)
//! ```
//!
//! (The paper writes the per-symbol photon count; multiplying by the symbol
//! rate f_s gives power — confirmed by reproducing the paper's §5 wall-plug
//! totals, see energy::model tests.)

use super::constants::{self, E_CHARGE};

/// One WDM channel source.
#[derive(Debug, Clone, Copy)]
pub struct LaserChannel {
    pub wavelength_nm: f64,
    pub power_w: f64,
}

/// The N-channel WDM source feeding the weight bank.
#[derive(Debug, Clone)]
pub struct WdmSource {
    pub channels: Vec<LaserChannel>,
    /// Combined quantum efficiency η (laser + detector + waveguide loss).
    pub eta: f64,
}

impl WdmSource {
    /// Evenly spaced channels around 1550 nm, each at `power_w`.
    pub fn uniform(n: usize, power_w: f64) -> WdmSource {
        let spacing_nm = 0.8; // 100 GHz ITU grid
        let start = 1550.0 - spacing_nm * (n as f64 - 1.0) / 2.0;
        WdmSource {
            channels: (0..n)
                .map(|i| LaserChannel {
                    wavelength_nm: start + spacing_nm * i as f64,
                    power_w,
                })
                .collect(),
            eta: constants::ETA,
        }
    }

    /// The §4 testbed's four external-cavity lasers.
    pub fn testbed() -> WdmSource {
        WdmSource {
            channels: constants::TESTBED_WAVELENGTHS_NM
                .iter()
                .map(|&wavelength_nm| LaserChannel { wavelength_nm, power_w: 1e-3 })
                .collect(),
            eta: constants::ETA,
        }
    }

    pub fn n_channels(&self) -> usize {
        self.channels.len()
    }

    pub fn total_power_w(&self) -> f64 {
        self.channels.iter().map(|c| c.power_w).sum()
    }
}

/// Eq. (3): minimum per-laser optical power for a weight bank with M rows,
/// N_b bits of precision, at symbol rate `f_s`.
pub fn min_laser_power(m_rows: usize, n_bits: u32, f_s_hz: f64) -> f64 {
    let photons_shot = 2f64.powi(2 * n_bits as i32 + 1);
    let photons_cap = constants::PD_CAPACITANCE_F * constants::PD_DRIVE_V / E_CHARGE;
    let photons = photons_shot.max(photons_cap);
    m_rows as f64 * (constants::photon_energy() / constants::ETA) * f_s_hz * photons
}

/// Check the channel count fits a single waveguide bus at the given MRR
/// finesse (§3: finesse 368 supports up to 108 channels — i.e. a channel
/// needs ≈ finesse/108 ≈ 3.4 linewidths of spacing).
pub fn max_channels_for_finesse(finesse: f64) -> usize {
    let per_channel_linewidths =
        constants::MRR_FINESSE / constants::MAX_WDM_CHANNELS as f64;
    (finesse / per_channel_linewidths).floor() as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq3_headline_value() {
        // §5 bank: M = 50, 6 bits, 10 GHz. CV_d/e ≈ 15k photons dominates;
        // P ≥ 50 · (1.28e-19/0.2) · 1e10 · 1.5e4 ≈ 4.8 mW per laser.
        let p = min_laser_power(50, 6, 10e9);
        assert!(p > 4.0e-3 && p < 5.5e-3, "P_laser = {p}");
    }

    #[test]
    fn shot_limit_takes_over_at_high_precision() {
        // at 8 bits, 2^17 = 131k photons > CV/e = 15k
        let p6 = min_laser_power(50, 6, 10e9);
        let p8 = min_laser_power(50, 8, 10e9);
        assert!(p8 / p6 > 5.0, "shot-noise term should dominate: {p6} {p8}");
    }

    #[test]
    fn power_scales_linearly_with_fanout_and_rate() {
        let base = min_laser_power(10, 6, 1e9);
        assert!((min_laser_power(20, 6, 1e9) / base - 2.0).abs() < 1e-9);
        assert!((min_laser_power(10, 6, 2e9) / base - 2.0).abs() < 1e-9);
    }

    #[test]
    fn uniform_grid_spacing() {
        let src = WdmSource::uniform(20, 1e-3);
        assert_eq!(src.n_channels(), 20);
        let d = src.channels[1].wavelength_nm - src.channels[0].wavelength_nm;
        assert!((d - 0.8).abs() < 1e-9);
        assert!((src.total_power_w() - 20e-3).abs() < 1e-12);
        // centred on 1550
        let mid = (src.channels[0].wavelength_nm
            + src.channels[19].wavelength_nm) / 2.0;
        assert!((mid - 1550.0).abs() < 1e-9);
    }

    #[test]
    fn testbed_has_four_lasers() {
        let t = WdmSource::testbed();
        assert_eq!(t.n_channels(), 4);
        assert!((t.channels[0].wavelength_nm - 1546.558).abs() < 1e-9);
    }

    #[test]
    fn finesse_368_supports_108_channels() {
        assert_eq!(max_channels_for_finesse(368.0), 108);
        // lower finesse, fewer channels
        assert!(max_channels_for_finesse(60.0) < 20);
    }
}
