//! Device-level silicon-photonics substrate.
//!
//! The paper's testbed is a fabricated SOI photonic integrated circuit; this
//! module is its simulated equivalent (simulated substitutions for the paper's hardware), built
//! bottom-up from the component physics so every experiment in §2/§4 runs
//! against the same code path the real chip would exercise:
//!
//! * [`constants`]  — physical constants and the paper's component values
//! * [`mrr`]        — add-drop micro-ring resonator transmission physics
//! * [`heater`]     — thermal (photoconductive-heater) and carrier-depletion
//!   tuning actuators with first-order dynamics
//! * [`calibration`]— feed-forward current→weight LUT + feedback locking
//! * [`bpd`]        — balanced photodetector with shot/Johnson noise and the
//!   mis-biased on-chip mode of §4
//! * [`tia`]        — transimpedance amplifier with tunable gain (Hadamard)
//! * [`converters`] — DAC/ADC quantisation and rate limits
//! * [`laser`]      — WDM source array and the Eq. (3) power floor
//! * [`crosstalk`]  — inter-channel crosstalk from MRR finesse/spacing
//! * [`weight_bank`]— the full M×N photonic weight bank (Figs. 3(d), 4(b))
//! * [`noise`]      — shared noise-source model
//! * [`drift`]      — device-lifetime physics: thermal drift, calibration
//!   aging, fault injection and the online recalibration scheduler

pub mod bpd;
pub mod calibration;
pub mod constants;
pub mod converters;
pub mod crosstalk;
pub mod drift;
pub mod heater;
pub mod laser;
pub mod mrr;
pub mod noise;
pub mod tia;
pub mod weight_bank;

pub use weight_bank::{BankConfig, BpdMode, WeightBank};
