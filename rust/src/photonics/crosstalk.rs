//! Inter-channel crosstalk in the WDM weight bank.
//!
//! Each MRR is tuned to weight one wavelength, but its Lorentzian response
//! has finite width: neighbouring channels see a residual response. The §4
//! experiment notes the measurements "accurately account for ... crosstalk
//! between neighbouring MRRs"; here the effect is modeled from the add-drop
//! physics of the shared-bus row:
//!
//! Channel j propagates the through bus past every ring i in series; ring i
//! diverts T_d(φ_ij) of it onto the drop bus and passes T_p(φ_ij). To first
//! order (small off-resonant diversion) the channel's effective weight is
//!
//! ```text
//!   w_eff_j = Σ_i T_d(φ_ij)  −  Π_i T_p(φ_ij)
//! ```
//!
//! which reduces to the ideal w_j = T_d − T_p for an isolated ring and
//! penalises crowded channel grids exactly the way the hardware does.

use super::mrr::MrrDesign;

/// Crosstalk model for one weight-bank row of N MRRs on a shared bus.
#[derive(Debug, Clone)]
pub struct CrosstalkModel {
    /// Channel spacing measured in MRR FWHM linewidths (≥ ~3 for ≲1%
    /// crosstalk; the paper's 108-channel design uses finesse/108 ≈ 3.4).
    pub spacing_linewidths: f64,
    pub design: MrrDesign,
}

impl CrosstalkModel {
    pub fn new(design: MrrDesign, spacing_linewidths: f64) -> CrosstalkModel {
        CrosstalkModel { spacing_linewidths, design }
    }

    /// Phase offset of channel j as seen by the MRR tuned for channel i.
    fn channel_offset(&self, i: usize, j: usize) -> f64 {
        let fwhm = self.design.fwhm_phase();
        (j as f64 - i as f64) * self.spacing_linewidths * fwhm
    }

    /// Effective per-channel weights of a row inscribed with `weights`
    /// (each ring tuned so that its *own* channel sees the target weight).
    pub fn effective_weights(&self, weights: &[f32]) -> Vec<f64> {
        let mut phis = Vec::new();
        let mut out = vec![0.0f64; weights.len()];
        self.effective_weights_into(weights, &mut phis, &mut out);
        out
    }

    /// [`Self::effective_weights`] without the per-call allocations: the
    /// caller owns both the detuning-phase scratch (`phis`, cleared and
    /// refilled, capacity reused) and the output slice (length exactly
    /// `weights.len()`). This is the form [`super::weight_bank::WeightBank`]
    /// drives once per row on every re-inscription — the hottest
    /// crosstalk path — so steady-state inscriptions stay heap-free.
    // lint: hot-path
    pub fn effective_weights_into(
        &self,
        weights: &[f32],
        phis: &mut Vec<f64>,
        out: &mut [f64],
    ) {
        debug_assert_eq!(weights.len(), out.len());
        phis.clear();
        phis.extend(
            weights
                .iter()
                .map(|&w| self.design.detuning_for_weight(w as f64)),
        );
        for (j, o) in out.iter_mut().enumerate() {
            let mut drop_sum = 0.0;
            let mut thru_prod = 1.0;
            for (i, &phi_i) in phis.iter().enumerate() {
                let phi_ij = phi_i + self.channel_offset(i, j);
                drop_sum += self.design.drop(phi_ij);
                thru_prod *= self.design.through(phi_ij);
            }
            *o = drop_sum - thru_prod;
        }
    }

    /// Power fraction a resonance-parked ring steals from the adjacent
    /// channel — the headline leakage figure of merit.
    pub fn neighbour_leakage(&self) -> f64 {
        self.design.drop(self.channel_offset(0, 1))
    }

    /// Row inner product including crosstalk: Σ_j x_j · w_eff_j.
    pub fn perturbed_inner_product(&self, weights: &[f32], x: &[f32]) -> f64 {
        self.effective_weights(weights)
            .iter()
            .zip(x)
            .map(|(&w, &xi)| w * xi as f64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(spacing: f64) -> CrosstalkModel {
        CrosstalkModel::new(MrrDesign::default(), spacing)
    }

    #[test]
    fn isolated_ring_recovers_intended_weight() {
        let m = model(3.4);
        for w in [-0.9f32, -0.3, 0.0, 0.5, 0.95] {
            let eff = m.effective_weights(&[w]);
            assert!((eff[0] - w as f64).abs() < 1e-6, "w={w} eff={}", eff[0]);
        }
    }

    #[test]
    fn diagonal_dominates_at_design_spacing() {
        let m = model(3.4);
        let ws = [0.7f32, -0.3, 0.1, 0.9];
        let eff = m.effective_weights(&ws);
        for (i, &w) in ws.iter().enumerate() {
            assert!(
                (eff[i] - w as f64).abs() < 0.12,
                "channel {i}: want {w} eff {}",
                eff[i]
            );
        }
    }

    #[test]
    fn leakage_falls_with_spacing() {
        let close = model(1.0).neighbour_leakage();
        let wide = model(6.0).neighbour_leakage();
        assert!(close > 5.0 * wide, "close {close} wide {wide}");
        // paper-like spacing (~3.4 linewidths): leakage well under 5%
        assert!(model(3.4).neighbour_leakage() < 0.05);
    }

    #[test]
    fn single_ring_has_no_crosstalk() {
        let m = model(3.4);
        let got = m.perturbed_inner_product(&[0.5], &[0.8]);
        assert!((got - 0.4).abs() < 1e-6);
    }

    #[test]
    fn perturbation_is_small_at_design_spacing() {
        let m = model(3.4);
        let ws = [0.8f32, -0.6, 0.4, -0.2];
        let xs = [0.9f32, 0.5, 0.7, 0.3];
        let ideal: f64 = ws.iter().zip(&xs).map(|(&w, &x)| (w * x) as f64).sum();
        let got = m.perturbed_inner_product(&ws, &xs);
        assert!((got - ideal).abs() < 0.25, "ideal {ideal} got {got}");
        // and grows when channels crowd together
        let crowded = model(0.8).perturbed_inner_product(&ws, &xs);
        assert!(
            (crowded - ideal).abs() > (got - ideal).abs(),
            "crowding should hurt: {crowded} vs {got} (ideal {ideal})"
        );
    }
}
