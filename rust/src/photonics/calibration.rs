//! MRR calibration: feed-forward LUT + feedback locking.
//!
//! Fabrication variation makes every ring's drive→weight transfer unique
//! (§2: "the relationship between the applied MRR bias and the change in
//! weighting value ... must be determined experimentally"). The control
//! system therefore:
//!
//! 1. **Feed-forward calibration** — sweeps each MRR's drive, measures the
//!    resulting weight through the (noisy) readout chain, and stores a
//!    monotone LUT whose inverse maps target weight → drive.
//! 2. **Feedback locking** — at run time, iteratively corrects the drive
//!    against measured error to cancel drift and LUT interpolation error
//!    (refs 34–36).

use super::heater::Actuator;
use super::mrr::Mrr;
use crate::util::rng::Pcg64;
use crate::{Error, Result};

/// Measured (drive, weight) sweep of one ring, with inverse interpolation.
#[derive(Debug, Clone)]
pub struct CalibrationTable {
    /// Sorted by weight ascending: (drive, weight) samples.
    points: Vec<(f64, f64)>,
}

impl CalibrationTable {
    /// Sweep `n_points` drives across the actuator range, measuring the
    /// inscribed weight through a readout with Gaussian error `readout_std`.
    /// Repeats each measurement `avg` times (the §4 protocol measured each
    /// point three times and averaged).
    pub fn calibrate(
        mrr: &Mrr,
        actuator: &Actuator,
        n_points: usize,
        readout_std: f64,
        avg: usize,
        rng: &mut Pcg64,
    ) -> Result<CalibrationTable> {
        if n_points < 8 {
            return Err(Error::Calibration("need >= 8 sweep points".into()));
        }
        let navg = avg.max(1);
        let measure = |phase: f64, rng: &mut Pcg64| -> f64 {
            let mut m = 0.0;
            for _ in 0..navg {
                m += mrr.weight_at(phase) + rng.normal(0.0, readout_std);
            }
            m / navg as f64
        };

        // Pass 1 — coarse phase-uniform sweep over the full actuator range
        // to LOCATE the resonance. The weight-vs-phase curve peaks at the
        // ring's (unknown) fabrication offset and is monotone decreasing
        // over the following half-period; only that branch gives an
        // unambiguous weight -> drive inverse.
        let max_phase = actuator.steady_state_phase(1.0);
        let coarse: Vec<(f64, f64)> = (0..n_points)
            .map(|i| {
                let phase = max_phase * i as f64 / (n_points - 1) as f64;
                (phase, measure(phase, rng))
            })
            .collect();
        let i_peak = coarse
            .iter()
            .enumerate()
            .max_by(|a, b| a.1 .1.partial_cmp(&b.1 .1).unwrap())
            .map(|(i, _)| i)
            .unwrap();

        // High-finesse rings have resonance peaks *narrower than the coarse
        // spacing*: refine the peak location by ternary search around the
        // argmax sample, or the top of the weight range is unreachable.
        let step = max_phase / (n_points - 1) as f64;
        let (mut lo_p, mut hi_p) = (
            (coarse[i_peak].0 - step).max(0.0),
            (coarse[i_peak].0 + step).min(max_phase),
        );
        for _ in 0..48 {
            let m1 = lo_p + (hi_p - lo_p) / 3.0;
            let m2 = hi_p - (hi_p - lo_p) / 3.0;
            if measure(m1, rng) < measure(m2, rng) {
                lo_p = m1;
            } else {
                hi_p = m2;
            }
        }
        let phi_pk = 0.5 * (lo_p + hi_p);
        let peak_pt = (phi_pk, measure(phi_pk, rng));

        // The ring resonates twice per 2π of actuator phase (once at the
        // fabrication offset, once a full FSR later); the argmax may land on
        // either. Take the monotone-descending branch on whichever side of
        // the refined peak is longer.
        let right: Vec<(f64, f64)> = {
            let rest: Vec<(f64, f64)> = std::iter::once(peak_pt)
                .chain(coarse.iter().filter(|p| p.0 > phi_pk).cloned())
                .collect();
            let i_min = rest
                .iter()
                .enumerate()
                .min_by(|a, b| a.1 .1.partial_cmp(&b.1 .1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0);
            rest[..=i_min].to_vec()
        };
        let left: Vec<(f64, f64)> = {
            let rest: Vec<(f64, f64)> = coarse
                .iter()
                .filter(|p| p.0 < phi_pk)
                .cloned()
                .chain(std::iter::once(peak_pt))
                .collect();
            let i_min = rest
                .iter()
                .enumerate()
                .min_by(|a, b| a.1 .1.partial_cmp(&b.1 .1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(rest.len().saturating_sub(1));
            // reversed: peak first, descending toward the minimum
            rest[i_min..].iter().rev().cloned().collect()
        };

        // Pass 2 — adaptive refinement of the branch: the Lorentzian flank
        // compresses most of the weight range into a narrow phase window,
        // so insert midpoints wherever adjacent samples jump in weight.
        let mut branch: Vec<(f64, f64)> =
            if right.len() >= left.len() { right } else { left };
        if branch.len() < 2 {
            return Err(Error::Calibration(
                "could not isolate a monotone resonance branch".into(),
            ));
        }
        let w_span = (branch[0].1 - branch[branch.len() - 1].1).abs().max(1e-6);
        let max_gap = 2.0 * w_span / n_points as f64;
        let budget = 4 * n_points;
        let mut i = 0;
        while i + 1 < branch.len() && branch.len() < budget {
            let (p0, w0) = branch[i];
            let (p1, w1) = branch[i + 1];
            if (w1 - w0).abs() > max_gap && (p1 - p0).abs() > 1e-6 {
                let mid = 0.5 * (p0 + p1);
                branch.insert(i + 1, (mid, measure(mid, rng)));
            } else {
                i += 1;
            }
        }

        // Store as (drive, weight) sorted ascending by weight, dropping
        // noise-induced order inversions (isotonic cleanup).
        let mut points: Vec<(f64, f64)> = branch
            .into_iter()
            .map(|(phase, w)| (actuator.drive_for_phase(phase), w))
            .collect();
        points.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        let mut clean: Vec<(f64, f64)> = Vec::with_capacity(points.len());
        for p in points {
            if let Some(last) = clean.last() {
                if p.1 - last.1 < 1e-9 {
                    continue;
                }
            }
            clean.push(p);
        }
        if clean.len() < 2 {
            return Err(Error::Calibration(
                "sweep collapsed: readout noise exceeds weight range".into(),
            ));
        }
        Ok(CalibrationTable { points: clean })
    }

    /// Feed-forward inverse: drive estimated to inscribe `w` (linear
    /// interpolation between the bracketing sweep points).
    pub fn drive_for_weight(&self, w: f64) -> f64 {
        let pts = &self.points;
        if w <= pts[0].1 {
            return pts[0].0;
        }
        if w >= pts[pts.len() - 1].1 {
            return pts[pts.len() - 1].0;
        }
        // binary search on weight
        let mut lo = 0;
        let mut hi = pts.len() - 1;
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            if pts[mid].1 <= w {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let (d0, w0) = pts[lo];
        let (d1, w1) = pts[hi];
        d0 + (w - w0) / (w1 - w0) * (d1 - d0)
    }

    /// Achievable weight range recorded during the sweep.
    pub fn weight_range(&self) -> (f64, f64) {
        (self.points[0].1, self.points[self.points.len() - 1].1)
    }

    pub fn n_points(&self) -> usize {
        self.points.len()
    }
}

/// Analytic readout budget of one [`CalibrationTable::calibrate`] run:
/// the coarse locate sweep, the two-probe ternary peak refinement, the
/// refined-peak confirmation and the adaptive midpoint budget (capped at
/// `4 · n_points` branch samples, i.e. up to `3 · n_points` insertions),
/// each measured `avg` times. The runtime charges this per ring when the
/// recalibration scheduler re-runs the §4 protocol, so the lifetime
/// energy roll-up prices calibration readouts next to compute cycles.
pub fn sweep_cost(n_points: usize, avg: usize) -> u64 {
    let avg = avg.max(1) as u64;
    let n = n_points as u64;
    (n + 2 * 48 + 1 + 3 * n) * avg
}

/// Outcome of one feedback-lock session.
#[derive(Debug, Clone, Copy)]
pub struct LockResult {
    pub drive: f64,
    pub achieved_weight: f64,
    pub iterations: usize,
    pub converged: bool,
}

/// Feedback controller correcting the drive against measured weight error.
///
/// Works in the *weight* domain through the calibration LUT (an integral
/// controller on the LUT's setpoint): robust on the steep Lorentzian flank
/// where drive-domain proportional steps either stall or overshoot.
#[derive(Debug, Clone, Copy)]
pub struct FeedbackController {
    /// Integral gain on the weight-domain setpoint correction.
    pub gain: f64,
    pub max_iters: usize,
    /// Stop when |error| falls below this.
    pub tolerance: f64,
}

impl Default for FeedbackController {
    fn default() -> Self {
        FeedbackController { gain: 0.7, max_iters: 64, tolerance: 2e-3 }
    }
}

impl FeedbackController {
    /// Lock `mrr` onto `target_w`, starting from the LUT's feed-forward
    /// estimate, measuring through a readout with error `readout_std`.
    pub fn lock(
        &self,
        mrr: &Mrr,
        actuator: &Actuator,
        table: &CalibrationTable,
        target_w: f64,
        readout_std: f64,
        rng: &mut Pcg64,
    ) -> LockResult {
        self.lock_traced(mrr, actuator, table, target_w, readout_std, rng, None)
    }

    /// [`Self::lock`] recording the per-iteration *true* weight error into
    /// `trace` (monitor-photodiode view, before readout noise). The
    /// property suite uses it to pin the controller's contraction: under
    /// zero readout noise the error strictly decreases each iteration.
    #[allow(clippy::too_many_arguments)]
    pub fn lock_traced(
        &self,
        mrr: &Mrr,
        actuator: &Actuator,
        table: &CalibrationTable,
        target_w: f64,
        readout_std: f64,
        rng: &mut Pcg64,
        mut trace: Option<&mut Vec<f64>>,
    ) -> LockResult {
        let (w_lo, w_hi) = table.weight_range();
        let target = target_w.clamp(w_lo, w_hi);
        let mut bias = 0.0; // accumulated setpoint correction (weight units)
        let mut drive = table.drive_for_weight(target);
        let mut best = (f64::INFINITY, drive);
        for it in 0..self.max_iters {
            let phase = actuator.steady_state_phase(drive.clamp(0.0, 1.0));
            let meas = mrr.weight_at(phase) + rng.normal(0.0, readout_std);
            let err = target - meas;
            let true_err = (mrr.weight_at(phase) - target).abs();
            if let Some(t) = trace.as_deref_mut() {
                t.push(true_err);
            }
            if true_err < best.0 {
                best = (true_err, drive);
            }
            if err.abs() < self.tolerance {
                return LockResult {
                    drive,
                    achieved_weight: mrr.weight_at(phase),
                    iterations: it + 1,
                    converged: true,
                };
            }
            bias += self.gain * err;
            drive = table.drive_for_weight((target + bias).clamp(w_lo, w_hi));
        }
        // did not hit tolerance (e.g. readout noise floor): use best visited
        let phase = actuator.steady_state_phase(best.1.clamp(0.0, 1.0));
        LockResult {
            drive: best.1,
            achieved_weight: mrr.weight_at(phase),
            iterations: self.max_iters,
            converged: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::photonics::mrr::MrrDesign;
    use crate::util::check::check;

    fn test_ring(rng: &mut Pcg64) -> (Mrr, Actuator) {
        let fab = rng.uniform_in(0.0, 1.5);
        (Mrr::new(MrrDesign::default(), fab), Actuator::thermal())
    }

    #[test]
    fn clean_calibration_inverts_accurately() {
        check("calibration-inverts", 20, |rng| {
            let (mrr, act) = test_ring(rng);
            let table =
                CalibrationTable::calibrate(&mrr, &act, 512, 0.0, 1, rng).unwrap();
            let (w_lo, w_hi) = table.weight_range();
            for _ in 0..10 {
                let w = rng.uniform_in(w_lo + 0.02, w_hi - 0.02);
                let drive = table.drive_for_weight(w);
                let got = mrr.weight_at(act.steady_state_phase(drive));
                if (got - w).abs() > 0.02 {
                    return Err(format!("w={w} got={got}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn noisy_calibration_still_usable() {
        let mut rng = Pcg64::seed(11);
        let (mrr, act) = test_ring(&mut rng);
        let table =
            CalibrationTable::calibrate(&mrr, &act, 256, 0.02, 3, &mut rng).unwrap();
        let drive = table.drive_for_weight(0.5);
        let got = mrr.weight_at(act.steady_state_phase(drive));
        assert!((got - 0.5).abs() < 0.1, "got {got}");
    }

    #[test]
    fn feedback_beats_feedforward_under_noise() {
        let mut rng = Pcg64::seed(12);
        let mut ff_err = 0.0;
        let mut fb_err = 0.0;
        let n = 30;
        for _ in 0..n {
            let (mrr, act) = test_ring(&mut rng);
            let table =
                CalibrationTable::calibrate(&mrr, &act, 64, 0.03, 3, &mut rng).unwrap();
            let target = rng.uniform_in(-0.7, 0.9);
            let ff_drive = table.drive_for_weight(target);
            let ff_w = mrr.weight_at(act.steady_state_phase(ff_drive));
            ff_err += (ff_w - target).abs();
            let lock = FeedbackController::default().lock(
                &mrr, &act, &table, target, 0.002, &mut rng,
            );
            fb_err += (lock.achieved_weight - target).abs();
        }
        assert!(
            fb_err < ff_err * 0.5,
            "feedback {fb_err:.4} should beat feedforward {ff_err:.4}"
        );
    }

    #[test]
    fn lock_converges_and_reports() {
        let mut rng = Pcg64::seed(13);
        let (mrr, act) = test_ring(&mut rng);
        let table =
            CalibrationTable::calibrate(&mrr, &act, 256, 0.0, 1, &mut rng).unwrap();
        let lock = FeedbackController::default().lock(
            &mrr, &act, &table, 0.3, 0.0005, &mut rng,
        );
        assert!(lock.converged, "{lock:?}");
        assert!((lock.achieved_weight - 0.3).abs() < 5e-3);
        assert!(lock.iterations <= 64);
    }

    #[test]
    fn drive_for_weight_inverse_is_monotone_and_round_trips() {
        // device-lifetime property: across randomized fabrication
        // offsets, the LUT inverse is monotone in the target weight (the
        // branch isolation worked) and round-trips through the physical
        // weight_at within tolerance
        check("calibration-monotone-inverse", 25, |rng| {
            let (mrr, act) = test_ring(rng);
            let table =
                CalibrationTable::calibrate(&mrr, &act, 256, 0.0, 1, rng).unwrap();
            let (w_lo, w_hi) = table.weight_range();
            let mut prev_drive = f64::NAN;
            let mut dir = 0.0f64;
            for i in 0..=40 {
                let w = w_lo + 0.02 + (w_hi - w_lo - 0.04) * i as f64 / 40.0;
                let drive = table.drive_for_weight(w);
                let got = mrr.weight_at(act.steady_state_phase(drive));
                if (got - w).abs() > 0.02 {
                    return Err(format!("round trip w={w} got={got}"));
                }
                if prev_drive.is_finite() {
                    let step = drive - prev_drive;
                    if dir == 0.0 {
                        dir = step.signum();
                    } else if step * dir < -1e-12 {
                        return Err(format!(
                            "inverse not monotone at w={w}: drive {prev_drive} -> {drive}"
                        ));
                    }
                }
                prev_drive = drive;
            }
            Ok(())
        });
    }

    #[test]
    fn lock_error_strictly_decreases_without_readout_noise() {
        // the controller contraction the recalibration scheduler leans
        // on: with a noiseless monitor, every iteration strictly reduces
        // the true weight error until it reaches the tolerance floor
        check("lock-strict-contraction", 20, |rng| {
            let (mrr, act) = test_ring(rng);
            let table =
                CalibrationTable::calibrate(&mrr, &act, 512, 0.0, 1, rng).unwrap();
            let (w_lo, w_hi) = table.weight_range();
            let target = rng.uniform_in(w_lo + 0.05, w_hi - 0.05);
            let fb = FeedbackController { gain: 0.7, max_iters: 32, tolerance: 1e-6 };
            let mut trace = Vec::new();
            let lock =
                fb.lock_traced(&mrr, &act, &table, target, 0.0, rng, Some(&mut trace));
            if trace.is_empty() {
                return Err("no iterations traced".into());
            }
            for w in trace.windows(2) {
                // strict decrease down to well below the default 2e-3
                // tolerance; beneath that the LUT interpolation floor may
                // plateau and the controller is allowed to stop improving
                if w[0] > 5e-4 && w[1] >= w[0] {
                    return Err(format!(
                        "error did not decrease: {} -> {} (target {target})",
                        w[0], w[1]
                    ));
                }
            }
            if (lock.achieved_weight - target).abs() > 2e-3 {
                return Err(format!(
                    "noiseless lock missed: {} vs {target}",
                    lock.achieved_weight
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn sweep_cost_is_the_documented_budget() {
        // 256-pt, 3-avg protocol (the §4 run the scheduler replays):
        // (256 coarse + 96 ternary + 1 confirm + 768 midpoints) × 3
        assert_eq!(sweep_cost(256, 3), (256 + 96 + 1 + 768) * 3);
        assert_eq!(sweep_cost(8, 0), 8 + 96 + 1 + 24); // avg clamps to 1
        assert!(sweep_cost(512, 3) > sweep_cost(256, 3));
    }

    #[test]
    fn degenerate_inputs_error() {
        let mut rng = Pcg64::seed(14);
        let (mrr, act) = test_ring(&mut rng);
        assert!(CalibrationTable::calibrate(&mrr, &act, 1, 0.0, 1, &mut rng).is_err());
        // absurd readout noise: sweep collapses to nothing monotone...
        // (with enough noise all points may still survive sorting, so just
        // check the API surfaces errors rather than panicking)
        let r = CalibrationTable::calibrate(&mrr, &act, 4, 100.0, 1, &mut rng);
        if let Ok(t) = r {
            assert!(t.n_points() >= 2);
        }
    }

    #[test]
    fn out_of_range_targets_clamp() {
        let mut rng = Pcg64::seed(15);
        let (mrr, act) = test_ring(&mut rng);
        let table =
            CalibrationTable::calibrate(&mrr, &act, 128, 0.0, 1, &mut rng).unwrap();
        let (w_lo, w_hi) = table.weight_range();
        let lock = FeedbackController::default().lock(
            &mrr, &act, &table, 5.0, 0.0, &mut rng,
        );
        assert!(lock.achieved_weight <= w_hi + 1e-6);
        let lock = FeedbackController::default().lock(
            &mrr, &act, &table, -5.0, 0.0, &mut rng,
        );
        assert!(lock.achieved_weight >= w_lo - 1e-6);
    }
}
