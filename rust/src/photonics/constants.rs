//! Physical constants and the paper's component parameter values.
//!
//! Every number cited in §2, §4 and §5 of the paper lives here with its
//! provenance, so the energy model (energy::model) and the device simulator
//! share one source of truth.

/// Planck constant (J·s).
pub const H_PLANCK: f64 = 6.626_070_15e-34;
/// Speed of light (m/s).
pub const C_LIGHT: f64 = 2.997_924_58e8;
/// Elementary charge (C).
pub const E_CHARGE: f64 = 1.602_176_634e-19;
/// Boltzmann constant (J/K).
pub const K_BOLTZMANN: f64 = 1.380_649e-23;

/// Operating wavelength (§5): 1550 nm.
pub const WAVELENGTH_M: f64 = 1550e-9;

/// Photon energy at 1550 nm (J): ħω = h·c/λ ≈ 1.28e-19 J.
pub fn photon_energy() -> f64 {
    H_PLANCK * C_LIGHT / WAVELENGTH_M
}

/// Combined quantum efficiency η of laser + detector + waveguide loss (§5).
pub const ETA: f64 = 0.2;

/// Photodetector capacitance (§5, ref 44): 2.4 fF.
pub const PD_CAPACITANCE_F: f64 = 2.4e-15;
/// Photodetector driving voltage (§5): 1 V.
pub const PD_DRIVE_V: f64 = 1.0;

/// Maximum operational rate (§5): 10 GHz, limited by the DAC throughput.
pub const F_S_HZ: f64 = 10e9;
/// ADC/operational fixed precision assumed in Fig. 6 (§5): 6 bits.
pub const N_BITS: u32 = 6;

/// DAC power (§5): 180 mW (12-bit, 10 GS/s, Alphacore D12B10G).
pub const P_DAC_W: f64 = 0.180;
/// ADC power (§5): 13 mW (6-bit, 12 GS/s, Alphacore A6B12G).
pub const P_ADC_W: f64 = 0.013;
/// TIA energy (§5, ref 61): 2.4 pJ/bit at 20 GS/s.
pub const TIA_PJ_PER_BIT: f64 = 2.4e-12;

/// MRR thermal-lock heater power (§5): ~14.12 mW per MRR.
pub const P_MRR_HEATER_W: f64 = 14.12e-3;
/// MRR carrier-depletion tuning power (§5): ~120 µW per MRR
/// (also the residual per-MRR power after post-fabrication trimming).
pub const P_MRR_TRIMMED_W: f64 = 120e-6;

/// Photonic MAC cell footprint (§5): 47.4 µm x 73.0 µm.
pub const MAC_CELL_AREA_M2: f64 = 47.4e-6 * 73.0e-6;

/// Thermally-tuned MRR response time (§5, ref 30): 170 µs — the reason the
/// *experimental* testbed runs at ~2.0 µJ/MAC while the projected system
/// uses carrier-depletion tuning at GHz rates.
pub const THERMAL_TAU_S: f64 = 170e-6;

/// Paper's headline weight-bank geometry (§5): M = 50 rows, N = 20 channels.
pub const BANK_ROWS: usize = 50;
pub const BANK_COLS: usize = 20;

/// MRR finesse of the optimised design supporting 108 WDM channels (§3).
pub const MRR_FINESSE: f64 = 368.0;
/// Maximum WDM channels a single waveguide supports at that finesse (§3).
pub const MAX_WDM_CHANNELS: usize = 108;

/// Experimental laser wavelengths of the §4 testbed (nm).
pub const TESTBED_WAVELENGTHS_NM: [f64; 4] = [1546.558, 1548.675, 1549.595, 1551.480];

/// Measured inner-product error std of the §4 testbed circuits,
/// scaled to the normalised [-1, 1] output range.
pub const SIGMA_SINGLE_MRR: f64 = 0.019; // Fig. 3(c), 6.72 bits
pub const SIGMA_OFFCHIP_BPD: f64 = 0.098; // Fig. 5(a), 4.35 bits
pub const SIGMA_ONCHIP_BPD: f64 = 0.202; // Fig. 5(a), 3.31 bits

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn photon_energy_at_1550nm() {
        let e = photon_energy();
        assert!((e - 1.28e-19).abs() < 0.01e-19, "{e}");
    }

    #[test]
    fn shot_vs_capacitance_floor() {
        // §5: with Nb = 6, C = 2.4 fF, Vd = 1 V the capacitance term
        // C·Vd/e = 15k photons dominates the shot-noise term 2^(2·6+1) = 8192.
        let shot = 2f64.powi(2 * N_BITS as i32 + 1);
        let cap = PD_CAPACITANCE_F * PD_DRIVE_V / E_CHARGE;
        assert!(cap > shot);
        assert!((cap - 14_980.0).abs() < 50.0, "{cap}");
    }
}
