//! Noise sources of the analog photonic datapath.
//!
//! The §4 measurements lump several physical noise sources into one
//! empirical inner-product error; the device simulator keeps them separate
//! so their relative contributions can be studied (and so the lumped σ the
//! paper reports emerges from physics rather than being injected directly):
//!
//! * laser relative intensity noise (RIN), multiplicative
//! * photodetector shot noise ∝ √photocurrent
//! * receiver thermal (Johnson) noise, additive
//! * MRR tuning error (residual calibration/lock error), in the phase domain
//!
//! All values are expressed in the *normalised* signal domain ([-1, 1]
//! full-scale BPD output) so they compose directly with the weight-bank
//! transfer function.

use crate::util::rng::Pcg64;

/// Per-source noise magnitudes (std, normalised units unless noted).
#[derive(Debug, Clone, Copy)]
pub struct NoiseModel {
    /// Laser RIN: multiplicative fractional amplitude noise per channel.
    pub rin_frac: f64,
    /// Shot-noise coefficient: std = shot_coeff * sqrt(|signal|).
    pub shot_coeff: f64,
    /// Additive receiver/thermal noise std.
    pub thermal: f64,
    /// Residual MRR phase-tuning error std (radians).
    pub phase_jitter: f64,
}

impl NoiseModel {
    /// Noise-free ideal device.
    pub fn ideal() -> NoiseModel {
        NoiseModel { rin_frac: 0.0, shot_coeff: 0.0, thermal: 0.0, phase_jitter: 0.0 }
    }

    /// Calibrated to the §4 off-chip-BPD circuit (lumped σ ≈ 0.098 for 1x4
    /// inner products): dominated by thermal-tuning residuals and receiver
    /// noise through the correctly-biased Thorlabs BPD.
    pub fn offchip_bpd() -> NoiseModel {
        NoiseModel {
            rin_frac: 0.010,
            shot_coeff: 0.012,
            thermal: 0.090,
            phase_jitter: 0.012,
        }
    }

    /// Calibrated to the §4 on-chip-BPD circuit (lumped σ ≈ 0.202): the
    /// sensing/sourcing-constrained control board mis-biases the PIN pair,
    /// which shows up as a much larger additive receiver noise.
    pub fn onchip_bpd() -> NoiseModel {
        NoiseModel {
            rin_frac: 0.010,
            shot_coeff: 0.012,
            thermal: 0.195,
            phase_jitter: 0.012,
        }
    }

    /// Calibrated to the Fig. 3(c) single-MRR multiplication experiment
    /// (lumped σ ≈ 0.019): one ring, power-meter readout, no splitter tree.
    pub fn single_mrr() -> NoiseModel {
        NoiseModel {
            rin_frac: 0.008,
            shot_coeff: 0.010,
            thermal: 0.028,
            phase_jitter: 0.003,
        }
    }

    /// Draw a multiplicative input-amplitude factor for one channel.
    pub fn sample_rin(&self, rng: &mut Pcg64) -> f64 {
        1.0 + rng.normal(0.0, self.rin_frac)
    }

    /// Draw the additive receiver noise for one inner-product readout whose
    /// normalised signal magnitude is `signal_abs`.
    pub fn sample_readout(&self, signal_abs: f64, rng: &mut Pcg64) -> f64 {
        let shot = self.shot_coeff * signal_abs.max(0.0).sqrt();
        rng.normal(0.0, (shot * shot + self.thermal * self.thermal).sqrt())
    }

    /// Draw a residual phase-tuning error for one MRR.
    pub fn sample_phase_jitter(&self, rng: &mut Pcg64) -> f64 {
        if self.phase_jitter == 0.0 {
            0.0
        } else {
            rng.normal(0.0, self.phase_jitter)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Summary;

    #[test]
    fn ideal_is_silent() {
        let m = NoiseModel::ideal();
        let mut rng = Pcg64::seed(0);
        for _ in 0..100 {
            assert_eq!(m.sample_readout(0.5, &mut rng), 0.0);
            assert_eq!(m.sample_rin(&mut rng), 1.0);
            assert_eq!(m.sample_phase_jitter(&mut rng), 0.0);
        }
    }

    #[test]
    fn readout_std_composes_shot_and_thermal() {
        let m = NoiseModel { rin_frac: 0.0, shot_coeff: 0.03, thermal: 0.04, phase_jitter: 0.0 };
        let mut rng = Pcg64::seed(1);
        let mut s = Summary::new();
        for _ in 0..50_000 {
            s.add(m.sample_readout(1.0, &mut rng));
        }
        let want = (0.03f64 * 0.03 + 0.04 * 0.04).sqrt();
        assert!((s.std() - want).abs() < 0.002, "std {} want {want}", s.std());
        assert!(s.mean().abs() < 0.002);
    }

    #[test]
    fn shot_noise_grows_with_signal() {
        let m = NoiseModel { rin_frac: 0.0, shot_coeff: 0.05, thermal: 0.0, phase_jitter: 0.0 };
        let mut rng = Pcg64::seed(2);
        let std_at = |sig: f64, rng: &mut Pcg64| {
            let mut s = Summary::new();
            for _ in 0..20_000 {
                s.add(m.sample_readout(sig, rng));
            }
            s.std()
        };
        let lo = std_at(0.25, &mut rng);
        let hi = std_at(1.0, &mut rng);
        assert!((hi / lo - 2.0).abs() < 0.1, "sqrt scaling: {lo} {hi}");
    }

    #[test]
    fn onchip_noisier_than_offchip() {
        let on = NoiseModel::onchip_bpd();
        let off = NoiseModel::offchip_bpd();
        assert!(on.thermal > 2.0 * off.thermal);
        let single = NoiseModel::single_mrr();
        assert!(single.thermal < off.thermal);
    }
}
