//! Data converters: the DAC that drives MRR/input modulators and the ADC
//! that digitises TIA outputs.
//!
//! Both are uniform mid-rise quantisers over a symmetric range, matching
//! the L1 `quantize` kernel's semantics (kernels/quantize.py). The DAC's
//! sample rate caps the system's operational rate f_s (§5: the 10 GS/s DAC
//! limits f_s to 10 GHz even though TIAs run at 20 GS/s).

use crate::{Error, Result};

/// A uniform quantiser over [-range, range].
#[derive(Debug, Clone, Copy)]
pub struct Quantizer {
    pub bits: u32,
    pub range: f64,
}

impl Quantizer {
    /// A zero or negative (or non-finite) range would make [`Self::quantize`]
    /// emit inf/NaN for every input, so it is rejected at construction.
    pub fn new(bits: u32, range: f64) -> Quantizer {
        assert!(
            range > 0.0 && range.is_finite(),
            "quantizer range must be positive and finite, got {range}"
        );
        Quantizer { bits, range }
    }

    /// Quantise; values are clamped into range first (converter saturates).
    /// NaN inputs saturate to 0.0 (mid-scale): `f64::clamp` propagates NaN,
    /// and one NaN code on the converter would otherwise poison every
    /// downstream analog readout.
    pub fn quantize(&self, x: f64) -> f64 {
        if x.is_nan() {
            return 0.0;
        }
        if self.bits == 0 {
            return x; // transparent (ideal converter)
        }
        let levels = 2f64.powi(self.bits as i32 - 1);
        let xn = (x / self.range).clamp(-1.0, 1.0);
        (xn * levels).round() / levels * self.range
    }

    /// Step size (LSB).
    pub fn lsb(&self) -> f64 {
        2.0 * self.range / 2f64.powi(self.bits as i32)
    }
}

/// Digital-to-analog converter with a rate limit.
#[derive(Debug, Clone, Copy)]
pub struct Dac {
    pub quant: Quantizer,
    pub max_rate_hz: f64,
    pub power_w: f64,
}

impl Dac {
    /// The §5 part: Alphacore D12B10G — 12-bit, 10 GS/s, 180 mW.
    pub fn alphacore_d12b10g() -> Dac {
        Dac {
            quant: Quantizer::new(12, 1.0),
            max_rate_hz: 10e9,
            power_w: super::constants::P_DAC_W,
        }
    }

    pub fn convert(&self, code: f64) -> f64 {
        self.quant.quantize(code)
    }
}

/// Analog-to-digital converter with a rate limit.
#[derive(Debug, Clone, Copy)]
pub struct Adc {
    pub quant: Quantizer,
    pub max_rate_hz: f64,
    pub power_w: f64,
}

impl Adc {
    /// The §5 part: Alphacore A6B12G — 6-bit, 12 GS/s, 13 mW.
    pub fn alphacore_a6b12g() -> Adc {
        Adc {
            quant: Quantizer::new(6, 1.0),
            max_rate_hz: 12e9,
            power_w: super::constants::P_ADC_W,
        }
    }

    pub fn sample(&self, v: f64) -> f64 {
        self.quant.quantize(v)
    }
}

/// System operational rate: the slowest converter on the signal path wins
/// (§5: "the throughput of the DAC limited f_s to 10 GHz").
pub fn operational_rate(dac: &Dac, adc: &Adc) -> f64 {
    dac.max_rate_hz.min(adc.max_rate_hz)
}

/// Validate a requested rate against the converter chain.
pub fn check_rate(f_s: f64, dac: &Dac, adc: &Adc) -> Result<()> {
    let max = operational_rate(dac, adc);
    if f_s > max {
        return Err(Error::Photonics(format!(
            "requested f_s {:.2} GHz exceeds converter limit {:.2} GHz",
            f_s / 1e9,
            max / 1e9
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantizer_basics() {
        let q = Quantizer::new(2, 1.0); // levels at -1, -0.5, 0, 0.5, 1
        assert_eq!(q.quantize(0.3), 0.5);
        assert_eq!(q.quantize(0.2), 0.0);
        assert_eq!(q.quantize(-0.8), -1.0);
        assert_eq!(q.quantize(5.0), 1.0); // saturates
        assert_eq!(q.lsb(), 0.5);
    }

    #[test]
    fn zero_bits_is_transparent() {
        let q = Quantizer::new(0, 1.0);
        assert_eq!(q.quantize(0.123456), 0.123456);
    }

    #[test]
    fn nan_saturates_to_midscale() {
        // regression: `(x / range).clamp(-1, 1)` propagates NaN, which used
        // to poison the whole analog path through one bad sample
        for bits in [0, 1, 6, 12] {
            let q = Quantizer::new(bits, 1.0);
            assert_eq!(q.quantize(f64::NAN), 0.0, "bits={bits}");
        }
        // infinities keep saturating to full scale
        let q = Quantizer::new(6, 1.0);
        assert_eq!(q.quantize(f64::INFINITY), 1.0);
        assert_eq!(q.quantize(f64::NEG_INFINITY), -1.0);
    }

    #[test]
    #[should_panic(expected = "quantizer range")]
    fn zero_range_rejected() {
        let _ = Quantizer::new(6, 0.0);
    }

    #[test]
    #[should_panic(expected = "quantizer range")]
    fn negative_range_rejected() {
        let _ = Quantizer::new(6, -1.0);
    }

    #[test]
    fn error_bounded_by_half_lsb() {
        let q = Quantizer::new(6, 1.0);
        for i in 0..1000 {
            let x = -1.0 + 2.0 * i as f64 / 999.0;
            assert!((q.quantize(x) - x).abs() <= q.lsb() / 2.0 + 1e-12);
        }
    }

    #[test]
    fn idempotent() {
        let q = Quantizer::new(5, 1.0);
        for i in 0..100 {
            let x = -1.2 + 2.4 * i as f64 / 99.0;
            let once = q.quantize(x);
            assert_eq!(q.quantize(once), once);
        }
    }

    #[test]
    fn paper_rate_limit() {
        let dac = Dac::alphacore_d12b10g();
        let adc = Adc::alphacore_a6b12g();
        assert_eq!(operational_rate(&dac, &adc), 10e9); // DAC-limited
        assert!(check_rate(10e9, &dac, &adc).is_ok());
        assert!(check_rate(12e9, &dac, &adc).is_err());
    }

    #[test]
    fn paper_parts_match_constants() {
        assert_eq!(Dac::alphacore_d12b10g().quant.bits, 12);
        assert_eq!(Adc::alphacore_a6b12g().quant.bits, 6);
        assert!((Dac::alphacore_d12b10g().power_w - 0.180).abs() < 1e-12);
        assert!((Adc::alphacore_a6b12g().power_w - 0.013).abs() < 1e-12);
    }
}
