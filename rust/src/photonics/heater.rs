//! MRR tuning actuators: photoconductive thermal heaters and
//! carrier-depletion phase shifters.
//!
//! The §4 testbed tunes MRRs with in-ring N-doped photoconductive heaters
//! (Jayatilleka 2015/2019): slow (~170 µs time constant) but wide-range.
//! The §5 projected system uses carrier-depletion PN junctions: ~120 µW,
//! GHz-rate, but with a narrow tuning range that cannot absorb fabrication
//! offsets — hence thermal *locking* or post-fabrication trimming.
//!
//! The models here give the weight bank its actuator dynamics (settle
//! times feed the schedule/energy roll-ups) and its current→phase transfer
//! (the nonlinearity the calibration LUT must learn).

use super::constants::THERMAL_TAU_S;

/// Which actuator technology tunes each MRR.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TuningKind {
    /// In-ring photoconductive heater (testbed): slow, wide range, ~mW.
    Thermal,
    /// Reverse-biased PN junction (projected system): fast, narrow, ~µW.
    CarrierDepletion,
}

/// First-order actuator model: drive → steady-state phase, with an
/// exponential settling transient.
#[derive(Debug, Clone)]
pub struct Actuator {
    pub kind: TuningKind,
    /// Phase shift per unit drive² (thermal: φ ∝ I²R; depletion: ≈linear).
    gain: f64,
    /// Time constant of the transient (s).
    tau_s: f64,
    /// Maximum phase swing the actuator can reach (radians).
    max_phase: f64,
    /// Current phase state (radians).
    phase: f64,
    /// Target phase being settled toward.
    target: f64,
}

impl Actuator {
    pub fn thermal() -> Actuator {
        Actuator {
            kind: TuningKind::Thermal,
            // heater: P = I²R heats the ring; phase ∝ ΔT ∝ power.
            gain: 2.0 * std::f64::consts::PI,
            tau_s: THERMAL_TAU_S,
            max_phase: 2.0 * std::f64::consts::PI, // full FSR reachable
            phase: 0.0,
            target: 0.0,
        }
    }

    pub fn carrier_depletion() -> Actuator {
        Actuator {
            kind: TuningKind::CarrierDepletion,
            gain: 0.15, // weak plasma-dispersion effect
            tau_s: 25e-12, // ~40 GHz electro-optic bandwidth
            // §3: depletion range is narrow — often smaller than the
            // fabrication-induced resonance offset.
            max_phase: 0.15,
            phase: 0.0,
            target: 0.0,
        }
    }

    /// Steady-state phase for a normalised drive in [0, 1].
    ///
    /// Thermal heaters are quadratic in drive current (P = I²R); depletion
    /// shifters are approximately linear in reverse bias.
    pub fn steady_state_phase(&self, drive: f64) -> f64 {
        let d = drive.clamp(0.0, 1.0);
        let raw = match self.kind {
            TuningKind::Thermal => self.gain * d * d,
            TuningKind::CarrierDepletion => self.gain * d,
        };
        raw.min(self.max_phase)
    }

    /// Invert [`steady_state_phase`]: drive needed for a target phase.
    pub fn drive_for_phase(&self, phase: f64) -> f64 {
        let p = phase.clamp(0.0, self.max_phase);
        match self.kind {
            TuningKind::Thermal => (p / self.gain).sqrt(),
            TuningKind::CarrierDepletion => (p / self.gain).min(1.0),
        }
    }

    /// Command a new drive; the phase settles exponentially.
    pub fn set_drive(&mut self, drive: f64) {
        self.target = self.steady_state_phase(drive);
    }

    /// Advance the transient by `dt` seconds.
    pub fn step(&mut self, dt: f64) {
        let alpha = 1.0 - (-dt / self.tau_s).exp();
        self.phase += alpha * (self.target - self.phase);
    }

    /// Instantaneous phase (radians).
    pub fn phase(&self) -> f64 {
        self.phase
    }

    /// Jump straight to steady state (used when simulating at time scales
    /// far beyond τ, e.g. one training step per thermal settle).
    pub fn settle(&mut self) {
        self.phase = self.target;
    }

    /// Time to settle within `frac` of the target (s): τ·ln(1/frac).
    pub fn settle_time(&self, frac: f64) -> f64 {
        self.tau_s * (1.0 / frac).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thermal_quadratic_depletion_linear() {
        let th = Actuator::thermal();
        let p1 = th.steady_state_phase(0.3);
        let p2 = th.steady_state_phase(0.6);
        assert!((p2 / p1 - 4.0).abs() < 1e-9, "thermal should be quadratic");

        let cd = Actuator::carrier_depletion();
        let q1 = cd.steady_state_phase(0.3);
        let q2 = cd.steady_state_phase(0.6);
        assert!((q2 / q1 - 2.0).abs() < 1e-9, "depletion should be linear");
    }

    #[test]
    fn drive_phase_roundtrip() {
        for act in [Actuator::thermal(), Actuator::carrier_depletion()] {
            for d in [0.05, 0.2, 0.5, 0.9] {
                let phase = act.steady_state_phase(d);
                let back = act.drive_for_phase(phase);
                assert!((back - d).abs() < 1e-9, "{:?} d={d}", act.kind);
            }
        }
    }

    #[test]
    fn depletion_range_is_narrow() {
        // the §3 observation that motivates thermal locking
        let cd = Actuator::carrier_depletion();
        let th = Actuator::thermal();
        assert!(cd.steady_state_phase(1.0) < 0.2);
        assert!(th.steady_state_phase(1.0) > 6.0);
    }

    #[test]
    fn settling_dynamics() {
        let mut act = Actuator::thermal();
        act.set_drive(1.0);
        let target = act.steady_state_phase(1.0);
        // after one tau: ~63% there
        act.step(THERMAL_TAU_S);
        assert!((act.phase() / target - 0.632).abs() < 0.01);
        // after many taus: settled
        for _ in 0..20 {
            act.step(THERMAL_TAU_S);
        }
        assert!((act.phase() - target).abs() < 1e-6 * target);
        // settle() short-circuits
        let mut act2 = Actuator::thermal();
        act2.set_drive(1.0);
        act2.settle();
        assert_eq!(act2.phase(), target);
    }

    #[test]
    fn step_converges_to_steady_state_for_both_actuators() {
        // device-lifetime property: the first-order transient of either
        // technology contracts monotonically onto steady_state_phase, and
        // settle_time(frac) really is the time after which the residual
        // is below frac of the commanded swing
        for (act, dt) in [
            (Actuator::thermal(), THERMAL_TAU_S / 3.0),
            (Actuator::carrier_depletion(), 25e-12 / 3.0),
        ] {
            for drive in [0.25, 0.6, 1.0] {
                let mut a = act.clone();
                let target = a.steady_state_phase(drive);
                a.set_drive(drive);
                let mut prev = (a.phase() - target).abs();
                assert!(prev > 0.0, "{:?} starts away from target", a.kind);
                let mut steps = 0usize;
                while (a.phase() - target).abs() > 1e-9 * target.max(1e-12) {
                    a.step(dt);
                    let err = (a.phase() - target).abs();
                    assert!(
                        err < prev || err == 0.0,
                        "{:?} drive={drive}: error grew {prev} -> {err}",
                        a.kind
                    );
                    prev = err;
                    steps += 1;
                    assert!(steps < 10_000, "{:?} failed to converge", a.kind);
                }
                // settle_time contract: after t99 of stepping, within 1%
                let mut b = act.clone();
                b.set_drive(drive);
                let t99 = b.settle_time(0.01);
                let n = (t99 / dt).ceil() as usize;
                for _ in 0..n {
                    b.step(dt);
                }
                assert!(
                    (b.phase() - target).abs() <= 0.011 * target,
                    "{:?} drive={drive}: not settled after t99",
                    b.kind
                );
            }
        }
    }

    #[test]
    fn settle_time_is_tau_scaled() {
        let act = Actuator::thermal();
        let t99 = act.settle_time(0.01);
        assert!((t99 / THERMAL_TAU_S - (100.0f64).ln()).abs() < 1e-9);
        // thermal settle dominates the testbed's 2 µJ/MAC (§5): ~ms scale
        assert!(t99 > 0.5e-3 && t99 < 1.5e-3);
    }
}
