//! The photonic weight bank: M rows × N WDM channels of add-drop MRRs
//! (Figs. 3(d) and 4(b)) simulated at device level.
//!
//! Composition of the whole §2–§3 signal chain:
//!
//! 1. WDM carriers (one per column) amplitude-encoded with the input vector
//!    by all-pass input modulators (+ laser RIN),
//! 2. a 1×M splitter fanning the bus into every row,
//! 3. per-row MRR arrays whose rings are *inscribed* with the weight tile
//!    through calibration LUT + feedback locking (fabrication offsets and
//!    residual lock error included), with inter-channel crosstalk,
//! 4. per-row balanced photodetectors (shot/thermal noise; optional
//!    mis-biased on-chip mode),
//! 5. per-row TIAs whose gains implement the Hadamard product,
//! 6. an optional ADC.
//!
//! Outputs are in the normalised domain ([-1, 1] for full-scale inputs), as
//! in Figs. 3(c)/5(a); callers rescale digitally (see kernels/ref.py for
//! the identical convention on the L1 side).

use super::bpd::Bpd;
use super::calibration::{sweep_cost, CalibrationTable, FeedbackController};
use super::converters::Quantizer;
use super::crosstalk::CrosstalkModel;
use super::heater::Actuator;
use super::mrr::{Mrr, MrrDesign};
use super::noise::NoiseModel;
use super::tia::TiaArray;
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;
use crate::{Error, Result};

/// Which photodetection circuit reads the rows (§4 experiment).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BpdMode {
    /// Noise-free reference device.
    Ideal,
    /// Grating couplers to the off-chip Thorlabs BDX1BA (σ ≈ 0.098).
    OffChip,
    /// Integrated germanium PIN pair with the mis-biased control circuit
    /// (σ ≈ 0.202).
    OnChip,
    /// Single-MRR characterisation chain (Fig. 3(c), σ ≈ 0.019).
    SingleMrr,
}

/// Static configuration of a bank instance.
#[derive(Debug, Clone)]
pub struct BankConfig {
    pub rows: usize,
    pub cols: usize,
    pub bpd_mode: BpdMode,
    /// Ring design (sets finesse and hence how many channels fit the FSR).
    pub design: MrrDesign,
    /// WDM grid spacing in MRR linewidths (≈3.4 at the paper's design point).
    pub spacing_linewidths: f64,
    /// ADC resolution; 0 = analog readout (the §4 power-meter protocol).
    pub adc_bits: u32,
    /// Device seed: fabrication offsets + intrinsic noise stream.
    pub seed: u64,
}

impl BankConfig {
    /// The paper's headline bank geometry (50 × 20), using the §3
    /// high-finesse ring design (finesse ≈ 368): 20 channels at 3.4
    /// linewidths occupy ~68 linewidths of a 368-linewidth FSR.
    pub fn paper(bpd_mode: BpdMode) -> BankConfig {
        BankConfig {
            rows: super::constants::BANK_ROWS,
            cols: super::constants::BANK_COLS,
            bpd_mode,
            design: MrrDesign::high_finesse(),
            spacing_linewidths: 3.4,
            adc_bits: 0,
            seed: 42,
        }
    }

    /// The §4 testbed: a 1 × 4 array of the Fig. 3(b) rings (finesse ≈ 30).
    /// Channels at 7 linewidths keep all four inside one FSR.
    pub fn testbed(bpd_mode: BpdMode) -> BankConfig {
        BankConfig {
            rows: 1,
            cols: 4,
            bpd_mode,
            design: MrrDesign::default(),
            spacing_linewidths: 7.0,
            adc_bits: 0,
            seed: 42,
        }
    }

    /// Channels must fit within one free spectral range, or neighbouring
    /// resonance orders alias (weights become unphysical).
    pub fn validate(&self) -> Result<()> {
        let span = self.cols as f64 * self.spacing_linewidths;
        let finesse = self.design.finesse();
        if span > finesse {
            return Err(Error::Photonics(format!(
                "{} channels x {} linewidths = {span:.0} exceeds the ring \
                 FSR ({finesse:.0} linewidths): raise finesse or shrink grid",
                self.cols, self.spacing_linewidths
            )));
        }
        Ok(())
    }
}

struct Ring {
    mrr: Mrr,
    table: CalibrationTable,
    /// Drive locked in by the last inscribe().
    drive: f64,
    /// Physically achieved weight at that drive (incl. residual lock error).
    w_actual: f64,
    /// Local slope dw/dφ at the operating point (for fast jitter mapping).
    slope: f64,
}

/// A device-level weight bank.
pub struct WeightBank {
    pub cfg: BankConfig,
    /// Device identity (drift modelling / diagnostics).
    design: MrrDesign,
    actuator: Actuator,
    rings: Vec<Ring>, // row-major rows × cols
    bpd: Bpd,
    noise: NoiseModel,
    tias: TiaArray,
    adc: Option<Quantizer>,
    crosstalk: CrosstalkModel,
    /// Effective per-ring weights after crosstalk (row-major), refreshed by
    /// inscribe().
    w_eff: Vec<f64>,
    /// Reusable per-row scratch of [`Self::refresh_effective`] (achieved
    /// weights and their detuning phases): re-inscription runs once per
    /// tile per dispatch, so it must not allocate at steady state.
    scratch_row_w: Vec<f32>,
    scratch_phis: Vec<f64>,
    rng: Pcg64,
    /// Per-ring thermal drift phase (radians, row-major), applied on top of
    /// whatever the actuator reaches at inscription time. Fed by the
    /// runtime's [`crate::photonics::drift::DriftModel`] via
    /// [`Self::set_drift`]; all zeros on a fresh (or just-recalibrated)
    /// device.
    drift: Vec<f64>,
    /// Injected dead-ring faults: (ring index, stuck weight). Applied after
    /// inscription, overriding whatever the lock achieved.
    stuck: Vec<(usize, f64)>,
    /// Count of bank operational cycles performed (for energy/speed roll-up).
    pub cycles: u64,
}

impl WeightBank {
    pub fn new(cfg: BankConfig) -> Result<WeightBank> {
        if cfg.rows == 0 || cfg.cols == 0 {
            return Err(Error::Photonics("bank must have rows, cols >= 1".into()));
        }
        cfg.validate()?;
        let design = cfg.design;
        let actuator = Actuator::thermal();
        let mut rng = Pcg64::new(cfg.seed, 0xba9c);
        let (bpd, noise) = match cfg.bpd_mode {
            BpdMode::Ideal => (Bpd::ideal(), NoiseModel::ideal()),
            BpdMode::OffChip => (Bpd::offchip(), NoiseModel::offchip_bpd()),
            BpdMode::OnChip => (Bpd::onchip(), NoiseModel::onchip_bpd()),
            BpdMode::SingleMrr => {
                let mut b = Bpd::offchip();
                b.noise = NoiseModel::single_mrr();
                (b, NoiseModel::single_mrr())
            }
        };

        // Fabricate + calibrate each ring (feed-forward sweep, 3x averaged,
        // exactly the §4 protocol).
        let cal_noise = noise.thermal * 0.5;
        let mut rings = Vec::with_capacity(cfg.rows * cfg.cols);
        for _ in 0..cfg.rows * cfg.cols {
            let fab_offset = rng.uniform_in(0.0, 1.2);
            let mrr = Mrr::new(design, fab_offset);
            let table =
                CalibrationTable::calibrate(&mrr, &actuator, 256, cal_noise, 3, &mut rng)?;
            rings.push(Ring { mrr, table, drive: 0.0, w_actual: 0.0, slope: 0.0 });
        }

        let n_total = cfg.rows * cfg.cols;
        let mut bank = WeightBank {
            tias: TiaArray::new(cfg.rows, 0),
            crosstalk: CrosstalkModel::new(design, cfg.spacing_linewidths),
            adc: (cfg.adc_bits > 0).then(|| Quantizer::new(cfg.adc_bits, 1.0)),
            w_eff: vec![0.0; n_total],
            scratch_row_w: Vec::with_capacity(cfg.cols),
            scratch_phis: Vec::with_capacity(cfg.cols),
            drift: vec![0.0; n_total],
            stuck: Vec::new(),
            design,
            actuator,
            rings,
            bpd,
            noise,
            cfg,
            rng,
            cycles: 0,
        };
        // start from a neutral inscription
        let zeros = Tensor::zeros(&[bank.cfg.rows, bank.cfg.cols]);
        bank.inscribe(&zeros)?;
        Ok(bank)
    }

    pub fn rows(&self) -> usize {
        self.cfg.rows
    }

    pub fn cols(&self) -> usize {
        self.cfg.cols
    }

    /// MAC cells in the array (`rows × cols`) — the MACs one optical
    /// cycle performs when every channel and row is live. `on-bank MACs
    /// / (cycles × cells)` is the bank-utilisation figure `pdfa report`
    /// derives from a run's recorded bank geometry (padding tiles and
    /// differential e⁺/e⁻ passes drive it below 100%).
    pub fn cells(&self) -> usize {
        self.cfg.rows * self.cfg.cols
    }

    fn check_tile_shape(&self, weights: &Tensor) -> Result<()> {
        if weights.shape() != [self.cfg.rows, self.cfg.cols] {
            // lint: allow(hot-path-alloc) — cold path, shape error
            return Err(Error::Shape(format!(
                "inscribe expects ({}, {}), got {:?}",
                self.cfg.rows,
                self.cfg.cols,
                weights.shape()
            )));
        }
        Ok(())
    }

    /// Refresh the crosstalk-effective weights from the per-ring achieved
    /// weights, row by row. Allocation-free at steady state: the per-row
    /// weight and phase scratch live on the bank and the crosstalk model
    /// writes straight into `w_eff`.
    fn refresh_effective(&mut self) {
        let WeightBank {
            cfg,
            rings,
            crosstalk,
            w_eff,
            scratch_row_w,
            scratch_phis,
            ..
        } = self;
        let cols = cfg.cols;
        for r in 0..cfg.rows {
            scratch_row_w.clear();
            scratch_row_w
                .extend(rings[r * cols..(r + 1) * cols].iter().map(|ring| ring.w_actual as f32));
            crosstalk.effective_weights_into(
                scratch_row_w,
                scratch_phis,
                &mut w_eff[r * cols..(r + 1) * cols],
            );
        }
    }

    /// Inscribe a (rows × cols) weight tile into the bank: feedback-lock
    /// every ring onto its target, then refresh the crosstalk-effective
    /// weights. Weights outside the achievable range are clamped by the
    /// lock (as on the real chip).
    ///
    /// Lock-readout noise is drawn from the bank's own stream; prefer
    /// [`Self::inscribe_keyed`] when the caller needs the inscription to be
    /// a pure function of its inputs (the runtime dispatcher keys the
    /// stream per operation so drifting runs stay thread-count invariant
    /// and resumable bit-exactly).
    pub fn inscribe(&mut self, weights: &Tensor) -> Result<()> {
        let mut rng = self.rng.clone();
        let out = self.inscribe_keyed(weights, &mut rng);
        self.rng = rng;
        out
    }

    /// [`Self::inscribe`] with a caller-owned lock-noise stream: the
    /// inscription becomes a pure function of (device physics, drift state,
    /// `weights`, `rng`). Any pending per-ring drift phases
    /// ([`Self::set_drift`]) deflect the achieved weights — the lock closes
    /// on its calibration-table view of the ring, then the ring drifts out
    /// from under it, exactly the §4 failure mode the recalibration
    /// scheduler watches for. Stuck-ring faults override their cells last.
    pub fn inscribe_keyed(&mut self, weights: &Tensor, rng: &mut Pcg64) -> Result<()> {
        self.check_tile_shape(weights)?;
        let fb = FeedbackController::default();
        let lock_readout = self.noise.thermal * 0.25;
        for (idx, ring) in self.rings.iter_mut().enumerate() {
            let target = weights.data()[idx] as f64;
            let lock = fb.lock(
                &ring.mrr,
                &self.actuator,
                &ring.table,
                target,
                lock_readout,
                rng,
            );
            ring.drive = lock.drive;
            // the feedback loop settles the actuator, then the slow thermal
            // drift phase shifts the resonance out from under the lock
            let d = self.drift[idx];
            let phase = self.actuator.steady_state_phase(lock.drive) + d;
            ring.w_actual = if d != 0.0 {
                ring.mrr.weight_at(phase)
            } else {
                lock.achieved_weight
            };
            // numerical slope dw/dφ at the (drifted) operating point
            let h = 1e-4;
            ring.slope =
                (ring.mrr.weight_at(phase + h) - ring.mrr.weight_at(phase - h)) / (2.0 * h);
        }
        self.apply_stuck();
        self.refresh_effective();
        Ok(())
    }

    /// Inscribe a weight tile in the *perfect-calibration limit*: every ring
    /// achieves its (clamped) target exactly, with zero residual lock error
    /// and zero phase-jitter sensitivity. With `with_crosstalk` the spectral
    /// crosstalk of the shared bus still applies (it is a physical effect,
    /// not a calibration error); without it the effective weights equal the
    /// targets bit for bit. This is the `PhysicsConfig::ideal` inscription
    /// path of the photonic runtime backend — and it is orders of magnitude
    /// cheaper than [`Self::inscribe`], since no feedback lock runs.
    pub fn inscribe_exact(&mut self, weights: &Tensor, with_crosstalk: bool) -> Result<()> {
        self.check_tile_shape(weights)?;
        for (idx, ring) in self.rings.iter_mut().enumerate() {
            // NaN targets park the ring at zero (clamp would keep the NaN)
            let t = weights.data()[idx] as f64;
            ring.drive = 0.0;
            let w = if t.is_nan() { 0.0 } else { t.clamp(-1.0, 1.0) };
            let d = self.drift[idx];
            ring.w_actual = if d != 0.0 {
                // even a perfectly calibrated inscription sits on a physical
                // resonance: map the target to its design detuning and let
                // the drift phase deflect it along the Lorentzian flank
                self.design.weight(self.design.detuning_for_weight(w) + d)
            } else {
                w
            };
            ring.slope = 0.0;
        }
        self.apply_stuck();
        if with_crosstalk {
            self.refresh_effective();
        } else {
            for (w, ring) in self.w_eff.iter_mut().zip(&self.rings) {
                *w = ring.w_actual;
            }
        }
        Ok(())
    }

    /// Load the device-lifetime state for subsequent inscriptions: per-ring
    /// drift phases (radians, row-major, one per ring) and stuck-ring
    /// faults. Allocation-free at steady state (the fault list reuses its
    /// capacity), so the dispatcher can refresh it on every drift tick.
    /// Takes effect at the next inscribe; already-inscribed weights and
    /// snapshots are untouched (drift moves the device, not the memory).
    pub fn set_drift(&mut self, phases: &[f64], stuck: &[(usize, f64)]) -> Result<()> {
        if phases.len() != self.drift.len() {
            return Err(Error::Shape(format!(
                "set_drift expects {} ring phases, got {}",
                self.drift.len(),
                phases.len()
            )));
        }
        self.drift.copy_from_slice(phases);
        self.stuck.clear();
        self.stuck.extend_from_slice(stuck);
        Ok(())
    }

    /// Override the stuck-ring cells after an inscription: a dead ring
    /// holds its fault weight with zero phase-jitter sensitivity (its
    /// resonance no longer tracks the actuator at all).
    fn apply_stuck(&mut self) {
        for &(idx, w) in &self.stuck {
            if let Some(ring) = self.rings.get_mut(idx) {
                ring.w_actual = w;
                ring.slope = 0.0;
            }
        }
    }

    /// Re-run the §4 calibration protocol on every ring — the full
    /// feed-forward sweep (256 points, 3× averaged) through the same noisy
    /// readout used at fabrication time — then verify the refreshed tables
    /// close the loop with one probe lock. Returns the total readout cycles
    /// consumed (charged to the energy roll-up by the scheduler) and the
    /// probe's residual weight error.
    ///
    /// Recalibration measures the *physical* ring, so the refreshed LUTs
    /// absorb whatever the current thermal state is; the caller (the
    /// runtime's recalibration scheduler) zeroes its drift model at the
    /// same time, which is what makes the pair a calibration epoch.
    pub fn recalibrate(&mut self, rng: &mut Pcg64) -> Result<(u64, f64)> {
        let cal_noise = self.noise.thermal * 0.5;
        for ring in &mut self.rings {
            ring.table = CalibrationTable::calibrate(
                &ring.mrr,
                &self.actuator,
                256,
                cal_noise,
                3,
                rng,
            )?;
        }
        let mut cycles = self.rings.len() as u64 * sweep_cost(256, 3);
        // probe lock on ring (0, 0): the §4 protocol's post-calibration
        // verification that the feedback loop still closes
        let fb = FeedbackController::default();
        let lock_readout = self.noise.thermal * 0.25;
        let probe = {
            let (w_lo, w_hi) = self.rings[0].table.weight_range();
            0.5 * (w_lo + w_hi)
        };
        let lock = fb.lock(
            &self.rings[0].mrr,
            &self.actuator,
            &self.rings[0].table,
            probe,
            lock_readout,
            rng,
        );
        cycles += lock.iterations as u64;
        Ok((cycles, (lock.achieved_weight - probe).abs()))
    }

    /// Program the per-row TIA gains with g'(a) (Hadamard product, §3).
    pub fn set_tia_gains(&mut self, gprime: &[f32]) -> Result<()> {
        self.tias.program(gprime)
    }

    /// Reset TIA gains to unity (pure mat-vec mode).
    pub fn clear_tia_gains(&mut self) {
        let ones = vec![1.0f32; self.cfg.rows];
        self.tias.program(&ones).expect("unity gains are valid");
    }

    /// One operational cycle: drive the bus with channel amplitudes
    /// `x ∈ [0, 1]^cols`, return the normalised per-row outputs.
    pub fn matvec(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        if x.len() != self.cfg.cols {
            return Err(Error::Shape(format!(
                "matvec expects {} channel amplitudes, got {}",
                self.cfg.cols,
                x.len()
            )));
        }
        self.cycles += 1;
        let mut out = vec![0.0f32; self.cfg.rows];
        // disjoint field borrows: the ring table is read-only while the
        // intrinsic noise stream advances
        let rings = &self.rings;
        run_chain(
            &self.noise,
            &self.bpd,
            &self.tias,
            self.adc.as_ref(),
            self.cfg.rows,
            self.cfg.cols,
            &self.w_eff,
            |i| rings[i].slope,
            x,
            None,
            &mut self.rng,
            &mut out,
        );
        Ok(out)
    }

    /// Read-only evaluation of one operational cycle against a *stored*
    /// inscription, without touching the bank's own state.
    ///
    /// This is the sharing-safe half of the matvec split: [`Self::matvec`]
    /// needs `&mut self` (it advances the device's intrinsic noise stream
    /// and cycle counter), which forces every serve/trainer replica to own
    /// a full bank clone. `eval` instead borrows the bank immutably and
    /// threads the stochastic state (`rng`) through the caller, so one
    /// `Arc<WeightBank>` can be shared across a worker pool — each worker
    /// holding its own snapshot + RNG — under the same `Send + Sync`
    /// contract the runtime's [`crate::runtime::Artifact`]s require.
    ///
    /// `gains` optionally overrides the programmed TIA gains for this cycle
    /// (the per-sample g′(a) Hadamard mask) without reprogramming the
    /// array; `None` uses the gains set by [`Self::set_tia_gains`].
    /// Cycle accounting is the caller's responsibility.
    pub fn eval(
        &self,
        ins: &Inscription,
        x: &[f32],
        gains: Option<&[f32]>,
        rng: &mut Pcg64,
    ) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; self.cfg.rows];
        self.eval_into(ins, x, gains, rng, &mut out)?;
        Ok(out)
    }

    /// [`Self::eval`] without the per-cycle allocation: the row readouts
    /// are written into `out` (length exactly `rows`). This is the form
    /// the photonic runtime drives from its batch-row worker pool — one
    /// reusable buffer per worker instead of one `Vec` per optical cycle.
    // lint: hot-path
    pub fn eval_into(
        &self,
        ins: &Inscription,
        x: &[f32],
        gains: Option<&[f32]>,
        rng: &mut Pcg64,
        out: &mut [f32],
    ) -> Result<()> {
        if (ins.rows, ins.cols) != (self.cfg.rows, self.cfg.cols) {
            return Err(Error::Shape("inscription geometry mismatch".into()));
        }
        if x.len() != self.cfg.cols {
            // lint: allow(hot-path-alloc) — cold path, shape error
            return Err(Error::Shape(format!(
                "eval expects {} channel amplitudes, got {}",
                self.cfg.cols,
                x.len()
            )));
        }
        if let Some(g) = gains {
            if g.len() != self.cfg.rows {
                // lint: allow(hot-path-alloc) — cold path, shape error
                return Err(Error::Shape(format!(
                    "eval expects {} TIA gains, got {}",
                    self.cfg.rows,
                    g.len()
                )));
            }
        }
        if out.len() != self.cfg.rows {
            // lint: allow(hot-path-alloc) — cold path, shape error
            return Err(Error::Shape(format!(
                "eval_into expects an output buffer of {} rows, got {}",
                self.cfg.rows,
                out.len()
            )));
        }
        run_chain(
            &self.noise,
            &self.bpd,
            &self.tias,
            self.adc.as_ref(),
            self.cfg.rows,
            self.cfg.cols,
            &ins.w_eff,
            |i| ins.ring_state[i].2,
            x,
            gains,
            rng,
            out,
        );
        Ok(())
    }

    /// 1×N inner product (the §4 experiment shape). Uses row 0.
    pub fn inner_product(&mut self, x: &[f32], w: &[f32]) -> Result<f32> {
        if w.len() != self.cfg.cols {
            return Err(Error::Shape("weight length != bank cols".into()));
        }
        let mut tile = Tensor::zeros(&[self.cfg.rows, self.cfg.cols]);
        tile.data_mut()[..w.len()].copy_from_slice(w);
        self.inscribe(&tile)?;
        Ok(self.matvec(x)?[0])
    }

    /// Single-MRR multiplication (Fig. 3(c)): x·w through ring (0, 0) with
    /// all other channels dark.
    pub fn multiply(&mut self, x: f32, w: f32) -> Result<f32> {
        // stack scratch for every realistic channel count, as in run_chain
        let n = self.cfg.cols;
        let mut ws_stack = [0.0f32; 128];
        let mut xs_stack = [0.0f32; 128];
        let mut ws_heap = Vec::new();
        let mut xs_heap = Vec::new();
        let (ws, xs): (&mut [f32], &mut [f32]) = if n <= 128 {
            (&mut ws_stack[..n], &mut xs_stack[..n])
        } else {
            ws_heap.resize(n, 0.0);
            xs_heap.resize(n, 0.0);
            (&mut ws_heap, &mut xs_heap)
        };
        ws[0] = w;
        xs[0] = x;
        // normalise against cols: matvec divides by n, multiply is 1-channel
        let y = self.inner_product(xs, ws)?;
        Ok(y * n as f32)
    }

    /// The inscribable weight range of ring (0,0)'s calibration (useful for
    /// validating targets before inscribing).
    pub fn weight_range(&self) -> (f64, f64) {
        self.rings[0].table.weight_range()
    }

    /// Snapshot the current inscription (drives, achieved weights, slopes,
    /// crosstalk-effective weights). Models the paper's §5 analog weight
    /// memory: the fixed B(k) tiles are stored once and switching between
    /// them costs (near-)nothing, unlike re-locking every ring.
    pub fn snapshot(&self) -> Inscription {
        let mut ins = Inscription::empty();
        self.snapshot_into(&mut ins);
        ins
    }

    /// [`Self::snapshot`] into a caller-owned [`Inscription`], reusing its
    /// vector capacities: clear + extend instead of fresh allocations.
    /// The photonic runtime keeps a pool of these per dispatcher, so
    /// snapshotting every tile of every dispatch is heap-free once the
    /// pool has warmed to the model's tile count.
    // lint: hot-path
    pub fn snapshot_into(&self, ins: &mut Inscription) {
        ins.rows = self.cfg.rows;
        ins.cols = self.cfg.cols;
        ins.ring_state.clear();
        ins.ring_state
            .extend(self.rings.iter().map(|r| (r.drive, r.w_actual, r.slope)));
        ins.w_eff.clear();
        ins.w_eff.extend_from_slice(&self.w_eff);
    }

    /// Restore a previously snapshotted inscription (an analog-memory
    /// weight switch). Does not consume an operational cycle.
    pub fn restore(&mut self, ins: &Inscription) -> Result<()> {
        if (ins.rows, ins.cols) != (self.cfg.rows, self.cfg.cols) {
            return Err(Error::Shape("inscription geometry mismatch".into()));
        }
        for (ring, &(drive, w_actual, slope)) in
            self.rings.iter_mut().zip(&ins.ring_state)
        {
            ring.drive = drive;
            ring.w_actual = w_actual;
            ring.slope = slope;
        }
        self.w_eff.clone_from(&ins.w_eff);
        Ok(())
    }
}

/// The full §2–§3 signal chain for one operational cycle, shared by the
/// mutating [`WeightBank::matvec`] and the read-only [`WeightBank::eval`] /
/// [`WeightBank::eval_into`]: amplitude encoding + RIN, per-ring
/// Lorentzian-slope phase jitter on the effective weights, balanced
/// photodetection, TIA gain (programmed or overridden per cycle), optional
/// ADC. Row readouts land in `out[..rows]` (caller-validated length).
// lint: hot-path
#[allow(clippy::too_many_arguments)]
fn run_chain(
    noise: &NoiseModel,
    bpd: &Bpd,
    tias: &TiaArray,
    adc: Option<&Quantizer>,
    rows: usize,
    cols: usize,
    w_eff: &[f64],
    slope_at: impl Fn(usize) -> f64,
    x: &[f32],
    gain_override: Option<&[f32]>,
    rng: &mut Pcg64,
    out: &mut [f32],
) {
    let n = cols;
    // amplitude encoding + RIN, shared by all rows (same bus + splitter);
    // stack scratch for every realistic channel count (the §3 design tops
    // out at 108 WDM channels), heap only beyond it — this runs once per
    // optical cycle on the simulator's hottest path
    let mut amps_stack = [0.0f64; 128];
    let mut amps_heap;
    let amps: &mut [f64] = if n <= 128 {
        &mut amps_stack[..n]
    } else {
        // lint: allow(hot-path-alloc) — beyond the §3 channel budget only
        amps_heap = vec![0.0f64; n];
        &mut amps_heap
    };
    for (a, &xi) in amps.iter_mut().zip(x) {
        // f64::clamp propagates NaN: a NaN sample darks its channel instead
        let xi = (xi as f64).clamp(0.0, 1.0);
        let xi = if xi.is_nan() { 0.0 } else { xi };
        *a = xi * noise.sample_rin(rng);
    }
    for r in 0..rows {
        // per-ring instantaneous weight = crosstalk-effective weight +
        // phase jitter mapped through the local Lorentzian slope
        let mut diff = 0.0; // Σ x_i (T_d − T_p) = Σ x_i w_i
        for c in 0..n {
            let jitter = noise.sample_phase_jitter(rng) * slope_at(r * n + c);
            let w_inst = (w_eff[r * n + c] + jitter).clamp(-1.0, 1.0);
            diff += amps[c] * w_inst;
        }
        // BPD expects (drop_sum - through_sum) = diff (already the
        // differential), normalised by channel count inside read()
        let i_out = bpd.read(diff, 0.0, n, rng);
        let v = match gain_override {
            Some(g) => {
                let tia = &tias.tias[r];
                ((g[r] as f64).clamp(0.0, 1.0) * i_out)
                    .clamp(-tia.v_sat, tia.v_sat)
            }
            None => tias.amplify_row(r, i_out),
        };
        out[r] = match adc {
            Some(q) => q.quantize(v) as f32,
            None => v as f32,
        };
    }
}

/// A stored weight-bank inscription (see [`WeightBank::snapshot`]).
#[derive(Debug, Clone)]
pub struct Inscription {
    rows: usize,
    cols: usize,
    ring_state: Vec<(f64, f64, f64)>,
    w_eff: Vec<f64>,
}

impl Inscription {
    /// An empty pool slot for [`WeightBank::snapshot_into`] to fill. Not
    /// a valid inscription until then (geometry 0×0 fails every eval).
    // lint: allow(hot-path-alloc) — pool warm-up: slots are created until
    // the snapshot pool covers the tiling, then reused on every dispatch
    pub fn empty() -> Inscription {
        Inscription {
            rows: 0,
            cols: 0,
            ring_state: Vec::new(),
            w_eff: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Summary;

    fn ideal_bank(rows: usize, cols: usize) -> WeightBank {
        WeightBank::new(BankConfig {
            rows,
            cols,
            bpd_mode: BpdMode::Ideal,
            design: MrrDesign::high_finesse(),
            spacing_linewidths: 8.0, // wide spacing: negligible crosstalk
            adc_bits: 0,
            seed: 7,
        })
        .unwrap()
    }

    #[test]
    fn ideal_bank_computes_exact_matvec() {
        let mut bank = ideal_bank(3, 4);
        assert_eq!(bank.cells(), 12); // per-cycle MAC capacity (telemetry)
        let w = Tensor::new(
            &[3, 4],
            vec![0.5, -0.3, 0.8, 0.1, -0.6, 0.2, 0.0, 0.9, 0.25, -0.75, 0.4, -0.1],
        )
        .unwrap();
        bank.inscribe(&w).unwrap();
        let x = [1.0f32, 0.5, 0.8, 0.2];
        let got = bank.matvec(&x).unwrap();
        for r in 0..3 {
            let want: f32 = (0..4).map(|c| w.at(r, c) * x[c]).sum::<f32>() / 4.0;
            assert!(
                (got[r] - want).abs() < 0.02,
                "row {r}: got {} want {want}",
                got[r]
            );
        }
        assert_eq!(bank.cycles, 1);
    }

    #[test]
    fn tia_gains_gate_rows() {
        let mut bank = ideal_bank(2, 3);
        let w = Tensor::full(&[2, 3], 0.5);
        bank.inscribe(&w).unwrap();
        bank.set_tia_gains(&[0.0, 1.0]).unwrap();
        let out = bank.matvec(&[1.0, 1.0, 1.0]).unwrap();
        assert_eq!(out[0], 0.0);
        assert!(out[1].abs() > 0.3);
        bank.clear_tia_gains();
        let out = bank.matvec(&[1.0, 1.0, 1.0]).unwrap();
        assert!(out[0].abs() > 0.3);
    }

    #[test]
    fn shape_validation() {
        let mut bank = ideal_bank(2, 3);
        assert!(bank.inscribe(&Tensor::zeros(&[3, 2])).is_err());
        assert!(bank.matvec(&[1.0, 1.0]).is_err());
        assert!(WeightBank::new(BankConfig {
            rows: 0,
            cols: 1,
            bpd_mode: BpdMode::Ideal,
            design: MrrDesign::default(),
            spacing_linewidths: 3.4,
            adc_bits: 0,
            seed: 1,
        })
        .is_err());
    }

    #[test]
    fn adc_quantises_output() {
        let mut bank = WeightBank::new(BankConfig {
            rows: 1,
            cols: 2,
            bpd_mode: BpdMode::Ideal,
            design: MrrDesign::default(),
            spacing_linewidths: 8.0,
            adc_bits: 2,
            seed: 3,
        })
        .unwrap();
        bank.inscribe(&Tensor::new(&[1, 2], vec![0.6, 0.0]).unwrap()).unwrap();
        let out = bank.matvec(&[1.0, 0.0]).unwrap()[0];
        // 2-bit levels: multiples of 0.5
        assert!((out * 2.0 - (out * 2.0).round()).abs() < 1e-6, "{out}");
    }

    #[test]
    fn noisy_modes_have_ordered_error() {
        // device-level reproduction of the Fig. 5(a) ordering:
        // σ(on-chip) > σ(off-chip) > σ(ideal) = 0 for 1x4 inner products
        let mut rng = Pcg64::seed(99);
        let sigma_of = |mode: BpdMode, rng: &mut Pcg64| {
            let mut bank = WeightBank::new(BankConfig::testbed(mode)).unwrap();
            let mut s = Summary::new();
            for _ in 0..120 {
                let w: Vec<f32> = (0..4).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect();
                let x: Vec<f32> = (0..4).map(|_| rng.uniform() as f32).collect();
                let got = bank.inner_product(&x, &w).unwrap();
                let want: f32 =
                    w.iter().zip(&x).map(|(&wi, &xi)| wi * xi).sum::<f32>() / 4.0;
                s.add((got - want) as f64);
            }
            s.std()
        };
        let s_ideal = sigma_of(BpdMode::Ideal, &mut rng);
        let s_off = sigma_of(BpdMode::OffChip, &mut rng);
        let s_on = sigma_of(BpdMode::OnChip, &mut rng);
        assert!(s_ideal < 0.02, "ideal σ={s_ideal}");
        assert!(s_off > s_ideal && s_on > 1.5 * s_off, "{s_ideal} {s_off} {s_on}");
    }

    #[test]
    fn matvec_tracks_ideal_inner_product_in_every_bpd_mode() {
        // The full signal chain must stay within each circuit's noise
        // budget of the ideal normalised inner product w·x / n. Noise σ
        // per mode follows Fig. 5(a): ideal ≈ 0, single-MRR 0.019,
        // off-chip 0.098, on-chip 0.202 — allow ~5σ (+ lock/crosstalk
        // margin) per sample.
        let mut rng = Pcg64::seed(31);
        for (mode, tol) in [
            (BpdMode::Ideal, 0.06),
            (BpdMode::SingleMrr, 0.15),
            (BpdMode::OffChip, 0.60),
            (BpdMode::OnChip, 1.20),
        ] {
            let mut bank = WeightBank::new(BankConfig {
                seed: 17,
                ..BankConfig::testbed(mode)
            })
            .unwrap();
            let mut worst = 0.0f32;
            let mut s = Summary::new();
            for _ in 0..60 {
                let w: Vec<f32> =
                    (0..4).map(|_| rng.uniform_in(-0.9, 0.9) as f32).collect();
                let x: Vec<f32> = (0..4).map(|_| rng.uniform() as f32).collect();
                let got = bank.inner_product(&x, &w).unwrap();
                let want: f32 =
                    w.iter().zip(&x).map(|(&wi, &xi)| wi * xi).sum::<f32>() / 4.0;
                let e = got - want;
                worst = worst.max(e.abs());
                s.add(e as f64);
            }
            assert!(
                worst < tol,
                "{mode:?}: worst-case error {worst} exceeds tolerance {tol}"
            );
            // the error must be noise, not bias (bound scales with mode σ)
            assert!(
                s.mean().abs() < (tol / 3.0) as f64,
                "{mode:?}: biased by {}",
                s.mean()
            );
        }
    }

    #[test]
    fn multiply_covers_full_quadrants() {
        let mut bank = WeightBank::new(BankConfig::testbed(BpdMode::Ideal)).unwrap();
        for (x, w) in [(0.8f32, 0.5f32), (0.9, -0.7), (0.3, 0.3), (1.0, -1.0)] {
            let got = bank.multiply(x, w).unwrap();
            assert!((got - x * w).abs() < 0.05, "x={x} w={w} got={got}");
        }
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let mut bank = ideal_bank(2, 3);
        let w1 = Tensor::new(&[2, 3], vec![0.5, -0.3, 0.8, 0.1, -0.6, 0.2]).unwrap();
        let w2 = Tensor::full(&[2, 3], -0.4);
        bank.inscribe(&w1).unwrap();
        let snap1 = bank.snapshot();
        let out1 = bank.matvec(&[1.0, 0.5, 0.8]).unwrap();
        bank.inscribe(&w2).unwrap();
        let out2 = bank.matvec(&[1.0, 0.5, 0.8]).unwrap();
        assert_ne!(out1, out2);
        bank.restore(&snap1).unwrap();
        let out1b = bank.matvec(&[1.0, 0.5, 0.8]).unwrap();
        // ideal bank: identical outputs after restore
        for (a, b) in out1.iter().zip(&out1b) {
            assert!((a - b).abs() < 1e-6);
        }
        // geometry mismatch rejected
        let other = ideal_bank(3, 2).snapshot();
        assert!(bank.restore(&other).is_err());
    }

    #[test]
    fn eval_matches_matvec_on_ideal_bank() {
        // the read-only split must compute the identical signal chain
        let mut bank = ideal_bank(3, 4);
        let w = Tensor::new(
            &[3, 4],
            vec![0.5, -0.3, 0.8, 0.1, -0.6, 0.2, 0.0, 0.9, 0.25, -0.75, 0.4, -0.1],
        )
        .unwrap();
        bank.inscribe(&w).unwrap();
        let ins = bank.snapshot();
        let x = [1.0f32, 0.5, 0.8, 0.2];
        let want = bank.matvec(&x).unwrap();
        let mut rng = Pcg64::seed(123); // independent stream: ideal noise is 0
        let got = bank.eval(&ins, &x, None, &mut rng).unwrap();
        assert_eq!(got, want);
        // eval consumed no bank cycles and left the bank state untouched
        assert_eq!(bank.cycles, 1);
        assert_eq!(bank.matvec(&x).unwrap(), want);
    }

    #[test]
    fn eval_gain_override_gates_rows() {
        let mut bank = ideal_bank(2, 3);
        bank.inscribe(&Tensor::full(&[2, 3], 0.5)).unwrap();
        let ins = bank.snapshot();
        let mut rng = Pcg64::seed(5);
        let x = [1.0f32, 1.0, 1.0];
        let out = bank.eval(&ins, &x, Some(&[0.0, 1.0]), &mut rng).unwrap();
        assert_eq!(out[0], 0.0);
        assert!(out[1].abs() > 0.3);
        // the override is per cycle: programmed gains stay untouched
        let out = bank.eval(&ins, &x, None, &mut rng).unwrap();
        assert!(out[0].abs() > 0.3);
        // and validated
        assert!(bank.eval(&ins, &x, Some(&[1.0]), &mut rng).is_err());
    }

    #[test]
    fn eval_into_matches_eval_and_validates_buffer() {
        let mut bank = ideal_bank(3, 4);
        bank.inscribe(&Tensor::new(
            &[3, 4],
            vec![0.5, -0.3, 0.8, 0.1, -0.6, 0.2, 0.0, 0.9, 0.25, -0.75, 0.4, -0.1],
        )
        .unwrap())
        .unwrap();
        let ins = bank.snapshot();
        let x = [1.0f32, 0.5, 0.8, 0.2];
        let mut rng = Pcg64::seed(2);
        let want = bank.eval(&ins, &x, None, &mut rng).unwrap();
        let mut got = vec![9.0f32; 3]; // stale values must be overwritten
        bank.eval_into(&ins, &x, None, &mut rng, &mut got).unwrap();
        assert_eq!(got, want);
        let mut short = vec![0.0f32; 2];
        assert!(bank.eval_into(&ins, &x, None, &mut rng, &mut short).is_err());
    }

    #[test]
    fn eval_rejects_geometry_mismatch() {
        let mut bank = ideal_bank(2, 3);
        bank.inscribe(&Tensor::zeros(&[2, 3])).unwrap();
        let other = ideal_bank(3, 2).snapshot();
        let mut rng = Pcg64::seed(1);
        assert!(bank.eval(&other, &[1.0, 1.0, 1.0], None, &mut rng).is_err());
        let ins = bank.snapshot();
        assert!(bank.eval(&ins, &[1.0, 1.0], None, &mut rng).is_err());
    }

    #[test]
    fn shared_bank_eval_from_threads() {
        // the Send + Sync contract the runtime artifacts need: one bank,
        // many readers, each with its own inscription snapshot + RNG
        let mut bank = ideal_bank(2, 3);
        let w = Tensor::new(&[2, 3], vec![0.5, -0.3, 0.8, 0.1, -0.6, 0.2]).unwrap();
        bank.inscribe(&w).unwrap();
        let ins = bank.snapshot();
        let x = [1.0f32, 0.5, 0.8];
        let mut rng = Pcg64::seed(77);
        let want = bank.eval(&ins, &x, None, &mut rng).unwrap();
        let bank = std::sync::Arc::new(bank);
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let bank = bank.clone();
                let ins = ins.clone();
                std::thread::spawn(move || {
                    let mut rng = Pcg64::seed(77);
                    bank.eval(&ins, &[1.0, 0.5, 0.8], None, &mut rng).unwrap()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), want);
        }
    }

    #[test]
    fn inscribe_exact_is_exact() {
        let mut bank = ideal_bank(2, 3);
        let w = Tensor::new(&[2, 3], vec![0.5, -0.3, 0.8, 0.1, -0.6, 0.2]).unwrap();
        bank.inscribe_exact(&w, false).unwrap();
        let x = [1.0f32, 0.5, 0.8];
        let got = bank.matvec(&x).unwrap();
        for r in 0..2 {
            let want: f32 = (0..3).map(|c| w.at(r, c) * x[c]).sum::<f32>() / 3.0;
            assert!((got[r] - want).abs() < 1e-6, "row {r}: {} vs {want}", got[r]);
        }
        // out-of-range targets clamp, shape mismatch rejected
        bank.inscribe_exact(&Tensor::full(&[2, 3], 5.0), false).unwrap();
        assert!(bank.matvec(&[1.0, 0.0, 0.0]).unwrap()[0] <= 1.0);
        assert!(bank.inscribe_exact(&Tensor::zeros(&[3, 2]), false).is_err());
        // with_crosstalk folds the spectral model back in
        let mut crowded = WeightBank::new(BankConfig {
            rows: 1,
            cols: 4,
            bpd_mode: BpdMode::Ideal,
            design: MrrDesign::default(),
            spacing_linewidths: 1.0, // heavy crosstalk
            adc_bits: 0,
            seed: 9,
        })
        .unwrap();
        let w = Tensor::new(&[1, 4], vec![0.8, -0.6, 0.4, -0.2]).unwrap();
        crowded.inscribe_exact(&w, false).unwrap();
        let clean = crowded.matvec(&[1.0, 1.0, 1.0, 1.0]).unwrap()[0];
        crowded.inscribe_exact(&w, true).unwrap();
        let xtalk = crowded.matvec(&[1.0, 1.0, 1.0, 1.0]).unwrap()[0];
        assert!((clean - xtalk).abs() > 1e-4, "{clean} vs {xtalk}");
    }

    #[test]
    fn snapshot_into_reuses_capacity_and_matches_snapshot() {
        let mut bank = ideal_bank(2, 3);
        bank.inscribe(&Tensor::full(&[2, 3], 0.25)).unwrap();
        let fresh = bank.snapshot();
        let mut pooled = Inscription::empty();
        bank.snapshot_into(&mut pooled);
        let x = [1.0f32, 0.5, 0.8];
        let mut rng1 = Pcg64::seed(4);
        let mut rng2 = Pcg64::seed(4);
        assert_eq!(
            bank.eval(&fresh, &x, None, &mut rng1).unwrap(),
            bank.eval(&pooled, &x, None, &mut rng2).unwrap()
        );
        // refilling after another inscription reuses the warmed slot
        bank.inscribe(&Tensor::full(&[2, 3], -0.5)).unwrap();
        let cap = (pooled.ring_state.capacity(), pooled.w_eff.capacity());
        bank.snapshot_into(&mut pooled);
        assert_eq!((pooled.ring_state.capacity(), pooled.w_eff.capacity()), cap);
        // an unfilled pool slot is not a valid inscription
        assert!(bank.eval(&Inscription::empty(), &x, None, &mut rng1).is_err());
    }

    #[test]
    fn drift_deflects_inscribed_weights_along_the_flank() {
        let mut bank = ideal_bank(2, 3);
        let w = Tensor::full(&[2, 3], 0.5);
        bank.inscribe(&w).unwrap();
        let clean: Vec<f64> = bank.rings.iter().map(|r| r.w_actual).collect();
        // a small phase drift deflects every ring by ~slope · phase
        let d = 1e-4;
        bank.set_drift(&[d; 6], &[]).unwrap();
        bank.inscribe(&w).unwrap();
        for (ring, &w0) in bank.rings.iter().zip(&clean) {
            let moved = ring.w_actual - w0;
            assert!(moved.abs() > 1e-6, "drift must move the weight");
            assert!(
                moved.signum() == (ring.slope * d).signum()
                    && moved.abs() < ring.slope.abs() * d * 2.0 + 1e-6,
                "deflection {moved} inconsistent with slope {}",
                ring.slope
            );
        }
        // zeroed drift restores the clean inscription bit-exactly (the
        // ideal-mode lock is deterministic)
        bank.set_drift(&[0.0; 6], &[]).unwrap();
        bank.inscribe(&w).unwrap();
        let back: Vec<f64> = bank.rings.iter().map(|r| r.w_actual).collect();
        assert_eq!(back, clean);
        // the perfect-calibration path drifts too (it still sits on a
        // physical resonance)
        bank.inscribe_exact(&w, false).unwrap();
        let exact_clean: Vec<f64> = bank.rings.iter().map(|r| r.w_actual).collect();
        bank.set_drift(&[d; 6], &[]).unwrap();
        bank.inscribe_exact(&w, false).unwrap();
        for (ring, &w0) in bank.rings.iter().zip(&exact_clean) {
            assert!((ring.w_actual - w0).abs() > 1e-6);
        }
        // geometry validated
        assert!(bank.set_drift(&[0.0; 3], &[]).is_err());
    }

    #[test]
    fn stuck_ring_holds_its_fault_weight() {
        let mut bank = ideal_bank(2, 3);
        bank.set_drift(&[0.0; 6], &[(1, 0.25)]).unwrap();
        let w = Tensor::full(&[2, 3], -0.8);
        bank.inscribe(&w).unwrap();
        assert_eq!(bank.rings[1].w_actual, 0.25);
        assert_eq!(bank.rings[1].slope, 0.0);
        // the dead ring degrades the row readout but never produces NaN
        let out = bank.matvec(&[1.0, 1.0, 1.0]).unwrap();
        assert!(out.iter().all(|v| v.is_finite()), "{out:?}");
        // exact path honours the fault too, straight into w_eff
        bank.inscribe_exact(&w, false).unwrap();
        assert_eq!(bank.rings[1].w_actual, 0.25);
        assert_eq!(bank.w_eff[1], 0.25);
        // out-of-range fault indices are ignored, not a panic
        bank.set_drift(&[0.0; 6], &[(99, 0.5)]).unwrap();
        bank.inscribe(&w).unwrap();
    }

    #[test]
    fn recalibrate_reprices_but_preserves_a_quiet_ideal_device() {
        // BpdMode::Ideal has zero readout noise, so re-running the §4
        // sweep reproduces the fabrication-time tables exactly: the
        // scheduler's table swap is a numerical no-op on a quiet device
        // while still charging the full protocol cost
        let mut bank = ideal_bank(2, 3);
        let w = Tensor::new(&[2, 3], vec![0.5, -0.3, 0.8, 0.1, -0.6, 0.2]).unwrap();
        bank.inscribe(&w).unwrap();
        let before: Vec<f64> = bank.rings.iter().map(|r| r.w_actual).collect();
        let mut rng = Pcg64::keyed(7, 0, 0);
        let (cycles, residual) = bank.recalibrate(&mut rng).unwrap();
        assert!(
            cycles > 6 * sweep_cost(256, 3),
            "6 ring sweeps + probe lock, got {cycles}"
        );
        assert!(residual < 2e-3, "probe residual {residual}");
        bank.inscribe(&w).unwrap();
        let after: Vec<f64> = bank.rings.iter().map(|r| r.w_actual).collect();
        assert_eq!(after, before);
    }

    #[test]
    fn inscribe_keyed_is_a_pure_function_of_its_stream() {
        // the thread-invariance contract: lock-readout noise comes only
        // from the caller's keyed stream, never from bank-internal state
        let mut bank = WeightBank::new(BankConfig::testbed(BpdMode::OffChip)).unwrap();
        let w = Tensor::new(&[1, 4], vec![0.3, -0.2, 0.6, 0.1]).unwrap();
        let weights_of = |bank: &WeightBank| -> Vec<f64> {
            bank.rings.iter().map(|r| r.w_actual).collect()
        };
        let mut r1 = Pcg64::keyed(42, 9, 1);
        bank.inscribe_keyed(&w, &mut r1).unwrap();
        let a = weights_of(&bank);
        let mut r2 = Pcg64::keyed(42, 9, 1);
        bank.inscribe_keyed(&w, &mut r2).unwrap();
        assert_eq!(a, weights_of(&bank), "same key must be bit-identical");
        let mut r3 = Pcg64::keyed(42, 10, 1);
        bank.inscribe_keyed(&w, &mut r3).unwrap();
        assert_ne!(a, weights_of(&bank), "a fresh op draws fresh lock noise");
    }

    #[test]
    fn deterministic_per_seed() {
        let run = |seed: u64| {
            let mut bank = WeightBank::new(BankConfig {
                seed,
                ..BankConfig::testbed(BpdMode::OffChip)
            })
            .unwrap();
            bank.inner_product(&[0.5, 0.6, 0.7, 0.8], &[0.1, -0.2, 0.3, -0.4])
                .unwrap()
        };
        assert_eq!(run(5), run(5));
        assert_ne!(run(5), run(6));
    }
}
