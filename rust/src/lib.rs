//! # photonic-dfa
//!
//! Reproduction of *Silicon Photonic Architecture for Training Deep Neural
//! Networks with Direct Feedback Alignment* (Filipovich et al., Optica 2022)
//! as a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the digital control system and every analog
//!   substrate simulated at device level: micro-ring resonator (MRR) physics,
//!   thermal/carrier tuning and calibration, balanced photodetection, TIAs,
//!   data converters, the WDM optical link budget, the photonic weight bank,
//!   a GeMM compiler that tiles arbitrary matrix products onto the finite
//!   bank, the paper's energy/speed model (Eqs. 2–4, Fig. 6), the dataset
//!   substrate, and the training coordinator that drives the AOT artifacts.
//! * **L2** — the MLP forward/backward (DFA, Eq. 1) written in JAX,
//!   AOT-lowered once to HLO text (`python/compile/`).
//! * **L1** — Pallas kernels for the weight-bank datapath, embedded in the
//!   same HLO.
//!
//! Python never runs on the training path: the `pdfa` binary loads
//! `artifacts/*.hlo.txt` through PJRT (the `xla` crate) and is self-contained.
//!
//! See `DESIGN.md` for the full system inventory and the per-figure
//! experiment index, and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod coordinator;
pub mod data;
pub mod dfa;
pub mod energy;
pub mod error;
pub mod experiments;
pub mod gemm;
pub mod photonics;
pub mod runtime;
pub mod tensor;
pub mod util;

pub use error::{Error, Result};
