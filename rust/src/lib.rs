//! # photonic-dfa
//!
//! Reproduction of *Silicon Photonic Architecture for Training Deep Neural
//! Networks with Direct Feedback Alignment* (Filipovich et al., Optica 2022)
//! as a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the digital control system and every analog
//!   substrate simulated at device level: micro-ring resonator (MRR) physics,
//!   thermal/carrier tuning and calibration, balanced photodetection, TIAs,
//!   data converters, the WDM optical link budget, the photonic weight bank,
//!   a GeMM compiler that tiles arbitrary matrix products onto the finite
//!   bank, the paper's energy/speed model (Eqs. 2–4, Fig. 6), the dataset
//!   substrate, and the training coordinator that drives the AOT artifacts.
//! * **L2** — the MLP forward/backward (DFA, Eq. 1) written in JAX,
//!   AOT-lowered once to HLO text (`python/compile/`).
//! * **L1** — Pallas kernels for the weight-bank datapath, embedded in the
//!   same HLO.
//!
//! Python never runs on the training path. The runtime layer is
//! backend-abstracted behind [`runtime::StepEngine`]: the default build is
//! fully hermetic and executes every training-step artifact with the
//! pure-Rust [`runtime::NativeEngine`] (no XLA toolchain anywhere), while
//! `--features pjrt` compiles `artifacts/*.hlo.txt` through PJRT for the
//! compile-once/execute-many L2/L1 path. The `pjrt` feature additionally
//! requires vendoring the `xla` crate by hand — see the note in
//! `Cargo.toml` — since it is not part of the offline dependency set.
//!
//! Every engine reports hardware [`telemetry`] — analytic MAC counts,
//! optical cycles, and (on the photonic backend) modeled energy under
//! the paper's §5 component budget — surfaced per epoch in run records,
//! per request window in serve stats, and as a paper-comparison table by
//! `pdfa report`:
//!
//! ```
//! use photonic_dfa::runtime::{open, Backend};
//!
//! let engine = open("artifacts", Backend::Native).unwrap();
//! assert_eq!(engine.platform_name(), "native");
//! let fwd = engine.load("fwd_tiny").unwrap();
//! assert_eq!(fwd.spec().inputs.len(), 7); // w1 b1 w2 b2 w3 b3 x
//! // nothing executed yet: the telemetry counters are still zero
//! assert!(engine.telemetry().is_empty());
//! ```
//!
//! See `README.md` for the workspace layout, test/bench entry points and
//! the `pjrt` feature flag, `DESIGN.md` for the module map and subsystem
//! contracts, `EXPERIMENTS.md` for the paper-figure reproduction guide,
//! and `ROADMAP.md` for the project north star and open items.

pub mod analysis;
pub mod coordinator;
pub mod data;
pub mod dfa;
pub mod energy;
pub mod error;
pub mod experiments;
pub mod gemm;
pub mod photonics;
pub mod runtime;
pub mod serve;
pub mod telemetry;
pub mod tensor;
pub mod util;

pub use error::{Error, Result};
