//! Component power table (§5) with provenance.
//!
//! These per-part numbers feed both the analytic tables (`pdfa energy`)
//! and the runtime accrual path: [`crate::energy::EnergyModel`] rolls
//! them up into joules-per-optical-cycle for the telemetry layer, so a
//! training run's modeled energy is priced from exactly the same §5
//! budget as the headline E_op figures.

use crate::photonics::constants as k;

/// How the weight-bank MRRs are held on resonance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MrrTuning {
    /// Embedded N-doped heaters lock out fabrication offsets: ~14.12 mW/MRR.
    HeaterLocked,
    /// Post-fabrication trimming corrects offsets permanently; only the
    /// ~120 µW carrier-depletion tuner remains.
    Trimmed,
}

impl MrrTuning {
    pub fn power_per_mrr_w(&self) -> f64 {
        match self {
            MrrTuning::HeaterLocked => k::P_MRR_HEATER_W,
            MrrTuning::Trimmed => k::P_MRR_TRIMMED_W,
        }
    }
}

/// Electrical power of the active components around the bank.
#[derive(Debug, Clone, Copy)]
pub struct ComponentPowers {
    /// DAC driving one input-modulator channel (W).
    pub dac_w: f64,
    /// ADC digitising one row output (W).
    pub adc_w: f64,
    /// TIA energy per converted bit (J/bit).
    pub tia_j_per_bit: f64,
    /// MRR resonance control (W per MRR).
    pub mrr_tuning: MrrTuning,
}

impl ComponentPowers {
    /// The §5 part selection.
    pub fn paper(tuning: MrrTuning) -> ComponentPowers {
        ComponentPowers {
            dac_w: k::P_DAC_W,          // Alphacore D12B10G, 180 mW
            adc_w: k::P_ADC_W,          // Alphacore A6B12G, 13 mW
            tia_j_per_bit: k::TIA_PJ_PER_BIT, // 2.4 pJ/bit (20 GS/s part)
            mrr_tuning: tuning,
        }
    }

    /// TIA power at symbol rate f_s: one output sample per cycle per row.
    ///
    /// 2.4 pJ/bit × f_s reproduces the paper's §5 totals (E_op = 1.0 pJ at
    /// 50×20 with heaters — see model::tests), pinning down the paper's
    /// per-TIA accounting to one bit-time per sample.
    pub fn tia_w(&self, f_s_hz: f64) -> f64 {
        self.tia_j_per_bit * f_s_hz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_values() {
        let c = ComponentPowers::paper(MrrTuning::HeaterLocked);
        assert!((c.dac_w - 0.180).abs() < 1e-12);
        assert!((c.adc_w - 0.013).abs() < 1e-12);
        assert!((c.tia_j_per_bit - 2.4e-12).abs() < 1e-20);
        assert!((c.mrr_tuning.power_per_mrr_w() - 14.12e-3).abs() < 1e-9);
        assert!(
            (ComponentPowers::paper(MrrTuning::Trimmed)
                .mrr_tuning
                .power_per_mrr_w()
                - 120e-6)
                .abs()
                < 1e-12
        );
    }

    #[test]
    fn tia_power_at_10ghz() {
        let c = ComponentPowers::paper(MrrTuning::HeaterLocked);
        assert!((c.tia_w(10e9) - 0.024).abs() < 1e-9); // 24 mW
    }
}
