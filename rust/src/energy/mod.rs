//! Energy & speed model of the photonic DFA architecture (paper §5).
//!
//! Reproduces, analytically, every number in the evaluation:
//!
//! * Eq. (2): OPS = 2·f_s·M·N (Fig. 6 x-axis scale, 20 TOPS headline)
//! * Eq. (3): per-laser optical power floor (in photonics::laser)
//! * Eq. (4): wall-plug power roll-up over lasers, MRRs, DACs, TIAs, ADCs
//! * Fig. 6: optimal E_op vs MAC-cell count for heater-locked vs trimmed MRRs
//! * compute density: 5.78 TOPS/mm² at the 47.4 µm × 73.0 µm MAC cell
//!
//! * [`components`] — per-part power table with §5 provenance
//! * [`model`]      — Eqs. (2)/(4), E_op, and the [`EnergyModel`] that
//!   prices the telemetry layer's optical cycles in joules
//! * [`sweep`]      — the Fig. 6 optimiser over bank aspect ratios
//! * [`area`]       — compute density
//!
//! The analytic tables are rendered by `pdfa energy`; the *runtime* side
//! — attaching [`EnergyModel`] to a live photonic engine so every
//! training step accrues modeled joules — lives in [`crate::telemetry`]
//! and surfaces through `pdfa report`.

pub mod area;
pub mod components;
pub mod model;
pub mod sweep;

pub use components::{ComponentPowers, MrrTuning};
pub use model::{ArchitectureModel, EnergyModel, PowerBreakdown};
pub use sweep::{optimal_energy_curve, OptimalPoint};
