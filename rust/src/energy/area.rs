//! Chip area and compute density (§5).

use crate::photonics::constants as k;

/// Area of an M × N bank of photonic MAC cells (m²). The 47.4 µm × 73.0 µm
/// cell already includes waveguide/electronic routing, bonding pads and
/// anti-crosstalk spacing (§5).
pub fn bank_area_m2(m: usize, n: usize) -> f64 {
    (m * n) as f64 * k::MAC_CELL_AREA_M2
}

/// Compute density in OPS per m².
pub fn compute_density_ops_per_m2(m: usize, n: usize, f_s_hz: f64) -> f64 {
    2.0 * f_s_hz * (m * n) as f64 / bank_area_m2(m, n)
}

/// Compute density in TOPS/mm² — the unit §5 quotes (5.78 for any bank,
/// since both OPS and area scale with M·N).
pub fn compute_density_tops_per_mm2(f_s_hz: f64) -> f64 {
    2.0 * f_s_hz / k::MAC_CELL_AREA_M2 / 1e12 / 1e6
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_density_578() {
        let d = compute_density_tops_per_mm2(k::F_S_HZ);
        assert!((d - 5.78).abs() < 0.02, "density {d}");
    }

    #[test]
    fn headline_bank_area() {
        // 1000 cells x 3460.2 µm² ≈ 3.46 mm²
        let a = bank_area_m2(50, 20);
        assert!((a - 3.4602e-6).abs() < 1e-9, "{a}");
        let d = compute_density_ops_per_m2(50, 20, k::F_S_HZ);
        assert!((d / 1e18 - 5.78).abs() < 0.02); // 5.78e18 OPS/m² = 5.78 TOPS/mm²
    }

    #[test]
    fn density_independent_of_shape() {
        let a = compute_density_ops_per_m2(10, 10, k::F_S_HZ);
        let b = compute_density_ops_per_m2(200, 17, k::F_S_HZ);
        assert!((a - b).abs() < 1e-6 * a);
    }
}
