//! The Fig. 6 sweep: optimal E_op as a function of MAC-cell count.
//!
//! For every total cell count C the figure plots min over bank aspect
//! ratios (M, N) with M·N = C and M, N ≥ 5 of the Eq. (4)/Eq. (2) energy
//! per operation, for both MRR-locking schemes, at 10 GHz and 6 bits.

use super::components::MrrTuning;
use super::model::ArchitectureModel;

/// One point of the Fig. 6 curve.
#[derive(Debug, Clone, Copy)]
pub struct OptimalPoint {
    pub cells: usize,
    pub best_m: usize,
    pub best_n: usize,
    pub e_op_j: f64,
}

/// Minimise E_op over factorisations M·N = `cells` with M, N ≥ `min_dim`.
/// Returns None when `cells` has no admissible factorisation.
pub fn optimal_for_cells(
    base: ArchitectureModel,
    cells: usize,
    min_dim: usize,
) -> Option<OptimalPoint> {
    let mut best: Option<OptimalPoint> = None;
    let mut m = min_dim;
    while m * m <= cells * cells / (min_dim * min_dim) && m <= cells / min_dim {
        if cells % m == 0 {
            let n = cells / m;
            if n >= min_dim {
                for (mm, nn) in [(m, n), (n, m)] {
                    let e = base.with_dims(mm, nn).energy_per_op();
                    if best.map_or(true, |b| e < b.e_op_j) {
                        best = Some(OptimalPoint {
                            cells,
                            best_m: mm,
                            best_n: nn,
                            e_op_j: e,
                        });
                    }
                }
            }
        }
        m += 1;
    }
    best
}

/// The full Fig. 6 curve for one tuning scheme: log-spaced cell counts from
/// `lo` to `hi`, keeping only counts that admit an (M, N ≥ 5) factorisation.
pub fn optimal_energy_curve(
    tuning: MrrTuning,
    lo: usize,
    hi: usize,
    points: usize,
) -> Vec<OptimalPoint> {
    let base = ArchitectureModel::paper(tuning);
    let mut out = Vec::new();
    let (llo, lhi) = ((lo as f64).ln(), (hi as f64).ln());
    let mut last_cells = 0;
    for i in 0..points {
        let target = (llo + (lhi - llo) * i as f64 / (points - 1).max(1) as f64).exp();
        // Fig. 6 plots the *ideal* bank dimensions per cell count: search a
        // small window above the target so prime-ish counts with only
        // degenerate factorisations don't distort the curve.
        let start = (target.round() as usize).max(lo.max(25));
        let window_end = ((start as f64 * 1.08) as usize).max(start + 4).min(hi);
        let mut best: Option<OptimalPoint> = None;
        for cells in start..=window_end {
            if let Some(p) = optimal_for_cells(base, cells, 5) {
                if best.as_ref().map_or(true, |b| p.e_op_j < b.e_op_j) {
                    best = Some(p);
                }
            }
        }
        if let Some(p) = best {
            if p.cells != last_cells {
                last_cells = p.cells;
                out.push(p);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optimal_respects_min_dim() {
        let base = ArchitectureModel::paper(MrrTuning::HeaterLocked);
        let p = optimal_for_cells(base, 1000, 5).unwrap();
        assert!(p.best_m >= 5 && p.best_n >= 5);
        assert_eq!(p.best_m * p.best_n, 1000);
        // primes below min_dim^2 have no admissible factorisation
        assert!(optimal_for_cells(base, 997, 5).is_none());
    }

    #[test]
    fn optimal_beats_or_matches_square() {
        let base = ArchitectureModel::paper(MrrTuning::Trimmed);
        let p = optimal_for_cells(base, 400, 5).unwrap();
        let square = base.with_dims(20, 20).energy_per_op();
        assert!(p.e_op_j <= square + 1e-20);
    }

    #[test]
    fn heater_curve_above_trimmed_curve() {
        // Fig. 6: heater locking costs ~3-4x more per op at every scale
        let heater = optimal_energy_curve(MrrTuning::HeaterLocked, 25, 10_000, 12);
        let trimmed = optimal_energy_curve(MrrTuning::Trimmed, 25, 10_000, 12);
        assert!(!heater.is_empty() && !trimmed.is_empty());
        for (h, t) in heater.iter().zip(&trimmed) {
            // at small scale shared DAC cost dominates both schemes; the
            // heater penalty grows with cell count
            let factor = if h.cells >= 500 { 1.5 } else { 1.0 };
            assert!(h.e_op_j > factor * t.e_op_j, "{h:?} vs {t:?}");
        }
    }

    #[test]
    fn curves_trend_downward() {
        // E_op falls with scale across the Fig. 6 range
        let c = optimal_energy_curve(MrrTuning::Trimmed, 25, 100_000, 16);
        assert!(c.len() >= 8);
        assert!(c.last().unwrap().e_op_j < c.first().unwrap().e_op_j / 3.0);
    }

    #[test]
    fn heater_optimal_prefers_wide_banks() {
        // heaters charge per MRR ~ N(M+1): at fixed cells the optimiser
        // should push toward large M (few channels, many rows) since the
        // +1 column of input modulators then amortises.
        let base = ArchitectureModel::paper(MrrTuning::HeaterLocked);
        let p = optimal_for_cells(base, 1000, 5).unwrap();
        assert!(p.best_m >= p.best_n, "{p:?}");
    }
}
