//! Eqs. (2) and (4): throughput and wall-plug power of an M × N bank.

use super::components::{ComponentPowers, MrrTuning};
use crate::photonics::constants as k;
use crate::photonics::laser::min_laser_power;

/// Per-term wall-plug power decomposition of Eq. (4).
#[derive(Debug, Clone, Copy)]
pub struct PowerBreakdown {
    pub laser_w: f64,
    pub mrr_w: f64,
    pub dac_w: f64,
    pub tia_w: f64,
    pub adc_w: f64,
}

impl PowerBreakdown {
    pub fn total_w(&self) -> f64 {
        self.laser_w + self.mrr_w + self.dac_w + self.tia_w + self.adc_w
    }
}

/// The analytic architecture model for one weight-bank configuration.
#[derive(Debug, Clone, Copy)]
pub struct ArchitectureModel {
    /// Bank rows M (fan-out) and WDM channels N.
    pub m: usize,
    pub n: usize,
    /// Operational rate f_s (Hz); §5 caps it at the 10 GS/s DAC.
    pub f_s_hz: f64,
    /// Fixed-point precision N_b of the analog datapath.
    pub n_bits: u32,
    pub components: ComponentPowers,
}

impl ArchitectureModel {
    /// The §5 headline configuration: 50 × 20 @ 10 GHz, 6 bits.
    pub fn paper(tuning: MrrTuning) -> ArchitectureModel {
        ArchitectureModel {
            m: k::BANK_ROWS,
            n: k::BANK_COLS,
            f_s_hz: k::F_S_HZ,
            n_bits: k::N_BITS,
            components: ComponentPowers::paper(tuning),
        }
    }

    pub fn with_dims(self, m: usize, n: usize) -> ArchitectureModel {
        ArchitectureModel { m, n, ..self }
    }

    /// Eq. (2): OPS = 2 · f_s · M · N (a MAC = one multiply + one add).
    pub fn ops_per_second(&self) -> f64 {
        2.0 * self.f_s_hz * (self.m * self.n) as f64
    }

    /// Eq. (4): P_total = N·P_laser + N(M+1)·P_MRR + N·P_DAC + M(P_TIA + P_ADC).
    ///
    /// N(M+1) MRRs: the M×N weight bank plus the N input modulators.
    pub fn power_breakdown(&self) -> PowerBreakdown {
        let c = &self.components;
        let p_laser = min_laser_power(self.m, self.n_bits, self.f_s_hz);
        PowerBreakdown {
            laser_w: self.n as f64 * p_laser,
            mrr_w: (self.n * (self.m + 1)) as f64 * c.mrr_tuning.power_per_mrr_w(),
            dac_w: self.n as f64 * c.dac_w,
            tia_w: self.m as f64 * c.tia_w(self.f_s_hz),
            adc_w: self.m as f64 * c.adc_w,
        }
    }

    /// E_op = P_total / OPS (J per operation).
    pub fn energy_per_op(&self) -> f64 {
        self.power_breakdown().total_w() / self.ops_per_second()
    }

    /// Energy per MAC (= 2 ops).
    pub fn energy_per_mac(&self) -> f64 {
        2.0 * self.energy_per_op()
    }
}

/// Runtime energy-accrual model: prices the telemetry layer's optical
/// cycles in joules under the paper's §5 component budget (laser, MRR
/// tuning, DAC, TIA, ADC — balanced photodetection is passive).
///
/// One optical cycle drives the whole M × N bank for one symbol period,
/// so a cycle costs `P_total / f_s` joules (Eq. 4 over Eq. 2's rate).
/// The photonic engine builds one of these from its
/// [`crate::runtime::PhysicsConfig`] bank geometry and multiplies it
/// into every [`crate::telemetry::Telemetry`] snapshot.
///
/// ```
/// use photonic_dfa::energy::{EnergyModel, MrrTuning};
///
/// // the §5 bank: 50 × 20 at 10 GHz, heater-locked
/// let m = EnergyModel::for_bank(50, 20, MrrTuning::HeaterLocked);
/// // one cycle = 1000 MACs = 2000 ops at ~1 pJ/op => ~2 nJ
/// let per_cycle = m.joules_per_cycle();
/// assert!((per_cycle - 2.0e-9).abs() < 0.2e-9, "{per_cycle}");
/// assert_eq!(m.joules(10), 10.0 * per_cycle);
/// ```
#[derive(Debug, Clone, Copy)]
pub struct EnergyModel {
    arch: ArchitectureModel,
}

impl EnergyModel {
    /// Model for an M × N bank with the paper's §5 part selection and
    /// the given MRR tuning scheme.
    pub fn for_bank(rows: usize, cols: usize, tuning: MrrTuning) -> EnergyModel {
        EnergyModel { arch: ArchitectureModel::paper(tuning).with_dims(rows, cols) }
    }

    /// The underlying Eq. (2)/(4) architecture model.
    pub fn arch(&self) -> &ArchitectureModel {
        &self.arch
    }

    /// Joules per optical cycle: `P_total / f_s`.
    pub fn joules_per_cycle(&self) -> f64 {
        self.arch.power_breakdown().total_w() / self.arch.f_s_hz
    }

    /// Modeled energy of `cycles` optical cycles.
    pub fn joules(&self, cycles: u64) -> f64 {
        cycles as f64 * self.joules_per_cycle()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq2_headline_20_tops() {
        let m = ArchitectureModel::paper(MrrTuning::HeaterLocked);
        assert!((m.ops_per_second() - 20e12).abs() < 1e6, "{}", m.ops_per_second());
    }

    #[test]
    fn eq4_headline_1pj_with_heaters() {
        // §5: "we can achieve ... an energy consumption E_op of 1.0 pJ per
        // operation using MRRs with thermal heaters"
        let m = ArchitectureModel::paper(MrrTuning::HeaterLocked);
        let e_op = m.energy_per_op();
        assert!(
            (e_op - 1.0e-12).abs() < 0.05e-12,
            "E_op = {:.4} pJ, want ~1.0",
            e_op * 1e12
        );
    }

    #[test]
    fn eq4_headline_028pj_with_trimming() {
        // §5: "0.28 pJ per operation using post-fabrication trimming"
        let m = ArchitectureModel::paper(MrrTuning::Trimmed);
        let e_op = m.energy_per_op();
        assert!(
            (e_op - 0.28e-12).abs() < 0.02e-12,
            "E_op = {:.4} pJ, want ~0.28",
            e_op * 1e12
        );
    }

    #[test]
    fn heater_power_dominates_locked_config() {
        let m = ArchitectureModel::paper(MrrTuning::HeaterLocked);
        let b = m.power_breakdown();
        assert!(b.mrr_w > 0.7 * b.total_w(), "{b:?}");
        // and vanishes with trimming
        let t = ArchitectureModel::paper(MrrTuning::Trimmed);
        let bt = t.power_breakdown();
        assert!(bt.mrr_w < 0.05 * b.total_w());
        // total ~20 W vs ~5.7 W (§5 figures)
        assert!((b.total_w() - 20.0).abs() < 1.0, "{}", b.total_w());
        assert!((bt.total_w() - 5.7).abs() < 0.5, "{}", bt.total_w());
    }

    #[test]
    fn eop_improves_with_scale_then_saturates() {
        // Fig. 6 trend: per-op energy falls as the bank grows (fixed costs
        // amortise) until per-cell costs dominate.
        let base = ArchitectureModel::paper(MrrTuning::Trimmed);
        let small = base.with_dims(5, 5).energy_per_op();
        let mid = base.with_dims(50, 20).energy_per_op();
        let big = base.with_dims(200, 50).energy_per_op();
        assert!(small > mid && mid > big, "{small} {mid} {big}");
    }

    #[test]
    fn energy_model_prices_cycles_consistently() {
        // J/cycle over the cycle's M·N MACs == energy_per_mac (= 2·E_op)
        let m = EnergyModel::for_bank(50, 20, MrrTuning::HeaterLocked);
        let per_mac = m.joules_per_cycle() / (50.0 * 20.0);
        assert!((per_mac - m.arch().energy_per_mac()).abs() < 1e-18);
        assert_eq!(m.joules(0), 0.0);
        assert!((m.joules(3) - 3.0 * m.joules_per_cycle()).abs() < 1e-18);
        // trimming removes the heater budget
        let t = EnergyModel::for_bank(50, 20, MrrTuning::Trimmed);
        assert!(t.joules_per_cycle() < 0.5 * m.joules_per_cycle());
    }

    #[test]
    fn energy_per_mac_is_twice_per_op() {
        let m = ArchitectureModel::paper(MrrTuning::HeaterLocked);
        assert!((m.energy_per_mac() - 2.0 * m.energy_per_op()).abs() < 1e-20);
        // headline claim: "less than one picojoule per MAC" — holds for the
        // trimmed configuration
        let t = ArchitectureModel::paper(MrrTuning::Trimmed);
        assert!(t.energy_per_mac() < 1.0e-12);
    }
}
