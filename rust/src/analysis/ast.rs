//! Item/function scoping and pragma recovery over the token stream.
//!
//! [`SourceFile::parse`] finds every `fn` item (free functions, methods,
//! nested fns, fns inside closures' parents), records its body as a
//! token-index range, and attaches the lint pragmas written in the
//! comment block directly above its signature. It also marks
//! `#[cfg(test)] mod … { … }` ranges so rules can skip test code, and
//! indexes every `// lint: …` comment by line for line-scoped pragmas.
//!
//! ## Pragma vocabulary
//!
//! Function-level (comment block above the `fn`, attributes allowed in
//! between):
//! * `// lint: hot-path` — the hot-path-alloc rule roots its closure here.
//! * `// lint: thread-body` — the panic-free-serve rule roots its
//!   closure here.
//! * `// lint: rng-region` — the keyed-rng-only rule checks this body.
//! * `// lint: allow(<rule>) — why` — suppress `<rule>` in this body.
//!   The written contract after the `)` is mandatory: a bare `allow`
//!   suppresses nothing.
//! * `// lint: boundary(<rule>) — why` — stop `<rule>`'s transitive
//!   closure at this fn: neither its body nor anything reachable only
//!   through it is checked. Requires a written contract; counted as
//!   suppression debt.
//!
//! Line-level (a comment on the flagged line, or the comment line(s)
//! directly above it):
//! * `// lint: allow(<rule>) — why` — suppress `<rule>` on the next
//!   code line. On a call-site line this also prunes that call edge
//!   from `<rule>`'s transitive closure.
//! * `// lint: timing: why` — sanction a wallclock read.
//! * `// lint: ordering: why` — justify a non-`Relaxed` atomic ordering.
//! * `// lint: guarded: why` — sanction an index expression in a
//!   thread body by stating the bounds invariant.

use std::collections::{BTreeMap, BTreeSet};

use super::lexer::{lex, Tok, TokKind};

/// One parsed `// lint: …` pragma.
#[derive(Debug, Clone, PartialEq)]
pub struct Pragma {
    /// `hot-path`, `allow`, `boundary`, `timing`, `ordering`, ….
    pub name: String,
    /// `allow(arg)` argument or the text after `name:` (justification).
    pub arg: String,
    /// Written contract: the text after `name(arg)`, dashes/colons
    /// stripped. For the `name: free text` form it equals `arg` — the
    /// free text is its own justification.
    pub note: String,
    /// Line of the comment carrying the pragma.
    pub line: u32,
}

/// Parse a comment's text into a pragma, if it is one. Accepts
/// `// lint: name`, `// lint: name(arg) — why`, `// lint: name: free text`.
pub fn parse_pragma(comment: &str, line: u32) -> Option<Pragma> {
    let body = comment.trim_start_matches('/').trim_start_matches('!').trim();
    let rest = body.strip_prefix("lint:")?.trim();
    if rest.is_empty() {
        return None;
    }
    let name_end = rest
        .find(|c: char| c == '(' || c == ':' || c.is_whitespace())
        .unwrap_or(rest.len());
    let name = rest[..name_end].to_string();
    let tail = rest[name_end..].trim();
    let (arg, note) = if let Some(t) = tail.strip_prefix('(') {
        let arg = t.split(')').next().unwrap_or("").trim().to_string();
        let after = t.split_once(')').map(|(_, a)| a).unwrap_or("");
        let note = after
            .trim_start_matches(|c: char| {
                c.is_whitespace() || c == '—' || c == '-' || c == ':'
            })
            .trim()
            .to_string();
        (arg, note)
    } else if let Some(t) = tail.strip_prefix(':') {
        let why = t.trim().to_string();
        (why.clone(), why)
    } else {
        (String::new(), String::new())
    };
    Some(Pragma { name, arg, note, line })
}

/// One `fn` item: name, signature line, body token range, attached
/// pragmas, owning `impl` type (methods/associated fns) if any.
#[derive(Debug, Clone)]
pub struct Function {
    pub name: String,
    /// Line of the `fn` keyword.
    pub line: u32,
    /// Token-index range of the body, braces included (`start == end`
    /// for bodiless trait-method declarations).
    pub body: (usize, usize),
    pub pragmas: Vec<Pragma>,
    /// Base type name of the innermost enclosing `impl` block (`None`
    /// for free fns; trait methods in `trait` blocks are also `None`).
    pub owner: Option<String>,
    /// Parameter name → last type-forming ident of its annotation
    /// (`xs: &[Tile]` → `Tile`). Receiver-typing hints for the graph.
    pub params: BTreeMap<String, String>,
    /// Last type-forming ident of the return type, if any.
    pub ret_ty: Option<String>,
    /// Does the return type mention a `*Guard*` ident? Lock-order uses
    /// this: only guard-returning callees leak held locks to callers.
    pub ret_guard: bool,
    /// Does the fn take a `self` receiver (i.e. is it dot-callable)?
    pub has_self: bool,
}

impl Function {
    pub fn has_pragma(&self, name: &str) -> bool {
        self.pragmas.iter().any(|p| p.name == name)
    }

    /// Effective fn-level suppression: an `allow(rule)` pragma with a
    /// non-empty written contract. Bare allows are inert by design —
    /// the acceptance bar is "every suppression carries a contract".
    pub fn allows(&self, rule: &str) -> bool {
        self.pragmas
            .iter()
            .any(|p| p.name == "allow" && p.arg == rule && !p.note.is_empty())
    }

    /// Transitive-closure boundary for `rule` (written contract
    /// required, same as `allows`).
    pub fn boundary(&self, rule: &str) -> bool {
        self.pragmas
            .iter()
            .any(|p| p.name == "boundary" && p.arg == rule && !p.note.is_empty())
    }
}

/// One `impl` block: the base name of the implemented type, the trait
/// being implemented (for `impl Trait for Type`), and the token range
/// of the block body.
#[derive(Debug, Clone)]
pub struct ImplBlock {
    pub ty: String,
    /// `Some(trait_name)` for trait impls — the graph uses this to
    /// expand trait-typed receivers to their implementors.
    pub trait_of: Option<String>,
    pub range: (usize, usize),
}

/// A lexed + scoped source file, ready for the rules.
#[derive(Debug)]
pub struct SourceFile {
    /// Path with forward slashes, as the walker found it (rules match
    /// on suffixes so the root prefix does not matter).
    pub path: String,
    pub toks: Vec<Tok>,
    pub fns: Vec<Function>,
    /// Every pragma in the file, for line-scoped lookups.
    pub pragmas: Vec<Pragma>,
    /// Token-index ranges of `#[cfg(test)] mod … { … }` bodies.
    pub test_ranges: Vec<(usize, usize)>,
    /// Every `impl` block, for method-owner attribution.
    pub impls: Vec<ImplBlock>,
    /// Struct field name → declared type idents (`snaps: Vec<Snapshot>`
    /// records `Vec`'s inner ident heuristically as the *last* type
    /// ident, `Snapshot`). Aggregated crate-wide by the graph.
    pub fields: BTreeMap<String, BTreeSet<String>>,
    /// `static NAME: Type` declarations (name → last type ident).
    pub statics: BTreeMap<String, String>,
    /// `struct`/`enum` names declared in this file.
    pub types: BTreeSet<String>,
}

impl SourceFile {
    pub fn parse(path: &str, src: &str) -> SourceFile {
        let toks = lex(src);
        let pragmas = toks
            .iter()
            .filter(|t| t.is_comment())
            .filter_map(|t| parse_pragma(&t.text, t.line))
            .collect();
        let mut f = SourceFile {
            path: path.replace('\\', "/"),
            toks,
            fns: Vec::new(),
            pragmas,
            test_ranges: Vec::new(),
            impls: Vec::new(),
            fields: BTreeMap::new(),
            statics: BTreeMap::new(),
            types: BTreeSet::new(),
        };
        f.scan_items();
        f
    }

    /// Next non-comment token index at or after `i`.
    pub fn sig_at(&self, i: usize) -> Option<usize> {
        (i..self.toks.len()).find(|&j| !self.toks[j].is_comment())
    }

    /// Previous non-comment token index at or before `i`.
    pub fn sig_before(&self, i: usize) -> Option<usize> {
        (0..=i).rev().find(|&j| !self.toks[j].is_comment())
    }

    /// The innermost function whose body contains token `i`.
    pub fn enclosing_fn(&self, i: usize) -> Option<&Function> {
        self.fns
            .iter()
            .filter(|f| f.body.0 <= i && i < f.body.1)
            .min_by_key(|f| f.body.1 - f.body.0)
    }

    /// Is token `i` inside a `#[cfg(test)]` module?
    pub fn in_test(&self, i: usize) -> bool {
        self.test_ranges.iter().any(|&(s, e)| s <= i && i < e)
    }

    /// Line-scoped pragma lookup: a pragma named `name` whose comment
    /// sits on `line` itself, or on a comment line whose next code line
    /// is `line` (stacked comment blocks directly above count).
    pub fn line_pragma(&self, line: u32, name: &str) -> Option<&Pragma> {
        self.pragmas
            .iter()
            .find(|p| p.name == name && self.pragma_covers(p, line))
    }

    fn pragma_covers(&self, p: &Pragma, line: u32) -> bool {
        if p.line == line {
            return true;
        }
        // the first code line after the pragma's comment block
        let next_code = self
            .toks
            .iter()
            .filter(|t| !t.is_comment())
            .map(|t| t.line)
            .find(|&l| l > p.line);
        next_code == Some(line)
    }

    /// Find `impl` blocks, `struct`/`enum`/`static` type facts, `fn`
    /// items and `#[cfg(test)]` modules.
    fn scan_items(&mut self) {
        let mut impls = Vec::new();
        let mut fns = Vec::new();
        let mut tests = Vec::new();
        let mut fields: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
        let mut statics: BTreeMap<String, String> = BTreeMap::new();
        let mut types: BTreeSet<String> = BTreeSet::new();
        let n = self.toks.len();
        let mut i = 0;
        while i < n {
            let t = &self.toks[i];
            if t.is_ident("impl") {
                if let Some(b) = self.impl_block(i) {
                    impls.push(b);
                }
            } else if t.is_ident("struct") || t.is_ident("enum") {
                if let Some(nm) = self.sig_at(i + 1) {
                    if self.toks[nm].kind == TokKind::Ident {
                        types.insert(self.toks[nm].text.clone());
                    }
                }
                if t.is_ident("struct") {
                    self.struct_fields(i, &mut fields);
                }
            } else if t.is_ident("static") {
                self.static_ty(i, &mut statics);
            } else if t.is_ident("fn") {
                // `fn` keyword of an item (a fn-pointer type `fn(…)` has
                // no name ident after it)
                if let Some(ni) = self.sig_at(i + 1) {
                    if self.toks[ni].kind == TokKind::Ident {
                        let name = self.toks[ni].text.clone();
                        let line = t.line;
                        let body = self.fn_body_range(ni + 1);
                        let pragmas = self.fn_pragmas(i);
                        let owner = impls
                            .iter()
                            .filter(|b: &&ImplBlock| b.range.0 <= i && i < b.range.1)
                            .min_by_key(|b| b.range.1 - b.range.0)
                            .map(|b| b.ty.clone());
                        let (params, has_self) = self.param_types(ni);
                        let (ret_ty, ret_guard) = self.ret_info(ni, body.0);
                        fns.push(Function {
                            name,
                            line,
                            body,
                            pragmas,
                            owner,
                            params,
                            ret_ty,
                            ret_guard,
                            has_self,
                        });
                    }
                }
            } else if t.is_punct('#') && self.is_cfg_test(i) {
                if let Some((s, e)) = self.cfg_test_mod_range(i) {
                    tests.push((s, e));
                }
            }
            i += 1;
        }
        self.fns = fns;
        self.test_ranges = tests;
        self.impls = impls;
        self.fields = fields;
        self.statics = statics;
        self.types = types;
    }

    /// Last type-forming ident from `frm` until a stop punct at depth
    /// zero. `stops` are punct chars that end the run when angle and
    /// paren/bracket depth are both zero (closing `)`/`]` stops are
    /// honored at paren depth zero regardless of angle depth — a return
    /// type inside a param list ends at the list's `)`). Returns the
    /// ident and the index *of* the stopping token.
    pub(crate) fn type_run_last_ident(
        &self,
        frm: usize,
        stops: &str,
    ) -> (Option<String>, usize) {
        let mut angle = 0i32;
        let mut paren = 0i32;
        let mut last: Option<String> = None;
        let mut i = frm;
        let n = self.toks.len();
        while i < n {
            let t = &self.toks[i];
            if let Some(p) = t.punct() {
                match p {
                    '<' => angle += 1,
                    '>' if i > 0 && self.toks[i - 1].is_punct('-') => {}
                    '>' => angle = (angle - 1).max(0),
                    '(' | '[' => paren += 1,
                    ')' | ']' => {
                        if paren == 0 && stops.contains(p) {
                            return (last, i);
                        }
                        paren = (paren - 1).max(0);
                    }
                    _ if angle == 0 && paren == 0 && stops.contains(p) => {
                        return (last, i);
                    }
                    _ => {}
                }
            } else if t.kind == TokKind::Ident
                && !matches!(
                    t.text.as_str(),
                    "mut" | "dyn" | "impl" | "ref" | "const" | "as" | "where"
                        | "pub" | "crate" | "super" | "self"
                )
            {
                last = Some(t.text.clone());
            }
            i += 1;
        }
        (last, i)
    }

    /// Record `field: Type` pairs of the `struct` starting at `i`
    /// (brace-bodied structs only; tuple structs carry no named fields).
    fn struct_fields(&self, i: usize, fields: &mut BTreeMap<String, BTreeSet<String>>) {
        let Some(ni) = self.sig_at(i + 1) else { return };
        if self.toks[ni].kind != TokKind::Ident {
            return;
        }
        let mut j = self.sig_at(ni + 1);
        if j.is_some_and(|x| self.toks[x].is_punct('<')) {
            j = self.skip_angles(j.unwrap()).and_then(|nj| self.sig_at(nj));
        }
        let Some(j) = j.filter(|&x| self.toks[x].is_punct('{')) else { return };
        let end = self.match_brace(j);
        let mut depth = 0i32;
        let mut k = j;
        while k < end {
            match self.toks[k].punct() {
                Some('{') => depth += 1,
                Some('}') => depth -= 1,
                Some(':') if depth == 1 => {
                    // skip `::` path separators inside field types
                    if self.sig_at(k + 1).is_some_and(|x| self.toks[x].is_punct(':')) {
                        k = self.sig_at(k + 1).unwrap() + 1;
                        continue;
                    }
                    let prev = k.checked_sub(1).and_then(|x| self.sig_before(x));
                    if let Some(p) = prev.filter(|&x| self.toks[x].kind == TokKind::Ident) {
                        let fname = self.toks[p].text.clone();
                        let (ty, after) = self.type_run_last_ident(k + 1, ",}");
                        if let Some(ty) = ty {
                            fields.entry(fname).or_default().insert(ty);
                        }
                        k = after;
                        continue;
                    }
                }
                _ => {}
            }
            k += 1;
        }
    }

    /// Record the `static NAME: Type` declaration starting at `i`.
    fn static_ty(&self, i: usize, statics: &mut BTreeMap<String, String>) {
        let mut j = self.sig_at(i + 1);
        if j.is_some_and(|x| self.toks[x].is_ident("mut")) {
            j = self.sig_at(j.unwrap() + 1);
        }
        let Some(j) = j.filter(|&x| self.toks[x].kind == TokKind::Ident) else {
            return;
        };
        let name = self.toks[j].text.clone();
        if !self.sig_at(j + 1).is_some_and(|c| self.toks[c].is_punct(':')) {
            return;
        }
        let c = self.sig_at(j + 1).unwrap();
        let (ty, _) = self.type_run_last_ident(c + 1, "=;");
        if let Some(ty) = ty {
            statics.insert(name, ty);
        }
    }

    /// Parameter name → type ident for the fn whose name sits at
    /// `name_idx`, plus whether the fn takes a `self` receiver.
    fn param_types(&self, name_idx: usize) -> (BTreeMap<String, String>, bool) {
        let mut j = self.sig_at(name_idx + 1);
        if j.is_some_and(|x| self.toks[x].is_punct('<')) {
            j = self.skip_angles(j.unwrap()).and_then(|nj| self.sig_at(nj));
        }
        let Some(j) = j.filter(|&x| self.toks[x].is_punct('(')) else {
            return (BTreeMap::new(), false);
        };
        let mut out = BTreeMap::new();
        let mut has_self = false;
        let mut k = j + 1;
        let mut depth = 1i32;
        let n = self.toks.len();
        while k < n && depth > 0 {
            let t = &self.toks[k];
            if t.is_ident("self") && depth == 1 {
                has_self = true;
            }
            match t.punct() {
                Some('(') | Some('[') => depth += 1,
                Some(')') | Some(']') => depth -= 1,
                Some(':') if depth == 1 => {
                    if self.sig_at(k + 1).is_some_and(|x| self.toks[x].is_punct(':')) {
                        k = self.sig_at(k + 1).unwrap() + 1;
                        continue;
                    }
                    let prev = k.checked_sub(1).and_then(|x| self.sig_before(x));
                    let named = prev.filter(|&x| {
                        self.toks[x].kind == TokKind::Ident
                            && !self.toks[x].is_ident("self")
                    });
                    if let Some(p) = named {
                        let pname = self.toks[p].text.clone();
                        let (ty, after) = self.type_run_last_ident(k + 1, ",)");
                        if let Some(ty) = ty {
                            out.insert(pname, ty);
                        }
                        k = after;
                        continue;
                    }
                }
                _ => {}
            }
            k += 1;
        }
        (out, has_self)
    }

    /// Return-type facts for the fn whose name sits at `name_idx`: the
    /// last type-forming ident after `->`, and whether any return-type
    /// token names a `*Guard*` type.
    fn ret_info(&self, name_idx: usize, body_start: usize) -> (Option<String>, bool) {
        let mut k = name_idx;
        while k + 1 < body_start {
            if self.toks[k].is_punct('-') && self.toks[k + 1].is_punct('>') {
                let (ty, _) = self.type_run_last_ident(k + 2, "{;");
                let guard = (k + 2..body_start).any(|x| {
                    self.toks[x].kind == TokKind::Ident
                        && self.toks[x].text.contains("Guard")
                });
                return (ty, guard);
            }
            k += 1;
        }
        (None, false)
    }

    /// Index of the `]` matching the `[` at `open` (forward walk).
    pub(crate) fn match_bracket_fwd(&self, open: usize) -> Option<usize> {
        let mut depth = 0i32;
        for i in open..self.toks.len() {
            match self.toks[i].punct() {
                Some('[') => depth += 1,
                Some(']') => {
                    depth -= 1;
                    if depth == 0 {
                        return Some(i);
                    }
                }
                _ => {}
            }
        }
        None
    }

    /// Index of the `{` matching the `}` at `close` (backward walk).
    pub(crate) fn match_brace_back(&self, close: usize) -> Option<usize> {
        let mut depth = 0i32;
        let mut i = close as isize;
        while i >= 0 {
            match self.toks[i as usize].punct() {
                Some('}') => depth += 1,
                Some('{') => {
                    depth -= 1;
                    if depth == 0 {
                        return Some(i as usize);
                    }
                }
                _ => {}
            }
            i -= 1;
        }
        None
    }

    /// Index of the `[` matching the `]` at `close` (backward walk).
    pub(crate) fn match_bracket_back(&self, close: usize) -> Option<usize> {
        let mut depth = 0i32;
        let mut i = close as isize;
        while i >= 0 {
            match self.toks[i as usize].punct() {
                Some(']') => depth += 1,
                Some('[') => {
                    depth -= 1;
                    if depth == 0 {
                        return Some(i as usize);
                    }
                }
                _ => {}
            }
            i -= 1;
        }
        None
    }

    /// Index of the `(` matching the `)` at `close` (backward walk).
    pub(crate) fn match_paren_back(&self, close: usize) -> Option<usize> {
        let mut depth = 0i32;
        let mut i = close as isize;
        while i >= 0 {
            match self.toks[i as usize].punct() {
                Some(')') => depth += 1,
                Some('(') => {
                    depth -= 1;
                    if depth == 0 {
                        return Some(i as usize);
                    }
                }
                _ => {}
            }
            i -= 1;
        }
        None
    }

    /// Parse the `impl` starting at `i`: skip generics, read the
    /// implemented type's base name (the type after `for` when the
    /// block is a trait impl), and return its brace-balanced range.
    fn impl_block(&self, i: usize) -> Option<ImplBlock> {
        let mut j = self.sig_at(i + 1)?;
        // generic parameter list on the impl itself
        if self.toks[j].is_punct('<') {
            j = self.skip_angles(j)?;
        }
        // walk the head: idents form candidate type names; `for` resets
        // to the implemented type (what came before it was the trait);
        // `<…>` generic args are skipped; stop at the block's `{`.
        let mut ty = String::new();
        let mut trait_of: Option<String> = None;
        loop {
            let k = self.sig_at(j)?;
            let t = &self.toks[k];
            if t.is_punct('{') {
                if ty.is_empty() {
                    return None;
                }
                return Some(ImplBlock { ty, trait_of, range: (i, self.match_brace(k)) });
            } else if t.is_punct('<') {
                j = self.skip_angles(k)?;
            } else if t.is_ident("for") {
                trait_of = (!ty.is_empty()).then(|| ty.clone());
                ty.clear();
                j = k + 1;
            } else if t.kind == TokKind::Ident {
                // path segments overwrite (keep the last: `fmt::Display`
                // → `Display`), keywords like dyn/mut are harmless here
                ty = t.text.clone();
                j = k + 1;
            } else {
                j = k + 1; // `::`, `&`, lifetimes, `(`/`)` in fn traits
            }
        }
    }

    /// Index one past the `>` matching the `<` at `open`, treating the
    /// `>` of a `->` arrow as plain punctuation. Shared with the call
    /// graph's turbofish handling.
    pub(crate) fn skip_angles(&self, open: usize) -> Option<usize> {
        let mut depth = 0i32;
        let mut i = open;
        while i < self.toks.len() {
            let t = &self.toks[i];
            match t.punct() {
                Some('<') => depth += 1,
                Some('>') if i > 0 && self.toks[i - 1].is_punct('-') => {}
                Some('>') => {
                    depth -= 1;
                    if depth == 0 {
                        return Some(i + 1);
                    }
                }
                _ => {}
            }
            i += 1;
        }
        None
    }

    /// From just after the fn name: skip the signature (parens, generics,
    /// return type, where clause) to the opening `{` of the body and
    /// return the brace-balanced range. `;` at bracket depth 0 means a
    /// bodiless declaration.
    fn fn_body_range(&self, from: usize) -> (usize, usize) {
        let n = self.toks.len();
        let mut depth = 0i32; // () and [] nesting inside the signature
        let mut i = from;
        while i < n {
            let t = &self.toks[i];
            match t.punct() {
                Some('(') | Some('[') => depth += 1,
                Some(')') | Some(']') => depth -= 1,
                Some('{') if depth == 0 => {
                    let end = self.match_brace(i);
                    return (i, end);
                }
                Some(';') if depth == 0 => return (i, i),
                _ => {}
            }
            i += 1;
        }
        (n, n)
    }

    /// Index one past the `}` matching the `{` at `open`.
    fn match_brace(&self, open: usize) -> usize {
        let mut depth = 0i32;
        for i in open..self.toks.len() {
            match self.toks[i].punct() {
                Some('{') => depth += 1,
                Some('}') => {
                    depth -= 1;
                    if depth == 0 {
                        return i + 1;
                    }
                }
                _ => {}
            }
        }
        self.toks.len()
    }

    /// Pragmas attached to the fn at token `fn_idx`: comments in the
    /// contiguous block above it, looking back across attributes and
    /// visibility/qualifier keywords.
    fn fn_pragmas(&self, fn_idx: usize) -> Vec<Pragma> {
        let mut out = Vec::new();
        let mut i = fn_idx;
        while i > 0 {
            i -= 1;
            let t = &self.toks[i];
            if t.is_comment() {
                if let Some(p) = parse_pragma(&t.text, t.line) {
                    out.push(p);
                }
                continue;
            }
            match (t.kind, t.text.as_str()) {
                (TokKind::Ident, "pub" | "unsafe" | "const" | "async" | "extern" | "crate"
                    | "super" | "self" | "in") => continue,
                (TokKind::Str, _) => continue, // extern "C"
                (TokKind::Punct, ")") => {
                    // pub(crate) — walk to the matching (
                    let mut depth = 0i32;
                    while i > 0 {
                        match self.toks[i].punct() {
                            Some(')') => depth += 1,
                            Some('(') => {
                                depth -= 1;
                                if depth == 0 {
                                    break;
                                }
                            }
                            _ => {}
                        }
                        i -= 1;
                    }
                    continue;
                }
                (TokKind::Punct, "]") => {
                    // #[attr…] — walk to the matching [ and its #
                    let mut depth = 0i32;
                    while i > 0 {
                        match self.toks[i].punct() {
                            Some(']') => depth += 1,
                            Some('[') => {
                                depth -= 1;
                                if depth == 0 {
                                    break;
                                }
                            }
                            _ => {}
                        }
                        i -= 1;
                    }
                    if i > 0 && self.toks[i - 1].is_punct('#') {
                        i -= 1;
                    }
                    continue;
                }
                _ => break,
            }
        }
        out.reverse();
        out
    }

    /// Does the `#` at `i` open exactly `#[cfg(test)]`?
    fn is_cfg_test(&self, i: usize) -> bool {
        let want = ["[", "cfg", "(", "test", ")", "]"];
        let mut j = i + 1;
        for w in want {
            match self.sig_at(j) {
                Some(k) if self.toks[k].text == w => j = k + 1,
                _ => return false,
            }
        }
        true
    }

    /// Body range of the `mod … { … }` following the `#[cfg(test)]` at
    /// `i` (other attributes may sit in between).
    fn cfg_test_mod_range(&self, i: usize) -> Option<(usize, usize)> {
        let mut j = i + 1;
        // skip to the end of this attribute
        loop {
            let k = self.sig_at(j)?;
            j = k + 1;
            if self.toks[k].is_punct(']') {
                break;
            }
        }
        // skip further attributes, then expect `mod name {`
        loop {
            let k = self.sig_at(j)?;
            if self.toks[k].is_punct('#') {
                let close = (k..self.toks.len())
                    .find(|&x| self.toks[x].is_punct(']'))?;
                j = close + 1;
                continue;
            }
            if !self.toks[k].is_ident("mod") {
                return None;
            }
            j = k + 1;
            break;
        }
        let name = self.sig_at(j)?;
        let open = self.sig_at(name + 1)?;
        if !self.toks[open].is_punct('{') {
            return None; // `mod tests;` out-of-line — file not walked
        }
        Some((open, self.match_brace(open)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pragma_forms_parse() {
        let p = parse_pragma("// lint: hot-path", 3).unwrap();
        assert_eq!((p.name.as_str(), p.arg.as_str()), ("hot-path", ""));
        let p = parse_pragma("// lint: allow(hot-path-alloc) — cold error path", 4).unwrap();
        assert_eq!((p.name.as_str(), p.arg.as_str()), ("allow", "hot-path-alloc"));
        assert_eq!(p.note, "cold error path");
        let p = parse_pragma("// lint: ordering: release pairs with acquire", 5).unwrap();
        assert_eq!(p.name, "ordering");
        assert_eq!(p.arg, "release pairs with acquire");
        assert_eq!(p.note, p.arg);
        let bare = parse_pragma("// lint: allow(lock-order)", 6).unwrap();
        assert_eq!(bare.note, "");
        let b = parse_pragma("// lint: boundary(panic-free-serve): engine contract", 7).unwrap();
        assert_eq!((b.name.as_str(), b.arg.as_str()), ("boundary", "panic-free-serve"));
        assert_eq!(b.note, "engine contract");
        assert!(parse_pragma("// just a comment", 1).is_none());
        assert!(parse_pragma("// lint:", 1).is_none());
    }

    #[test]
    fn bare_allow_is_inert() {
        let src = "\
// lint: allow(hot-path-alloc)
fn bare() {}
// lint: allow(hot-path-alloc) — contract text
fn noted() {}
";
        let f = SourceFile::parse("src/x.rs", src);
        assert!(!f.fns[0].allows("hot-path-alloc"));
        assert!(f.fns[1].allows("hot-path-alloc"));
    }

    #[test]
    fn impl_owners_attach_to_methods() {
        let src = "\
struct Bank;
impl Bank {
    fn eval(&self) {}
}
impl std::fmt::Display for Bank {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result { Ok(()) }
}
impl<T: Clone> Iterator for Wrapper<T> {
    fn next(&mut self) -> Option<T> { None }
}
fn free() {}
";
        let f = SourceFile::parse("src/x.rs", src);
        let owner = |name: &str| {
            f.fns
                .iter()
                .find(|x| x.name == name)
                .and_then(|x| x.owner.clone())
        };
        assert_eq!(owner("eval").as_deref(), Some("Bank"));
        assert_eq!(owner("fmt").as_deref(), Some("Bank"));
        assert_eq!(owner("next").as_deref(), Some("Wrapper"));
        assert_eq!(owner("free"), None);
    }

    #[test]
    fn fn_scoping_and_pragmas() {
        let src = "\
// lint: hot-path
#[inline]
pub fn fast(x: &[f32]) -> f32 { x[0] }

fn plain() {}
";
        let f = SourceFile::parse("src/x.rs", src);
        assert_eq!(f.fns.len(), 2);
        assert!(f.fns[0].has_pragma("hot-path"));
        assert_eq!(f.fns[0].name, "fast");
        assert!(!f.fns[1].has_pragma("hot-path"));
    }

    #[test]
    fn innermost_fn_wins() {
        let src = "fn outer() { fn inner() { let y = 1; } let z = 2; }";
        let f = SourceFile::parse("src/x.rs", src);
        let yi = f.toks.iter().position(|t| t.is_ident("y")).unwrap();
        let zi = f.toks.iter().position(|t| t.is_ident("z")).unwrap();
        assert_eq!(f.enclosing_fn(yi).unwrap().name, "inner");
        assert_eq!(f.enclosing_fn(zi).unwrap().name, "outer");
    }

    #[test]
    fn cfg_test_mods_are_marked() {
        let src = "\
fn live() {}
#[cfg(test)]
mod tests {
    #[test]
    fn t() { let inside = 1; }
}
";
        let f = SourceFile::parse("src/x.rs", src);
        let ii = f.toks.iter().position(|t| t.is_ident("inside")).unwrap();
        let li = f.toks.iter().position(|t| t.is_ident("live")).unwrap();
        assert!(f.in_test(ii));
        assert!(!f.in_test(li));
    }

    #[test]
    fn line_pragmas_cover_their_next_code_line() {
        let src = "\
fn f() {
    // lint: timing: latency metric only
    let t = now();
    let u = later();
}
";
        let f = SourceFile::parse("src/x.rs", src);
        assert!(f.line_pragma(3, "timing").is_some());
        assert!(f.line_pragma(4, "timing").is_none());
    }

    #[test]
    fn bodiless_trait_fns_do_not_swallow_items() {
        let src = "trait T { fn a(&self); fn b(&self) { self.a() } } fn c() {}";
        let f = SourceFile::parse("src/x.rs", src);
        let names: Vec<_> = f.fns.iter().map(|x| x.name.as_str()).collect();
        assert_eq!(names, ["a", "b", "c"]);
        assert_eq!(f.fns[0].body.0, f.fns[0].body.1);
    }
}
