//! Item/function scoping and pragma recovery over the token stream.
//!
//! [`SourceFile::parse`] finds every `fn` item (free functions, methods,
//! nested fns, fns inside closures' parents), records its body as a
//! token-index range, and attaches the lint pragmas written in the
//! comment block directly above its signature. It also marks
//! `#[cfg(test)] mod … { … }` ranges so rules can skip test code, and
//! indexes every `// lint: …` comment by line for line-scoped pragmas.
//!
//! ## Pragma vocabulary
//!
//! Function-level (comment block above the `fn`, attributes allowed in
//! between):
//! * `// lint: hot-path` — the hot-path-alloc rule checks this body.
//! * `// lint: thread-body` — the panic-free-serve rule checks this body.
//! * `// lint: rng-region` — the keyed-rng-only rule checks this body.
//! * `// lint: allow(<rule>)` — suppress `<rule>` in this body.
//!
//! Line-level (a comment on the flagged line, or the comment line(s)
//! directly above it):
//! * `// lint: allow(<rule>) — why` — suppress `<rule>` on the next
//!   code line.
//! * `// lint: timing: why` — sanction a wallclock read.
//! * `// lint: ordering: why` — justify a non-`Relaxed` atomic ordering.
//! * `// lint: guarded: why` — sanction an index expression in a
//!   thread body by stating the bounds invariant.

use super::lexer::{lex, Tok, TokKind};

/// One parsed `// lint: …` pragma.
#[derive(Debug, Clone, PartialEq)]
pub struct Pragma {
    /// `hot-path`, `allow`, `timing`, `ordering`, `guarded`, ….
    pub name: String,
    /// `allow(arg)` argument or the text after `name:` (justification).
    pub arg: String,
    /// Line of the comment carrying the pragma.
    pub line: u32,
}

/// Parse a comment's text into a pragma, if it is one. Accepts
/// `// lint: name`, `// lint: name(arg)`, `// lint: name: free text`.
pub fn parse_pragma(comment: &str, line: u32) -> Option<Pragma> {
    let body = comment.trim_start_matches('/').trim_start_matches('!').trim();
    let rest = body.strip_prefix("lint:")?.trim();
    if rest.is_empty() {
        return None;
    }
    let name_end = rest
        .find(|c: char| c == '(' || c == ':' || c.is_whitespace())
        .unwrap_or(rest.len());
    let name = rest[..name_end].to_string();
    let tail = rest[name_end..].trim();
    let arg = if let Some(t) = tail.strip_prefix('(') {
        t.split(')').next().unwrap_or("").trim().to_string()
    } else if let Some(t) = tail.strip_prefix(':') {
        t.trim().to_string()
    } else {
        String::new()
    };
    Some(Pragma { name, arg, line })
}

/// One `fn` item: name, signature line, body token range, attached
/// pragmas.
#[derive(Debug, Clone)]
pub struct Function {
    pub name: String,
    /// Line of the `fn` keyword.
    pub line: u32,
    /// Token-index range of the body, braces included (`start == end`
    /// for bodiless trait-method declarations).
    pub body: (usize, usize),
    pub pragmas: Vec<Pragma>,
}

impl Function {
    pub fn has_pragma(&self, name: &str) -> bool {
        self.pragmas.iter().any(|p| p.name == name)
    }

    pub fn allows(&self, rule: &str) -> bool {
        self.pragmas
            .iter()
            .any(|p| p.name == "allow" && p.arg == rule)
    }
}

/// A lexed + scoped source file, ready for the rules.
#[derive(Debug)]
pub struct SourceFile {
    /// Path with forward slashes, as the walker found it (rules match
    /// on suffixes so the root prefix does not matter).
    pub path: String,
    pub toks: Vec<Tok>,
    pub fns: Vec<Function>,
    /// Every pragma in the file, for line-scoped lookups.
    pub pragmas: Vec<Pragma>,
    /// Token-index ranges of `#[cfg(test)] mod … { … }` bodies.
    pub test_ranges: Vec<(usize, usize)>,
}

impl SourceFile {
    pub fn parse(path: &str, src: &str) -> SourceFile {
        let toks = lex(src);
        let pragmas = toks
            .iter()
            .filter(|t| t.is_comment())
            .filter_map(|t| parse_pragma(&t.text, t.line))
            .collect();
        let mut f = SourceFile {
            path: path.replace('\\', "/"),
            toks,
            fns: Vec::new(),
            pragmas,
            test_ranges: Vec::new(),
        };
        f.scan_items();
        f
    }

    /// Next non-comment token index at or after `i`.
    pub fn sig_at(&self, i: usize) -> Option<usize> {
        (i..self.toks.len()).find(|&j| !self.toks[j].is_comment())
    }

    /// Previous non-comment token index at or before `i`.
    pub fn sig_before(&self, i: usize) -> Option<usize> {
        (0..=i).rev().find(|&j| !self.toks[j].is_comment())
    }

    /// The innermost function whose body contains token `i`.
    pub fn enclosing_fn(&self, i: usize) -> Option<&Function> {
        self.fns
            .iter()
            .filter(|f| f.body.0 <= i && i < f.body.1)
            .min_by_key(|f| f.body.1 - f.body.0)
    }

    /// Is token `i` inside a `#[cfg(test)]` module?
    pub fn in_test(&self, i: usize) -> bool {
        self.test_ranges.iter().any(|&(s, e)| s <= i && i < e)
    }

    /// Line-scoped pragma lookup: a pragma named `name` whose comment
    /// sits on `line` itself, or on a comment line whose next code line
    /// is `line` (stacked comment blocks directly above count).
    pub fn line_pragma(&self, line: u32, name: &str) -> Option<&Pragma> {
        self.pragmas
            .iter()
            .find(|p| p.name == name && self.pragma_covers(p, line))
    }

    fn pragma_covers(&self, p: &Pragma, line: u32) -> bool {
        if p.line == line {
            return true;
        }
        // the first code line after the pragma's comment block
        let next_code = self
            .toks
            .iter()
            .filter(|t| !t.is_comment())
            .map(|t| t.line)
            .find(|&l| l > p.line);
        next_code == Some(line)
    }

    /// Find `fn` items and `#[cfg(test)]` modules.
    fn scan_items(&mut self) {
        let mut fns = Vec::new();
        let mut tests = Vec::new();
        let n = self.toks.len();
        let mut i = 0;
        while i < n {
            let t = &self.toks[i];
            if t.is_ident("fn") {
                // `fn` keyword of an item (a fn-pointer type `fn(…)` has
                // no name ident after it)
                if let Some(ni) = self.sig_at(i + 1) {
                    if self.toks[ni].kind == TokKind::Ident {
                        let name = self.toks[ni].text.clone();
                        let line = t.line;
                        let body = self.fn_body_range(ni + 1);
                        let pragmas = self.fn_pragmas(i);
                        fns.push(Function { name, line, body, pragmas });
                    }
                }
            } else if t.is_punct('#') && self.is_cfg_test(i) {
                if let Some((s, e)) = self.cfg_test_mod_range(i) {
                    tests.push((s, e));
                }
            }
            i += 1;
        }
        self.fns = fns;
        self.test_ranges = tests;
    }

    /// From just after the fn name: skip the signature (parens, generics,
    /// return type, where clause) to the opening `{` of the body and
    /// return the brace-balanced range. `;` at bracket depth 0 means a
    /// bodiless declaration.
    fn fn_body_range(&self, from: usize) -> (usize, usize) {
        let n = self.toks.len();
        let mut depth = 0i32; // () and [] nesting inside the signature
        let mut i = from;
        while i < n {
            let t = &self.toks[i];
            match t.punct() {
                Some('(') | Some('[') => depth += 1,
                Some(')') | Some(']') => depth -= 1,
                Some('{') if depth == 0 => {
                    let end = self.match_brace(i);
                    return (i, end);
                }
                Some(';') if depth == 0 => return (i, i),
                _ => {}
            }
            i += 1;
        }
        (n, n)
    }

    /// Index one past the `}` matching the `{` at `open`.
    fn match_brace(&self, open: usize) -> usize {
        let mut depth = 0i32;
        for i in open..self.toks.len() {
            match self.toks[i].punct() {
                Some('{') => depth += 1,
                Some('}') => {
                    depth -= 1;
                    if depth == 0 {
                        return i + 1;
                    }
                }
                _ => {}
            }
        }
        self.toks.len()
    }

    /// Pragmas attached to the fn at token `fn_idx`: comments in the
    /// contiguous block above it, looking back across attributes and
    /// visibility/qualifier keywords.
    fn fn_pragmas(&self, fn_idx: usize) -> Vec<Pragma> {
        let mut out = Vec::new();
        let mut i = fn_idx;
        while i > 0 {
            i -= 1;
            let t = &self.toks[i];
            if t.is_comment() {
                if let Some(p) = parse_pragma(&t.text, t.line) {
                    out.push(p);
                }
                continue;
            }
            match (t.kind, t.text.as_str()) {
                (TokKind::Ident, "pub" | "unsafe" | "const" | "async" | "extern" | "crate"
                    | "super" | "self" | "in") => continue,
                (TokKind::Str, _) => continue, // extern "C"
                (TokKind::Punct, ")") => {
                    // pub(crate) — walk to the matching (
                    let mut depth = 0i32;
                    while i > 0 {
                        match self.toks[i].punct() {
                            Some(')') => depth += 1,
                            Some('(') => {
                                depth -= 1;
                                if depth == 0 {
                                    break;
                                }
                            }
                            _ => {}
                        }
                        i -= 1;
                    }
                    continue;
                }
                (TokKind::Punct, "]") => {
                    // #[attr…] — walk to the matching [ and its #
                    let mut depth = 0i32;
                    while i > 0 {
                        match self.toks[i].punct() {
                            Some(']') => depth += 1,
                            Some('[') => {
                                depth -= 1;
                                if depth == 0 {
                                    break;
                                }
                            }
                            _ => {}
                        }
                        i -= 1;
                    }
                    if i > 0 && self.toks[i - 1].is_punct('#') {
                        i -= 1;
                    }
                    continue;
                }
                _ => break,
            }
        }
        out.reverse();
        out
    }

    /// Does the `#` at `i` open exactly `#[cfg(test)]`?
    fn is_cfg_test(&self, i: usize) -> bool {
        let want = ["[", "cfg", "(", "test", ")", "]"];
        let mut j = i + 1;
        for w in want {
            match self.sig_at(j) {
                Some(k) if self.toks[k].text == w => j = k + 1,
                _ => return false,
            }
        }
        true
    }

    /// Body range of the `mod … { … }` following the `#[cfg(test)]` at
    /// `i` (other attributes may sit in between).
    fn cfg_test_mod_range(&self, i: usize) -> Option<(usize, usize)> {
        let mut j = i + 1;
        // skip to the end of this attribute
        loop {
            let k = self.sig_at(j)?;
            j = k + 1;
            if self.toks[k].is_punct(']') {
                break;
            }
        }
        // skip further attributes, then expect `mod name {`
        loop {
            let k = self.sig_at(j)?;
            if self.toks[k].is_punct('#') {
                let close = (k..self.toks.len())
                    .find(|&x| self.toks[x].is_punct(']'))?;
                j = close + 1;
                continue;
            }
            if !self.toks[k].is_ident("mod") {
                return None;
            }
            j = k + 1;
            break;
        }
        let name = self.sig_at(j)?;
        let open = self.sig_at(name + 1)?;
        if !self.toks[open].is_punct('{') {
            return None; // `mod tests;` out-of-line — file not walked
        }
        Some((open, self.match_brace(open)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pragma_forms_parse() {
        let p = parse_pragma("// lint: hot-path", 3).unwrap();
        assert_eq!((p.name.as_str(), p.arg.as_str()), ("hot-path", ""));
        let p = parse_pragma("// lint: allow(hot-path-alloc) — cold error path", 4).unwrap();
        assert_eq!((p.name.as_str(), p.arg.as_str()), ("allow", "hot-path-alloc"));
        let p = parse_pragma("// lint: ordering: release pairs with acquire", 5).unwrap();
        assert_eq!(p.name, "ordering");
        assert_eq!(p.arg, "release pairs with acquire");
        assert!(parse_pragma("// just a comment", 1).is_none());
        assert!(parse_pragma("// lint:", 1).is_none());
    }

    #[test]
    fn fn_scoping_and_pragmas() {
        let src = "\
// lint: hot-path
#[inline]
pub fn fast(x: &[f32]) -> f32 { x[0] }

fn plain() {}
";
        let f = SourceFile::parse("src/x.rs", src);
        assert_eq!(f.fns.len(), 2);
        assert!(f.fns[0].has_pragma("hot-path"));
        assert_eq!(f.fns[0].name, "fast");
        assert!(!f.fns[1].has_pragma("hot-path"));
    }

    #[test]
    fn innermost_fn_wins() {
        let src = "fn outer() { fn inner() { let y = 1; } let z = 2; }";
        let f = SourceFile::parse("src/x.rs", src);
        let yi = f.toks.iter().position(|t| t.is_ident("y")).unwrap();
        let zi = f.toks.iter().position(|t| t.is_ident("z")).unwrap();
        assert_eq!(f.enclosing_fn(yi).unwrap().name, "inner");
        assert_eq!(f.enclosing_fn(zi).unwrap().name, "outer");
    }

    #[test]
    fn cfg_test_mods_are_marked() {
        let src = "\
fn live() {}
#[cfg(test)]
mod tests {
    #[test]
    fn t() { let inside = 1; }
}
";
        let f = SourceFile::parse("src/x.rs", src);
        let ii = f.toks.iter().position(|t| t.is_ident("inside")).unwrap();
        let li = f.toks.iter().position(|t| t.is_ident("live")).unwrap();
        assert!(f.in_test(ii));
        assert!(!f.in_test(li));
    }

    #[test]
    fn line_pragmas_cover_their_next_code_line() {
        let src = "\
fn f() {
    // lint: timing: latency metric only
    let t = now();
    let u = later();
}
";
        let f = SourceFile::parse("src/x.rs", src);
        assert!(f.line_pragma(3, "timing").is_some());
        assert!(f.line_pragma(4, "timing").is_none());
    }

    #[test]
    fn bodiless_trait_fns_do_not_swallow_items() {
        let src = "trait T { fn a(&self); fn b(&self) { self.a() } } fn c() {}";
        let f = SourceFile::parse("src/x.rs", src);
        let names: Vec<_> = f.fns.iter().map(|x| x.name.as_str()).collect();
        assert_eq!(names, ["a", "b", "c"]);
        assert_eq!(f.fns[0].body.0, f.fns[0].body.1);
    }
}
